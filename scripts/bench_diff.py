#!/usr/bin/env python3
"""Perf-trajectory guard over BENCH_ci.json (ROADMAP "Perf-trajectory
tracking" item). Two subcommands:

  bench_diff.py diff --baseline PREV.json --current CUR.json [--tolerance 0.25]

    Compare the current CI perf-smoke record against the previous run's
    artifact and FAIL on counter regressions — e.g. `train.divide_values`
    growing back toward the full-row baseline. Direction-aware: watched
    counters declare whether lower or higher is better, and a relative
    tolerance absorbs noise. A missing baseline (first run, expired cache)
    or a baseline missing a newly added counter is skipped with a note,
    never failed — the guard must not brick CI on its own introduction.

  bench_diff.py identical A.json B.json --fields serve.decisions train.svs ...

    Assert that dotted-path fields are exactly equal between two records.
    CI uses it to pin thread-invariance: bench_smoke at --threads 1 and
    --threads 2 must produce bit-identical serve decisions (decision lines
    are printed in round-trip decimal, so string equality is bit equality)
    and identical model shape/accuracy.

Wall-clock fields are deliberately NOT watched: CI machines vary too much
for a tolerance that is both useful and quiet. The counters are the
machine-independent perf trajectory.
"""

import argparse
import json
import os
import sys

# (dotted path, direction) — direction is which way REGRESSION points:
#   lower-better: fail if current > baseline * (1 + tolerance)
#   higher-better: fail if current < baseline * (1 - tolerance)
#   zero: fail unless current == 0 (tolerance-free invariants)
WATCHED = [
    ("train.divide_values", "lower-better"),
    ("train.final_rows", "lower-better"),
    ("train.stitched_values", "higher-better"),
    ("train.cache_hit_rate", "higher-better"),
    ("serve.warm.rows_computed", "zero"),
    # The smoke train run never passes --quant-route and never caps the
    # registry, so quantized kernel values and segment re-gathers must both
    # be exactly 0 — quantization leaking into an exact path, or GC
    # thrashing the live level's working set, fails here.
    ("train.quantized_values", "zero"),
    ("train.segment_regathers", "zero"),
    # Streaming-update trajectory: the warm update's kernel work must not
    # creep back toward the cold-retrain baseline it is measured against.
    ("update.update_values_computed", "lower-better"),
    ("update.cold_values_computed", "lower-better"),
    # No-op invariants (ISSUE 7): an empty-delta `dcsvm update` run must
    # report exactly zero work of every kind, and a replayed batch across a
    # block-preserving hot swap must recompute zero kernel rows.
    ("update.noop.update_values_computed", "zero"),
    ("update.noop.svs_added", "zero"),
    ("update.noop.svs_dropped", "zero"),
    ("serve_swap.post_swap_rows_computed", "zero"),
    # Multiclass (OVO) trajectory (ISSUE 8): the shared-context ensemble's
    # vote accuracy must not decay, the pairwise machine count must not
    # creep (k(k-1)/2 is structural), and a replayed batch against the
    # per-class SV-block cache must compute zero kernel rows.
    ("multiclass.train.accuracy", "higher-better"),
    ("multiclass.train.pair_dispatches", "lower-better"),
    ("multiclass.serve.cold.pair_dispatches", "lower-better"),
    ("multiclass.serve.warm.rows_computed", "zero"),
    # Distributed trajectory (ISSUE 9): wire traffic is the resource the
    # α-summary-only exchange exists to minimize — it must not creep back
    # toward shipping kernel blocks — and the distributed solution must
    # keep matching the single-process one on held-out accuracy.
    ("distributed.comm_bytes", "lower-better"),
    ("distributed.accuracy", "higher-better"),
    # Fault-tolerance invariants (ISSUE 10): a clean distributed run must
    # never trip the recovery machinery — every recovery counter stays 0 —
    # while the fault leg (worker 1 killed mid-round) must keep recovering
    # to the clean run's quality without its recovery cost creeping up
    # (extra replayed rounds or re-shard churn mean detection got slower or
    # the re-shard planner got sloppier).
    ("distributed.workers_lost", "zero"),
    ("distributed.resharded_rows", "zero"),
    ("distributed.rounds_replayed", "zero"),
    ("distributed.respawns", "zero"),
    ("distributed_fault.accuracy", "higher-better"),
    ("distributed_fault.comm_bytes", "lower-better"),
    ("distributed_fault.rounds_replayed", "lower-better"),
    ("distributed_fault.resharded_rows", "lower-better"),
]


def fail(msg: str) -> None:
    print(f"bench_diff: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path: str) -> dict:
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot read {path}: {e}")
        raise AssertionError  # unreachable; keeps type checkers calm


# Sentinel distinguishing a missing key from a legitimate JSON null value
# (e.g. `objective` is null for early-stop runs): null == null must compare
# equal in `identical` mode, while an absent field is an error.
_MISSING = object()


def lookup(obj, dotted: str):
    """Resolve a dotted path; returns _MISSING when any hop is absent."""
    cur = obj
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return _MISSING
        cur = cur[part]
    return cur


def cmd_diff(args) -> None:
    if not os.path.exists(args.baseline):
        print(f"bench_diff: no baseline at {args.baseline}; nothing to diff (first run?)")
        return
    base = load(args.baseline)
    cur = load(args.current)
    tol = args.tolerance
    failures = []
    print(f"bench_diff: {args.current} vs baseline {args.baseline} (tolerance {tol:.0%})")
    for path, direction in WATCHED:
        b, c = lookup(base, path), lookup(cur, path)
        if c is _MISSING or c is None:
            failures.append(f"{path}: missing or null in current record")
            continue
        if direction == "zero":
            # Tolerance-free invariant on the CURRENT record alone — no
            # baseline needed, so it is never skipped on a first run or
            # when the counter is newer than the cached baseline.
            ok = c == 0
            verdict = "ok" if ok else "REGRESSION (must stay 0)"
            print(f"  {path}: current={c} [{direction}] {verdict}")
            if not ok:
                failures.append(f"{path}: current={c} (must stay 0)")
            continue
        if b is _MISSING or b is None:
            print(f"  {path}: no baseline value (new counter?) — skipped")
            continue
        if direction == "lower-better":
            ok = float(c) <= float(b) * (1.0 + tol)
            verdict = "ok" if ok else f"REGRESSION (> baseline +{tol:.0%})"
        else:  # higher-better
            ok = float(c) >= float(b) * (1.0 - tol)
            verdict = "ok" if ok else f"REGRESSION (< baseline -{tol:.0%})"
        print(f"  {path}: baseline={b} current={c} [{direction}] {verdict}")
        if not ok:
            failures.append(f"{path}: baseline={b} current={c} ({direction})")
    if failures:
        fail("counter regressions:\n  " + "\n  ".join(failures))
    print("bench_diff: OK — no counter regressions")


def cmd_identical(args) -> None:
    a, b = load(args.a), load(args.b)
    failures = []
    for path in args.fields:
        va, vb = lookup(a, path), lookup(b, path)
        if va is _MISSING or vb is _MISSING:
            failures.append(
                f"{path}: absent ({args.a}: {va is not _MISSING}, {args.b}: {vb is not _MISSING})"
            )
        elif va != vb:
            failures.append(f"{path}: differs\n    {args.a}: {json.dumps(va)[:200]}\n    {args.b}: {json.dumps(vb)[:200]}")
        else:
            print(f"  {path}: identical")
    if failures:
        fail("records differ:\n  " + "\n  ".join(failures))
    print(f"bench_diff: OK — {len(args.fields)} field(s) bit-identical")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    d = sub.add_parser("diff", help="diff current BENCH_ci.json against a baseline")
    d.add_argument("--baseline", required=True)
    d.add_argument("--current", required=True)
    d.add_argument("--tolerance", type=float, default=0.25,
                   help="relative slack before a counter move counts as a regression")
    d.set_defaults(func=cmd_diff)

    i = sub.add_parser("identical", help="assert dotted fields are equal across two records")
    i.add_argument("a")
    i.add_argument("b")
    i.add_argument("--fields", nargs="+", required=True)
    i.set_defaults(func=cmd_identical)

    args = ap.parse_args()
    args.func(args)


if __name__ == "__main__":
    main()
