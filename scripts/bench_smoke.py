#!/usr/bin/env python3
"""CI perf-smoke: train + serve a small synthetic workload, emit BENCH_ci.json.

Runs the built `dcsvm` binary through the same harness path users hit:

1. `dcsvm train --algo dcsvm ... --save-model model.json` with
   `DCSVM_RESULTS_DIR` set, so the harness appends its structured
   `{config, outcome}` record to `results.jsonl`.
2. `dcsvm serve --model model.json` over stdio, replaying one LIBSVM batch
   twice: the first per-batch stats line is the cold profile, the second
   must be fully warm (`rows_computed == 0`).

The script then assembles BENCH_ci.json:

    {
      "train": {"wall_s", "train_s", "accuracy", "cache_hit_rate",
                "final_rows", "segment_rows", "divide_values",
                "stitched_values", ...},
      "serve": {"cold": {...}, "warm": {...}}
    }

and exits non-zero if any REQUIRED counter is missing or null — a CI guard
that the instrumentation the perf trajectory depends on never silently
disappears.

`--threads N` pins the worker/dispatch thread count for BOTH the train and
serve runs (train `--threads`, serve `--workers`, `DCSVM_THREADS`), and the
serve decision lines land in `serve.decisions` — CI runs the script at 1
and 2 threads, with the SIMD tier auto-detected and with
`DCSVM_FORCE_SCALAR=1`, and asserts the decisions are bit-identical across
all four runs (`scripts/bench_diff.py identical`).

The script also drives the streaming-update legs (ISSUE 7): a zero-SV seed
model is bootstrapped over a labeled history chunk via `dcsvm update`, a
label-flipped drift chunk is absorbed warm with `--compare-cold` retraining
on the cumulative file (gate: the warm update computes strictly fewer
kernel values), an empty-delta update must be a byte-identical no-op with
all counters zero, and a socket server started with `--allow-swap true` is
hot-swapped mid-session — a self-swap keeps every SV block, so the replayed
batch must recompute zero rows. Results land in the REQUIRED `update` and
`serve_swap` sections of BENCH_ci.json, whose zero-invariants
`bench_diff.py` re-checks on every run.

The script also gates `--quant-route`: it trains an early-prediction model,
serves the same 64-row batch with the exact f32 router and with the
int8-quantized router, and fails if the fraction of flipped predicted
labels exceeds QUANT_FLIP_GATE. The result lands in the `quant` section of
BENCH_ci.json.

The multiclass leg (ISSUE 8) trains a 4-class OVO ensemble over the shared
kernel context (`--algo ovo --dataset mc4`), requires the harness record to
carry the `pair_dispatches`/`votes` counters and the ensemble's
vote-accuracy, then serves the saved model over stdio: every batch must
report `pair_dispatches == k(k-1)/2` machines and `votes == machines×rows`,
output lines must be `LABEL margin` with a valid class id, and the warm
replay must compute zero SV-block rows. Results land in the REQUIRED
`multiclass` section of BENCH_ci.json, watched by `bench_diff.py`.

The distributed leg (ISSUE 9) runs `train --distributed true` — the
coordinator spawns 2 local `dcsvm worker` processes, shards the rows,
runs 2 block-minimization rounds exchanging only α summaries, and
conquers — and requires the harness record to carry the `comm_bytes`/
`rounds`/`worker_values_computed` counters with `comm_bytes` staying far
below one serialized kernel block. Results land in the REQUIRED
`distributed` section of BENCH_ci.json; `bench_diff.py` watches
`distributed.comm_bytes` lower-better, and holds the recovery counters
(`workers_lost`, `resharded_rows`, `rounds_replayed`, `respawns`) to
exactly zero on the clean leg.

The fault leg (ISSUE 10) proves recovery end to end through the real
binary: a clean 3-worker reference run at tight eps, then the identical
config with `DCSVM_FAULT=worker:1,round:2,kind:exit` so worker 1 kills
itself mid-round. Gates: the faulted run still exits 0, reports exactly
one lost worker, re-shards its rows (> 0) onto the survivors, replays
the interrupted round, matches the clean run's test accuracy exactly
and its objective within 1e-6 relative. Results land in the REQUIRED
`distributed_fault` section of BENCH_ci.json, watched by
`bench_diff.py`.

Usage: bench_smoke.py [--binary target/release/dcsvm] [--out BENCH_ci.json]
                      [--threads 2]
"""

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

# Outcome fields BENCH_ci.json must carry, and that must be non-null for an
# exact DC-SVM run (see rust/src/harness Outcome::to_json).
REQUIRED_TRAIN = [
    "train_s",
    "accuracy",
    "cache_hit_rate",
    "final_rows",
    "segment_rows",
    "divide_values",
    "stitched_values",
    "parallel_dispatches",
    "stitch_groups",
    "registry_bytes",
    "simd_tier",
    "quantized_values",
    "segment_regathers",
]
# Per-batch serving stats fields (see rust/src/serving BatchStats::to_json).
REQUIRED_SERVE = ["rows", "latency_ms", "cache_hits", "cache_misses", "rows_computed", "hit_rate"]

# Counters the `dcsvm update` stdout JSON must carry on the warm drift leg
# (the `--compare-cold` comparator included). bench_diff.py additionally
# holds the no-op leg's counters to exactly zero.
REQUIRED_UPDATE = [
    "update_values_computed",
    "svs_added",
    "svs_dropped",
    "margin_violations",
    "objective",
    "svs",
    "cold_values_computed",
    "warm_beats_cold",
]

# Distributed-train harness-outcome fields: the wire-efficiency counters
# are the whole point of the leg, and the fault-recovery counters (ISSUE
# 10) must be recorded on EVERY distributed run — zero when clean — so a
# silent counter removal fails here, not in a postmortem.
REQUIRED_DIST = ["train_s", "accuracy", "objective", "comm_bytes", "rounds",
                 "worker_values_computed", "workers_lost", "resharded_rows",
                 "rounds_replayed", "respawns"]
DIST_WORKERS = 2
DIST_ROUNDS = 2
DIST_N_TRAIN = 300
DIST_N_TEST = 100
# Fault leg: 3 spawned workers, worker 1 killed at round 2 via DCSVM_FAULT;
# the run must re-shard onto the survivors and still match the clean
# reference run (exact accuracy, objective within FAULT_OBJ_RTOL relative).
# Tight eps so both conquer solves converge to the same objective.
FAULT_WORKERS = 3
FAULT_SPEC = "worker:1,round:2,kind:exit"
FAULT_EPS = "1e-8"
FAULT_OBJ_RTOL = 1e-6

# Multiclass (OVO) harness-outcome fields: the shared-context pair counters
# must be recorded alongside the usual quality numbers.
REQUIRED_OVO_TRAIN = ["train_s", "accuracy", "svs", "pair_dispatches", "votes"]
# Per-batch serving stats the OVO legs additionally require.
REQUIRED_OVO_SERVE = REQUIRED_SERVE + ["pair_dispatches", "votes"]
OVO_CLASSES = 4
OVO_MACHINES = OVO_CLASSES * (OVO_CLASSES - 1) // 2

# Max fraction of the 64 quant-gate rows whose predicted label may flip
# when routing goes through the int8-quantized sample rows. The per-row
# quantization error bound is scale/2 ≈ (hi-lo)/508, far below the routing
# margins of all but boundary rows — a loose gate that still catches a
# broken quantizer (which flips ~half the batch) without being flaky.
QUANT_FLIP_GATE = 0.15

TRAIN_FLAGS = [
    "--algo", "dcsvm",
    "--dataset", "covtype-like",
    "--n-train", "600",
    "--n-test", "150",
    "--gamma", "16",
    "--c", "4",
    "--levels", "2",
    "--k-base", "4",
    "--sample-m", "64",
    "--backend", "native",
    "--seed", "0",
]


def fail(msg: str) -> None:
    print(f"bench_smoke: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def run(cmd, **kw):
    print("bench_smoke: $", " ".join(cmd), file=sys.stderr)
    return subprocess.run(cmd, check=False, **kw)


def require(obj: dict, keys, what: str) -> dict:
    out = {}
    for k in keys:
        if k not in obj or obj[k] is None:
            fail(f"{what}: required counter '{k}' missing or null in {json.dumps(obj)[:400]}")
        out[k] = obj[k]
    return out


def libsvm_batch(dim: int, rows: int) -> str:
    """Deterministic synthetic LIBSVM rows (values only feed the kernel)."""
    lines = []
    for r in range(rows):
        feats = " ".join(f"{j + 1}:{((r * 31 + j * 7) % 19 - 9) / 10.0:.1f}" for j in range(dim))
        lines.append(f"{1 if r % 2 == 0 else -1} {feats}")
    return "\n".join(lines) + "\n"


def stream_feats(r: int, dim: int):
    """Deterministic pseudo-random feature row in [-1, 1) for stream row r."""
    return [((r * 2654435761 + j * 40503) % 1000) / 500.0 - 1.0 for j in range(dim)]


def libsvm_stream(dim: int, rows: int, start: int = 0, flip: bool = False) -> str:
    """Deterministic LABELED stream rows for the update leg: the label is a
    function of the features (sign of the first three coordinates' sum), so
    the warm/cold solves exercise a real SV structure. `flip` inverts the
    rule — the drift event the warm update has to absorb."""
    lines = []
    for r in range(start, start + rows):
        feats = stream_feats(r, dim)
        label = 1 if sum(feats[:3]) >= 0.0 else -1
        if flip:
            label = -label
        cols = " ".join(f"{j + 1}:{v:.3f}" for j, v in enumerate(feats))
        lines.append(f"{label} {cols}")
    return "\n".join(lines) + "\n"


def update_stdout_json(p, what: str) -> dict:
    """The one JSON line `dcsvm update` prints on stdout."""
    for line in p.stdout.splitlines():
        line = line.strip()
        if line.startswith("{"):
            try:
                return json.loads(line)
            except json.JSONDecodeError:
                continue
    fail(f"{what}: no JSON line on stdout\nstdout:\n{p.stdout}\nstderr:\n{p.stderr}")
    raise AssertionError  # unreachable


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--binary", default="target/release/dcsvm")
    ap.add_argument("--out", default="BENCH_ci.json")
    ap.add_argument("--threads", type=int, default=2,
                    help="worker/dispatch threads for train and serve")
    args = ap.parse_args()

    if not os.path.exists(args.binary):
        fail(f"binary not found: {args.binary} (run `cargo build --release` first)")

    workdir = tempfile.mkdtemp(prefix="dcsvm_bench_smoke_")
    results_dir = os.path.join(workdir, "results")
    model_path = os.path.join(workdir, "model.json")
    threads = str(max(1, args.threads))
    env = dict(os.environ, DCSVM_RESULTS_DIR=results_dir, DCSVM_THREADS=threads)

    # ---- train (harness path; records results.jsonl) ---------------------
    t0 = time.monotonic()
    p = run(
        [args.binary, "train", *TRAIN_FLAGS, "--threads", threads,
         "--save-model", model_path],
        env=env,
        capture_output=True,
        text=True,
    )
    wall_s = time.monotonic() - t0
    if p.returncode != 0:
        fail(f"train exited {p.returncode}\nstdout:\n{p.stdout}\nstderr:\n{p.stderr}")

    results_path = os.path.join(results_dir, "results.jsonl")
    if not os.path.exists(results_path):
        fail(f"DCSVM_RESULTS_DIR produced no {results_path}")
    with open(results_path, encoding="utf-8") as f:
        records = [json.loads(line) for line in f if line.strip()]
    if not records:
        fail("results.jsonl is empty")
    outcome = records[-1].get("outcome")
    if not isinstance(outcome, dict):
        fail("results.jsonl record carries no outcome object")
    train_stats = require(outcome, REQUIRED_TRAIN, "train outcome")
    train_stats["wall_s"] = round(wall_s, 3)
    train_stats["algo"] = outcome.get("algo")
    train_stats["svs"] = outcome.get("svs")
    train_stats["objective"] = outcome.get("objective")

    # ---- serve (stdio transport; cold batch then warm replay) ------------
    with open(model_path, encoding="utf-8") as f:
        dim = json.load(f).get("dim")
    if not isinstance(dim, int) or dim <= 0:
        fail(f"model.json has no usable dim (got {dim!r})")
    batch = libsvm_batch(dim, 64)
    p = run(
        [args.binary, "serve", "--model", model_path, "--batch", "64",
         "--workers", threads, "--backend", "native"],
        env=env,
        input=batch + batch,  # same 64-row batch twice: cold, then warm
        capture_output=True,
        text=True,
    )
    if p.returncode != 0:
        fail(f"serve exited {p.returncode}\nstderr:\n{p.stderr}")
    stats_lines = []
    for line in p.stderr.splitlines():
        line = line.strip()
        if not line.startswith("{"):
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError:
            continue
        if "batch" in obj and "rows" in obj:
            stats_lines.append(obj)
    if len(stats_lines) < 2:
        fail(f"expected 2 per-batch stats lines on stderr, got {len(stats_lines)}:\n{p.stderr}")
    cold = require(stats_lines[0], REQUIRED_SERVE, "cold serve batch")
    warm = require(stats_lines[1], REQUIRED_SERVE, "warm serve batch")
    if warm["rows_computed"] != 0:
        fail(f"warm replay computed {warm['rows_computed']} rows; cross-request cache broken")
    if cold["rows_computed"] <= 0:
        fail("cold batch computed no rows; stats are not being recorded")
    # The decision lines themselves (round-trip decimal, so string equality
    # is bit equality): the thread-invariance CI step compares them between
    # a 1-thread and an N-thread run of this script.
    decisions = [line.strip() for line in p.stdout.splitlines() if line.strip()]
    if len(decisions) != 128:
        fail(f"expected 128 decision lines (2 × 64-row batches), got {len(decisions)}")

    # ---- quant-route gate (early model: int8 routing vs exact f32) -------
    # Train an early-prediction model (router + per-cluster locals), then
    # serve the SAME 64-row batch twice — once with the exact f32 router,
    # once with `--quant-route true`. Routing through int8-quantized sample
    # rows may flip which cluster a boundary row lands in (and hence its
    # predicted label); the gate bounds how many rows that may touch.
    early_flags = list(TRAIN_FLAGS)
    early_flags[early_flags.index("dcsvm")] = "early"
    early_model = os.path.join(workdir, "early_model.json")
    p = run(
        [args.binary, "train", *early_flags, "--threads", threads,
         "--save-model", early_model],
        env=env,
        capture_output=True,
        text=True,
    )
    if p.returncode != 0:
        fail(f"early train exited {p.returncode}\nstderr:\n{p.stderr}")

    def serve_labels(quant: bool):
        cmd = [args.binary, "serve", "--model", early_model, "--batch", "64",
               "--workers", threads, "--backend", "native"]
        if quant:
            cmd += ["--quant-route", "true"]
        q = run(cmd, env=env, input=batch, capture_output=True, text=True)
        if q.returncode != 0:
            fail(f"quant-gate serve (quant={quant}) exited {q.returncode}\nstderr:\n{q.stderr}")
        labels = [line.split()[0] for line in q.stdout.splitlines() if line.strip()]
        if len(labels) != 64:
            fail(f"quant-gate serve (quant={quant}): expected 64 decision lines, got {len(labels)}")
        return labels

    exact_labels = serve_labels(False)
    quant_labels = serve_labels(True)
    flips = sum(1 for a, b in zip(exact_labels, quant_labels) if a != b)
    flip_rate = flips / 64.0
    print(
        f"bench_smoke: quant-route gate: {flips}/64 label flips "
        f"({flip_rate:.1%}, gate {QUANT_FLIP_GATE:.0%})",
        file=sys.stderr,
    )
    if flip_rate > QUANT_FLIP_GATE:
        fail(f"quant-route flipped {flips}/64 predicted labels "
             f"(rate {flip_rate:.2f} > gate {QUANT_FLIP_GATE})")

    # ---- multiclass (OVO) leg: shared-context train -> ensemble serve ----
    # Train all k(k-1)/2 pairwise machines over ONE KernelContext on the
    # synthetic 4-class workload, then serve the saved ensemble: per-batch
    # stats must make the pairwise work visible (pair_dispatches, votes)
    # and a warm replay must compute zero SV-block rows.
    ovo_model = os.path.join(workdir, "ovo_model.json")
    p = run(
        [args.binary, "train", "--algo", "ovo", "--dataset", f"mc{OVO_CLASSES}",
         "--n-train", "400", "--n-test", "120", "--gamma", "2", "--c", "4",
         "--levels", "1", "--sample-m", "32", "--backend", "native",
         "--seed", "0", "--threads", threads, "--save-model", ovo_model],
        env=env,
        capture_output=True,
        text=True,
    )
    if p.returncode != 0:
        fail(f"ovo train exited {p.returncode}\nstdout:\n{p.stdout}\nstderr:\n{p.stderr}")
    with open(results_path, encoding="utf-8") as f:
        records = [json.loads(line) for line in f if line.strip()]
    ovo_outcome = records[-1].get("outcome")
    if not isinstance(ovo_outcome, dict) or ovo_outcome.get("algo") != "ovo":
        fail(f"ovo train recorded no outcome: {json.dumps(records[-1])[:400]}")
    ovo_train = require(ovo_outcome, REQUIRED_OVO_TRAIN, "ovo train outcome")
    if ovo_train["pair_dispatches"] != OVO_MACHINES:
        fail(f"ovo train dispatched {ovo_train['pair_dispatches']} pairs, "
             f"expected {OVO_MACHINES} for {OVO_CLASSES} classes")

    with open(ovo_model, encoding="utf-8") as f:
        ovo_dim = json.load(f).get("dim")
    if not isinstance(ovo_dim, int) or ovo_dim <= 0:
        fail(f"ovo model has no usable dim (got {ovo_dim!r})")
    ovo_batch = libsvm_batch(ovo_dim, 64)
    p = run(
        [args.binary, "serve", "--model", ovo_model, "--batch", "64",
         "--workers", threads, "--backend", "native"],
        env=env,
        input=ovo_batch + ovo_batch,  # same batch twice: cold, then warm
        capture_output=True,
        text=True,
    )
    if p.returncode != 0:
        fail(f"ovo serve exited {p.returncode}\nstderr:\n{p.stderr}")
    ovo_stats = []
    for line in p.stderr.splitlines():
        line = line.strip()
        if not line.startswith("{"):
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError:
            continue
        if "batch" in obj and "rows" in obj:
            ovo_stats.append(obj)
    if len(ovo_stats) < 2:
        fail(f"ovo serve: expected 2 per-batch stats lines, got {len(ovo_stats)}:\n{p.stderr}")
    ovo_cold = require(ovo_stats[0], REQUIRED_OVO_SERVE, "ovo cold serve batch")
    ovo_warm = require(ovo_stats[1], REQUIRED_OVO_SERVE, "ovo warm serve batch")
    for name, st in (("cold", ovo_cold), ("warm", ovo_warm)):
        if st["pair_dispatches"] != OVO_MACHINES:
            fail(f"ovo {name} batch evaluated {st['pair_dispatches']} machines, "
                 f"expected {OVO_MACHINES}")
        if st["votes"] != OVO_MACHINES * 64:
            fail(f"ovo {name} batch cast {st['votes']} votes, "
                 f"expected {OVO_MACHINES * 64}")
    if ovo_warm["rows_computed"] != 0:
        fail(f"ovo warm replay computed {ovo_warm['rows_computed']} rows; "
             "per-class SV-block cache broken")
    if ovo_cold["rows_computed"] <= 0:
        fail("ovo cold batch computed no rows; stats are not being recorded")
    ovo_lines = [line.strip() for line in p.stdout.splitlines() if line.strip()]
    if len(ovo_lines) != 128:
        fail(f"ovo serve: expected 128 output lines, got {len(ovo_lines)}")
    if ovo_lines[:64] != ovo_lines[64:]:
        fail("ovo replay produced different labels/margins than the cold batch")
    for line in ovo_lines[:64]:
        parts = line.split()
        if len(parts) != 2 or not parts[0].isdigit() or int(parts[0]) >= OVO_CLASSES:
            fail(f"ovo output line is not 'LABEL margin' with a valid class id: {line!r}")

    # ---- distributed leg: coordinator + 2 spawned workers ----------------
    # Parallel block minimization end to end through the real binary: the
    # coordinator spawns DIST_WORKERS local `dcsvm worker` processes,
    # shards rows round-robin, exchanges only per-round α summaries, and
    # conquers. Gates: the wire counters exist, comm_bytes stays far below
    # one serialized n×n kernel block (f32), and the worker side actually
    # computed kernel values.
    p = run(
        [args.binary, "train", "--distributed", "true",
         "--workers", str(DIST_WORKERS), "--rounds", str(DIST_ROUNDS),
         "--dataset", "covtype-like", "--n-train", str(DIST_N_TRAIN),
         "--n-test", str(DIST_N_TEST), "--gamma", "16", "--c", "4",
         "--backend", "native", "--seed", "0", "--threads", threads],
        env=env,
        capture_output=True,
        text=True,
    )
    if p.returncode != 0:
        fail(f"distributed train exited {p.returncode}\n"
             f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}")
    with open(results_path, encoding="utf-8") as f:
        records = [json.loads(line) for line in f if line.strip()]
    dist_outcome = records[-1].get("outcome")
    if not isinstance(dist_outcome, dict) or dist_outcome.get("algo") != "Distributed":
        fail(f"distributed train recorded no outcome: {json.dumps(records[-1])[:400]}")
    dist_stats = require(dist_outcome, REQUIRED_DIST, "distributed outcome")
    kernel_block_bytes = DIST_N_TRAIN * DIST_N_TRAIN * 4
    if not 0 < dist_stats["comm_bytes"] < kernel_block_bytes / 4:
        fail(f"distributed comm_bytes {dist_stats['comm_bytes']} not in "
             f"(0, {kernel_block_bytes // 4}): α-summary-only exchange broken")
    if dist_stats["rounds"] != DIST_ROUNDS:
        fail(f"distributed run reported {dist_stats['rounds']} rounds, "
             f"expected {DIST_ROUNDS}")
    if dist_stats["worker_values_computed"] <= 0:
        fail("distributed workers computed no kernel values; "
             "worker counters are not flowing back")
    dist_stats["workers"] = DIST_WORKERS
    dist_stats["kernel_block_bytes"] = kernel_block_bytes
    for counter in ("workers_lost", "resharded_rows", "rounds_replayed", "respawns"):
        if dist_stats[counter] != 0:
            fail(f"clean distributed run reported {counter}={dist_stats[counter]}; "
                 "recovery machinery fired without a fault")

    # ---- fault leg: kill worker 1 mid-round, assert full recovery --------
    # The same distributed config run twice at tight eps: once clean (the
    # reference), once with DCSVM_FAULT making worker 1 exit at round 2.
    # The faulted run must survive by re-sharding the lost rows onto the
    # survivors and replaying the round — and the recovered result must
    # match the reference exactly on accuracy and within FAULT_OBJ_RTOL
    # relative on the dual objective (recovery never costs correctness).
    fault_flags = [args.binary, "train", "--distributed", "true",
                   "--workers", str(FAULT_WORKERS), "--rounds", str(DIST_ROUNDS),
                   "--dataset", "covtype-like", "--n-train", str(DIST_N_TRAIN),
                   "--n-test", str(DIST_N_TEST), "--gamma", "16", "--c", "4",
                   "--eps", FAULT_EPS, "--backend", "native", "--seed", "0",
                   "--threads", threads]

    def dist_record(run_env, what):
        q = run(fault_flags, env=run_env, capture_output=True, text=True)
        if q.returncode != 0:
            fail(f"{what} exited {q.returncode}\nstdout:\n{q.stdout}\nstderr:\n{q.stderr}")
        with open(results_path, encoding="utf-8") as f:
            recs = [json.loads(line) for line in f if line.strip()]
        out = recs[-1].get("outcome")
        if not isinstance(out, dict) or out.get("algo") != "Distributed":
            fail(f"{what} recorded no outcome: {json.dumps(recs[-1])[:400]}")
        return require(out, REQUIRED_DIST, what)

    clean_ref = dist_record(env, "fault-leg clean reference")
    faulted = dist_record(dict(env, DCSVM_FAULT=FAULT_SPEC), "faulted distributed train")
    if faulted["workers_lost"] != 1:
        fail(f"faulted run lost {faulted['workers_lost']} workers, expected exactly 1")
    if faulted["resharded_rows"] <= 0:
        fail("faulted run re-sharded no rows; the lost shard was dropped, not recovered")
    if faulted["rounds_replayed"] < 1:
        fail("faulted run replayed no rounds; the interrupted round was not recovered")
    if faulted["respawns"] != 0:
        fail(f"faulted run respawned {faulted['respawns']} workers with "
             "--worker-retries at its 0 default")
    if faulted["accuracy"] != clean_ref["accuracy"]:
        fail(f"fault recovery changed test accuracy: clean {clean_ref['accuracy']} "
             f"vs faulted {faulted['accuracy']}")
    obj_rel = abs(faulted["objective"] - clean_ref["objective"]) / max(
        1.0, abs(clean_ref["objective"]))
    if obj_rel > FAULT_OBJ_RTOL:
        fail(f"fault recovery moved the objective by {obj_rel:.2e} relative "
             f"(gate {FAULT_OBJ_RTOL:.0e}): clean {clean_ref['objective']} "
             f"vs faulted {faulted['objective']}")
    print(
        f"bench_smoke: fault leg: lost {faulted['workers_lost']:.0f} worker, "
        f"re-sharded {faulted['resharded_rows']:.0f} rows, replayed "
        f"{faulted['rounds_replayed']:.0f} round(s); objective rel diff "
        f"{obj_rel:.2e}, accuracy match",
        file=sys.stderr,
    )
    fault_stats = {
        "workers": FAULT_WORKERS,
        "fault": FAULT_SPEC,
        "clean": clean_ref,
        "faulted": faulted,
        "objective_rel_diff": obj_rel,
        "accuracy": faulted["accuracy"],
        "comm_bytes": faulted["comm_bytes"],
        "resharded_rows": faulted["resharded_rows"],
        "rounds_replayed": faulted["rounds_replayed"],
    }

    # ---- streaming update leg (train -> update -> no-op update) ----------
    # A self-contained labeled stream: bootstrap a model from a zero-SV
    # seed over the history chunk (a warm solve over 0 SVs ∪ history IS a
    # cold train, through the same `dcsvm update` machinery), then absorb a
    # label-flipped drift chunk warm, with `--compare-cold` retraining on
    # the cumulative file as the comparator. Gates: the warm update must
    # compute strictly fewer kernel values than the cold retrain, and an
    # empty-delta update must be a byte-identical no-op with every counter
    # at zero (bench_diff.py re-checks the zeros against this artifact).
    sdim = 8
    history = libsvm_stream(sdim, 192)
    drift = libsvm_stream(sdim, 64, start=192, flip=True)
    history_path = os.path.join(workdir, "history.libsvm")
    drift_path = os.path.join(workdir, "drift.libsvm")
    cumulative_path = os.path.join(workdir, "cumulative.libsvm")
    empty_path = os.path.join(workdir, "empty.libsvm")
    seed_model = os.path.join(workdir, "update_seed.json")
    model1 = os.path.join(workdir, "update_model1.json")
    model2 = os.path.join(workdir, "update_model2.json")
    noop_out = os.path.join(workdir, "update_noop.json")
    with open(history_path, "w", encoding="utf-8") as f:
        f.write(history)
    with open(drift_path, "w", encoding="utf-8") as f:
        f.write(drift)
    with open(cumulative_path, "w", encoding="utf-8") as f:
        f.write(history + drift)
    with open(empty_path, "w", encoding="utf-8") as f:
        f.write("")
    with open(seed_model, "w", encoding="utf-8") as f:
        json.dump({"type": "svm", "kernel": "rbf", "gamma": 0.5, "eta": 0.0,
                   "dim": sdim, "coef": [], "sv_x": []}, f)

    update_base = [args.binary, "update", "--c", "4", "--backend", "native",
                   "--threads", threads]
    p = run([*update_base, "--model", seed_model, "--data", history_path,
             "--out", model1], env=env, capture_output=True, text=True)
    if p.returncode != 0:
        fail(f"bootstrap update exited {p.returncode}\nstderr:\n{p.stderr}")
    boot = update_stdout_json(p, "bootstrap update")
    if not boot.get("svs"):
        fail(f"bootstrap update produced no SVs: {json.dumps(boot)}")

    p = run([*update_base, "--model", model1, "--data", drift_path,
             "--out", model2, "--compare-cold", cumulative_path],
            env=env, capture_output=True, text=True)
    if p.returncode != 0:
        fail(f"warm update exited {p.returncode}\nstderr:\n{p.stderr}")
    warm_update = require(update_stdout_json(p, "warm update"), REQUIRED_UPDATE,
                          "warm update")
    if warm_update["warm_beats_cold"] is not True:
        fail(f"warm update did not beat the cold retrain: {json.dumps(warm_update)}")
    if warm_update["update_values_computed"] <= 0:
        fail("warm update computed no kernel values; counters are not recorded")
    if warm_update["margin_violations"] <= 0:
        fail("label-flipped drift produced no margin violations; the PROCESS gate is dead")

    p = run([*update_base, "--model", model2, "--data", empty_path,
             "--out", noop_out], env=env, capture_output=True, text=True)
    if p.returncode != 0:
        fail(f"no-op update exited {p.returncode}\nstderr:\n{p.stderr}")
    noop = update_stdout_json(p, "no-op update")
    if noop.get("noop") is not True:
        fail(f"empty delta was not reported as a no-op: {json.dumps(noop)}")
    with open(model2, "rb") as f:
        model2_bytes = f.read()
    with open(noop_out, "rb") as f:
        noop_bytes = f.read()
    if model2_bytes != noop_bytes:
        fail("no-op update did not copy the model file byte-identically")
    noop_counters = require(
        noop, ["update_values_computed", "svs_added", "svs_dropped"], "no-op update")
    noop_counters["byte_identical"] = True

    # ---- hot-swap serve leg (socket transport, --allow-swap) -------------
    # Serve the history model over a socket, swap to the drift-updated
    # model mid-session, then self-swap: a self-swap keeps EVERY SV block
    # bit-identical, so the replayed batch must recompute zero rows — the
    # cache entries provably survive the swap.
    import socket as socketlib

    swap_queries = [stream_feats(r, sdim) for r in range(5000, 5032)]
    serve_cmd = [args.binary, "serve", "--model", model1, "--backend", "native",
                 "--workers", threads, "--listen", "127.0.0.1:0",
                 "--allow-swap", "true"]
    print("bench_smoke: $", " ".join(serve_cmd), file=sys.stderr)
    server = subprocess.Popen(serve_cmd, env=env, stdin=subprocess.DEVNULL,
                              stdout=subprocess.DEVNULL, stderr=subprocess.PIPE,
                              text=True)
    try:
        addr = None
        for _ in range(64):
            line = server.stderr.readline()
            if not line:
                break
            line = line.strip()
            if line.startswith("{"):
                try:
                    addr = json.loads(line).get("listening")
                except json.JSONDecodeError:
                    continue
                if addr:
                    break
        if not addr:
            fail("swap serve never announced a listening address")
        host, _, port = addr.rpartition(":")
        conn = socketlib.create_connection((host, int(port)), timeout=30)
        rfile = conn.makefile("r", encoding="utf-8")

        def req(obj, what):
            conn.sendall((json.dumps(obj) + "\n").encode("utf-8"))
            line = rfile.readline()
            if not line:
                fail(f"{what}: server closed the connection")
            resp = json.loads(line)
            if resp.get("error"):
                fail(f"{what}: error response {json.dumps(resp)[:300]}")
            return resp

        cold_swap = req({"x": swap_queries}, "pre-swap decide")
        first_swap = req({"swap_model": model2}, "swap to updated model")
        if first_swap.get("swapped") is not True:
            fail(f"swap did not land: {json.dumps(first_swap)}")
        post_first = req({"x": swap_queries}, "post-swap decide")
        self_swap = req({"swap_model": model2}, "self-swap")
        if self_swap.get("blocks_kept") != self_swap.get("blocks_total"):
            fail(f"self-swap must keep every SV block: {json.dumps(self_swap)}")
        replay = req({"x": swap_queries}, "post-self-swap replay")
        replay_rows = replay.get("stats", {}).get("rows_computed")
        if replay_rows != 0:
            fail(f"replay across a block-preserving swap recomputed {replay_rows} rows")
        totals = req({"stats": True}, "stats").get("stats_total", {})
        if totals.get("swaps") != 2:
            fail(f"server counted {totals.get('swaps')} swaps, expected 2")
        req({"shutdown": True}, "shutdown")
        rfile.close()
        conn.close()
        server.wait(timeout=30)
    finally:
        if server.poll() is None:
            server.kill()

    serve_swap = {
        "queries": len(swap_queries),
        "cold_rows_computed": cold_swap.get("stats", {}).get("rows_computed"),
        "first_swap": {k: first_swap.get(k)
                       for k in ("blocks_total", "blocks_kept", "route_kept", "svs")},
        "self_swap": {k: self_swap.get(k)
                      for k in ("blocks_total", "blocks_kept", "route_kept")},
        "post_swap_rows_computed": replay_rows,
        "post_first_swap_rows_computed":
            post_first.get("stats", {}).get("rows_computed"),
        "swaps": totals.get("swaps"),
    }

    bench = {
        "suite": "ci-perf-smoke",
        "dataset": "covtype-like",
        "threads": int(threads),
        "train": train_stats,
        "serve": {"cold": cold, "warm": warm, "decisions": decisions},
        "update": {
            **{k: warm_update[k] for k in REQUIRED_UPDATE},
            "bootstrap_svs": boot.get("svs"),
            "noop": noop_counters,
        },
        "serve_swap": serve_swap,
        "distributed": dist_stats,
        "distributed_fault": fault_stats,
        "multiclass": {
            "classes": OVO_CLASSES,
            "machines": OVO_MACHINES,
            "train": ovo_train,
            "serve": {"cold": ovo_cold, "warm": ovo_warm,
                      "lines": ovo_lines[:64]},
        },
        "quant": {
            "rows": 64,
            "flips": flips,
            "flip_rate": round(flip_rate, 4),
            "gate": QUANT_FLIP_GATE,
        },
    }
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(bench, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"bench_smoke: OK -> {args.out}", file=sys.stderr)
    print(json.dumps(bench, indent=2, sort_keys=True))
    shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
