#!/usr/bin/env python3
"""CI perf-smoke: train + serve a small synthetic workload, emit BENCH_ci.json.

Runs the built `dcsvm` binary through the same harness path users hit:

1. `dcsvm train --algo dcsvm ... --save-model model.json` with
   `DCSVM_RESULTS_DIR` set, so the harness appends its structured
   `{config, outcome}` record to `results.jsonl`.
2. `dcsvm serve --model model.json` over stdio, replaying one LIBSVM batch
   twice: the first per-batch stats line is the cold profile, the second
   must be fully warm (`rows_computed == 0`).

The script then assembles BENCH_ci.json:

    {
      "train": {"wall_s", "train_s", "accuracy", "cache_hit_rate",
                "final_rows", "segment_rows", "divide_values",
                "stitched_values", ...},
      "serve": {"cold": {...}, "warm": {...}}
    }

and exits non-zero if any REQUIRED counter is missing or null — a CI guard
that the instrumentation the perf trajectory depends on never silently
disappears.

`--threads N` pins the worker/dispatch thread count for BOTH the train and
serve runs (train `--threads`, serve `--workers`, `DCSVM_THREADS`), and the
serve decision lines land in `serve.decisions` — CI runs the script at 1
and 2 threads, with the SIMD tier auto-detected and with
`DCSVM_FORCE_SCALAR=1`, and asserts the decisions are bit-identical across
all four runs (`scripts/bench_diff.py identical`).

The script also gates `--quant-route`: it trains an early-prediction model,
serves the same 64-row batch with the exact f32 router and with the
int8-quantized router, and fails if the fraction of flipped predicted
labels exceeds QUANT_FLIP_GATE. The result lands in the `quant` section of
BENCH_ci.json.

Usage: bench_smoke.py [--binary target/release/dcsvm] [--out BENCH_ci.json]
                      [--threads 2]
"""

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

# Outcome fields BENCH_ci.json must carry, and that must be non-null for an
# exact DC-SVM run (see rust/src/harness Outcome::to_json).
REQUIRED_TRAIN = [
    "train_s",
    "accuracy",
    "cache_hit_rate",
    "final_rows",
    "segment_rows",
    "divide_values",
    "stitched_values",
    "parallel_dispatches",
    "stitch_groups",
    "registry_bytes",
    "simd_tier",
    "quantized_values",
    "segment_regathers",
]
# Per-batch serving stats fields (see rust/src/serving BatchStats::to_json).
REQUIRED_SERVE = ["rows", "latency_ms", "cache_hits", "cache_misses", "rows_computed", "hit_rate"]

# Max fraction of the 64 quant-gate rows whose predicted label may flip
# when routing goes through the int8-quantized sample rows. The per-row
# quantization error bound is scale/2 ≈ (hi-lo)/508, far below the routing
# margins of all but boundary rows — a loose gate that still catches a
# broken quantizer (which flips ~half the batch) without being flaky.
QUANT_FLIP_GATE = 0.15

TRAIN_FLAGS = [
    "--algo", "dcsvm",
    "--dataset", "covtype-like",
    "--n-train", "600",
    "--n-test", "150",
    "--gamma", "16",
    "--c", "4",
    "--levels", "2",
    "--k-base", "4",
    "--sample-m", "64",
    "--backend", "native",
    "--seed", "0",
]


def fail(msg: str) -> None:
    print(f"bench_smoke: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def run(cmd, **kw):
    print("bench_smoke: $", " ".join(cmd), file=sys.stderr)
    return subprocess.run(cmd, check=False, **kw)


def require(obj: dict, keys, what: str) -> dict:
    out = {}
    for k in keys:
        if k not in obj or obj[k] is None:
            fail(f"{what}: required counter '{k}' missing or null in {json.dumps(obj)[:400]}")
        out[k] = obj[k]
    return out


def libsvm_batch(dim: int, rows: int) -> str:
    """Deterministic synthetic LIBSVM rows (values only feed the kernel)."""
    lines = []
    for r in range(rows):
        feats = " ".join(f"{j + 1}:{((r * 31 + j * 7) % 19 - 9) / 10.0:.1f}" for j in range(dim))
        lines.append(f"{1 if r % 2 == 0 else -1} {feats}")
    return "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--binary", default="target/release/dcsvm")
    ap.add_argument("--out", default="BENCH_ci.json")
    ap.add_argument("--threads", type=int, default=2,
                    help="worker/dispatch threads for train and serve")
    args = ap.parse_args()

    if not os.path.exists(args.binary):
        fail(f"binary not found: {args.binary} (run `cargo build --release` first)")

    workdir = tempfile.mkdtemp(prefix="dcsvm_bench_smoke_")
    results_dir = os.path.join(workdir, "results")
    model_path = os.path.join(workdir, "model.json")
    threads = str(max(1, args.threads))
    env = dict(os.environ, DCSVM_RESULTS_DIR=results_dir, DCSVM_THREADS=threads)

    # ---- train (harness path; records results.jsonl) ---------------------
    t0 = time.monotonic()
    p = run(
        [args.binary, "train", *TRAIN_FLAGS, "--threads", threads,
         "--save-model", model_path],
        env=env,
        capture_output=True,
        text=True,
    )
    wall_s = time.monotonic() - t0
    if p.returncode != 0:
        fail(f"train exited {p.returncode}\nstdout:\n{p.stdout}\nstderr:\n{p.stderr}")

    results_path = os.path.join(results_dir, "results.jsonl")
    if not os.path.exists(results_path):
        fail(f"DCSVM_RESULTS_DIR produced no {results_path}")
    with open(results_path, encoding="utf-8") as f:
        records = [json.loads(line) for line in f if line.strip()]
    if not records:
        fail("results.jsonl is empty")
    outcome = records[-1].get("outcome")
    if not isinstance(outcome, dict):
        fail("results.jsonl record carries no outcome object")
    train_stats = require(outcome, REQUIRED_TRAIN, "train outcome")
    train_stats["wall_s"] = round(wall_s, 3)
    train_stats["algo"] = outcome.get("algo")
    train_stats["svs"] = outcome.get("svs")
    train_stats["objective"] = outcome.get("objective")

    # ---- serve (stdio transport; cold batch then warm replay) ------------
    with open(model_path, encoding="utf-8") as f:
        dim = json.load(f).get("dim")
    if not isinstance(dim, int) or dim <= 0:
        fail(f"model.json has no usable dim (got {dim!r})")
    batch = libsvm_batch(dim, 64)
    p = run(
        [args.binary, "serve", "--model", model_path, "--batch", "64",
         "--workers", threads, "--backend", "native"],
        env=env,
        input=batch + batch,  # same 64-row batch twice: cold, then warm
        capture_output=True,
        text=True,
    )
    if p.returncode != 0:
        fail(f"serve exited {p.returncode}\nstderr:\n{p.stderr}")
    stats_lines = []
    for line in p.stderr.splitlines():
        line = line.strip()
        if not line.startswith("{"):
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError:
            continue
        if "batch" in obj and "rows" in obj:
            stats_lines.append(obj)
    if len(stats_lines) < 2:
        fail(f"expected 2 per-batch stats lines on stderr, got {len(stats_lines)}:\n{p.stderr}")
    cold = require(stats_lines[0], REQUIRED_SERVE, "cold serve batch")
    warm = require(stats_lines[1], REQUIRED_SERVE, "warm serve batch")
    if warm["rows_computed"] != 0:
        fail(f"warm replay computed {warm['rows_computed']} rows; cross-request cache broken")
    if cold["rows_computed"] <= 0:
        fail("cold batch computed no rows; stats are not being recorded")
    # The decision lines themselves (round-trip decimal, so string equality
    # is bit equality): the thread-invariance CI step compares them between
    # a 1-thread and an N-thread run of this script.
    decisions = [line.strip() for line in p.stdout.splitlines() if line.strip()]
    if len(decisions) != 128:
        fail(f"expected 128 decision lines (2 × 64-row batches), got {len(decisions)}")

    # ---- quant-route gate (early model: int8 routing vs exact f32) -------
    # Train an early-prediction model (router + per-cluster locals), then
    # serve the SAME 64-row batch twice — once with the exact f32 router,
    # once with `--quant-route true`. Routing through int8-quantized sample
    # rows may flip which cluster a boundary row lands in (and hence its
    # predicted label); the gate bounds how many rows that may touch.
    early_flags = list(TRAIN_FLAGS)
    early_flags[early_flags.index("dcsvm")] = "early"
    early_model = os.path.join(workdir, "early_model.json")
    p = run(
        [args.binary, "train", *early_flags, "--threads", threads,
         "--save-model", early_model],
        env=env,
        capture_output=True,
        text=True,
    )
    if p.returncode != 0:
        fail(f"early train exited {p.returncode}\nstderr:\n{p.stderr}")

    def serve_labels(quant: bool):
        cmd = [args.binary, "serve", "--model", early_model, "--batch", "64",
               "--workers", threads, "--backend", "native"]
        if quant:
            cmd += ["--quant-route", "true"]
        q = run(cmd, env=env, input=batch, capture_output=True, text=True)
        if q.returncode != 0:
            fail(f"quant-gate serve (quant={quant}) exited {q.returncode}\nstderr:\n{q.stderr}")
        labels = [line.split()[0] for line in q.stdout.splitlines() if line.strip()]
        if len(labels) != 64:
            fail(f"quant-gate serve (quant={quant}): expected 64 decision lines, got {len(labels)}")
        return labels

    exact_labels = serve_labels(False)
    quant_labels = serve_labels(True)
    flips = sum(1 for a, b in zip(exact_labels, quant_labels) if a != b)
    flip_rate = flips / 64.0
    print(
        f"bench_smoke: quant-route gate: {flips}/64 label flips "
        f"({flip_rate:.1%}, gate {QUANT_FLIP_GATE:.0%})",
        file=sys.stderr,
    )
    if flip_rate > QUANT_FLIP_GATE:
        fail(f"quant-route flipped {flips}/64 predicted labels "
             f"(rate {flip_rate:.2f} > gate {QUANT_FLIP_GATE})")

    bench = {
        "suite": "ci-perf-smoke",
        "dataset": "covtype-like",
        "threads": int(threads),
        "train": train_stats,
        "serve": {"cold": cold, "warm": warm, "decisions": decisions},
        "quant": {
            "rows": 64,
            "flips": flips,
            "flip_rate": round(flip_rate, 4),
            "gate": QUANT_FLIP_GATE,
        },
    }
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(bench, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"bench_smoke: OK -> {args.out}", file=sys.stderr)
    print(json.dumps(bench, indent=2, sort_keys=True))
    shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
