#!/usr/bin/env python3
"""Intra-repo markdown link/anchor checker (CI `docs` job, `make linkcheck`).

Usage: check_links.py FILE.md [FILE.md ...]

Checks every inline link `[text](target)` in the given markdown files:

- `http(s)://` and `mailto:` targets are skipped (CI runs offline);
- relative file targets must exist (resolved against the linking file's
  directory);
- `#anchor` fragments must match a heading in the target markdown file
  (GitHub slug rules: lowercase, punctuation stripped, spaces to
  hyphens, duplicate headings suffixed -1, -2, ...).

Fenced code blocks and inline code spans are ignored, so example
snippets containing bracket syntax are not treated as links.

Exits non-zero listing every dead link/anchor found.
"""

import os
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*$")
FENCE = re.compile(r"```.*?```", re.S)
CODE_SPAN = re.compile(r"`[^`\n]*`")


def slugify(heading: str) -> str:
    """GitHub-style heading slug."""
    heading = CODE_SPAN.sub(lambda m: m.group(0).strip("`"), heading)
    out = []
    for ch in heading.strip().lower():
        if ch.isalnum():
            out.append(ch)
        elif ch in " -":
            out.append("-")
        elif ch == "_":
            out.append("_")
        # other punctuation: dropped
    return "".join(out)


def heading_slugs(path: str) -> set:
    counts = {}
    slugs = set()
    with open(path, encoding="utf-8") as f:
        text = FENCE.sub("", f.read())
    for line in text.splitlines():
        m = HEADING.match(line)
        if not m:
            continue
        slug = slugify(m.group(2))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def check(files):
    errors = []
    for path in files:
        with open(path, encoding="utf-8") as f:
            text = f.read()
        text = FENCE.sub("", text)
        text = CODE_SPAN.sub("", text)
        for m in LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            file_part, _, frag = target.partition("#")
            base = (
                os.path.join(os.path.dirname(path) or ".", file_part)
                if file_part
                else path
            )
            if file_part and not os.path.exists(base):
                errors.append(f"{path}: dead link {target} (no such file)")
                continue
            if frag:
                if not (os.path.isfile(base) and base.endswith(".md")):
                    continue  # cannot anchor-check non-markdown targets
                if frag.lower() not in heading_slugs(base):
                    errors.append(f"{path}: dead anchor {target}")
    return errors


def main(argv):
    files = argv[1:]
    if not files:
        print(__doc__)
        return 2
    missing = [f for f in files if not os.path.isfile(f)]
    if missing:
        print("no such file(s): " + ", ".join(missing))
        return 2
    errors = check(files)
    for e in errors:
        print(e)
    if errors:
        print(f"FAIL: {len(errors)} dead link(s)/anchor(s)")
        return 1
    print(f"OK: {len(files)} file(s), no dead intra-repo links")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
