//! DC-SVM: the paper's Algorithm 1 — multilevel divide-and-conquer kernel
//! SVM training.
//!
//! ```text
//! for l = l_max … 1:
//!     k_l = k^l clusters
//!     sample m points   (level l_max: from all data;
//!                        below: from the SVs of ᾱ^{(l+1)} — adaptive clustering)
//!     two-step kernel kmeans → partition V_1..V_{k_l}
//!     solve each cluster subproblem warm-started from ᾱ^{(l+1)}
//! refine: solve the SVM restricted to level-1 SVs
//! final:  solve the whole problem warm-started from the refined ᾱ
//! ```
//!
//! The whole run shares **one** [`KernelContext`]: cluster subproblems are
//! solved through [`KernelContext::view`] **segmented** subset views —
//! each cluster's kernel rows are cluster-length partial rows cached under
//! the cluster's `(segment, row)` keys, so the divide phase computes ~n/k
//! kernel values per row instead of n. Everything stays resident for later
//! levels, the refine solve and the final conquer solve, whose full rows
//! are *stitched* from the cached segments (copy the covered columns,
//! compute only the rest) — the cache analogue of the α warm start.
//! `final_rows_computed` / `divide_values_computed` /
//! `segment_rows_computed` in the result quantify the effect, and
//! `segment_views = false` replays the v1 full-row behavior as an ablation
//! baseline (bit-identical α either way — `tests/dcsvm_e2e.rs`).
//!
//! Early stopping after any level yields the early-prediction model
//! (eq. 11): the level's router + per-cluster local models.

pub mod update;

use std::time::Instant;

use crate::cache::KernelContext;
use crate::data::Dataset;
use crate::kernel::{BlockKernel, KernelKind};
use crate::kmeans::{two_step_partition, two_step_partition_restricted, Partition, Router};
use crate::predict::{EarlyModel, SvmModel};
use crate::solver::{SmoConfig, SmoSolver};
use crate::util::prng::Pcg64;
use crate::util::threadpool::{default_threads, scope_map};
use crate::util::timer::Series;

/// Configuration for the multilevel driver.
#[derive(Clone, Debug)]
pub struct DcSvmConfig {
    pub kind: KernelKind,
    pub c: f64,
    /// Number of divide levels l_max (level l has k_base^l clusters).
    /// levels = 4, k_base = 4 reproduces the paper's 256-cluster bottom.
    pub levels: usize,
    pub k_base: usize,
    /// Kernel-kmeans sample size m (paper: 1000).
    pub sample_m: usize,
    /// Subproblem / final stopping tolerances.
    pub eps_sub: f64,
    pub eps_final: f64,
    /// Byte budget of the run's shared kernel-row cache (one
    /// [`KernelContext`] serves the divide, refine and final solves).
    pub cache_bytes: usize,
    /// Sample upper-level kmeans from the current SV set (Algorithm 1).
    pub adaptive: bool,
    /// Solve the level-1-SV-restricted problem before the final solve.
    pub refine: bool,
    /// Stop after finishing this level and return the early model
    /// (None = run to the exact solution; Some(1) = paper's DC-SVM (early)).
    pub stop_after_level: Option<usize>,
    /// Iteration caps (0 = unlimited).
    pub max_iter_sub: usize,
    pub max_iter_final: usize,
    pub seed: u64,
    /// Worker threads for independent cluster subproblems
    /// (default: [`default_threads`]).
    pub threads: usize,
    /// Keep per-level ᾱ snapshots (Figure 2 analysis) and the pre-final ᾱ.
    pub keep_level_alphas: bool,
    /// Solve cluster subproblems over segmented views (cluster-length
    /// kernel rows). `false` replays the v1 full-row behavior — the
    /// ablation baseline; the final α is bit-identical either way.
    pub segment_views: bool,
    /// Byte cap on the context's gathered segment features (0 =
    /// unlimited): once a level is solved and the next level's
    /// registrations push past the cap, the oldest segments drop their
    /// gathered copies (column lists stay, so stitching is unaffected).
    /// The cap is floored at the live level's working set — the driver
    /// marks each level as a registry generation
    /// ([`KernelContext::begin_registry_generation`]), and the GC only
    /// evicts earlier generations, so a level that alone exceeds the cap
    /// cannot thrash re-gathers against itself.
    pub registry_cap_bytes: usize,
    /// Route kmeans assignment passes through int8-quantized sample
    /// operands (`--quant-route`). Approximation-tolerant paths only —
    /// cluster/refine/final solves stay exact; the early model's router
    /// stays quantized for prediction. Decision flips vs the f32 path are
    /// gated in CI.
    pub quant_route: bool,
}

impl Default for DcSvmConfig {
    fn default() -> Self {
        DcSvmConfig {
            kind: KernelKind::Rbf { gamma: 1.0 },
            c: 1.0,
            levels: 4,
            k_base: 4,
            sample_m: 256,
            eps_sub: 1e-3,
            eps_final: 1e-3,
            cache_bytes: 256 << 20,
            adaptive: true,
            refine: true,
            stop_after_level: None,
            max_iter_sub: 0,
            max_iter_final: 0,
            seed: 0,
            threads: default_threads(),
            keep_level_alphas: false,
            segment_views: true,
            registry_cap_bytes: 0,
            quant_route: false,
        }
    }
}

/// The one place all three solver configurations (cluster subproblem,
/// refine, final) are built — they differ only in tolerance, iteration cap
/// and progress cadence.
fn solver_cfg(cfg: &DcSvmConfig, eps: f64, max_iter: usize, report_every: usize) -> SmoConfig {
    SmoConfig {
        c: cfg.c,
        eps,
        max_iter,
        shrinking: true,
        report_every,
        row_batch: 0,
    }
}

/// Per-level record (Table 6 + Figure 2 data).
#[derive(Clone, Debug)]
pub struct LevelStats {
    pub level: usize,
    pub k: usize,
    pub clustering_s: f64,
    pub training_s: f64,
    pub sv_count: usize,
    pub sub_iterations: usize,
    /// Kernel entries evaluated by this level's cluster solves (segmented
    /// views make this ~n/k per computed row instead of n).
    pub values_computed: u64,
    /// ᾱ^{(l)} snapshot if `keep_level_alphas`.
    pub alpha: Option<Vec<f64>>,
    /// Cumulative wall-clock when this level finished.
    pub cumulative_s: f64,
}

/// Training outcome.
pub struct DcSvmResult {
    /// Final α (exact solve) or last-level ᾱ (early stop).
    pub alpha: Vec<f64>,
    /// Objective of `alpha` on the *whole* problem (None if early-stopped
    /// and not evaluated).
    pub objective: Option<f64>,
    pub levels: Vec<LevelStats>,
    pub refine_s: f64,
    pub final_s: f64,
    pub total_s: f64,
    pub final_iterations: usize,
    /// Kernel rows the final (conquer) solve had to compute — strictly
    /// lower than a cold-cache solve because the divide/refine phases left
    /// their rows in the shared context cache.
    pub final_rows_computed: u64,
    /// Kernel entries the final solve evaluated (stitching makes this
    /// lower than `final_rows_computed · n`: covered columns are copied
    /// from divide/refine segment entries, not recomputed).
    pub final_values_computed: u64,
    /// Kernel entries evaluated by divide-phase cluster solves (all
    /// levels; clustering/routing passes excluded). The segment-granularity
    /// headline metric: ≥2× lower at k ≥ 4 than with `segment_views =
    /// false` (`tests/dcsvm_e2e.rs`).
    pub divide_values_computed: u64,
    /// Partial (cluster-segment) kernel rows computed over the run.
    pub segment_rows_computed: u64,
    /// Kernel entries reused by full-row stitching over the run.
    pub stitched_values: u64,
    /// Backend dispatches that fanned out over row panels (> 1 worker).
    pub parallel_dispatches: u64,
    /// Gathered stitch-fill dispatches (grouped prefetch collapses many
    /// stitched rows into one — compare with `stitched_rows` counters in
    /// the context's `ValueStats`).
    pub stitch_groups: u64,
    /// Peak bytes of gathered segment features over the run (the registry
    /// GC's high-water mark; equals the total gathered bytes when no cap
    /// is set).
    pub registry_peak_bytes: u64,
    /// Times a GC-dropped segment had to re-gather its features. With the
    /// per-level generation floor this stays 0 in a normal run even under
    /// a tight `registry_cap_bytes` (`tests/dcsvm_e2e.rs`).
    pub segment_regathers: u64,
    /// Kernel entries evaluated against int8-quantized routing operands
    /// (0 unless `quant_route`).
    pub quantized_values: u64,
    /// Shared-cache counters over the whole run (note/bench reporting).
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// ᾱ as handed to the final solve (kept with `keep_level_alphas`;
    /// lets tests/benches replay the conquer solve on a cold cache).
    pub pre_final_alpha: Option<Vec<f64>>,
    /// Early-prediction model built from the deepest solved level.
    pub early_model: Option<EarlyModel>,
    /// (elapsed, objective) trace of the final whole-problem solve,
    /// time-shifted by the divide-phase cost (Figure 3 series).
    pub trace: Series,
    pub early_stopped: bool,
}

impl DcSvmResult {
    pub fn sv_count(&self) -> usize {
        self.alpha.iter().filter(|&&a| a > 0.0).count()
    }

    /// Hit rate of the run's shared kernel-row cache.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

/// Train DC-SVM. Builds exactly one [`KernelContext`] for the run and
/// threads views through levels → refine → final.
pub fn train(ds: &Dataset, kernel: &dyn BlockKernel, cfg: &DcSvmConfig) -> DcSvmResult {
    assert_eq!(kernel.kind(), cfg.kind, "kernel backend kind mismatch");
    let n = ds.len();
    let t0 = Instant::now();
    let mut rng = Pcg64::new(cfg.seed);
    let ctx = KernelContext::new(ds, kernel, cfg.cache_bytes)
        .with_threads(cfg.threads)
        .with_registry_cap(cfg.registry_cap_bytes)
        .with_quant_route(cfg.quant_route);

    let mut alpha = vec![0f64; n];
    let mut levels = Vec::new();
    let mut last_partition: Option<(Router, Partition)> = None;
    let mut early_stopped = false;
    let mut divide_values = 0u64;

    // ---------------- divide phase: levels l_max .. 1 ----------------------
    for level in (1..=cfg.levels).rev() {
        let k = cfg.k_base.pow(level as u32).min(n.max(1));
        let tl = Instant::now();
        // This level's cluster segments are the live working set: the
        // registry GC may evict earlier levels but never this one.
        ctx.begin_registry_generation();

        // Adaptive sampling pool: SVs of the level below (paper Alg. 1).
        let sv_pool: Option<Vec<usize>> = if cfg.adaptive && level < cfg.levels {
            let pool: Vec<usize> = (0..n).filter(|&i| alpha[i] > 0.0).collect();
            if pool.len() >= cfg.k_base { Some(pool) } else { None }
        } else {
            None
        };
        let (router, part) =
            two_step_partition(&ctx, k, cfg.sample_m, sv_pool.as_deref(), &mut rng);
        let clustering_s = tl.elapsed().as_secs_f64();

        // Solve the k cluster subproblems independently (warm-started)
        // through subset views of the shared context: no dataset copies,
        // and computed rows survive into later phases. Segmented views
        // (the default) fetch cluster-length rows — the divide-phase
        // kernel bill shrinks by roughly the cluster factor.
        let tt = Instant::now();
        let vals0 = ctx.value_stats();
        let scfg = solver_cfg(cfg, cfg.eps_sub, cfg.max_iter_sub, 0);
        let jobs: Vec<Vec<usize>> =
            part.members.iter().filter(|m| !m.is_empty()).cloned().collect();
        // Concurrent cluster solvers split the dispatch thread budget
        // between them — solver-level parallelism already occupies those
        // cores, and uncapped nesting would put threads² workers on the
        // machine. Refine and final (single solves) get the full budget
        // back below.
        let concurrent = cfg.threads.min(jobs.len()).max(1);
        ctx.set_threads((cfg.threads / concurrent).max(1));
        let alpha_ref = &alpha;
        let ctx_ref = &ctx;
        let segment_views = cfg.segment_views;
        let results: Vec<(Vec<usize>, Vec<f64>, usize)> =
            scope_map(cfg.threads, jobs, |_, members| {
                let a0: Vec<f64> = members.iter().map(|&i| alpha_ref[i]).collect();
                let warm = a0.iter().any(|&a| a != 0.0);
                let view = if segment_views {
                    ctx_ref.view(&members)
                } else {
                    ctx_ref.view_unsegmented(&members)
                };
                let res = SmoSolver::new(view, scfg.clone()).solve_warm(
                    if warm { Some(&a0) } else { None },
                    &mut |_| {},
                );
                (members, res.alpha, res.iterations)
            });
        ctx.set_threads(cfg.threads);
        let mut sub_iterations = 0usize;
        for (members, sub_alpha, iters) in results {
            sub_iterations += iters;
            for (t, &i) in members.iter().enumerate() {
                alpha[i] = sub_alpha[t];
            }
        }
        let training_s = tt.elapsed().as_secs_f64();
        let level_values = ctx.value_stats().since(&vals0).values_computed;
        divide_values += level_values;

        let sv_count = alpha.iter().filter(|&&a| a > 0.0).count();
        crate::debug!(
            "level {level}: k={k} clustering {clustering_s:.2}s training {training_s:.2}s svs {sv_count}"
        );
        levels.push(LevelStats {
            level,
            k,
            clustering_s,
            training_s,
            sv_count,
            sub_iterations,
            values_computed: level_values,
            alpha: cfg.keep_level_alphas.then(|| alpha.clone()),
            cumulative_s: t0.elapsed().as_secs_f64(),
        });
        last_partition = Some((router, part));

        if cfg.stop_after_level == Some(level) {
            early_stopped = true;
            break;
        }
    }

    // Early model from the deepest solved level's partition (SV rows and
    // norms gathered straight from the context — no subset copies).
    let early_model = last_partition.map(|(router, part)| {
        let locals: Vec<SvmModel> = part
            .members
            .iter()
            .map(|members| SvmModel::from_alpha_subset(&ctx, members, &alpha))
            .collect();
        EarlyModel::new(router, locals)
    });

    if early_stopped {
        let cs = ctx.stats();
        let vs = ctx.value_stats();
        return DcSvmResult {
            alpha,
            objective: None,
            levels,
            refine_s: 0.0,
            final_s: 0.0,
            total_s: t0.elapsed().as_secs_f64(),
            final_iterations: 0,
            final_rows_computed: 0,
            final_values_computed: 0,
            divide_values_computed: divide_values,
            segment_rows_computed: vs.segment_rows,
            stitched_values: vs.values_stitched,
            parallel_dispatches: vs.parallel_dispatches,
            stitch_groups: vs.stitch_groups,
            registry_peak_bytes: ctx.registry_peak_bytes() as u64,
            segment_regathers: ctx.segment_regathers(),
            quantized_values: vs.quantized_values,
            cache_hits: cs.hits,
            cache_misses: cs.misses,
            pre_final_alpha: None,
            early_model,
            trace: Series::default(),
            early_stopped: true,
        };
    }

    // ---------------- refine step: solve on level-1 SVs --------------------
    let mut refine_s = 0.0;
    if cfg.refine {
        let tr = Instant::now();
        let sv_idx: Vec<usize> = (0..n).filter(|&i| alpha[i] > 0.0).collect();
        if sv_idx.len() >= 2 && sv_idx.len() < n {
            // The SV segment is the new live working set; divide-phase
            // segments become evictable history.
            ctx.begin_registry_generation();
            let a0: Vec<f64> = sv_idx.iter().map(|&i| alpha[i]).collect();
            // The refine solve gets its own SV-set segment: it computes
            // K(SV, SV) instead of K(SV, ·), and the final solve stitches
            // those columns back out of the cache.
            let refine_view = if cfg.segment_views {
                ctx.view(&sv_idx)
            } else {
                ctx.view_unsegmented(&sv_idx)
            };
            let res = SmoSolver::new(
                refine_view,
                solver_cfg(cfg, cfg.eps_sub, cfg.max_iter_sub, 0),
            )
            .solve_warm(Some(&a0), &mut |_| {});
            for (t, &i) in sv_idx.iter().enumerate() {
                alpha[i] = res.alpha[t];
            }
        }
        refine_s = tr.elapsed().as_secs_f64();
    }

    // ---------------- conquer: final whole-problem solve -------------------
    let offset = t0.elapsed().as_secs_f64();
    let tf = Instant::now();
    let mut trace = Series::default();
    let pre_final_alpha = cfg.keep_level_alphas.then(|| alpha.clone());
    let res = SmoSolver::new(
        ctx.view_full(),
        solver_cfg(cfg, cfg.eps_final, cfg.max_iter_final, 2000),
    )
    .solve_warm(Some(&alpha), &mut |p| {
        trace.push(offset + p.elapsed_s, p.objective);
    });
    let final_s = tf.elapsed().as_secs_f64();

    let cs = ctx.stats();
    let vs = ctx.value_stats();
    DcSvmResult {
        alpha: res.alpha,
        objective: Some(res.objective),
        levels,
        refine_s,
        final_s,
        total_s: t0.elapsed().as_secs_f64(),
        final_iterations: res.iterations,
        final_rows_computed: res.rows_computed,
        final_values_computed: res.values_computed,
        divide_values_computed: divide_values,
        segment_rows_computed: vs.segment_rows,
        stitched_values: vs.values_stitched,
        parallel_dispatches: vs.parallel_dispatches,
        stitch_groups: vs.stitch_groups,
        registry_peak_bytes: ctx.registry_peak_bytes() as u64,
        segment_regathers: ctx.segment_regathers(),
        quantized_values: vs.quantized_values,
        cache_hits: cs.hits,
        cache_misses: cs.misses,
        pre_final_alpha,
        early_model,
        trace,
        early_stopped: false,
    }
}

/// Outcome of a [`train_restricted`] run (a member-subset DC-SVM solve
/// over a caller-owned shared context). Indices are LOCAL to the member
/// set; the caller maps them back through its member list.
pub struct RestrictedResult {
    /// Final α over the member subset (local order).
    pub alpha: Vec<f64>,
    /// Objective of the final restricted solve (None if early-stopped).
    pub objective: Option<f64>,
    pub final_iterations: usize,
    pub sub_iterations: usize,
    pub early_stopped: bool,
}

/// [`train`] restricted to a member subset of a **caller-owned**
/// [`KernelContext`] — the one-shared-context multi-class path: every OVO
/// pair trains through this over the SAME context, so kernel rows cached
/// for one pair's segments are stitched into every later pair's.
///
/// Mirrors [`train`] phase-for-phase over LOCAL indices (levels → refine →
/// final), with three deliberate differences:
///
/// * **Labels come from `labels`** (one ±1 per LOCAL member, via
///   [`crate::cache::KernelView::with_labels`]) — the context's dataset
///   carries placeholder labels shared by all pairs.
/// * **Never touches the context's thread budget**: `cfg.threads` here IS
///   this subproblem's dispatch budget, already split by the caller's
///   concurrent-pairs rule, so `--threads N` never nests. Cluster
///   subproblems within the pair run serially on the calling thread
///   (`scope_map(1, ..)` semantics via the budget: the pair-level fan-out
///   is the parallel axis).
/// * **Never starts a registry generation**: generation policy is
///   value-neutral (GC only drops re-gatherable features) and belongs to
///   whoever owns the context's lifecycle.
///
/// Bit-identity with a materialized per-pair run (`tests/multiclass_e2e.rs`)
/// holds because the rng draw sequence depends only on LOCAL pool lengths,
/// sample rows gathered by global index are bitwise the rows a copy would
/// hold, and kernel values are pure per `(x_i, x_j)` at any dispatch shape.
pub fn train_restricted(
    ctx: &KernelContext,
    members: &[usize],
    labels: &[i8],
    cfg: &DcSvmConfig,
) -> RestrictedResult {
    assert_eq!(ctx.kind(), cfg.kind, "kernel backend kind mismatch");
    assert_eq!(members.len(), labels.len(), "one label per member");
    let n = members.len();
    let mut rng = Pcg64::new(cfg.seed);

    let mut alpha = vec![0f64; n];
    let mut sub_iterations = 0usize;
    let mut early_stopped = false;

    // ---------------- divide phase: levels l_max .. 1 ----------------------
    for level in (1..=cfg.levels).rev() {
        let k = cfg.k_base.pow(level as u32).min(n.max(1));

        let sv_pool: Option<Vec<usize>> = if cfg.adaptive && level < cfg.levels {
            let pool: Vec<usize> = (0..n).filter(|&i| alpha[i] > 0.0).collect();
            if pool.len() >= cfg.k_base { Some(pool) } else { None }
        } else {
            None
        };
        let (_router, part) = two_step_partition_restricted(
            ctx,
            k,
            cfg.sample_m,
            members,
            sv_pool.as_deref(),
            &mut rng,
        );

        let scfg = solver_cfg(cfg, cfg.eps_sub, cfg.max_iter_sub, 0);
        let jobs: Vec<Vec<usize>> =
            part.members.iter().filter(|m| !m.is_empty()).cloned().collect();
        let alpha_ref = &alpha;
        let segment_views = cfg.segment_views;
        let results: Vec<(Vec<usize>, Vec<f64>, usize)> =
            scope_map(cfg.threads, jobs, |_, locals| {
                let a0: Vec<f64> = locals.iter().map(|&t| alpha_ref[t]).collect();
                let warm = a0.iter().any(|&a| a != 0.0);
                let globals: Vec<usize> = locals.iter().map(|&t| members[t]).collect();
                let cluster_labels: Vec<i8> = locals.iter().map(|&t| labels[t]).collect();
                let view = if segment_views {
                    ctx.view(&globals).with_labels(cluster_labels)
                } else {
                    ctx.view_unsegmented(&globals).with_labels(cluster_labels)
                };
                let res = SmoSolver::new(view, scfg.clone()).solve_warm(
                    if warm { Some(&a0) } else { None },
                    &mut |_| {},
                );
                (locals, res.alpha, res.iterations)
            });
        for (locals, sub_alpha, iters) in results {
            sub_iterations += iters;
            for (t, &i) in locals.iter().enumerate() {
                alpha[i] = sub_alpha[t];
            }
        }

        if cfg.stop_after_level == Some(level) {
            early_stopped = true;
            break;
        }
    }

    if early_stopped {
        return RestrictedResult {
            alpha,
            objective: None,
            final_iterations: 0,
            sub_iterations,
            early_stopped: true,
        };
    }

    // ---------------- refine step: solve on level-1 SVs --------------------
    if cfg.refine {
        let sv_idx: Vec<usize> = (0..n).filter(|&i| alpha[i] > 0.0).collect();
        if sv_idx.len() >= 2 && sv_idx.len() < n {
            let a0: Vec<f64> = sv_idx.iter().map(|&i| alpha[i]).collect();
            let globals: Vec<usize> = sv_idx.iter().map(|&t| members[t]).collect();
            let sv_labels: Vec<i8> = sv_idx.iter().map(|&t| labels[t]).collect();
            let refine_view = if cfg.segment_views {
                ctx.view(&globals).with_labels(sv_labels)
            } else {
                ctx.view_unsegmented(&globals).with_labels(sv_labels)
            };
            let res = SmoSolver::new(
                refine_view,
                solver_cfg(cfg, cfg.eps_sub, cfg.max_iter_sub, 0),
            )
            .solve_warm(Some(&a0), &mut |_| {});
            for (t, &i) in sv_idx.iter().enumerate() {
                alpha[i] = res.alpha[t];
            }
        }
    }

    // ---------------- conquer: final member-set solve ----------------------
    let final_view = if cfg.segment_views {
        ctx.view(members).with_labels(labels.to_vec())
    } else {
        ctx.view_unsegmented(members).with_labels(labels.to_vec())
    };
    let res = SmoSolver::new(
        final_view,
        solver_cfg(cfg, cfg.eps_final, cfg.max_iter_final, 0),
    )
    .solve_warm(Some(&alpha), &mut |_| {});

    RestrictedResult {
        alpha: res.alpha,
        objective: Some(res.objective),
        final_iterations: res.iterations,
        sub_iterations,
        early_stopped: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate_split};
    use crate::kernel::native::NativeKernel;
    use crate::solver::solve_svm;

    fn setup(n: usize) -> (Dataset, Dataset, NativeKernel, DcSvmConfig) {
        let (tr, te) = generate_split(&covtype_like(), n, n / 4, 42);
        let kind = KernelKind::Rbf { gamma: 16.0 };
        let kern = NativeKernel::new(kind);
        let cfg = DcSvmConfig {
            kind,
            c: 4.0,
            levels: 2,
            k_base: 4,
            sample_m: 64,
            eps_final: 1e-5,
            eps_sub: 1e-3,
            ..Default::default()
        };
        (tr, te, kern, cfg)
    }

    #[test]
    fn reaches_global_optimum() {
        let (tr, _, kern, cfg) = setup(500);
        let dc = train(&tr, &kern, &cfg);
        let direct = solve_svm(
            &tr,
            &kern,
            SmoConfig { c: cfg.c, eps: 1e-5, ..Default::default() },
        );
        let rel = (dc.objective.unwrap() - direct.objective).abs()
            / direct.objective.abs().max(1e-12);
        assert!(rel < 1e-3, "dc {} direct {}", dc.objective.unwrap(), direct.objective);
        assert!(!dc.early_stopped);
        assert_eq!(dc.levels.len(), 2);
        // The shared context saw cross-phase reuse.
        assert!(dc.cache_hits > 0, "no cache hits across phases");
    }

    #[test]
    fn early_stop_produces_working_model() {
        let (tr, te, kern, mut cfg) = setup(600);
        cfg.stop_after_level = Some(1);
        let dc = train(&tr, &kern, &cfg);
        assert!(dc.early_stopped);
        assert!(dc.objective.is_none());
        let em = dc.early_model.expect("early model");
        let acc = em.accuracy(&te, &kern);
        assert!(acc > 0.75, "early model acc {acc}");
    }

    #[test]
    fn warm_start_reduces_final_iterations() {
        let (tr, _, kern, cfg) = setup(500);
        let dc = train(&tr, &kern, &cfg);
        let direct = solve_svm(
            &tr,
            &kern,
            SmoConfig { c: cfg.c, eps: 1e-5, ..Default::default() },
        );
        assert!(
            dc.final_iterations < direct.iterations,
            "final {} vs direct {}",
            dc.final_iterations,
            direct.iterations
        );
    }

    #[test]
    fn deterministic_and_thread_invariant() {
        let (tr, _, kern, mut cfg) = setup(300);
        cfg.stop_after_level = Some(1);
        cfg.keep_level_alphas = true;
        cfg.threads = 1;
        let a = train(&tr, &kern, &cfg);
        cfg.threads = 4;
        let b = train(&tr, &kern, &cfg);
        assert_eq!(a.alpha, b.alpha, "thread count changed the result");
    }

    /// Segment-granular divide must not change the math: the full run
    /// (levels → refine → final) produces bit-identical α with
    /// `segment_views` on and off, while computing strictly fewer kernel
    /// values in the divide phase.
    #[test]
    fn segment_views_bit_identical_and_cheaper() {
        let (tr, _, kern, mut cfg) = setup(500);
        cfg.segment_views = true;
        let seg = train(&tr, &kern, &cfg);
        cfg.segment_views = false;
        let full = train(&tr, &kern, &cfg);
        assert_eq!(seg.alpha, full.alpha, "segmented run changed the solution");
        assert_eq!(seg.final_iterations, full.final_iterations);
        assert!(
            seg.divide_values_computed < full.divide_values_computed,
            "segmented divide computed {} values, full-row {}",
            seg.divide_values_computed,
            full.divide_values_computed
        );
        assert!(seg.segment_rows_computed > 0, "no segment rows recorded");
        assert_eq!(full.segment_rows_computed, 0, "baseline must not use segments");
        assert!(seg.stitched_values > 0, "final solve never stitched");
    }

    /// Satellite: a registry byte cap drops solved levels' gathered
    /// features without changing a single bit of the solution, and the
    /// peak counter records the (lower) high-water mark.
    #[test]
    fn registry_cap_preserves_solution() {
        let (tr, _, kern, mut cfg) = setup(400);
        let full = train(&tr, &kern, &cfg);
        cfg.registry_cap_bytes = 64 << 10; // well below the run's gathered total
        let capped = train(&tr, &kern, &cfg);
        assert_eq!(full.alpha, capped.alpha, "registry GC changed the solution");
        assert_eq!(full.final_iterations, capped.final_iterations);
        assert!(full.registry_peak_bytes > 0, "uncapped peak not recorded");
        assert!(
            capped.registry_peak_bytes < full.registry_peak_bytes,
            "cap did not lower the registry peak: {} vs {}",
            capped.registry_peak_bytes,
            full.registry_peak_bytes
        );
    }

    #[test]
    fn level_stats_recorded() {
        let (tr, _, kern, mut cfg) = setup(400);
        cfg.levels = 3;
        cfg.keep_level_alphas = true;
        let dc = train(&tr, &kern, &cfg);
        assert_eq!(dc.levels.len(), 3);
        assert_eq!(dc.levels[0].level, 3);
        assert_eq!(dc.levels[0].k, 64);
        assert_eq!(dc.levels[2].k, 4);
        for ls in &dc.levels {
            assert!(ls.alpha.is_some());
            assert!(ls.sv_count > 0);
            assert!(ls.values_computed > 0, "level {} computed no values", ls.level);
        }
        assert!(dc.pre_final_alpha.is_some());
    }
}
