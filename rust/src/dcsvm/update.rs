//! Streaming model updates: `dcsvm update` — warm-started incremental
//! re-solves seeded from a trained model's SV set.
//!
//! The paper's key primitive is that a solver warm-started from a smaller
//! problem's support vectors converges in few iterations (the conquer step
//! of Algorithm 1, paper Theorem 2: the SV set is essentially identified
//! early). An update applies the same primitive to *data drift*: the
//! current model's SVs **are** the compressed memory of everything trained
//! so far, so `update` rebuilds the dual problem over `SVs ∪ delta` —
//! orders of magnitude smaller than the cumulative raw stream — and
//! warm-starts SMO from the model's own α (reconstructed from `coef`,
//! since `coef_i = α_i y_i`).
//!
//! The online PROCESS/EVICT idea of [`crate::baselines::lasvm`] is
//! promoted to first class here, in batch form:
//!
//! - **process gate**: each delta row's kernel values against the current
//!   SV set are computed in one batched segment dispatch (the SV prefix is
//!   a registered [`crate::cache::KernelContext`] segment, so the rows
//!   stay cached and stitch into the solve's full rows — none of the gate
//!   work is thrown away). Its gradient `g_p = y_p f(x_p) − 1` classifies
//!   the row as margin-violating (an active insertion, LaSVM PROCESS) or
//!   margin-satisfied (enters at α=0 and is shrunk out almost
//!   immediately);
//! - **warm solve**: one SMO run over `SVs ∪ delta`, warm-started from
//!   the reconstructed α — the conquer-step machinery unchanged;
//! - **evict**: rows ending at α=0 leave the expansion (LaSVM REMOVE) —
//!   [`SvmModel::from_ctx_alpha`] keeps only α>0 rows, and the
//!   [`UpdateResult::svs_dropped`] / [`UpdateResult::svs_added`] counters
//!   report the churn.
//!
//! An **empty delta is a bit-identical no-op**: the caller's model passes
//! through untouched and every counter stays 0 (`scripts/bench_diff.py`
//! gates this invariant in CI; the CLI additionally copies the model file
//! bytes verbatim so the emitted JSON is byte-identical).
//!
//! `tests/streaming_update.rs` drives the drift scenario end-to-end:
//! accuracy recovers after each drift chunk, and every warm update
//! computes strictly fewer kernel values than a cold retrain on the same
//! cumulative data ([`cold_solve`] is the comparator, and the
//! `--compare-cold` CLI flag gates the same claim in `bench-smoke` CI).

use std::time::Instant;

use anyhow::{bail, Result};

use crate::cache::KernelContext;
use crate::data::Dataset;
use crate::kernel::BlockKernel;
use crate::predict::SvmModel;
use crate::solver::objective::projected_violation;
use crate::solver::{SmoConfig, SmoSolver};
use crate::util::threadpool::default_threads;

/// Configuration of one incremental update (and of its cold comparator).
#[derive(Clone, Debug)]
pub struct UpdateConfig {
    /// Box constraint C. Seed α from the model are clamped into `[0, C]`.
    pub c: f64,
    /// KKT stopping tolerance.
    pub eps: f64,
    /// Hard iteration cap (0 = unlimited).
    pub max_iter: usize,
    /// Byte budget of the update's kernel-row cache.
    pub cache_bytes: usize,
    /// Worker budget for panel-parallel kernel dispatches (0 = all cores).
    pub threads: usize,
}

impl Default for UpdateConfig {
    fn default() -> Self {
        UpdateConfig {
            c: 1.0,
            eps: 1e-3,
            max_iter: 0,
            cache_bytes: crate::cache::DEFAULT_CACHE_BYTES,
            threads: 0,
        }
    }
}

/// Outcome of one incremental update.
#[derive(Clone, Debug)]
pub struct UpdateResult {
    /// The updated model (SVs of the warm re-solve).
    pub model: SvmModel,
    /// Dual objective of the update subproblem (`SVs ∪ delta`).
    pub objective: f64,
    pub iterations: usize,
    pub elapsed_s: f64,
    /// Kernel entries evaluated during the whole update (process gate +
    /// warm solve) — the `update_values_computed` counter of the harness
    /// `Outcome` and `BENCH_ci.json`.
    pub values_computed: u64,
    /// Delta rows that ended as support vectors.
    pub svs_added: u64,
    /// Old SVs whose α fell to 0 (evicted from the expansion).
    pub svs_dropped: u64,
    /// Delta rows violating the old model's margin (LaSVM PROCESS
    /// insertions); the rest entered margin-satisfied at α=0.
    pub margin_violations: u64,
    /// True when the delta was empty and the model passed through
    /// untouched (all counters 0).
    pub noop: bool,
}

impl UpdateResult {
    fn noop(model: SvmModel) -> UpdateResult {
        UpdateResult {
            model,
            objective: 0.0,
            iterations: 0,
            elapsed_s: 0.0,
            values_computed: 0,
            svs_added: 0,
            svs_dropped: 0,
            margin_violations: 0,
            noop: true,
        }
    }
}

/// Reconstruct the dual seed of a model: each SV becomes a dataset row
/// labeled `sign(coef)`, with `α = |coef|` clamped into `[0, c]`
/// (`coef_i = α_i y_i`, so the pair is exact up to the clamp when the
/// update's C differs from the training C).
pub fn seed_from_model(model: &SvmModel, c: f64) -> (Dataset, Vec<f64>) {
    let n_sv = model.num_svs();
    let mut y = Vec::with_capacity(n_sv);
    let mut alpha = Vec::with_capacity(n_sv);
    for &cf in &model.coef {
        y.push(if cf >= 0.0 { 1i8 } else { -1i8 });
        alpha.push((cf.abs() as f64).clamp(0.0, c));
    }
    let ds = Dataset::new(model.sv_x.clone(), y, model.dim, "update-seed");
    (ds, alpha)
}

/// Apply one incremental update: warm-started SMO over `SVs(model) ∪
/// delta`, through one [`KernelContext`] whose SV-prefix segment caches
/// the process-gate rows for the solve's stitching path.
pub fn update(
    model: &SvmModel,
    delta: &Dataset,
    kernel: &dyn BlockKernel,
    cfg: &UpdateConfig,
) -> Result<UpdateResult> {
    if kernel.kind() != model.kind {
        bail!("update: kernel {:?} does not match model {:?}", kernel.kind(), model.kind);
    }
    if delta.is_empty() {
        return Ok(UpdateResult::noop(model.clone()));
    }
    if delta.dim != model.dim {
        bail!("update: delta dim {} does not match model dim {}", delta.dim, model.dim);
    }
    let t0 = Instant::now();
    let (seed_ds, seed_alpha) = seed_from_model(model, cfg.c);
    let n_sv = seed_ds.len();
    let working = seed_ds.appended(delta, "update-working");
    let n = working.len();
    let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };
    let ctx = KernelContext::new(&working, kernel, cfg.cache_bytes).with_threads(threads);

    // LaSVM PROCESS gate, batched: kernel rows of every delta point against
    // the SV prefix in one segment dispatch. The segment entries stay
    // cached, so the solve's full rows stitch them back in — the gate's
    // kernel work is reused, not repeated.
    let mut margin_violations = 0u64;
    if n_sv > 0 {
        let sv_cols: Vec<usize> = (0..n_sv).collect();
        let seg = ctx.register_segment(&sv_cols);
        let delta_rows: Vec<usize> = (n_sv..n).collect();
        ctx.compute_segment_rows(&seg, &delta_rows);
        for &p in &delta_rows {
            let krow = ctx.segment_row(&seg, p);
            // g_p = y_p Σ_j coef_j K_pj − 1  (coef_j = α_j y_j).
            let yp = working.y[p] as f64;
            let mut g = -1.0;
            for (t, &cf) in model.coef.iter().enumerate() {
                g += yp * cf as f64 * krow[t] as f64;
            }
            if projected_violation(0.0, g, cfg.c) > 0.0 {
                margin_violations += 1;
            }
        }
    }

    // Warm conquer-style solve over the whole expansion.
    let mut alpha0 = seed_alpha;
    alpha0.resize(n, 0.0);
    let smo = SmoConfig {
        c: cfg.c,
        eps: cfg.eps,
        max_iter: cfg.max_iter,
        shrinking: true,
        report_every: 0,
        row_batch: 0,
    };
    let res = SmoSolver::new(ctx.view_full(), smo).solve_warm(Some(&alpha0), &mut |_| {});

    let svs_dropped = (0..n_sv).filter(|&i| res.alpha[i] == 0.0).count() as u64;
    let svs_added = (n_sv..n).filter(|&i| res.alpha[i] > 0.0).count() as u64;
    let updated = SvmModel::from_ctx_alpha(&ctx, &res.alpha);
    Ok(UpdateResult {
        model: updated,
        objective: res.objective,
        iterations: res.iterations,
        elapsed_s: t0.elapsed().as_secs_f64(),
        values_computed: ctx.value_stats().values_computed,
        svs_added,
        svs_dropped,
        margin_violations,
        noop: false,
    })
}

/// Outcome of a cold from-scratch solve (the comparator a warm update is
/// measured against).
#[derive(Clone, Debug)]
pub struct ColdResult {
    pub model: SvmModel,
    pub objective: f64,
    pub iterations: usize,
    pub elapsed_s: f64,
    /// Kernel entries evaluated by the cold solve.
    pub values_computed: u64,
}

/// Cold comparator: solve `data` from scratch (no warm seed) with the
/// same solver settings, counting kernel values. The drift e2e and the
/// `--compare-cold` CLI flag assert a warm [`update`] computes strictly
/// fewer values than this on the same cumulative data.
pub fn cold_solve(data: &Dataset, kernel: &dyn BlockKernel, cfg: &UpdateConfig) -> ColdResult {
    let t0 = Instant::now();
    let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };
    let ctx = KernelContext::new(data, kernel, cfg.cache_bytes).with_threads(threads);
    let smo = SmoConfig {
        c: cfg.c,
        eps: cfg.eps,
        max_iter: cfg.max_iter,
        shrinking: true,
        report_every: 0,
        row_batch: 0,
    };
    let res = SmoSolver::new(ctx.view_full(), smo).solve();
    ColdResult {
        model: SvmModel::from_ctx_alpha(&ctx, &res.alpha),
        objective: res.objective,
        iterations: res.iterations,
        elapsed_s: t0.elapsed().as_secs_f64(),
        values_computed: ctx.value_stats().values_computed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate};
    use crate::kernel::native::NativeKernel;
    use crate::kernel::KernelKind;
    use crate::util::prng::Pcg64;

    fn setup(n: usize, seed: u64) -> (Dataset, NativeKernel) {
        let mut rng = Pcg64::new(seed);
        let ds = generate(&covtype_like(), n, &mut rng);
        let k = NativeKernel::new(KernelKind::Rbf { gamma: 8.0 });
        (ds, k)
    }

    fn train_base(ds: &Dataset, kernel: &dyn BlockKernel, cfg: &UpdateConfig) -> SvmModel {
        cold_solve(ds, kernel, cfg).model
    }

    #[test]
    fn empty_delta_is_a_noop() {
        let (ds, k) = setup(60, 5);
        let cfg = UpdateConfig { cache_bytes: 8 << 20, threads: 1, ..UpdateConfig::default() };
        let model = train_base(&ds, &k, &cfg);
        let empty = Dataset::new(Vec::new(), Vec::new(), ds.dim, "empty");
        let res = update(&model, &empty, &k, &cfg).unwrap();
        assert!(res.noop);
        assert_eq!(res.values_computed, 0);
        assert_eq!((res.svs_added, res.svs_dropped), (0, 0));
        // Bit-identical pass-through, JSON included.
        assert_eq!(res.model.to_json().to_string(), model.to_json().to_string());
        assert_eq!(
            res.model.sv_x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            model.sv_x.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn update_learns_the_delta_and_counts_work() {
        let (ds, k) = setup(90, 6);
        let cfg = UpdateConfig { cache_bytes: 8 << 20, threads: 1, ..UpdateConfig::default() };
        let (base, rest) = {
            let idx_a: Vec<usize> = (0..60).collect();
            let idx_b: Vec<usize> = (60..90).collect();
            (ds.subset(&idx_a, "base"), ds.subset(&idx_b, "delta"))
        };
        let model = train_base(&base, &k, &cfg);
        let res = update(&model, &rest, &k, &cfg).unwrap();
        assert!(!res.noop);
        assert!(res.values_computed > 0);
        assert!(res.model.num_svs() > 0);
        assert_eq!(
            res.model.num_svs() as u64,
            model.num_svs() as u64 + res.svs_added - res.svs_dropped
        );
        // The updated model classifies the delta at least as well as the
        // stale one (it trained on it).
        let stale = model.accuracy(&rest, &k);
        let fresh = res.model.accuracy(&rest, &k);
        assert!(
            fresh >= stale - 1e-9,
            "update hurt delta accuracy: {fresh} < {stale}"
        );
    }

    #[test]
    fn warm_update_beats_cold_on_kernel_values() {
        let (ds, k) = setup(120, 7);
        let cfg = UpdateConfig { cache_bytes: 8 << 20, threads: 1, ..UpdateConfig::default() };
        let base_idx: Vec<usize> = (0..90).collect();
        let delta_idx: Vec<usize> = (90..120).collect();
        let base = ds.subset(&base_idx, "base");
        let delta = ds.subset(&delta_idx, "delta");
        let model = train_base(&base, &k, &cfg);
        let warm = update(&model, &delta, &k, &cfg).unwrap();
        let cold = cold_solve(&ds, &k, &cfg);
        assert!(
            warm.values_computed < cold.values_computed,
            "warm update ({}) did not beat cold retrain ({})",
            warm.values_computed,
            cold.values_computed
        );
    }

    #[test]
    fn rejects_mismatched_kernel_and_dim() {
        let (ds, k) = setup(40, 8);
        let cfg = UpdateConfig { cache_bytes: 8 << 20, threads: 1, ..UpdateConfig::default() };
        let model = train_base(&ds, &k, &cfg);
        let other = NativeKernel::new(KernelKind::Rbf { gamma: 2.0 });
        let delta = ds.subset(&[0, 1], "delta");
        assert!(update(&model, &delta, &other, &cfg).is_err());
        let bad_dim = Dataset::new(vec![0.0; 4], vec![1, -1], 2, "bad");
        assert!(update(&model, &bad_dim, &k, &cfg).is_err());
    }
}
