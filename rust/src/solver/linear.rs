//! Linear SVM via dual coordinate descent (Hsieh et al. 2008 — the
//! LIBLINEAR algorithm the paper uses as the second stage of LLSVM,
//! FastFood and LTPU).
//!
//! L1-loss dual (no bias, matching the paper's setting):
//!
//! ```text
//! min_α ½ αᵀ Q̄ α − eᵀα,  0 ≤ α ≤ C,  Q̄_ij = y_i y_j x_iᵀ x_j
//! ```
//!
//! maintaining the primal vector w = Σ_i α_i y_i x_i so each coordinate
//! update is O(d): G_i = y_i wᵀx_i − 1, α_i ← clip(α_i − G_i/‖x_i‖²),
//! w += Δα_i y_i x_i. Epochs visit coordinates in a random permutation with
//! the standard active-set shrinking of bound variables.

use crate::data::Dataset;
use crate::util::prng::Pcg64;

#[derive(Clone, Debug)]
pub struct LinearSvmConfig {
    pub c: f64,
    pub eps: f64,
    pub max_epochs: usize,
    pub seed: u64,
}

impl Default for LinearSvmConfig {
    fn default() -> Self {
        LinearSvmConfig { c: 1.0, eps: 1e-3, max_epochs: 1000, seed: 0 }
    }
}

/// Trained linear model (weights over the feature space the caller supplied
/// — raw input features, Nyström features, Fourier features, ...).
#[derive(Clone, Debug)]
pub struct LinearModel {
    pub w: Vec<f64>,
    pub alpha: Vec<f64>,
    pub epochs: usize,
    pub elapsed_s: f64,
}

impl LinearModel {
    #[inline]
    pub fn decision(&self, x: &[f32]) -> f64 {
        x.iter().zip(&self.w).map(|(&xi, &wi)| xi as f64 * wi).sum()
    }

    pub fn predict(&self, x: &[f32]) -> i8 {
        if self.decision(x) >= 0.0 {
            1
        } else {
            -1
        }
    }

    pub fn accuracy(&self, ds: &Dataset) -> f64 {
        let correct = (0..ds.len())
            .filter(|&i| self.predict(ds.row(i)) == ds.y[i])
            .count();
        correct as f64 / ds.len().max(1) as f64
    }
}

/// Train with dual CD.
pub fn train_linear(ds: &Dataset, cfg: &LinearSvmConfig) -> LinearModel {
    let t0 = std::time::Instant::now();
    let n = ds.len();
    let d = ds.dim;
    let c = cfg.c;
    let mut rng = Pcg64::new(cfg.seed);

    let sq: Vec<f64> = (0..n)
        .map(|i| ds.row(i).iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().max(1e-12))
        .collect();

    let mut alpha = vec![0f64; n];
    let mut w = vec![0f64; d];
    let mut active: Vec<usize> = (0..n).collect();
    let mut epochs = 0usize;

    // Shrinking bounds on the projected gradient (LIBLINEAR §4).
    let mut m_bar = f64::INFINITY;
    let mut m_low = f64::NEG_INFINITY;

    while epochs < cfg.max_epochs {
        epochs += 1;
        rng.shuffle(&mut active);
        let mut max_pg = f64::NEG_INFINITY;
        let mut min_pg = f64::INFINITY;
        let mut removed = Vec::new();

        for (pos, &i) in active.iter().enumerate() {
            let yi = ds.y[i] as f64;
            let xi = ds.row(i);
            let g = yi * xi.iter().zip(&w).map(|(&x, &wv)| x as f64 * wv).sum::<f64>() - 1.0;

            // projected gradient + shrinking test
            let pg = if alpha[i] <= 0.0 {
                if g > m_bar {
                    removed.push(pos);
                    continue;
                }
                g.min(0.0)
            } else if alpha[i] >= c {
                if g < m_low {
                    removed.push(pos);
                    continue;
                }
                g.max(0.0)
            } else {
                g
            };
            max_pg = max_pg.max(pg);
            min_pg = min_pg.min(pg);

            if pg.abs() > 1e-14 {
                let old = alpha[i];
                alpha[i] = (old - g / sq[i]).clamp(0.0, c);
                let da = (alpha[i] - old) * yi;
                if da != 0.0 {
                    for (j, &x) in xi.iter().enumerate() {
                        w[j] += da * x as f64;
                    }
                }
            }
        }

        for &pos in removed.iter().rev() {
            active.swap_remove(pos);
        }

        if max_pg - min_pg < cfg.eps {
            if active.len() == n {
                break;
            }
            // converged on the shrunk set: restore and loosen bounds
            active = (0..n).collect();
            m_bar = f64::INFINITY;
            m_low = f64::NEG_INFINITY;
        } else {
            m_bar = if max_pg <= 0.0 { f64::INFINITY } else { max_pg };
            m_low = if min_pg >= 0.0 { f64::NEG_INFINITY } else { min_pg };
        }
    }

    LinearModel { w, alpha, epochs, elapsed_s: t0.elapsed().as_secs_f64() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, kddcup99_like};
    use crate::util::prng::Pcg64;

    fn linearly_separable(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Pcg64::new(seed);
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let label: i8 = if rng.next_f64() < 0.5 { 1 } else { -1 };
            for j in 0..d {
                let shift = if j == 0 { label as f64 * 1.5 } else { 0.0 };
                x.push((rng.next_gaussian() * 0.4 + shift) as f32);
            }
            y.push(label);
        }
        Dataset::new(x, y, d, "sep")
    }

    #[test]
    fn separates_separable_data() {
        let ds = linearly_separable(400, 6, 1);
        let m = train_linear(&ds, &LinearSvmConfig { c: 10.0, ..Default::default() });
        assert!(m.accuracy(&ds) > 0.97, "acc {}", m.accuracy(&ds));
    }

    #[test]
    fn feasible_dual_and_primal_consistency() {
        let ds = linearly_separable(150, 4, 2);
        let cfg = LinearSvmConfig { c: 2.0, ..Default::default() };
        let m = train_linear(&ds, &cfg);
        assert!(m.alpha.iter().all(|&a| (0.0..=cfg.c).contains(&a)));
        // w must equal Σ α_i y_i x_i
        let mut w = vec![0f64; ds.dim];
        for i in 0..ds.len() {
            for j in 0..ds.dim {
                w[j] += m.alpha[i] * ds.y[i] as f64 * ds.row(i)[j] as f64;
            }
        }
        for j in 0..ds.dim {
            assert!((w[j] - m.w[j]).abs() < 1e-8, "w[{j}]");
        }
    }

    #[test]
    fn works_on_synthetic_dataset() {
        let mut rng = Pcg64::new(3);
        let ds = generate(&kddcup99_like(), 800, &mut rng);
        let m = train_linear(&ds, &LinearSvmConfig { c: 1.0, ..Default::default() });
        // kddcup-like is nearly separable => linear SVM should do very well
        assert!(m.accuracy(&ds) > 0.95, "acc {}", m.accuracy(&ds));
    }

    #[test]
    fn epochs_bounded() {
        let ds = linearly_separable(100, 3, 4);
        let m = train_linear(
            &ds,
            &LinearSvmConfig { max_epochs: 2, eps: 1e-12, ..Default::default() },
        );
        assert!(m.epochs <= 2);
    }
}
