//! SVM solvers: the exact greedy-CD (SMO-style) dual solver (`smo`) — our
//! LIBSVM-equivalent and the DC-SVM sub/whole-problem solver — plus a
//! LIBLINEAR-style linear dual CD (`linear`) used by the feature-map
//! baselines, and exact objective/KKT utilities with a brute-force
//! reference QP (`objective`).

pub mod linear;
pub mod objective;
pub mod smo;

pub use smo::{solve_svm, SmoConfig, SmoResult, SmoSolver};
