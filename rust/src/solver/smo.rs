//! Exact dual solver: greedy coordinate descent with shrinking over a
//! [`KernelView`] — the algorithm class of LIBSVM, specialized to the
//! paper's no-bias formulation (dual box constraints only, no equality
//! constraint).
//!
//! This solver plays two roles in the reproduction:
//! 1. run cold on the whole problem (an identity view), it **is** the
//!    "LIBSVM" comparator of the paper's tables (same greedy working-set
//!    selection, shrinking, cache-bounded kernel access, ε-KKT stopping);
//! 2. warm-started from ᾱ, it is the conquer step of DC-SVM, and it solves
//!    every cluster subproblem in the divide step through a subset view.
//!
//! Kernel access goes through the view's shared [`KernelContext`]. A
//! **segmented** view (cluster subproblem) fetches local-indexed partial
//! rows `K(x_i, members)` — cluster-length, so the divide phase computes
//! and caches ~n/k values per row instead of n; a full or unsegmented view
//! fetches full dataset-length rows (stitched from cached segments where
//! possible). Either way, everything a solve computes stays resident for
//! the refine and final solves (cross-phase reuse — the cache analogue of
//! the α warm start). The solver owns no cache;
//! `rows_computed`/`values_computed`/`cache_hit_rate` are per-solve counter
//! deltas of the shared cache (attribution is exact for solves that run
//! alone, approximate for concurrent divide-phase solves).
//!
//! Iteration: pick i with the largest projected-KKT violation, fetch kernel
//! row i (shared cache → block-kernel backend → AOT artifact via PJRT),
//! take the exact coordinate minimizer δ = clip(α_i − g_i/Q_ii) − α_i,
//! update the maintained gradient g = Qα − e over the active set. Shrinking
//! removes bound variables whose KKT conditions are strongly satisfied; on
//! apparent convergence the full gradient is reconstructed from the support
//! vectors (O(n·|S|) via the fused decision kernel) and optimality is
//! re-verified on the full set — so the returned solution is an exact
//! ε-solution of the *unshrunk* problem.

use std::time::Instant;

use crate::cache::{KernelContext, KernelView, DEFAULT_CACHE_BYTES};
use crate::data::Dataset;
use crate::kernel::BlockKernel;
use crate::solver::objective::{max_violation, objective_from_grad, projected_violation};

/// Solver configuration. The kernel-row cache budget lives on the
/// [`KernelContext`] now, not here — one budget per dataset, shared by
/// every solve.
#[derive(Clone, Debug)]
pub struct SmoConfig {
    /// Box constraint C.
    pub c: f64,
    /// KKT stopping tolerance (LIBSVM default 1e-3).
    pub eps: f64,
    /// Hard iteration cap (0 = unlimited).
    pub max_iter: usize,
    /// Enable shrinking.
    pub shrinking: bool,
    /// Invoke the progress callback every this many iterations.
    pub report_every: usize,
    /// On a kernel-row cache miss, prefetch rows for this many of the most
    /// violating active variables in ONE block dispatch (through
    /// [`KernelContext::compute_rows`]). Amortizes the per-call overhead of
    /// the PJRT backend (the working set stabilizes early — paper Figure 2
    /// — so prefetched rows get reused). 1 disables; 0 = auto: 64 when the
    /// backend `prefers_batched_rows()`; else the context's thread budget
    /// when the batched dispatch is large enough to fan out over row
    /// panels (`dispatch_fanout`), and 1 otherwise (serial speculative
    /// rows are wasted work on the native backend — bench_ablations A5).
    pub row_batch: usize,
}

impl Default for SmoConfig {
    fn default() -> Self {
        SmoConfig {
            c: 1.0,
            eps: 1e-3,
            max_iter: 0,
            shrinking: true,
            report_every: 2_000,
            row_batch: 0,
        }
    }
}

/// Progress snapshot passed to the callback (drives Figures 2–4 series).
pub struct SmoProgress<'a> {
    pub iter: usize,
    pub elapsed_s: f64,
    pub objective: f64,
    pub alpha: &'a [f64],
    pub active: usize,
}

/// Solve outcome.
#[derive(Clone, Debug)]
pub struct SmoResult {
    pub alpha: Vec<f64>,
    pub objective: f64,
    pub iterations: usize,
    pub sv_count: usize,
    pub bounded_sv_count: usize,
    pub final_violation: f64,
    pub elapsed_s: f64,
    /// Kernel rows (full or segment) computed during this solve
    /// (shared-cache miss delta).
    pub rows_computed: u64,
    /// Kernel **entries** evaluated during this solve (context
    /// `values_computed` delta) — the segment-aware cost metric: a
    /// segmented cluster solve pays ~n/k per row, a full-row solve pays n.
    pub values_computed: u64,
    /// Shared-cache hit rate over this solve's accesses.
    pub cache_hit_rate: f64,
    /// True if stopped by max_iter instead of ε-optimality.
    pub hit_iter_cap: bool,
}

/// The solver. Borrows a view of a kernel context; owns no cache.
pub struct SmoSolver<'a> {
    view: KernelView<'a>,
    /// Local labels, gathered once (hot-loop friendly).
    y: Vec<i8>,
    cfg: SmoConfig,
    /// Optional fixed linear term: solve
    /// `min ½αᵀQα − eᵀα + qᵀα  s.t. 0 ≤ α ≤ C` instead of the plain dual.
    /// This is the restricted block subproblem of parallel block
    /// minimization (arXiv:1608.02010): freezing the out-of-block
    /// variables ᾱ adds the constant-gradient term
    /// `q_i = y_i Σ_{j∉B} ᾱ_j y_j K(x_i, x_j)` to block B's dual. The
    /// maintained gradient becomes `g = Qα − e + q`; every KKT test reads
    /// `g` unchanged, and the reported objective adds `½ qᵀα` on top of
    /// the [`objective_from_grad`] identity.
    linear_offset: Option<Vec<f64>>,
}

impl<'a> SmoSolver<'a> {
    pub fn new(view: KernelView<'a>, cfg: SmoConfig) -> Self {
        let y = view.labels();
        SmoSolver { view, y, cfg, linear_offset: None }
    }

    /// Solve with a fixed linear term `q` added to the dual gradient (one
    /// entry per view-local variable): the distributed block subproblem.
    /// An all-zero `q` is bit-identical to the plain solve.
    pub fn with_linear_offset(mut self, q: Vec<f64>) -> Self {
        assert_eq!(q.len(), self.view.len(), "linear offset length != view length");
        self.linear_offset = Some(q);
        self
    }

    /// The true objective of the problem being solved: the plain dual
    /// identity from the maintained gradient, plus the `½ qᵀα` correction
    /// when a linear offset is active (there `g = Qα − e + q`, so
    /// `½ Σ α(g−1)` counts only half the linear term).
    fn objective_value(&self, alpha: &[f64], grad: &[f64]) -> f64 {
        let base = objective_from_grad(alpha, grad);
        match &self.linear_offset {
            Some(q) => {
                base + 0.5 * alpha.iter().zip(q).map(|(&a, &qi)| a * qi).sum::<f64>()
            }
            None => base,
        }
    }

    /// Solve from zero.
    pub fn solve(&mut self) -> SmoResult {
        self.solve_warm(None, &mut |_| {})
    }

    /// Solve warm-started from `alpha0` with a progress callback.
    pub fn solve_warm(
        &mut self,
        alpha0: Option<&[f64]>,
        on_progress: &mut dyn FnMut(&SmoProgress),
    ) -> SmoResult {
        let n = self.view.len();
        let c = self.cfg.c;
        let t0 = Instant::now();
        let stats0 = self.view.ctx().stats();
        let vals0 = self.view.ctx().value_stats();

        // --- initialize alpha and gradient -------------------------------
        let mut alpha = match alpha0 {
            Some(a0) => {
                assert_eq!(a0.len(), n);
                a0.iter().map(|&a| a.clamp(0.0, c)).collect::<Vec<f64>>()
            }
            None => vec![0f64; n],
        };
        // g = Qα − e (+ q with a linear offset); at α = 0 that is q − e.
        let mut grad: Vec<f64> = match &self.linear_offset {
            Some(q) => q.iter().map(|&qi| qi - 1.0).collect(),
            None => vec![-1f64; n],
        };
        if alpha.iter().any(|&a| a != 0.0) {
            self.init_gradient_from(&alpha, &mut grad);
        }

        // --- active set ---------------------------------------------------
        let mut active: Vec<usize> = (0..n).collect();
        let mut shrunk = false;
        let shrink_interval = n.clamp(200, 4000);
        let mut since_shrink = 0usize;

        // Incrementally-maintained objective (exact: each coordinate step
        // changes f by δ·g_i + ½δ²Q_ii even under shrinking, where g_i is
        // the pre-update gradient — with a linear offset, g_i carries the
        // constant q_i so the same increment stays exact). Used for
        // progress reporting; the final result recomputes from the
        // reconstructed gradient.
        let mut obj = self.objective_value(&alpha, &grad);

        // Warm-start shrink: when ᾱ comes from the divide phase the SV set
        // is already ~identified (paper Theorem 2 / Figure 2), so variables
        // at bound with strongly-satisfied KKT can be shrunk immediately
        // instead of being rescanned every selection pass. The end-of-solve
        // reconstruction re-verifies them, so exactness is unaffected.
        if self.cfg.shrinking && alpha0.is_some() {
            let vmax = alpha
                .iter()
                .zip(&grad)
                .map(|(&a, &g)| projected_violation(a, g, c))
                .fold(0.0f64, f64::max);
            let thresh = vmax.max(self.cfg.eps);
            let before = active.len();
            active.retain(|&j| {
                let at_lo = alpha[j] <= 0.0;
                let at_hi = alpha[j] >= c;
                !(at_lo && grad[j] > thresh || at_hi && grad[j] < -thresh)
            });
            if active.len() < before {
                shrunk = true;
            }
        }

        let mut iter = 0usize;
        let mut hit_cap = false;

        loop {
            // ---- greedy working-variable selection over active set -------
            let mut best = usize::MAX;
            let mut best_v = 0.0f64;
            for &i in &active {
                let v = projected_violation(alpha[i], grad[i], c);
                if v > best_v {
                    best_v = v;
                    best = i;
                }
            }

            if best_v < self.cfg.eps || best == usize::MAX {
                if shrunk {
                    // Apparent convergence on the shrunk problem: rebuild
                    // the full gradient and re-verify on all variables.
                    self.reconstruct_gradient(&alpha, &mut grad, &active);
                    active = (0..n).collect();
                    shrunk = false;
                    since_shrink = 0;
                    continue;
                }
                break; // ε-optimal on the full problem
            }

            if self.cfg.max_iter > 0 && iter >= self.cfg.max_iter {
                hit_cap = true;
                break;
            }

            // ---- coordinate update --------------------------------------
            let i = best;
            let yi = self.y[i] as f64;
            let qii = {
                let kii = self
                    .view
                    .ctx()
                    .kind()
                    .self_eval(self.view.x_row(i), self.view.norm(i))
                    as f64;
                kii.max(1e-12)
            };
            let delta = (alpha[i] - grad[i] / qii).clamp(0.0, c) - alpha[i];
            if delta != 0.0 {
                obj += delta * (grad[i] + 0.5 * delta * qii);
                alpha[i] += delta;
                // g_j += δ Q_ij over the active set (+ self handled inside)
                if !self.view.is_row_cached(i) {
                    self.prefetch_rows(i, &active, &alpha, &grad, c);
                }
                let row = self.view.local_row(i);
                let dyi = delta * yi;
                match self.view.unsegmented_map() {
                    // Segmented or full view: the row is directly indexed
                    // by the same local indices the solver iterates.
                    None => {
                        for &j in &active {
                            grad[j] += dyi * (self.y[j] as f64) * (row[j] as f64);
                        }
                    }
                    // Unsegmented subset view: full dataset-length row,
                    // indexed through the local→global map.
                    Some(map) => {
                        for &j in &active {
                            grad[j] += dyi * (self.y[j] as f64) * (row[map[j]] as f64);
                        }
                    }
                }
            }

            iter += 1;
            since_shrink += 1;

            // ---- shrinking ----------------------------------------------
            if self.cfg.shrinking && since_shrink >= shrink_interval && active.len() > 32 {
                since_shrink = 0;
                let thresh = best_v.max(self.cfg.eps);
                let before = active.len();
                active.retain(|&j| {
                    let at_lo = alpha[j] <= 0.0;
                    let at_hi = alpha[j] >= c;
                    // keep free variables and weakly-satisfied bound ones
                    !(at_lo && grad[j] > thresh || at_hi && grad[j] < -thresh)
                });
                if active.len() < before {
                    shrunk = true;
                }
            }

            // ---- progress -----------------------------------------------
            if self.cfg.report_every > 0 && iter % self.cfg.report_every == 0 {
                on_progress(&SmoProgress {
                    iter,
                    elapsed_s: t0.elapsed().as_secs_f64(),
                    objective: obj,
                    alpha: &alpha,
                    active: active.len(),
                });
            }
        }

        // If we stopped shrunk at the iteration cap, reconstruct so the
        // reported objective/violation are for the true problem.
        if shrunk {
            self.reconstruct_gradient(&alpha, &mut grad, &active);
        }

        let objective = self.objective_value(&alpha, &grad);
        let final_violation = max_violation(&alpha, &grad, c);
        let sv_count = alpha.iter().filter(|&&a| a > 0.0).count();
        let bounded = alpha.iter().filter(|&&a| a >= c).count();
        let elapsed_s = t0.elapsed().as_secs_f64();
        on_progress(&SmoProgress {
            iter,
            elapsed_s,
            objective,
            alpha: &alpha,
            active: active.len(),
        });

        let delta_stats = self.view.ctx().stats().since(&stats0);
        let delta_vals = self.view.ctx().value_stats().since(&vals0);
        SmoResult {
            alpha,
            objective,
            iterations: iter,
            sv_count,
            bounded_sv_count: bounded,
            final_violation,
            elapsed_s,
            rows_computed: delta_stats.misses,
            values_computed: delta_vals.values_computed,
            cache_hit_rate: delta_stats.hit_rate(),
            hit_iter_cap: hit_cap,
        }
    }

    /// Batched kernel-row prefetch: on a miss for row `i`, compute rows for
    /// `i` plus the most violating uncached active variables in ONE backend
    /// dispatch (amortizes PJRT call overhead; the working set stabilizes
    /// early so the speculative rows get reused).
    fn prefetch_rows(
        &self,
        i: usize,
        active: &[usize],
        alpha: &[f64],
        grad: &[f64],
        c: f64,
    ) {
        // Never prefetch more rows than a fraction of the cache can hold —
        // otherwise a tight cache budget turns speculative rows into
        // immediate evictions of the working set. Eviction is per shard, so
        // also cap at one shard's budget (the smallest, post-rebalance):
        // even if every pick collides on one shard (key % shards), the
        // batch cannot evict its own rows. Budgets are bytes now, so the
        // caps scale with this view's row length — a segmented cluster
        // solve can prefetch k× deeper than a full-row solve.
        let ctx = self.view.ctx();
        let cache = ctx.cache();
        let row_len = self.view.row_len();
        let row_bytes = (row_len * 4).max(1);
        let auto = if ctx.kernel().prefers_batched_rows() {
            64
        } else {
            // A row-panel-parallel dispatch computes a small speculative
            // batch in roughly the wall-clock of one row, so batch up to
            // the thread budget — but only where the backend would
            // actually fan out; below its parallel threshold speculation
            // stays off (it is pure waste there — bench_ablations A5).
            let t = ctx.threads().min(8);
            if ctx.kernel().dispatch_fanout(t, row_len, ctx.dim(), t) > 1 {
                t
            } else {
                1
            }
        };
        let batch = (if self.cfg.row_batch == 0 { auto } else { self.cfg.row_batch })
            .min((cache.budget_bytes() / 8 / row_bytes).max(1))
            .min((cache.min_shard_budget_bytes() / row_bytes).max(1))
            .max(1);
        let mut picks: Vec<usize> = vec![i];
        if batch > 1 {
            // Top-(batch-1) violating uncached active variables.
            let mut cands: Vec<(f64, usize)> = active
                .iter()
                .filter(|&&j| j != i && !self.view.is_row_cached(j))
                .map(|&j| (projected_violation(alpha[j], grad[j], c), j))
                .filter(|&(v, _)| v > 0.0)
                .collect();
            let take = (batch - 1).min(cands.len());
            if take > 0 {
                cands.select_nth_unstable_by(take - 1, |a, b| b.0.total_cmp(&a.0));
                picks.extend(cands[..take].iter().map(|&(_, j)| j));
            }
        }
        self.view.ensure_rows(&picks);
    }

    /// g = Qα − e computed from scratch using only the SVs of `alpha`
    /// (cost O(n·|S|) through the fused decision path).
    fn init_gradient_from(&self, alpha: &[f64], grad: &mut [f64]) {
        let n = self.view.len();
        let sv: Vec<usize> = (0..n).filter(|&i| alpha[i] != 0.0).collect();
        self.decision_into(&sv, alpha, (0..n).collect::<Vec<_>>().as_slice(), grad);
        for (j, g) in grad.iter_mut().enumerate() {
            *g = (self.y[j] as f64) * *g - 1.0;
        }
        if let Some(q) = &self.linear_offset {
            for (g, &qi) in grad.iter_mut().zip(q) {
                *g += qi;
            }
        }
    }

    /// Rebuild grad for variables outside `active` (the shrunk ones).
    fn reconstruct_gradient(&self, alpha: &[f64], grad: &mut [f64], active: &[usize]) {
        let n = self.view.len();
        let mut in_active = vec![false; n];
        for &i in active {
            in_active[i] = true;
        }
        let todo: Vec<usize> = (0..n).filter(|&i| !in_active[i]).collect();
        if todo.is_empty() {
            return;
        }
        let sv: Vec<usize> = (0..n).filter(|&i| alpha[i] != 0.0).collect();
        let mut dv = vec![0f64; todo.len()];
        self.decision_into(&sv, alpha, &todo, &mut dv);
        let q = self.linear_offset.as_deref();
        for (t, &j) in todo.iter().enumerate() {
            grad[j] = (self.y[j] as f64) * dv[t] - 1.0 + q.map_or(0.0, |q| q[j]);
        }
    }

    /// `dv[t] = Σ_{i∈sv} α_i y_i K(x_{query[t]}, x_i)`, chunked through the
    /// backend's (possibly fused) decision path. `sv`/`query` are local
    /// indices of the view.
    fn decision_into(&self, sv: &[usize], alpha: &[f64], query: &[usize], out: &mut [f64]) {
        debug_assert_eq!(query.len(), out.len());
        if sv.is_empty() {
            out.iter_mut().for_each(|v| *v = 0.0);
            return;
        }
        let dim = self.view.ctx().dim();
        let kernel = self.view.ctx().kernel();
        // Gather SV matrix + coef once.
        let mut xd = Vec::with_capacity(sv.len() * dim);
        let mut dnorms = Vec::with_capacity(sv.len());
        let mut coef = Vec::with_capacity(sv.len());
        for &i in sv {
            xd.extend_from_slice(self.view.x_row(i));
            dnorms.push(self.view.norm(i));
            coef.push((alpha[i] * self.y[i] as f64) as f32);
        }
        const CHUNK: usize = 512;
        let mut xq = Vec::with_capacity(CHUNK * dim);
        let mut qnorms = Vec::with_capacity(CHUNK);
        let mut dv = vec![0f32; CHUNK];
        for (ci, chunk) in query.chunks(CHUNK).enumerate() {
            xq.clear();
            qnorms.clear();
            for &qi in chunk {
                xq.extend_from_slice(self.view.x_row(qi));
                qnorms.push(self.view.norm(qi));
            }
            kernel.decision(
                &xq,
                &qnorms,
                &xd,
                &dnorms,
                dim,
                &coef,
                &mut dv[..chunk.len()],
            );
            let offset = ci * CHUNK;
            for t in 0..chunk.len() {
                out[offset + t] = dv[t] as f64;
            }
        }
    }
}

/// Convenience: cold solve with a throwaway default-budget context. Callers
/// that already own a [`KernelContext`] should use
/// `SmoSolver::new(ctx.view_full(), cfg)` to share cached rows instead.
pub fn solve_svm(ds: &Dataset, kernel: &dyn BlockKernel, cfg: SmoConfig) -> SmoResult {
    let ctx = KernelContext::new(ds, kernel, DEFAULT_CACHE_BYTES);
    SmoSolver::new(ctx.view_full(), cfg).solve()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate, ijcnn1_like};
    use crate::kernel::{native::NativeKernel, KernelKind};
    use crate::prop_assert;
    use crate::solver::objective::{dense_q, objective_dense, ProjGradRef};
    use crate::util::{prng::Pcg64, proptest::check};

    fn kernel() -> NativeKernel {
        NativeKernel::new(KernelKind::Rbf { gamma: 8.0 })
    }

    fn cfg(c: f64, eps: f64) -> SmoConfig {
        SmoConfig { c, eps, ..Default::default() }
    }

    #[test]
    fn matches_reference_qp_small() {
        let mut rng = Pcg64::new(10);
        let ds = generate(&covtype_like(), 60, &mut rng);
        let k = kernel();
        let ctx = KernelContext::new(&ds, &k, DEFAULT_CACHE_BYTES);
        let mut solver = SmoSolver::new(ctx.view_full(), cfg(1.0, 1e-8));
        let res = solver.solve();
        let q = dense_q(&ds, &k);
        let (_, ref_obj) = ProjGradRef::default().solve(&q, ds.len(), 1.0);
        assert!(
            (res.objective - ref_obj).abs() < 1e-5 * (1.0 + ref_obj.abs()),
            "smo {} vs ref {}",
            res.objective,
            ref_obj
        );
        // objective identity cross-check against dense formula
        let dense = objective_dense(&q, &res.alpha);
        assert!((dense - res.objective).abs() < 1e-7 * (1.0 + dense.abs()));
    }

    #[test]
    fn kkt_at_exit_and_feasible() {
        let mut rng = Pcg64::new(11);
        let ds = generate(&ijcnn1_like(), 120, &mut rng);
        let k = kernel();
        let c = 4.0;
        let res = solve_svm(&ds, &k, cfg(c, 1e-6));
        assert!(res.final_violation < 1e-6, "viol {}", res.final_violation);
        assert!(res.alpha.iter().all(|&a| (0.0..=c).contains(&a)));
        assert!(!res.hit_iter_cap);
    }

    #[test]
    fn warm_start_preserves_optimum_and_is_cheaper() {
        let mut rng = Pcg64::new(12);
        let ds = generate(&covtype_like(), 150, &mut rng);
        let k = kernel();
        let ctx = KernelContext::new(&ds, &k, DEFAULT_CACHE_BYTES);
        let cold = SmoSolver::new(ctx.view_full(), cfg(1.0, 1e-7)).solve();
        // warm start from a *slightly perturbed* optimum
        let mut a0 = cold.alpha.clone();
        let mut prng = Pcg64::new(13);
        for a in a0.iter_mut() {
            *a = (*a + 0.01 * prng.next_f64()).clamp(0.0, 1.0);
        }
        let warm = SmoSolver::new(ctx.view_full(), cfg(1.0, 1e-7))
            .solve_warm(Some(&a0), &mut |_| {});
        assert!(
            (warm.objective - cold.objective).abs() < 1e-5 * (1.0 + cold.objective.abs()),
            "warm {} cold {}",
            warm.objective,
            cold.objective
        );
        assert!(
            warm.iterations < cold.iterations,
            "warm {} >= cold {} iterations",
            warm.iterations,
            cold.iterations
        );
        // Cross-solve cache reuse: the second solve found rows resident.
        assert!(
            warm.rows_computed < cold.rows_computed,
            "warm computed {} rows, cold {}",
            warm.rows_computed,
            cold.rows_computed
        );
    }

    #[test]
    fn shrinking_changes_nothing() {
        let mut rng = Pcg64::new(14);
        let ds = generate(&covtype_like(), 140, &mut rng);
        let k = kernel();
        let with = solve_svm(&ds, &k, SmoConfig { shrinking: true, ..cfg(1.0, 1e-7) });
        let without = solve_svm(&ds, &k, SmoConfig { shrinking: false, ..cfg(1.0, 1e-7) });
        assert!(
            (with.objective - without.objective).abs()
                < 1e-5 * (1.0 + without.objective.abs()),
            "with {} without {}",
            with.objective,
            without.objective
        );
    }

    #[test]
    fn iter_cap_respected() {
        let mut rng = Pcg64::new(15);
        let ds = generate(&covtype_like(), 200, &mut rng);
        let k = kernel();
        let res = solve_svm(&ds, &k, SmoConfig { max_iter: 10, ..cfg(1.0, 1e-9) });
        assert!(res.hit_iter_cap);
        assert_eq!(res.iterations, 10);
    }

    #[test]
    fn progress_callback_fires_and_objective_decreases() {
        let mut rng = Pcg64::new(16);
        let ds = generate(&covtype_like(), 150, &mut rng);
        let k = kernel();
        let ctx = KernelContext::new(&ds, &k, DEFAULT_CACHE_BYTES);
        let mut objs = Vec::new();
        let mut solver = SmoSolver::new(
            ctx.view_full(),
            SmoConfig { report_every: 50, ..cfg(1.0, 1e-7) },
        );
        solver.solve_warm(None, &mut |p| objs.push(p.objective));
        assert!(objs.len() >= 2);
        // objective is monotone nonincreasing in CD
        for w in objs.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "{objs:?}");
        }
    }

    /// Segmented and unsegmented subset views must produce bit-identical
    /// solves (same iterates — kernel entries are pure elementwise
    /// functions), while the segmented solve evaluates strictly fewer
    /// kernel entries (cluster-length rows instead of full rows).
    #[test]
    fn segmented_view_solve_matches_unsegmented_bitwise() {
        let mut rng = Pcg64::new(18);
        let ds = generate(&covtype_like(), 140, &mut rng);
        let k = kernel();
        let members: Vec<usize> = (0..ds.len()).filter(|i| i % 4 != 1).collect();
        let ctx_seg = KernelContext::new(&ds, &k, DEFAULT_CACHE_BYTES);
        let ctx_v1 = KernelContext::new(&ds, &k, DEFAULT_CACHE_BYTES);
        let seg = SmoSolver::new(ctx_seg.view(&members), cfg(2.0, 1e-7)).solve();
        let v1 = SmoSolver::new(ctx_v1.view_unsegmented(&members), cfg(2.0, 1e-7)).solve();
        assert_eq!(seg.iterations, v1.iterations);
        assert_eq!(seg.alpha, v1.alpha, "segment rows changed the trajectory");
        assert!(
            seg.values_computed < v1.values_computed,
            "segmented solve computed {} kernel values, unsegmented {}",
            seg.values_computed,
            v1.values_computed
        );
    }

    /// A subset view solve must agree exactly with solving the materialized
    /// subset dataset (same math, shared-cache rows notwithstanding).
    #[test]
    fn subset_view_solve_matches_materialized_subset() {
        let mut rng = Pcg64::new(17);
        let ds = generate(&covtype_like(), 120, &mut rng);
        let k = kernel();
        let members: Vec<usize> = (0..ds.len()).filter(|i| i % 3 != 0).collect();
        let ctx = KernelContext::new(&ds, &k, DEFAULT_CACHE_BYTES);
        let via_view = SmoSolver::new(ctx.view(&members), cfg(2.0, 1e-7)).solve();
        let sub = ds.subset(&members, "sub");
        let via_subset = solve_svm(&sub, &k, cfg(2.0, 1e-7));
        assert_eq!(via_view.iterations, via_subset.iterations);
        for (a, b) in via_view.alpha.iter().zip(&via_subset.alpha) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    /// An all-zero linear offset must not change the solve at all — same
    /// trajectory, bit-identical α and objective.
    #[test]
    fn zero_linear_offset_is_bit_identical() {
        let mut rng = Pcg64::new(21);
        let ds = generate(&covtype_like(), 100, &mut rng);
        let k = kernel();
        let ctx = KernelContext::new(&ds, &k, DEFAULT_CACHE_BYTES);
        let plain = SmoSolver::new(ctx.view_full(), cfg(2.0, 1e-7)).solve();
        let ctx2 = KernelContext::new(&ds, &k, DEFAULT_CACHE_BYTES);
        let offset = SmoSolver::new(ctx2.view_full(), cfg(2.0, 1e-7))
            .with_linear_offset(vec![0.0; ds.len()])
            .solve();
        assert_eq!(plain.iterations, offset.iterations);
        assert_eq!(plain.alpha, offset.alpha);
        assert_eq!(plain.objective, offset.objective);
    }

    /// The restricted block subproblem (external ᾱ frozen into a linear
    /// offset — the distributed round's local solve) must match a dense
    /// projected-gradient oracle on the same offset problem.
    #[test]
    fn linear_offset_matches_dense_oracle() {
        let mut rng = Pcg64::new(22);
        let n = 90;
        let ds = generate(&covtype_like(), n, &mut rng);
        let k = kernel();
        let c = 2.0;
        let members: Vec<usize> = (0..n).filter(|i| i % 2 == 0).collect();
        let ext: Vec<usize> = (0..n).filter(|i| i % 2 == 1).collect();
        let q_full = dense_q(&ds, &k);
        let mut aext = vec![0f64; n];
        for (t, &j) in ext.iter().enumerate() {
            aext[j] = (0.1 + 0.02 * t as f64).min(c);
        }
        // q_i = Σ_{j external} ᾱ_j Q_ij for block members i.
        let q_off: Vec<f64> = members
            .iter()
            .map(|&i| ext.iter().map(|&j| aext[j] * q_full[i * n + j]).sum())
            .collect();
        let ctx = KernelContext::new(&ds, &k, DEFAULT_CACHE_BYTES);
        let res = SmoSolver::new(ctx.view(&members), cfg(c, 1e-8))
            .with_linear_offset(q_off.clone())
            .solve();
        // Dense oracle: projected gradient with the gradient seeded at
        // q − e (same loop as ProjGradRef, plus the offset).
        let sub = ds.subset(&members, "blk");
        let qb = dense_q(&sub, &k);
        let nb = members.len();
        let lip = (0..nb)
            .map(|i| qb[i * nb..(i + 1) * nb].iter().map(|v| v.abs()).sum::<f64>())
            .fold(0.0, f64::max)
            .max(1e-12);
        let step = 1.0 / lip;
        let mut alpha = vec![0f64; nb];
        let mut grad: Vec<f64> = q_off.iter().map(|&q| q - 1.0).collect();
        for _ in 0..200_000 {
            let mut moved = 0.0f64;
            for i in 0..nb {
                let target = (alpha[i] - step * grad[i]).clamp(0.0, c);
                let delta = target - alpha[i];
                if delta != 0.0 {
                    alpha[i] = target;
                    moved = moved.max(delta.abs());
                    for j in 0..nb {
                        grad[j] += delta * qb[j * nb + i];
                    }
                }
            }
            if moved < 1e-10 {
                break;
            }
        }
        let ref_obj = objective_from_grad(&alpha, &grad)
            + 0.5 * alpha.iter().zip(&q_off).map(|(&a, &q)| a * q).sum::<f64>();
        assert!(
            (res.objective - ref_obj).abs() < 1e-5 * (1.0 + ref_obj.abs()),
            "smo-with-offset {} vs oracle {}",
            res.objective,
            ref_obj
        );
        assert!(res.final_violation < 1e-8 * 10.0, "viol {}", res.final_violation);
    }

    /// Property: on random small problems the solver is feasible, ε-optimal,
    /// and matches the brute-force reference objective.
    #[test]
    fn prop_smo_correct_random_instances() {
        check("smo-vs-ref", 8, |rng: &mut Pcg64| {
            let n = 20 + rng.below(30);
            let gamma = 0.5 + 4.0 * rng.next_f64();
            let c = 0.25 + 2.0 * rng.next_f64();
            let ds = generate(&covtype_like(), n, rng);
            let k = NativeKernel::new(KernelKind::Rbf { gamma: gamma as f32 });
            let res = solve_svm(&ds, &k, cfg(c, 1e-8));
            prop_assert!(
                res.alpha.iter().all(|&a| (-1e-12..=c + 1e-12).contains(&a)),
                "infeasible alpha"
            );
            let q = dense_q(&ds, &k);
            let (_, ref_obj) = ProjGradRef::default().solve(&q, n, c);
            prop_assert!(
                (res.objective - ref_obj).abs() < 1e-4 * (1.0 + ref_obj.abs()),
                "obj {} vs ref {} (n={n}, gamma={gamma:.3}, C={c:.3})",
                res.objective,
                ref_obj
            );
            Ok(())
        });
    }
}
