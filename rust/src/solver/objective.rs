//! Exact objective / gradient / KKT utilities and a brute-force reference
//! QP solver.
//!
//! The dual problem (paper eq. 1, no bias term):
//!
//! ```text
//! min_α f(α) = ½ αᵀQα − eᵀα   s.t. 0 ≤ α ≤ C,   Q_ij = y_i y_j K(x_i, x_j)
//! ```
//!
//! `dense_q` materializes Q for small problems; `ProjGradRef` is an O(n²)
//! projected-gradient solver used purely as a test oracle for the SMO
//! solver; `objective_from_grad` is the O(n) identity
//! f(α) = ½ Σ α_i (g_i − 1) the production solver uses.

use crate::data::Dataset;
use crate::kernel::BlockKernel;

/// Materialize the full Q matrix (f64) — test/bench use only (O(n²) memory).
pub fn dense_q(ds: &Dataset, kernel: &dyn BlockKernel) -> Vec<f64> {
    let n = ds.len();
    let norms = ds.sq_norms();
    let mut k = vec![0f32; n * n];
    kernel.block(&ds.x, &norms, &ds.x, &norms, ds.dim, &mut k);
    let mut q = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            q[i * n + j] = (ds.y[i] as f64) * (ds.y[j] as f64) * (k[i * n + j] as f64);
        }
    }
    q
}

/// f(α) from a dense Q.
pub fn objective_dense(q: &[f64], alpha: &[f64]) -> f64 {
    let n = alpha.len();
    let mut f = 0.0;
    for i in 0..n {
        let mut qa = 0.0;
        for j in 0..n {
            qa += q[i * n + j] * alpha[j];
        }
        f += alpha[i] * (0.5 * qa - 1.0);
    }
    f
}

/// f(α) = ½ Σ α_i (g_i − 1) given the maintained gradient g = Qα − e.
pub fn objective_from_grad(alpha: &[f64], grad: &[f64]) -> f64 {
    alpha.iter().zip(grad).map(|(&a, &g)| 0.5 * a * (g - 1.0)).sum()
}

/// Projected KKT violation of coordinate i: the magnitude of the projected
/// gradient (0 iff i satisfies its KKT condition).
#[inline]
pub fn projected_violation(alpha_i: f64, grad_i: f64, c: f64) -> f64 {
    if alpha_i <= 0.0 {
        (-grad_i).max(0.0)
    } else if alpha_i >= c {
        grad_i.max(0.0)
    } else {
        grad_i.abs()
    }
}

/// Max projected KKT violation over all coordinates.
pub fn max_violation(alpha: &[f64], grad: &[f64], c: f64) -> f64 {
    alpha
        .iter()
        .zip(grad)
        .map(|(&a, &g)| projected_violation(a, g, c))
        .fold(0.0, f64::max)
}

/// Brute-force projected-gradient reference solver (test oracle).
/// Converges linearly; only for n ≤ a few hundred.
pub struct ProjGradRef {
    pub max_iter: usize,
    pub tol: f64,
}

impl Default for ProjGradRef {
    fn default() -> Self {
        ProjGradRef { max_iter: 200_000, tol: 1e-10 }
    }
}

impl ProjGradRef {
    /// Solve with dense Q; returns (alpha, objective).
    pub fn solve(&self, q: &[f64], n: usize, c: f64) -> (Vec<f64>, f64) {
        // Lipschitz constant of the gradient: ||Q||_inf row-sum bound.
        let lip = (0..n)
            .map(|i| q[i * n..(i + 1) * n].iter().map(|v| v.abs()).sum::<f64>())
            .fold(0.0, f64::max)
            .max(1e-12);
        let step = 1.0 / lip;
        let mut alpha = vec![0f64; n];
        let mut grad = vec![-1f64; n]; // Qα − e at α = 0
        for _ in 0..self.max_iter {
            // gradient step + projection
            let mut moved = 0.0f64;
            for i in 0..n {
                let target = (alpha[i] - step * grad[i]).clamp(0.0, c);
                let delta = target - alpha[i];
                if delta != 0.0 {
                    alpha[i] = target;
                    moved = moved.max(delta.abs());
                    for j in 0..n {
                        grad[j] += delta * q[j * n + i];
                    }
                }
            }
            if moved < self.tol {
                break;
            }
        }
        let obj = objective_from_grad(&alpha, &grad);
        (alpha, obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate};
    use crate::kernel::{native::NativeKernel, KernelKind};
    use crate::util::prng::Pcg64;

    fn small_problem(n: usize, seed: u64) -> (Dataset, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let spec = covtype_like();
        let ds = generate(&spec, n, &mut rng);
        let k = NativeKernel::new(KernelKind::Rbf { gamma: 8.0 });
        let q = dense_q(&ds, &k);
        (ds, q)
    }

    #[test]
    fn objective_identities_agree() {
        let (_, q) = small_problem(24, 1);
        let n = 24;
        let mut rng = Pcg64::new(2);
        let alpha: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let mut grad = vec![0f64; n];
        for i in 0..n {
            grad[i] = (0..n).map(|j| q[i * n + j] * alpha[j]).sum::<f64>() - 1.0;
        }
        let f1 = objective_dense(&q, &alpha);
        let f2 = objective_from_grad(&alpha, &grad);
        assert!((f1 - f2).abs() < 1e-10, "{f1} vs {f2}");
    }

    #[test]
    fn projgrad_satisfies_kkt() {
        let (_, q) = small_problem(32, 3);
        let c = 1.0;
        let (alpha, _) = ProjGradRef::default().solve(&q, 32, c);
        let n = 32;
        let mut grad = vec![0f64; n];
        for i in 0..n {
            grad[i] = (0..n).map(|j| q[i * n + j] * alpha[j]).sum::<f64>() - 1.0;
        }
        let viol = max_violation(&alpha, &grad, c);
        assert!(viol < 1e-5, "KKT violation {viol}");
        assert!(alpha.iter().all(|&a| (0.0..=c).contains(&a)));
    }

    #[test]
    fn projgrad_beats_feasible_points() {
        let (_, q) = small_problem(20, 4);
        let c = 0.7;
        let (_, obj) = ProjGradRef::default().solve(&q, 20, c);
        // optimal objective must be <= objective at any feasible point
        let mut rng = Pcg64::new(5);
        for _ in 0..20 {
            let alpha: Vec<f64> = (0..20).map(|_| rng.next_f64() * c).collect();
            assert!(obj <= objective_dense(&q, &alpha) + 1e-8);
        }
        // and <= 0 (alpha=0 is feasible with f=0)
        assert!(obj <= 1e-12);
    }

    #[test]
    fn violation_cases() {
        let c = 1.0;
        assert_eq!(projected_violation(0.0, 1.0, c), 0.0); // at 0, grad>0: satisfied
        assert_eq!(projected_violation(0.0, -2.0, c), 2.0);
        assert_eq!(projected_violation(c, -1.0, c), 0.0); // at C, grad<0: satisfied
        assert_eq!(projected_violation(c, 3.0, c), 3.0);
        assert_eq!(projected_violation(0.5, -0.25, c), 0.25); // interior
    }
}
