//! Serve transports: one request-handling core, two wire front-ends.
//!
//! The paper's early-prediction result only matters at deployment scale if
//! one trained model can answer many clients at once, and the whole point
//! of [`ServingContext`] is that kernel state amortizes across *all* the
//! work the process ever does. This module is the front-end that makes the
//! sharing real:
//!
//! - [`ServeCore`] — the transport-independent request core: one shared
//!   [`ServingContext`], the `--workers` setting, global batch/served
//!   counters, and an aggregate [`BatchStats`] total. Both transports
//!   delegate every batch to [`ServeCore::decide_tracked`], so their
//!   decisions (and their stats lines) are byte-for-byte comparable.
//! - **stdio** ([`run_stdio`]) — the original single-connection loop:
//!   LIBSVM rows on stdin, one `±1 decision` line per row on stdout, one
//!   JSON stats line per batch on stderr.
//! - **socket** ([`run_listener`]) — a TCP listener speaking
//!   newline-delimited JSON (one request object per line, one response
//!   object per line — PROTOCOL.md is the reference). An accept loop hands
//!   connections to a fixed pool of connection workers over a bounded
//!   [`WorkQueue`] (backpressure instead of unbounded queueing); each
//!   connection is served sequentially, N connections concurrently, all
//!   from the ONE shared context — kernel rows computed for one client
//!   warm the cache for every other client. Malformed input produces a
//!   structured error object ([`ERROR_CODES`]) instead of a process exit;
//!   EOF and broken pipes end the connection gracefully with a
//!   per-connection stats summary on stderr.
//!
//! [`ServeClient`] is a tiny blocking client for the socket protocol —
//! the test/example harness, not a production SDK.
//!
//! The `dcsvm serve` flag set lives here too ([`SERVE_FLAGS`]): the CLI
//! usage text ([`serve_usage`]) and README's flag table ([`readme_row`])
//! are both rendered from that one table, and `tests/docs_sync.rs` fails
//! the build when they drift.
//!
//! The line framing itself — read-poll accumulation, the
//! [`MAX_REQUEST_BYTES`] cap, UTF-8 validation, structured error objects —
//! lives in [`crate::util::wire`], shared with the distributed worker
//! protocol; this module maps each [`Frame`] to serve policy.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::{BatchStats, ServingContext, ServingModel, SwapStats};
use crate::kernel::{BlockKernel, KernelKind};
use crate::util::flags::FlagSet;
use crate::util::json::Json;
use crate::util::threadpool::WorkQueue;
use crate::util::wire::{self, error_response, with_id, Codec, Frame};

// ---------------------------------------------------------------------------
// Flag table — the single source of truth for `dcsvm serve` flags.

// The generic spec/table machinery now lives in `util::flags` (shared with
// `update`, `train`, and the distributed `worker` subcommand); the serve
// names re-export from here so existing imports keep working.
pub use crate::util::flags::{readme_row, FlagSpec};

/// Every `dcsvm serve` flag. The CLI usage text ([`serve_usage`]) and the
/// README flag table ([`readme_row`]) are both rendered from this list, so
/// docs and CLI cannot drift (`tests/docs_sync.rs` +
/// `tests/cli_roundtrip.rs` enforce it).
pub const SERVE_FLAGS: &[FlagSpec] = &[
    FlagSpec {
        flag: "--model",
        value: "FILE",
        default: "required",
        help: "model JSON written by train --save-model",
    },
    FlagSpec {
        flag: "--listen",
        value: "ADDR",
        default: "stdio mode",
        help: "serve newline-delimited JSON over TCP on ADDR (see PROTOCOL.md)",
    },
    FlagSpec {
        flag: "--batch",
        value: "N",
        default: "256",
        help: "stdio mode: LIBSVM rows per request batch",
    },
    FlagSpec {
        flag: "--workers",
        value: "N",
        default: "all cores",
        help: "threads each request batch is micro-batched across",
    },
    FlagSpec {
        flag: "--conns",
        value: "N",
        default: "8",
        help: "socket mode: connection-handler threads (bounds concurrent clients)",
    },
    FlagSpec {
        flag: "--cache-mb",
        value: "MB",
        default: "64",
        help: "serving-cache byte budget, split across decision components and the routing cache",
    },
    FlagSpec {
        flag: "--backend",
        value: "KIND",
        default: "auto",
        help: "kernel backend: auto, native, or pjrt",
    },
    FlagSpec {
        flag: "--quant-route",
        value: "BOOL",
        default: "false",
        help: "early models: route batches with int8-quantized sample rows (decisions stay exact per cluster)",
    },
    FlagSpec {
        flag: "--allow-swap",
        value: "BOOL",
        default: "false",
        help: "accept {\"swap_model\": FILE} requests: hot-swap to an updated model with zero downtime (see PROTOCOL.md)",
    },
    FlagSpec {
        flag: "--request-timeout",
        value: "SECS",
        default: "off",
        help: "socket mode: close a connection idle past this deadline with a structured timeout error",
    },
];

/// The serve flag surface as a parseable [`FlagSet`]: `cmd_serve` parses
/// against it, [`serve_usage`] and the README table render from it.
pub const SERVE_FLAG_SET: FlagSet =
    FlagSet { cmd: "serve", required: "--model FILE", flags: SERVE_FLAGS };

/// The `dcsvm serve` usage text, rendered from [`SERVE_FLAGS`].
pub fn serve_usage() -> String {
    SERVE_FLAG_SET.usage()
}

// ---------------------------------------------------------------------------
// Error-object catalogue (socket transport).

/// The request line was not valid JSON.
pub const ERR_PARSE: &str = "parse";
/// The request was JSON but not a valid request object (e.g. no `"x"`).
pub const ERR_BAD_REQUEST: &str = "bad_request";
/// A query row's length does not match the served model's dimension.
pub const ERR_DIM_MISMATCH: &str = "dim_mismatch";
/// A `swap_model` request could not be honored: swaps disabled
/// (`--allow-swap false`, the default), unreadable/invalid model file, or
/// no kernel backend for the new model. The served model is untouched.
pub const ERR_SWAP_FAILED: &str = "swap_failed";
/// The connection sat idle past the server's `--request-timeout`
/// deadline; the server answers with this error object and closes the
/// connection cleanly instead of holding a handler thread forever.
pub const ERR_TIMEOUT: &str = "timeout";
/// Every `code` an error object can carry; PROTOCOL.md catalogues each
/// (`tests/docs_sync.rs` enforces the catalogue).
pub const ERROR_CODES: &[&str] =
    &[ERR_PARSE, ERR_BAD_REQUEST, ERR_DIM_MISMATCH, ERR_SWAP_FAILED, ERR_TIMEOUT];

// The per-line byte cap and the read-poll interval are wire-layer
// properties now (shared with the worker protocol); the serve-side names
// are kept as re-exports.
pub use crate::util::wire::{MAX_FRAME_BYTES as MAX_REQUEST_BYTES, READ_POLL};

// ---------------------------------------------------------------------------
// The shared request core.

/// Builds a kernel backend for a hot-swapped model (kind, dim) — set by
/// the CLI to `harness::make_kernel` with the configured `--backend`, so
/// the serving crate never depends on the harness.
pub type KernelFactory =
    Box<dyn Fn(KernelKind, usize) -> Result<Box<dyn BlockKernel>> + Send + Sync>;

/// What one accepted `swap_model` request did (the response fields).
pub struct SwapOutcome {
    pub stats: SwapStats,
    /// SV count of the model now being served.
    pub svs: usize,
    /// [`ServingModel::describe`] of the new model.
    pub describe: String,
}

/// Transport-independent serving state: ONE [`ServingContext`] slot plus
/// the process-lifetime counters every transport reports. Built once by
/// `cmd_serve` (or a test) and shared by reference across all connection
/// workers — it is `Sync` because the context is.
///
/// The context lives in an `RwLock<Arc<...>>` swap slot: request handling
/// clones the `Arc` out ([`Self::ctx`]) and works on that snapshot, so a
/// concurrent [`Self::swap_from_file`] never blocks or tears an in-flight
/// batch — each batch is answered entirely by the model it started with,
/// and the next batch picks up the new one. Swapping is opt-in
/// (`--allow-swap`) and requires a [`KernelFactory`]
/// ([`Self::with_swap`]).
pub struct ServeCore {
    ctx: RwLock<Arc<ServingContext>>,
    workers: usize,
    t0: Instant,
    /// Global batch-index allocator; total queries served comes from
    /// `totals.rows` (no second counter to keep in sync).
    batches: AtomicUsize,
    conn_ids: AtomicUsize,
    totals: Mutex<BatchStats>,
    shutdown: AtomicBool,
    /// `Some` iff `swap_model` requests are allowed (`--allow-swap true`):
    /// the factory that builds the new model's kernel backend, and the
    /// cache byte budget for contexts that cannot adopt (kind/dim change).
    swap: Option<(KernelFactory, usize)>,
    swaps: AtomicUsize,
    /// `Some` iff `--request-timeout` was set: a socket connection idle
    /// past this (measured from its last completed request or its accept)
    /// is answered with a structured [`ERR_TIMEOUT`] object and closed.
    request_timeout: Option<Duration>,
}

impl ServeCore {
    /// Wrap a serving context; `workers` is the per-batch micro-batching
    /// width handed to [`ServingContext::decide`]. Swapping starts
    /// disabled — see [`Self::with_swap`].
    pub fn new(ctx: ServingContext, workers: usize) -> ServeCore {
        ServeCore {
            ctx: RwLock::new(Arc::new(ctx)),
            workers: workers.max(1),
            t0: Instant::now(),
            batches: AtomicUsize::new(0),
            conn_ids: AtomicUsize::new(0),
            totals: Mutex::new(BatchStats::default()),
            shutdown: AtomicBool::new(false),
            swap: None,
            swaps: AtomicUsize::new(0),
            request_timeout: None,
        }
    }

    /// Enable `swap_model` requests (`--allow-swap true`): `factory`
    /// builds the kernel backend for swapped-in models, `cache_bytes` is
    /// the budget for non-adopting swaps.
    pub fn with_swap(mut self, factory: KernelFactory, cache_bytes: usize) -> ServeCore {
        self.swap = Some((factory, cache_bytes));
        self
    }

    /// Enable the per-connection idle deadline (`--request-timeout`): a
    /// socket connection that goes `t` without completing a request gets a
    /// structured [`ERR_TIMEOUT`] error object and a clean close.
    /// Detection granularity is one [`READ_POLL`] tick.
    pub fn with_request_timeout(mut self, t: Duration) -> ServeCore {
        self.request_timeout = Some(t);
        self
    }

    /// A snapshot of the current serving context. Callers hold the `Arc`
    /// for at most one batch, so a swap's old context is dropped as soon
    /// as the last in-flight batch finishes.
    pub fn ctx(&self) -> Arc<ServingContext> {
        Arc::clone(&self.ctx.read().unwrap())
    }

    /// Whether `swap_model` requests are accepted.
    pub fn swap_allowed(&self) -> bool {
        self.swap.is_some()
    }

    /// Completed model swaps.
    pub fn swaps(&self) -> usize {
        self.swaps.load(Ordering::Relaxed)
    }

    /// Hot-swap to the model in `path`: load + parse the model JSON, build
    /// its kernel, adopt the current context's caches
    /// ([`ServingContext::adopt_from`] — unchanged SV blocks keep their
    /// entries), and publish the new context. In-flight batches finish on
    /// the old context; requests arriving after the publish see the new
    /// one. On any error the served model is untouched.
    pub fn swap_from_file(&self, path: &str) -> Result<SwapOutcome> {
        let Some((factory, cache_bytes)) = &self.swap else {
            bail!("swaps are disabled (start the server with --allow-swap true)");
        };
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        let mut model = ServingModel::from_json(&Json::parse(&text)?)?;
        let old = self.ctx();
        model.set_quant_route(old.model().quant_route());
        let kernel = factory(model.kind(), model.dim())?;
        let (ctx, stats) = ServingContext::adopt_from(model, kernel, *cache_bytes, &old);
        let outcome = SwapOutcome {
            stats,
            svs: ctx.num_svs(),
            describe: ctx.model().describe(),
        };
        *self.ctx.write().unwrap() = Arc::new(ctx);
        self.swaps.fetch_add(1, Ordering::Relaxed);
        Ok(outcome)
    }

    /// Decide one query batch through the shared context, assign it the
    /// next global batch index, and fold its counters into the process
    /// totals. Every transport routes every batch through here. The
    /// context snapshot is taken once per batch: a swap landing mid-batch
    /// never mixes two models' decisions.
    pub fn decide_tracked(&self, x: &[f32]) -> (Vec<f32>, BatchStats, usize) {
        let (dv, _, stats, index) = self.decide_tracked_full(x);
        (dv, stats, index)
    }

    /// [`Self::decide_tracked`] plus the voted class labels (`Some` iff an
    /// OVO model is being served — [`ServingContext::decide_full`]).
    pub fn decide_tracked_full(
        &self,
        x: &[f32],
    ) -> (Vec<f32>, Option<Vec<u16>>, BatchStats, usize) {
        let ctx = self.ctx();
        let (dv, labels, stats) = ctx.decide_full(x, self.workers);
        let index = self.batches.fetch_add(1, Ordering::Relaxed);
        self.totals.lock().unwrap().merge(&stats);
        (dv, labels, stats, index)
    }

    /// Request a graceful server stop: the socket accept loop stops taking
    /// new connections; in-flight connections drain.
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    fn next_conn_id(&self) -> usize {
        self.conn_ids.fetch_add(1, Ordering::Relaxed)
    }

    /// The process-lifetime summary line (PROTOCOL.md §stats glossary):
    /// batch counts, throughput, lifetime component-cache hit rate, and the
    /// aggregated per-batch counters.
    pub fn summary_json(&self) -> Json {
        let dt = self.t0.elapsed().as_secs_f64();
        let cache = self.ctx().stats();
        let totals = *self.totals.lock().unwrap();
        let served = totals.rows;
        Json::obj(vec![
            ("batches", Json::from(self.batches.load(Ordering::Relaxed))),
            ("served", Json::from(served)),
            ("swaps", Json::from(self.swaps())),
            ("total_s", Json::from(dt)),
            ("pred_per_s", Json::from(served as f64 / dt.max(1e-9))),
            ("cache_hits", Json::from(cache.hits as f64)),
            ("cache_misses", Json::from(cache.misses as f64)),
            ("hit_rate", Json::from(cache.hit_rate())),
            ("rows_computed", Json::from(totals.rows_computed as f64)),
            ("routing_hits", Json::from(totals.routing_hits as f64)),
            ("routing_misses", Json::from(totals.routing_misses as f64)),
            ("routing_dispatches", Json::from(totals.routing_dispatches as f64)),
            ("pair_dispatches", Json::from(totals.pair_dispatches as f64)),
            ("votes", Json::from(totals.votes as f64)),
            ("workers", Json::from(self.workers)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Socket transport: newline-delimited JSON requests.

/// Outcome of one request line: the response to write back, the batch
/// stats to fold into per-connection totals (None for control/error
/// requests), and whether the request asked the server to shut down.
pub struct RequestOutcome {
    pub response: Json,
    pub stats: Option<BatchStats>,
    pub shutdown: bool,
}

/// Build a v1 decide request (`{"id": ..., "x": [[f32; dim], ...]}`).
pub fn decide_request(id: Option<Json>, rows: &[Vec<f32>]) -> Json {
    let x = Json::Arr(
        rows.iter()
            .map(|r| Json::Arr(r.iter().map(|&v| Json::Num(v as f64)).collect()))
            .collect(),
    );
    let mut pairs = Vec::new();
    if let Some(id) = id {
        pairs.push(("id", id));
    }
    pairs.push(("x", x));
    Json::obj(pairs)
}

fn outcome(response: Json) -> RequestOutcome {
    RequestOutcome { response, stats: None, shutdown: false }
}

/// Handle one request line of the socket protocol (PROTOCOL.md): parse,
/// validate, decide through the shared core, and build the response
/// object. Never panics on client input — malformed requests map to
/// structured error objects and the connection stays usable.
pub fn handle_request(core: &ServeCore, line: &str) -> RequestOutcome {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            return outcome(error_response(Json::Null, ERR_PARSE, &e.to_string()));
        }
    };
    let id = req.get("id").clone();
    if req.get("shutdown").as_bool() == Some(true) {
        core.request_shutdown();
        return RequestOutcome {
            response: with_id(
                id,
                vec![("ok", Json::from(true)), ("shutdown", Json::from(true))],
            ),
            stats: None,
            shutdown: true,
        };
    }
    if req.get("stats").as_bool() == Some(true) {
        return outcome(with_id(id, vec![("stats_total", core.summary_json())]));
    }
    if let Some(path) = req.get("swap_model").as_str() {
        return match core.swap_from_file(path) {
            Ok(s) => outcome(with_id(
                id,
                vec![
                    ("swapped", Json::from(true)),
                    ("model", Json::from(s.describe.as_str())),
                    ("svs", Json::from(s.svs)),
                    ("blocks_total", Json::from(s.stats.blocks_total)),
                    ("blocks_kept", Json::from(s.stats.blocks_kept)),
                    ("route_kept", Json::from(s.stats.route_kept)),
                ],
            )),
            Err(e) => outcome(error_response(id, ERR_SWAP_FAILED, &format!("{e:#}"))),
        };
    }
    let Some(rows) = req.get("x").as_arr() else {
        return outcome(error_response(
            id,
            ERR_BAD_REQUEST,
            "request needs \"x\": [[f32; dim], ...] (or \"shutdown\"/\"stats\"/\"swap_model\")",
        ));
    };
    let dim = core.ctx().dim();
    // No up-front reserve from the untrusted row count: a request line of
    // millions of empty arrays must not allocate rows.len()·dim floats
    // before the first row fails validation. Push-growth is amortized.
    let mut x: Vec<f32> = Vec::new();
    for (r, row) in rows.iter().enumerate() {
        let Some(vals) = row.as_arr() else {
            return outcome(error_response(
                id,
                ERR_BAD_REQUEST,
                &format!("x[{r}] is not an array of numbers"),
            ));
        };
        if vals.len() != dim {
            return outcome(error_response(
                id,
                ERR_DIM_MISMATCH,
                &format!("x[{r}] has {} features, served model has dim {dim}", vals.len()),
            ));
        }
        for (c, v) in vals.iter().enumerate() {
            // Non-finite features are rejected up front: NaN/inf would
            // poison the kernel AND serialize as invalid JSON (the writer
            // has no token for them).
            let Some(f) = v.as_f64().filter(|f| f.is_finite()) else {
                return outcome(error_response(
                    id,
                    ERR_BAD_REQUEST,
                    &format!("x[{r}][{c}] is not a finite number"),
                ));
            };
            x.push(f as f32);
        }
    }
    let (dv, labels, stats, index) = core.decide_tracked_full(&x);
    let predictions = Json::Arr(
        dv.iter().map(|&d| Json::from(if d >= 0.0 { 1.0 } else { -1.0 })).collect(),
    );
    // f32 → f64 is exact and the JSON writer emits round-trip decimals, so
    // a client recovers bit-identical f32 decision values. A non-finite
    // decision (possible when e.g. a polynomial kernel overflows on finite
    // inputs) serializes as null — the response line must stay valid JSON.
    let decisions = Json::Arr(
        dv.iter()
            .map(|&d| if d.is_finite() { Json::from(d as f64) } else { Json::Null })
            .collect(),
    );
    let mut fields = vec![("predictions", predictions), ("decisions", decisions)];
    // Multiclass (OVO) models also report the voted class label per row;
    // their "decisions" carry the vote margins. Binary responses omit the
    // key entirely (PROTOCOL.md).
    if let Some(labels) = labels {
        fields.push((
            "labels",
            Json::Arr(labels.iter().map(|&l| Json::from(l as usize)).collect()),
        ));
    }
    fields.push(("stats", stats.to_json(index)));
    RequestOutcome {
        response: with_id(id, fields),
        stats: Some(stats),
        shutdown: false,
    }
}

/// Serve one accepted connection to completion: one response line per
/// request line, until EOF, a write failure (client went away — the
/// SIGPIPE-as-EPIPE path), an oversized request line, an idle deadline
/// (`--request-timeout` → structured [`ERR_TIMEOUT`] + close), or a
/// shutdown request. Reads poll on [`READ_POLL`] so a worker parked on an
/// idle connection still notices a shutdown requested elsewhere, and line
/// length is bounded by [`MAX_REQUEST_BYTES`]. Emits a per-connection
/// stats summary line on stderr when the connection ends.
fn handle_connection(core: &ServeCore, stream: TcpStream, conn_id: usize) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".to_string());
    let Ok(mut codec) = wire::tcp_codec(stream) else { return };
    let mut conn_totals = BatchStats::default();
    let mut requests = 0u64;
    // Idle-deadline clock (`--request-timeout`): reset whenever a request
    // completes, so the deadline bounds gaps between requests, not
    // connection lifetime.
    let mut last_activity = Instant::now();
    loop {
        // A back-to-back sender never produces an Idle frame, so the
        // shutdown flag must also be checked between served requests or a
        // busy client could stall a graceful shutdown forever. An Idle
        // frame (read-poll tick) loops back here too — that is how an
        // idle connection notices a shutdown requested elsewhere.
        if core.shutdown_requested() {
            break;
        }
        let frame = match codec.read_frame() {
            Ok(f) => f,
            Err(_) => break,
        };
        match frame {
            Frame::Eof => break, // clean EOF between requests
            Frame::Idle => {
                // Read-poll tick with no bytes: the only place an idle
                // deadline can fire (a mid-request stall surfaces here
                // too, since partial lines never complete a frame).
                if let Some(t) = core.request_timeout {
                    if last_activity.elapsed() >= t {
                        let resp = error_response(
                            Json::Null,
                            ERR_TIMEOUT,
                            &format!(
                                "connection idle past the {:.1}s --request-timeout deadline",
                                t.as_secs_f64()
                            ),
                        );
                        let _ = codec.write_json(&resp);
                        break;
                    }
                }
                continue;
            }
            Frame::Overflow => {
                let resp = error_response(
                    Json::Null,
                    ERR_BAD_REQUEST,
                    &format!("request line exceeds {MAX_REQUEST_BYTES} bytes"),
                );
                let _ = codec.write_json(&resp);
                break; // line framing lost mid-line: close
            }
            Frame::NotUtf8 => {
                // Framing is intact (the codec read to a newline), so
                // answer with a structured error and keep the connection
                // usable.
                let resp = error_response(
                    Json::Null,
                    ERR_PARSE,
                    "request line is not valid UTF-8",
                );
                if codec.write_json(&resp).is_err() {
                    break;
                }
                last_activity = Instant::now();
            }
            Frame::Line(line) => {
                if line.trim().is_empty() {
                    continue;
                }
                let out = handle_request(core, &line);
                if let Some(stats) = &out.stats {
                    conn_totals.merge(stats);
                }
                requests += 1;
                if codec.write_json(&out.response).is_err() {
                    break;
                }
                last_activity = Instant::now();
                if out.shutdown {
                    break;
                }
            }
        }
    }
    eprintln!(
        "{}",
        Json::obj(vec![
            ("conn", Json::from(conn_id)),
            ("peer", Json::from(peer)),
            ("requests", Json::from(requests as f64)),
            ("rows", Json::from(conn_totals.rows)),
            ("cache_hits", Json::from(conn_totals.cache_hits as f64)),
            ("cache_misses", Json::from(conn_totals.cache_misses as f64)),
            ("rows_computed", Json::from(conn_totals.rows_computed as f64)),
            ("routing_dispatches", Json::from(conn_totals.routing_dispatches as f64)),
            ("latency_ms", Json::from(conn_totals.latency_s * 1e3)),
        ])
    );
}

/// Accept connections on `listener` and serve them from `conn_workers`
/// worker threads, all sharing `core`'s one [`ServingContext`]. The
/// accept loop hands each connection to the pool over a bounded
/// [`WorkQueue`] (capacity `2 × conn_workers`): when every worker is busy
/// and the queue is full, accepting blocks — backpressure, not unbounded
/// buffering. Returns after a graceful shutdown request
/// (`{"shutdown": true}` on any connection): new connections stop being
/// accepted, queued and in-flight requests drain, and connections
/// sitting idle are closed at their next [`READ_POLL`] tick.
pub fn run_listener(
    core: &ServeCore,
    listener: TcpListener,
    conn_workers: usize,
) -> Result<()> {
    let conn_workers = conn_workers.max(1);
    let mut wake_addr = listener.local_addr().context("serve: listener local_addr")?;
    // A wildcard bind (0.0.0.0 / [::]) is not connectable on every
    // platform; the shutdown wake-up dials loopback on the bound port.
    if wake_addr.ip().is_unspecified() {
        wake_addr.set_ip(match wake_addr.ip() {
            std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
            std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
        });
    }
    let queue: WorkQueue<TcpStream> = WorkQueue::new(conn_workers * 2);
    std::thread::scope(|s| {
        for _ in 0..conn_workers {
            s.spawn(|| {
                while let Some(stream) = queue.pop() {
                    handle_connection(core, stream, core.next_conn_id());
                    if core.shutdown_requested() {
                        queue.close();
                        // The accept loop may be parked in accept();
                        // a throwaway local connection wakes it so it can
                        // observe the flag and exit.
                        let _ = TcpStream::connect(wake_addr);
                    }
                }
            });
        }
        loop {
            if core.shutdown_requested() {
                break;
            }
            let stream = match listener.accept() {
                Ok((stream, _)) => stream,
                Err(_) => {
                    if core.shutdown_requested() {
                        break;
                    }
                    // Persistent accept errors (e.g. EMFILE under fd
                    // pressure) must not busy-spin the loop.
                    std::thread::sleep(Duration::from_millis(50));
                    continue;
                }
            };
            // A post-shutdown accept is (usually) the wake-up connection —
            // either way, stop accepting and let the pool drain.
            if core.shutdown_requested() {
                break;
            }
            if !queue.push(stream) {
                break;
            }
        }
        queue.close();
    });
    Ok(())
}

// ---------------------------------------------------------------------------
// Stdio transport: LIBSVM rows in, prediction lines out.

/// The stdio serve loop against arbitrary reader/writers (the testable
/// core of [`run_stdio`]): read LIBSVM rows from `reader` in batches of
/// `batch` lines, decide each batch through the shared core, write one
/// `±1 decision` line per row to `out` (decision values in round-trip
/// decimal — parsing them back yields the exact f32), and one JSON stats
/// line per batch to `err`. A broken pipe on `out` ends the loop
/// gracefully, mirroring the socket transport's disconnect handling.
pub fn run_stdio_io<R: BufRead, W: Write, E: Write>(
    core: &ServeCore,
    batch: usize,
    reader: R,
    mut out: W,
    mut err: E,
) -> Result<()> {
    let batch = batch.max(1);
    let mut lines = reader.lines();
    let mut buf: Vec<String> = Vec::with_capacity(batch);
    loop {
        buf.clear();
        while buf.len() < batch {
            match lines.next() {
                Some(Ok(l)) if !l.trim().is_empty() => buf.push(l),
                Some(Ok(_)) => continue,
                Some(Err(e)) => return Err(e.into()),
                None => break,
            }
        }
        if buf.is_empty() {
            break;
        }
        let joined = buf.join("\n");
        let ds = crate::data::libsvm::parse_libsvm(
            std::io::Cursor::new(joined),
            Some(core.ctx().dim()),
            "stdin".into(),
        )?;
        let (dv, labels, stats, index) = core.decide_tracked_full(&ds.x);
        let mut text = String::new();
        match &labels {
            // OVO: one "label margin" line per row (labels are class ids).
            Some(labels) => {
                for (&l, &d) in labels.iter().zip(&dv) {
                    text.push_str(&format!("{l} {d}\n"));
                }
            }
            None => {
                for &d in &dv {
                    text.push_str(&format!("{} {}\n", if d >= 0.0 { "+1" } else { "-1" }, d));
                }
            }
        }
        if let Err(e) = out.write_all(text.as_bytes()).and_then(|()| out.flush()) {
            if e.kind() == std::io::ErrorKind::BrokenPipe {
                break;
            }
            return Err(e.into());
        }
        let _ = writeln!(err, "{}", stats.to_json(index));
    }
    Ok(())
}

/// [`run_stdio_io`] wired to the process's stdin/stdout/stderr.
pub fn run_stdio(core: &ServeCore, batch: usize) -> Result<()> {
    let stdin = std::io::stdin();
    run_stdio_io(core, batch, stdin.lock(), std::io::stdout(), std::io::stderr())
}

// ---------------------------------------------------------------------------
// Blocking client (tests + examples/serve_client.rs).

/// Minimal blocking client for the socket protocol: one request line out,
/// one response line back. Test and example harness — not a production
/// SDK (no timeouts, no reconnects).
pub struct ServeClient {
    codec: Codec<BufReader<TcpStream>, TcpStream>,
}

impl ServeClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<ServeClient> {
        let stream = TcpStream::connect(addr).context("connect to serve socket")?;
        let reader =
            BufReader::new(stream.try_clone().context("clone serve socket")?);
        // No read timeout (the client blocks until its server answers) and
        // no response cap (it trusts its own server), matching read_line.
        Ok(ServeClient {
            codec: Codec::new(reader, stream).with_max_bytes(usize::MAX),
        })
    }

    /// One request/response round trip; returns the parsed response object
    /// (which may be an error object — the caller inspects `"error"`).
    pub fn request(&mut self, req: &Json) -> Result<Json> {
        self.codec.write_json(req)?;
        loop {
            match self.codec.read_frame()? {
                Frame::Line(line) => {
                    return Json::parse(line.trim_end())
                        .map_err(|e| anyhow!("bad response line: {e}"));
                }
                Frame::Eof => bail!("server closed the connection"),
                Frame::Idle => continue, // reachable only with a timeout set
                Frame::Overflow => bail!("response line exceeds the frame cap"),
                Frame::NotUtf8 => bail!("response line is not valid UTF-8"),
            }
        }
    }

    /// Decide a batch of query rows (each of the served model's dim).
    pub fn decide(&mut self, rows: &[Vec<f32>]) -> Result<Json> {
        self.request(&decide_request(None, rows))
    }

    /// Ask the server to shut down gracefully (stop accepting, drain).
    pub fn shutdown_server(&mut self) -> Result<Json> {
        self.request(&Json::obj(vec![("shutdown", Json::from(true))]))
    }

    /// Ask the server to hot-swap to the model file at `path` (requires
    /// `--allow-swap true` on the server).
    pub fn swap_model(&mut self, path: &str) -> Result<Json> {
        self.request(&Json::obj(vec![("swap_model", Json::from(path))]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate_split};
    use crate::kernel::native::NativeKernel;
    use crate::kernel::KernelKind;
    use crate::predict::SvmModel;
    use crate::serving::ServingModel;

    /// A core around a zero-SV exact model (decisions are all 0.0): cheap
    /// to build, exercises the full request path.
    fn tiny_core() -> ServeCore {
        let (tr, _) = generate_split(&covtype_like(), 40, 10, 1);
        let kind = KernelKind::Rbf { gamma: 1.0 };
        let model = SvmModel::from_alpha(&tr, &vec![0.0; tr.len()], kind);
        let ctx = ServingContext::new(
            ServingModel::Exact(model),
            Box::new(NativeKernel::new(kind)),
            1 << 20,
        );
        ServeCore::new(ctx, 1)
    }

    #[test]
    fn usage_and_readme_rows_cover_every_flag() {
        let usage = serve_usage();
        assert!(usage.starts_with("usage: dcsvm serve"));
        for f in SERVE_FLAGS {
            assert!(usage.contains(f.flag), "usage missing {}", f.flag);
            assert!(usage.contains(f.help), "usage missing help for {}", f.flag);
            let row = readme_row(f);
            assert!(row.starts_with("| `"), "{row}");
            assert!(row.contains(f.default), "{row}");
            // A raw pipe inside a cell would break the README table.
            let cells = [f.flag, f.value, f.default, f.help];
            assert!(
                cells.iter().all(|c| !c.contains('|')),
                "markdown table cells must not contain raw pipes: {}",
                f.flag
            );
        }
    }

    #[test]
    fn malformed_requests_get_structured_errors() {
        let core = tiny_core();
        let out = handle_request(&core, "this is not json");
        assert_eq!(out.response.get("error").get("code").as_str(), Some(ERR_PARSE));
        assert!(!out.shutdown);
        assert!(out.stats.is_none());

        let out = handle_request(&core, r#"{"id": 3, "rows": []}"#);
        assert_eq!(
            out.response.get("error").get("code").as_str(),
            Some(ERR_BAD_REQUEST)
        );
        assert_eq!(out.response.get("id").as_f64(), Some(3.0), "id echoed on errors");

        let out = handle_request(&core, r#"{"x": [[1.0, 2.0]]}"#);
        assert_eq!(
            out.response.get("error").get("code").as_str(),
            Some(ERR_DIM_MISMATCH)
        );

        // Non-finite features are rejected before touching the kernel
        // ("1e999" parses as +inf, which JSON could not serialize back).
        let mut features: Vec<String> = vec!["0.5".to_string(); core.ctx().dim()];
        features[0] = "1e999".to_string();
        let line = format!("{{\"x\": [[{}]]}}", features.join(","));
        let out = handle_request(&core, &line);
        assert_eq!(
            out.response.get("error").get("code").as_str(),
            Some(ERR_BAD_REQUEST)
        );

        // The shutdown flag must be untouched by bad requests.
        assert!(!core.shutdown_requested());
    }

    #[test]
    fn decide_request_roundtrips_through_the_core() {
        let core = tiny_core();
        let dim = core.ctx().dim();
        let rows = vec![vec![0.5f32; dim], vec![0.25f32; dim]];
        let line = decide_request(Some(Json::from(7usize)), &rows).to_string();
        let out = handle_request(&core, &line);
        assert_eq!(out.response.get("error"), &Json::Null, "{}", out.response);
        assert_eq!(out.response.get("id").as_usize(), Some(7));
        let decisions = out.response.get("decisions").as_arr().unwrap();
        assert_eq!(decisions.len(), 2);
        let preds = out.response.get("predictions").as_arr().unwrap();
        assert!(preds.iter().all(|p| matches!(p.as_f64(), Some(v) if v.abs() == 1.0)));
        assert_eq!(out.response.get("stats").get("rows").as_usize(), Some(2));
        assert!(out.stats.is_some());
        // Binary responses never carry a labels key (PROTOCOL.md).
        assert_eq!(out.response.get("labels"), &Json::Null);
    }

    /// A core serving a small OVO ensemble (multiclass request tests).
    fn ovo_core() -> (ServeCore, crate::multiclass::OvoModel, crate::multiclass::MulticlassDataset)
    {
        use crate::multiclass::{synthetic_multiclass, train_ovo};
        let tr = synthetic_multiclass(3, 180, 3, 4);
        let kind = KernelKind::Rbf { gamma: 2.0 };
        let kern = NativeKernel::new(kind);
        let cfg = crate::dcsvm::DcSvmConfig {
            kind,
            c: 4.0,
            levels: 1,
            sample_m: 24,
            ..Default::default()
        };
        let model = train_ovo(&tr, &kern, &cfg);
        let ctx = ServingContext::new(
            ServingModel::Ovo(model.clone()),
            Box::new(NativeKernel::new(kind)),
            1 << 20,
        );
        (ServeCore::new(ctx, 1), model, tr)
    }

    #[test]
    fn ovo_requests_return_voted_labels() {
        let (core, model, tr) = ovo_core();
        let kern = NativeKernel::new(model.kind);
        let nq = 3usize;
        let dim = core.ctx().dim();
        let norms: Vec<f32> = (0..nq)
            .map(|i| tr.row(i).iter().map(|&v| v * v).sum())
            .collect();
        let want = model.predict_with_margins(&tr.x[..nq * dim], &norms, &kern);
        let rows: Vec<Vec<f32>> =
            (0..nq).map(|i| tr.x[i * dim..(i + 1) * dim].to_vec()).collect();
        let out = handle_request(&core, &decide_request(None, &rows).to_string());
        assert_eq!(out.response.get("error"), &Json::Null, "{}", out.response);
        let labels = out.response.get("labels").as_arr().unwrap();
        let decisions = out.response.get("decisions").as_arr().unwrap();
        assert_eq!(labels.len(), nq);
        for (t, &(l, m)) in want.iter().enumerate() {
            assert_eq!(labels[t].as_usize(), Some(l as usize), "label mismatch at {t}");
            assert_eq!(decisions[t].as_f64().map(|v| v as f32), Some(m));
        }
        let stats = out.response.get("stats");
        assert_eq!(
            stats.get("pair_dispatches").as_f64(),
            Some(model.machines.len() as f64)
        );
        assert_eq!(stats.get("votes").as_f64(), Some((model.machines.len() * nq) as f64));
        // The lifetime summary aggregates the new counters too.
        let total = core.summary_json();
        assert_eq!(total.get("pair_dispatches").as_f64(), Some(model.machines.len() as f64));
        assert!(total.get("votes").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn ovo_stdio_lines_are_label_then_margin() {
        let (core, model, tr) = ovo_core();
        let kern = NativeKernel::new(model.kind);
        let dim = core.ctx().dim();
        let nq = 4usize;
        let norms: Vec<f32> = (0..nq)
            .map(|i| tr.row(i).iter().map(|&v| v * v).sum())
            .collect();
        let want = model.predict_with_margins(&tr.x[..nq * dim], &norms, &kern);
        let text =
            crate::data::libsvm::format_libsvm_multiclass(&tr.x[..nq * dim], &tr.labels[..nq], dim);
        let mut out = Vec::new();
        let mut err = Vec::new();
        run_stdio_io(&core, 8, std::io::Cursor::new(text), &mut out, &mut err).unwrap();
        let out = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), nq, "{out}");
        for (t, line) in lines.iter().enumerate() {
            let (label, margin) = line.split_once(' ').unwrap();
            assert_eq!(label.parse::<u16>().unwrap(), want[t].0, "line {t}: {line}");
            assert_eq!(margin.parse::<f32>().unwrap(), want[t].1, "line {t}: {line}");
        }
    }

    #[test]
    fn shutdown_request_flags_the_core() {
        let core = tiny_core();
        assert!(!core.shutdown_requested());
        let out = handle_request(&core, r#"{"shutdown": true}"#);
        assert!(out.shutdown);
        assert!(core.shutdown_requested());
        assert_eq!(out.response.get("ok").as_bool(), Some(true));
        assert_eq!(out.response.get("shutdown").as_bool(), Some(true));
    }

    #[test]
    fn idle_connection_times_out_with_structured_error_and_close() {
        use std::io::BufRead as _;
        let core = tiny_core().with_request_timeout(Duration::from_millis(300));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::scope(|s| {
            let server = s.spawn(|| {
                let (stream, _) = listener.accept().unwrap();
                handle_connection(&core, stream, 0);
            });
            let client = TcpStream::connect(addr).unwrap();
            let mut reader = std::io::BufReader::new(client.try_clone().unwrap());
            // A request served before the deadline resets the idle clock —
            // the timeout bounds gaps between requests, not connection age.
            let dim = core.ctx().dim();
            let line = decide_request(Some(Json::from(1usize)), &[vec![0.5f32; dim]]).to_string();
            {
                let mut w = client.try_clone().unwrap();
                writeln!(w, "{line}").unwrap();
            }
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            let resp = Json::parse(&resp).unwrap();
            assert_eq!(resp.get("error"), &Json::Null, "{resp}");
            // Now go silent: the server must answer with a structured
            // timeout error and close, not hold the handler forever.
            let t0 = Instant::now();
            let mut err_line = String::new();
            reader.read_line(&mut err_line).unwrap();
            let err = Json::parse(&err_line).unwrap();
            assert_eq!(err.get("error").get("code").as_str(), Some(ERR_TIMEOUT), "{err}");
            assert!(
                err.get("error").get("message").as_str().unwrap().contains("--request-timeout"),
                "{err}"
            );
            // ... followed by a clean EOF (read_line returns 0 bytes).
            let mut eof = String::new();
            assert_eq!(reader.read_line(&mut eof).unwrap(), 0, "{eof:?}");
            assert!(t0.elapsed() < Duration::from_secs(10), "timeout took {:?}", t0.elapsed());
            server.join().unwrap();
        });
    }

    #[test]
    fn stats_request_reports_core_totals() {
        let core = tiny_core();
        let dim = core.ctx().dim();
        let rows = vec![vec![0.125f32; dim]];
        handle_request(&core, &decide_request(None, &rows).to_string());
        let out = handle_request(&core, r#"{"id": "s", "stats": true}"#);
        let total = out.response.get("stats_total");
        assert_eq!(total.get("batches").as_usize(), Some(1));
        assert_eq!(total.get("served").as_usize(), Some(1));
        assert_eq!(out.response.get("id").as_str(), Some("s"));
    }

    #[test]
    fn swap_requests_rejected_unless_enabled() {
        let core = tiny_core();
        let out = handle_request(&core, r#"{"id": 1, "swap_model": "/nope.json"}"#);
        assert_eq!(
            out.response.get("error").get("code").as_str(),
            Some(ERR_SWAP_FAILED)
        );
        assert!(out
            .response
            .get("error")
            .get("message")
            .as_str()
            .unwrap()
            .contains("--allow-swap"));
        assert_eq!(out.response.get("id").as_f64(), Some(1.0));
        assert_eq!(core.swaps(), 0);
    }

    #[test]
    fn swap_replaces_the_served_model_and_counts() {
        let (tr, _) = generate_split(&covtype_like(), 60, 10, 2);
        let kind = KernelKind::Rbf { gamma: 2.0 };
        let zero = SvmModel::from_alpha(&tr, &vec![0.0; tr.len()], kind);
        let ctx = ServingContext::new(
            ServingModel::Exact(zero),
            Box::new(NativeKernel::new(kind)),
            1 << 20,
        );
        let factory: KernelFactory =
            Box::new(|kind, _dim| Ok(Box::new(NativeKernel::new(kind))));
        let core = ServeCore::new(ctx, 1).with_swap(factory, 1 << 20);
        assert!(core.swap_allowed());
        assert_eq!(core.ctx().num_svs(), 0);

        // A model with SVs, written to disk like `dcsvm update --out`.
        let trained = SvmModel::from_alpha(&tr, &vec![0.5; tr.len()], kind);
        let dir = std::env::temp_dir().join("dcsvm-swap-unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("swapped.json");
        std::fs::write(&path, trained.to_json().to_string()).unwrap();

        let line = format!("{{\"swap_model\": {}}}", Json::from(path.to_str().unwrap()));
        let out = handle_request(&core, &line);
        assert_eq!(out.response.get("error"), &Json::Null, "{}", out.response);
        assert_eq!(out.response.get("swapped").as_bool(), Some(true));
        assert_eq!(out.response.get("svs").as_usize(), Some(trained.num_svs()));
        assert!(out.response.get("blocks_total").as_usize().unwrap() >= 1);
        assert_eq!(core.swaps(), 1);
        assert_eq!(core.ctx().num_svs(), trained.num_svs());
        assert_eq!(core.summary_json().get("swaps").as_usize(), Some(1));

        // A bad file leaves the swapped model serving.
        let out = handle_request(&core, r#"{"swap_model": "/no/such/file.json"}"#);
        assert_eq!(
            out.response.get("error").get("code").as_str(),
            Some(ERR_SWAP_FAILED)
        );
        assert_eq!(core.swaps(), 1);
        assert_eq!(core.ctx().num_svs(), trained.num_svs());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stdio_loop_emits_predictions_and_stats() {
        let core = tiny_core();
        let dim = core.ctx().dim();
        let mut text = String::new();
        for r in 0..2 {
            text.push('1'); // label: required by the LIBSVM format, ignored
            for j in 0..dim {
                text.push_str(&format!(" {}:{}", j + 1, (r + j) as f32 * 0.1));
            }
            text.push('\n');
        }
        let mut out = Vec::new();
        let mut err = Vec::new();
        run_stdio_io(&core, 8, std::io::Cursor::new(text), &mut out, &mut err)
            .unwrap();
        let out = String::from_utf8(out).unwrap();
        assert_eq!(out.lines().count(), 2, "{out}");
        assert!(out.lines().all(|l| l.starts_with("+1 ") || l.starts_with("-1 ")));
        let err = String::from_utf8(err).unwrap();
        assert!(err.lines().any(|l| l.starts_with('{')), "{err}");
        let summary = core.summary_json();
        assert_eq!(summary.get("served").as_usize(), Some(2));
        assert_eq!(summary.get("batches").as_usize(), Some(1));
    }
}
