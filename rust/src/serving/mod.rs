//! Persistent serving subsystem: cross-request kernel reuse for
//! `dcsvm serve`.
//!
//! The paper's headline serving result (early prediction: ~96% covtype
//! accuracy at ~100× LIBSVM's prediction speed) is only reachable if the
//! request path stops re-paying per-batch setup. The old serve loop built a
//! throwaway [`crate::cache::KernelContext`] per stdin batch, so every
//! batch recomputed SV norms and every kernel value — the serving-path
//! twin of the training-side waste the shared context removed.
//!
//! [`ServingContext`] is built **once** per loaded model and lives for the
//! whole process:
//!
//! - it owns the deserialized [`ServingModel`] (exact [`SvmModel`] or the
//!   early-prediction [`EarlyModel`]), whose SV rows/norms/coefficients are
//!   the dataset the kernel runs against;
//! - it owns the [`BlockKernel`] backend (native or PJRT), so backend
//!   selection and artifact lookup happen once;
//! - it owns one byte-budgeted [`ShardedRowCache`] per decision component
//!   (one for an exact model, one per cluster for an early model) holding
//!   **SV-block segments** of kernel rows against that component's SV set:
//!   the SV set is split into contiguous blocks of [`DEFAULT_SV_BLOCK`]
//!   vectors and each cache entry is
//!   `[query (dim) | K(query, sv_block)]`, keyed by the 64-bit content
//!   fingerprint of the query row mixed with the block index. Repeated
//!   queries — health probes, hot keys, retried requests, replayed batches
//!   — hit instead of recompute, across request batches, for the life of
//!   the process; the block granularity is the serving twin of the
//!   training cache's `(row, segment)` keys and the substrate for
//!   near-duplicate reuse (a future quantized fingerprint can share
//!   unchanged blocks between similar queries).
//!
//! Decisions are evaluated from the cached blocks (`Σ_j coef_j · row_j`,
//! accumulated block by block in ascending SV order — the exact operation
//! sequence of a single pass over the whole SV set), so a hit is
//! bit-identical to the original computation and the block split never
//! changes a decision value: two
//! identical batches produce identical decision values while the second
//! computes zero kernel rows against the SV set
//! (`tests/serving_roundtrip.rs`). Early-model *routing* is cached the
//! same way: a per-fingerprint routing cache stores each query's decision
//! component (`[query | component]`), so a fully warm batch skips the
//! `K(batch, sample)` routing dispatch entirely and performs **zero**
//! kernel work of any kind ([`BatchStats::routing_dispatches`] is 0).
//!
//! Correctness under fingerprint collisions: the query itself is stored as
//! the entry prefix and verified on every hit. A colliding key (probability
//! ~2⁻⁶⁴ per pair) degrades to an uncached recompute — never a wrong row.
//!
//! Request batches are micro-batched across a `--workers` scoped pool
//! ([`scope_map`]); the sharded cache admits concurrent fills, and outputs
//! are returned in input order regardless of worker count. Each
//! [`ServingContext::decide`] call returns a [`BatchStats`] —
//! latency/throughput/hit counters serialized as one JSON line per request
//! batch by the CLI.
//!
//! Transports: the CLI's stdio loop and the `--listen` TCP socket
//! front-end both delegate to one request-handling core in [`transport`],
//! so N concurrent connections share ONE context — kernel rows computed
//! for one client warm the cache for every other client (PROTOCOL.md
//! documents the wire format).
//!
//! **Hot swap** (`dcsvm update` → zero-downtime serving): a context can be
//! rebuilt around an updated model with [`ServingContext::adopt_from`],
//! which *shares* the predecessor's per-component caches (they are
//! `Arc`ed) and revalidates them block by block. Every cache entry starts
//! with a **block tag** — `[tag | query (dim) | K(block)]` — and each
//! `(component, block)` pair owns one tag. Adoption keeps a block's tag
//! iff its SV slice is bit-identical in the new model (same block size,
//! same span, same feature bits; coefficients are read at fold time and
//! may change freely), and allocates a fresh tag otherwise, so stale
//! entries under unchanged keys fail the tag check, miss, and are
//! recomputed in place ([`ShardedRowCache::put_replace`]). A warm client
//! replaying a query after a swap therefore recomputes rows **only for
//! changed blocks** — the unchanged prefix of an incrementally updated SV
//! set keeps hitting (`tests/serve_socket.rs` counts it). The early-model
//! routing cache is shared iff the router (sample set + centroids) is
//! JSON-identical, and rebuilt otherwise.

pub mod transport;

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::cache::{CacheStats, ShardedRowCache};
use crate::kernel::{BlockKernel, KernelKind};
use crate::multiclass::OvoModel;
use crate::predict::{EarlyModel, SvmModel};
use crate::util::json::Json;
use crate::util::threadpool::scope_map;

/// Shard count of each serving cache: enough to keep `--workers` request
/// threads from serializing on fills.
const SERVE_SHARDS: usize = 16;

/// SV vectors per cache block: components with more SVs split their
/// `[query | K(query, SV-set)]` entries into per-block segments (tests
/// shrink it via [`ServingContext::with_block_size`]; small models fit one
/// block and behave exactly as before).
pub const DEFAULT_SV_BLOCK: usize = 512;

/// Cache key of one (query fingerprint, SV block) pair. Distinct blocks of
/// the same query always get distinct keys; cross-query collisions are
/// caught by the stored-query verification on hit.
#[inline]
fn block_key(fp: u64, block: usize) -> u64 {
    fp.wrapping_add((block as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// A deserialized model the serving layer can evaluate.
pub enum ServingModel {
    /// The exact global model: one SV set, one decision function.
    Exact(SvmModel),
    /// The paper's early-prediction model (eq. 11): route to a cluster,
    /// evaluate only that cluster's local model.
    Early(EarlyModel),
    /// One-vs-one multiclass ensemble: one decision component per class
    /// (the per-class SV block), every machine's vote folded from the same
    /// cached class rows ([`OvoModel::machine_decisions`]).
    Ovo(OvoModel),
}

impl ServingModel {
    /// Load from model-file JSON. OVO ensembles carry a `"machines"`
    /// array ([`OvoModel::to_json`]) — checked first; early-model files
    /// carry a `"router"` object ([`EarlyModel::to_json`]); everything
    /// else parses as a plain [`SvmModel`] (including pre-`"type"`-field
    /// files).
    pub fn from_json(j: &Json) -> Result<ServingModel> {
        if j.get("machines").as_arr().is_some() {
            Ok(ServingModel::Ovo(OvoModel::from_json(j)?))
        } else if j.get("router").as_obj().is_some() {
            Ok(ServingModel::Early(EarlyModel::from_json(j)?))
        } else {
            Ok(ServingModel::Exact(SvmModel::from_json(j)?))
        }
    }

    /// Feature dimension queries must have.
    pub fn dim(&self) -> usize {
        match self {
            ServingModel::Exact(m) => m.dim,
            ServingModel::Early(em) => em.dim(),
            ServingModel::Ovo(m) => m.dim,
        }
    }

    /// Kernel family + parameters the backend must implement.
    pub fn kind(&self) -> KernelKind {
        match self {
            ServingModel::Exact(m) => m.kind,
            ServingModel::Early(em) => em.kind(),
            ServingModel::Ovo(m) => m.kind,
        }
    }

    /// Total support vectors (across locals for an early model, across
    /// class blocks for an OVO ensemble).
    pub fn num_svs(&self) -> usize {
        match self {
            ServingModel::Exact(m) => m.num_svs(),
            ServingModel::Early(em) => em.total_svs(),
            ServingModel::Ovo(m) => m.num_svs(),
        }
    }

    /// Short human-readable tag for logs ("exact" / "early(k=16)" /
    /// "ovo(classes=7, machines=21)").
    pub fn describe(&self) -> String {
        match self {
            ServingModel::Exact(_) => "exact".to_string(),
            ServingModel::Early(em) => format!("early(k={})", em.locals.len()),
            ServingModel::Ovo(m) => {
                format!("ovo(classes={}, machines={})", m.present.len(), m.machines.len())
            }
        }
    }

    /// Enable (or disable) int8-quantized routing for an early model
    /// (`--quant-route`). Routing is the only approximation-tolerant stage
    /// of the serving path, so this never touches decision evaluation: an
    /// exact or OVO model has no router and the call is a no-op. Must be
    /// set before the model is moved into a [`ServingContext`].
    pub fn set_quant_route(&mut self, on: bool) {
        match self {
            ServingModel::Exact(_) | ServingModel::Ovo(_) => {}
            ServingModel::Early(em) => em.set_quant_route(on),
        }
    }

    /// Whether quantized routing is armed (always false for exact/OVO
    /// models).
    pub fn quant_route(&self) -> bool {
        match self {
            ServingModel::Exact(_) | ServingModel::Ovo(_) => false,
            ServingModel::Early(em) => em.quant_route(),
        }
    }
}

/// Per-request-batch serving statistics: one [`ServingContext::decide`]
/// call produces one of these, and the CLI emits it as a JSON line.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStats {
    /// Queries in the batch.
    pub rows: usize,
    /// Wall-clock of the whole decide call (routing + kernel + reduction).
    pub latency_s: f64,
    /// Serving-cache hits this batch (queries answered without any kernel
    /// computation).
    pub cache_hits: u64,
    /// Serving-cache misses this batch.
    pub cache_misses: u64,
    /// Kernel rows (query × SV-set) actually computed this batch; a fully
    /// warm batch computes zero.
    pub rows_computed: u64,
    /// Early-model routing decisions answered from the per-fingerprint
    /// routing cache (always 0 for exact models, which need no routing).
    pub routing_hits: u64,
    /// Early-model routing cache misses (queries whose component had to be
    /// computed); always 0 for exact models.
    pub routing_misses: u64,
    /// `K(batch, sample)` routing kernel dispatches this batch: 0 or 1.
    /// A fully warm early-model batch — and every exact-model batch —
    /// dispatches none.
    pub routing_dispatches: u64,
    /// OVO pairwise machines evaluated this batch (= `machines.len()` for
    /// a non-empty multiclass batch; 0 for binary models). Each machine's
    /// decision folds the batch's cached per-class kernel rows — this
    /// counts the fan-out, not extra kernel work.
    pub pair_dispatches: u64,
    /// OVO pairwise votes cast this batch (= rows × machines; 0 for
    /// binary models).
    pub votes: u64,
}

impl BatchStats {
    /// Hit fraction of this batch's cache probes.
    pub fn hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Predictions per second.
    pub fn throughput(&self) -> f64 {
        self.rows as f64 / self.latency_s.max(1e-9)
    }

    /// The structured per-request summary line (`--workers`/latency/cache
    /// plumbing for dashboards and EXPERIMENTS.md).
    pub fn to_json(&self, batch_index: usize) -> Json {
        Json::obj(vec![
            ("batch", Json::from(batch_index)),
            ("rows", Json::from(self.rows)),
            ("latency_ms", Json::from(self.latency_s * 1e3)),
            ("pred_per_s", Json::from(self.throughput())),
            ("cache_hits", Json::from(self.cache_hits as f64)),
            ("cache_misses", Json::from(self.cache_misses as f64)),
            ("hit_rate", Json::from(self.hit_rate())),
            ("rows_computed", Json::from(self.rows_computed as f64)),
            ("routing_hits", Json::from(self.routing_hits as f64)),
            ("routing_misses", Json::from(self.routing_misses as f64)),
            ("routing_dispatches", Json::from(self.routing_dispatches as f64)),
            ("pair_dispatches", Json::from(self.pair_dispatches as f64)),
            ("votes", Json::from(self.votes as f64)),
        ])
    }

    /// Fold another batch's counters into an aggregate (the serve
    /// transport's per-connection and global totals). Rows and latencies
    /// add; rates are recomputed from the summed counters.
    pub fn merge(&mut self, other: &BatchStats) {
        self.rows += other.rows;
        self.latency_s += other.latency_s;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.rows_computed += other.rows_computed;
        self.routing_hits += other.routing_hits;
        self.routing_misses += other.routing_misses;
        self.routing_dispatches += other.routing_dispatches;
        self.pair_dispatches += other.pair_dispatches;
        self.votes += other.votes;
    }
}

/// Persistent per-model serving state: model + backend + per-component
/// serving caches. Construct once per loaded model; share across all
/// request batches (it is `Sync` — workers only need `&self`).
pub struct ServingContext {
    model: ServingModel,
    kernel: Box<dyn BlockKernel>,
    dim: usize,
    /// SV vectors per cache block (see [`DEFAULT_SV_BLOCK`]).
    sv_block: usize,
    /// One cache per decision component: index 0 for an exact model, index
    /// c for early-model cluster c. Entry layout, per SV block b:
    /// `[tag | query (dim) | K(query, sv_{b·B} .. sv_{min((b+1)·B, s)})]`.
    /// `Arc`ed so a hot-swapped successor context can adopt them in place
    /// ([`Self::adopt_from`]).
    caches: Vec<Arc<ShardedRowCache>>,
    /// Block tags: `block_tags[c][b]` is the generation tag entries of
    /// component `c`, SV block `b` must carry to be valid for THIS
    /// context. Adoption preserves tags of bit-identical blocks and bumps
    /// the rest, so stale entries in a shared cache become inert misses.
    block_tags: Vec<Vec<u32>>,
    /// First unused tag (tags stay `< 2^24` so `tag as f32` is exact).
    next_tag: u32,
    /// Early-model routing cache: `[query (dim) | component id]`, keyed by
    /// the same content fingerprint as the row caches (stored query
    /// verified on hit). `None` for exact models — their routing is
    /// trivial. Untagged: adoption shares it only when the router is
    /// identical, and rebuilds it otherwise.
    route_cache: Option<Arc<ShardedRowCache>>,
}

/// What a hot swap ([`ServingContext::adopt_from`]) preserved: the serve
/// transport reports these in the swap response, and the concurrency test
/// pins `blocks_kept` to the unchanged-SV-block count.
#[derive(Clone, Copy, Debug, Default)]
pub struct SwapStats {
    /// SV blocks of the new context (all components).
    pub blocks_total: usize,
    /// Blocks whose tag — and therefore whose resident cache entries —
    /// survived the swap.
    pub blocks_kept: usize,
    /// Whether the early-model routing cache was carried over.
    pub route_kept: bool,
}

impl ServingContext {
    /// Build the persistent context. `cache_bytes` is the total serving
    /// cache budget, split across components proportional to their
    /// per-query entry bytes (an empty component still gets a floor
    /// share). SV sets larger than [`DEFAULT_SV_BLOCK`] are cached as
    /// per-block segments.
    pub fn new(
        model: ServingModel,
        kernel: Box<dyn BlockKernel>,
        cache_bytes: usize,
    ) -> ServingContext {
        Self::with_block_size(model, kernel, cache_bytes, DEFAULT_SV_BLOCK)
    }

    /// [`Self::new`] with an explicit SV-block size (tests force multi-block
    /// layouts on small models with it). Decisions are bit-identical for
    /// every block size.
    pub fn with_block_size(
        model: ServingModel,
        kernel: Box<dyn BlockKernel>,
        cache_bytes: usize,
        sv_block: usize,
    ) -> ServingContext {
        assert_eq!(
            kernel.kind(),
            model.kind(),
            "kernel backend kind mismatch with model"
        );
        let sv_block = sv_block.max(1);
        let dim = model.dim();
        let comp_svs: Vec<usize> = match &model {
            ServingModel::Exact(m) => vec![m.num_svs()],
            ServingModel::Early(em) => em.locals.iter().map(|m| m.num_svs()).collect(),
            // One decision component per class: a query's row against a
            // class block is computed once and folded by EVERY machine
            // touching that class.
            ServingModel::Ovo(m) => m.class_sv_norms.iter().map(Vec::len).collect(),
        };
        // Per-query entry bytes of a component: one [tag | query | K-block]
        // entry per SV block. Early models also carry a routing cache
        // (`[query | component]`, row length dim+1); it takes its
        // proportional — tiny — share of the same byte budget.
        let blocks = |svs: usize| svs.div_ceil(sv_block).max(1);
        let comp_len = |svs: usize| blocks(svs) * (dim + 1) + svs;
        let route_len = match &model {
            ServingModel::Exact(_) | ServingModel::Ovo(_) => None,
            ServingModel::Early(_) => Some(dim + 1),
        };
        let total_len: usize = (comp_svs.iter().map(|&s| comp_len(s)).sum::<usize>()
            + route_len.unwrap_or(0))
        .max(1);
        let share = |row_len: usize| {
            (cache_bytes as u128 * row_len as u128 / total_len as u128) as usize
        };
        let caches = comp_svs
            .iter()
            .map(|&s| Arc::new(ShardedRowCache::new(share(comp_len(s)), SERVE_SHARDS)))
            .collect();
        let route_cache =
            route_len.map(|len| Arc::new(ShardedRowCache::new(share(len), SERVE_SHARDS)));
        // Fresh contexts number every (component, block) tag sequentially
        // from 1 (0 is reserved so a zeroed entry never verifies).
        let mut next_tag = 1u32;
        let block_tags = comp_svs
            .iter()
            .map(|&s| {
                (0..blocks(s))
                    .map(|_| {
                        let t = next_tag;
                        next_tag += 1;
                        t
                    })
                    .collect()
            })
            .collect();
        ServingContext {
            model,
            kernel,
            dim,
            sv_block,
            caches,
            block_tags,
            next_tag,
            route_cache,
        }
    }

    /// Build a context around `model` that **adopts** `prev`'s caches: the
    /// zero-downtime half of `dcsvm update`. Per-component caches are
    /// shared (`Arc`) with `prev`, and each SV block keeps its tag — so
    /// its resident entries keep verifying — iff its SV slice is
    /// bit-identical in the new model (same block size, same span, same
    /// `f32` bits; coefficients may differ, they are folded at read time).
    /// Changed or new blocks get fresh tags: their stale entries miss on
    /// the tag check and are recomputed in place. Nothing is adopted when
    /// the kernel kind (γ included) or query dimension changed — then this
    /// degrades to a cold [`Self::with_block_size`] context.
    ///
    /// `prev` may keep serving concurrently: its in-flight fills write
    /// entries under its own tags, which this context treats as misses
    /// (and vice versa) — wrong answers are structurally impossible, the
    /// cost of a racing fill is one recompute.
    pub fn adopt_from(
        model: ServingModel,
        kernel: Box<dyn BlockKernel>,
        cache_bytes: usize,
        prev: &ServingContext,
    ) -> (ServingContext, SwapStats) {
        let mut fresh = Self::with_block_size(model, kernel, cache_bytes, prev.sv_block);
        let mut stats = SwapStats {
            blocks_total: fresh.block_tags.iter().map(Vec::len).sum(),
            ..SwapStats::default()
        };
        if fresh.dim != prev.dim || fresh.model.kind() != prev.model.kind() {
            return (fresh, stats);
        }
        // Tags issued by this context must never collide with live ones
        // from the chain of contexts sharing these caches.
        let mut next_tag = prev.next_tag.max(fresh.next_tag);
        let dim = fresh.dim;
        let n_comps = fresh.caches.len().min(prev.caches.len());
        for c in 0..n_comps {
            // Share the predecessor's cache (its resident entries are the
            // point); the block tags below decide which entries still
            // verify. The fresh cache built above is dropped — budgets
            // follow the adopted cache.
            fresh.caches[c] = Arc::clone(&prev.caches[c]);
            let (new_sv, new_norms) = component_svs_of(&fresh.model, c);
            let (old_sv, old_norms) = component_svs_of(&prev.model, c);
            let b_count = fresh.block_tags[c].len();
            for b in 0..b_count {
                let b_lo = (b * fresh.sv_block).min(new_norms.len());
                let b_hi = ((b + 1) * fresh.sv_block).min(new_norms.len());
                let o_hi = ((b + 1) * fresh.sv_block).min(old_norms.len());
                let kept = b < prev.block_tags[c].len()
                    && b_hi == o_hi
                    && bits_eq(&new_sv[b_lo * dim..b_hi * dim], &old_sv[b_lo * dim..b_hi * dim]);
                if kept {
                    fresh.block_tags[c][b] = prev.block_tags[c][b];
                    stats.blocks_kept += 1;
                } else {
                    fresh.block_tags[c][b] = next_tag;
                    next_tag += 1;
                }
            }
        }
        fresh.next_tag = next_tag;
        // Routing entries encode only the router's geometry (sample set +
        // centroids), so they survive iff the router is identical.
        if let (ServingModel::Early(new_em), ServingModel::Early(old_em), Some(rc)) =
            (&fresh.model, &prev.model, &prev.route_cache)
        {
            if new_em.router.to_json().to_string() == old_em.router.to_json().to_string() {
                fresh.route_cache = Some(Arc::clone(rc));
                stats.route_kept = true;
            }
        }
        (fresh, stats)
    }

    /// Number of SV blocks of a component with `n_svs` support vectors
    /// (always at least one, so empty components still cache query-only
    /// entries).
    fn component_blocks(&self, n_svs: usize) -> usize {
        n_svs.div_ceil(self.sv_block).max(1)
    }

    /// The model being served.
    pub fn model(&self) -> &ServingModel {
        &self.model
    }

    /// Feature dimension queries must have.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Total support vectors of the served model.
    pub fn num_svs(&self) -> usize {
        self.model.num_svs()
    }

    /// Lifetime hit/miss counters aggregated over all component caches.
    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for c in &self.caches {
            let cs = c.stats();
            s.hits += cs.hits;
            s.misses += cs.misses;
        }
        s
    }

    /// Decision values for a row-major query batch (`x.len() == n · dim`).
    /// Queries are routed (early models), micro-batched across `workers`
    /// threads, and answered through the persistent serving cache; outputs
    /// are in input order for any worker count. For an OVO model the
    /// decision value is the vote *margin*; [`Self::decide_full`] also
    /// returns the voted labels.
    pub fn decide(&self, x: &[f32], workers: usize) -> (Vec<f32>, BatchStats) {
        let (dv, _, stats) = self.decide_full(x, workers);
        (dv, stats)
    }

    /// [`Self::decide`] plus the per-query class labels: `Some` for an OVO
    /// model (the winning class of each query's pairwise vote), `None` for
    /// binary models, whose label is the sign of the decision value.
    pub fn decide_full(
        &self,
        x: &[f32],
        workers: usize,
    ) -> (Vec<f32>, Option<Vec<u16>>, BatchStats) {
        let t0 = std::time::Instant::now();
        assert_eq!(x.len() % self.dim.max(1), 0, "query batch/dim mismatch");
        let n = x.len() / self.dim.max(1);
        let is_ovo = matches!(self.model, ServingModel::Ovo(_));
        if n == 0 {
            return (
                Vec::new(),
                is_ovo.then(Vec::new),
                BatchStats { latency_s: t0.elapsed().as_secs_f64(), ..Default::default() },
            );
        }
        // Route every query to its decision component. Early-model routing
        // goes through the per-fingerprint routing cache: only queries
        // never seen before enter the (single) K(misses, sample) dispatch
        // — which fans out over row panels across the worker budget — so a
        // fully warm batch dispatches no routing kernel at all.
        let budget = workers.max(1);
        let workers = budget.min(n);
        let (assign, route) = self.route(x, n, budget);

        // Micro-batch across workers; scope_map returns in input order.
        let chunk = n.div_ceil(workers);
        let jobs: Vec<(usize, usize)> =
            (0..n).step_by(chunk).map(|lo| (lo, (lo + chunk).min(n))).collect();
        // Fill dispatches go through the row-panel-parallel path with
        // whatever worker budget the micro-batch split leaves idle (only
        // batches smaller than the budget leave any; the micro-batch
        // workers are the primary parallelism). Routing above gets the
        // full budget — its single dispatch runs before the split.
        let fill_threads = (budget / jobs.len().max(1)).max(1);
        let assign_ref = &assign;
        let parts: Vec<(Vec<f32>, Option<Vec<u16>>, RangeStats)> =
            scope_map(workers, jobs, |_, (lo, hi)| match &self.model {
                ServingModel::Ovo(m) => {
                    let (dv, labels, rs) = self.decide_range_ovo(m, x, lo, hi, fill_threads);
                    (dv, Some(labels), rs)
                }
                _ => {
                    let (dv, rs) = self.decide_range(x, assign_ref, lo, hi, fill_threads);
                    (dv, None, rs)
                }
            });

        // Counters are threaded per worker (not derived from global cache
        // deltas), so concurrent decide() calls on the shared context each
        // report only their own batch's hits/misses.
        let mut dv = Vec::with_capacity(n);
        let mut labels = is_ovo.then(|| Vec::with_capacity(n));
        let mut agg = RangeStats::default();
        for (part, part_labels, rs) in parts {
            dv.extend_from_slice(&part);
            if let (Some(all), Some(part)) = (labels.as_mut(), part_labels) {
                all.extend_from_slice(&part);
            }
            agg.computed += rs.computed;
            agg.hits += rs.hits;
            agg.misses += rs.misses;
        }
        let machines = match &self.model {
            ServingModel::Ovo(m) => m.machines.len() as u64,
            _ => 0,
        };
        (
            dv,
            labels,
            BatchStats {
                rows: n,
                latency_s: t0.elapsed().as_secs_f64(),
                cache_hits: agg.hits,
                cache_misses: agg.misses,
                rows_computed: agg.computed,
                routing_hits: route.hits,
                routing_misses: route.misses,
                routing_dispatches: route.dispatches,
                pair_dispatches: machines,
                votes: machines * n as u64,
            },
        )
    }

    /// Component assignment for each of the `n` queries in `x`, with
    /// routing-cache counters. Exact models route trivially (component 0,
    /// no counters). Early models probe the routing cache per query
    /// fingerprint (hit verified against the stored query, like the row
    /// caches) and batch all misses into one `K(misses, sample)` dispatch
    /// whose results are cached for every later batch on the shared
    /// context — including other clients' batches under the socket
    /// transport.
    fn route(&self, x: &[f32], n: usize, threads: usize) -> (Vec<u16>, RouteStats) {
        let em = match &self.model {
            // Exact models have one component; OVO queries visit EVERY
            // class component, so neither routes.
            ServingModel::Exact(_) | ServingModel::Ovo(_) => {
                return (vec![0u16; n], RouteStats::default())
            }
            ServingModel::Early(em) => em,
        };
        let dim = self.dim;
        let cache =
            self.route_cache.as_ref().expect("early model carries a routing cache");
        let mut assign = vec![0u16; n];
        let mut rs = RouteStats::default();
        let mut missing: Vec<usize> = Vec::new();
        for i in 0..n {
            let q = &x[i * dim..(i + 1) * dim];
            if let Some(entry) = cache.get(fingerprint(q)) {
                if &entry[..dim] == q {
                    assign[i] = entry[dim] as u16;
                    rs.hits += 1;
                    continue;
                }
                // Fingerprint collision: recompute below, uncached.
            }
            rs.misses += 1;
            missing.push(i);
        }
        if !missing.is_empty() {
            // Routing is per-row independent (nearest sample centroid), so
            // dispatching only the misses assigns each query exactly as
            // routing the full batch would. Identical unseen queries are
            // deduped within the batch (the same discipline as the row
            // path): one routing row per unique query.
            rs.dispatches = 1;
            let query = |i: usize| &x[i * dim..(i + 1) * dim];
            let mut first: HashMap<u64, usize> = HashMap::new(); // fp -> uniq slot
            let mut uniq: Vec<usize> = Vec::new(); // representative indices
            let mut rep: Vec<usize> = Vec::with_capacity(missing.len());
            for &i in &missing {
                let key = fingerprint(query(i));
                match first.get(&key).copied() {
                    Some(u) if query(uniq[u]) == query(i) => rep.push(u),
                    _ => {
                        first.insert(key, uniq.len());
                        uniq.push(i);
                        rep.push(uniq.len() - 1);
                    }
                }
            }
            let mut xq = Vec::with_capacity(uniq.len() * dim);
            let mut qn = Vec::with_capacity(uniq.len());
            for &i in &uniq {
                let q = query(i);
                xq.extend_from_slice(q);
                qn.push(q.iter().map(|&v| v * v).sum());
            }
            let got = em.router.assign_rows_par(&xq, &qn, self.kernel.as_ref(), threads);
            for (s, &i) in uniq.iter().enumerate() {
                let q = query(i);
                let mut entry = Vec::with_capacity(dim + 1);
                entry.extend_from_slice(q);
                entry.push(got[s] as f32);
                cache.put(fingerprint(q), entry.into());
            }
            for (&i, &u) in missing.iter().zip(&rep) {
                assign[i] = got[u];
            }
        }
        (assign, rs)
    }

    /// ±1 predictions (sign of [`Self::decide`], decision 0 ↦ +1).
    pub fn predict(&self, x: &[f32], workers: usize) -> (Vec<i8>, BatchStats) {
        let (dv, stats) = self.decide(x, workers);
        (dv.into_iter().map(|d| if d >= 0.0 { 1 } else { -1 }).collect(), stats)
    }

    /// SV rows / norms / coefficients of decision component `c`.
    fn component(&self, c: usize) -> (&[f32], &[f32], &[f32]) {
        component_of(&self.model, c)
    }

    /// The tag entries of component `c`, SV block `b` must open with to
    /// verify under this context (exposed for swap tests).
    pub fn block_tag(&self, c: usize, b: usize) -> u32 {
        self.block_tags[c][b]
    }

    /// Decide queries `lo..hi` (one worker's micro-batch): per SV block of
    /// each component, probe the cache per query, batch-compute all misses
    /// in ONE backend dispatch against the block's contiguous SV slice
    /// (fanned out over row panels when `fill_threads > 1` — the
    /// single-micro-batch case), store the new entries, and fold the block
    /// into the running decisions. Blocks are folded in ascending SV order
    /// with a single accumulator per query — the exact operation sequence
    /// of a one-pass reduction, so decisions are bit-identical for every
    /// block size, worker count, and fill-thread count.
    fn decide_range(
        &self,
        x: &[f32],
        assign: &[u16],
        lo: usize,
        hi: usize,
        fill_threads: usize,
    ) -> (Vec<f32>, RangeStats) {
        let dim = self.dim;
        let mut dv = vec![0f32; hi - lo];
        let mut rs = RangeStats::default();
        for c in 0..self.caches.len() {
            let idx: Vec<usize> = (lo..hi).filter(|&i| assign[i] as usize == c).collect();
            if idx.is_empty() {
                continue;
            }
            let (sv_x, sv_norms, coef) = self.component(c);
            let n_svs = coef.len();
            let cache = &self.caches[c];
            let query = |t: usize| &x[idx[t] * dim..(idx[t] + 1) * dim];
            // Fingerprints are block-independent (block_key mixes the
            // block index in separately); hash each query once, not once
            // per block per pass.
            let fps: Vec<u64> = (0..idx.len()).map(|t| fingerprint(query(t))).collect();
            let mut acc = vec![0f32; idx.len()];

            for b in 0..self.component_blocks(n_svs) {
                let b_lo = (b * self.sv_block).min(n_svs);
                let b_hi = ((b + 1) * self.sv_block).min(n_svs);
                let blen = b_hi - b_lo;
                let tag_f = self.block_tags[c][b] as f32;

                // Probe pass: resident entries (verified against this
                // context's block tag and the stored query prefix) are
                // reused; the rest are batched misses. A stale-tag entry
                // — left by a predecessor context across a hot swap — is
                // a miss, recomputed and overwritten below.
                let mut rows: Vec<Option<Arc<[f32]>>> = vec![None; idx.len()];
                let mut missing: Vec<usize> = Vec::new(); // positions into idx
                for (t, &i) in idx.iter().enumerate() {
                    let q = &x[i * dim..(i + 1) * dim];
                    if let Some(entry) = cache.get(block_key(fps[t], b)) {
                        if entry[0] == tag_f && &entry[1..1 + dim] == q {
                            rs.hits += 1;
                            rows[t] = Some(entry);
                            continue;
                        }
                        // Stale tag or fingerprint collision: recompute.
                    }
                    rs.misses += 1;
                    missing.push(t);
                }

                // Fill pass: dedupe identical queries within the
                // micro-batch (the probe pass ran before any fill, so
                // batch-internal repeats all missed), then one kernel
                // dispatch for the unique missing queries against this
                // block's SV slice.
                if !missing.is_empty() {
                    let mut first: HashMap<u64, usize> = HashMap::new(); // fp -> uniq slot
                    let mut uniq: Vec<usize> = Vec::new(); // representative positions
                    let mut rep: Vec<usize> = Vec::with_capacity(missing.len());
                    for &t in &missing {
                        let fp = fps[t];
                        match first.get(&fp).copied() {
                            Some(u) if query(uniq[u]) == query(t) => rep.push(u),
                            _ => {
                                first.insert(fp, uniq.len());
                                uniq.push(t);
                                rep.push(uniq.len() - 1);
                            }
                        }
                    }
                    rs.computed += uniq.len() as u64;
                    let mut xq = Vec::with_capacity(uniq.len() * dim);
                    let mut qn = Vec::with_capacity(uniq.len());
                    for &t in &uniq {
                        let q = query(t);
                        xq.extend_from_slice(q);
                        qn.push(q.iter().map(|&v| v * v).sum());
                    }
                    let mut kblock = vec![0f32; uniq.len() * blen];
                    if blen > 0 {
                        self.kernel.block_par(
                            &xq,
                            &qn,
                            &sv_x[b_lo * dim..b_hi * dim],
                            &sv_norms[b_lo..b_hi],
                            dim,
                            fill_threads,
                            &mut kblock,
                        );
                    }
                    let mut entries: Vec<Arc<[f32]>> = Vec::with_capacity(uniq.len());
                    for (s, &t) in uniq.iter().enumerate() {
                        let q = query(t);
                        let mut entry = Vec::with_capacity(1 + dim + blen);
                        entry.push(tag_f);
                        entry.extend_from_slice(q);
                        entry.extend_from_slice(&kblock[s * blen..(s + 1) * blen]);
                        let entry: Arc<[f32]> = entry.into();
                        // put_replace, not put: a stale-tag entry from a
                        // pre-swap context may be resident under this key
                        // and must be overwritten, not kept.
                        cache.put_replace(block_key(fps[t], b), Arc::clone(&entry));
                        entries.push(entry);
                    }
                    for (&t, &u) in missing.iter().zip(&rep) {
                        rows[t] = Some(Arc::clone(&entries[u]));
                    }
                }

                // Fold this block into the accumulators (fixed order, so
                // cached and fresh entries yield bit-identical decisions).
                let bcoef = &coef[b_lo..b_hi];
                for (t, slot) in rows.iter().enumerate() {
                    let entry = slot.as_ref().expect("serving block filled");
                    let krow = &entry[1 + dim..];
                    let mut a = acc[t];
                    for (&k, &w) in krow.iter().zip(bcoef) {
                        a += k * w;
                    }
                    acc[t] = a;
                }
            }

            for (t, &i) in idx.iter().enumerate() {
                dv[i - lo] = acc[t];
            }
        }
        (dv, rs)
    }

    /// OVO twin of [`Self::decide_range`]: assemble each query's kernel
    /// row against EVERY class block from the per-(class, block) cache —
    /// probe / dedupe / one `block_par` fill per block, identical entry
    /// layout and discipline — then fold all machines' decisions and the
    /// vote from the assembled rows ([`OvoModel::machine_decisions`], the
    /// same fold offline prediction uses, so labels and margins are
    /// bit-identical to [`OvoModel::predict_with_margins`]). The rows are
    /// per-class, not per-machine: a row computed for one pairwise vote is
    /// reused by every other machine touching that class, this batch and
    /// every warm batch after it.
    fn decide_range_ovo(
        &self,
        m: &OvoModel,
        x: &[f32],
        lo: usize,
        hi: usize,
        fill_threads: usize,
    ) -> (Vec<f32>, Vec<u16>, RangeStats) {
        let dim = self.dim;
        let nq = hi - lo;
        let mut rs = RangeStats::default();
        let query = |t: usize| &x[(lo + t) * dim..(lo + t + 1) * dim];
        let fps: Vec<u64> = (0..nq).map(|t| fingerprint(query(t))).collect();
        // Contiguous per-class rows (row t of class c at [t·svs, (t+1)·svs)),
        // scattered from cache entries block by block.
        let mut class_rows: Vec<Vec<f32>> = (0..m.num_classes)
            .map(|c| vec![0f32; nq * m.class_sv_norms[c].len()])
            .collect();
        for c in 0..m.num_classes {
            let sv_x = &m.class_sv_x[c];
            let sv_norms = &m.class_sv_norms[c];
            let n_svs = sv_norms.len();
            let rows_c = &mut class_rows[c];
            let cache = &self.caches[c];
            for b in 0..self.component_blocks(n_svs) {
                let b_lo = (b * self.sv_block).min(n_svs);
                let b_hi = ((b + 1) * self.sv_block).min(n_svs);
                let blen = b_hi - b_lo;
                let tag_f = self.block_tags[c][b] as f32;

                let mut missing: Vec<usize> = Vec::new();
                for t in 0..nq {
                    let q = query(t);
                    if let Some(entry) = cache.get(block_key(fps[t], b)) {
                        if entry[0] == tag_f && &entry[1..1 + dim] == q {
                            rs.hits += 1;
                            rows_c[t * n_svs + b_lo..t * n_svs + b_hi]
                                .copy_from_slice(&entry[1 + dim..]);
                            continue;
                        }
                        // Stale tag or fingerprint collision: recompute.
                    }
                    rs.misses += 1;
                    missing.push(t);
                }

                if !missing.is_empty() {
                    let mut first: HashMap<u64, usize> = HashMap::new(); // fp -> uniq slot
                    let mut uniq: Vec<usize> = Vec::new();
                    let mut rep: Vec<usize> = Vec::with_capacity(missing.len());
                    for &t in &missing {
                        let fp = fps[t];
                        match first.get(&fp).copied() {
                            Some(u) if query(uniq[u]) == query(t) => rep.push(u),
                            _ => {
                                first.insert(fp, uniq.len());
                                uniq.push(t);
                                rep.push(uniq.len() - 1);
                            }
                        }
                    }
                    rs.computed += uniq.len() as u64;
                    let mut xq = Vec::with_capacity(uniq.len() * dim);
                    let mut qn = Vec::with_capacity(uniq.len());
                    for &t in &uniq {
                        let q = query(t);
                        xq.extend_from_slice(q);
                        qn.push(q.iter().map(|&v| v * v).sum());
                    }
                    let mut kblock = vec![0f32; uniq.len() * blen];
                    if blen > 0 {
                        self.kernel.block_par(
                            &xq,
                            &qn,
                            &sv_x[b_lo * dim..b_hi * dim],
                            &sv_norms[b_lo..b_hi],
                            dim,
                            fill_threads,
                            &mut kblock,
                        );
                    }
                    for (s, &t) in uniq.iter().enumerate() {
                        let q = query(t);
                        let mut entry = Vec::with_capacity(1 + dim + blen);
                        entry.push(tag_f);
                        entry.extend_from_slice(q);
                        entry.extend_from_slice(&kblock[s * blen..(s + 1) * blen]);
                        cache.put_replace(block_key(fps[t], b), entry.into());
                    }
                    for (&t, &u) in missing.iter().zip(&rep) {
                        rows_c[t * n_svs + b_lo..t * n_svs + b_hi]
                            .copy_from_slice(&kblock[u * blen..(u + 1) * blen]);
                    }
                }
            }
        }

        let mut dv = vec![0f32; nq];
        let mut labels = vec![0u16; nq];
        for t in 0..nq {
            let rows: Vec<&[f32]> = (0..m.num_classes)
                .map(|c| {
                    let svs = m.class_sv_norms[c].len();
                    &class_rows[c][t * svs..(t + 1) * svs]
                })
                .collect();
            let decisions = m.machine_decisions(&rows);
            let (label, margin) = m.vote(&decisions);
            labels[t] = label;
            dv[t] = margin;
        }
        (dv, labels, rs)
    }
}

/// Per-micro-batch counters, threaded through `decide_range` so a batch's
/// [`BatchStats`] never includes another concurrent batch's probes.
#[derive(Clone, Copy, Debug, Default)]
struct RangeStats {
    computed: u64,
    hits: u64,
    misses: u64,
}

/// Routing-cache counters of one [`ServingContext::decide`] call.
#[derive(Clone, Copy, Debug, Default)]
struct RouteStats {
    hits: u64,
    misses: u64,
    dispatches: u64,
}

/// SV rows / norms / coefficients of decision component `c` of a model
/// (free function so [`ServingContext::adopt_from`] can read two models'
/// components while mutating its own tag table).
fn component_of(model: &ServingModel, c: usize) -> (&[f32], &[f32], &[f32]) {
    let m = match model {
        ServingModel::Exact(m) => m,
        ServingModel::Early(em) => &em.locals[c],
        // OVO machines weight a class block pairwise; there is no single
        // per-component coefficient vector. OVO decisions go through
        // `decide_range_ovo`, never here.
        ServingModel::Ovo(_) => unreachable!("OVO components carry no single coef vector"),
    };
    (&m.sv_x, &m.sv_norms, &m.coef)
}

/// SV rows / norms of decision component `c` — the coefficient-free subset
/// of [`component_of`] that is total over every model family (adoption
/// compares SV bits and never needs coefficients).
fn component_svs_of(model: &ServingModel, c: usize) -> (&[f32], &[f32]) {
    match model {
        ServingModel::Exact(m) => (&m.sv_x, &m.sv_norms),
        ServingModel::Early(em) => (&em.locals[c].sv_x, &em.locals[c].sv_norms),
        ServingModel::Ovo(m) => (&m.class_sv_x[c], &m.class_sv_norms[c]),
    }
}

/// Bit-level equality of two f32 slices (the adoption criterion: cached
/// kernel values are a function of the SV bits, so bit-equal blocks have
/// bit-equal entries; `==` on f32 would wrongly unify -0.0/0.0 and
/// disqualify NaN payloads).
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// FNV-1a over the query's f32 bit patterns: the stable content key of the
/// serving cache. Entries store the query itself as a prefix and hits are
/// verified against it, so a collision degrades to an uncached recompute,
/// never a wrong row.
fn fingerprint(q: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &v in q {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::KernelContext;
    use crate::data::synthetic::{covtype_like, generate_split};
    use crate::dcsvm::DcSvmConfig;
    use crate::kernel::native::NativeKernel;
    use crate::solver::{SmoConfig, SmoSolver};

    fn exact_model(n: usize, seed: u64) -> (SvmModel, crate::data::Dataset) {
        let (tr, te) = generate_split(&covtype_like(), n, n / 3, seed);
        let kind = KernelKind::Rbf { gamma: 16.0 };
        let kern = NativeKernel::new(kind);
        let ctx = KernelContext::new(&tr, &kern, 32 << 20);
        let res = SmoSolver::new(
            ctx.view_full(),
            SmoConfig { c: 4.0, eps: 1e-3, ..Default::default() },
        )
        .solve();
        (SvmModel::from_ctx_alpha(&ctx, &res.alpha), te)
    }

    fn serve_ctx(model: ServingModel) -> ServingContext {
        let kern = NativeKernel::new(model.kind());
        ServingContext::new(model, Box::new(kern), 8 << 20)
    }

    #[test]
    fn warm_batch_hits_and_matches_cold_batch_exactly() {
        let (model, te) = exact_model(300, 5);
        let ctx = serve_ctx(ServingModel::Exact(model));
        let (dv1, s1) = ctx.decide(&te.x, 1);
        assert_eq!(s1.rows, te.len());
        assert_eq!(s1.cache_hits, 0, "cold batch must not hit");
        assert_eq!(s1.rows_computed, te.len() as u64);
        let (dv2, s2) = ctx.decide(&te.x, 1);
        assert_eq!(dv1, dv2, "warm decisions must be bit-identical");
        assert_eq!(s2.rows_computed, 0, "warm batch must compute nothing");
        assert!(s2.cache_hits > s1.cache_hits);
        assert_eq!(s2.cache_hits, te.len() as u64);
        assert!((s2.hit_rate() - 1.0).abs() < 1e-12);
        // Exact models never dispatch routing.
        assert_eq!(s1.routing_dispatches, 0);
        assert_eq!(s1.routing_hits + s1.routing_misses, 0);
    }

    #[test]
    fn serving_decisions_match_model_signs() {
        let (model, te) = exact_model(300, 6);
        let kern = NativeKernel::new(model.kind);
        let norms = te.sq_norms();
        let want = model.predict_batch(&te.x, &norms, &kern);
        let ctx = serve_ctx(ServingModel::Exact(model));
        let (preds, _) = ctx.predict(&te.x, 2);
        assert_eq!(preds, want);
    }

    #[test]
    fn worker_count_does_not_change_decisions() {
        let (model, te) = exact_model(200, 7);
        let a = serve_ctx(ServingModel::Exact(model));
        let (dv1, _) = a.decide(&te.x, 1);
        let (dv4, _) = a.decide(&te.x, 4); // second pass: all cached
        assert_eq!(dv1, dv4);
        // And from a cold cache with 3 workers.
        let (model2, _) = exact_model(200, 7);
        let b = serve_ctx(ServingModel::Exact(model2));
        let (dv3, _) = b.decide(&te.x, 3);
        assert_eq!(dv1, dv3);
    }

    #[test]
    fn duplicate_queries_hit_within_one_batch() {
        let (model, te) = exact_model(250, 8);
        let ctx = serve_ctx(ServingModel::Exact(model));
        // Batch = the same query row repeated 5 times.
        let q = &te.x[..ctx.dim()];
        let mut x = Vec::new();
        for _ in 0..5 {
            x.extend_from_slice(q);
        }
        let (dv, stats) = ctx.decide(&x, 1);
        assert!(dv.windows(2).all(|w| w[0] == w[1]));
        // Probes all miss (the probe pass runs before any fill), but the
        // kernel computes the repeated query exactly once.
        assert_eq!(stats.rows_computed, 1);
        assert_eq!(stats.cache_misses, 5);
        let (_, s2) = ctx.decide(&x, 1);
        assert_eq!(s2.cache_hits, 5);
        assert_eq!(s2.rows_computed, 0);
    }

    /// SV-block segmentation (cache v2): decisions are bit-identical for
    /// every block size, counters scale with the block count, and a warm
    /// multi-block batch computes nothing.
    #[test]
    fn sv_blocks_bit_identical_across_block_sizes() {
        let (model, te) = exact_model(300, 14);
        let n_svs = model.num_svs();
        assert!(n_svs > 4, "model too small to exercise multiple blocks");
        let kern_a = NativeKernel::new(model.kind);
        let kern_b = NativeKernel::new(model.kind);
        let single = ServingContext::new(
            ServingModel::Exact(model.clone()),
            Box::new(kern_a),
            8 << 20,
        );
        let blocked = ServingContext::with_block_size(
            ServingModel::Exact(model),
            Box::new(kern_b),
            8 << 20,
            3,
        );
        let (dv1, s1) = single.decide(&te.x, 2);
        let (dv2, s2) = blocked.decide(&te.x, 2);
        assert_eq!(dv1, dv2, "block size changed decision values");
        let blocks = n_svs.div_ceil(3);
        assert!(blocks > 1);
        assert_eq!(s1.cache_misses, te.len() as u64);
        assert_eq!(s2.cache_misses, (te.len() * blocks) as u64);
        assert_eq!(s2.rows_computed, (te.len() * blocks) as u64);
        // Warm pass over the blocked context: every block hits.
        let (dv3, s3) = blocked.decide(&te.x, 2);
        assert_eq!(dv1, dv3);
        assert_eq!(s3.rows_computed, 0);
        assert_eq!(s3.cache_hits, (te.len() * blocks) as u64);
        assert!((s3.hit_rate() - 1.0).abs() < 1e-12);
    }

    /// Tentpole: SV-block fill and routing dispatches route through the
    /// row-panel-parallel path (`block_par`) — decisions stay bit-identical
    /// between a forced-parallel-threshold kernel under many workers and
    /// plain single-worker serial evaluation.
    #[test]
    fn parallel_sv_block_fills_bit_identical() {
        let (model, te) = exact_model(250, 15);
        let serial = serve_ctx(ServingModel::Exact(model.clone()));
        // Threshold 1 forces every dispatch that CAN fan out down the
        // parallel path (fills in the under-budget small-batch case, and
        // any multi-row dispatch).
        let forced = NativeKernel::with_par_threshold(model.kind, 1);
        let par = ServingContext::new(ServingModel::Exact(model), Box::new(forced), 8 << 20);
        // A batch smaller than the worker budget: leftover budget flows
        // to the fill dispatches (fill_threads > 1).
        let small = &te.x[..16 * par.dim()];
        let (dv_serial, _) = serial.decide(small, 1);
        let (dv_par, s) = par.decide(small, 32);
        assert_eq!(dv_serial, dv_par, "parallel fills changed decision bits");
        assert_eq!(s.rows, 16);
        // A full batch across many workers agrees too, and a warm replay
        // still computes nothing.
        let (dv_all_serial, _) = serial.decide(&te.x, 1);
        let (dv_all_par, _) = par.decide(&te.x, 4);
        assert_eq!(dv_all_serial, dv_all_par);
        let (dv_warm, s2) = par.decide(&te.x, 1);
        assert_eq!(dv_all_par, dv_warm);
        assert_eq!(s2.rows_computed, 0);
    }

    #[test]
    fn early_model_serves_and_reuses_across_batches() {
        let (tr, te) = generate_split(&covtype_like(), 600, 150, 9);
        let kind = KernelKind::Rbf { gamma: 16.0 };
        let kern = NativeKernel::new(kind);
        let cfg = DcSvmConfig {
            kind,
            c: 4.0,
            levels: 2,
            k_base: 4,
            sample_m: 64,
            stop_after_level: Some(1),
            ..Default::default()
        };
        let res = crate::dcsvm::train(&tr, &kern, &cfg);
        let em = res.early_model.expect("early model");
        let norms = te.sq_norms();
        let want = em.predict_batch(&te.x, &norms, &kern);

        // Roundtrip through JSON, as the CLI does.
        let text = em.to_json().to_string();
        let model = ServingModel::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(matches!(model, ServingModel::Early(_)));
        let ctx = serve_ctx(model);
        let (preds, s1) = ctx.predict(&te.x, 2);
        assert_eq!(preds, want, "serving path disagrees with EarlyModel");
        assert_eq!(s1.routing_dispatches, 1, "cold batch routes in one dispatch");
        assert_eq!(s1.routing_hits, 0);
        assert_eq!(s1.routing_misses, te.len() as u64);
        let (preds2, s2) = ctx.predict(&te.x, 2);
        assert_eq!(preds, preds2);
        assert_eq!(s2.rows_computed, 0);
        assert!(s2.cache_hits > s1.cache_hits);
        // Warm batch: routing answered entirely from the routing cache —
        // zero kernel dispatches of any kind.
        assert_eq!(s2.routing_dispatches, 0, "warm batch must skip routing dispatch");
        assert_eq!(s2.routing_hits, te.len() as u64);
        assert_eq!(s2.routing_misses, 0);
    }

    #[test]
    fn exact_json_loads_as_exact() {
        let (model, _) = exact_model(120, 10);
        let text = model.to_json().to_string();
        let back = ServingModel::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(matches!(back, ServingModel::Exact(_)));
        assert_eq!(back.num_svs(), model.num_svs());
        assert_eq!(back.dim(), model.dim);
        assert_eq!(back.kind(), model.kind);
    }

    #[test]
    fn empty_model_serves_zero_decisions() {
        let (tr, _) = generate_split(&covtype_like(), 40, 10, 11);
        let kind = KernelKind::Rbf { gamma: 1.0 };
        let model = SvmModel::from_alpha(&tr, &vec![0.0; tr.len()], kind);
        let ctx = serve_ctx(ServingModel::Exact(model));
        let (dv, stats) = ctx.decide(&tr.x, 2);
        assert!(dv.iter().all(|&d| d == 0.0));
        assert_eq!(stats.rows, tr.len());
        // Second pass still hits (entries are query-only rows).
        let (_, s2) = ctx.decide(&tr.x, 2);
        assert_eq!(s2.rows_computed, 0);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let (model, _) = exact_model(80, 12);
        let ctx = serve_ctx(ServingModel::Exact(model));
        let (dv, stats) = ctx.decide(&[], 4);
        assert!(dv.is_empty());
        assert_eq!(stats.rows, 0);
        assert_eq!(stats.cache_hits + stats.cache_misses, 0);
    }

    #[test]
    fn batch_stats_json_shape() {
        let s = BatchStats {
            rows: 10,
            latency_s: 0.5,
            cache_hits: 6,
            cache_misses: 4,
            rows_computed: 4,
            routing_hits: 7,
            routing_misses: 3,
            routing_dispatches: 1,
            pair_dispatches: 6,
            votes: 60,
        };
        let j = s.to_json(3);
        assert_eq!(j.get("batch").as_usize(), Some(3));
        assert_eq!(j.get("rows").as_usize(), Some(10));
        assert_eq!(j.get("cache_hits").as_f64(), Some(6.0));
        assert!((j.get("hit_rate").as_f64().unwrap() - 0.6).abs() < 1e-12);
        assert!((j.get("pred_per_s").as_f64().unwrap() - 20.0).abs() < 1e-9);
        assert_eq!(j.get("routing_hits").as_f64(), Some(7.0));
        assert_eq!(j.get("routing_misses").as_f64(), Some(3.0));
        assert_eq!(j.get("routing_dispatches").as_f64(), Some(1.0));
        assert_eq!(j.get("pair_dispatches").as_f64(), Some(6.0));
        assert_eq!(j.get("votes").as_f64(), Some(60.0));
        // Emits as a single parseable line.
        let line = j.to_string();
        assert!(!line.contains('\n'));
        assert!(Json::parse(&line).is_ok());
    }

    #[test]
    fn batch_stats_merge_sums_counters() {
        let mut a = BatchStats {
            rows: 2,
            latency_s: 0.25,
            cache_hits: 1,
            cache_misses: 1,
            rows_computed: 1,
            routing_hits: 2,
            routing_misses: 0,
            routing_dispatches: 0,
            pair_dispatches: 3,
            votes: 6,
        };
        let b = BatchStats {
            rows: 3,
            latency_s: 0.5,
            cache_hits: 0,
            cache_misses: 3,
            rows_computed: 3,
            routing_hits: 0,
            routing_misses: 3,
            routing_dispatches: 1,
            pair_dispatches: 3,
            votes: 9,
        };
        a.merge(&b);
        assert_eq!(a.rows, 5);
        assert!((a.latency_s - 0.75).abs() < 1e-12);
        assert_eq!(a.cache_hits, 1);
        assert_eq!(a.cache_misses, 4);
        assert_eq!(a.rows_computed, 4);
        assert_eq!(a.routing_hits, 2);
        assert_eq!(a.routing_misses, 3);
        assert_eq!(a.routing_dispatches, 1);
        assert_eq!(a.pair_dispatches, 6);
        assert_eq!(a.votes, 15);
    }

    /// Hand-built exact model over `svs` explicit SV rows (dim 2): swap
    /// tests need exact control over which SV blocks change.
    fn toy_model(svs: &[([f32; 2], f32)]) -> SvmModel {
        let mut sv_x = Vec::new();
        let mut coef = Vec::new();
        for (row, w) in svs {
            sv_x.extend_from_slice(row);
            coef.push(*w);
        }
        let sv_norms = sv_x.chunks(2).map(|r| r.iter().map(|&v| v * v).sum()).collect();
        SvmModel {
            sv_x,
            sv_norms,
            coef,
            dim: 2,
            kind: KernelKind::Rbf { gamma: 4.0 },
        }
    }

    fn toy_ctx(model: SvmModel, sv_block: usize) -> ServingContext {
        let kern = NativeKernel::new(model.kind);
        ServingContext::with_block_size(
            ServingModel::Exact(model),
            Box::new(kern),
            4 << 20,
            sv_block,
        )
    }

    /// Tentpole: adoption keeps the tags — and so the resident entries —
    /// of SV blocks whose slices are bit-identical, and a post-swap replay
    /// recomputes ONLY the changed/new blocks.
    #[test]
    fn hot_swap_adoption_recomputes_only_changed_blocks() {
        let old_svs: Vec<([f32; 2], f32)> =
            vec![([0.1, 0.2], 0.5), ([0.3, 0.4], -0.25), ([0.5, 0.6], 0.75), ([0.7, 0.8], -0.5), ([0.9, 1.0], 0.25)];
        let old_model = toy_model(&old_svs);
        // Block size 2 over 5 SVs: blocks [0,2) [2,4) [4,5).
        let old_ctx = toy_ctx(old_model, 2);
        let queries: Vec<f32> = vec![0.15, 0.25, 0.55, 0.45, 0.85, 0.95];
        let (dv_old, s1) = old_ctx.decide(&queries, 1);
        assert_eq!(s1.rows_computed, 3 * 3, "3 queries × 3 blocks, cold");

        // Update: same first 5 SVs bit-identical (coef of SV 0 changes —
        // legal, coefs fold at read time), plus 2 appended SVs. New blocks:
        // [0,2) [2,4) [4,6) [6,7) — the old partial tail [4,5) grew, so
        // only the first two blocks survive.
        let mut new_svs = old_svs.clone();
        new_svs[0].1 = 1.5;
        new_svs.push(([1.1, 1.2], 0.4));
        new_svs.push(([1.3, 1.4], -0.3));
        let new_model = toy_model(&new_svs);
        let kern = NativeKernel::new(new_model.kind);
        let (new_ctx, swap) = ServingContext::adopt_from(
            ServingModel::Exact(new_model.clone()),
            Box::new(kern),
            4 << 20,
            &old_ctx,
        );
        assert_eq!(swap.blocks_total, 4);
        assert_eq!(swap.blocks_kept, 2);
        assert_eq!(new_ctx.block_tag(0, 0), old_ctx.block_tag(0, 0));
        assert_eq!(new_ctx.block_tag(0, 1), old_ctx.block_tag(0, 1));
        assert_ne!(new_ctx.block_tag(0, 2), old_ctx.block_tag(0, 2));

        // Replay the same queries on the adopted context: the two kept
        // blocks hit, the changed tail + new block recompute.
        let (dv_new, s2) = new_ctx.decide(&queries, 1);
        assert_eq!(s2.cache_hits, 3 * 2, "kept blocks must keep hitting");
        assert_eq!(s2.rows_computed, 3 * 2, "only changed/new blocks recompute");
        // Decisions equal the new model evaluated from scratch,
        // bit-for-bit (kept entries + fresh fills fold identically).
        let norms: Vec<f32> =
            queries.chunks(2).map(|q| q.iter().map(|&v| v * v).sum()).collect();
        let kern2 = NativeKernel::new(new_model.kind);
        let want = new_model.decision_batch(&queries, &norms, &kern2);
        assert_eq!(dv_new, want);
        assert_ne!(dv_old, dv_new, "updated coef must change decisions");

        // The predecessor context still serves correctly over the shared
        // cache: its tags ignore the successor's fresh entries.
        let (dv_old2, _) = old_ctx.decide(&queries, 1);
        assert_eq!(dv_old, dv_old2, "pre-swap context torn by the swap");

        // Warm replay on the new context computes nothing at all.
        let (dv_new2, s3) = new_ctx.decide(&queries, 1);
        assert_eq!(dv_new, dv_new2);
        assert_eq!(s3.rows_computed, 0);
    }

    /// A coefficient-only update keeps every block: zero recomputation
    /// after the swap, decisions change to the new weights.
    #[test]
    fn coef_only_swap_recomputes_nothing() {
        let svs: Vec<([f32; 2], f32)> =
            vec![([0.1, 0.9], 0.5), ([0.4, 0.3], -0.5), ([0.8, 0.2], 0.25)];
        let old_ctx = toy_ctx(toy_model(&svs), 2);
        let queries: Vec<f32> = vec![0.2, 0.7, 0.6, 0.1];
        let (dv_old, _) = old_ctx.decide(&queries, 1);
        let mut new_svs = svs.clone();
        for s in &mut new_svs {
            s.1 *= -1.0;
        }
        let new_model = toy_model(&new_svs);
        let kern = NativeKernel::new(new_model.kind);
        let (new_ctx, swap) = ServingContext::adopt_from(
            ServingModel::Exact(new_model),
            Box::new(kern),
            4 << 20,
            &old_ctx,
        );
        assert_eq!(swap.blocks_kept, swap.blocks_total);
        let (dv_new, s) = new_ctx.decide(&queries, 1);
        assert_eq!(s.rows_computed, 0, "coef-only swap must not recompute");
        assert_eq!(s.cache_hits, 2 * 2);
        // Flipped coefficients negate every decision exactly.
        let want: Vec<f32> = dv_old.iter().map(|&d| -d).collect();
        assert_eq!(dv_new, want);
    }

    /// Kernel-parameter (γ) or dimension changes adopt nothing: every
    /// cached value is a function of γ and the query layout.
    #[test]
    fn kernel_change_adopts_no_blocks() {
        let svs: Vec<([f32; 2], f32)> = vec![([0.1, 0.9], 0.5), ([0.4, 0.3], -0.5)];
        let old_ctx = toy_ctx(toy_model(&svs), 2);
        let queries = [0.2f32, 0.7];
        let _ = old_ctx.decide(&queries, 1);
        let mut hotter = toy_model(&svs);
        hotter.kind = KernelKind::Rbf { gamma: 32.0 };
        let kern = NativeKernel::new(hotter.kind);
        let (new_ctx, swap) = ServingContext::adopt_from(
            ServingModel::Exact(hotter),
            Box::new(kern),
            4 << 20,
            &old_ctx,
        );
        assert_eq!(swap.blocks_kept, 0);
        let (_, s) = new_ctx.decide(&queries, 1);
        assert_eq!(s.cache_hits, 0, "γ changed: nothing may hit");
        assert_eq!(s.rows_computed, 1);
    }

    #[test]
    fn routing_cache_reuse_is_per_query_not_per_batch() {
        // Serve overlapping batches: queries routed in batch 1 must not be
        // re-dispatched when they reappear in batch 2 alongside new ones.
        let (tr, te) = generate_split(&covtype_like(), 600, 120, 13);
        let kind = KernelKind::Rbf { gamma: 16.0 };
        let kern = NativeKernel::new(kind);
        let cfg = DcSvmConfig {
            kind,
            c: 4.0,
            levels: 2,
            k_base: 4,
            sample_m: 64,
            stop_after_level: Some(1),
            ..Default::default()
        };
        let res = crate::dcsvm::train(&tr, &kern, &cfg);
        let em = res.early_model.expect("early model");
        let ctx = serve_ctx(ServingModel::Early(em));
        let dim = ctx.dim();
        let half = (te.len() / 2) * dim;
        let (first, all) = (&te.x[..half], &te.x[..]);
        let (_, s1) = ctx.decide(first, 2);
        assert_eq!(s1.routing_misses, (half / dim) as u64);
        // Second batch = first half (already routed) + second half (new):
        // one dispatch covering only the new queries.
        let (_, s2) = ctx.decide(all, 2);
        assert_eq!(s2.routing_hits, (half / dim) as u64);
        assert_eq!(s2.routing_misses, (te.len() - half / dim) as u64);
        assert_eq!(s2.routing_dispatches, 1);
        // Third pass over everything: fully warm.
        let (_, s3) = ctx.decide(all, 2);
        assert_eq!(s3.routing_dispatches, 0);
        assert_eq!(s3.routing_hits, te.len() as u64);
        assert_eq!(s3.rows_computed, 0);
    }

    /// Tentpole (multiclass serving): an OVO ensemble loads from its JSON,
    /// serves labels + margins bit-identical to offline prediction, and
    /// its kernel rows are per CLASS, not per machine — one row per
    /// (query, class) feeds every pairwise vote touching that class, and a
    /// warm replay computes nothing.
    #[test]
    fn ovo_serves_votes_like_offline_and_shares_rows_across_machines() {
        use crate::multiclass::{synthetic_multiclass, train_ovo};
        let tr = synthetic_multiclass(4, 400, 5, 21);
        let te = synthetic_multiclass(4, 60, 5, 21);
        let kind = KernelKind::Rbf { gamma: 2.0 };
        let kern = NativeKernel::new(kind);
        let cfg = crate::dcsvm::DcSvmConfig {
            kind,
            c: 4.0,
            levels: 1,
            sample_m: 32,
            ..Default::default()
        };
        let model = train_ovo(&tr, &kern, &cfg);
        let norms: Vec<f32> = (0..te.len())
            .map(|i| te.row(i).iter().map(|&v| v * v).sum())
            .collect();
        let want = model.predict_with_margins(&te.x, &norms, &kern);
        let machines = model.machines.len() as u64;

        // Roundtrip through JSON, as the CLI does: the "machines" key
        // discriminates OVO files.
        let text = model.to_json().to_string();
        let back = ServingModel::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(matches!(back, ServingModel::Ovo(_)));
        assert_eq!(back.num_svs(), model.num_svs());
        let ctx = serve_ctx(back);
        let (dv, labels, s1) = ctx.decide_full(&te.x, 2);
        let labels = labels.expect("ovo serving must return labels");
        for (t, &(l, m)) in want.iter().enumerate() {
            assert_eq!(labels[t], l, "label mismatch at {t}");
            assert_eq!(dv[t], m, "margin mismatch at {t}");
        }
        // 4 classes, each one SV block: a cold query computes 4 class
        // rows, not 6 machines × 2 half-rows — counter-visible reuse.
        assert_eq!(s1.rows_computed, (te.len() * 4) as u64);
        assert_eq!(s1.pair_dispatches, machines);
        assert_eq!(s1.votes, machines * te.len() as u64);
        assert_eq!(s1.routing_dispatches, 0, "ovo never routes");
        // Warm replay: zero kernel work, bit-identical votes.
        let (dv2, labels2, s2) = ctx.decide_full(&te.x, 2);
        assert_eq!(dv, dv2);
        assert_eq!(labels, labels2.unwrap());
        assert_eq!(s2.rows_computed, 0);
        assert_eq!(s2.cache_hits, (te.len() * 4) as u64);
        // decide() is the same evaluation minus the labels.
        let (dv3, s3) = ctx.decide(&te.x, 3);
        assert_eq!(dv, dv3);
        assert_eq!(s3.rows_computed, 0);
    }

    /// OVO decisions are bit-identical for every SV-block size and worker
    /// count (the class rows are assembled from block entries, the fold is
    /// one pass over the assembled row).
    #[test]
    fn ovo_block_size_and_workers_do_not_change_votes() {
        use crate::multiclass::{synthetic_multiclass, train_ovo};
        let tr = synthetic_multiclass(3, 240, 4, 22);
        let te = synthetic_multiclass(3, 40, 4, 22);
        let kind = KernelKind::Rbf { gamma: 2.0 };
        let kern = NativeKernel::new(kind);
        let cfg = crate::dcsvm::DcSvmConfig {
            kind,
            c: 4.0,
            levels: 1,
            sample_m: 32,
            ..Default::default()
        };
        let model = train_ovo(&tr, &kern, &cfg);
        let single = serve_ctx(ServingModel::Ovo(model.clone()));
        let blocked = ServingContext::with_block_size(
            ServingModel::Ovo(model),
            Box::new(NativeKernel::new(kind)),
            8 << 20,
            3,
        );
        let (dv1, l1, _) = single.decide_full(&te.x, 1);
        let (dv2, l2, s2) = blocked.decide_full(&te.x, 4);
        assert_eq!(dv1, dv2, "block size changed vote margins");
        assert_eq!(l1.unwrap(), l2.unwrap(), "block size changed labels");
        assert!(s2.rows_computed > (te.len() * 3) as u64, "blocks must multiply fills");
        let (dv3, _, s3) = blocked.decide_full(&te.x, 1);
        assert_eq!(dv2, dv3);
        assert_eq!(s3.rows_computed, 0);
    }
}
