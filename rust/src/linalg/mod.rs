//! Dense symmetric linear algebra substrate (no external BLAS/LAPACK
//! offline): cyclic Jacobi eigendecomposition and the inverse-square-root
//! map needed by the Nyström feature construction (LLSVM baseline).

/// Cyclic Jacobi eigendecomposition of a symmetric matrix `a` (row-major
/// n×n, destroyed). Returns (eigenvalues, eigenvectors row-major n×n with
/// eigenvector j in column j), i.e. A = V diag(λ) Vᵀ.
pub fn jacobi_eigh(mut a: Vec<f64>, n: usize, tol: f64, max_sweeps: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), n * n);
    let mut v = vec![0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    for _ in 0..max_sweeps {
        // Off-diagonal Frobenius mass.
        let mut off = 0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += a[p * n + q] * a[p * n + q];
            }
        }
        if off.sqrt() <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p, q of A.
                for i in 0..n {
                    let aip = a[i * n + p];
                    let aiq = a[i * n + q];
                    a[i * n + p] = c * aip - s * aiq;
                    a[i * n + q] = s * aip + c * aiq;
                }
                for i in 0..n {
                    let api = a[p * n + i];
                    let aqi = a[q * n + i];
                    a[p * n + i] = c * api - s * aqi;
                    a[q * n + i] = s * api + c * aqi;
                }
                // Accumulate V.
                for i in 0..n {
                    let vip = v[i * n + p];
                    let viq = v[i * n + q];
                    v[i * n + p] = c * vip - s * viq;
                    v[i * n + q] = s * vip + c * viq;
                }
            }
        }
    }
    let eig: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
    (eig, v)
}

/// Pseudo-inverse square root of a symmetric PSD matrix: W^(−1/2) =
/// V diag(λ_i > cutoff ? λ_i^(−1/2) : 0) Vᵀ. Returns row-major n×n.
pub fn inv_sqrt_psd(w: &[f64], n: usize, rel_cutoff: f64) -> Vec<f64> {
    let (eig, v) = jacobi_eigh(w.to_vec(), n, 1e-12, 64);
    let lmax = eig.iter().cloned().fold(0.0, f64::max);
    let cutoff = lmax * rel_cutoff;
    let mut out = vec![0f64; n * n];
    for t in 0..n {
        if eig[t] <= cutoff {
            continue;
        }
        let s = 1.0 / eig[t].sqrt();
        // out += s * v[:,t] v[:,t]ᵀ
        for i in 0..n {
            let vit = v[i * n + t] * s;
            if vit == 0.0 {
                continue;
            }
            for j in 0..n {
                out[i * n + j] += vit * v[j * n + t];
            }
        }
    }
    out
}

/// y = A·x for row-major A (n×m).
pub fn matvec(a: &[f64], n: usize, m: usize, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.len(), n * m);
    assert_eq!(x.len(), m);
    assert_eq!(y.len(), n);
    for i in 0..n {
        y[i] = a[i * m..(i + 1) * m].iter().zip(x).map(|(&av, &xv)| av * xv).sum();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn random_psd(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::new(seed);
        let b: Vec<f64> = (0..n * n).map(|_| rng.next_gaussian()).collect();
        // A = BᵀB + 0.1 I
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for t in 0..n {
                    s += b[t * n + i] * b[t * n + j];
                }
                a[i * n + j] = s + if i == j { 0.1 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn eigh_reconstructs_matrix() {
        let n = 12;
        let a = random_psd(n, 1);
        let (eig, v) = jacobi_eigh(a.clone(), n, 1e-12, 64);
        // Reconstruct V diag(eig) Vᵀ.
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for t in 0..n {
                    s += v[i * n + t] * eig[t] * v[j * n + t];
                }
                assert!((s - a[i * n + j]).abs() < 1e-8, "[{i},{j}]");
            }
        }
    }

    #[test]
    fn eigh_orthonormal_vectors() {
        let n = 10;
        let a = random_psd(n, 2);
        let (_, v) = jacobi_eigh(a, n, 1e-12, 64);
        for s in 0..n {
            for t in 0..n {
                let dot: f64 = (0..n).map(|i| v[i * n + s] * v[i * n + t]).sum();
                let want = if s == t { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-9, "[{s},{t}] dot {dot}");
            }
        }
    }

    #[test]
    fn inv_sqrt_squares_to_pinv() {
        let n = 8;
        let a = random_psd(n, 3);
        let h = inv_sqrt_psd(&a, n, 1e-12);
        // h·a·h ≈ I (all eigenvalues above cutoff here)
        let mut ha = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                ha[i * n + j] = (0..n).map(|t| h[i * n + t] * a[t * n + j]).sum();
            }
        }
        for i in 0..n {
            for j in 0..n {
                let s: f64 = (0..n).map(|t| ha[i * n + t] * h[t * n + j]).sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-6, "[{i},{j}] got {s}");
            }
        }
    }

    #[test]
    fn matvec_basic() {
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let x = vec![1.0, 0.0, -1.0];
        let mut y = vec![0.0; 2];
        matvec(&a, 2, 3, &x, &mut y);
        assert_eq!(y, vec![-2.0, -2.0]);
    }
}
