//! Stub PJRT runtime, compiled unless BOTH `pjrt` and `pjrt-xla` are
//! enabled (so `--features pjrt` alone — CI's feature-matrix step — still
//! builds without the FFI toolchain).
//!
//! The real `runtime` module executes AOT artifacts through the `xla` FFI
//! crate, which cannot be vendored into the offline build. This stub
//! mirrors its public surface so every consumer compiles unchanged:
//! [`Engine::load_default`] always returns `None`, so the harness's "auto"
//! backend selection falls back to [`crate::kernel::native::NativeKernel`],
//! and the `pjrt` backend mode reports artifacts as unavailable. No
//! [`Engine`] value can ever be constructed, so the [`PjrtKernel`] methods
//! are unreachable.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::kernel::{BlockKernel, KernelKind};

/// Tile-shape ABI read from artifacts/manifest.json (mirror of the real
/// runtime's type).
#[derive(Clone, Copy, Debug)]
pub struct TileAbi {
    pub d_pad: usize,
    pub nq_slim: usize,
    pub nq_wide: usize,
    pub nd_blk: usize,
}

/// Stub engine: can never be constructed.
pub struct Engine {
    abi: TileAbi,
    dir: PathBuf,
}

impl Engine {
    pub fn load(dir: &Path) -> Result<Engine> {
        bail!(
            "pjrt feature disabled: cannot load artifacts from {} (rebuild with --features pjrt and the xla dependency)",
            dir.display()
        )
    }

    /// Default artifact directory: `$DCSVM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("DCSVM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Always `None`: callers fall back to the native backend.
    pub fn load_default() -> Option<Engine> {
        None
    }

    pub fn abi(&self) -> TileAbi {
        self.abi
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn has_artifact(&self, _name: &str) -> bool {
        false
    }

    pub fn execute(&self, name: &str, _args: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        bail!("pjrt feature disabled: cannot execute artifact '{name}'")
    }

    pub fn call_counts(&self) -> Vec<(String, u64)> {
        Vec::new()
    }
}

/// Stub PJRT-backed kernel. Unreachable: constructing one requires an
/// [`Engine`], which the stub never produces.
pub struct PjrtKernel<'e> {
    _engine: &'e Engine,
    _kind: KernelKind,
}

impl<'e> PjrtKernel<'e> {
    pub fn new(engine: &'e Engine, kind: KernelKind) -> Self {
        PjrtKernel { _engine: engine, _kind: kind }
    }
}

#[allow(clippy::too_many_arguments)] // flat block ABI; see the trait docs
impl BlockKernel for PjrtKernel<'_> {
    fn kind(&self) -> KernelKind {
        unreachable!("stub PjrtKernel cannot exist: no Engine can be constructed")
    }

    fn block(
        &self,
        _xq: &[f32],
        _q_norms: &[f32],
        _xd: &[f32],
        _d_norms: &[f32],
        _dim: usize,
        _out: &mut [f32],
    ) {
        unreachable!("stub PjrtKernel cannot exist: no Engine can be constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_never_loads() {
        assert!(Engine::load_default().is_none());
        assert!(Engine::load(Path::new("artifacts")).is_err());
    }

    #[test]
    fn default_dir_env_override() {
        std::env::set_var("DCSVM_ARTIFACTS", "/tmp/nope-artifacts");
        assert_eq!(Engine::default_dir(), PathBuf::from("/tmp/nope-artifacts"));
        std::env::remove_var("DCSVM_ARTIFACTS");
        assert_eq!(Engine::default_dir(), PathBuf::from("artifacts"));
    }
}
