//! Prediction paths.
//!
//! - [`SvmModel`]: decision over the global SV set — used for the exact
//!   model and for "prediction by (10)" (naive use of a lower-level ᾱ).
//! - [`EarlyModel`]: the paper's early prediction (eq. 11): route the test
//!   point to its kernel-kmeans cluster, evaluate only that cluster's local
//!   model — O(|S|d/k) per point.
//! - [`BcmModel`]: Bayesian Committee Machine baseline (Tresp 2000):
//!   calibrated log-odds combination of *all* cluster models — the Table-1
//!   comparator that is both slower (k× kernel evaluations) and less
//!   accurate at large k.
//!
//! Construction and evaluation prefer a [`KernelContext`]
//! (`from_ctx_alpha`, `from_alpha_subset`, `accuracy_ctx`): SV norms are
//! gathered from the context's precomputed norms and batch decisions run
//! through the context's backend — no `sq_norms()` recomputation for
//! datasets that already have a context.
//!
//! [`SvmModel`] and [`EarlyModel`] serialize to JSON (`to_json` /
//! `from_json`) for the CLI train→save→serve flow; the serving layer
//! ([`crate::serving::ServingModel`]) distinguishes the two by the
//! early model's `"router"` field.

use crate::cache::KernelContext;
use crate::data::Dataset;
use crate::kernel::{BlockKernel, KernelKind};
use crate::kmeans::Router;

/// A kernel SVM decision model: f(x) = Σ_i coef_i K(x, sv_i).
#[derive(Clone, Debug)]
pub struct SvmModel {
    pub sv_x: Vec<f32>,
    pub sv_norms: Vec<f32>,
    /// coef_i = α_i y_i
    pub coef: Vec<f32>,
    pub dim: usize,
    pub kind: KernelKind,
}

impl SvmModel {
    /// Gather the support vectors of `alpha` over `ds` (standalone path:
    /// norms are computed per SV row; prefer [`Self::from_ctx_alpha`] when a
    /// context exists).
    pub fn from_alpha(ds: &Dataset, alpha: &[f64], kind: KernelKind) -> SvmModel {
        let dim = ds.dim;
        let mut sv_x = Vec::new();
        let mut sv_norms = Vec::new();
        let mut coef = Vec::new();
        for i in 0..ds.len() {
            if alpha[i] > 0.0 {
                sv_x.extend_from_slice(ds.row(i));
                sv_norms.push(ds.row(i).iter().map(|&v| v * v).sum());
                coef.push((alpha[i] * ds.y[i] as f64) as f32);
            }
        }
        SvmModel { sv_x, sv_norms, coef, dim, kind }
    }

    /// Gather the support vectors of `alpha` through a [`KernelContext`]:
    /// SV norms come from the context's precomputed norms.
    pub fn from_ctx_alpha(ctx: &KernelContext, alpha: &[f64]) -> SvmModel {
        let ds = ctx.ds();
        assert_eq!(alpha.len(), ds.len());
        let dim = ds.dim;
        let mut sv_x = Vec::new();
        let mut sv_norms = Vec::new();
        let mut coef = Vec::new();
        for i in 0..ds.len() {
            if alpha[i] > 0.0 {
                sv_x.extend_from_slice(ds.row(i));
                sv_norms.push(ctx.norm(i));
                coef.push((alpha[i] * ds.y[i] as f64) as f32);
            }
        }
        SvmModel { sv_x, sv_norms, coef, dim, kind: ctx.kind() }
    }

    /// Local model of a cluster: the SVs of globally indexed `alpha`
    /// restricted to `members`, gathered through the context (no subset
    /// dataset materialization).
    pub fn from_alpha_subset(
        ctx: &KernelContext,
        members: &[usize],
        alpha: &[f64],
    ) -> SvmModel {
        let ds = ctx.ds();
        let dim = ds.dim;
        let mut sv_x = Vec::new();
        let mut sv_norms = Vec::new();
        let mut coef = Vec::new();
        for &i in members {
            if alpha[i] > 0.0 {
                sv_x.extend_from_slice(ds.row(i));
                sv_norms.push(ctx.norm(i));
                coef.push((alpha[i] * ds.y[i] as f64) as f32);
            }
        }
        SvmModel { sv_x, sv_norms, coef, dim, kind: ctx.kind() }
    }

    /// Number of support vectors in the expansion.
    pub fn num_svs(&self) -> usize {
        self.coef.len()
    }

    /// Decision values for a row-major batch.
    pub fn decision_batch(
        &self,
        x: &[f32],
        norms: &[f32],
        kernel: &dyn BlockKernel,
    ) -> Vec<f32> {
        self.decision_batch_par(x, norms, kernel, 1)
    }

    /// [`Self::decision_batch`] with an in-process thread budget: large
    /// query batches fan out over per-query chunks
    /// ([`BlockKernel::decision_par`]) — decision values are bit-identical
    /// for any `threads` value.
    pub fn decision_batch_par(
        &self,
        x: &[f32],
        norms: &[f32],
        kernel: &dyn BlockKernel,
        threads: usize,
    ) -> Vec<f32> {
        debug_assert_eq!(kernel.kind(), self.kind);
        let n = norms.len();
        let mut out = vec![0f32; n];
        if self.coef.is_empty() {
            return out;
        }
        kernel.decision_par(
            x,
            norms,
            &self.sv_x,
            &self.sv_norms,
            self.dim,
            &self.coef,
            threads,
            &mut out,
        );
        out
    }

    /// ±1 predictions for a row-major batch (sign of the decision value,
    /// 0 ↦ +1).
    pub fn predict_batch(
        &self,
        x: &[f32],
        norms: &[f32],
        kernel: &dyn BlockKernel,
    ) -> Vec<i8> {
        self.predict_batch_par(x, norms, kernel, 1)
    }

    /// [`Self::predict_batch`] with an in-process thread budget.
    pub fn predict_batch_par(
        &self,
        x: &[f32],
        norms: &[f32],
        kernel: &dyn BlockKernel,
        threads: usize,
    ) -> Vec<i8> {
        self.decision_batch_par(x, norms, kernel, threads)
            .into_iter()
            .map(|d| if d >= 0.0 { 1 } else { -1 })
            .collect()
    }

    /// Accuracy on a test dataset (standalone path — computes test norms).
    pub fn accuracy(&self, test: &Dataset, kernel: &dyn BlockKernel) -> f64 {
        let norms = test.sq_norms();
        let preds = self.predict_batch(&test.x, &norms, kernel);
        crate::metrics::accuracy(&preds, &test.y)
    }

    /// Accuracy on a dataset that already has a [`KernelContext`] (norms
    /// and backend come from the context; large batches fan out over the
    /// context's thread budget — bit-identically — and the dispatch is
    /// counted in its `ValueStats`).
    pub fn accuracy_ctx(&self, ctx: &KernelContext) -> f64 {
        debug_assert_eq!(ctx.kind(), self.kind);
        // One K(test, SV) decision pass outside the row cache; counted so
        // the context's kernel-value accounting covers prediction too.
        ctx.count_external_values((ctx.len() * self.num_svs()) as u64);
        let mut dv = vec![0f32; ctx.len()];
        if !self.coef.is_empty() {
            ctx.decision_dispatch(
                &ctx.ds().x,
                ctx.norms(),
                &self.sv_x,
                &self.sv_norms,
                self.dim,
                &self.coef,
                &mut dv,
            );
        }
        let preds: Vec<i8> = dv.into_iter().map(|d| if d >= 0.0 { 1 } else { -1 }).collect();
        crate::metrics::accuracy(&preds, &ctx.ds().y)
    }

    /// Serialize to JSON (model persistence for the CLI train/predict flow).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let (kname, gamma, eta) = match self.kind {
            KernelKind::Rbf { gamma } => ("rbf", gamma as f64, 0.0),
            KernelKind::Poly { gamma, eta } => ("poly", gamma as f64, eta as f64),
            KernelKind::Linear => ("linear", 0.0, 0.0),
        };
        Json::obj(vec![
            ("type", Json::from("svm")),
            ("kernel", Json::from(kname)),
            ("gamma", Json::from(gamma)),
            ("eta", Json::from(eta)),
            ("dim", Json::from(self.dim)),
            ("coef", Json::arr_f64(&self.coef.iter().map(|&c| c as f64).collect::<Vec<_>>())),
            ("sv_x", Json::arr_f64(&self.sv_x.iter().map(|&v| v as f64).collect::<Vec<_>>())),
        ])
    }

    /// Deserialize from JSON.
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<SvmModel> {
        use anyhow::{anyhow, bail};
        let dim = j.get("dim").as_usize().ok_or_else(|| anyhow!("model: missing dim"))?;
        let gamma = j.get("gamma").as_f64().unwrap_or(0.0) as f32;
        let eta = j.get("eta").as_f64().unwrap_or(0.0) as f32;
        let kind = match j.get("kernel").as_str() {
            Some("rbf") => KernelKind::Rbf { gamma },
            Some("poly") => KernelKind::Poly { gamma, eta },
            Some("linear") => KernelKind::Linear,
            other => bail!("model: bad kernel {other:?}"),
        };
        let coef: Vec<f32> = j
            .get("coef")
            .as_arr()
            .ok_or_else(|| anyhow!("model: missing coef"))?
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0) as f32)
            .collect();
        let sv_x: Vec<f32> = j
            .get("sv_x")
            .as_arr()
            .ok_or_else(|| anyhow!("model: missing sv_x"))?
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0) as f32)
            .collect();
        if sv_x.len() != coef.len() * dim {
            bail!("model: sv_x/coef/dim inconsistent");
        }
        let sv_norms = sv_x.chunks(dim).map(|r| r.iter().map(|&v| v * v).sum()).collect();
        Ok(SvmModel { sv_x, sv_norms, coef, dim, kind })
    }
}

/// Early prediction (paper eq. 11): local model of the routed cluster only.
#[derive(Clone)]
pub struct EarlyModel {
    pub router: Router,
    /// One local model per cluster (possibly empty: no SVs in cluster).
    pub locals: Vec<SvmModel>,
}

impl EarlyModel {
    /// Build from a partition's cluster models.
    pub fn new(router: Router, locals: Vec<SvmModel>) -> EarlyModel {
        EarlyModel { router, locals }
    }

    /// Enable (or disable) the int8-quantized routing operand for this
    /// model's router ([`Router::set_quant_route`]). Routing is the
    /// approximation-tolerant half of early prediction; the per-cluster
    /// local decisions stay exact either way.
    pub fn set_quant_route(&mut self, on: bool) {
        self.router.set_quant_route(on);
    }

    /// Whether routing currently runs against quantized operands.
    pub fn quant_route(&self) -> bool {
        self.router.quant_route()
    }

    /// ±1 predictions: each query is routed to its cluster and evaluated
    /// by that cluster's local model only (one backend dispatch per
    /// non-empty cluster).
    pub fn predict_batch(
        &self,
        x: &[f32],
        norms: &[f32],
        kernel: &dyn BlockKernel,
    ) -> Vec<i8> {
        self.predict_batch_par(x, norms, kernel, 1)
    }

    /// [`Self::predict_batch`] with an in-process thread budget: the
    /// routing pass and each cluster's decision dispatch fan out over row
    /// panels. Predictions are bit-identical for any `threads` value.
    pub fn predict_batch_par(
        &self,
        x: &[f32],
        norms: &[f32],
        kernel: &dyn BlockKernel,
        threads: usize,
    ) -> Vec<i8> {
        let n = norms.len();
        let dim = self.locals.first().map(|m| m.dim).unwrap_or(1);
        let assign = self.router.assign_rows_par(x, norms, kernel, threads);
        // Batch per cluster for efficiency (one backend dispatch each).
        let mut out = vec![0i8; n];
        for c in 0..self.locals.len() {
            let idx: Vec<usize> =
                (0..n).filter(|&i| assign[i] as usize == c).collect();
            if idx.is_empty() {
                continue;
            }
            let mut cx = Vec::with_capacity(idx.len() * dim);
            let mut cn = Vec::with_capacity(idx.len());
            for &i in &idx {
                cx.extend_from_slice(&x[i * dim..(i + 1) * dim]);
                cn.push(norms[i]);
            }
            let preds = self.locals[c].predict_batch_par(&cx, &cn, kernel, threads);
            for (t, &i) in idx.iter().enumerate() {
                out[i] = preds[t];
            }
        }
        out
    }

    /// Accuracy on a test dataset (standalone path — computes test norms).
    pub fn accuracy(&self, test: &Dataset, kernel: &dyn BlockKernel) -> f64 {
        let norms = test.sq_norms();
        let preds = self.predict_batch(&test.x, &norms, kernel);
        crate::metrics::accuracy(&preds, &test.y)
    }

    /// Accuracy through an existing [`KernelContext`] (dispatches fan out
    /// over the context's thread budget, bit-identically).
    pub fn accuracy_ctx(&self, ctx: &KernelContext) -> f64 {
        // Count the K(test, sample) routing pass; the per-cluster local
        // decisions are O(|S|/k) per point on top.
        ctx.count_external_values((ctx.len() * self.router.sample_size()) as u64);
        let preds = self.predict_batch_par(&ctx.ds().x, ctx.norms(), ctx.kernel(), ctx.threads());
        crate::metrics::accuracy(&preds, &ctx.ds().y)
    }

    /// Total SVs across local models (test cost is |S|/k per point).
    pub fn total_svs(&self) -> usize {
        self.locals.iter().map(|m| m.num_svs()).sum()
    }

    /// Feature dimension (every local model shares it).
    pub fn dim(&self) -> usize {
        self.locals.first().map(|m| m.dim).unwrap_or_else(|| self.router.dim())
    }

    /// Kernel of the local models (shared; locals with zero SVs still carry
    /// the kind they were built with).
    pub fn kind(&self) -> KernelKind {
        self.locals.first().expect("early model has at least one local").kind
    }

    /// Serialize (router + per-cluster local models) for model persistence.
    /// The `"router"` key distinguishes early models from plain
    /// [`SvmModel`] files when loading.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("type", Json::from("early")),
            ("router", self.router.to_json()),
            ("locals", Json::Arr(self.locals.iter().map(|m| m.to_json()).collect())),
        ])
    }

    /// Deserialize a model saved by [`EarlyModel::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<EarlyModel> {
        use anyhow::{anyhow, bail};
        let router = Router::from_json(j.get("router"))?;
        let locals: Vec<SvmModel> = j
            .get("locals")
            .as_arr()
            .ok_or_else(|| anyhow!("early model: missing locals"))?
            .iter()
            .map(SvmModel::from_json)
            .collect::<anyhow::Result<_>>()?;
        if locals.is_empty() {
            bail!("early model: locals must be non-empty");
        }
        if locals.len() != router.k {
            bail!(
                "early model: {} locals for a k={} router",
                locals.len(),
                router.k
            );
        }
        let (dim, kind) = (locals[0].dim, locals[0].kind);
        if locals.iter().any(|m| m.dim != dim || m.kind != kind) {
            bail!("early model: locals disagree on dim/kernel");
        }
        if router.dim() != dim {
            bail!("early model: router dim {} != model dim {dim}", router.dim());
        }
        Ok(EarlyModel { router, locals })
    }
}

/// Bayesian Committee Machine combination of the k cluster models
/// (Tresp 2000), adapted to SVM decisions via sigmoid calibration: each
/// committee member emits p_c(y=1|x) = σ(a·f_c(x)); members are combined in
/// log-odds space (product of experts with the uniform-prior correction).
pub struct BcmModel {
    pub locals: Vec<SvmModel>,
    /// Sigmoid calibration slope.
    pub slope: f64,
}

impl BcmModel {
    pub fn new(locals: Vec<SvmModel>) -> BcmModel {
        BcmModel { locals, slope: 2.0 }
    }

    pub fn predict_batch(
        &self,
        x: &[f32],
        norms: &[f32],
        kernel: &dyn BlockKernel,
    ) -> Vec<i8> {
        let n = norms.len();
        let mut logodds = vec![0f64; n];
        for m in &self.locals {
            if m.num_svs() == 0 {
                continue;
            }
            let dv = m.decision_batch(x, norms, kernel);
            for (i, &d) in dv.iter().enumerate() {
                // log(σ(af)/(1−σ(af))) = a·f — the calibrated log-odds.
                logodds[i] += self.slope * d as f64;
            }
        }
        logodds
            .into_iter()
            .map(|l| if l >= 0.0 { 1 } else { -1 })
            .collect()
    }

    /// Accuracy on a test dataset (standalone path — computes test norms).
    pub fn accuracy(&self, test: &Dataset, kernel: &dyn BlockKernel) -> f64 {
        let norms = test.sq_norms();
        let preds = self.predict_batch(&test.x, &norms, kernel);
        crate::metrics::accuracy(&preds, &test.y)
    }

    /// Accuracy through an existing [`KernelContext`].
    pub fn accuracy_ctx(&self, ctx: &KernelContext) -> f64 {
        let preds = self.predict_batch(&ctx.ds().x, ctx.norms(), ctx.kernel());
        crate::metrics::accuracy(&preds, &ctx.ds().y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::KernelContext;
    use crate::data::synthetic::{covtype_like, generate_split};
    use crate::kernel::native::NativeKernel;
    use crate::solver::{SmoConfig, SmoSolver};

    #[test]
    fn exact_model_learns() {
        let (tr, te) = generate_split(&covtype_like(), 400, 150, 11);
        let kind = KernelKind::Rbf { gamma: 16.0 };
        let kern = NativeKernel::new(kind);
        let ctx = KernelContext::new(&tr, &kern, 64 << 20);
        let res = SmoSolver::new(
            ctx.view_full(),
            SmoConfig { c: 8.0, eps: 1e-4, ..Default::default() },
        )
        .solve();
        let model = SvmModel::from_ctx_alpha(&ctx, &res.alpha);
        assert_eq!(model.num_svs(), res.sv_count);
        let te_ctx = KernelContext::new(&te, &kern, 1 << 20);
        let acc = model.accuracy_ctx(&te_ctx);
        assert!(acc > 0.80, "exact model acc {acc}");
        // ctx path and standalone path agree exactly.
        assert_eq!(acc, model.accuracy(&te, &kern));
    }

    #[test]
    fn ctx_and_standalone_construction_agree() {
        let (tr, _) = generate_split(&covtype_like(), 200, 50, 14);
        let kind = KernelKind::Rbf { gamma: 8.0 };
        let kern = NativeKernel::new(kind);
        let ctx = KernelContext::new(&tr, &kern, 1 << 20);
        let alpha: Vec<f64> =
            (0..tr.len()).map(|i| if i % 3 == 0 { 0.5 } else { 0.0 }).collect();
        let a = SvmModel::from_alpha(&tr, &alpha, kind);
        let b = SvmModel::from_ctx_alpha(&ctx, &alpha);
        assert_eq!(a.sv_x, b.sv_x);
        assert_eq!(a.coef, b.coef);
        assert_eq!(a.sv_norms, b.sv_norms);
        // Subset construction over all indices equals the global one.
        let all: Vec<usize> = (0..tr.len()).collect();
        let c = SvmModel::from_alpha_subset(&ctx, &all, &alpha);
        assert_eq!(a.sv_x, c.sv_x);
        assert_eq!(a.coef, c.coef);
    }

    #[test]
    fn empty_model_predicts_negative() {
        let (tr, _) = generate_split(&covtype_like(), 20, 5, 12);
        let kind = KernelKind::Rbf { gamma: 1.0 };
        let kern = NativeKernel::new(kind);
        let model = SvmModel::from_alpha(&tr, &vec![0.0; tr.len()], kind);
        assert_eq!(model.num_svs(), 0);
        let norms = tr.sq_norms();
        let preds = model.predict_batch(&tr.x, &norms, &kern);
        assert!(preds.iter().all(|&p| p == 1)); // decision 0.0 -> sign +1
    }

    #[test]
    fn early_model_json_roundtrip_predicts_identically() {
        let (tr, te) = generate_split(&covtype_like(), 500, 120, 21);
        let kind = KernelKind::Rbf { gamma: 16.0 };
        let kern = NativeKernel::new(kind);
        let cfg = crate::dcsvm::DcSvmConfig {
            kind,
            c: 4.0,
            levels: 2,
            k_base: 4,
            sample_m: 64,
            stop_after_level: Some(1),
            ..Default::default()
        };
        let res = crate::dcsvm::train(&tr, &kern, &cfg);
        let em = res.early_model.expect("early model");
        let text = em.to_json().to_string();
        let back =
            EarlyModel::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.dim(), em.dim());
        assert_eq!(back.kind(), em.kind());
        assert_eq!(back.total_svs(), em.total_svs());
        let norms = te.sq_norms();
        assert_eq!(
            back.predict_batch(&te.x, &norms, &kern),
            em.predict_batch(&te.x, &norms, &kern)
        );
    }

    #[test]
    fn bcm_with_single_member_equals_that_member() {
        let (tr, te) = generate_split(&covtype_like(), 300, 100, 13);
        let kind = KernelKind::Rbf { gamma: 16.0 };
        let kern = NativeKernel::new(kind);
        let ctx = KernelContext::new(&tr, &kern, 64 << 20);
        let res = SmoSolver::new(
            ctx.view_full(),
            SmoConfig { c: 4.0, eps: 1e-3, ..Default::default() },
        )
        .solve();
        let m = SvmModel::from_ctx_alpha(&ctx, &res.alpha);
        let norms = te.sq_norms();
        let single = m.predict_batch(&te.x, &norms, &kern);
        let bcm = BcmModel::new(vec![m]);
        assert_eq!(bcm.predict_batch(&te.x, &norms, &kern), single);
    }
}
