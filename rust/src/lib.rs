//! # DC-SVM
//!
//! A production-grade reproduction of *"A Divide-and-Conquer Solver for
//! Kernel Support Vector Machines"* (Hsieh, Si, Dhillon — ICML 2014) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)**: the DC-SVM framework — multilevel
//!   divide-and-conquer driver, two-step kernel kmeans, exact greedy-CD
//!   (SMO-style) solver with shrinking and an LRU kernel cache, early
//!   prediction, every baseline from the paper's evaluation, the
//!   persistent serving subsystem (`serving`), CLI, and bench harness.
//! - **runtime**: loads AOT-compiled HLO artifacts (`artifacts/*.hlo.txt`)
//!   and executes kernel blocks via the PJRT CPU client (`xla` crate).
//! - **L2/L1 (python/, build-time only)**: JAX graphs over Pallas kernels,
//!   lowered once by `make artifacts`. Python is never on the request path.
//!
//! See DESIGN.md for the full system inventory and experiment index, and
//! EXPERIMENTS.md for measured reproductions of every table and figure.

pub mod baselines;
pub mod bench;
pub mod cache;
pub mod config;
/// PJRT runtime. The *real* implementation needs the `xla` FFI crate,
/// which the offline build cannot vendor, so it compiles only with BOTH
/// `pjrt` and `pjrt-xla` enabled (the latter documents the manual `xla`
/// dependency step in `Cargo.toml`). Everything else — including the
/// plain `--features pjrt` build CI's feature matrix exercises — gets an
/// API-identical stub: artifacts never load, and every consumer falls
/// back to the native backend.
#[cfg(all(feature = "pjrt", feature = "pjrt-xla"))]
pub mod runtime;
#[cfg(not(all(feature = "pjrt", feature = "pjrt-xla")))]
#[path = "runtime_stub.rs"]
pub mod runtime;
pub mod solver;
pub mod data;
pub mod distributed;
pub mod harness;
pub mod kernel;
pub mod dcsvm;
pub mod kmeans;
pub mod linalg;
pub mod metrics;
pub mod multiclass;
pub mod predict;
pub mod serving;
pub mod util;
