//! Every comparator from the paper's evaluation (Tables 3–4, Figures 3–4),
//! built on the shared substrates:
//!
//! | baseline | paper ref | module |
//! |---|---|---|
//! | CascadeSVM | Graf et al. 2005 | `cascade` |
//! | LaSVM (online) | Bordes et al. 2005 | `lasvm` |
//! | LLSVM (kmeans Nyström) | Zhang et al. 2008 / Wang et al. 2011 | `llsvm` |
//! | FastFood (random Fourier) | Le et al. 2013 | `fastfood` |
//! | LTPU (RBF network) | Moody & Darken 1989 | `ltpu` |
//! | SpSVM (greedy basis) | Keerthi et al. 2006 | `spsvm` |
//!
//! ("LIBSVM" is our exact solver run cold — `crate::solver::smo` — and BCM
//! prediction lives in `crate::predict`.)

pub mod cascade;
pub mod euclid_kmeans;
pub mod fastfood;
pub mod lasvm;
pub mod llsvm;
pub mod ltpu;
pub mod spsvm;
