//! SpSVM — building SVMs with reduced classifier complexity
//! (Keerthi, Chapelle, DeCoste — JMLR 2006): a greedy sparse kernel model
//! f(x) = Σ_{j∈J} β_j K(x, b_j) grown one basis vector at a time.
//!
//! Faithful-in-shape implementation: at each step a random candidate pool is
//! scored by how much a one-dimensional exact line search on the squared
//! hinge loss would reduce the regularized objective (Keerthi's "59
//! candidates" heuristic); the best candidate joins the basis, then the full
//! β is refit on the kernel features of the basis with the dual-CD linear
//! solver (ridge-equivalent squared-hinge stage replaced by hinge, as in our
//! other feature-map baselines). Accuracy saturates with basis size — the
//! qualitative behaviour Table 3/Figure 3 show.

use std::time::Instant;

use crate::data::Dataset;
use crate::kernel::{native::NativeKernel, BlockKernel, KernelKind};
use crate::solver::linear::{train_linear, LinearModel, LinearSvmConfig};
use crate::util::prng::Pcg64;

#[derive(Clone, Debug)]
pub struct SpsvmConfig {
    pub kind: KernelKind,
    pub c: f64,
    /// Final basis size.
    pub basis: usize,
    /// Candidates scored per growth step.
    pub candidates: usize,
    /// Basis vectors added between refits.
    pub grow_step: usize,
    pub seed: u64,
}

impl Default for SpsvmConfig {
    fn default() -> Self {
        SpsvmConfig {
            kind: KernelKind::Rbf { gamma: 1.0 },
            c: 1.0,
            basis: 64,
            candidates: 16,
            grow_step: 8,
            seed: 0,
        }
    }
}

pub struct SpsvmModel {
    basis_x: Vec<f32>,
    basis_norms: Vec<f32>,
    dim: usize,
    kind: KernelKind,
    pub linear: LinearModel,
    pub basis_size: usize,
    pub elapsed_s: f64,
}

impl SpsvmModel {
    pub fn features(&self, x: &[f32], norms: &[f32]) -> Vec<f32> {
        let n = norms.len();
        let kern = NativeKernel::new(self.kind);
        let mut out = vec![0f32; n * self.basis_size];
        kern.block(x, norms, &self.basis_x, &self.basis_norms, self.dim, &mut out);
        out
    }

    pub fn predict_batch(&self, x: &[f32], norms: &[f32]) -> Vec<i8> {
        let feats = self.features(x, norms);
        (0..norms.len())
            .map(|i| self.linear.predict(&feats[i * self.basis_size..(i + 1) * self.basis_size]))
            .collect()
    }

    pub fn accuracy(&self, test: &Dataset) -> f64 {
        let norms = test.sq_norms();
        self.accuracy_with_norms(test, &norms)
    }

    /// Accuracy with precomputed test norms (e.g. from a
    /// [`crate::cache::KernelContext`] the harness already built).
    pub fn accuracy_with_norms(&self, test: &Dataset, norms: &[f32]) -> f64 {
        let preds = self.predict_batch(&test.x, norms);
        crate::metrics::accuracy(&preds, &test.y)
    }
}

/// Train SpSVM by greedy basis growth. `norms` are the squared L2 norms of
/// `ds`'s rows — precomputed once by the caller (a
/// [`crate::cache::KernelContext`] when one exists for the dataset).
pub fn train(ds: &Dataset, norms: &[f32], cfg: &SpsvmConfig) -> SpsvmModel {
    let t0 = Instant::now();
    let n = ds.len();
    let dim = ds.dim;
    debug_assert_eq!(norms.len(), n);
    let kern = NativeKernel::new(cfg.kind);
    let mut rng = Pcg64::new(cfg.seed);

    let target = cfg.basis.min(n);
    let mut basis_idx: Vec<usize> = Vec::with_capacity(target);
    let mut in_basis = vec![false; n];

    // Current margins y_i f(x_i) (starts at 0).
    let mut fx = vec![0f64; n];
    let mut model_linear: Option<LinearModel> = None;

    let mut kb_col = vec![0f32; n]; // kernel column of a candidate

    while basis_idx.len() < target {
        // ---- grow: pick best of a random candidate pool -------------------
        for _ in 0..cfg.grow_step.min(target - basis_idx.len()) {
            let mut best: Option<(usize, f64)> = None;
            for _ in 0..cfg.candidates {
                let cand = rng.below(n);
                if in_basis[cand] {
                    continue;
                }
                // Score: squared-hinge objective decrease of an exact 1-D
                // line search along the candidate's kernel column.
                kern.block(
                    ds.row(cand),
                    &norms[cand..cand + 1],
                    &ds.x,
                    norms,
                    dim,
                    &mut kb_col,
                );
                // minimize Σ_i max(0, 1 − y_i(f_i + β k_i))² over β: one
                // Newton step from β=0 on the active set.
                let mut g = 0f64;
                let mut h = 1e-9f64;
                for i in 0..n {
                    let yi = ds.y[i] as f64;
                    let m = 1.0 - yi * fx[i];
                    if m > 0.0 {
                        let k = kb_col[i] as f64;
                        g += -2.0 * m * yi * k;
                        h += 2.0 * k * k;
                    }
                }
                let beta = -g / h;
                let decrease = 0.5 * g.abs() * beta.abs(); // ≈ quadratic gain
                if best.map(|(_, s)| decrease > s).unwrap_or(true) {
                    best = Some((cand, decrease));
                }
            }
            if let Some((cand, _)) = best {
                in_basis[cand] = true;
                basis_idx.push(cand);
            } else {
                break;
            }
        }

        // ---- refit β on the current basis ---------------------------------
        let bsz = basis_idx.len();
        let mut bx = Vec::with_capacity(bsz * dim);
        let mut bn = Vec::with_capacity(bsz);
        for &b in &basis_idx {
            bx.extend_from_slice(ds.row(b));
            bn.push(norms[b]);
        }
        let mut feats = vec![0f32; n * bsz];
        kern.block(&ds.x, norms, &bx, &bn, dim, &mut feats);
        let fds = Dataset::new(feats.clone(), ds.y.clone(), bsz, "spsvm-feats");
        let lm = train_linear(
            &fds,
            &LinearSvmConfig { c: cfg.c, eps: 1e-3, max_epochs: 60, seed: cfg.seed },
        );
        for i in 0..n {
            fx[i] = lm.decision(&feats[i * bsz..(i + 1) * bsz]);
        }
        model_linear = Some(lm);
    }

    let bsz = basis_idx.len();
    let mut basis_x = Vec::with_capacity(bsz * dim);
    let mut basis_norms = Vec::with_capacity(bsz);
    for &b in &basis_idx {
        basis_x.extend_from_slice(ds.row(b));
        basis_norms.push(norms[b]);
    }
    SpsvmModel {
        basis_x,
        basis_norms,
        dim,
        kind: cfg.kind,
        linear: model_linear.expect("at least one refit"),
        basis_size: bsz,
        elapsed_s: t0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate_split};

    #[test]
    fn spsvm_learns() {
        let (tr, te) = generate_split(&covtype_like(), 700, 200, 81);
        let model = train(
            &tr,
            &tr.sq_norms(),
            &SpsvmConfig {
                kind: KernelKind::Rbf { gamma: 16.0 },
                c: 4.0,
                basis: 48,
                ..Default::default()
            },
        );
        let acc = model.accuracy(&te);
        assert!(acc > 0.70, "spsvm acc {acc}");
        assert_eq!(model.basis_size, 48);
    }

    #[test]
    fn basis_respects_budget() {
        let (tr, _) = generate_split(&covtype_like(), 120, 30, 82);
        let model = train(
            &tr,
            &tr.sq_norms(),
            &SpsvmConfig {
                kind: KernelKind::Rbf { gamma: 8.0 },
                basis: 500, // larger than n
                ..Default::default()
            },
        );
        assert!(model.basis_size <= 120);
    }
}
