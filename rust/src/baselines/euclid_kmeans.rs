//! Plain euclidean kmeans (Lloyd) — substrate for the landmark/center-based
//! baselines (LLSVM's kmeans Nyström, LTPU's RBF units).

use crate::util::prng::Pcg64;

/// Run Lloyd kmeans on row-major `x` ([n, d]); returns centers ([k, d]).
pub fn kmeans_centers(
    x: &[f32],
    n: usize,
    d: usize,
    k: usize,
    max_iter: usize,
    rng: &mut Pcg64,
) -> Vec<f64> {
    assert_eq!(x.len(), n * d);
    let k = k.min(n).max(1);
    // kmeans++ init.
    let mut centers = vec![0f64; k * d];
    let first = rng.below(n);
    for j in 0..d {
        centers[j] = x[first * d + j] as f64;
    }
    let dist2 = |xi: &[f32], c: &[f64]| -> f64 {
        xi.iter()
            .zip(c)
            .map(|(&v, &cv)| (v as f64 - cv) * (v as f64 - cv))
            .sum()
    };
    let mut min_d: Vec<f64> = (0..n)
        .map(|i| dist2(&x[i * d..(i + 1) * d], &centers[0..d]))
        .collect();
    for c in 1..k {
        // sample proportional to distance² (kmeans++)
        let total: f64 = min_d.iter().sum();
        let mut target = rng.next_f64() * total.max(1e-30);
        let mut pick = n - 1;
        for (i, &dv) in min_d.iter().enumerate() {
            target -= dv;
            if target <= 0.0 {
                pick = i;
                break;
            }
        }
        for j in 0..d {
            centers[c * d + j] = x[pick * d + j] as f64;
        }
        for i in 0..n {
            min_d[i] = min_d[i].min(dist2(&x[i * d..(i + 1) * d], &centers[c * d..(c + 1) * d]));
        }
    }

    let mut assign = vec![0usize; n];
    for _ in 0..max_iter {
        let mut changed = 0;
        for i in 0..n {
            let xi = &x[i * d..(i + 1) * d];
            let best = (0..k)
                .min_by(|&a, &b| {
                    dist2(xi, &centers[a * d..(a + 1) * d])
                        .total_cmp(&dist2(xi, &centers[b * d..(b + 1) * d]))
                })
                .unwrap();
            if best != assign[i] {
                assign[i] = best;
                changed += 1;
            }
        }
        // recompute centers
        let mut counts = vec![0usize; k];
        let mut sums = vec![0f64; k * d];
        for i in 0..n {
            counts[assign[i]] += 1;
            for j in 0..d {
                sums[assign[i] * d + j] += x[i * d + j] as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // reseed at a random point
                let p = rng.below(n);
                for j in 0..d {
                    centers[c * d + j] = x[p * d + j] as f64;
                }
            } else {
                for j in 0..d {
                    centers[c * d + j] = sums[c * d + j] / counts[c] as f64;
                }
            }
        }
        if changed == 0 {
            break;
        }
    }
    centers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_blob_centers() {
        let mut rng = Pcg64::new(1);
        let truth = [(0.0f64, 0.0f64), (10.0, 0.0), (0.0, 10.0)];
        let mut x = Vec::new();
        for &(cx, cy) in &truth {
            for _ in 0..30 {
                x.push((cx + rng.next_gaussian() * 0.2) as f32);
                x.push((cy + rng.next_gaussian() * 0.2) as f32);
            }
        }
        let centers = kmeans_centers(&x, 90, 2, 3, 50, &mut rng);
        // every true center must be close to some found center
        for &(cx, cy) in &truth {
            let best = (0..3)
                .map(|c| {
                    let dx = centers[c * 2] - cx;
                    let dy = centers[c * 2 + 1] - cy;
                    dx * dx + dy * dy
                })
                .fold(f64::INFINITY, f64::min);
            assert!(best < 0.05, "center ({cx},{cy}) missed: {best}");
        }
    }

    #[test]
    fn k_capped() {
        let mut rng = Pcg64::new(2);
        let x = vec![0f32, 1.0, 2.0];
        let c = kmeans_centers(&x, 3, 1, 10, 20, &mut rng);
        assert_eq!(c.len(), 3);
    }
}
