//! LTPU — Locally-Tuned Processing Units (Moody & Darken 1989): an RBF
//! network whose units sit at kmeans centers, with linear output weights
//! trained by a linear SVM (the paper sets unit width γ to the best RBF-SVM
//! γ and fits weights with LIBLINEAR — we mirror both choices).

use std::time::Instant;

use crate::data::Dataset;
use crate::solver::linear::{train_linear, LinearModel, LinearSvmConfig};
use crate::util::prng::Pcg64;

use super::euclid_kmeans::kmeans_centers;

#[derive(Clone, Debug)]
pub struct LtpuConfig {
    pub gamma: f64,
    pub c: f64,
    /// Number of RBF units (kmeans centers).
    pub units: usize,
    pub seed: u64,
}

impl Default for LtpuConfig {
    fn default() -> Self {
        LtpuConfig { gamma: 1.0, c: 1.0, units: 64, seed: 0 }
    }
}

pub struct LtpuModel {
    centers: Vec<f64>, // [units, dim]
    dim: usize,
    units: usize,
    gamma: f64,
    pub linear: LinearModel,
    pub elapsed_s: f64,
}

impl LtpuModel {
    fn unit_activations(&self, x: &[f32], out: &mut [f32]) {
        for u in 0..self.units {
            let c = &self.centers[u * self.dim..(u + 1) * self.dim];
            let d2: f64 = x
                .iter()
                .zip(c)
                .map(|(&xv, &cv)| (xv as f64 - cv) * (xv as f64 - cv))
                .sum();
            out[u] = (-self.gamma * d2).exp() as f32;
        }
    }

    pub fn features(&self, x: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0f32; n * self.units];
        for i in 0..n {
            let (lo, hi) = (i * self.units, (i + 1) * self.units);
            self.unit_activations(&x[i * self.dim..(i + 1) * self.dim], &mut out[lo..hi]);
        }
        out
    }

    pub fn predict_batch(&self, x: &[f32], n: usize) -> Vec<i8> {
        let feats = self.features(x, n);
        (0..n)
            .map(|i| self.linear.predict(&feats[i * self.units..(i + 1) * self.units]))
            .collect()
    }

    pub fn accuracy(&self, test: &Dataset) -> f64 {
        let preds = self.predict_batch(&test.x, test.len());
        crate::metrics::accuracy(&preds, &test.y)
    }
}

/// Train the LTPU network.
pub fn train(ds: &Dataset, cfg: &LtpuConfig) -> LtpuModel {
    let t0 = Instant::now();
    let mut rng = Pcg64::new(cfg.seed);
    let units = cfg.units.min(ds.len());
    let sample = rng.sample_indices(ds.len(), (units * 20).min(ds.len()));
    let mut sx = Vec::with_capacity(sample.len() * ds.dim);
    for &i in &sample {
        sx.extend_from_slice(ds.row(i));
    }
    let centers = kmeans_centers(&sx, sample.len(), ds.dim, units, 25, &mut rng);

    let mut model = LtpuModel {
        centers,
        dim: ds.dim,
        units,
        gamma: cfg.gamma,
        linear: LinearModel { w: vec![], alpha: vec![], epochs: 0, elapsed_s: 0.0 },
        elapsed_s: 0.0,
    };
    let feats = model.features(&ds.x, ds.len());
    let fds = Dataset::new(feats, ds.y.clone(), units, format!("{}-ltpu", ds.name));
    model.linear = train_linear(
        &fds,
        &LinearSvmConfig { c: cfg.c, eps: 1e-3, max_epochs: 150, seed: cfg.seed },
    );
    model.elapsed_s = t0.elapsed().as_secs_f64();
    model
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate_split};

    #[test]
    fn ltpu_learns() {
        let (tr, te) = generate_split(&covtype_like(), 800, 250, 71);
        let model = train(
            &tr,
            &LtpuConfig { gamma: 16.0, c: 4.0, units: 64, ..Default::default() },
        );
        let acc = model.accuracy(&te);
        assert!(acc > 0.70, "ltpu acc {acc}");
    }

    #[test]
    fn activations_in_unit_range() {
        let (tr, _) = generate_split(&covtype_like(), 100, 20, 72);
        let model = train(&tr, &LtpuConfig { gamma: 4.0, units: 16, ..Default::default() });
        let feats = model.features(&tr.x, tr.len());
        assert!(feats.iter().all(|&f| (0.0..=1.0 + 1e-6).contains(&f)));
    }
}
