//! LaSVM (Bordes et al., JMLR 2005) — online kernel SVM comparator.
//!
//! Adapted to the paper's no-bias dual (single-coordinate updates instead of
//! τ-violating pairs; the pair mechanism exists only to preserve the
//! equality constraint Σα_i y_i = 0, which the no-bias dual does not have):
//!
//! - PROCESS(i): insert a fresh point into the expansion and take one exact
//!   coordinate step on it if it violates KKT.
//! - REPROCESS: one coordinate step on the most violating member of the
//!   current expansion, then drop non-SV members whose KKT conditions hold.
//! - Online passes interleave one PROCESS with one REPROCESS; FINISH runs
//!   REPROCESS to ε on the expansion (as in the original paper).
//!
//! Kernel rows are computed only against the current expansion, so the
//! memory footprint is O(|S|²) like the original.
//!
//! The PROCESS/EVICT insertion–removal scheme is promoted to a first-class
//! batch primitive in [`crate::dcsvm::update`]: `dcsvm update` gates the
//! appended rows through the same margin test (batched over a cached SV
//! segment) and lets one warm-started SMO run play the REPROCESS/FINISH
//! role, evicting members whose α falls to 0.

use std::time::Instant;

use crate::cache::KernelContext;
use crate::data::Dataset;
use crate::kernel::{BlockKernel, KernelKind};
use crate::predict::SvmModel;
use crate::util::prng::Pcg64;

#[derive(Clone, Debug)]
pub struct LaSvmConfig {
    pub kind: KernelKind,
    pub c: f64,
    pub eps: f64,
    /// Online passes over the data.
    pub passes: usize,
    pub seed: u64,
    /// Iteration cap for the FINISH phase (0 = unlimited).
    pub max_finish_iter: usize,
}

impl Default for LaSvmConfig {
    fn default() -> Self {
        LaSvmConfig {
            kind: KernelKind::Rbf { gamma: 1.0 },
            c: 1.0,
            eps: 1e-3,
            passes: 1,
            seed: 0,
            max_finish_iter: 0,
        }
    }
}

pub struct LaSvmResult {
    pub model: SvmModel,
    pub alpha: Vec<f64>,
    pub elapsed_s: f64,
    pub process_steps: usize,
    pub reprocess_steps: usize,
}

struct Expansion<'a> {
    ds: &'a Dataset,
    kernel: &'a dyn BlockKernel,
    norms: &'a [f32],
    /// Dataset indices in the expansion.
    idx: Vec<usize>,
    /// Gradient g_i = Σ_j α_j Q_ij − 1 for members (maintained).
    grad: Vec<f64>,
    /// α for members.
    alpha: Vec<f64>,
    /// Cached kernel rows member×member (grown as members join).
    krows: Vec<Vec<f32>>,
}

impl<'a> Expansion<'a> {
    /// Kernel values of dataset point `p` against all current members.
    fn kernel_to_members(&self, p: usize) -> Vec<f32> {
        let m = self.idx.len();
        let mut out = vec![0f32; m];
        if m == 0 {
            return out;
        }
        let dim = self.ds.dim;
        let mut xd = Vec::with_capacity(m * dim);
        let mut dn = Vec::with_capacity(m);
        for &j in &self.idx {
            xd.extend_from_slice(self.ds.row(j));
            dn.push(self.norms[j]);
        }
        self.kernel.block(
            self.ds.row(p),
            &self.norms[p..p + 1],
            &xd,
            &dn,
            dim,
            &mut out,
        );
        out
    }

    /// Insert point p (must not be a member); returns its member slot.
    fn insert(&mut self, p: usize) -> usize {
        let krow = self.kernel_to_members(p);
        // g_p = y_p Σ_j α_j y_j K_pj − 1
        let yp = self.ds.y[p] as f64;
        let mut g = -1.0;
        for (t, &j) in self.idx.iter().enumerate() {
            g += yp * self.alpha[t] * self.ds.y[j] as f64 * krow[t] as f64;
        }
        // extend existing member rows with K(member, p)
        for (t, row) in self.krows.iter_mut().enumerate() {
            row.push(krow[t]);
        }
        let kpp = self.kernel.kind().self_eval(self.ds.row(p), self.norms[p]);
        let mut newrow = krow;
        newrow.push(kpp);
        self.krows.push(newrow);
        self.idx.push(p);
        self.alpha.push(0.0);
        self.grad.push(g);
        self.idx.len() - 1
    }

    /// Exact coordinate step on member slot t; returns |δ|.
    fn step(&mut self, t: usize, c: f64) -> f64 {
        let p = self.idx[t];
        let qtt = (self.krows[t][t] as f64).max(1e-12);
        let delta = (self.alpha[t] - self.grad[t] / qtt).clamp(0.0, c) - self.alpha[t];
        if delta != 0.0 {
            self.alpha[t] += delta;
            let yp = self.ds.y[p] as f64;
            for (s, &j) in self.idx.iter().enumerate() {
                self.grad[s] +=
                    delta * yp * self.ds.y[j] as f64 * self.krows[t][s] as f64;
            }
        }
        delta.abs()
    }

    /// Most violating member slot and its violation.
    fn max_violating(&self, c: f64) -> (usize, f64) {
        let mut best = (usize::MAX, 0.0f64);
        for t in 0..self.idx.len() {
            let v = crate::solver::objective::projected_violation(
                self.alpha[t],
                self.grad[t],
                c,
            );
            if v > best.1 {
                best = (t, v);
            }
        }
        best
    }

    /// Remove non-SV members whose KKT conditions hold (α=0, g≥0).
    fn evict(&mut self) {
        let mut t = 0;
        while t < self.idx.len() {
            if self.alpha[t] == 0.0 && self.grad[t] >= 0.0 && self.idx.len() > 1 {
                let last = self.idx.len() - 1;
                self.idx.swap(t, last);
                self.alpha.swap(t, last);
                self.grad.swap(t, last);
                self.krows.swap(t, last);
                self.idx.pop();
                self.alpha.pop();
                self.grad.pop();
                let removed = self.krows.pop().unwrap();
                let _ = removed;
                // fix row columns: swap col t/last then truncate
                for row in self.krows.iter_mut() {
                    row.swap(t, last);
                    row.pop();
                }
            } else {
                t += 1;
            }
        }
    }
}

/// Train LaSVM on a [`KernelContext`] (dataset, backend and precomputed
/// norms all come from the context).
pub fn train(ctx: &KernelContext, cfg: &LaSvmConfig) -> LaSvmResult {
    let t0 = Instant::now();
    let ds = ctx.ds();
    let kernel = ctx.kernel();
    let n = ds.len();
    let mut rng = Pcg64::new(cfg.seed);

    let mut exp = Expansion {
        ds,
        kernel,
        norms: ctx.norms(),
        idx: Vec::new(),
        grad: Vec::new(),
        alpha: Vec::new(),
        krows: Vec::new(),
    };
    let mut in_expansion = vec![false; n];
    let mut process_steps = 0usize;
    let mut reprocess_steps = 0usize;

    // Seed with a few points of each class (as the original recommends).
    let mut seeded = [0usize; 2];
    for i in 0..n {
        let cls = (ds.y[i] == 1) as usize;
        if seeded[cls] < 3 && !in_expansion[i] {
            let t = exp.insert(i);
            exp.step(t, cfg.c);
            in_expansion[i] = true;
            seeded[cls] += 1;
        }
        if seeded == [3, 3] {
            break;
        }
    }

    let mut order: Vec<usize> = (0..n).collect();
    for _ in 0..cfg.passes {
        rng.shuffle(&mut order);
        for &p in &order {
            if in_expansion[p] {
                continue;
            }
            // PROCESS
            let t = exp.insert(p);
            in_expansion[p] = true;
            exp.step(t, cfg.c);
            process_steps += 1;
            // REPROCESS
            let (worst, v) = exp.max_violating(cfg.c);
            if worst != usize::MAX && v > cfg.eps {
                exp.step(worst, cfg.c);
                reprocess_steps += 1;
            }
            // periodic eviction keeps the expansion ~ SV-sized
            if exp.idx.len() % 64 == 0 {
                for &j in &exp.idx {
                    let _ = j;
                }
                let before: Vec<usize> = exp.idx.clone();
                exp.evict();
                for j in before {
                    if !exp.idx.contains(&j) {
                        in_expansion[j] = false;
                    }
                }
            }
        }
    }

    // FINISH: reprocess to ε.
    let mut finish_iter = 0usize;
    loop {
        let (worst, v) = exp.max_violating(cfg.c);
        if worst == usize::MAX || v <= cfg.eps {
            break;
        }
        exp.step(worst, cfg.c);
        reprocess_steps += 1;
        finish_iter += 1;
        if cfg.max_finish_iter > 0 && finish_iter >= cfg.max_finish_iter {
            break;
        }
    }

    let mut alpha = vec![0f64; n];
    for (t, &i) in exp.idx.iter().enumerate() {
        alpha[i] = exp.alpha[t];
    }
    let model = SvmModel::from_ctx_alpha(ctx, &alpha);
    LaSvmResult {
        model,
        alpha,
        elapsed_s: t0.elapsed().as_secs_f64(),
        process_steps,
        reprocess_steps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate_split, kddcup99_like};
    use crate::kernel::native::NativeKernel;

    #[test]
    fn learns_separable_quickly() {
        let (tr, te) = generate_split(&kddcup99_like(), 500, 200, 41);
        let kind = KernelKind::Rbf { gamma: 8.0 };
        let kern = NativeKernel::new(kind);
        let ctx = KernelContext::new(&tr, &kern, 1 << 20);
        let res = train(&ctx, &LaSvmConfig { kind, c: 4.0, ..Default::default() });
        let acc = res.model.accuracy(&te, &kern);
        assert!(acc > 0.93, "lasvm acc {acc}");
    }

    #[test]
    fn feasible_alpha() {
        let (tr, _) = generate_split(&covtype_like(), 300, 80, 42);
        let kind = KernelKind::Rbf { gamma: 16.0 };
        let kern = NativeKernel::new(kind);
        let ctx = KernelContext::new(&tr, &kern, 1 << 20);
        let cfg = LaSvmConfig { kind, c: 2.0, ..Default::default() };
        let res = train(&ctx, &cfg);
        assert!(res.alpha.iter().all(|&a| (0.0..=cfg.c).contains(&a)));
        assert!(res.process_steps > 0);
    }

    #[test]
    fn more_passes_no_worse_objective() {
        let (tr, _) = generate_split(&covtype_like(), 250, 60, 43);
        let kind = KernelKind::Rbf { gamma: 16.0 };
        let kern = NativeKernel::new(kind);
        let ctx = KernelContext::new(&tr, &kern, 1 << 20);
        let one = train(
            &ctx,
            &LaSvmConfig { kind, c: 2.0, passes: 1, max_finish_iter: 1, ..Default::default() },
        );
        let two = train(
            &ctx,
            &LaSvmConfig { kind, c: 2.0, passes: 3, ..Default::default() },
        );
        let f1 = crate::metrics::objective_of(&tr, &kern, &one.alpha);
        let f2 = crate::metrics::objective_of(&tr, &kern, &two.alpha);
        assert!(f2 <= f1 + 1e-6, "f2 {f2} > f1 {f1}");
    }
}
