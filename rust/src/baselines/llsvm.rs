//! LLSVM — low-rank linearization with the kmeans Nyström method
//! (Zhang et al. 2008 / Wang et al. 2011), the paper's strongest
//! approximate-solver comparator.
//!
//! Landmarks U = kmeans centers of the input space; the Nyström feature map
//!
//! ```text
//! φ(x) = W^(−1/2) · [K(x, u_1), …, K(x, u_m)]ᵀ,   W = K(U, U)
//! ```
//!
//! gives ⟨φ(x), φ(z)⟩ = the rank-m Nyström approximation of K(x, z); a
//! linear SVM (dual CD) on φ(x) then approximates the kernel SVM. Accuracy
//! saturates with m — the crossover Figure 3 demonstrates against DC-SVM.

use std::time::Instant;

use crate::data::Dataset;
use crate::kernel::{native::NativeKernel, BlockKernel, KernelKind};
use crate::linalg::inv_sqrt_psd;
use crate::solver::linear::{train_linear, LinearModel, LinearSvmConfig};
use crate::util::prng::Pcg64;

use super::euclid_kmeans::kmeans_centers;

#[derive(Clone, Debug)]
pub struct LlsvmConfig {
    pub kind: KernelKind,
    pub c: f64,
    /// Number of landmarks (Nyström rank).
    pub landmarks: usize,
    pub seed: u64,
    pub linear_eps: f64,
}

impl Default for LlsvmConfig {
    fn default() -> Self {
        LlsvmConfig {
            kind: KernelKind::Rbf { gamma: 1.0 },
            c: 1.0,
            landmarks: 64,
            seed: 0,
            linear_eps: 1e-3,
        }
    }
}

pub struct LlsvmModel {
    /// Landmarks, row-major [m, dim] (f32 for kernel evaluation).
    landmarks: Vec<f32>,
    landmark_norms: Vec<f32>,
    /// W^(−1/2), row-major m×m.
    w_inv_sqrt: Vec<f64>,
    dim: usize,
    m: usize,
    kind: KernelKind,
    pub linear: LinearModel,
    pub elapsed_s: f64,
}

impl LlsvmModel {
    /// Map a batch of rows to Nyström features ([n, m] row-major f32).
    pub fn features(&self, x: &[f32], norms: &[f32]) -> Vec<f32> {
        let n = norms.len();
        let kern = NativeKernel::new(self.kind);
        let mut kxu = vec![0f32; n * self.m];
        kern.block(x, norms, &self.landmarks, &self.landmark_norms, self.dim, &mut kxu);
        // φ = kxu · (W^(−1/2))ᵀ ( = ·W^(−1/2), symmetric)
        let mut out = vec![0f32; n * self.m];
        for i in 0..n {
            let row = &kxu[i * self.m..(i + 1) * self.m];
            let dst = &mut out[i * self.m..(i + 1) * self.m];
            for j in 0..self.m {
                let mut s = 0f64;
                for t in 0..self.m {
                    s += row[t] as f64 * self.w_inv_sqrt[t * self.m + j];
                }
                dst[j] = s as f32;
            }
        }
        out
    }

    pub fn predict_batch(&self, x: &[f32], norms: &[f32]) -> Vec<i8> {
        let feats = self.features(x, norms);
        (0..norms.len())
            .map(|i| self.linear.predict(&feats[i * self.m..(i + 1) * self.m]))
            .collect()
    }

    pub fn accuracy(&self, test: &Dataset) -> f64 {
        let norms = test.sq_norms();
        self.accuracy_with_norms(test, &norms)
    }

    /// Accuracy with precomputed test norms (e.g. from a
    /// [`crate::cache::KernelContext`] the harness already built).
    pub fn accuracy_with_norms(&self, test: &Dataset, norms: &[f32]) -> f64 {
        let preds = self.predict_batch(&test.x, norms);
        crate::metrics::accuracy(&preds, &test.y)
    }
}

/// Train LLSVM. `norms` are the squared L2 norms of `ds`'s rows —
/// precomputed once by the caller (a [`crate::cache::KernelContext`] when
/// one exists for the dataset).
pub fn train(ds: &Dataset, norms: &[f32], cfg: &LlsvmConfig) -> LlsvmModel {
    let t0 = Instant::now();
    let mut rng = Pcg64::new(cfg.seed);
    let dim = ds.dim;
    let m = cfg.landmarks.min(ds.len());

    // Landmarks: kmeans centers on (a sample of) the training data.
    let sample = rng.sample_indices(ds.len(), (m * 20).min(ds.len()));
    let mut sx = Vec::with_capacity(sample.len() * dim);
    for &i in &sample {
        sx.extend_from_slice(ds.row(i));
    }
    let centers64 = kmeans_centers(&sx, sample.len(), dim, m, 25, &mut rng);
    let landmarks: Vec<f32> = centers64.iter().map(|&v| v as f32).collect();
    let landmark_norms: Vec<f32> = landmarks
        .chunks(dim)
        .map(|r| r.iter().map(|&v| v * v).sum())
        .collect();

    // W = K(U, U), W^(−1/2) by symmetric eigendecomposition.
    let kern = NativeKernel::new(cfg.kind);
    let mut w32 = vec![0f32; m * m];
    kern.block(&landmarks, &landmark_norms, &landmarks, &landmark_norms, dim, &mut w32);
    let w: Vec<f64> = w32.iter().map(|&v| v as f64).collect();
    let w_inv_sqrt = inv_sqrt_psd(&w, m, 1e-7);

    let mut model = LlsvmModel {
        landmarks,
        landmark_norms,
        w_inv_sqrt,
        dim,
        m,
        kind: cfg.kind,
        linear: LinearModel { w: vec![], alpha: vec![], epochs: 0, elapsed_s: 0.0 },
        elapsed_s: 0.0,
    };

    // Linear SVM on the Nyström features.
    debug_assert_eq!(norms.len(), ds.len());
    let feats = model.features(&ds.x, norms);
    let fds = Dataset::new(feats, ds.y.clone(), m, format!("{}-nystrom", ds.name));
    model.linear = train_linear(
        &fds,
        &LinearSvmConfig { c: cfg.c, eps: cfg.linear_eps, max_epochs: 200, seed: cfg.seed },
    );
    model.elapsed_s = t0.elapsed().as_secs_f64();
    model
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate_split};

    #[test]
    fn llsvm_learns() {
        let (tr, te) = generate_split(&covtype_like(), 800, 250, 51);
        let cfg = LlsvmConfig {
            kind: KernelKind::Rbf { gamma: 16.0 },
            c: 4.0,
            landmarks: 48,
            ..Default::default()
        };
        let model = train(&tr, &tr.sq_norms(), &cfg);
        let acc = model.accuracy(&te);
        assert!(acc > 0.70, "llsvm acc {acc}");
    }

    #[test]
    fn feature_inner_products_approximate_kernel() {
        let (tr, _) = generate_split(&covtype_like(), 300, 50, 52);
        let kind = KernelKind::Rbf { gamma: 4.0 };
        let norms = tr.sq_norms();
        let model = train(&tr, &norms, &LlsvmConfig { kind, landmarks: 100, ..Default::default() });
        let feats = model.features(&tr.x, &norms);
        let m = model.m;
        let kern = NativeKernel::new(kind);
        // compare ⟨φ_i, φ_j⟩ with K_ij on a few pairs
        let mut errs = Vec::new();
        for &(i, j) in &[(0usize, 1usize), (5, 9), (20, 40), (100, 200)] {
            let dot: f64 = (0..m)
                .map(|t| feats[i * m + t] as f64 * feats[j * m + t] as f64)
                .sum();
            let k = kind.eval(tr.row(i), tr.row(j)) as f64;
            errs.push((dot - k).abs());
        }
        let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mean_err < 0.15, "nystrom approx error {mean_err} ({errs:?})");
    }

    #[test]
    fn more_landmarks_no_worse() {
        let (tr, te) = generate_split(&covtype_like(), 600, 200, 53);
        let kind = KernelKind::Rbf { gamma: 16.0 };
        let norms = tr.sq_norms();
        let small =
            train(&tr, &norms, &LlsvmConfig { kind, c: 4.0, landmarks: 8, ..Default::default() });
        let large =
            train(&tr, &norms, &LlsvmConfig { kind, c: 4.0, landmarks: 96, ..Default::default() });
        assert!(large.accuracy(&te) + 0.03 >= small.accuracy(&te));
    }
}
