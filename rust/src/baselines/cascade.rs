//! CascadeSVM (Graf et al., NIPS 2005) — the paper's main "other
//! divide-and-conquer" comparator.
//!
//! A binary partition tree over *randomly* split data: leaves train SVMs on
//! their shards; each internal node trains on the union of its children's
//! support vectors; the root model is returned. Unlike DC-SVM there is no
//! data-dependent (kernel kmeans) partition, and false negatives (true SVs
//! discarded below) can never be recovered — the two weaknesses Figure 2
//! demonstrates.

use std::time::Instant;

use crate::cache::KernelContext;
use crate::data::Dataset;
use crate::kernel::{BlockKernel, KernelKind};
use crate::predict::SvmModel;
use crate::solver::{SmoConfig, SmoSolver};
use crate::util::prng::Pcg64;
use crate::util::threadpool::scope_map;

#[derive(Clone, Debug)]
pub struct CascadeConfig {
    pub kind: KernelKind,
    pub c: f64,
    pub eps: f64,
    /// Tree depth: 2^depth leaves.
    pub depth: usize,
    pub cache_bytes: usize,
    pub seed: u64,
    pub threads: usize,
    pub max_iter: usize,
}

impl Default for CascadeConfig {
    fn default() -> Self {
        CascadeConfig {
            kind: KernelKind::Rbf { gamma: 1.0 },
            c: 1.0,
            eps: 1e-3,
            depth: 3,
            cache_bytes: 64 << 20,
            seed: 0,
            threads: 1,
            max_iter: 0,
        }
    }
}

pub struct CascadeResult {
    pub model: SvmModel,
    /// α in the index space of the original dataset (non-root points 0).
    pub alpha: Vec<f64>,
    pub elapsed_s: f64,
    /// SV counts per tree level, leaves first.
    pub level_sv_counts: Vec<usize>,
}

/// Train CascadeSVM. One [`KernelContext`] is shared by every node of the
/// partition tree: rows computed at the leaves stay resident for the upper
/// SV-union solves.
pub fn train(ds: &Dataset, kernel: &dyn BlockKernel, cfg: &CascadeConfig) -> CascadeResult {
    assert_eq!(kernel.kind(), cfg.kind, "kernel backend kind mismatch");
    let t0 = Instant::now();
    let n = ds.len();
    let mut rng = Pcg64::new(cfg.seed);
    let ctx = KernelContext::new(ds, kernel, cfg.cache_bytes).with_threads(cfg.threads);

    // Random leaf shards.
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let leaves = 1usize << cfg.depth;
    let shard = n.div_ceil(leaves);
    let mut groups: Vec<Vec<usize>> = perm
        .chunks(shard.max(1))
        .map(|c| c.to_vec())
        .collect();

    let scfg = SmoConfig {
        c: cfg.c,
        eps: cfg.eps,
        max_iter: cfg.max_iter,
        shrinking: true,
        report_every: 0,
        row_batch: 0,
    };

    let mut alpha = vec![0f64; n];
    let mut level_sv_counts = Vec::new();

    // Cascade upward: each pass trains every group on its members (warm-
    // started with surviving α), keeps only SVs, then merges pairs.
    loop {
        let results: Vec<(Vec<usize>, Vec<f64>)> = {
            let alpha_ref = &alpha;
            let ctx_ref = &ctx;
            let jobs = std::mem::take(&mut groups);
            // Concurrent group solvers split the dispatch thread budget
            // (same guard as dcsvm::train — uncapped nesting would put
            // threads² workers on the machine); the final single-group
            // pass gets the whole budget.
            let concurrent = cfg.threads.min(jobs.len()).max(1);
            ctx.set_threads((cfg.threads / concurrent).max(1));
            scope_map(cfg.threads, jobs, |_, members| {
                let a0: Vec<f64> = members.iter().map(|&i| alpha_ref[i]).collect();
                let warm = a0.iter().any(|&a| a != 0.0);
                // Unsegmented (full-row, global-keyed) views on purpose:
                // cascade re-partitions survivors every merge pass, so
                // pass-p member sets never recur in pass p+1 — per-pass
                // segments would get zero cross-pass hits while gathering
                // a dataset-sized feature copy per pass. Full rows keyed
                // by global index stay resident across merges (the merged
                // solve finds its SV rows already cached).
                let view = ctx_ref.view_unsegmented(&members);
                let res = SmoSolver::new(view, scfg.clone()).solve_warm(
                    if warm { Some(&a0) } else { None },
                    &mut |_| {},
                );
                (members, res.alpha)
            })
        };
        ctx.set_threads(cfg.threads);
        // keep only SVs of each group
        let mut sv_groups: Vec<Vec<usize>> = Vec::with_capacity(results.len());
        alpha.iter_mut().for_each(|a| *a = 0.0);
        let mut svs = 0;
        for (members, sub_alpha) in results {
            let mut kept = Vec::new();
            for (t, &i) in members.iter().enumerate() {
                if sub_alpha[t] > 0.0 {
                    alpha[i] = sub_alpha[t];
                    kept.push(i);
                }
            }
            svs += kept.len();
            sv_groups.push(kept);
        }
        level_sv_counts.push(svs);

        if sv_groups.len() == 1 {
            break;
        }
        // merge pairs
        groups = sv_groups
            .chunks(2)
            .map(|pair| pair.iter().flatten().copied().collect())
            .collect();
    }

    let model = SvmModel::from_ctx_alpha(&ctx, &alpha);
    CascadeResult { model, alpha, elapsed_s: t0.elapsed().as_secs_f64(), level_sv_counts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate_split};
    use crate::kernel::native::NativeKernel;

    #[test]
    fn cascade_learns_reasonably() {
        let (tr, te) = generate_split(&covtype_like(), 600, 200, 31);
        let kind = KernelKind::Rbf { gamma: 16.0 };
        let kern = NativeKernel::new(kind);
        let res = train(
            &tr,
            &kern,
            &CascadeConfig { kind, c: 4.0, depth: 2, ..Default::default() },
        );
        let acc = res.model.accuracy(&te, &kern);
        assert!(acc > 0.75, "cascade acc {acc}");
        // Tree with depth 2 → passes: 4 groups, 2, 1 = 3 levels.
        assert_eq!(res.level_sv_counts.len(), 3);
    }

    #[test]
    fn alpha_support_matches_model() {
        let (tr, _) = generate_split(&covtype_like(), 300, 80, 32);
        let kind = KernelKind::Rbf { gamma: 16.0 };
        let kern = NativeKernel::new(kind);
        let res = train(&tr, &kern, &CascadeConfig { kind, c: 1.0, depth: 2, ..Default::default() });
        let nnz = res.alpha.iter().filter(|&&a| a > 0.0).count();
        assert_eq!(nnz, res.model.num_svs());
        assert!(nnz > 0);
    }

    #[test]
    fn depth_zero_is_plain_svm() {
        let (tr, _) = generate_split(&covtype_like(), 200, 50, 33);
        let kind = KernelKind::Rbf { gamma: 16.0 };
        let kern = NativeKernel::new(kind);
        let res = train(&tr, &kern, &CascadeConfig { kind, c: 1.0, depth: 0, ..Default::default() });
        assert_eq!(res.level_sv_counts.len(), 1);
        let direct = crate::solver::solve_svm(
            &tr,
            &kern,
            SmoConfig { c: 1.0, eps: 1e-3, ..Default::default() },
        );
        assert_eq!(res.model.num_svs(), direct.sv_count);
    }
}
