//! FastFood (Le, Sarlós, Smola — ICML 2013): loglinear-time random Fourier
//! features for the RBF kernel, followed by a linear SVM (dual CD) — exactly
//! the paper's FastFood comparator pipeline.
//!
//! Each block of d' = 2^p features is V x = (1/(σ√d')) · S·H·G·Π·H·B·x,
//! where B is a random ±1 diagonal, H the Walsh–Hadamard transform, Π a
//! random permutation, G a Gaussian diagonal, and S a scaling diagonal
//! matched to the χ-distributed row norms of a Gaussian matrix. Features
//! are [cos(Vx + b)] with random phases b (the standard RFF embedding);
//! E[φ(x)ᵀφ(z)] → exp(−γ‖x−z‖²) with γ = 1/(2σ²).

use std::time::Instant;

use crate::data::Dataset;
use crate::solver::linear::{train_linear, LinearModel, LinearSvmConfig};
use crate::util::prng::Pcg64;

#[derive(Clone, Debug)]
pub struct FastfoodConfig {
    /// RBF width: K = exp(−γ‖x−z‖²).
    pub gamma: f64,
    pub c: f64,
    /// Total Fourier features (rounded up to blocks of the padded dim).
    pub features: usize,
    pub seed: u64,
}

impl Default for FastfoodConfig {
    fn default() -> Self {
        FastfoodConfig { gamma: 1.0, c: 1.0, features: 512, seed: 0 }
    }
}

/// One S·H·G·Π·H·B stack producing d_pad features.
struct FastfoodBlock {
    b: Vec<f32>,     // ±1
    perm: Vec<u32>,
    g: Vec<f32>,
    s: Vec<f32>,
    phase: Vec<f32>, // random phases for the cos embedding
}

pub struct FastfoodModel {
    blocks: Vec<FastfoodBlock>,
    dim: usize,
    d_pad: usize,
    scale: f32, // 1/(σ√d_pad) premultiplier
    feat_scale: f32,
    pub linear: LinearModel,
    pub elapsed_s: f64,
}

/// In-place Walsh–Hadamard transform (length must be a power of two).
pub fn hadamard(v: &mut [f32]) {
    let n = v.len();
    debug_assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let (a, b) = (v[j], v[j + h]);
                v[j] = a + b;
                v[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

impl FastfoodModel {
    fn num_features(&self) -> usize {
        self.blocks.len() * self.d_pad
    }

    /// Map one input row to its Fourier features.
    fn features_row(&self, x: &[f32], out: &mut [f32]) {
        let dp = self.d_pad;
        let mut buf = vec![0f32; dp];
        for (bi, blk) in self.blocks.iter().enumerate() {
            // B·x (zero-padded)
            for t in 0..dp {
                buf[t] = if t < x.len() { x[t] * blk.b[t] } else { 0.0 };
            }
            hadamard(&mut buf);
            // Π
            let permuted: Vec<f32> =
                blk.perm.iter().map(|&p| buf[p as usize]).collect();
            buf.copy_from_slice(&permuted);
            // G
            for t in 0..dp {
                buf[t] *= blk.g[t];
            }
            hadamard(&mut buf);
            // S + global scale, then the cos embedding
            let dst = &mut out[bi * dp..(bi + 1) * dp];
            for t in 0..dp {
                let v = buf[t] * blk.s[t] * self.scale;
                dst[t] = (v + blk.phase[t]).cos() * self.feat_scale;
            }
        }
    }

    /// Feature matrix for a batch ([n, features] row-major).
    pub fn features(&self, x: &[f32], n: usize) -> Vec<f32> {
        let nf = self.num_features();
        let mut out = vec![0f32; n * nf];
        for i in 0..n {
            self.features_row(&x[i * self.dim..(i + 1) * self.dim], &mut out[i * nf..(i + 1) * nf]);
        }
        out
    }

    pub fn predict_batch(&self, x: &[f32], n: usize) -> Vec<i8> {
        let nf = self.num_features();
        let feats = self.features(x, n);
        (0..n).map(|i| self.linear.predict(&feats[i * nf..(i + 1) * nf])).collect()
    }

    pub fn accuracy(&self, test: &Dataset) -> f64 {
        let preds = self.predict_batch(&test.x, test.len());
        crate::metrics::accuracy(&preds, &test.y)
    }

    /// Monte-Carlo kernel estimate ⟨φ(x), φ(z)⟩ (test hook).
    pub fn kernel_estimate(&self, x: &[f32], z: &[f32]) -> f64 {
        let nf = self.num_features();
        let mut fx = vec![0f32; nf];
        let mut fz = vec![0f32; nf];
        self.features_row(x, &mut fx);
        self.features_row(z, &mut fz);
        fx.iter().zip(&fz).map(|(&a, &b)| a as f64 * b as f64).sum()
    }
}

/// Train the FastFood pipeline.
pub fn train(ds: &Dataset, cfg: &FastfoodConfig) -> FastfoodModel {
    let t0 = Instant::now();
    let dim = ds.dim;
    let d_pad = dim.next_power_of_two().max(2);
    let n_blocks = cfg.features.div_ceil(d_pad);
    let mut rng = Pcg64::new(cfg.seed);

    // sigma from gamma: K = exp(−γr²) = exp(−r²/(2σ²)) → σ = 1/√(2γ)
    let sigma = 1.0 / (2.0 * cfg.gamma).sqrt();

    let mut blocks = Vec::with_capacity(n_blocks);
    for _ in 0..n_blocks {
        let b: Vec<f32> = (0..d_pad)
            .map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let mut perm: Vec<u32> = (0..d_pad as u32).collect();
        {
            let mut p64: Vec<usize> = (0..d_pad).collect();
            rng.shuffle(&mut p64);
            for (t, &p) in p64.iter().enumerate() {
                perm[t] = p as u32;
            }
        }
        let g: Vec<f32> = (0..d_pad).map(|_| rng.next_gaussian() as f32).collect();
        // S: match row norms to the χ distribution of a Gaussian matrix:
        // s_i = r_i / ‖G‖_frob where r_i ~ chi(d) approximated by the norm
        // of a fresh Gaussian d-vector.
        let gnorm = (g.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()).sqrt();
        let s: Vec<f32> = (0..d_pad)
            .map(|_| {
                let r: f64 = (0..d_pad)
                    .map(|_| {
                        let v = rng.next_gaussian();
                        v * v
                    })
                    .sum::<f64>()
                    .sqrt();
                (r / gnorm.max(1e-12)) as f32
            })
            .collect();
        let phase: Vec<f32> = (0..d_pad)
            .map(|_| (rng.next_f64() * 2.0 * std::f64::consts::PI) as f32)
            .collect();
        blocks.push(FastfoodBlock { b, perm, g, s, phase });
    }

    let nf = n_blocks * d_pad;
    let mut model = FastfoodModel {
        blocks,
        dim,
        d_pad,
        scale: (1.0 / (sigma * (d_pad as f64).sqrt())) as f32,
        feat_scale: (2.0f64 / nf as f64).sqrt() as f32,
        linear: LinearModel { w: vec![], alpha: vec![], epochs: 0, elapsed_s: 0.0 },
        elapsed_s: 0.0,
    };

    let feats = model.features(&ds.x, ds.len());
    let fds = Dataset::new(feats, ds.y.clone(), nf, format!("{}-fastfood", ds.name));
    model.linear = train_linear(
        &fds,
        &LinearSvmConfig { c: cfg.c, eps: 1e-3, max_epochs: 120, seed: cfg.seed },
    );
    model.elapsed_s = t0.elapsed().as_secs_f64();
    model
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate_split};

    #[test]
    fn hadamard_involution() {
        let mut v = vec![1.0f32, 2.0, -3.0, 0.5, 4.0, -1.0, 0.0, 2.5];
        let orig = v.clone();
        hadamard(&mut v);
        hadamard(&mut v);
        // H·H = n·I
        for (a, b) in v.iter().zip(&orig) {
            assert!((a / 8.0 - b).abs() < 1e-5);
        }
    }

    #[test]
    fn kernel_estimate_close_to_rbf() {
        let (tr, _) = generate_split(&covtype_like(), 50, 10, 61);
        let gamma = 2.0;
        let model = train(&tr, &FastfoodConfig { gamma, features: 4096, ..Default::default() });
        let mut errs = Vec::new();
        for &(i, j) in &[(0usize, 1usize), (2, 3), (10, 20), (7, 30)] {
            let est = model.kernel_estimate(tr.row(i), tr.row(j));
            let d2: f64 = tr
                .row(i)
                .iter()
                .zip(tr.row(j))
                .map(|(&a, &b)| (a as f64 - b as f64).powi(2))
                .sum();
            let truth = (-gamma * d2).exp();
            errs.push((est - truth).abs());
        }
        let mean = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mean < 0.08, "fastfood kernel error {mean} ({errs:?})");
    }

    #[test]
    fn fastfood_learns() {
        let (tr, te) = generate_split(&covtype_like(), 800, 250, 62);
        let model = train(
            &tr,
            &FastfoodConfig { gamma: 16.0, c: 4.0, features: 256, ..Default::default() },
        );
        let acc = model.accuracy(&te);
        assert!(acc > 0.65, "fastfood acc {acc}");
    }
}
