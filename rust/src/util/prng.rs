//! PCG64 pseudo-random number generator.
//!
//! The offline environment has no `rand` crate, so the framework carries its
//! own PRNG. PCG-XSL-RR-128/64 (O'Neill 2014): a 128-bit LCG state with an
//! xor-shift-low + random-rotate output permutation. Deterministic and
//! seedable — every experiment in EXPERIMENTS.md records its seed, and all
//! tests/benches are exactly reproducible.

const MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// PCG64 generator. `Clone` lets callers fork an identical stream for
/// reference re-computation in tests.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

impl Pcg64 {
    /// Create a generator from a seed and stream id. Distinct streams are
    /// statistically independent (distinct odd increments).
    pub fn new_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(MULT).wrapping_add(rng.inc);
        rng
    }

    pub fn new(seed: u64) -> Self {
        Self::new_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; fine for data generation).
    pub fn next_gaussian(&mut self) -> f64 {
        // Avoid ln(0).
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        if k * 3 > n {
            // Dense: shuffle a full index vector, take a prefix.
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // Sparse: rejection into a sorted set.
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.below(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(8);
        assert_ne!(Pcg64::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_roughly() {
        let mut rng = Pcg64::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::new(3);
        let n = 40_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.next_gaussian();
            s += g;
            s2 += g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Pcg64::new(4);
        for &(n, k) in &[(10, 10), (1000, 5), (50, 25)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new_stream(1, 1);
        let mut b = Pcg64::new_stream(1, 2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }
}
