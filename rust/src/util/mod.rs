//! Self-contained substrates the offline environment forces us to carry:
//! PRNG (`prng`), JSON (`json`), thread pool (`threadpool`), timers
//! (`timer`), logging (`logging`), a mini property-test harness
//! (`proptest`), the shared NDJSON wire layer (`wire`), and declarative
//! CLI flag tables (`flags`).

pub mod flags;
pub mod json;
pub mod logging;
pub mod prng;
pub mod proptest;
pub mod threadpool;
pub mod timer;
pub mod wire;
