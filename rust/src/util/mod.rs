//! Self-contained substrates the offline environment forces us to carry:
//! PRNG (`prng`), JSON (`json`), thread pool (`threadpool`), timers
//! (`timer`), logging (`logging`), and a mini property-test harness
//! (`proptest`).

pub mod json;
pub mod logging;
pub mod prng;
pub mod proptest;
pub mod threadpool;
pub mod timer;
