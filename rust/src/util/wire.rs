//! Shared newline-delimited-JSON wire layer.
//!
//! One line, one message: every TCP protocol in this crate — the serve
//! front-end (`serving/transport.rs`) and the distributed worker protocol
//! (`distributed/`) — frames messages as newline-terminated JSON objects.
//! This module owns the framing so the two protocols cannot drift:
//!
//! - [`Codec`] — a reader/writer pair with the line-accumulation loop:
//!   reads poll on a timeout (so a blocked reader can notice a shutdown
//!   flag), partial reads survive across poll ticks, line length is
//!   capped ([`MAX_FRAME_BYTES`]), and UTF-8 is validated once per
//!   complete line. Both directions count bytes ([`Codec::bytes_in`] /
//!   [`Codec::bytes_out`]) — the distributed coordinator's `comm_bytes`
//!   counter is exactly these totals.
//! - [`Frame`] — what one read attempt produced: a complete [`Frame::Line`],
//!   end-of-stream, an idle poll tick, an over-cap line, or invalid UTF-8.
//!   The *consumer* decides policy (error object? close? retry?); the codec
//!   only frames.
//! - [`with_id`] / [`error_response`] — the structured response/error
//!   object builders shared by every protocol (PROTOCOL.md).
//!
//! The loop here is the one the PR-3 socket transport proved out; the
//! serve transport's behavior on top of it is bit-identical to the
//! pre-extraction code (`tests/serve_socket.rs` pins it).

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Hard cap on one wire line. A peer exceeding it gets a structured error
/// and its connection is closed (line framing is unrecoverable mid-line),
/// so a single malicious or buggy peer cannot grow a read buffer without
/// bound (PROTOCOL.md §2).
pub const MAX_FRAME_BYTES: usize = 8 << 20;

/// How often a blocking read wakes to let the caller re-check its
/// shutdown/abort flag: bounds how long an idle connection can delay a
/// graceful shutdown (PROTOCOL.md §2).
pub const READ_POLL: Duration = Duration::from_millis(250);

/// What one [`Codec::read_frame`] call produced. Only `Line` carries a
/// message; the other variants are connection states the caller turns
/// into policy (close, error object, re-check a flag and retry).
#[derive(Debug)]
pub enum Frame {
    /// One complete line, UTF-8 valid, trailing newline preserved (a final
    /// line at EOF may lack it). May be blank — callers skip empty lines.
    Line(String),
    /// Clean end of stream between lines.
    Eof,
    /// A poll tick fired with no complete line; partial bytes stay
    /// buffered in the codec. Re-check shutdown flags and call again.
    Idle,
    /// The line exceeded the codec's byte cap. The buffer was discarded —
    /// framing is lost mid-line, so the connection should close after an
    /// error response.
    Overflow,
    /// A complete line arrived but was not valid UTF-8. The buffer was
    /// discarded; framing is intact, so the connection stays usable.
    NotUtf8,
}

/// A framed reader/writer pair. `read_frame` accumulates raw bytes (NOT a
/// `String`: `read_line`'s UTF-8 guard would discard bytes already
/// consumed from the socket if a read-timeout tick fired while the buffer
/// ended mid-multibyte character; `read_until` keeps every consumed byte
/// across ticks), `write_json` writes one message per line, and both
/// directions are byte-counted.
pub struct Codec<R, W> {
    reader: R,
    writer: W,
    buf: Vec<u8>,
    max_bytes: usize,
    bytes_in: u64,
    bytes_out: u64,
}

/// The [`Codec`] shape every TCP protocol in the crate uses ([`tcp_codec`]).
pub type TcpCodec = Codec<BufReader<TcpStream>, TcpStream>;

/// Wrap a TCP stream in a codec: the read half polls on [`READ_POLL`]
/// (errors setting the timeout are ignored — the loop then simply blocks,
/// which only delays shutdown detection), the write half is the stream
/// itself.
pub fn tcp_codec(stream: TcpStream) -> io::Result<TcpCodec> {
    let read_half = stream.try_clone()?;
    let _ = read_half.set_read_timeout(Some(READ_POLL));
    Ok(Codec::new(BufReader::new(read_half), stream))
}

impl<R: BufRead, W: Write> Codec<R, W> {
    /// A codec over arbitrary reader/writer halves, capped at
    /// [`MAX_FRAME_BYTES`] per line.
    pub fn new(reader: R, writer: W) -> Codec<R, W> {
        Codec {
            reader,
            writer,
            buf: Vec::new(),
            max_bytes: MAX_FRAME_BYTES,
            bytes_in: 0,
            bytes_out: 0,
        }
    }

    /// Override the per-line byte cap (`usize::MAX` effectively uncaps —
    /// the blocking client uses that to trust its own server).
    pub fn with_max_bytes(mut self, max_bytes: usize) -> Codec<R, W> {
        self.max_bytes = max_bytes;
        self
    }

    /// Read until one [`Frame`] is available. `Idle` (a read-timeout tick
    /// with no complete line) returns with partial bytes still buffered,
    /// so the caller can re-check its shutdown flag and call again without
    /// losing data. `Err` is a real transport error — the connection is
    /// gone.
    pub fn read_frame(&mut self) -> io::Result<Frame> {
        loop {
            // Budget: one byte past the remaining cap, so an over-long
            // line is detected (len > max) without unbounded buffering.
            let budget =
                self.max_bytes.saturating_sub(self.buf.len()).saturating_add(1) as u64;
            match self.reader.by_ref().take(budget).read_until(b'\n', &mut self.buf) {
                Ok(0) => {
                    if self.buf.is_empty() {
                        return Ok(Frame::Eof); // clean EOF between lines
                    }
                    return Ok(self.take_line()); // final line, no newline
                }
                Ok(n) => {
                    self.bytes_in += n as u64;
                    if self.buf.len() > self.max_bytes {
                        self.buf.clear(); // framing lost mid-line
                        return Ok(Frame::Overflow);
                    }
                    if self.buf.ends_with(b"\n") {
                        return Ok(self.take_line());
                    }
                    // No newline and under budget: EOF mid-line — the next
                    // read returns Ok(0) and serves this final line.
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
                    ) =>
                {
                    return Ok(Frame::Idle);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// [`Codec::read_frame`] with an absolute deadline: idle poll ticks
    /// are consumed internally (partial bytes stay buffered across them)
    /// until a complete frame arrives or the deadline passes — `Ok(None)`
    /// means the deadline expired with no complete frame. The deadline's
    /// granularity is one [`READ_POLL`] tick; `Idle` never surfaces to the
    /// caller. This is the primitive round/request deadlines are built on
    /// (distributed `--round-timeout`, serve `--request-timeout`).
    pub fn read_frame_deadline(&mut self, deadline: Instant) -> io::Result<Option<Frame>> {
        loop {
            match self.read_frame()? {
                Frame::Idle => {
                    if Instant::now() >= deadline {
                        return Ok(None);
                    }
                }
                frame => return Ok(Some(frame)),
            }
        }
    }

    fn take_line(&mut self) -> Frame {
        match String::from_utf8(std::mem::take(&mut self.buf)) {
            Ok(line) => Frame::Line(line),
            Err(_) => Frame::NotUtf8,
        }
    }

    /// Write one message as one line (`{json}\n`) and flush.
    pub fn write_json(&mut self, msg: &Json) -> io::Result<()> {
        let mut text = msg.to_string();
        text.push('\n');
        self.writer.write_all(text.as_bytes())?;
        self.writer.flush()?;
        self.bytes_out += text.len() as u64;
        Ok(())
    }

    /// Bytes consumed from the reader (including partial lines and
    /// discarded over-cap/invalid lines).
    pub fn bytes_in(&self) -> u64 {
        self.bytes_in
    }

    /// Bytes successfully written (messages plus their newlines).
    pub fn bytes_out(&self) -> u64 {
        self.bytes_out
    }
}

// ---------------------------------------------------------------------------
// Structured response objects (shared by every line protocol).

/// Response-object builder applying the id-echo rule once: the request's
/// `id` is included iff the request carried one (absent → no `"id"` key,
/// never a spurious null).
pub fn with_id(id: Json, rest: Vec<(&str, Json)>) -> Json {
    let mut pairs = Vec::with_capacity(rest.len() + 1);
    if !matches!(id, Json::Null) {
        pairs.push(("id", id));
    }
    pairs.extend(rest);
    Json::obj(pairs)
}

/// The structured error object every protocol answers malformed input
/// with: `{"error": {"code": ..., "message": ...}}`, id echoed per
/// [`with_id`]. Codes are protocol-specific (PROTOCOL.md catalogues
/// them).
pub fn error_response(id: Json, code: &str, message: &str) -> Json {
    with_id(
        id,
        vec![(
            "error",
            Json::obj(vec![("code", Json::from(code)), ("message", Json::from(message))]),
        )],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn codec_over(input: &[u8]) -> Codec<Cursor<Vec<u8>>, Vec<u8>> {
        Codec::new(Cursor::new(input.to_vec()), Vec::new())
    }

    #[test]
    fn frames_lines_then_eof() {
        let mut c = codec_over(b"{\"a\": 1}\n\n{\"b\": 2}");
        let Ok(Frame::Line(l1)) = c.read_frame() else { panic!() };
        assert_eq!(l1, "{\"a\": 1}\n");
        let Ok(Frame::Line(blank)) = c.read_frame() else { panic!() };
        assert_eq!(blank, "\n", "blank lines are frames; callers skip them");
        // Final line without a trailing newline is still served...
        let Ok(Frame::Line(l2)) = c.read_frame() else { panic!() };
        assert_eq!(l2, "{\"b\": 2}");
        // ...and the stream then reports clean EOF.
        assert!(matches!(c.read_frame(), Ok(Frame::Eof)));
        assert_eq!(c.bytes_in(), 18);
    }

    #[test]
    fn overflow_discards_and_reports() {
        let big = vec![b'x'; 64];
        let mut c = codec_over(&big).with_max_bytes(16);
        assert!(matches!(c.read_frame(), Ok(Frame::Overflow)));
    }

    #[test]
    fn invalid_utf8_is_survivable() {
        let mut c = codec_over(b"\xff\xfe\n{\"ok\": true}\n");
        assert!(matches!(c.read_frame(), Ok(Frame::NotUtf8)));
        // Framing is intact: the next line still parses.
        let Ok(Frame::Line(l)) = c.read_frame() else { panic!() };
        assert_eq!(l.trim_end(), "{\"ok\": true}");
    }

    /// A reader that never has data, like a socket whose read timeout
    /// keeps firing.
    struct AlwaysBlocks;
    impl std::io::Read for AlwaysBlocks {
        fn read(&mut self, _: &mut [u8]) -> io::Result<usize> {
            Err(io::Error::from(io::ErrorKind::WouldBlock))
        }
    }

    #[test]
    fn read_frame_deadline_expires_on_idle_and_serves_ready_lines() {
        // A peer that produces nothing: the deadline expires as Ok(None).
        let mut c = Codec::new(BufReader::new(AlwaysBlocks), Vec::new());
        let t0 = std::time::Instant::now();
        let got = c.read_frame_deadline(t0 + Duration::from_millis(20)).unwrap();
        assert!(got.is_none(), "idle reader must time out");
        assert!(t0.elapsed() >= Duration::from_millis(20));

        // A ready line is served immediately, well before the deadline.
        let mut c = codec_over(b"{\"ok\": true}\n");
        let got = c
            .read_frame_deadline(std::time::Instant::now() + Duration::from_secs(60))
            .unwrap();
        assert!(matches!(got, Some(Frame::Line(l)) if l.trim_end() == "{\"ok\": true}"));
        // EOF is a frame, not a timeout.
        let got = c
            .read_frame_deadline(std::time::Instant::now() + Duration::from_secs(60))
            .unwrap();
        assert!(matches!(got, Some(Frame::Eof)));
    }

    #[test]
    fn write_json_counts_bytes() {
        let mut c = codec_over(b"");
        let msg = Json::obj(vec![("ok", Json::from(true))]);
        c.write_json(&msg).unwrap();
        let text = String::from_utf8(c.writer.clone()).unwrap();
        assert_eq!(text, format!("{msg}\n"));
        assert_eq!(c.bytes_out(), text.len() as u64);
    }

    #[test]
    fn id_echo_rule() {
        let r = with_id(Json::from(7usize), vec![("ok", Json::from(true))]);
        assert_eq!(r.get("id").as_usize(), Some(7));
        let r = with_id(Json::Null, vec![("ok", Json::from(true))]);
        assert_eq!(r.get("id"), &Json::Null);
        let e = error_response(Json::from("q"), "parse", "nope");
        assert_eq!(e.get("error").get("code").as_str(), Some("parse"));
        assert_eq!(e.get("error").get("message").as_str(), Some("nope"));
        assert_eq!(e.get("id").as_str(), Some("q"));
    }
}
