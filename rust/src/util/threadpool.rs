//! Scoped thread pool for per-cluster subproblem solving.
//!
//! No `rayon`/`tokio` offline, so the framework carries a small
//! work-stealing-free pool: a fixed set of workers pulling indexed jobs from
//! a shared queue. The API is deliberately minimal — `scope_map` runs one
//! closure per item and returns outputs in item order, which is exactly what
//! the DC-SVM divide step needs (solve k cluster subproblems, keep results
//! indexed by cluster).
//!
//! Determinism: outputs depend only on per-item computation, never on
//! scheduling order, so results are identical for any `threads` value —
//! property-tested in dcsvm tests.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: the `DCSVM_THREADS` env var if set,
/// otherwise available parallelism (1 in this container).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("DCSVM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to each item of `items` on up to `threads` worker threads;
/// returns outputs in input order. Panics in workers propagate.
pub fn scope_map<T, R, F>(threads: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        // Fast path, also keeps stack traces simple under tests.
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let next = AtomicUsize::new(0);
    let inputs: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let outputs: Vec<Mutex<Option<R>>> =
        (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = inputs[i].lock().unwrap().take().unwrap();
                let out = f(i, item);
                *outputs[i].lock().unwrap() = Some(out);
            });
        }
    });

    outputs
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker produced no output"))
        .collect()
}

/// Parallel-for over `0..n` chunked ranges; used for bulk array work
/// (e.g. assigning n points to clusters).
pub fn par_chunks<F>(threads: usize, n: usize, min_chunk: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1);
    if n == 0 {
        return;
    }
    let chunk = ((n + threads - 1) / threads).max(min_chunk.max(1));
    let ranges: Vec<_> = (0..n)
        .step_by(chunk)
        .map(|lo| lo..(lo + chunk).min(n))
        .collect();
    if ranges.len() == 1 {
        f(ranges.into_iter().next().unwrap());
        return;
    }
    std::thread::scope(|s| {
        for r in ranges {
            let f = &f;
            s.spawn(move || f(r));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let items: Vec<usize> = (0..97).collect();
        let out = scope_map(4, items, |i, x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..97).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn same_result_any_thread_count() {
        let compute = |threads: usize| {
            scope_map(threads, (0..50).collect::<Vec<u64>>(), |_, x| {
                // some non-trivial per-item work
                (0..x).map(|v| v.wrapping_mul(2654435761)).sum::<u64>()
            })
        };
        let base = compute(1);
        for t in [2, 3, 8] {
            assert_eq!(compute(t), base);
        }
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<u32> = scope_map(4, Vec::<u32>::new(), |_, x| x);
        assert!(out.is_empty());
        assert_eq!(scope_map(4, vec![9], |_, x| x + 1), vec![10]);
    }

    #[test]
    fn par_chunks_covers_all() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        par_chunks(4, 1000, 16, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}
