//! Scoped thread pool for per-cluster subproblem solving.
//!
//! No `rayon`/`tokio` offline, so the framework carries a small
//! work-stealing-free pool: a fixed set of workers pulling indexed jobs from
//! a shared queue. The API is deliberately minimal — `scope_map` runs one
//! closure per item and returns outputs in item order, which is exactly what
//! the DC-SVM divide step needs (solve k cluster subproblems, keep results
//! indexed by cluster) — plus [`WorkQueue`], the bounded open-ended
//! counterpart for work discovered at runtime (the serve transport's
//! accepted connections).
//!
//! Determinism: outputs depend only on per-item computation, never on
//! scheduling order, so results are identical for any `threads` value —
//! property-tested in dcsvm tests.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Number of worker threads to use: the `DCSVM_THREADS` env var if set,
/// otherwise available parallelism (1 in this container).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("DCSVM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to each item of `items` on up to `threads` worker threads;
/// returns outputs in input order. Panics in workers propagate.
pub fn scope_map<T, R, F>(threads: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        // Fast path, also keeps stack traces simple under tests.
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let next = AtomicUsize::new(0);
    let inputs: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let outputs: Vec<Mutex<Option<R>>> =
        (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = inputs[i].lock().unwrap().take().unwrap();
                let out = f(i, item);
                *outputs[i].lock().unwrap() = Some(out);
            });
        }
    });

    outputs
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker produced no output"))
        .collect()
}

/// Parallel-for over `0..n` chunked ranges; used for bulk array work
/// (e.g. assigning n points to clusters).
pub fn par_chunks<F>(threads: usize, n: usize, min_chunk: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1);
    if n == 0 {
        return;
    }
    let chunk = n.div_ceil(threads).max(min_chunk.max(1));
    let ranges: Vec<_> = (0..n)
        .step_by(chunk)
        .map(|lo| lo..(lo + chunk).min(n))
        .collect();
    if ranges.len() == 1 {
        f(ranges.into_iter().next().unwrap());
        return;
    }
    std::thread::scope(|s| {
        for r in ranges {
            let f = &f;
            s.spawn(move || f(r));
        }
    });
}

/// Bounded multi-producer/multi-consumer job queue (Mutex + Condvar): the
/// handoff between a producer that discovers work and a fixed set of worker
/// threads that drain it. The serve transport uses one to pass accepted
/// TCP connections from the accept loop to its connection workers; the
/// bound gives backpressure (bounded in-flight work) instead of unbounded
/// queueing.
///
/// Semantics:
/// - [`WorkQueue::push`] blocks while the queue is at capacity; returns
///   `false` (dropping the item) once the queue is closed.
/// - [`WorkQueue::pop`] blocks until an item arrives; after
///   [`WorkQueue::close`] it drains the remaining items, then returns
///   `None` — workers exit by `while let Some(job) = q.pop()`.
/// - [`WorkQueue::close`] is idempotent and wakes every blocked caller.
pub struct WorkQueue<T> {
    inner: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> WorkQueue<T> {
    /// A queue admitting at most `cap.max(1)` pending items.
    pub fn new(cap: usize) -> WorkQueue<T> {
        WorkQueue {
            inner: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Enqueue one item, blocking while the queue is full. Returns `false`
    /// if the queue was closed (the item is dropped).
    pub fn push(&self, item: T) -> bool {
        let mut st = self.inner.lock().unwrap();
        loop {
            if st.closed {
                return false;
            }
            if st.items.len() < self.cap {
                break;
            }
            st = self.not_full.wait(st).unwrap();
        }
        st.items.push_back(item);
        self.not_empty.notify_one();
        true
    }

    /// Dequeue one item, blocking until one arrives. Returns `None` once
    /// the queue is closed AND drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.inner.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Close the queue: pending items remain poppable, further pushes are
    /// refused, and every blocked push/pop wakes.
    pub fn close(&self) {
        let mut st = self.inner.lock().unwrap();
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Items currently queued (racy under concurrency; for tests/metrics).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().items.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let items: Vec<usize> = (0..97).collect();
        let out = scope_map(4, items, |i, x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..97).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn same_result_any_thread_count() {
        let compute = |threads: usize| {
            scope_map(threads, (0..50).collect::<Vec<u64>>(), |_, x| {
                // some non-trivial per-item work
                (0..x).map(|v| v.wrapping_mul(2654435761)).sum::<u64>()
            })
        };
        let base = compute(1);
        for t in [2, 3, 8] {
            assert_eq!(compute(t), base);
        }
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<u32> = scope_map(4, Vec::<u32>::new(), |_, x| x);
        assert!(out.is_empty());
        assert_eq!(scope_map(4, vec![9], |_, x| x + 1), vec![10]);
    }

    #[test]
    fn work_queue_delivers_every_item_once() {
        use std::sync::atomic::AtomicU64;
        let q: WorkQueue<usize> = WorkQueue::new(4);
        let seen: Vec<AtomicU64> = (0..200).map(|_| AtomicU64::new(0)).collect();
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    while let Some(i) = q.pop() {
                        seen[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
            for i in 0..200 {
                assert!(q.push(i), "queue closed early");
            }
            q.close();
        });
        assert!(seen.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn work_queue_close_drains_then_ends() {
        let q: WorkQueue<u32> = WorkQueue::new(8);
        assert!(q.push(1));
        assert!(q.push(2));
        q.close();
        assert!(!q.push(3), "push after close must be refused");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None, "pop stays None after drain");
        assert!(q.is_empty());
    }

    #[test]
    fn work_queue_bounds_pending_items() {
        let q: WorkQueue<u32> = WorkQueue::new(2);
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.len(), 2);
        // A third push must block until a consumer pops.
        std::thread::scope(|s| {
            s.spawn(|| {
                assert!(q.push(3)); // blocks until the pop below
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            assert_eq!(q.pop(), Some(1));
            assert_eq!(q.pop(), Some(2));
            assert_eq!(q.pop(), Some(3));
        });
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn par_chunks_covers_all() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        par_chunks(4, 1000, 16, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}
