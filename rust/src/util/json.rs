//! Minimal JSON parser + writer.
//!
//! The offline environment has no `serde`; configs (rust/src/config),
//! the artifact manifest (runtime), and experiment result files all go
//! through this module. Supports the full JSON grammar except `\u` escapes
//! beyond the BMP surrogate pairs (accepted, decoded).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so emitted JSON is
/// deterministic — results files diff cleanly across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 { Some(n as usize) } else { None }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; Null on miss so lookups chain.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json { Json::Num(v) }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json { Json::Num(v as f64) }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json { Json::Str(v.to_string()) }
}
impl From<String> for Json {
    fn from(v: String) -> Json { Json::Str(v) }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json { Json::Bool(v) }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: copy raw continuation bytes.
                    let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            v = v * 16
                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"obj":{"k":-3}}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        let raw = Json::parse("\"é😀\"").unwrap();
        assert_eq!(raw.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn reads_real_manifest_shape() {
        let m = r#"{"artifacts":{"rbf_block_wide":{"file":"rbf_block_wide.hlo.txt","inputs":[[256,128],[1024,128],[256],[1024],[1]]}},"d_pad":128}"#;
        let v = Json::parse(m).unwrap();
        assert_eq!(v.get("d_pad").as_usize(), Some(128));
        let inputs = v.get("artifacts").get("rbf_block_wide").get("inputs");
        assert_eq!(inputs.as_arr().unwrap()[0].as_arr().unwrap()[0].as_usize(), Some(256));
    }
}
