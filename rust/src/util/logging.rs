//! Leveled stderr logger (no `log`/`env_logger` facade needed).
//!
//! Level is process-global, set once from the CLI (`-v`/`-q`) or the
//! `DCSVM_LOG` env var (error|warn|info|debug|trace). Benches default to
//! `warn` so timing output stays clean.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn init_from_env() {
    if let Ok(v) = std::env::var("DCSVM_LOG") {
        set_level(match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        });
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

#[doc(hidden)]
pub fn log_at(l: Level, args: std::fmt::Arguments) {
    if enabled(l) {
        eprintln!("[{:5}] {}", format!("{l:?}").to_ascii_lowercase(), args);
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log_at($crate::util::logging::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logging::log_at($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logging::log_at($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! trace {
    ($($t:tt)*) => { $crate::util::logging::log_at($crate::util::logging::Level::Trace, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
