//! Mini property-testing harness (no `proptest` crate offline).
//!
//! `check(name, cases, |rng| ...)` runs a property closure over `cases`
//! independently seeded PRNGs. On failure it reports the failing case's seed
//! so the case replays deterministically with `replay(seed, f)`. No
//! shrinking — properties here are written over small sizes already.

use super::prng::Pcg64;

/// Run `f` for `cases` random cases. Each case gets a fresh `Pcg64` seeded
/// from `(name hash, case index)`. `f` returns `Err(msg)` to fail.
pub fn check<F>(name: &str, cases: u64, f: F)
where
    F: Fn(&mut Pcg64) -> Result<(), String>,
{
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Pcg64::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, f: F)
where
    F: Fn(&mut Pcg64) -> Result<(), String>,
{
    let mut rng = Pcg64::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replayed property failed (seed {seed:#x}): {msg}");
    }
}

/// Assert helper for properties: formats a labelled failure message.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 50, |rng| {
            let a = rng.next_f64();
            let b = rng.next_f64();
            prop_assert!((a + b - (b + a)).abs() < 1e-15, "a={a} b={b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn cases_get_distinct_seeds() {
        use std::cell::RefCell;
        let seen = RefCell::new(Vec::new());
        check("distinct", 10, |rng| {
            seen.borrow_mut().push(rng.next_u64());
            Ok(())
        });
        let v = seen.borrow();
        let set: std::collections::HashSet<_> = v.iter().collect();
        assert_eq!(set.len(), v.len());
    }
}
