//! Declarative CLI flag specs — one table per subcommand.
//!
//! `dcsvm serve` proved the pattern out: a single `&[FlagSpec]` table is
//! the source of truth for the usage text, the README flag table, AND the
//! strict parser (unknown flags rejected before a value is demanded, so
//! `--verbose` errors as unknown rather than "needs a value"). This
//! module generalizes it so `serve`, `update`, `worker`, and the
//! distributed `train` flags all render and parse from one definition
//! each — `tests/docs_sync.rs` and `tests/cli_roundtrip.rs` pin both
//! sides.

use anyhow::{anyhow, bail, Result};

/// One CLI flag: name, value placeholder, default, one-line help.
pub struct FlagSpec {
    pub flag: &'static str,
    pub value: &'static str,
    pub default: &'static str,
    pub help: &'static str,
}

/// One README flag-table row, rendered from a [`FlagSpec`]. README.md must
/// contain this exact line for every flag of a documented table
/// (`tests/docs_sync.rs`).
pub fn readme_row(f: &FlagSpec) -> String {
    format!("| `{} {}` | {} | {} |", f.flag, f.value, f.default, f.help)
}

/// A subcommand's complete flag surface: the command name (error-message
/// prefix), the required-flags fragment of the usage line, and the table.
pub struct FlagSet {
    pub cmd: &'static str,
    /// Rendered between the command and `[flags]` in the usage line, e.g.
    /// `"--model FILE"`; empty when every flag is optional.
    pub required: &'static str,
    pub flags: &'static [FlagSpec],
}

impl FlagSet {
    /// The `dcsvm {cmd} --help` text, rendered from the table.
    pub fn usage(&self) -> String {
        let mut s = if self.required.is_empty() {
            format!("usage: dcsvm {} [flags]\n", self.cmd)
        } else {
            format!("usage: dcsvm {} {} [flags]\n", self.cmd, self.required)
        };
        for f in self.flags {
            let head = format!("{} {}", f.flag, f.value);
            s.push_str(&format!("  {head:<26} {}  [{}]\n", f.help, f.default));
        }
        s
    }

    /// Strict `--key value` parse against the table: `Ok(None)` when help
    /// was requested (the caller prints [`Self::usage`]), otherwise the
    /// `(flag, value)` pairs in argument order. Unknown flags are rejected
    /// BEFORE a value is demanded; a known flag with no value errors as
    /// such.
    pub fn parse<'a>(&self, args: &'a [String]) -> Result<Option<Vec<(&'static str, &'a str)>>> {
        let mut pairs = Vec::with_capacity(args.len() / 2);
        let mut i = 0;
        while i < args.len() {
            let key = args[i].as_str();
            if matches!(key, "--help" | "-h" | "help") {
                return Ok(None);
            }
            let Some(spec) = self.flags.iter().find(|f| f.flag == key) else {
                bail!("{}: unknown flag '{key}'\n{}", self.cmd, self.usage());
            };
            let Some(val) = args.get(i + 1) else {
                bail!("{}: flag {key} needs a value\n{}", self.cmd, self.usage());
            };
            pairs.push((spec.flag, val.as_str()));
            i += 2;
        }
        Ok(Some(pairs))
    }

    // --- shared value validators (error text embeds cmd + usage) ---

    /// A positive integer (≥ 1).
    pub fn positive(&self, flag: &str, val: &str) -> Result<usize> {
        let n: usize = val.parse().map_err(|_| {
            anyhow!(
                "{}: {flag} needs a positive integer, got '{val}'\n{}",
                self.cmd,
                self.usage()
            )
        })?;
        if n == 0 {
            bail!("{}: {flag} must be at least 1\n{}", self.cmd, self.usage());
        }
        Ok(n)
    }

    /// A non-negative integer (0 allowed — "unlimited"/"default" counts).
    pub fn count(&self, flag: &str, val: &str) -> Result<usize> {
        val.parse().map_err(|_| {
            anyhow!(
                "{}: {flag} needs a non-negative integer, got '{val}'\n{}",
                self.cmd,
                self.usage()
            )
        })
    }

    /// A finite positive float.
    pub fn positive_f(&self, flag: &str, val: &str) -> Result<f64> {
        let f: f64 = val.parse().map_err(|_| {
            anyhow!(
                "{}: {flag} needs a positive number, got '{val}'\n{}",
                self.cmd,
                self.usage()
            )
        })?;
        if !f.is_finite() || f <= 0.0 {
            bail!("{}: {flag} must be positive\n{}", self.cmd, self.usage());
        }
        Ok(f)
    }

    /// A `true`/`false` literal.
    pub fn boolean(&self, flag: &str, val: &str) -> Result<bool> {
        val.parse().map_err(|_| {
            anyhow!(
                "{}: {flag} needs true or false, got '{val}'\n{}",
                self.cmd,
                self.usage()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SET: FlagSet = FlagSet {
        cmd: "demo",
        required: "--in FILE",
        flags: &[
            FlagSpec { flag: "--in", value: "FILE", default: "required", help: "input file" },
            FlagSpec { flag: "--n", value: "N", default: "4", help: "a count" },
        ],
    };

    fn args(a: &[&str]) -> Vec<String> {
        a.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn usage_lists_every_flag() {
        let u = SET.usage();
        assert!(u.starts_with("usage: dcsvm demo --in FILE [flags]\n"), "{u}");
        for f in SET.flags {
            assert!(u.contains(f.flag) && u.contains(f.help), "{u}");
        }
    }

    #[test]
    fn parse_is_strict_and_ordered() {
        let a = args(&["--n", "2", "--in", "x"]);
        let pairs = SET.parse(&a).unwrap().unwrap();
        assert_eq!(pairs, vec![("--n", "2"), ("--in", "x")]);
        assert!(SET.parse(&args(&["--help"])).unwrap().is_none());
        // Unknown flags are rejected before a value is demanded.
        let e = SET.parse(&args(&["--bogus"])).unwrap_err().to_string();
        assert!(e.contains("demo: unknown flag '--bogus'"), "{e}");
        assert!(e.contains("usage:"), "{e}");
        let e = SET.parse(&args(&["--n"])).unwrap_err().to_string();
        assert!(e.contains("demo: flag --n needs a value"), "{e}");
    }

    #[test]
    fn validators_name_flag_and_print_usage() {
        assert_eq!(SET.positive("--n", "3").unwrap(), 3);
        let e = SET.positive("--n", "0").unwrap_err().to_string();
        assert!(e.contains("--n must be at least 1") && e.contains("usage:"), "{e}");
        let e = SET.positive("--n", "abc").unwrap_err().to_string();
        assert!(e.contains("positive integer"), "{e}");
        assert_eq!(SET.count("--n", "0").unwrap(), 0);
        assert_eq!(SET.positive_f("--n", "0.5").unwrap(), 0.5);
        assert!(SET.positive_f("--n", "-1").is_err());
        assert!(SET.positive_f("--n", "inf").is_err());
        assert!(SET.boolean("--n", "true").unwrap());
        let e = SET.boolean("--n", "yes").unwrap_err().to_string();
        assert!(e.contains("needs true or false"), "{e}");
    }
}
