//! Wall-clock timers and per-phase accounting.
//!
//! The paper's Table 6 reports clustering-vs-training time per level and
//! Figures 2–4 are time-series; `PhaseTimer` provides named accumulators and
//! `Stopwatch` provides trace timestamps relative to a run's start.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch anchored at construction.
#[derive(Clone, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Named phase accumulators (e.g. "clustering.l3", "training.l3").
#[derive(Default, Debug, Clone)]
pub struct PhaseTimer {
    acc: BTreeMap<String, Duration>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `name`, accumulating across calls.
    pub fn time<R>(&mut self, name: &str, f: impl FnOnce() -> R) -> R {
        let t = Instant::now();
        let r = f();
        self.add(name, t.elapsed());
        r
    }

    pub fn add(&mut self, name: &str, d: Duration) {
        *self.acc.entry(name.to_string()).or_default() += d;
    }

    pub fn secs(&self, name: &str) -> f64 {
        self.acc.get(name).map(|d| d.as_secs_f64()).unwrap_or(0.0)
    }

    pub fn phases(&self) -> impl Iterator<Item = (&str, f64)> {
        self.acc.iter().map(|(k, v)| (k.as_str(), v.as_secs_f64()))
    }

    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.acc {
            *self.acc.entry(k.clone()).or_default() += *v;
        }
    }
}

/// A recorded (time, value) series, e.g. objective vs seconds (Figure 3).
#[derive(Default, Debug, Clone)]
pub struct Series {
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn push(&mut self, t: f64, v: f64) {
        self.points.push((t, v));
    }
    pub fn last_value(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }
    /// Earliest time at which value <= threshold (for "time to reach X").
    pub fn time_to_reach_below(&self, threshold: f64) -> Option<f64> {
        self.points.iter().find(|&&(_, v)| v <= threshold).map(|&(t, _)| t)
    }
    pub fn to_csv(&self, header: (&str, &str)) -> String {
        let mut s = format!("{},{}\n", header.0, header.1);
        for &(t, v) in &self.points {
            s.push_str(&format!("{t:.6},{v:.8}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_timer_accumulates() {
        let mut pt = PhaseTimer::new();
        pt.add("a", Duration::from_millis(10));
        pt.add("a", Duration::from_millis(15));
        pt.add("b", Duration::from_millis(5));
        assert!((pt.secs("a") - 0.025).abs() < 1e-9);
        assert!((pt.secs("b") - 0.005).abs() < 1e-9);
        assert_eq!(pt.secs("missing"), 0.0);
    }

    #[test]
    fn series_threshold() {
        let mut s = Series::default();
        s.push(0.0, 1.0);
        s.push(1.0, 0.1);
        s.push(2.0, 0.01);
        assert_eq!(s.time_to_reach_below(0.05), Some(2.0));
        assert_eq!(s.time_to_reach_below(0.5), Some(1.0));
        assert_eq!(s.time_to_reach_below(1e-9), None);
    }

    #[test]
    fn timer_time_runs_closure() {
        let mut pt = PhaseTimer::new();
        let v = pt.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert!(pt.secs("work") >= 0.0);
    }

    #[test]
    fn csv_format() {
        let mut s = Series::default();
        s.push(0.5, 2.0);
        let csv = s.to_csv(("t", "obj"));
        assert!(csv.starts_with("t,obj\n"));
        assert!(csv.contains("0.5"));
    }
}
