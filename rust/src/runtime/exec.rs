//! Tiled executor: implements [`BlockKernel`] on top of the fixed-shape AOT
//! artifacts.
//!
//! The artifacts compute (nq_tile × nd_blk) kernel tiles at a padded feature
//! dim; this module embeds arbitrary `(nq, nd, dim)` requests into those
//! tiles (zero-padding is exact — see python/compile/model.py padded
//! wrappers, which the python tests verify against the oracle) and masks the
//! padded slots on the way out.
//!
//! Two query-tile variants exist per kernel: "slim" (64 rows) for the
//! solver's kernel-row fetches, "wide" (256 rows) for bulk work. The fused
//! decision artifacts accumulate across data tiles (coef-padding with zeros
//! keeps the sum exact).

use anyhow::Result;

use super::{Engine, TileAbi};
use crate::kernel::{BlockKernel, KernelKind};

/// PJRT-backed block kernel (the production hot path).
pub struct PjrtKernel<'e> {
    engine: &'e Engine,
    kind: KernelKind,
    abi: TileAbi,
}

impl<'e> PjrtKernel<'e> {
    pub fn new(engine: &'e Engine, kind: KernelKind) -> Self {
        let abi = engine.abi();
        PjrtKernel { engine, kind, abi }
    }

    fn pad_rows(x: &[f32], n: usize, dim: usize, n_pad: usize, d_pad: usize) -> Vec<f32> {
        let mut out = vec![0f32; n_pad * d_pad];
        for i in 0..n {
            out[i * d_pad..i * d_pad + dim].copy_from_slice(&x[i * dim..(i + 1) * dim]);
        }
        out
    }

    fn pad_vec(v: &[f32], n_pad: usize) -> Vec<f32> {
        let mut out = vec![0f32; n_pad];
        out[..v.len()].copy_from_slice(v);
        out
    }

    /// Pick the query-tile size for a request of `nq` rows.
    fn q_tile(&self, nq: usize) -> (usize, &'static str) {
        if nq <= self.abi.nq_slim {
            (self.abi.nq_slim, "slim")
        } else {
            (self.abi.nq_wide, "wide")
        }
    }

    fn block_artifact(&self, tag: &str) -> String {
        match self.kind {
            // The linear artifact is named `lin_block_wide` in the catalog.
            KernelKind::Linear => "lin_block_wide".to_string(),
            _ => format!("{}_block_{}", self.kind.name(), tag),
        }
    }

    fn decision_artifact(&self) -> String {
        format!("{}_decision_wide", self.kind.name())
    }

    /// One padded (q_tile × nd_blk) block execution; returns the flat tile.
    #[allow(clippy::too_many_arguments)]
    fn run_block_tile(
        &self,
        xq_pad: &[f32],
        qn_pad: &[f32],
        q_tile: usize,
        tag: &str,
        xd_pad: &[f32],
        dn_pad: &[f32],
    ) -> Result<Vec<f32>> {
        let d = self.abi.d_pad as i64;
        let (qt, ndb) = (q_tile as i64, self.abi.nd_blk as i64);
        let name = self.block_artifact(tag);
        match self.kind {
            KernelKind::Rbf { gamma } => self.engine.execute(
                &name,
                &[
                    (xq_pad, &[qt, d]),
                    (xd_pad, &[ndb, d]),
                    (qn_pad, &[qt]),
                    (dn_pad, &[ndb]),
                    (&[gamma], &[1]),
                ],
            ),
            KernelKind::Poly { gamma, eta } => self.engine.execute(
                &name,
                &[
                    (xq_pad, &[qt, d]),
                    (xd_pad, &[ndb, d]),
                    (&[gamma], &[1]),
                    (&[eta], &[1]),
                ],
            ),
            KernelKind::Linear => self.engine.execute(
                &name,
                &[(xq_pad, &[qt, d]), (xd_pad, &[ndb, d])],
            ),
        }
    }
}

#[allow(clippy::too_many_arguments)] // flat block ABI; see the trait docs
impl BlockKernel for PjrtKernel<'_> {
    fn kind(&self) -> KernelKind {
        self.kind
    }

    fn prefers_batched_rows(&self) -> bool {
        true // per-dispatch overhead must be amortized (bench_kernel_micro)
    }

    fn block(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        out: &mut [f32],
    ) {
        let nq = q_norms.len();
        let nd = d_norms.len();
        assert!(dim <= self.abi.d_pad, "dim {dim} > padded dim {}", self.abi.d_pad);
        assert_eq!(out.len(), nq * nd);
        let (ndb, dp) = (self.abi.nd_blk, self.abi.d_pad);

        // Linear-kind requests fall back to wide only (no slim artifact).
        let mut q0 = 0;
        while q0 < nq {
            let (q_tile, tag) = match self.kind {
                KernelKind::Linear => (self.abi.nq_wide, "wide"),
                _ => self.q_tile(nq - q0),
            };
            let q_take = q_tile.min(nq - q0);
            let xq_pad =
                Self::pad_rows(&xq[q0 * dim..(q0 + q_take) * dim], q_take, dim, q_tile, dp);
            let qn_pad = Self::pad_vec(&q_norms[q0..q0 + q_take], q_tile);

            let mut d0 = 0;
            while d0 < nd {
                let d_take = ndb.min(nd - d0);
                let xd_pad =
                    Self::pad_rows(&xd[d0 * dim..(d0 + d_take) * dim], d_take, dim, ndb, dp);
                let dn_pad = Self::pad_vec(&d_norms[d0..d0 + d_take], ndb);
                let tile = self
                    .run_block_tile(&xq_pad, &qn_pad, q_tile, tag, &xd_pad, &dn_pad)
                    .expect("PJRT block execution failed");
                for qi in 0..q_take {
                    let src = &tile[qi * ndb..qi * ndb + d_take];
                    let dst = &mut out[(q0 + qi) * nd + d0..(q0 + qi) * nd + d0 + d_take];
                    dst.copy_from_slice(src);
                }
                d0 += d_take;
            }
            q0 += q_take;
        }
    }

    /// Fused decision via the `*_decision_wide` artifacts (RBF/poly);
    /// linear falls back to the default block-then-GEMV path.
    fn decision(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        coef: &[f32],
        out: &mut [f32],
    ) {
        if matches!(self.kind, KernelKind::Linear) {
            // No fused linear artifact; use the trait default.
            return default_decision(self, xq, q_norms, xd, d_norms, dim, coef, out);
        }
        let nq = q_norms.len();
        let nd = d_norms.len();
        assert!(dim <= self.abi.d_pad);
        assert_eq!(out.len(), nq);
        assert_eq!(coef.len(), nd);
        let (ndb, dp, qw) = (self.abi.nd_blk, self.abi.d_pad, self.abi.nq_wide);
        let name = self.decision_artifact();

        let mut q0 = 0;
        while q0 < nq {
            let q_take = qw.min(nq - q0);
            let xq_pad =
                Self::pad_rows(&xq[q0 * dim..(q0 + q_take) * dim], q_take, dim, qw, dp);
            let qn_pad = Self::pad_vec(&q_norms[q0..q0 + q_take], qw);
            let mut acc = vec![0f64; q_take];

            let mut d0 = 0;
            while d0 < nd {
                let d_take = ndb.min(nd - d0);
                let xd_pad =
                    Self::pad_rows(&xd[d0 * dim..(d0 + d_take) * dim], d_take, dim, ndb, dp);
                let dn_pad = Self::pad_vec(&d_norms[d0..d0 + d_take], ndb);
                let coef_pad = Self::pad_vec(&coef[d0..d0 + d_take], ndb);
                let (qt, ndbi, d) = (qw as i64, ndb as i64, dp as i64);
                let dv = match self.kind {
                    KernelKind::Rbf { gamma } => self.engine.execute(
                        &name,
                        &[
                            (&xq_pad, &[qt, d]),
                            (&xd_pad, &[ndbi, d]),
                            (&qn_pad, &[qt]),
                            (&dn_pad, &[ndbi]),
                            (&coef_pad, &[ndbi]),
                            (&[gamma], &[1]),
                        ],
                    ),
                    KernelKind::Poly { gamma, eta } => self.engine.execute(
                        &name,
                        &[
                            (&xq_pad, &[qt, d]),
                            (&xd_pad, &[ndbi, d]),
                            (&coef_pad, &[ndbi]),
                            (&[gamma], &[1]),
                            (&[eta], &[1]),
                        ],
                    ),
                    KernelKind::Linear => unreachable!(),
                }
                .expect("PJRT decision execution failed");
                for qi in 0..q_take {
                    acc[qi] += dv[qi] as f64;
                }
                d0 += d_take;
            }
            for qi in 0..q_take {
                out[q0 + qi] = acc[qi] as f32;
            }
            q0 += q_take;
        }
    }
}

/// The `BlockKernel::decision` default body, callable from an override.
#[allow(clippy::too_many_arguments)]
fn default_decision(
    k: &dyn BlockKernel,
    xq: &[f32],
    q_norms: &[f32],
    xd: &[f32],
    d_norms: &[f32],
    dim: usize,
    coef: &[f32],
    out: &mut [f32],
) {
    let nq = q_norms.len();
    let nd = d_norms.len();
    let mut block = vec![0f32; nq * nd];
    k.block(xq, q_norms, xd, d_norms, dim, &mut block);
    for i in 0..nq {
        let row = &block[i * nd..(i + 1) * nd];
        out[i] = row.iter().zip(coef).map(|(&kv, &c)| kv * c).sum();
    }
}
