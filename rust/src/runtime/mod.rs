//! PJRT runtime: loads the AOT-compiled HLO artifacts and executes them on
//! the CPU PJRT client via the `xla` crate.
//!
//! This is the bridge between L3 (Rust coordinator) and L2/L1 (JAX/Pallas,
//! build-time only): `make artifacts` lowers the kernels to
//! `artifacts/*.hlo.txt` + `manifest.json`, and this module
//! - parses the manifest (shape ABI) with the in-repo JSON parser,
//! - compiles each HLO text module once (`HloModuleProto::from_text_file`
//!   → `XlaComputation::from_proto` → `PjRtClient::compile`),
//! - exposes `Engine::execute(name, args)` for the tiled executor
//!   ([`exec::PjrtKernel`]) that implements [`crate::kernel::BlockKernel`].
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that the bundled xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py).

pub mod exec;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

pub use exec::PjrtKernel;

/// Tile-shape ABI read from artifacts/manifest.json.
#[derive(Clone, Copy, Debug)]
pub struct TileAbi {
    pub d_pad: usize,
    pub nq_slim: usize,
    pub nq_wide: usize,
    pub nd_blk: usize,
}

struct EngineInner {
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions per artifact (perf accounting).
    calls: HashMap<String, u64>,
}

/// A compiled-artifact registry bound to one PJRT CPU client.
///
/// SAFETY of `Send + Sync`: the `xla` crate's wrappers hold raw pointers
/// without marking them Send/Sync, but the underlying PJRT CPU client is
/// internally synchronized (it is the same client the multi-threaded XLA
/// runtime uses). We additionally serialize *all* access through one Mutex,
/// so no two threads ever enter the FFI concurrently through this type.
pub struct Engine {
    inner: Mutex<EngineInner>,
    abi: TileAbi,
    dir: PathBuf,
}

unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load and compile every artifact listed in `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {}", manifest_path.display()))?;
        let manifest = Json::parse(&text).context("parse manifest.json")?;
        let abi = TileAbi {
            d_pad: manifest
                .get("d_pad")
                .as_usize()
                .ok_or_else(|| anyhow!("manifest missing d_pad"))?,
            nq_slim: manifest
                .get("nq_slim")
                .as_usize()
                .ok_or_else(|| anyhow!("manifest missing nq_slim"))?,
            nq_wide: manifest
                .get("nq_wide")
                .as_usize()
                .ok_or_else(|| anyhow!("manifest missing nq_wide"))?,
            nd_blk: manifest
                .get("nd_blk")
                .as_usize()
                .ok_or_else(|| anyhow!("manifest missing nd_blk"))?,
        };

        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut exes = HashMap::new();
        let artifacts = manifest
            .get("artifacts")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        for (name, meta) in artifacts {
            let file = meta
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?;
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            exes.insert(name.clone(), exe);
        }
        if exes.is_empty() {
            bail!("no artifacts found in {}", dir.display());
        }
        crate::info!(
            "runtime: compiled {} artifacts from {} (d_pad={}, tiles {}x{}/{}x{})",
            exes.len(),
            dir.display(),
            abi.d_pad,
            abi.nq_slim,
            abi.nd_blk,
            abi.nq_wide,
            abi.nd_blk
        );
        Ok(Engine {
            inner: Mutex::new(EngineInner { exes, calls: HashMap::new() }),
            abi,
            dir: dir.to_path_buf(),
        })
    }

    /// Default artifact directory: `$DCSVM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("DCSVM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load from the default directory; `None` if artifacts are not built
    /// (callers fall back to the native backend).
    pub fn load_default() -> Option<Engine> {
        let dir = Self::default_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        match Engine::load(&dir) {
            Ok(e) => Some(e),
            Err(err) => {
                crate::warn_!("runtime: failed to load artifacts: {err:#}");
                None
            }
        }
    }

    pub fn abi(&self) -> TileAbi {
        self.abi
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.inner.lock().unwrap().exes.contains_key(name)
    }

    /// Execute an artifact. `args` are f32 buffers with their shapes; the
    /// single (tuple-wrapped) output is returned as a flat f32 vector.
    pub fn execute(&self, name: &str, args: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        let mut inner = self.inner.lock().unwrap();
        let exe = inner
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                if dims.len() == 1 {
                    Ok(lit)
                } else {
                    lit.reshape(dims).map_err(|e| anyhow!("reshape: {e:?}"))
                }
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {name}: {e:?}"))?;
        let out = lit
            .to_tuple1()
            .map_err(|e| anyhow!("to_tuple1 {name}: {e:?}"))?;
        let v = out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("to_vec {name}: {e:?}"))?;
        *inner.calls.entry(name.to_string()).or_insert(0) += 1;
        Ok(v)
    }

    /// Per-artifact execution counts (perf accounting).
    pub fn call_counts(&self) -> Vec<(String, u64)> {
        let inner = self.inner.lock().unwrap();
        let mut v: Vec<_> = inner.calls.iter().map(|(k, &c)| (k.clone(), c)).collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine tests that need compiled artifacts live in rust/tests/
    // (integration), where they skip gracefully if artifacts/ is absent.

    #[test]
    fn default_dir_env_override() {
        std::env::set_var("DCSVM_ARTIFACTS", "/tmp/nope-artifacts");
        assert_eq!(Engine::default_dir(), PathBuf::from("/tmp/nope-artifacts"));
        std::env::remove_var("DCSVM_ARTIFACTS");
        assert_eq!(Engine::default_dir(), PathBuf::from("artifacts"));
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(Engine::load(Path::new("/definitely/not/here")).is_err());
    }
}
