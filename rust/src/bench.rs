//! Micro/macro bench harness (the environment has no criterion crate).
//!
//! `time_fn` measures a closure with warmup + repetitions and robust stats;
//! `Table` prints paper-style rows. Every `rust/benches/bench_*.rs` target
//! (one per paper table/figure) builds on these.

use std::time::Instant;

/// Timing statistics over repetitions (seconds).
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub reps: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
    pub stddev_s: f64,
}

/// Measure `f` with `warmup` unmeasured runs and `reps` measured runs.
pub fn time_fn<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps.max(1));
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    let n = times.len();
    let mean = times.iter().sum::<f64>() / n as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n as f64;
    BenchStats {
        reps: n,
        median_s: times[n / 2],
        mean_s: mean,
        min_s: times[0],
        stddev_s: var.sqrt(),
    }
}

/// Fixed-width table printer for paper-style output.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            println!("{s}");
        };
        line(&self.headers, &self.widths);
        let sep: Vec<String> = self.widths.iter().map(|&w| "-".repeat(w)).collect();
        line(&sep, &self.widths);
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

/// Format seconds compactly ("12.3s", "456ms").
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}s")
    } else if s >= 1.0 {
        format!("{s:.1}s")
    } else if s >= 1e-3 {
        format!("{:.0}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Standard bench banner so logs are self-describing.
pub fn banner(id: &str, what: &str) {
    println!();
    println!("=== {id}: {what} ===");
    println!(
        "(synthetic substitute workloads — see DESIGN.md §Substitutions; \
         shapes/orderings reproduce the paper, absolute times are 1-core)"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts_reps() {
        let mut calls = 0;
        let st = time_fn(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(st.reps, 5);
        assert!(st.min_s <= st.median_s);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(120.0), "120s");
        assert_eq!(fmt_secs(2.34), "2.3s");
        assert_eq!(fmt_secs(0.012), "12ms");
        assert!(fmt_secs(2e-5).ends_with("us"));
    }

    #[test]
    fn table_rows_align() {
        let mut t = Table::new(&["a", "long-header"]);
        t.row(&["xxxxxx".into(), "1".into()]);
        t.print(); // smoke: no panic
    }
}
