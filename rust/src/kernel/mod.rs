//! Kernel functions and the block-kernel backend abstraction.
//!
//! Everything expensive in kernel SVM training reduces to *kernel block*
//! evaluation K(Xq, Xd) (see DESIGN.md §2). `BlockKernel` is the single
//! interface the solver, kmeans, DC-SVM driver, and predictors consume; it
//! has two implementations:
//!
//! - [`native::NativeKernel`]: pure-Rust blocked evaluation (reference
//!   backend; always available, used by unit tests and as the comparator in
//!   `bench_kernel_micro`);
//! - [`crate::runtime::PjrtKernel`]: executes the AOT-compiled Pallas/XLA
//!   artifacts via PJRT — the production hot path.

pub mod native;

/// Kernel function family + parameters. γ/η are runtime values (the PJRT
/// artifacts take them as inputs, so no recompilation across the paper's
/// (C, γ) grids).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelKind {
    /// exp(-γ‖x−z‖²)
    Rbf { gamma: f32 },
    /// (γ·xᵀz + η)³ — the paper's degree-3 polynomial
    Poly { gamma: f32, eta: f32 },
    /// xᵀz
    Linear,
}

impl KernelKind {
    /// Evaluate on a single pair (scalar reference implementation — the
    /// oracle for both backends' tests).
    pub fn eval(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match *self {
            KernelKind::Rbf { gamma } => {
                let d2: f32 = a
                    .iter()
                    .zip(b)
                    .map(|(&u, &v)| (u - v) * (u - v))
                    .sum();
                (-gamma * d2).exp()
            }
            KernelKind::Poly { gamma, eta } => {
                let dot: f32 = a.iter().zip(b).map(|(&u, &v)| u * v).sum();
                let g = gamma * dot + eta;
                g * g * g
            }
            KernelKind::Linear => a.iter().zip(b).map(|(&u, &v)| u * v).sum(),
        }
    }

    /// K(x, x) — needed by kernel kmeans distances and Theorem-2 bounds.
    pub fn self_eval(&self, a: &[f32], sq_norm: f32) -> f32 {
        match *self {
            KernelKind::Rbf { .. } => 1.0,
            KernelKind::Poly { gamma, eta } => {
                let g = gamma * sq_norm + eta;
                g * g * g
            }
            KernelKind::Linear => {
                let _ = a;
                sq_norm
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Rbf { .. } => "rbf",
            KernelKind::Poly { .. } => "poly",
            KernelKind::Linear => "linear",
        }
    }
}

/// A batched kernel-block evaluator.
///
/// `xq`/`xd` are row-major `[nq, dim]` / `[nd, dim]`; `q_norms`/`d_norms`
/// are the rows' squared L2 norms (consumed by RBF; ignored otherwise);
/// `out` is row-major `[nq, nd]`. The flat argument lists mirror the AOT
/// artifact ABI (matrices + norms + outputs), hence the allow.
#[allow(clippy::too_many_arguments)]
pub trait BlockKernel: Sync + Send {
    fn kind(&self) -> KernelKind;

    /// Whether this backend amortizes per-call overhead across batched
    /// kernel-row requests. The PJRT backend pays a fixed dispatch cost per
    /// call (FFI + literal copies + XLA launch), so the solver should fetch
    /// rows in batches; the native backend computes rows at memory speed,
    /// where speculative batching is wasted work (measured in
    /// bench_ablations A5).
    fn prefers_batched_rows(&self) -> bool {
        false
    }

    fn block(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        out: &mut [f32],
    );

    /// Fused decision values: `out[i] = Σ_j coef[j]·K(xq_i, xd_j)`.
    /// Default materializes the block; the PJRT backend overrides with the
    /// fused artifact.
    fn decision(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        coef: &[f32],
        out: &mut [f32],
    ) {
        let nq = q_norms.len();
        let nd = d_norms.len();
        debug_assert_eq!(out.len(), nq);
        let mut block = vec![0f32; nq * nd];
        self.block(xq, q_norms, xd, d_norms, dim, &mut block);
        for i in 0..nq {
            let row = &block[i * nd..(i + 1) * nd];
            out[i] = row.iter().zip(coef).map(|(&k, &c)| k * c).sum();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_eval_matches_formulas() {
        let a = [1.0f32, 2.0];
        let b = [0.0f32, 1.0];
        let rbf = KernelKind::Rbf { gamma: 0.5 };
        assert!((rbf.eval(&a, &b) - (-0.5f32 * 2.0).exp()).abs() < 1e-7);
        let poly = KernelKind::Poly { gamma: 1.0, eta: 1.0 };
        assert!((poly.eval(&a, &b) - 27.0).abs() < 1e-5); // (2+1)^3
        assert!((KernelKind::Linear.eval(&a, &b) - 2.0).abs() < 1e-7);
    }

    #[test]
    fn self_eval_consistency() {
        let a = [0.5f32, -1.5, 2.0];
        let n: f32 = a.iter().map(|v| v * v).sum();
        for kind in [
            KernelKind::Rbf { gamma: 2.0 },
            KernelKind::Poly { gamma: 0.3, eta: 0.7 },
            KernelKind::Linear,
        ] {
            assert!(
                (kind.self_eval(&a, n) - kind.eval(&a, &a)).abs() < 1e-5,
                "{kind:?}"
            );
        }
    }
}
