//! Kernel functions and the block-kernel backend abstraction.
//!
//! Everything expensive in kernel SVM training reduces to *kernel block*
//! evaluation K(Xq, Xd) (see DESIGN.md §2). `BlockKernel` is the single
//! interface the solver, kmeans, DC-SVM driver, and predictors consume; it
//! has two implementations:
//!
//! - [`native::NativeKernel`]: pure-Rust blocked evaluation (reference
//!   backend; always available, used by unit tests and as the comparator in
//!   `bench_kernel_micro`);
//! - [`crate::runtime::PjrtKernel`]: executes the AOT-compiled Pallas/XLA
//!   artifacts via PJRT — the production hot path.

pub mod native;
pub mod quant;

pub use native::{simd_tier, SimdTier};

use crate::util::threadpool::scope_map;

/// Split a row-major dispatch into per-chunk jobs of `chunk` query rows:
/// the rows' features, their norms, and the matching disjoint `&mut`
/// output slice (`row_stride` output values per row — `nd` for block
/// dispatches, 1 for decision values). The one splitter both
/// [`BlockKernel::decision_par`] and the native backend's
/// [`BlockKernel::block_par`] use, so the two dispatch paths cannot
/// drift.
fn split_row_jobs<'j>(
    xq: &'j [f32],
    q_norms: &'j [f32],
    out: &'j mut [f32],
    dim: usize,
    row_stride: usize,
    chunk: usize,
) -> Vec<(&'j [f32], &'j [f32], &'j mut [f32])> {
    let nq = q_norms.len();
    let chunk = chunk.max(1);
    let mut jobs = Vec::with_capacity(nq.div_ceil(chunk));
    let mut out_rest: &'j mut [f32] = out;
    let mut lo = 0usize;
    while lo < nq {
        let take = chunk.min(nq - lo);
        let (o, rest) = std::mem::take(&mut out_rest).split_at_mut(take * row_stride);
        jobs.push((&xq[lo * dim..(lo + take) * dim], &q_norms[lo..lo + take], o));
        out_rest = rest;
        lo += take;
    }
    jobs
}

/// Kernel function family + parameters. γ/η are runtime values (the PJRT
/// artifacts take them as inputs, so no recompilation across the paper's
/// (C, γ) grids).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelKind {
    /// exp(-γ‖x−z‖²)
    Rbf { gamma: f32 },
    /// (γ·xᵀz + η)³ — the paper's degree-3 polynomial
    Poly { gamma: f32, eta: f32 },
    /// xᵀz
    Linear,
}

impl KernelKind {
    /// Evaluate on a single pair (scalar reference implementation — the
    /// oracle for both backends' tests).
    pub fn eval(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match *self {
            KernelKind::Rbf { gamma } => {
                let d2: f32 = a
                    .iter()
                    .zip(b)
                    .map(|(&u, &v)| (u - v) * (u - v))
                    .sum();
                (-gamma * d2).exp()
            }
            KernelKind::Poly { gamma, eta } => {
                let dot: f32 = a.iter().zip(b).map(|(&u, &v)| u * v).sum();
                let g = gamma * dot + eta;
                g * g * g
            }
            KernelKind::Linear => a.iter().zip(b).map(|(&u, &v)| u * v).sum(),
        }
    }

    /// K(x, x) — needed by kernel kmeans distances and Theorem-2 bounds.
    pub fn self_eval(&self, a: &[f32], sq_norm: f32) -> f32 {
        match *self {
            KernelKind::Rbf { .. } => 1.0,
            KernelKind::Poly { gamma, eta } => {
                let g = gamma * sq_norm + eta;
                g * g * g
            }
            KernelKind::Linear => {
                let _ = a;
                sq_norm
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Rbf { .. } => "rbf",
            KernelKind::Poly { .. } => "poly",
            KernelKind::Linear => "linear",
        }
    }
}

/// A batched kernel-block evaluator.
///
/// `xq`/`xd` are row-major `[nq, dim]` / `[nd, dim]`; `q_norms`/`d_norms`
/// are the rows' squared L2 norms (consumed by RBF; ignored otherwise);
/// `out` is row-major `[nq, nd]`. The flat argument lists mirror the AOT
/// artifact ABI (matrices + norms + outputs), hence the allow.
#[allow(clippy::too_many_arguments)]
pub trait BlockKernel: Sync + Send {
    fn kind(&self) -> KernelKind;

    /// Whether this backend amortizes per-call overhead across batched
    /// kernel-row requests. The PJRT backend pays a fixed dispatch cost per
    /// call (FFI + literal copies + XLA launch), so the solver should fetch
    /// rows in batches; the native backend computes rows at memory speed,
    /// where speculative batching is wasted work (measured in
    /// bench_ablations A5).
    fn prefers_batched_rows(&self) -> bool {
        false
    }

    /// How many row-panel chunks [`Self::block_par`] would split an
    /// `nq × nd` dispatch over `dim` features into at the given thread
    /// budget — 1 means the dispatch stays single-threaded. Callers use it
    /// to size speculative row batches (the solver's prefetch) so that
    /// batching is only turned on where the fan-out actually pays for it.
    /// Backends without an in-process parallel path (PJRT parallelizes
    /// inside XLA) keep the default of 1.
    fn dispatch_fanout(&self, nq: usize, nd: usize, dim: usize, threads: usize) -> usize {
        let _ = (nq, nd, dim, threads);
        1
    }

    fn block(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        out: &mut [f32],
    );

    /// [`Self::block`] with an in-process thread budget: backends that
    /// compute on the calling thread may partition the **output rows** into
    /// panels and evaluate them on up to `threads` workers. The guarantee
    /// is bit-identity: each output row's arithmetic is unchanged, only
    /// which thread computes it varies, so results are identical for every
    /// `threads` value. Returns the number of row-panel chunks actually
    /// used (1 = the dispatch ran single-threaded). The default ignores
    /// `threads` and delegates to [`Self::block`].
    fn block_par(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        threads: usize,
        out: &mut [f32],
    ) -> usize {
        let _ = threads;
        self.block(xq, q_norms, xd, d_norms, dim, out);
        1
    }

    /// Fused decision values: `out[i] = Σ_j coef[j]·K(xq_i, xd_j)`.
    /// Default materializes the block; the PJRT backend overrides with the
    /// fused artifact.
    fn decision(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        coef: &[f32],
        out: &mut [f32],
    ) {
        let nq = q_norms.len();
        let nd = d_norms.len();
        debug_assert_eq!(out.len(), nq);
        let mut block = vec![0f32; nq * nd];
        self.block(xq, q_norms, xd, d_norms, dim, &mut block);
        for i in 0..nq {
            let row = &block[i * nd..(i + 1) * nd];
            out[i] = row.iter().zip(coef).map(|(&k, &c)| k * c).sum();
        }
    }

    /// [`Self::decision`] with an in-process thread budget: decision values
    /// are per-row independent, so queries are partitioned into chunks and
    /// each chunk runs through the backend's (possibly fused) decision path
    /// on its own worker. Bit-identical to the single-threaded call for
    /// every `threads` value; returns the number of chunks used (1 =
    /// single-threaded). Backends whose [`Self::dispatch_fanout`] stays at
    /// the default of 1 never split.
    fn decision_par(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        coef: &[f32],
        threads: usize,
        out: &mut [f32],
    ) -> usize {
        let nq = q_norms.len();
        debug_assert_eq!(out.len(), nq);
        let fanout = self.dispatch_fanout(nq, d_norms.len(), dim, threads);
        if fanout <= 1 {
            self.decision(xq, q_norms, xd, d_norms, dim, coef, out);
            return 1;
        }
        let jobs = split_row_jobs(xq, q_norms, out, dim, 1, nq.div_ceil(fanout));
        let used = jobs.len();
        scope_map(used, jobs, |_, (q, qn, o)| {
            self.decision(q, qn, xd, d_norms, dim, coef, o);
        });
        used
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_eval_matches_formulas() {
        let a = [1.0f32, 2.0];
        let b = [0.0f32, 1.0];
        let rbf = KernelKind::Rbf { gamma: 0.5 };
        assert!((rbf.eval(&a, &b) - (-0.5f32 * 2.0).exp()).abs() < 1e-7);
        let poly = KernelKind::Poly { gamma: 1.0, eta: 1.0 };
        assert!((poly.eval(&a, &b) - 27.0).abs() < 1e-5); // (2+1)^3
        assert!((KernelKind::Linear.eval(&a, &b) - 2.0).abs() < 1e-7);
    }

    #[test]
    fn self_eval_consistency() {
        let a = [0.5f32, -1.5, 2.0];
        let n: f32 = a.iter().map(|v| v * v).sum();
        for kind in [
            KernelKind::Rbf { gamma: 2.0 },
            KernelKind::Poly { gamma: 0.3, eta: 0.7 },
            KernelKind::Linear,
        ] {
            assert!(
                (kind.self_eval(&a, n) - kind.eval(&a, &a)).abs() < 1e-5,
                "{kind:?}"
            );
        }
    }
}
