//! Error-bounded int8 row quantization for approximation-tolerant paths.
//!
//! The paper's early-prediction argument (Hsieh, Si & Dhillon, ICML 2014,
//! §5) already accepts approximate predictions from level-ℓ subproblem
//! models; routing a query to its kernel-kmeans cluster is likewise robust
//! to small kernel perturbations (the argmin over cluster distances moves
//! only for queries near a cluster boundary). That licenses a quantized
//! fast path for **routing and early prediction only** — the exact solver
//! path never touches this module, which is why the scalar-vs-SIMD and
//! 1-vs-N-thread bit-identity gates are unaffected by `--quant-route`.
//!
//! Each row is quantized independently with an affine (scale, zero-point)
//! code: `v ≈ zero + scale · q` with `q ∈ [-127, 127]`. `scale` maps the
//! row's exact `[min, max]` range onto the 254-step grid, so every value
//! lands within half a step of a code point and the reconstruction error
//! is bounded by `scale / 2` **per element** ([`QuantizedRows::error_bound`]
//! — property-tested in this module). A constant row gets `scale = 0` and
//! is carried exactly by its zero-point.
//!
//! Kernel blocks against quantized rows reuse the identity
//! `<q, d̂_j> = zero_j · Σ_t q_t + scale_j · Σ_t q_t · data_jt`, then apply
//! the SAME elementwise transform as the exact backend
//! ([`super::native::kernel_transform`]) with the **exact** stored row
//! norms — so the only approximation is the cross term, and its error is
//! bounded by `error_bound(j) · ‖q‖₁`.

use super::native::kernel_transform;
use super::KernelKind;

/// Int8-quantized row-major matrix with per-row affine codes. Stored
/// alongside the exact `GatheredCols` features in the segment registry and
/// inside the kmeans `Router` when `--quant-route` is on.
#[derive(Clone, Debug)]
pub struct QuantizedRows {
    /// `[n, dim]` row-major int8 codes.
    data: Vec<i8>,
    /// Per-row step size (`(max - min) / 254`; 0 for constant rows).
    scale: Vec<f32>,
    /// Per-row zero-point (`(max + min) / 2` — the range midpoint, so the
    /// codes are symmetric in `[-127, 127]`).
    zero: Vec<f32>,
    dim: usize,
}

impl QuantizedRows {
    /// Quantize `x` (`[n, dim]` row-major f32) row by row.
    pub fn from_rows(x: &[f32], dim: usize) -> QuantizedRows {
        assert!(dim > 0 || x.is_empty(), "dim 0 with non-empty data");
        let n = if dim == 0 { 0 } else { x.len() / dim };
        assert_eq!(x.len(), n * dim, "row data not a multiple of dim");
        let mut data = Vec::with_capacity(n * dim);
        let mut scale = Vec::with_capacity(n);
        let mut zero = Vec::with_capacity(n);
        for r in 0..n {
            let row = &x[r * dim..(r + 1) * dim];
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &v in row {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let (z, s) = if hi > lo {
                ((hi + lo) * 0.5, (hi - lo) / 254.0)
            } else {
                // Constant row: the zero-point carries the value exactly.
                (lo, 0.0)
            };
            for &v in row {
                let q = if s == 0.0 {
                    0i8
                } else {
                    ((v - z) / s).round().clamp(-127.0, 127.0) as i8
                };
                data.push(q);
            }
            scale.push(s);
            zero.push(z);
        }
        QuantizedRows { data, scale, zero, dim }
    }

    /// Number of quantized rows.
    pub fn len(&self) -> usize {
        self.scale.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scale.is_empty()
    }

    /// Features per row.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Heap bytes of the quantized store (counted against the segment
    /// registry cap next to the f32 features it shadows).
    pub fn bytes(&self) -> usize {
        self.data.len() + (self.scale.len() + self.zero.len()) * 4
    }

    /// Per-element reconstruction error bound of row `r`: every dequantized
    /// value is within `scale / 2` of the original (the row range maps onto
    /// the ±127 grid exactly, so clamping never adds error).
    pub fn error_bound(&self, r: usize) -> f32 {
        self.scale[r] * 0.5
    }

    /// Reconstruct row `r` (`zero + scale · q` per element).
    pub fn dequantize_row(&self, r: usize) -> Vec<f32> {
        let z = self.zero[r];
        let s = self.scale[r];
        self.data[r * self.dim..(r + 1) * self.dim]
            .iter()
            .map(|&q| z + s * q as f32)
            .collect()
    }

    /// Kernel block `out[i*n + j] = K(q_i, d̂_j)` of f32 queries against the
    /// quantized rows: the cross term expands the affine code
    /// (`zero_j · Σ_t q_t` is hoisted per query), then the exact backend's
    /// elementwise transform runs with the **exact** `d_norms` the caller
    /// stored at quantization time. Deterministic and thread-invariant —
    /// each `(i, j)` value is a pure function of the query and the codes.
    pub fn block(
        &self,
        kind: KernelKind,
        xq: &[f32],
        q_norms: &[f32],
        d_norms: &[f32],
        out: &mut [f32],
    ) {
        let nq = q_norms.len();
        let nd = self.len();
        let dim = self.dim;
        debug_assert_eq!(xq.len(), nq * dim);
        debug_assert_eq!(d_norms.len(), nd);
        debug_assert_eq!(out.len(), nq * nd);
        for i in 0..nq {
            let q = &xq[i * dim..(i + 1) * dim];
            let qsum: f32 = q.iter().sum();
            let row = &mut out[i * nd..(i + 1) * nd];
            for (j, v) in row.iter_mut().enumerate() {
                let codes = &self.data[j * dim..(j + 1) * dim];
                let mut s = 0f32;
                for (&qt, &ct) in q.iter().zip(codes) {
                    s += qt * ct as f32;
                }
                *v = self.zero[j] * qsum + self.scale[j] * s;
            }
        }
        kernel_transform(kind, q_norms, d_norms, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::native::NativeKernel;
    use crate::kernel::BlockKernel;
    use crate::prop_assert;
    use crate::util::prng::Pcg64;
    use crate::util::proptest::check;

    /// Satellite: int8 quantize→dequantize of random rows stays within the
    /// derived per-row bound `scale / 2` (plus f32 arithmetic slack).
    #[test]
    fn prop_quantize_dequantize_within_error_bound() {
        check("int8-quant-error-bound", 20, |rng: &mut Pcg64| {
            let n = 1 + rng.below(10);
            let dim = 1 + rng.below(48);
            // Sweep magnitudes across four decades so the bound is checked
            // where f32 granularity actually varies.
            let mag = 10f64.powf(rng.next_f64() * 4.0 - 2.0);
            let x: Vec<f32> =
                (0..n * dim).map(|_| (rng.next_gaussian() * mag) as f32).collect();
            let qr = QuantizedRows::from_rows(&x, dim);
            prop_assert!(qr.len() == n, "expected {n} rows, got {}", qr.len());
            for r in 0..n {
                let row = &x[r * dim..(r + 1) * dim];
                let back = qr.dequantize_row(r);
                let bound = qr.error_bound(r) as f64;
                let vmax =
                    row.iter().fold(0f32, |m, &v| m.max(v.abs())) as f64;
                let tol = bound * (1.0 + 1e-5) + 1e-6 * vmax + 1e-12;
                for (t, (&v, &w)) in row.iter().zip(&back).enumerate() {
                    prop_assert!(
                        ((v as f64) - (w as f64)).abs() <= tol,
                        "row {r} col {t}: |{v} - {w}| exceeds bound {bound} (tol {tol})"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn constant_and_single_element_rows_are_exact() {
        let x = vec![3.5f32, 3.5, 3.5, -2.0, -2.0, -2.0];
        let qr = QuantizedRows::from_rows(&x, 3);
        assert_eq!(qr.len(), 2);
        assert_eq!(qr.error_bound(0), 0.0);
        assert_eq!(qr.dequantize_row(0), vec![3.5, 3.5, 3.5]);
        assert_eq!(qr.dequantize_row(1), vec![-2.0, -2.0, -2.0]);
        let one = QuantizedRows::from_rows(&[7.25], 1);
        assert_eq!(one.dequantize_row(0), vec![7.25]);
    }

    #[test]
    fn empty_input_quantizes_to_empty() {
        let qr = QuantizedRows::from_rows(&[], 5);
        assert!(qr.is_empty());
        assert_eq!(qr.len(), 0);
        assert_eq!(qr.bytes(), 0);
    }

    /// RBF/poly blocks from quantized rows stay within the bound the cross
    /// term implies: `|ΔK| ≤ L · 2 · error_bound(j) · ‖q_i‖₁` where `L` is
    /// the transform's Lipschitz constant in the cross product (γ for RBF
    /// via d², checked here), since the stored norms are exact.
    #[test]
    fn prop_quantized_rbf_block_within_derived_bound() {
        check("int8-quant-rbf-block-bound", 12, |rng: &mut Pcg64| {
            let nq = 1 + rng.below(6);
            let nd = 1 + rng.below(8);
            let dim = 1 + rng.below(24);
            let gamma = (0.1 + rng.next_f64()) as f32;
            let kind = KernelKind::Rbf { gamma };
            let xq: Vec<f32> =
                (0..nq * dim).map(|_| rng.next_gaussian() as f32).collect();
            let xd: Vec<f32> =
                (0..nd * dim).map(|_| rng.next_gaussian() as f32).collect();
            let norms = |x: &[f32]| -> Vec<f32> {
                x.chunks(dim).map(|r| r.iter().map(|&v| v * v).sum()).collect()
            };
            let (qn, dn) = (norms(&xq), norms(&xd));
            let exact_kernel = NativeKernel::new(kind);
            let mut exact = vec![0f32; nq * nd];
            exact_kernel.block(&xq, &qn, &xd, &dn, dim, &mut exact);
            let qr = QuantizedRows::from_rows(&xd, dim);
            let mut approx = vec![0f32; nq * nd];
            qr.block(kind, &xq, &qn, &dn, &mut approx);
            for i in 0..nq {
                let l1: f64 = xq[i * dim..(i + 1) * dim]
                    .iter()
                    .map(|&v| v.abs() as f64)
                    .sum();
                for j in 0..nd {
                    // |Δd²| = 2|Δcross| ≤ 2 · bound_j · ‖q‖₁ and
                    // |exp(-γa) − exp(-γb)| ≤ γ|a − b| for a, b ≥ 0.
                    let bound = 2.0 * gamma as f64 * qr.error_bound(j) as f64 * l1
                        + 1e-4;
                    let diff =
                        (exact[i * nd + j] as f64 - approx[i * nd + j] as f64).abs();
                    prop_assert!(
                        diff <= bound,
                        "[{i},{j}] |ΔK| = {diff} exceeds derived bound {bound}"
                    );
                }
            }
            Ok(())
        });
    }

    /// Linear-kernel sanity: with scale-0 (constant) rows the codes are
    /// exact, so the quantized block matches the exact block up to f32
    /// summation-order noise (bit-identity is NOT claimed — the affine
    /// expansion sums in a different order than `dot1`).
    #[test]
    fn exact_rows_give_near_exact_linear_block() {
        let dim = 7;
        let xd = vec![2.0f32; 3 * dim]; // constant rows → scale 0, exact codes
        let xq: Vec<f32> = (0..2 * dim).map(|t| (t as f32) * 0.25 - 1.0).collect();
        let norms = |x: &[f32]| -> Vec<f32> {
            x.chunks(dim).map(|r| r.iter().map(|&v| v * v).sum()).collect()
        };
        let (qn, dn) = (norms(&xq), norms(&xd));
        let kind = KernelKind::Linear;
        let exact_kernel = NativeKernel::new(kind);
        let mut exact = vec![0f32; 2 * 3];
        exact_kernel.block(&xq, &qn, &xd, &dn, dim, &mut exact);
        let qr = QuantizedRows::from_rows(&xd, dim);
        let mut approx = vec![0f32; 2 * 3];
        qr.block(kind, &xq, &qn, &dn, &mut approx);
        for (a, b) in exact.iter().zip(&approx) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn bytes_accounts_codes_and_codebooks() {
        let x: Vec<f32> = (0..4 * 6).map(|t| t as f32).collect();
        let qr = QuantizedRows::from_rows(&x, 6);
        assert_eq!(qr.dim(), 6);
        assert_eq!(qr.bytes(), 4 * 6 + 2 * 4 * 4); // codes + scale/zero f32s
    }
}
