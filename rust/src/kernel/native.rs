//! Pure-Rust blocked kernel evaluation (reference backend).
//!
//! Mirrors the math of the Pallas kernels exactly (python/compile/kernels):
//! the cross term is a register-blocked GEMM micro-kernel over the feature
//! dimension, followed by the elementwise kernel transform. Used as the
//! always-available backend, the oracle the PJRT backend is property-tested
//! against, and the comparator in `bench_kernel_micro`.
//!
//! The inner dot product runs on one of three instruction tiers, detected
//! once per process ([`simd_tier`]): explicit AVX2 intrinsics on x86_64,
//! explicit NEON intrinsics on aarch64, and the portable scalar
//! lane-accumulator kernel everywhere else (or when `DCSVM_FORCE_SCALAR=1`).
//! All three tiers share the [`LANES`]-lane accumulator layout and the
//! exact pairwise reduction order, so kernel values are bit-identical
//! across tiers — the scalar-vs-SIMD CI gate pins it.

use std::sync::OnceLock;

use super::{BlockKernel, KernelKind};
use crate::util::threadpool::scope_map;

/// Output-row panel width: the register-blocked micro-kernel processes 4
/// query rows at a time, and parallel row chunks are cut at multiples of
/// this so every chunk panels exactly like the serial sweep.
const PANEL: usize = 4;

/// Independent accumulator lanes of the inner dot kernel (fixed — part of
/// the arithmetic contract, see the [`dot1_scalar`] docs). 8 lanes = one
/// AVX2 `f32x8` register = two NEON `f32x4` registers, so on every tier
/// the same lane accumulates the same products.
const LANES: usize = 8;

/// Inner-kernel instruction tier selected once per process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdTier {
    /// Portable lane-accumulator loop (always available; forced by
    /// `DCSVM_FORCE_SCALAR=1`).
    Scalar,
    /// Explicit `std::arch` AVX2 intrinsics (x86_64 with runtime support).
    Avx2,
    /// Explicit `std::arch` NEON intrinsics (aarch64 with runtime support).
    Neon,
}

impl SimdTier {
    /// Stable lowercase tag ("scalar" / "avx2" / "neon") — recorded in the
    /// harness outcome so BENCH_ci.json says which tier produced a run.
    pub fn name(self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Avx2 => "avx2",
            SimdTier::Neon => "neon",
        }
    }
}

/// The process-wide inner-kernel tier: detected on first use, constant
/// afterwards (one relaxed atomic load per block dispatch, never per dot).
/// `DCSVM_FORCE_SCALAR=1` pins the scalar tier — CI runs the exact-path
/// smoke twice, forced-scalar and auto, and asserts bit-identical results.
pub fn simd_tier() -> SimdTier {
    static TIER: OnceLock<SimdTier> = OnceLock::new();
    *TIER.get_or_init(detect_tier)
}

fn detect_tier() -> SimdTier {
    if std::env::var("DCSVM_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false) {
        return SimdTier::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return SimdTier::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return SimdTier::Neon;
        }
    }
    SimdTier::Scalar
}

/// Multiply-add count (`nq · nd · dim`) below which a block dispatch stays
/// single-threaded: small dispatches (the solver's per-row fetches, tiny
/// cluster blocks) finish faster than scoped workers spawn.
pub const PAR_MIN_MADDS: usize = 1 << 20;

/// Native (CPU, pure Rust) block kernel.
#[derive(Clone, Copy, Debug)]
pub struct NativeKernel {
    pub kind: KernelKind,
    /// Madds threshold for row-panel parallel dispatch
    /// ([`PAR_MIN_MADDS`]; tests force tiny blocks parallel by lowering it).
    par_min_madds: usize,
}

impl NativeKernel {
    pub fn new(kind: KernelKind) -> Self {
        NativeKernel { kind, par_min_madds: PAR_MIN_MADDS }
    }

    /// [`Self::new`] with an explicit parallel-dispatch threshold in
    /// multiply-adds (`nq · nd · dim`); tests use 1 to force the parallel
    /// path on small blocks.
    pub fn with_par_threshold(kind: KernelKind, par_min_madds: usize) -> Self {
        NativeKernel { kind, par_min_madds: par_min_madds.max(1) }
    }

    /// Rows per parallel chunk for an `nq`-row dispatch at `threads`
    /// workers: the even split rounded up to a [`PANEL`] multiple, so
    /// chunked sweeps panel rows exactly like the serial sweep.
    fn row_chunk(nq: usize, threads: usize) -> usize {
        nq.div_ceil(threads.max(1).min(nq.max(1))).div_ceil(PANEL) * PANEL
    }
}

/// One dot product `<q, d>` — THE inner kernel every block evaluation in
/// this backend funnels through, whatever the dispatch shape, panel
/// position, thread, or instruction tier. `chunks_exact` gives the compiler
/// fixed-length bounds-check-free bodies it can unroll/vectorize, and the
/// [`LANES`] independent accumulators (reduced pairwise, then the remainder
/// added sequentially) make the accumulation order a pure function of
/// `(q, d, dim)` — which is exactly why kernel entries are bit-identical
/// across full-row vs segment dispatches and 1 vs N threads. The SIMD
/// tiers (`dot1_avx2`, `dot1_neon`) perform these exact per-lane
/// operations in vector registers (separate mul then add — no FMA, which
/// would skip the intermediate rounding), so they are bit-identical too.
#[inline]
fn dot1_scalar(q: &[f32], d: &[f32]) -> f32 {
    debug_assert_eq!(q.len(), d.len());
    let mut lanes = [0f32; LANES];
    let mut qc = q.chunks_exact(LANES);
    let mut dc = d.chunks_exact(LANES);
    for (qs, ds) in qc.by_ref().zip(dc.by_ref()) {
        for ((lane, &qv), &dv) in lanes.iter_mut().zip(qs).zip(ds) {
            *lane += qv * dv;
        }
    }
    let mut acc = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
    for (&qv, &dv) in qc.remainder().iter().zip(dc.remainder()) {
        acc += qv * dv;
    }
    acc
}

/// AVX2 `dot1`: one `f32x8` accumulator is exactly the scalar kernel's 8
/// lanes; `_mm256_mul_ps` + `_mm256_add_ps` (NOT fused) round per lane the
/// way the scalar `*` and `+=` do, and the reduction extracts the lanes and
/// adds them in the scalar kernel's pairwise order — bit-identical output.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2 (guarded by [`simd_tier`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot1_avx2(q: &[f32], d: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    debug_assert_eq!(q.len(), d.len());
    let n = q.len();
    let chunks = n / LANES;
    let mut acc = _mm256_setzero_ps();
    for i in 0..chunks {
        let qv = _mm256_loadu_ps(q.as_ptr().add(i * LANES));
        let dv = _mm256_loadu_ps(d.as_ptr().add(i * LANES));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(qv, dv));
    }
    let mut lanes = [0f32; LANES];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut s = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
    for j in chunks * LANES..n {
        s += *q.get_unchecked(j) * *d.get_unchecked(j);
    }
    s
}

/// NEON `dot1`: two `f32x4` accumulators are the scalar kernel's lanes
/// 0..4 and 4..8; `vmulq_f32` + `vaddq_f32` (not `vfmaq`) round per lane
/// like the scalar kernel, and the reduction reads the 8 lanes back and
/// adds them in the same pairwise order — bit-identical output.
///
/// # Safety
/// Caller must ensure the CPU supports NEON (guarded by [`simd_tier`]).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot1_neon(q: &[f32], d: &[f32]) -> f32 {
    use std::arch::aarch64::*;
    debug_assert_eq!(q.len(), d.len());
    let n = q.len();
    let chunks = n / LANES;
    let mut acc_lo = vdupq_n_f32(0.0);
    let mut acc_hi = vdupq_n_f32(0.0);
    for i in 0..chunks {
        let qp = q.as_ptr().add(i * LANES);
        let dp = d.as_ptr().add(i * LANES);
        acc_lo = vaddq_f32(acc_lo, vmulq_f32(vld1q_f32(qp), vld1q_f32(dp)));
        acc_hi = vaddq_f32(acc_hi, vmulq_f32(vld1q_f32(qp.add(4)), vld1q_f32(dp.add(4))));
    }
    let mut lanes = [0f32; LANES];
    vst1q_f32(lanes.as_mut_ptr(), acc_lo);
    vst1q_f32(lanes.as_mut_ptr().add(4), acc_hi);
    let mut s = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
    for j in chunks * LANES..n {
        s += *q.get_unchecked(j) * *d.get_unchecked(j);
    }
    s
}

/// Bind `$f` to the process's detected inner-dot function and run `$body`.
/// The tier match happens ONCE per macro use (i.e. once per block dispatch,
/// not once per dot), and each arm monomorphizes `$body` for its dot — the
/// `#[target_feature]` kernels stay behind the one `unsafe` closure here.
macro_rules! with_dot {
    ($f:ident => $body:expr) => {
        match simd_tier() {
            #[cfg(target_arch = "x86_64")]
            SimdTier::Avx2 => {
                // SAFETY: simd_tier() returns Avx2 only when the running
                // CPU reports AVX2 support.
                let $f = |q: &[f32], d: &[f32]| unsafe { dot1_avx2(q, d) };
                $body
            }
            #[cfg(target_arch = "aarch64")]
            SimdTier::Neon => {
                // SAFETY: simd_tier() returns Neon only when the running
                // CPU reports NEON support.
                let $f = |q: &[f32], d: &[f32]| unsafe { dot1_neon(q, d) };
                $body
            }
            _ => {
                let $f = dot1_scalar;
                $body
            }
        }
    };
}

/// The scalar-tier dot product, callable regardless of the detected tier —
/// the comparator side of the scalar-vs-SIMD bit-identity gate and the
/// `bench_kernel_micro` per-tier baseline.
pub fn dot_scalar(q: &[f32], d: &[f32]) -> f32 {
    dot1_scalar(q, d)
}

/// The detected-tier dot product (what every block dispatch runs inside).
/// Bit-identical to [`dot_scalar`] on every tier — asserted in tests and
/// per bench run.
pub fn dot_detected(q: &[f32], d: &[f32]) -> f32 {
    with_dot!(f => f(q, d))
}

/// Register-blocked dot-product panel: computes `out[i*nd+j] = <q_i, d_j>`
/// for a 4-row query panel — `dj` stays hot in L1 across the 4 rows. Each
/// row's arithmetic is the tier dot `f`, so panel membership never changes
/// a bit.
#[inline]
fn dot_panel4_impl<F: Fn(&[f32], &[f32]) -> f32 + Copy>(
    f: F,
    xq: &[f32],
    xd: &[f32],
    dim: usize,
    nd: usize,
    out: &mut [f32],
) {
    // xq: [4, dim], out: [4, nd]
    let q0 = &xq[0..dim];
    let q1 = &xq[dim..2 * dim];
    let q2 = &xq[2 * dim..3 * dim];
    let q3 = &xq[3 * dim..4 * dim];
    for j in 0..nd {
        let dj = &xd[j * dim..(j + 1) * dim];
        out[j] = f(q0, dj);
        out[nd + j] = f(q1, dj);
        out[2 * nd + j] = f(q2, dj);
        out[3 * nd + j] = f(q3, dj);
    }
}

#[inline]
fn dot_row_impl<F: Fn(&[f32], &[f32]) -> f32 + Copy>(
    f: F,
    q: &[f32],
    xd: &[f32],
    dim: usize,
    nd: usize,
    out: &mut [f32],
) {
    for j in 0..nd {
        out[j] = f(q, &xd[j * dim..(j + 1) * dim]);
    }
}

/// Single-row sweep on the detected tier (the panel-tail path, exposed for
/// the panel-vs-tail bit-identity test).
fn dot_row(q: &[f32], xd: &[f32], dim: usize, nd: usize, out: &mut [f32]) {
    with_dot!(f => dot_row_impl(f, q, xd, dim, nd, out))
}

fn cross_products_impl<F: Fn(&[f32], &[f32]) -> f32 + Copy>(
    f: F,
    xq: &[f32],
    nq: usize,
    xd: &[f32],
    nd: usize,
    dim: usize,
    out: &mut [f32],
) {
    let mut i = 0;
    while i + 4 <= nq {
        dot_panel4_impl(
            f,
            &xq[i * dim..(i + 4) * dim],
            xd,
            dim,
            nd,
            &mut out[i * nd..(i + 4) * nd],
        );
        i += 4;
    }
    while i < nq {
        dot_row_impl(
            f,
            &xq[i * dim..(i + 1) * dim],
            xd,
            dim,
            nd,
            &mut out[i * nd..(i + 1) * nd],
        );
        i += 1;
    }
}

/// Fill `out` ([nq, nd]) with the raw cross products Xq·Xdᵀ on the
/// detected instruction tier. The tier is resolved once per call, and every
/// tier's arithmetic is bit-identical (see [`dot1_scalar`]).
pub fn cross_products(
    xq: &[f32],
    nq: usize,
    xd: &[f32],
    nd: usize,
    dim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xq.len(), nq * dim);
    debug_assert_eq!(xd.len(), nd * dim);
    debug_assert_eq!(out.len(), nq * nd);
    with_dot!(f => cross_products_impl(f, xq, nq, xd, nd, dim, out))
}

#[allow(clippy::too_many_arguments)] // flat block ABI; see the trait docs
impl BlockKernel for NativeKernel {
    fn kind(&self) -> KernelKind {
        self.kind
    }

    fn dispatch_fanout(&self, nq: usize, nd: usize, dim: usize, threads: usize) -> usize {
        if threads <= 1 || nq < 2 {
            return 1;
        }
        if nq.saturating_mul(nd).saturating_mul(dim) < self.par_min_madds {
            return 1;
        }
        nq.div_ceil(Self::row_chunk(nq, threads))
    }

    /// Row-panel parallel block evaluation: the output rows are cut into
    /// [`PANEL`]-aligned chunks and each chunk runs the ordinary
    /// [`BlockKernel::block`] on its own scoped worker, writing a disjoint
    /// `&mut` slice of `out`. Every row's arithmetic funnels through
    /// the same tier dot regardless of chunk or thread, so the result is
    /// bit-identical to the single-threaded sweep (property-tested below).
    fn block_par(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        threads: usize,
        out: &mut [f32],
    ) -> usize {
        let nq = q_norms.len();
        let nd = d_norms.len();
        debug_assert_eq!(out.len(), nq * nd);
        let fanout = self.dispatch_fanout(nq, nd, dim, threads);
        if fanout <= 1 {
            self.block(xq, q_norms, xd, d_norms, dim, out);
            return 1;
        }
        let jobs = super::split_row_jobs(xq, q_norms, out, dim, nd, Self::row_chunk(nq, threads));
        debug_assert_eq!(jobs.len(), fanout);
        scope_map(fanout, jobs, |_, (q, qn, o)| {
            self.block(q, qn, xd, d_norms, dim, o);
        });
        fanout
    }

    fn block(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        out: &mut [f32],
    ) {
        let nq = q_norms.len();
        let nd = d_norms.len();
        cross_products(xq, nq, xd, nd, dim, out);
        kernel_transform(self.kind, q_norms, d_norms, out);
    }
}

/// Elementwise kernel transform over a cross-product block (`out[i*nd+j]`
/// holds `<q_i, d_j>` on entry, `K(q_i, d_j)` on exit). Shared by the exact
/// [`NativeKernel::block`] and the quantized routing path
/// ([`crate::kernel::quant::QuantizedRows::block`]), so the two differ ONLY
/// in how the cross products were produced.
pub(crate) fn kernel_transform(
    kind: KernelKind,
    q_norms: &[f32],
    d_norms: &[f32],
    out: &mut [f32],
) {
    let nq = q_norms.len();
    let nd = d_norms.len();
    debug_assert_eq!(out.len(), nq * nd);
    match kind {
        KernelKind::Rbf { gamma } => {
            for i in 0..nq {
                let qn = q_norms[i];
                let row = &mut out[i * nd..(i + 1) * nd];
                for (j, v) in row.iter_mut().enumerate() {
                    let d2 = (qn + d_norms[j] - 2.0 * *v).max(0.0);
                    *v = (-gamma * d2).exp();
                }
            }
        }
        KernelKind::Poly { gamma, eta } => {
            for v in out.iter_mut() {
                let g = gamma * *v + eta;
                *v = g * g * g;
            }
        }
        KernelKind::Linear => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn rand_matrix(rng: &mut Pcg64, n: usize, d: usize) -> Vec<f32> {
        (0..n * d).map(|_| rng.next_gaussian() as f32).collect()
    }

    fn norms(x: &[f32], d: usize) -> Vec<f32> {
        x.chunks(d).map(|r| r.iter().map(|&v| v * v).sum()).collect()
    }

    #[test]
    fn block_matches_scalar_eval_all_kernels() {
        let mut rng = Pcg64::new(1);
        for kind in [
            KernelKind::Rbf { gamma: 0.7 },
            KernelKind::Poly { gamma: 0.2, eta: 0.5 },
            KernelKind::Linear,
        ] {
            let (nq, nd, d) = (7, 13, 9); // odd sizes hit the tail paths
            let xq = rand_matrix(&mut rng, nq, d);
            let xd = rand_matrix(&mut rng, nd, d);
            let k = NativeKernel::new(kind);
            let mut out = vec![0f32; nq * nd];
            k.block(&xq, &norms(&xq, d), &xd, &norms(&xd, d), d, &mut out);
            for i in 0..nq {
                for j in 0..nd {
                    let want = kind.eval(&xq[i * d..(i + 1) * d], &xd[j * d..(j + 1) * d]);
                    let got = out[i * nd + j];
                    assert!(
                        (want - got).abs() < 1e-4 * (1.0 + want.abs()),
                        "{kind:?} [{i},{j}] want {want} got {got}"
                    );
                }
            }
        }
    }

    #[test]
    fn panel_and_tail_agree() {
        // nq=6 exercises one 4-panel + 2 tail rows; results must be
        // identical to per-row evaluation.
        let mut rng = Pcg64::new(2);
        let (nq, nd, d) = (6, 5, 17);
        let xq = rand_matrix(&mut rng, nq, d);
        let xd = rand_matrix(&mut rng, nd, d);
        let mut out = vec![0f32; nq * nd];
        cross_products(&xq, nq, &xd, nd, d, &mut out);
        for i in 0..nq {
            let mut row = vec![0f32; nd];
            dot_row(&xq[i * d..(i + 1) * d], &xd, d, nd, &mut row);
            for j in 0..nd {
                // Panel and tail paths share dot1: exact equality, not
                // tolerance — the backend's bit-stability contract.
                assert_eq!(out[i * nd + j].to_bits(), row[j].to_bits(), "[{i},{j}]");
            }
        }
    }

    /// Tentpole gate: the detected SIMD tier computes bit-identical dots to
    /// the scalar kernel across lengths that hit every chunk/remainder
    /// combination (on a scalar-only host both sides are the same kernel
    /// and the assert is vacuous — CI exercises the SIMD side on x86_64).
    #[test]
    fn simd_and_scalar_dot_bit_identical() {
        let mut rng = Pcg64::new(9);
        for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 64, 100, 257] {
            let q: Vec<f32> = (0..len).map(|_| rng.next_gaussian() as f32).collect();
            let d: Vec<f32> = (0..len).map(|_| rng.next_gaussian() as f32).collect();
            let s = dot_scalar(&q, &d);
            let v = dot_detected(&q, &d);
            assert_eq!(
                s.to_bits(),
                v.to_bits(),
                "len={len} tier={}: scalar {s} vs detected {v}",
                simd_tier().name()
            );
        }
    }

    /// The detected tier is one of the named tiers and stable across calls.
    #[test]
    fn simd_tier_is_stable_and_named() {
        let t = simd_tier();
        assert_eq!(t, simd_tier());
        assert!(["scalar", "avx2", "neon"].contains(&t.name()));
    }

    /// Tentpole guarantee: the row-panel parallel dispatch is bit-identical
    /// to the single-threaded sweep for every thread count, across random
    /// shapes (including rows that land in panel tails and chunk tails),
    /// and actually fans out when asked to.
    #[test]
    fn prop_block_par_bit_identical_any_thread_count() {
        use crate::prop_assert;
        use crate::util::proptest::check;
        check("block-par-bit-identity", 10, |rng: &mut Pcg64| {
            let nq = 1 + rng.below(40);
            let nd = 1 + rng.below(30);
            let d = 1 + rng.below(24);
            let threads = 1 + rng.below(8);
            let kind = if rng.next_f64() < 0.5 {
                KernelKind::Rbf { gamma: (0.2 + 4.0 * rng.next_f64()) as f32 }
            } else {
                KernelKind::Poly { gamma: (0.1 + rng.next_f64()) as f32, eta: 0.4 }
            };
            // Threshold 1 forces the parallel path on these small blocks.
            let k = NativeKernel::with_par_threshold(kind, 1);
            let xq = rand_matrix(rng, nq, d);
            let xd = rand_matrix(rng, nd, d);
            let (qn, dn) = (norms(&xq, d), norms(&xd, d));
            let mut serial = vec![0f32; nq * nd];
            k.block(&xq, &qn, &xd, &dn, d, &mut serial);
            let mut par = vec![0f32; nq * nd];
            let used = k.block_par(&xq, &qn, &xd, &dn, d, threads, &mut par);
            prop_assert!(
                used == k.dispatch_fanout(nq, nd, d, threads),
                "block_par used {used} chunks, fanout promised {}",
                k.dispatch_fanout(nq, nd, d, threads)
            );
            for (t, (a, b)) in serial.iter().zip(&par).enumerate() {
                prop_assert!(
                    a.to_bits() == b.to_bits(),
                    "entry {t} differs at {threads} threads: {a} vs {b}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn block_par_fans_out_above_threshold_only() {
        let mut rng = Pcg64::new(6);
        let kind = KernelKind::Rbf { gamma: 1.0 };
        let (nq, nd, d) = (16, 8, 5);
        let xq = rand_matrix(&mut rng, nq, d);
        let xd = rand_matrix(&mut rng, nd, d);
        let (qn, dn) = (norms(&xq, d), norms(&xd, d));
        let mut out = vec![0f32; nq * nd];
        // Default threshold: this block is far too small to fan out.
        let k = NativeKernel::new(kind);
        assert_eq!(k.block_par(&xq, &qn, &xd, &dn, d, 4, &mut out), 1);
        assert_eq!(k.dispatch_fanout(nq, nd, d, 4), 1);
        // Forced threshold: 16 rows at 4 threads = 4 panel-aligned chunks.
        let k = NativeKernel::with_par_threshold(kind, 1);
        assert_eq!(k.dispatch_fanout(nq, nd, d, 4), 4);
        assert_eq!(k.block_par(&xq, &qn, &xd, &dn, d, 4, &mut out), 4);
        // One thread or one row never fans out, threshold notwithstanding.
        assert_eq!(k.dispatch_fanout(nq, nd, d, 1), 1);
        assert_eq!(k.dispatch_fanout(1, nd, d, 4), 1);
    }

    #[test]
    fn decision_par_bit_identical_to_decision() {
        let mut rng = Pcg64::new(7);
        let kind = KernelKind::Rbf { gamma: 0.8 };
        let (nq, nd, d) = (23, 17, 9);
        let xq = rand_matrix(&mut rng, nq, d);
        let xd = rand_matrix(&mut rng, nd, d);
        let (qn, dn) = (norms(&xq, d), norms(&xd, d));
        let coef: Vec<f32> = (0..nd).map(|_| rng.next_gaussian() as f32).collect();
        let k = NativeKernel::with_par_threshold(kind, 1);
        let mut serial = vec![0f32; nq];
        k.decision(&xq, &qn, &xd, &dn, d, &coef, &mut serial);
        let mut par = vec![0f32; nq];
        let used = k.decision_par(&xq, &qn, &xd, &dn, d, &coef, 4, &mut par);
        assert!(used > 1, "decision_par stayed serial with a forced threshold");
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn default_decision_matches_manual() {
        let mut rng = Pcg64::new(3);
        let (nq, nd, d) = (5, 11, 4);
        let xq = rand_matrix(&mut rng, nq, d);
        let xd = rand_matrix(&mut rng, nd, d);
        let coef: Vec<f32> = (0..nd).map(|_| rng.next_gaussian() as f32).collect();
        let k = NativeKernel::new(KernelKind::Rbf { gamma: 1.2 });
        let mut dv = vec![0f32; nq];
        k.decision(&xq, &norms(&xq, d), &xd, &norms(&xd, d), d, &coef, &mut dv);
        for i in 0..nq {
            let want: f32 = (0..nd)
                .map(|j| {
                    coef[j]
                        * k.kind.eval(&xq[i * d..(i + 1) * d], &xd[j * d..(j + 1) * d])
                })
                .sum();
            assert!((dv[i] - want).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn rbf_block_is_symmetric_psd_spot() {
        let mut rng = Pcg64::new(4);
        let (n, d) = (16, 6);
        let x = rand_matrix(&mut rng, n, d);
        let nn = norms(&x, d);
        let k = NativeKernel::new(KernelKind::Rbf { gamma: 0.4 });
        let mut km = vec![0f32; n * n];
        k.block(&x, &nn, &x, &nn, d, &mut km);
        // symmetry
        for i in 0..n {
            for j in 0..n {
                assert!((km[i * n + j] - km[j * n + i]).abs() < 1e-6);
            }
            assert!((km[i * n + i] - 1.0).abs() < 1e-6);
        }
        // PSD spot-check: vᵀKv >= 0 for random v
        for _ in 0..5 {
            let v: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32).collect();
            let mut quad = 0f64;
            for i in 0..n {
                for j in 0..n {
                    quad += (v[i] * km[i * n + j] * v[j]) as f64;
                }
            }
            assert!(quad > -1e-5, "quad={quad}");
        }
    }
}
