//! Pure-Rust blocked kernel evaluation (reference backend).
//!
//! Mirrors the math of the Pallas kernels exactly (python/compile/kernels):
//! the cross term is a register-blocked GEMM micro-kernel over the feature
//! dimension, followed by the elementwise kernel transform. Used as the
//! always-available backend, the oracle the PJRT backend is property-tested
//! against, and the comparator in `bench_kernel_micro`.

use super::{BlockKernel, KernelKind};

/// Native (CPU, pure Rust) block kernel.
#[derive(Clone, Copy, Debug)]
pub struct NativeKernel {
    pub kind: KernelKind,
}

impl NativeKernel {
    pub fn new(kind: KernelKind) -> Self {
        NativeKernel { kind }
    }
}

/// Register-blocked dot-product panel: computes `out[i*nd+j] = <q_i, d_j>`
/// for a 4-row query panel, letting the compiler keep 4 accumulators live.
#[inline]
fn dot_panel4(xq: &[f32], xd: &[f32], dim: usize, nd: usize, out: &mut [f32]) {
    // xq: [4, dim], out: [4, nd]
    for j in 0..nd {
        let dj = &xd[j * dim..(j + 1) * dim];
        let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
        let q0 = &xq[0..dim];
        let q1 = &xq[dim..2 * dim];
        let q2 = &xq[2 * dim..3 * dim];
        let q3 = &xq[3 * dim..4 * dim];
        for t in 0..dim {
            let d = dj[t];
            a0 += q0[t] * d;
            a1 += q1[t] * d;
            a2 += q2[t] * d;
            a3 += q3[t] * d;
        }
        out[j] = a0;
        out[nd + j] = a1;
        out[2 * nd + j] = a2;
        out[3 * nd + j] = a3;
    }
}

#[inline]
fn dot_row(q: &[f32], xd: &[f32], dim: usize, nd: usize, out: &mut [f32]) {
    for j in 0..nd {
        let dj = &xd[j * dim..(j + 1) * dim];
        let mut acc = 0f32;
        for t in 0..dim {
            acc += q[t] * dj[t];
        }
        out[j] = acc;
    }
}

/// Fill `out` ([nq, nd]) with the raw cross products Xq·Xdᵀ.
pub fn cross_products(
    xq: &[f32],
    nq: usize,
    xd: &[f32],
    nd: usize,
    dim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xq.len(), nq * dim);
    debug_assert_eq!(xd.len(), nd * dim);
    debug_assert_eq!(out.len(), nq * nd);
    let mut i = 0;
    while i + 4 <= nq {
        dot_panel4(
            &xq[i * dim..(i + 4) * dim],
            xd,
            dim,
            nd,
            &mut out[i * nd..(i + 4) * nd],
        );
        i += 4;
    }
    while i < nq {
        dot_row(&xq[i * dim..(i + 1) * dim], xd, dim, nd, &mut out[i * nd..(i + 1) * nd]);
        i += 1;
    }
}

#[allow(clippy::too_many_arguments)] // flat block ABI; see the trait docs
impl BlockKernel for NativeKernel {
    fn kind(&self) -> KernelKind {
        self.kind
    }

    fn block(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        out: &mut [f32],
    ) {
        let nq = q_norms.len();
        let nd = d_norms.len();
        cross_products(xq, nq, xd, nd, dim, out);
        match self.kind {
            KernelKind::Rbf { gamma } => {
                for i in 0..nq {
                    let qn = q_norms[i];
                    let row = &mut out[i * nd..(i + 1) * nd];
                    for (j, v) in row.iter_mut().enumerate() {
                        let d2 = (qn + d_norms[j] - 2.0 * *v).max(0.0);
                        *v = (-gamma * d2).exp();
                    }
                }
            }
            KernelKind::Poly { gamma, eta } => {
                for v in out.iter_mut() {
                    let g = gamma * *v + eta;
                    *v = g * g * g;
                }
            }
            KernelKind::Linear => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn rand_matrix(rng: &mut Pcg64, n: usize, d: usize) -> Vec<f32> {
        (0..n * d).map(|_| rng.next_gaussian() as f32).collect()
    }

    fn norms(x: &[f32], d: usize) -> Vec<f32> {
        x.chunks(d).map(|r| r.iter().map(|&v| v * v).sum()).collect()
    }

    #[test]
    fn block_matches_scalar_eval_all_kernels() {
        let mut rng = Pcg64::new(1);
        for kind in [
            KernelKind::Rbf { gamma: 0.7 },
            KernelKind::Poly { gamma: 0.2, eta: 0.5 },
            KernelKind::Linear,
        ] {
            let (nq, nd, d) = (7, 13, 9); // odd sizes hit the tail paths
            let xq = rand_matrix(&mut rng, nq, d);
            let xd = rand_matrix(&mut rng, nd, d);
            let k = NativeKernel::new(kind);
            let mut out = vec![0f32; nq * nd];
            k.block(&xq, &norms(&xq, d), &xd, &norms(&xd, d), d, &mut out);
            for i in 0..nq {
                for j in 0..nd {
                    let want = kind.eval(&xq[i * d..(i + 1) * d], &xd[j * d..(j + 1) * d]);
                    let got = out[i * nd + j];
                    assert!(
                        (want - got).abs() < 1e-4 * (1.0 + want.abs()),
                        "{kind:?} [{i},{j}] want {want} got {got}"
                    );
                }
            }
        }
    }

    #[test]
    fn panel_and_tail_agree() {
        // nq=6 exercises one 4-panel + 2 tail rows; results must be
        // identical to per-row evaluation.
        let mut rng = Pcg64::new(2);
        let (nq, nd, d) = (6, 5, 17);
        let xq = rand_matrix(&mut rng, nq, d);
        let xd = rand_matrix(&mut rng, nd, d);
        let mut out = vec![0f32; nq * nd];
        cross_products(&xq, nq, &xd, nd, d, &mut out);
        for i in 0..nq {
            let mut row = vec![0f32; nd];
            dot_row(&xq[i * d..(i + 1) * d], &xd, d, nd, &mut row);
            for j in 0..nd {
                assert!((out[i * nd + j] - row[j]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn default_decision_matches_manual() {
        let mut rng = Pcg64::new(3);
        let (nq, nd, d) = (5, 11, 4);
        let xq = rand_matrix(&mut rng, nq, d);
        let xd = rand_matrix(&mut rng, nd, d);
        let coef: Vec<f32> = (0..nd).map(|_| rng.next_gaussian() as f32).collect();
        let k = NativeKernel::new(KernelKind::Rbf { gamma: 1.2 });
        let mut dv = vec![0f32; nq];
        k.decision(&xq, &norms(&xq, d), &xd, &norms(&xd, d), d, &coef, &mut dv);
        for i in 0..nq {
            let want: f32 = (0..nd)
                .map(|j| {
                    coef[j]
                        * k.kind.eval(&xq[i * d..(i + 1) * d], &xd[j * d..(j + 1) * d])
                })
                .sum();
            assert!((dv[i] - want).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn rbf_block_is_symmetric_psd_spot() {
        let mut rng = Pcg64::new(4);
        let (n, d) = (16, 6);
        let x = rand_matrix(&mut rng, n, d);
        let nn = norms(&x, d);
        let k = NativeKernel::new(KernelKind::Rbf { gamma: 0.4 });
        let mut km = vec![0f32; n * n];
        k.block(&x, &nn, &x, &nn, d, &mut km);
        // symmetry
        for i in 0..n {
            for j in 0..n {
                assert!((km[i * n + j] - km[j * n + i]).abs() < 1e-6);
            }
            assert!((km[i * n + i] - 1.0).abs() < 1e-6);
        }
        // PSD spot-check: vᵀKv >= 0 for random v
        for _ in 0..5 {
            let v: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32).collect();
            let mut quad = 0f64;
            for i in 0..n {
                for j in 0..n {
                    quad += (v[i] * km[i * n + j] * v[j]) as f64;
                }
            }
            assert!(quad > -1e-5, "quad={quad}");
        }
    }
}
