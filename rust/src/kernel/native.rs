//! Pure-Rust blocked kernel evaluation (reference backend).
//!
//! Mirrors the math of the Pallas kernels exactly (python/compile/kernels):
//! the cross term is a register-blocked GEMM micro-kernel over the feature
//! dimension, followed by the elementwise kernel transform. Used as the
//! always-available backend, the oracle the PJRT backend is property-tested
//! against, and the comparator in `bench_kernel_micro`.

use super::{BlockKernel, KernelKind};
use crate::util::threadpool::scope_map;

/// Output-row panel width: the register-blocked micro-kernel processes 4
/// query rows at a time, and parallel row chunks are cut at multiples of
/// this so every chunk panels exactly like the serial sweep.
const PANEL: usize = 4;

/// Independent accumulator lanes of [`dot1`] (fixed — part of the
/// arithmetic contract, see the `dot1` docs).
const LANES: usize = 4;

/// Multiply-add count (`nq · nd · dim`) below which a block dispatch stays
/// single-threaded: small dispatches (the solver's per-row fetches, tiny
/// cluster blocks) finish faster than scoped workers spawn.
pub const PAR_MIN_MADDS: usize = 1 << 20;

/// Native (CPU, pure Rust) block kernel.
#[derive(Clone, Copy, Debug)]
pub struct NativeKernel {
    pub kind: KernelKind,
    /// Madds threshold for row-panel parallel dispatch
    /// ([`PAR_MIN_MADDS`]; tests force tiny blocks parallel by lowering it).
    par_min_madds: usize,
}

impl NativeKernel {
    pub fn new(kind: KernelKind) -> Self {
        NativeKernel { kind, par_min_madds: PAR_MIN_MADDS }
    }

    /// [`Self::new`] with an explicit parallel-dispatch threshold in
    /// multiply-adds (`nq · nd · dim`); tests use 1 to force the parallel
    /// path on small blocks.
    pub fn with_par_threshold(kind: KernelKind, par_min_madds: usize) -> Self {
        NativeKernel { kind, par_min_madds: par_min_madds.max(1) }
    }

    /// Rows per parallel chunk for an `nq`-row dispatch at `threads`
    /// workers: the even split rounded up to a [`PANEL`] multiple, so
    /// chunked sweeps panel rows exactly like the serial sweep.
    fn row_chunk(nq: usize, threads: usize) -> usize {
        nq.div_ceil(threads.max(1).min(nq.max(1))).div_ceil(PANEL) * PANEL
    }
}

/// One dot product `<q, d>` — THE inner kernel every block evaluation in
/// this backend funnels through, whatever the dispatch shape, panel
/// position, or thread. `chunks_exact` gives the compiler fixed-length
/// bounds-check-free bodies it can unroll/vectorize, and the [`LANES`]
/// independent accumulators (reduced pairwise, then the remainder added
/// sequentially) make the accumulation order a pure function of
/// `(q, d, dim)` — which is exactly why kernel entries are bit-identical
/// across full-row vs segment dispatches and 1 vs N threads.
#[inline]
fn dot1(q: &[f32], d: &[f32]) -> f32 {
    debug_assert_eq!(q.len(), d.len());
    let mut lanes = [0f32; LANES];
    let mut qc = q.chunks_exact(LANES);
    let mut dc = d.chunks_exact(LANES);
    for (qs, ds) in qc.by_ref().zip(dc.by_ref()) {
        for ((lane, &qv), &dv) in lanes.iter_mut().zip(qs).zip(ds) {
            *lane += qv * dv;
        }
    }
    let mut acc = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
    for (&qv, &dv) in qc.remainder().iter().zip(dc.remainder()) {
        acc += qv * dv;
    }
    acc
}

/// Register-blocked dot-product panel: computes `out[i*nd+j] = <q_i, d_j>`
/// for a 4-row query panel — `dj` stays hot in L1 across the 4 rows. Each
/// row's arithmetic is [`dot1`], so panel membership never changes a bit.
#[inline]
fn dot_panel4(xq: &[f32], xd: &[f32], dim: usize, nd: usize, out: &mut [f32]) {
    // xq: [4, dim], out: [4, nd]
    let q0 = &xq[0..dim];
    let q1 = &xq[dim..2 * dim];
    let q2 = &xq[2 * dim..3 * dim];
    let q3 = &xq[3 * dim..4 * dim];
    for j in 0..nd {
        let dj = &xd[j * dim..(j + 1) * dim];
        out[j] = dot1(q0, dj);
        out[nd + j] = dot1(q1, dj);
        out[2 * nd + j] = dot1(q2, dj);
        out[3 * nd + j] = dot1(q3, dj);
    }
}

#[inline]
fn dot_row(q: &[f32], xd: &[f32], dim: usize, nd: usize, out: &mut [f32]) {
    for j in 0..nd {
        out[j] = dot1(q, &xd[j * dim..(j + 1) * dim]);
    }
}

/// Fill `out` ([nq, nd]) with the raw cross products Xq·Xdᵀ.
pub fn cross_products(
    xq: &[f32],
    nq: usize,
    xd: &[f32],
    nd: usize,
    dim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xq.len(), nq * dim);
    debug_assert_eq!(xd.len(), nd * dim);
    debug_assert_eq!(out.len(), nq * nd);
    let mut i = 0;
    while i + 4 <= nq {
        dot_panel4(
            &xq[i * dim..(i + 4) * dim],
            xd,
            dim,
            nd,
            &mut out[i * nd..(i + 4) * nd],
        );
        i += 4;
    }
    while i < nq {
        dot_row(&xq[i * dim..(i + 1) * dim], xd, dim, nd, &mut out[i * nd..(i + 1) * nd]);
        i += 1;
    }
}

#[allow(clippy::too_many_arguments)] // flat block ABI; see the trait docs
impl BlockKernel for NativeKernel {
    fn kind(&self) -> KernelKind {
        self.kind
    }

    fn dispatch_fanout(&self, nq: usize, nd: usize, dim: usize, threads: usize) -> usize {
        if threads <= 1 || nq < 2 {
            return 1;
        }
        if nq.saturating_mul(nd).saturating_mul(dim) < self.par_min_madds {
            return 1;
        }
        nq.div_ceil(Self::row_chunk(nq, threads))
    }

    /// Row-panel parallel block evaluation: the output rows are cut into
    /// [`PANEL`]-aligned chunks and each chunk runs the ordinary
    /// [`BlockKernel::block`] on its own scoped worker, writing a disjoint
    /// `&mut` slice of `out`. Every row's arithmetic funnels through
    /// [`dot1`] regardless of chunk or thread, so the result is
    /// bit-identical to the single-threaded sweep (property-tested below).
    fn block_par(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        threads: usize,
        out: &mut [f32],
    ) -> usize {
        let nq = q_norms.len();
        let nd = d_norms.len();
        debug_assert_eq!(out.len(), nq * nd);
        let fanout = self.dispatch_fanout(nq, nd, dim, threads);
        if fanout <= 1 {
            self.block(xq, q_norms, xd, d_norms, dim, out);
            return 1;
        }
        let jobs = super::split_row_jobs(xq, q_norms, out, dim, nd, Self::row_chunk(nq, threads));
        debug_assert_eq!(jobs.len(), fanout);
        scope_map(fanout, jobs, |_, (q, qn, o)| {
            self.block(q, qn, xd, d_norms, dim, o);
        });
        fanout
    }

    fn block(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        out: &mut [f32],
    ) {
        let nq = q_norms.len();
        let nd = d_norms.len();
        cross_products(xq, nq, xd, nd, dim, out);
        match self.kind {
            KernelKind::Rbf { gamma } => {
                for i in 0..nq {
                    let qn = q_norms[i];
                    let row = &mut out[i * nd..(i + 1) * nd];
                    for (j, v) in row.iter_mut().enumerate() {
                        let d2 = (qn + d_norms[j] - 2.0 * *v).max(0.0);
                        *v = (-gamma * d2).exp();
                    }
                }
            }
            KernelKind::Poly { gamma, eta } => {
                for v in out.iter_mut() {
                    let g = gamma * *v + eta;
                    *v = g * g * g;
                }
            }
            KernelKind::Linear => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn rand_matrix(rng: &mut Pcg64, n: usize, d: usize) -> Vec<f32> {
        (0..n * d).map(|_| rng.next_gaussian() as f32).collect()
    }

    fn norms(x: &[f32], d: usize) -> Vec<f32> {
        x.chunks(d).map(|r| r.iter().map(|&v| v * v).sum()).collect()
    }

    #[test]
    fn block_matches_scalar_eval_all_kernels() {
        let mut rng = Pcg64::new(1);
        for kind in [
            KernelKind::Rbf { gamma: 0.7 },
            KernelKind::Poly { gamma: 0.2, eta: 0.5 },
            KernelKind::Linear,
        ] {
            let (nq, nd, d) = (7, 13, 9); // odd sizes hit the tail paths
            let xq = rand_matrix(&mut rng, nq, d);
            let xd = rand_matrix(&mut rng, nd, d);
            let k = NativeKernel::new(kind);
            let mut out = vec![0f32; nq * nd];
            k.block(&xq, &norms(&xq, d), &xd, &norms(&xd, d), d, &mut out);
            for i in 0..nq {
                for j in 0..nd {
                    let want = kind.eval(&xq[i * d..(i + 1) * d], &xd[j * d..(j + 1) * d]);
                    let got = out[i * nd + j];
                    assert!(
                        (want - got).abs() < 1e-4 * (1.0 + want.abs()),
                        "{kind:?} [{i},{j}] want {want} got {got}"
                    );
                }
            }
        }
    }

    #[test]
    fn panel_and_tail_agree() {
        // nq=6 exercises one 4-panel + 2 tail rows; results must be
        // identical to per-row evaluation.
        let mut rng = Pcg64::new(2);
        let (nq, nd, d) = (6, 5, 17);
        let xq = rand_matrix(&mut rng, nq, d);
        let xd = rand_matrix(&mut rng, nd, d);
        let mut out = vec![0f32; nq * nd];
        cross_products(&xq, nq, &xd, nd, d, &mut out);
        for i in 0..nq {
            let mut row = vec![0f32; nd];
            dot_row(&xq[i * d..(i + 1) * d], &xd, d, nd, &mut row);
            for j in 0..nd {
                // Panel and tail paths share dot1: exact equality, not
                // tolerance — the backend's bit-stability contract.
                assert_eq!(out[i * nd + j].to_bits(), row[j].to_bits(), "[{i},{j}]");
            }
        }
    }

    /// Tentpole guarantee: the row-panel parallel dispatch is bit-identical
    /// to the single-threaded sweep for every thread count, across random
    /// shapes (including rows that land in panel tails and chunk tails),
    /// and actually fans out when asked to.
    #[test]
    fn prop_block_par_bit_identical_any_thread_count() {
        use crate::prop_assert;
        use crate::util::proptest::check;
        check("block-par-bit-identity", 10, |rng: &mut Pcg64| {
            let nq = 1 + rng.below(40);
            let nd = 1 + rng.below(30);
            let d = 1 + rng.below(24);
            let threads = 1 + rng.below(8);
            let kind = if rng.next_f64() < 0.5 {
                KernelKind::Rbf { gamma: (0.2 + 4.0 * rng.next_f64()) as f32 }
            } else {
                KernelKind::Poly { gamma: (0.1 + rng.next_f64()) as f32, eta: 0.4 }
            };
            // Threshold 1 forces the parallel path on these small blocks.
            let k = NativeKernel::with_par_threshold(kind, 1);
            let xq = rand_matrix(rng, nq, d);
            let xd = rand_matrix(rng, nd, d);
            let (qn, dn) = (norms(&xq, d), norms(&xd, d));
            let mut serial = vec![0f32; nq * nd];
            k.block(&xq, &qn, &xd, &dn, d, &mut serial);
            let mut par = vec![0f32; nq * nd];
            let used = k.block_par(&xq, &qn, &xd, &dn, d, threads, &mut par);
            prop_assert!(
                used == k.dispatch_fanout(nq, nd, d, threads),
                "block_par used {used} chunks, fanout promised {}",
                k.dispatch_fanout(nq, nd, d, threads)
            );
            for (t, (a, b)) in serial.iter().zip(&par).enumerate() {
                prop_assert!(
                    a.to_bits() == b.to_bits(),
                    "entry {t} differs at {threads} threads: {a} vs {b}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn block_par_fans_out_above_threshold_only() {
        let mut rng = Pcg64::new(6);
        let kind = KernelKind::Rbf { gamma: 1.0 };
        let (nq, nd, d) = (16, 8, 5);
        let xq = rand_matrix(&mut rng, nq, d);
        let xd = rand_matrix(&mut rng, nd, d);
        let (qn, dn) = (norms(&xq, d), norms(&xd, d));
        let mut out = vec![0f32; nq * nd];
        // Default threshold: this block is far too small to fan out.
        let k = NativeKernel::new(kind);
        assert_eq!(k.block_par(&xq, &qn, &xd, &dn, d, 4, &mut out), 1);
        assert_eq!(k.dispatch_fanout(nq, nd, d, 4), 1);
        // Forced threshold: 16 rows at 4 threads = 4 panel-aligned chunks.
        let k = NativeKernel::with_par_threshold(kind, 1);
        assert_eq!(k.dispatch_fanout(nq, nd, d, 4), 4);
        assert_eq!(k.block_par(&xq, &qn, &xd, &dn, d, 4, &mut out), 4);
        // One thread or one row never fans out, threshold notwithstanding.
        assert_eq!(k.dispatch_fanout(nq, nd, d, 1), 1);
        assert_eq!(k.dispatch_fanout(1, nd, d, 4), 1);
    }

    #[test]
    fn decision_par_bit_identical_to_decision() {
        let mut rng = Pcg64::new(7);
        let kind = KernelKind::Rbf { gamma: 0.8 };
        let (nq, nd, d) = (23, 17, 9);
        let xq = rand_matrix(&mut rng, nq, d);
        let xd = rand_matrix(&mut rng, nd, d);
        let (qn, dn) = (norms(&xq, d), norms(&xd, d));
        let coef: Vec<f32> = (0..nd).map(|_| rng.next_gaussian() as f32).collect();
        let k = NativeKernel::with_par_threshold(kind, 1);
        let mut serial = vec![0f32; nq];
        k.decision(&xq, &qn, &xd, &dn, d, &coef, &mut serial);
        let mut par = vec![0f32; nq];
        let used = k.decision_par(&xq, &qn, &xd, &dn, d, &coef, 4, &mut par);
        assert!(used > 1, "decision_par stayed serial with a forced threshold");
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn default_decision_matches_manual() {
        let mut rng = Pcg64::new(3);
        let (nq, nd, d) = (5, 11, 4);
        let xq = rand_matrix(&mut rng, nq, d);
        let xd = rand_matrix(&mut rng, nd, d);
        let coef: Vec<f32> = (0..nd).map(|_| rng.next_gaussian() as f32).collect();
        let k = NativeKernel::new(KernelKind::Rbf { gamma: 1.2 });
        let mut dv = vec![0f32; nq];
        k.decision(&xq, &norms(&xq, d), &xd, &norms(&xd, d), d, &coef, &mut dv);
        for i in 0..nq {
            let want: f32 = (0..nd)
                .map(|j| {
                    coef[j]
                        * k.kind.eval(&xq[i * d..(i + 1) * d], &xd[j * d..(j + 1) * d])
                })
                .sum();
            assert!((dv[i] - want).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn rbf_block_is_symmetric_psd_spot() {
        let mut rng = Pcg64::new(4);
        let (n, d) = (16, 6);
        let x = rand_matrix(&mut rng, n, d);
        let nn = norms(&x, d);
        let k = NativeKernel::new(KernelKind::Rbf { gamma: 0.4 });
        let mut km = vec![0f32; n * n];
        k.block(&x, &nn, &x, &nn, d, &mut km);
        // symmetry
        for i in 0..n {
            for j in 0..n {
                assert!((km[i * n + j] - km[j * n + i]).abs() < 1e-6);
            }
            assert!((km[i * n + i] - 1.0).abs() < 1e-6);
        }
        // PSD spot-check: vᵀKv >= 0 for random v
        for _ in 0..5 {
            let v: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32).collect();
            let mut quad = 0f64;
            for i in 0..n {
                for j in 0..n {
                    quad += (v[i] * km[i * n + j] * v[j]) as f64;
                }
            }
            assert!(quad > -1e-5, "quad={quad}");
        }
    }
}
