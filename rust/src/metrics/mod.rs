//! Evaluation metrics: accuracy, SV-set precision/recall (Figure 2),
//! relative objective error (Figure 3), and whole-problem objective
//! evaluation for arbitrary α (level snapshots).

use crate::data::Dataset;
use crate::kernel::BlockKernel;

/// Classification accuracy of predictions vs labels.
pub fn accuracy(preds: &[i8], labels: &[i8]) -> f64 {
    assert_eq!(preds.len(), labels.len());
    if preds.is_empty() {
        return 0.0;
    }
    preds.iter().zip(labels).filter(|(p, y)| p == y).count() as f64 / preds.len() as f64
}

/// Precision/recall of an estimated SV set vs the reference SV set
/// (paper Figure 2: how well lower levels identify the true SVs).
pub fn sv_precision_recall(alpha_est: &[f64], alpha_ref: &[f64]) -> (f64, f64) {
    assert_eq!(alpha_est.len(), alpha_ref.len());
    let mut tp = 0usize;
    let mut est = 0usize;
    let mut refn = 0usize;
    for (&a, &r) in alpha_est.iter().zip(alpha_ref) {
        let e = a > 0.0;
        let t = r > 0.0;
        est += e as usize;
        refn += t as usize;
        tp += (e && t) as usize;
    }
    let precision = if est == 0 { 1.0 } else { tp as f64 / est as f64 };
    let recall = if refn == 0 { 1.0 } else { tp as f64 / refn as f64 };
    (precision, recall)
}

/// Relative objective error (f − f*)/|f*| (Figure 3 y-axis).
pub fn relative_error(f: f64, f_star: f64) -> f64 {
    (f - f_star).abs() / f_star.abs().max(1e-30)
}

/// Whole-problem dual objective f(α) = ½αᵀQα − eᵀα evaluated from scratch.
/// Cost O(|S|·n̂) where n̂ = |S| (only SV rows contribute to the quadratic
/// term) — fine for snapshot evaluation.
pub fn objective_of(ds: &Dataset, kernel: &dyn BlockKernel, alpha: &[f64]) -> f64 {
    let n = ds.len();
    assert_eq!(alpha.len(), n);
    let dim = ds.dim;
    let sv: Vec<usize> = (0..n).filter(|&i| alpha[i] != 0.0).collect();
    let lin: f64 = alpha.iter().sum();
    if sv.is_empty() {
        return 0.0;
    }
    // Gather SV rows + coef.
    let mut x = Vec::with_capacity(sv.len() * dim);
    let mut norms = Vec::with_capacity(sv.len());
    let mut coef = Vec::with_capacity(sv.len());
    for &i in &sv {
        x.extend_from_slice(ds.row(i));
        norms.push(ds.row(i).iter().map(|&v| v * v).sum());
        coef.push((alpha[i] * ds.y[i] as f64) as f32);
    }
    // dv_i = Σ_j coef_j K(sv_i, sv_j); quad = Σ_i coef_i · dv_i
    let mut dv = vec![0f32; sv.len()];
    kernel.decision(&x, &norms, &x, &norms, dim, &coef, &mut dv);
    let quad: f64 = dv
        .iter()
        .zip(&coef)
        .map(|(&d, &c)| d as f64 * c as f64)
        .sum();
    0.5 * quad - lin
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate};
    use crate::kernel::{native::NativeKernel, KernelKind};
    use crate::solver::objective::{dense_q, objective_dense};
    use crate::util::prng::Pcg64;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, -1, 1], &[1, 1, 1]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn precision_recall_cases() {
        let est = [0.5, 0.0, 0.3, 0.0];
        let rf = [0.2, 0.2, 0.0, 0.0];
        let (p, r) = sv_precision_recall(&est, &rf);
        assert!((p - 0.5).abs() < 1e-12); // 1 of 2 est SVs is true
        assert!((r - 0.5).abs() < 1e-12); // 1 of 2 true SVs found
        let (p0, r0) = sv_precision_recall(&[0.0, 0.0], &[0.0, 0.0]);
        assert_eq!((p0, r0), (1.0, 1.0));
    }

    #[test]
    fn objective_of_matches_dense() {
        let mut rng = Pcg64::new(21);
        let ds = generate(&covtype_like(), 40, &mut rng);
        let kind = KernelKind::Rbf { gamma: 4.0 };
        let kern = NativeKernel::new(kind);
        let alpha: Vec<f64> = (0..40)
            .map(|_| if rng.next_f64() < 0.5 { rng.next_f64() } else { 0.0 })
            .collect();
        let got = objective_of(&ds, &kern, &alpha);
        let q = dense_q(&ds, &kern);
        let want = objective_dense(&q, &alpha);
        assert!(
            (got - want).abs() < 1e-4 * (1.0 + want.abs()),
            "{got} vs {want}"
        );
    }

    #[test]
    fn relative_error_basic() {
        assert!((relative_error(-9.9, -10.0) - 0.01).abs() < 1e-12);
    }
}
