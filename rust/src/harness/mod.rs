//! Run harness: one entry point that trains any [`Algo`] on a dataset pair
//! and reports the paper's metrics (train time, test accuracy, objective,
//! SV count). Used by the CLI, the examples, and every bench.
//!
//! The harness builds [`KernelContext`]s for the datasets it touches: one
//! per training set where the algorithm consumes kernel rows/norms, and one
//! per test set so prediction paths read precomputed norms and dispatch
//! batched kernel blocks through the same backend.

use std::sync::OnceLock;

use anyhow::{bail, Result};

use crate::baselines::{cascade, fastfood, lasvm, llsvm, ltpu, spsvm};
use crate::cache::KernelContext;
use crate::config::{Algo, RunConfig};
use crate::data::Dataset;
use crate::dcsvm;
use crate::kernel::{native::NativeKernel, BlockKernel, KernelKind};
use crate::predict::SvmModel;
use crate::runtime::{Engine, PjrtKernel};
use crate::solver::SmoSolver;

static ENGINE: OnceLock<Option<Engine>> = OnceLock::new();

/// The process-wide PJRT engine (compiled once), or None when artifacts are
/// not built / not loadable.
pub fn global_engine() -> Option<&'static Engine> {
    ENGINE.get_or_init(Engine::load_default).as_ref()
}

/// Build a kernel backend. `mode`: "native", "pjrt", or "auto" (pjrt when
/// artifacts are present and the feature dim fits, else native).
pub fn make_kernel(kind: KernelKind, mode: &str, dim: usize) -> Result<Box<dyn BlockKernel + 'static>> {
    match mode {
        "native" => Ok(Box::new(NativeKernel::new(kind))),
        "pjrt" => match global_engine() {
            Some(e) if dim <= e.abi().d_pad => Ok(Box::new(PjrtKernel::new(e, kind))),
            Some(e) => bail!("dataset dim {dim} exceeds artifact d_pad {}", e.abi().d_pad),
            None => bail!("pjrt backend requested but artifacts/ not available"),
        },
        "auto" => Ok(match global_engine() {
            Some(e) if dim <= e.abi().d_pad => Box::new(PjrtKernel::new(e, kind)),
            _ => Box::new(NativeKernel::new(kind)),
        }),
        other => bail!("unknown backend '{other}'"),
    }
}

/// Uniform outcome record (a row of the paper's tables).
#[derive(Clone, Debug)]
pub struct Outcome {
    pub algo: &'static str,
    pub train_s: f64,
    pub accuracy: f64,
    /// Whole-problem dual objective (exact algos only).
    pub objective: Option<f64>,
    pub svs: usize,
    pub note: String,
}

/// Train `cfg.algo` on (`tr`, `te`) and measure.
pub fn run(cfg: &RunConfig, tr: &Dataset, te: &Dataset) -> Result<Outcome> {
    let kind = cfg.kernel_kind()?;
    let kernel = make_kernel(kind, &cfg.backend, tr.dim)?;
    let cache_bytes = cfg.cache_mb << 20;
    // Test-set context: precomputed norms + batched dispatch for the
    // kernel-model prediction paths (the row cache is unused on the predict
    // side, so the budget is nominal). The random-feature baselines
    // (fastfood/ltpu) never consume test norms, so skip it for them.
    let te_ctx_opt = match cfg.algo {
        Algo::Fastfood | Algo::Ltpu => None,
        _ => Some(KernelContext::new(te, kernel.as_ref(), 1 << 20)),
    };
    let t0 = std::time::Instant::now();

    let outcome = match cfg.algo {
        Algo::Libsvm => {
            let te_ctx = te_ctx_opt.as_ref().expect("te context for kernel-model algo");
            let tr_ctx = KernelContext::new(tr, kernel.as_ref(), cache_bytes);
            let res = SmoSolver::new(tr_ctx.view_full(), cfg.smo_config()?).solve();
            let model = SvmModel::from_ctx_alpha(&tr_ctx, &res.alpha);
            Outcome {
                algo: cfg.algo.name(),
                train_s: res.elapsed_s,
                accuracy: model.accuracy_ctx(te_ctx),
                objective: Some(res.objective),
                svs: res.sv_count,
                note: format!("iters={} cache_hit={:.2}", res.iterations, res.cache_hit_rate),
            }
        }
        Algo::DcSvm | Algo::DcSvmEarly => {
            let te_ctx = te_ctx_opt.as_ref().expect("te context for kernel-model algo");
            let dcfg = cfg.dcsvm_config()?;
            let res = dcsvm::train(tr, kernel.as_ref(), &dcfg);
            // Cross-phase reuse of the run's shared kernel context — the
            // bench JSONs capture this going forward.
            let hit_rate = res.cache_hit_rate();
            let (accuracy, note) = if res.early_stopped {
                let em = res.early_model.as_ref().expect("early model");
                (
                    em.accuracy_ctx(te_ctx),
                    format!(
                        "early@level1 local_svs={} cache_hit={hit_rate:.2}",
                        em.total_svs()
                    ),
                )
            } else {
                let model = SvmModel::from_alpha(tr, &res.alpha, kind);
                (
                    model.accuracy_ctx(te_ctx),
                    format!(
                        "final_iters={} final_rows={} cache_hit={hit_rate:.2}",
                        res.final_iterations, res.final_rows_computed
                    ),
                )
            };
            Outcome {
                algo: cfg.algo.name(),
                train_s: res.total_s,
                accuracy,
                objective: res.objective,
                svs: res.sv_count(),
                note,
            }
        }
        Algo::Cascade => {
            let te_ctx = te_ctx_opt.as_ref().expect("te context for kernel-model algo");
            let ccfg = cascade::CascadeConfig {
                kind,
                c: cfg.c,
                eps: cfg.eps,
                depth: 3,
                cache_bytes,
                seed: cfg.seed,
                threads: cfg.threads,
                max_iter: 0,
            };
            let res = cascade::train(tr, kernel.as_ref(), &ccfg);
            Outcome {
                algo: cfg.algo.name(),
                train_s: res.elapsed_s,
                accuracy: res.model.accuracy_ctx(te_ctx),
                objective: Some(crate::metrics::objective_of(tr, kernel.as_ref(), &res.alpha)),
                svs: res.model.num_svs(),
                note: format!("levels={:?}", res.level_sv_counts),
            }
        }
        Algo::LaSvm => {
            let te_ctx = te_ctx_opt.as_ref().expect("te context for kernel-model algo");
            let tr_ctx = KernelContext::new(tr, kernel.as_ref(), cache_bytes);
            let lcfg = lasvm::LaSvmConfig {
                kind,
                c: cfg.c,
                eps: cfg.eps,
                passes: 1,
                seed: cfg.seed,
                max_finish_iter: 0,
            };
            let res = lasvm::train(&tr_ctx, &lcfg);
            Outcome {
                algo: cfg.algo.name(),
                train_s: res.elapsed_s,
                accuracy: res.model.accuracy_ctx(te_ctx),
                objective: Some(crate::metrics::objective_of(tr, kernel.as_ref(), &res.alpha)),
                svs: res.model.num_svs(),
                note: format!("proc={} reproc={}", res.process_steps, res.reprocess_steps),
            }
        }
        Algo::Llsvm => {
            let te_ctx = te_ctx_opt.as_ref().expect("te context for kernel-model algo");
            let tr_ctx = KernelContext::new(tr, kernel.as_ref(), 1 << 20);
            let model = llsvm::train(
                tr,
                tr_ctx.norms(),
                &llsvm::LlsvmConfig {
                    kind,
                    c: cfg.c,
                    landmarks: cfg.budget,
                    seed: cfg.seed,
                    linear_eps: 1e-3,
                },
            );
            Outcome {
                algo: cfg.algo.name(),
                train_s: model.elapsed_s,
                accuracy: model.accuracy_with_norms(te, te_ctx.norms()),
                objective: None,
                svs: cfg.budget,
                note: format!("landmarks={}", cfg.budget),
            }
        }
        Algo::Fastfood => {
            let model = fastfood::train(
                tr,
                &fastfood::FastfoodConfig {
                    gamma: cfg.gamma,
                    c: cfg.c,
                    features: cfg.budget * 8,
                    seed: cfg.seed,
                },
            );
            Outcome {
                algo: cfg.algo.name(),
                train_s: model.elapsed_s,
                accuracy: model.accuracy(te),
                objective: None,
                svs: 0,
                note: format!("features={}", cfg.budget * 8),
            }
        }
        Algo::Ltpu => {
            let model = ltpu::train(
                tr,
                &ltpu::LtpuConfig {
                    gamma: cfg.gamma,
                    c: cfg.c,
                    units: cfg.budget,
                    seed: cfg.seed,
                },
            );
            Outcome {
                algo: cfg.algo.name(),
                train_s: model.elapsed_s,
                accuracy: model.accuracy(te),
                objective: None,
                svs: 0,
                note: format!("units={}", cfg.budget),
            }
        }
        Algo::Spsvm => {
            let te_ctx = te_ctx_opt.as_ref().expect("te context for kernel-model algo");
            let tr_ctx = KernelContext::new(tr, kernel.as_ref(), 1 << 20);
            let model = spsvm::train(
                tr,
                tr_ctx.norms(),
                &spsvm::SpsvmConfig {
                    kind,
                    c: cfg.c,
                    basis: cfg.budget,
                    candidates: 16,
                    grow_step: 8,
                    seed: cfg.seed,
                },
            );
            Outcome {
                algo: cfg.algo.name(),
                train_s: model.elapsed_s,
                accuracy: model.accuracy_with_norms(te, te_ctx.norms()),
                objective: None,
                svs: model.basis_size,
                note: format!("basis={}", model.basis_size),
            }
        }
    };
    let _ = t0;
    Ok(outcome)
}

/// Load a synthetic dataset pair per the config.
pub fn load_dataset(cfg: &RunConfig) -> Result<(Dataset, Dataset)> {
    let spec = crate::data::synthetic::all_specs()
        .into_iter()
        .find(|s| s.name == cfg.dataset);
    let Some(spec) = spec else {
        bail!(
            "unknown dataset '{}' (available: {})",
            cfg.dataset,
            crate::data::synthetic::all_specs()
                .iter()
                .map(|s| s.name)
                .collect::<Vec<_>>()
                .join(", ")
        );
    };
    let (dtr, dte) = crate::data::synthetic::default_sizes(spec.name);
    let ntr = cfg.n_train.unwrap_or(dtr);
    let nte = cfg.n_test.unwrap_or(dte);
    Ok(crate::data::synthetic::generate_split(&spec, ntr, nte, cfg.seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(algo: Algo) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.algo = algo;
        cfg.dataset = "covtype-like".into();
        cfg.n_train = Some(350);
        cfg.n_test = Some(120);
        cfg.gamma = 16.0;
        cfg.c = 4.0;
        cfg.levels = 2;
        cfg.sample_m = 64;
        cfg.budget = 32;
        cfg.backend = "native".into();
        cfg
    }

    #[test]
    fn every_algo_runs_and_learns() {
        for algo in Algo::all() {
            let cfg = small_cfg(algo);
            let (tr, te) = load_dataset(&cfg).unwrap();
            let out = run(&cfg, &tr, &te).unwrap();
            assert!(
                out.accuracy > 0.60,
                "{}: accuracy {}",
                out.algo,
                out.accuracy
            );
            assert!(out.train_s >= 0.0);
        }
    }

    #[test]
    fn exact_algos_reach_same_objective() {
        let (tr, te) = load_dataset(&small_cfg(Algo::Libsvm)).unwrap();
        let mut ocfg = small_cfg(Algo::Libsvm);
        ocfg.eps = 1e-6;
        let lib = run(&ocfg, &tr, &te).unwrap();
        let mut dcfg = small_cfg(Algo::DcSvm);
        dcfg.eps = 1e-6;
        let dc = run(&dcfg, &tr, &te).unwrap();
        let (a, b) = (lib.objective.unwrap(), dc.objective.unwrap());
        assert!((a - b).abs() < 1e-4 * (1.0 + a.abs()), "libsvm {a} dcsvm {b}");
    }

    #[test]
    fn dcsvm_note_reports_cache_hit_rate() {
        let cfg = small_cfg(Algo::DcSvm);
        let (tr, te) = load_dataset(&cfg).unwrap();
        let out = run(&cfg, &tr, &te).unwrap();
        assert!(out.note.contains("cache_hit="), "note: {}", out.note);
    }

    #[test]
    fn unknown_dataset_rejected() {
        let mut cfg = small_cfg(Algo::Libsvm);
        cfg.dataset = "not-a-dataset".into();
        assert!(load_dataset(&cfg).is_err());
    }
}
