//! Run harness: one entry point that trains any [`Algo`] on a dataset pair
//! and reports the paper's metrics (train time, test accuracy, objective,
//! SV count). Used by the CLI, the examples, and every bench.
//!
//! The harness builds [`KernelContext`]s for the datasets it touches: one
//! per training set where the algorithm consumes kernel rows/norms, and one
//! per test set so prediction paths read precomputed norms and dispatch
//! batched kernel blocks through the same backend.

use std::sync::OnceLock;

use anyhow::{bail, Result};

use crate::baselines::{cascade, fastfood, lasvm, llsvm, ltpu, spsvm};
use crate::cache::KernelContext;
use crate::config::{Algo, RunConfig};
use crate::data::Dataset;
use crate::dcsvm;
use crate::kernel::{native::NativeKernel, BlockKernel, KernelKind};
use crate::predict::SvmModel;
use crate::runtime::{Engine, PjrtKernel};
use crate::solver::SmoSolver;
use crate::util::json::Json;

static ENGINE: OnceLock<Option<Engine>> = OnceLock::new();

/// The process-wide PJRT engine (compiled once), or None when artifacts are
/// not built / not loadable.
pub fn global_engine() -> Option<&'static Engine> {
    ENGINE.get_or_init(Engine::load_default).as_ref()
}

/// Build a kernel backend. `mode`: "native", "pjrt", or "auto" (pjrt when
/// artifacts are present and the feature dim fits, else native).
pub fn make_kernel(kind: KernelKind, mode: &str, dim: usize) -> Result<Box<dyn BlockKernel + 'static>> {
    match mode {
        "native" => Ok(Box::new(NativeKernel::new(kind))),
        "pjrt" => match global_engine() {
            Some(e) if dim <= e.abi().d_pad => Ok(Box::new(PjrtKernel::new(e, kind))),
            Some(e) => bail!("dataset dim {dim} exceeds artifact d_pad {}", e.abi().d_pad),
            None => bail!("pjrt backend requested but artifacts/ not available"),
        },
        "auto" => Ok(match global_engine() {
            Some(e) if dim <= e.abi().d_pad => Box::new(PjrtKernel::new(e, kind)),
            _ => Box::new(NativeKernel::new(kind)),
        }),
        other => bail!("unknown backend '{other}'"),
    }
}

/// Uniform outcome record (a row of the paper's tables).
#[derive(Clone, Debug)]
pub struct Outcome {
    pub algo: &'static str,
    pub train_s: f64,
    pub accuracy: f64,
    /// Whole-problem dual objective (exact algos only).
    pub objective: Option<f64>,
    pub svs: usize,
    /// Hit rate of the run's shared kernel-row cache (kernel-model algos
    /// that solve through a [`KernelContext`]).
    pub cache_hit_rate: Option<f64>,
    /// Kernel rows the final conquer solve computed (exact DC-SVM runs) —
    /// the cross-phase-reuse metric: strictly lower than a cold-cache
    /// solve because divide/refine left their rows resident.
    pub final_rows: Option<u64>,
    /// Partial (cluster-segment) kernel rows computed (DC-SVM runs over
    /// segmented views) — the cache-v2 granularity metric.
    pub segment_rows: Option<u64>,
    /// Kernel entries evaluated by divide-phase cluster solves (DC-SVM):
    /// ~k× lower with segmented views than with full rows.
    pub divide_values: Option<u64>,
    /// Kernel entries reused by full-row stitching (DC-SVM).
    pub stitched_values: Option<u64>,
    /// Backend dispatches that fanned out over row panels (kernel-context
    /// algos; 0 under `--threads 1` or below the parallel threshold).
    pub parallel_dispatches: Option<u64>,
    /// Gathered stitch-fill dispatches (grouped warm prefetch — collapses
    /// many stitched rows into one dispatch).
    pub stitch_groups: Option<u64>,
    /// Peak bytes of gathered segment features (the registry-GC
    /// high-water mark; DC-SVM runs).
    pub registry_bytes: Option<u64>,
    /// Inner-kernel dispatch tier the process selected at startup
    /// ("scalar" | "avx2" | "neon"; [`crate::kernel::simd_tier`]) — lets
    /// bench diffs pin which tier produced a record.
    pub simd_tier: &'static str,
    /// Kernel entries evaluated against int8-quantized routing operands
    /// (DC-SVM runs; 0 unless `--quant-route`).
    pub quantized_values: Option<u64>,
    /// Times a GC-dropped segment re-gathered its features (DC-SVM runs;
    /// stays 0 under the per-level generation floor).
    pub segment_regathers: Option<u64>,
    /// Kernel entries evaluated by a `dcsvm update` warm re-solve
    /// (streaming runs; strictly lower than a cold retrain on the same
    /// cumulative data, and exactly 0 for an empty-delta no-op).
    pub update_values_computed: Option<u64>,
    /// Delta rows that became support vectors in a `dcsvm update` run
    /// (0 for a no-op).
    pub svs_added: Option<u64>,
    /// Prior SVs evicted (α → 0) by a `dcsvm update` run (0 for a no-op).
    pub svs_dropped: Option<u64>,
    /// Pairwise OVO machines trained over the shared context (`--algo
    /// ovo`; k(k−1)/2 over the present classes).
    pub pair_dispatches: Option<u64>,
    /// Pairwise votes cast evaluating the test set (`--algo ovo`;
    /// rows × machines).
    pub votes: Option<u64>,
    /// Total bytes on the coordinator↔worker wire, both directions
    /// (`--distributed` runs; [`crate::distributed`]) — the
    /// communication-efficiency headline: α summaries only, orders of
    /// magnitude below one serialized kernel block.
    pub comm_bytes: Option<u64>,
    /// Block-minimization rounds the distributed run executed.
    pub rounds: Option<u64>,
    /// Kernel entries evaluated across all worker processes
    /// (`--distributed` runs; each worker's local solves + external-offset
    /// dispatches).
    pub worker_values_computed: Option<u64>,
    /// Workers declared lost mid-run — dead, stalled past
    /// `--round-timeout`, or garbling the protocol (`--distributed` runs;
    /// 0 for a clean run).
    pub workers_lost: Option<u64>,
    /// Rows moved from lost workers onto survivors via `reshard` messages
    /// (`--distributed` runs; 0 when nothing was lost or respawn
    /// recovered every loss).
    pub resharded_rows: Option<u64>,
    /// Interrupted rounds that were replayed after recovery
    /// (`--distributed` runs; 0 for a clean run).
    pub rounds_replayed: Option<u64>,
    /// Lost locally-spawned workers successfully respawned under
    /// `--worker-retries` (`--distributed` runs).
    pub respawns: Option<u64>,
    /// Free-text extras (iteration counts, per-algo details). Structured
    /// metrics live in the typed fields above, not here.
    pub note: String,
}

/// All counters absent, `simd_tier` "scalar": outcome sites name the
/// fields their algorithm actually measures and spread the rest, so adding
/// a counter means touching only the algorithms that produce it.
impl Default for Outcome {
    fn default() -> Self {
        Outcome {
            algo: "",
            train_s: 0.0,
            accuracy: 0.0,
            objective: None,
            svs: 0,
            cache_hit_rate: None,
            final_rows: None,
            segment_rows: None,
            divide_values: None,
            stitched_values: None,
            parallel_dispatches: None,
            stitch_groups: None,
            registry_bytes: None,
            simd_tier: "scalar",
            quantized_values: None,
            segment_regathers: None,
            update_values_computed: None,
            svs_added: None,
            svs_dropped: None,
            pair_dispatches: None,
            votes: None,
            comm_bytes: None,
            rounds: None,
            worker_values_computed: None,
            workers_lost: None,
            resharded_rows: None,
            rounds_replayed: None,
            respawns: None,
            note: String::new(),
        }
    }
}

impl Outcome {
    /// Structured record for bench result files: `cache_hit_rate` and
    /// `final_rows` are first-class fields (not `note` text), so
    /// EXPERIMENTS.md can track cross-phase reuse over time. See
    /// [`record_result_to`].
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("algo", Json::from(self.algo)),
            ("train_s", Json::from(self.train_s)),
            ("accuracy", Json::from(self.accuracy)),
            ("objective", self.objective.map(Json::from).unwrap_or(Json::Null)),
            ("svs", Json::from(self.svs)),
            (
                "cache_hit_rate",
                self.cache_hit_rate.map(Json::from).unwrap_or(Json::Null),
            ),
            (
                "final_rows",
                self.final_rows.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "segment_rows",
                self.segment_rows.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "divide_values",
                self.divide_values.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "stitched_values",
                self.stitched_values.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "parallel_dispatches",
                self.parallel_dispatches.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "stitch_groups",
                self.stitch_groups.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "registry_bytes",
                self.registry_bytes.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            ("simd_tier", Json::from(self.simd_tier)),
            (
                "quantized_values",
                self.quantized_values.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "segment_regathers",
                self.segment_regathers.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "update_values_computed",
                self.update_values_computed.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "svs_added",
                self.svs_added.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "svs_dropped",
                self.svs_dropped.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "pair_dispatches",
                self.pair_dispatches.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "votes",
                self.votes.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "comm_bytes",
                self.comm_bytes.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "rounds",
                self.rounds.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "worker_values_computed",
                self.worker_values_computed.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "workers_lost",
                self.workers_lost.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "resharded_rows",
                self.resharded_rows.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "rounds_replayed",
                self.rounds_replayed.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            (
                "respawns",
                self.respawns.map(|r| Json::from(r as f64)).unwrap_or(Json::Null),
            ),
            ("note", Json::from(self.note.as_str())),
        ])
    }
}

/// Append `{config, outcome}` as one JSON line to `<dir>/results.jsonl`
/// (creating the directory if needed) — the bench result files
/// EXPERIMENTS.md ingests.
pub fn record_result_to(
    dir: &std::path::Path,
    cfg: &RunConfig,
    out: &Outcome,
) -> std::io::Result<()> {
    use std::io::Write;
    std::fs::create_dir_all(dir)?;
    let line = Json::obj(vec![
        ("config", cfg.to_json()),
        ("outcome", out.to_json()),
    ]);
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(dir.join("results.jsonl"))?;
    writeln!(f, "{line}")
}

/// Honor `DCSVM_RESULTS_DIR`: when set, every [`run`] appends its outcome
/// there (benches set it to collect structured result JSONs). Failures are
/// non-fatal — result recording never kills a run.
fn record_result(cfg: &RunConfig, out: &Outcome) {
    if let Ok(dir) = std::env::var("DCSVM_RESULTS_DIR") {
        if !dir.is_empty() {
            let _ = record_result_to(std::path::Path::new(&dir), cfg, out);
        }
    }
}

/// Train `cfg.algo` on (`tr`, `te`) and measure.
pub fn run(cfg: &RunConfig, tr: &Dataset, te: &Dataset) -> Result<Outcome> {
    let kind = cfg.kernel_kind()?;
    let kernel = make_kernel(kind, &cfg.backend, tr.dim)?;
    let cache_bytes = cfg.cache_mb << 20;
    // Test-set context: precomputed norms + batched dispatch for the
    // kernel-model prediction paths (the row cache is unused on the predict
    // side, so the budget is nominal). The random-feature baselines
    // (fastfood/ltpu) never consume test norms, so skip it for them.
    let te_ctx_opt = match cfg.algo {
        Algo::Fastfood | Algo::Ltpu | Algo::Ovo => None,
        _ => Some(KernelContext::new(te, kernel.as_ref(), 1 << 20).with_threads(cfg.threads)),
    };
    let t0 = std::time::Instant::now();
    // Resolved once per process ([`crate::kernel::simd_tier`]); recorded on
    // every outcome so bench artifacts pin the tier they were produced on.
    let tier = crate::kernel::simd_tier().name();

    let outcome = match cfg.algo {
        Algo::Libsvm => {
            let te_ctx = te_ctx_opt.as_ref().expect("te context for kernel-model algo");
            let tr_ctx =
                KernelContext::new(tr, kernel.as_ref(), cache_bytes).with_threads(cfg.threads);
            let res = SmoSolver::new(tr_ctx.view_full(), cfg.smo_config()?).solve();
            let model = SvmModel::from_ctx_alpha(&tr_ctx, &res.alpha);
            let vs = tr_ctx.value_stats();
            Outcome {
                algo: cfg.algo.name(),
                train_s: res.elapsed_s,
                accuracy: model.accuracy_ctx(te_ctx),
                objective: Some(res.objective),
                svs: res.sv_count,
                cache_hit_rate: Some(res.cache_hit_rate),
                final_rows: None,
                segment_rows: None,
                divide_values: None,
                stitched_values: None,
                parallel_dispatches: Some(vs.parallel_dispatches),
                stitch_groups: Some(vs.stitch_groups),
                registry_bytes: None,
                simd_tier: tier,
                quantized_values: None,
                segment_regathers: None,
                update_values_computed: None,
                svs_added: None,
                svs_dropped: None,
                pair_dispatches: None,
                votes: None,
                note: format!("iters={}", res.iterations),
                ..Default::default()
            }
        }
        Algo::DcSvm | Algo::DcSvmEarly => {
            let te_ctx = te_ctx_opt.as_ref().expect("te context for kernel-model algo");
            let dcfg = cfg.dcsvm_config()?;
            let res = dcsvm::train(tr, kernel.as_ref(), &dcfg);
            // Cross-phase reuse of the run's shared kernel context lands in
            // the structured fields (the bench result JSONs capture it).
            let hit_rate = res.cache_hit_rate();
            let (accuracy, final_rows, note) = if res.early_stopped {
                let em = res.early_model.as_ref().expect("early model");
                (
                    em.accuracy_ctx(te_ctx),
                    None,
                    format!("early@level1 local_svs={}", em.total_svs()),
                )
            } else {
                let model = SvmModel::from_alpha(tr, &res.alpha, kind);
                (
                    model.accuracy_ctx(te_ctx),
                    Some(res.final_rows_computed),
                    format!("final_iters={}", res.final_iterations),
                )
            };
            Outcome {
                algo: cfg.algo.name(),
                train_s: res.total_s,
                accuracy,
                objective: res.objective,
                svs: res.sv_count(),
                cache_hit_rate: Some(hit_rate),
                final_rows,
                segment_rows: Some(res.segment_rows_computed),
                divide_values: Some(res.divide_values_computed),
                stitched_values: Some(res.stitched_values),
                parallel_dispatches: Some(res.parallel_dispatches),
                stitch_groups: Some(res.stitch_groups),
                registry_bytes: Some(res.registry_peak_bytes),
                simd_tier: tier,
                quantized_values: Some(res.quantized_values),
                segment_regathers: Some(res.segment_regathers),
                update_values_computed: None,
                svs_added: None,
                svs_dropped: None,
                pair_dispatches: None,
                votes: None,
                note,
                ..Default::default()
            }
        }
        Algo::Cascade => {
            let te_ctx = te_ctx_opt.as_ref().expect("te context for kernel-model algo");
            let ccfg = cascade::CascadeConfig {
                kind,
                c: cfg.c,
                eps: cfg.eps,
                depth: 3,
                cache_bytes,
                seed: cfg.seed,
                threads: cfg.threads,
                max_iter: 0,
            };
            let res = cascade::train(tr, kernel.as_ref(), &ccfg);
            Outcome {
                algo: cfg.algo.name(),
                train_s: res.elapsed_s,
                accuracy: res.model.accuracy_ctx(te_ctx),
                objective: Some(crate::metrics::objective_of(tr, kernel.as_ref(), &res.alpha)),
                svs: res.model.num_svs(),
                cache_hit_rate: None,
                final_rows: None,
                segment_rows: None,
                divide_values: None,
                stitched_values: None,
                parallel_dispatches: None,
                stitch_groups: None,
                registry_bytes: None,
                simd_tier: tier,
                quantized_values: None,
                segment_regathers: None,
                update_values_computed: None,
                svs_added: None,
                svs_dropped: None,
                pair_dispatches: None,
                votes: None,
                note: format!("levels={:?}", res.level_sv_counts),
                ..Default::default()
            }
        }
        Algo::LaSvm => {
            let te_ctx = te_ctx_opt.as_ref().expect("te context for kernel-model algo");
            let tr_ctx =
                KernelContext::new(tr, kernel.as_ref(), cache_bytes).with_threads(cfg.threads);
            let lcfg = lasvm::LaSvmConfig {
                kind,
                c: cfg.c,
                eps: cfg.eps,
                passes: 1,
                seed: cfg.seed,
                max_finish_iter: 0,
            };
            let res = lasvm::train(&tr_ctx, &lcfg);
            Outcome {
                algo: cfg.algo.name(),
                train_s: res.elapsed_s,
                accuracy: res.model.accuracy_ctx(te_ctx),
                objective: Some(crate::metrics::objective_of(tr, kernel.as_ref(), &res.alpha)),
                svs: res.model.num_svs(),
                cache_hit_rate: Some(tr_ctx.hit_rate()),
                final_rows: None,
                segment_rows: None,
                divide_values: None,
                stitched_values: None,
                parallel_dispatches: None,
                stitch_groups: None,
                registry_bytes: None,
                simd_tier: tier,
                quantized_values: None,
                segment_regathers: None,
                update_values_computed: None,
                svs_added: None,
                svs_dropped: None,
                pair_dispatches: None,
                votes: None,
                note: format!("proc={} reproc={}", res.process_steps, res.reprocess_steps),
                ..Default::default()
            }
        }
        Algo::Llsvm => {
            let te_ctx = te_ctx_opt.as_ref().expect("te context for kernel-model algo");
            let tr_ctx = KernelContext::new(tr, kernel.as_ref(), 1 << 20);
            let model = llsvm::train(
                tr,
                tr_ctx.norms(),
                &llsvm::LlsvmConfig {
                    kind,
                    c: cfg.c,
                    landmarks: cfg.budget,
                    seed: cfg.seed,
                    linear_eps: 1e-3,
                },
            );
            Outcome {
                algo: cfg.algo.name(),
                train_s: model.elapsed_s,
                accuracy: model.accuracy_with_norms(te, te_ctx.norms()),
                objective: None,
                svs: cfg.budget,
                cache_hit_rate: None,
                final_rows: None,
                segment_rows: None,
                divide_values: None,
                stitched_values: None,
                parallel_dispatches: None,
                stitch_groups: None,
                registry_bytes: None,
                simd_tier: tier,
                quantized_values: None,
                segment_regathers: None,
                update_values_computed: None,
                svs_added: None,
                svs_dropped: None,
                pair_dispatches: None,
                votes: None,
                note: format!("landmarks={}", cfg.budget),
                ..Default::default()
            }
        }
        Algo::Fastfood => {
            let model = fastfood::train(
                tr,
                &fastfood::FastfoodConfig {
                    gamma: cfg.gamma,
                    c: cfg.c,
                    features: cfg.budget * 8,
                    seed: cfg.seed,
                },
            );
            Outcome {
                algo: cfg.algo.name(),
                train_s: model.elapsed_s,
                accuracy: model.accuracy(te),
                objective: None,
                svs: 0,
                cache_hit_rate: None,
                final_rows: None,
                segment_rows: None,
                divide_values: None,
                stitched_values: None,
                parallel_dispatches: None,
                stitch_groups: None,
                registry_bytes: None,
                simd_tier: tier,
                quantized_values: None,
                segment_regathers: None,
                update_values_computed: None,
                svs_added: None,
                svs_dropped: None,
                pair_dispatches: None,
                votes: None,
                note: format!("features={}", cfg.budget * 8),
                ..Default::default()
            }
        }
        Algo::Ltpu => {
            let model = ltpu::train(
                tr,
                &ltpu::LtpuConfig {
                    gamma: cfg.gamma,
                    c: cfg.c,
                    units: cfg.budget,
                    seed: cfg.seed,
                },
            );
            Outcome {
                algo: cfg.algo.name(),
                train_s: model.elapsed_s,
                accuracy: model.accuracy(te),
                objective: None,
                svs: 0,
                cache_hit_rate: None,
                final_rows: None,
                segment_rows: None,
                divide_values: None,
                stitched_values: None,
                parallel_dispatches: None,
                stitch_groups: None,
                registry_bytes: None,
                simd_tier: tier,
                quantized_values: None,
                segment_regathers: None,
                update_values_computed: None,
                svs_added: None,
                svs_dropped: None,
                pair_dispatches: None,
                votes: None,
                note: format!("units={}", cfg.budget),
                ..Default::default()
            }
        }
        Algo::Spsvm => {
            let te_ctx = te_ctx_opt.as_ref().expect("te context for kernel-model algo");
            let tr_ctx = KernelContext::new(tr, kernel.as_ref(), 1 << 20);
            let model = spsvm::train(
                tr,
                tr_ctx.norms(),
                &spsvm::SpsvmConfig {
                    kind,
                    c: cfg.c,
                    basis: cfg.budget,
                    candidates: 16,
                    grow_step: 8,
                    seed: cfg.seed,
                },
            );
            Outcome {
                algo: cfg.algo.name(),
                train_s: model.elapsed_s,
                accuracy: model.accuracy_with_norms(te, te_ctx.norms()),
                objective: None,
                svs: model.basis_size,
                cache_hit_rate: None,
                final_rows: None,
                segment_rows: None,
                divide_values: None,
                stitched_values: None,
                parallel_dispatches: None,
                stitch_groups: None,
                registry_bytes: None,
                simd_tier: tier,
                quantized_values: None,
                segment_regathers: None,
                update_values_computed: None,
                svs_added: None,
                svs_dropped: None,
                pair_dispatches: None,
                votes: None,
                note: format!("basis={}", model.basis_size),
                ..Default::default()
            }
        }
        Algo::Ovo => {
            // The harness's synthetic datasets are binary; run them as a
            // 2-class OVO problem so `--algo ovo` slots into the same
            // apples-to-apples comparison table. Real multiclass data
            // enters through the CLI's LIBSVM / `mc<K>` paths.
            let mc_tr = crate::multiclass::MulticlassDataset::from_binary(tr);
            let mc_te = crate::multiclass::MulticlassDataset::from_binary(te);
            let dcfg = cfg.dcsvm_config()?;
            let res = crate::multiclass::train_ovo_shared(&mc_tr, kernel.as_ref(), &dcfg);
            let vs = res.value_stats;
            let machines = res.model.machines.len() as u64;
            Outcome {
                algo: cfg.algo.name(),
                train_s: res.train_s,
                accuracy: res.model.accuracy(&mc_te, kernel.as_ref()),
                objective: None,
                svs: res.model.num_svs(),
                cache_hit_rate: None,
                final_rows: None,
                segment_rows: Some(vs.segment_rows),
                divide_values: None,
                stitched_values: Some(vs.values_stitched),
                parallel_dispatches: Some(vs.parallel_dispatches),
                stitch_groups: Some(vs.stitch_groups),
                registry_bytes: None,
                simd_tier: tier,
                quantized_values: Some(vs.quantized_values),
                segment_regathers: None,
                update_values_computed: None,
                svs_added: None,
                svs_dropped: None,
                pair_dispatches: Some(res.pair_dispatches),
                votes: Some(machines * mc_te.len() as u64),
                note: format!(
                    "classes={} machines={}",
                    res.model.present.len(),
                    machines
                ),
                ..Default::default()
            }
        }
    };
    let _ = t0;
    record_result(cfg, &outcome);
    Ok(outcome)
}

/// Load a synthetic dataset pair per the config.
pub fn load_dataset(cfg: &RunConfig) -> Result<(Dataset, Dataset)> {
    let spec = crate::data::synthetic::all_specs()
        .into_iter()
        .find(|s| s.name == cfg.dataset);
    let Some(spec) = spec else {
        bail!(
            "unknown dataset '{}' (available: {})",
            cfg.dataset,
            crate::data::synthetic::all_specs()
                .iter()
                .map(|s| s.name)
                .collect::<Vec<_>>()
                .join(", ")
        );
    };
    let (dtr, dte) = crate::data::synthetic::default_sizes(spec.name);
    let ntr = cfg.n_train.unwrap_or(dtr);
    let nte = cfg.n_test.unwrap_or(dte);
    Ok(crate::data::synthetic::generate_split(&spec, ntr, nte, cfg.seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(algo: Algo) -> RunConfig {
        RunConfig {
            algo,
            dataset: "covtype-like".into(),
            n_train: Some(350),
            n_test: Some(120),
            gamma: 16.0,
            c: 4.0,
            levels: 2,
            sample_m: 64,
            budget: 32,
            backend: "native".into(),
            ..RunConfig::default()
        }
    }

    #[test]
    fn every_algo_runs_and_learns() {
        for algo in Algo::all() {
            let cfg = small_cfg(algo);
            let (tr, te) = load_dataset(&cfg).unwrap();
            let out = run(&cfg, &tr, &te).unwrap();
            assert!(
                out.accuracy > 0.60,
                "{}: accuracy {}",
                out.algo,
                out.accuracy
            );
            assert!(out.train_s >= 0.0);
        }
    }

    #[test]
    fn exact_algos_reach_same_objective() {
        let (tr, te) = load_dataset(&small_cfg(Algo::Libsvm)).unwrap();
        let mut ocfg = small_cfg(Algo::Libsvm);
        ocfg.eps = 1e-6;
        let lib = run(&ocfg, &tr, &te).unwrap();
        let mut dcfg = small_cfg(Algo::DcSvm);
        dcfg.eps = 1e-6;
        let dc = run(&dcfg, &tr, &te).unwrap();
        let (a, b) = (lib.objective.unwrap(), dc.objective.unwrap());
        assert!((a - b).abs() < 1e-4 * (1.0 + a.abs()), "libsvm {a} dcsvm {b}");
    }

    #[test]
    fn dcsvm_reports_structured_cache_stats() {
        let cfg = small_cfg(Algo::DcSvm);
        let (tr, te) = load_dataset(&cfg).unwrap();
        let out = run(&cfg, &tr, &te).unwrap();
        // Promoted out of the free-text note into typed fields.
        let hit = out.cache_hit_rate.expect("cache_hit_rate recorded");
        assert!((0.0..=1.0).contains(&hit), "hit rate {hit}");
        assert!(out.final_rows.is_some(), "final_rows recorded for exact dcsvm");
        assert!(out.segment_rows.is_some(), "segment_rows recorded for dcsvm");
        assert!(out.divide_values.is_some(), "divide_values recorded for dcsvm");
        assert!(out.stitched_values.is_some(), "stitched_values recorded for dcsvm");
        assert!(out.segment_rows.unwrap() > 0, "segmented divide recorded no rows");
        assert!(!out.note.contains("cache_hit="), "note: {}", out.note);
        assert!(out.parallel_dispatches.is_some(), "parallel_dispatches recorded for dcsvm");
        assert!(out.stitch_groups.is_some(), "stitch_groups recorded for dcsvm");
        assert!(
            out.registry_bytes.map(|b| b > 0).unwrap_or(false),
            "registry peak not recorded: {:?}",
            out.registry_bytes
        );
        assert!(
            ["scalar", "avx2", "neon"].contains(&out.simd_tier),
            "bad tier {}",
            out.simd_tier
        );
        assert_eq!(
            out.quantized_values,
            Some(0),
            "quantized_values must be 0 without --quant-route"
        );
        assert_eq!(out.segment_regathers, Some(0), "generation floor regathered");
        let j = out.to_json();
        assert_eq!(j.get("cache_hit_rate").as_f64(), Some(hit));
        assert!(j.get("final_rows").as_f64().is_some());
        assert!(j.get("segment_rows").as_f64().is_some());
        assert!(j.get("divide_values").as_f64().is_some());
        assert!(j.get("stitched_values").as_f64().is_some());
        assert!(j.get("parallel_dispatches").as_f64().is_some());
        assert!(j.get("stitch_groups").as_f64().is_some());
        assert!(j.get("registry_bytes").as_f64().is_some());
        assert_eq!(j.get("simd_tier").as_str(), Some(out.simd_tier));
        assert_eq!(j.get("quantized_values").as_f64(), Some(0.0));
        assert_eq!(j.get("segment_regathers").as_f64(), Some(0.0));
    }

    #[test]
    fn ovo_harness_reports_pair_counters() {
        let cfg = small_cfg(Algo::Ovo);
        let (tr, te) = load_dataset(&cfg).unwrap();
        let out = run(&cfg, &tr, &te).unwrap();
        // Binary data viewed as 2 classes → exactly one pairwise machine.
        assert_eq!(out.pair_dispatches, Some(1), "2 classes → 1 machine");
        assert_eq!(out.votes, Some(te.len() as u64));
        assert!(out.note.contains("classes=2"), "note: {}", out.note);
        let j = out.to_json();
        assert_eq!(j.get("pair_dispatches").as_f64(), Some(1.0));
        assert_eq!(j.get("votes").as_f64(), Some(te.len() as f64));
        // Binary algos leave the multiclass counters null.
        let bin = run(&small_cfg(Algo::DcSvm), &tr, &te).unwrap();
        assert_eq!(bin.pair_dispatches, None);
        assert_eq!(bin.votes, None);
    }

    #[test]
    fn record_result_appends_structured_jsonl() {
        let cfg = small_cfg(Algo::DcSvmEarly);
        let (tr, te) = load_dataset(&cfg).unwrap();
        let out = run(&cfg, &tr, &te).unwrap();
        let dir = std::env::temp_dir().join("dcsvm_results_test");
        let _ = std::fs::remove_dir_all(&dir);
        record_result_to(&dir, &cfg, &out).unwrap();
        record_result_to(&dir, &cfg, &out).unwrap();
        let text = std::fs::read_to_string(dir.join("results.jsonl")).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("config").get("dataset").as_str(), Some("covtype-like"));
            assert_eq!(j.get("outcome").get("algo").as_str(), Some(out.algo));
            assert!(j.get("outcome").get("cache_hit_rate").as_f64().is_some());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_dataset_rejected() {
        let mut cfg = small_cfg(Algo::Libsvm);
        cfg.dataset = "not-a-dataset".into();
        assert!(load_dataset(&cfg).is_err());
    }
}
