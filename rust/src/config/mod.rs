//! Typed configuration: JSON file + CLI-style `--key value` overrides.
//!
//! A single [`RunConfig`] describes a training run (dataset, kernel, solver,
//! DC-SVM schedule, backend). Files and flags both funnel through
//! [`RunConfig::apply`], so `dcsvm train --config run.json --gamma 32`
//! behaves as expected (flags win).

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::dcsvm::DcSvmConfig;
use crate::kernel::KernelKind;
use crate::solver::SmoConfig;
use crate::util::json::Json;

/// Which algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    DcSvm,
    DcSvmEarly,
    Libsvm, // our exact solver, cold start
    Cascade,
    LaSvm,
    Llsvm,
    Fastfood,
    Ltpu,
    Spsvm,
    /// One-vs-one multiclass DC-SVM over one shared kernel context.
    Ovo,
}

impl Algo {
    pub fn parse(s: &str) -> Result<Algo> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dcsvm" | "dc-svm" => Algo::DcSvm,
            "dcsvm-early" | "early" => Algo::DcSvmEarly,
            "libsvm" | "smo" | "exact" => Algo::Libsvm,
            "cascade" | "cascadesvm" => Algo::Cascade,
            "lasvm" => Algo::LaSvm,
            "llsvm" | "nystrom" => Algo::Llsvm,
            "fastfood" | "rff" => Algo::Fastfood,
            "ltpu" => Algo::Ltpu,
            "spsvm" => Algo::Spsvm,
            "ovo" | "multiclass" => Algo::Ovo,
            other => bail!("unknown algo '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::DcSvm => "DC-SVM",
            Algo::DcSvmEarly => "DC-SVM (early)",
            Algo::Libsvm => "LIBSVM",
            Algo::Cascade => "CascadeSVM",
            Algo::LaSvm => "LaSVM",
            Algo::Llsvm => "LLSVM",
            Algo::Fastfood => "FastFood",
            Algo::Ltpu => "LTPU",
            Algo::Spsvm => "SpSVM",
            Algo::Ovo => "OVO",
        }
    }

    pub fn all() -> [Algo; 10] {
        [
            Algo::DcSvmEarly,
            Algo::DcSvm,
            Algo::Libsvm,
            Algo::LaSvm,
            Algo::Cascade,
            Algo::Llsvm,
            Algo::Fastfood,
            Algo::Spsvm,
            Algo::Ltpu,
            Algo::Ovo,
        ]
    }
}

/// Full run configuration with defaults matching the paper's settings.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub algo: Algo,
    pub dataset: String,
    pub n_train: Option<usize>,
    pub n_test: Option<usize>,
    /// "rbf" | "poly" | "linear"
    pub kernel: String,
    pub gamma: f64,
    pub eta: f64,
    pub c: f64,
    pub eps: f64,
    pub levels: usize,
    pub k_base: usize,
    pub sample_m: usize,
    /// Byte budget (in MB) of the per-run shared kernel-row cache
    /// ([`crate::cache::KernelContext`]).
    pub cache_mb: usize,
    pub seed: u64,
    /// Worker threads for independent subproblems (`--threads`; default:
    /// `DCSVM_THREADS` env var or available parallelism).
    pub threads: usize,
    /// "native" | "pjrt" | "auto"
    pub backend: String,
    /// approximate-solver budget (landmarks/features/units/basis)
    pub budget: usize,
    /// Segment-granular divide-phase kernel caching (`--segments false`
    /// replays the v1 full-row behavior as an ablation baseline).
    pub segment_views: bool,
    /// Cap (in MB) on gathered segment features (`--registry-cap-mb`;
    /// 0 = keep every solved level's gathered copy — the default).
    pub registry_cap_mb: usize,
    /// Route kmeans assignment / early-prediction routing through int8-
    /// quantized sample operands (`--quant-route`; exact solves are
    /// unaffected).
    pub quant_route: bool,
    pub save_model: Option<String>,
    /// Train via distributed parallel block minimization
    /// (`--distributed true`; see [`crate::distributed`]).
    pub distributed: bool,
    /// Local worker processes to spawn when `workers_addr` is not set
    /// (`--workers`).
    pub dist_workers: usize,
    /// Comma-separated addresses of already-running `dcsvm worker`
    /// processes (`--workers-addr`). CLI-only: never serialized, because a
    /// config file naming live endpoints would go stale.
    pub workers_addr: Option<String>,
    /// Block-minimization rounds before the conquer solve (`--rounds`).
    pub rounds: usize,
    /// Per-round reply deadline in seconds (`--round-timeout`): a worker
    /// whose round reply takes longer is declared lost and recovered
    /// from (respawn/re-shard), bounding how long a hung worker can
    /// stall the run.
    pub round_timeout: f64,
    /// Deadline in seconds for connecting to each worker address
    /// (`--connect-timeout`).
    pub connect_timeout: f64,
    /// Respawn attempts for a lost locally-spawned worker before its rows
    /// are re-sharded onto survivors (`--worker-retries`; 0 = straight to
    /// re-sharding).
    pub worker_retries: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            algo: Algo::DcSvm,
            dataset: "covtype-like".into(),
            n_train: None,
            n_test: None,
            kernel: "rbf".into(),
            gamma: 32.0,
            eta: 0.0,
            c: 1.0,
            eps: 1e-3,
            levels: 4,
            k_base: 4,
            sample_m: 256,
            cache_mb: 256,
            seed: 0,
            threads: crate::util::threadpool::default_threads(),
            backend: "auto".into(),
            budget: 64,
            segment_views: true,
            registry_cap_mb: 0,
            quant_route: false,
            save_model: None,
            distributed: false,
            dist_workers: 2,
            workers_addr: None,
            rounds: 2,
            round_timeout: 60.0,
            connect_timeout: 10.0,
            worker_retries: 0,
        }
    }
}

impl RunConfig {
    /// Parse a JSON config file.
    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let json = Json::parse(&text).context("parse config json")?;
        let mut cfg = RunConfig::default();
        let obj = json.as_obj().ok_or_else(|| anyhow!("config must be an object"))?;
        for (k, v) in obj {
            cfg.apply(k, &json_to_arg(v))?;
        }
        Ok(cfg)
    }

    /// Apply one key/value override (CLI flag or JSON field).
    pub fn apply(&mut self, key: &str, val: &str) -> Result<()> {
        match key {
            "algo" => self.algo = Algo::parse(val)?,
            "dataset" => self.dataset = val.to_string(),
            "n_train" | "n-train" => self.n_train = Some(val.parse()?),
            "n_test" | "n-test" => self.n_test = Some(val.parse()?),
            "kernel" => self.kernel = val.to_string(),
            "gamma" => self.gamma = val.parse()?,
            "eta" => self.eta = val.parse()?,
            "c" | "C" => self.c = val.parse()?,
            "eps" => self.eps = val.parse()?,
            "levels" => self.levels = val.parse()?,
            "k_base" | "k-base" | "k" => self.k_base = val.parse()?,
            "sample_m" | "sample-m" => self.sample_m = val.parse()?,
            "cache_mb" | "cache-mb" => self.cache_mb = val.parse()?,
            "seed" => self.seed = val.parse()?,
            "threads" => self.threads = val.parse()?,
            "backend" => self.backend = val.to_string(),
            "budget" => self.budget = val.parse()?,
            "segments" | "segment_views" | "segment-views" => {
                self.segment_views = match val {
                    "1" => true,
                    "0" => false,
                    other => other.parse()?,
                }
            }
            "registry_cap_mb" | "registry-cap-mb" => self.registry_cap_mb = val.parse()?,
            "quant_route" | "quant-route" => {
                self.quant_route = match val {
                    "1" => true,
                    "0" => false,
                    other => other.parse()?,
                }
            }
            "save_model" | "save-model" => self.save_model = Some(val.to_string()),
            "distributed" => {
                self.distributed = match val {
                    "1" => true,
                    "0" => false,
                    other => other.parse()?,
                }
            }
            "workers" | "dist_workers" | "dist-workers" => self.dist_workers = val.parse()?,
            "workers_addr" | "workers-addr" => self.workers_addr = Some(val.to_string()),
            "rounds" => self.rounds = val.parse()?,
            "round_timeout" | "round-timeout" => {
                let secs: f64 = val.parse()?;
                if !secs.is_finite() || secs <= 0.0 {
                    bail!("round_timeout must be a positive number of seconds, got '{val}'");
                }
                self.round_timeout = secs;
            }
            "connect_timeout" | "connect-timeout" => {
                let secs: f64 = val.parse()?;
                if !secs.is_finite() || secs <= 0.0 {
                    bail!("connect_timeout must be a positive number of seconds, got '{val}'");
                }
                self.connect_timeout = secs;
            }
            "worker_retries" | "worker-retries" => self.worker_retries = val.parse()?,
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// The kernel this run uses.
    pub fn kernel_kind(&self) -> Result<KernelKind> {
        Ok(match self.kernel.as_str() {
            "rbf" => KernelKind::Rbf { gamma: self.gamma as f32 },
            "poly" => KernelKind::Poly { gamma: self.gamma as f32, eta: self.eta as f32 },
            "linear" => KernelKind::Linear,
            other => bail!("unknown kernel '{other}'"),
        })
    }

    pub fn smo_config(&self) -> Result<SmoConfig> {
        Ok(SmoConfig {
            c: self.c,
            eps: self.eps,
            max_iter: 0,
            shrinking: true,
            report_every: 2000,
            row_batch: 0,
        })
    }

    pub fn dcsvm_config(&self) -> Result<DcSvmConfig> {
        Ok(DcSvmConfig {
            kind: self.kernel_kind()?,
            c: self.c,
            levels: self.levels,
            k_base: self.k_base,
            sample_m: self.sample_m,
            eps_sub: self.eps.max(1e-3),
            eps_final: self.eps,
            cache_bytes: self.cache_mb << 20,
            adaptive: true,
            refine: true,
            stop_after_level: (self.algo == Algo::DcSvmEarly).then_some(1),
            max_iter_sub: 0,
            max_iter_final: 0,
            seed: self.seed,
            threads: self.threads,
            keep_level_alphas: false,
            segment_views: self.segment_views,
            registry_cap_bytes: self.registry_cap_mb << 20,
            quant_route: self.quant_route,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("algo", Json::from(self.algo.name())),
            ("dataset", Json::from(self.dataset.as_str())),
            ("kernel", Json::from(self.kernel.as_str())),
            ("gamma", Json::from(self.gamma)),
            ("eta", Json::from(self.eta)),
            ("c", Json::from(self.c)),
            ("eps", Json::from(self.eps)),
            ("levels", Json::from(self.levels)),
            ("k_base", Json::from(self.k_base)),
            ("sample_m", Json::from(self.sample_m)),
            ("cache_mb", Json::from(self.cache_mb)),
            ("seed", Json::from(self.seed as f64)),
            ("threads", Json::from(self.threads)),
            ("backend", Json::from(self.backend.as_str())),
            ("budget", Json::from(self.budget)),
            ("segments", Json::from(self.segment_views)),
            ("registry_cap_mb", Json::from(self.registry_cap_mb)),
            ("quant_route", Json::from(self.quant_route)),
            ("distributed", Json::from(self.distributed)),
            ("dist_workers", Json::from(self.dist_workers)),
            ("rounds", Json::from(self.rounds)),
            ("round_timeout", Json::from(self.round_timeout)),
            ("connect_timeout", Json::from(self.connect_timeout)),
            ("worker_retries", Json::from(self.worker_retries)),
        ])
    }
}

fn json_to_arg(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_valid() {
        let cfg = RunConfig::default();
        assert!(cfg.kernel_kind().is_ok());
        assert!(cfg.smo_config().is_ok());
        assert!(cfg.dcsvm_config().is_ok());
    }

    #[test]
    fn apply_overrides() {
        let mut cfg = RunConfig::default();
        cfg.apply("gamma", "8.5").unwrap();
        cfg.apply("algo", "cascade").unwrap();
        cfg.apply("kernel", "poly").unwrap();
        assert_eq!(cfg.gamma, 8.5);
        assert_eq!(cfg.algo, Algo::Cascade);
        assert!(matches!(cfg.kernel_kind().unwrap(), KernelKind::Poly { .. }));
        assert!(cfg.apply("bogus", "1").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("dcsvm_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        let mut cfg = RunConfig::default();
        cfg.apply("gamma", "4.0").unwrap();
        cfg.apply("dataset", "webspam-like").unwrap();
        std::fs::write(&path, cfg.to_json().to_string()).unwrap();
        let back = RunConfig::from_file(&path).unwrap();
        assert_eq!(back.gamma, 4.0);
        assert_eq!(back.dataset, "webspam-like");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn threads_default_and_flag_flow_end_to_end() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.threads, crate::util::threadpool::default_threads());
        let mut cfg = RunConfig::default();
        cfg.apply("threads", "3").unwrap();
        assert_eq!(cfg.threads, 3);
        assert_eq!(cfg.dcsvm_config().unwrap().threads, 3);
    }

    #[test]
    fn segments_flag_parses_and_flows() {
        let mut cfg = RunConfig::default();
        assert!(cfg.segment_views, "segment views default on");
        cfg.apply("segments", "false").unwrap();
        assert!(!cfg.segment_views);
        assert!(!cfg.dcsvm_config().unwrap().segment_views);
        cfg.apply("segments", "1").unwrap();
        assert!(cfg.segment_views);
        assert!(cfg.apply("segments", "maybe").is_err());
        assert_eq!(cfg.to_json().get("segments").as_bool(), Some(true));
    }

    #[test]
    fn registry_cap_flag_parses_and_flows() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.registry_cap_mb, 0, "registry cap defaults off");
        cfg.apply("registry-cap-mb", "8").unwrap();
        assert_eq!(cfg.registry_cap_mb, 8);
        assert_eq!(cfg.dcsvm_config().unwrap().registry_cap_bytes, 8 << 20);
        assert_eq!(cfg.to_json().get("registry_cap_mb").as_usize(), Some(8));
        assert!(cfg.apply("registry_cap_mb", "lots").is_err());
    }

    #[test]
    fn quant_route_flag_parses_and_flows() {
        let mut cfg = RunConfig::default();
        assert!(!cfg.quant_route, "quantized routing defaults off");
        assert!(!cfg.dcsvm_config().unwrap().quant_route);
        cfg.apply("quant-route", "true").unwrap();
        assert!(cfg.quant_route);
        assert!(cfg.dcsvm_config().unwrap().quant_route);
        cfg.apply("quant_route", "0").unwrap();
        assert!(!cfg.quant_route);
        assert!(cfg.apply("quant-route", "sometimes").is_err());
        cfg.apply("quant-route", "1").unwrap();
        assert_eq!(cfg.to_json().get("quant_route").as_bool(), Some(true));
    }

    #[test]
    fn distributed_flags_parse_and_flow() {
        let mut cfg = RunConfig::default();
        assert!(!cfg.distributed, "distributed defaults off");
        assert_eq!(cfg.dist_workers, 2);
        assert_eq!(cfg.rounds, 2);
        assert!(cfg.workers_addr.is_none());
        cfg.apply("distributed", "true").unwrap();
        cfg.apply("workers", "3").unwrap();
        cfg.apply("rounds", "4").unwrap();
        cfg.apply("workers-addr", "127.0.0.1:4100,127.0.0.1:4101").unwrap();
        assert!(cfg.distributed);
        assert_eq!(cfg.dist_workers, 3);
        assert_eq!(cfg.rounds, 4);
        assert_eq!(cfg.workers_addr.as_deref(), Some("127.0.0.1:4100,127.0.0.1:4101"));
        cfg.apply("distributed", "0").unwrap();
        assert!(!cfg.distributed);
        assert!(cfg.apply("distributed", "maybe").is_err());
        assert!(cfg.apply("rounds", "many").is_err());
        // Round-trips through a config file — but live endpoints do not.
        let j = cfg.to_json();
        assert_eq!(j.get("dist_workers").as_usize(), Some(3));
        assert_eq!(j.get("rounds").as_usize(), Some(4));
        assert_eq!(j.get("distributed").as_bool(), Some(false));
        assert_eq!(j.get("workers_addr"), &Json::Null);
    }

    #[test]
    fn recovery_flags_parse_validate_and_flow() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.round_timeout, 60.0, "round deadline defaults to 60s");
        assert_eq!(cfg.connect_timeout, 10.0, "connect deadline defaults to 10s");
        assert_eq!(cfg.worker_retries, 0, "respawn defaults off (straight to re-shard)");
        cfg.apply("round-timeout", "2.5").unwrap();
        cfg.apply("connect_timeout", "1.5").unwrap();
        cfg.apply("worker-retries", "3").unwrap();
        assert_eq!(cfg.round_timeout, 2.5);
        assert_eq!(cfg.connect_timeout, 1.5);
        assert_eq!(cfg.worker_retries, 3);
        // Deadlines must be positive finite seconds.
        assert!(cfg.apply("round_timeout", "0").is_err());
        assert!(cfg.apply("round-timeout", "-1").is_err());
        assert!(cfg.apply("round-timeout", "soon").is_err());
        assert!(cfg.apply("connect-timeout", "0").is_err());
        assert!(cfg.apply("worker_retries", "-1").is_err());
        // And they round-trip through a config file.
        let j = cfg.to_json();
        assert_eq!(j.get("round_timeout").as_f64(), Some(2.5));
        assert_eq!(j.get("connect_timeout").as_f64(), Some(1.5));
        assert_eq!(j.get("worker_retries").as_usize(), Some(3));
    }

    #[test]
    fn early_algo_sets_stop_level() {
        let mut cfg = RunConfig::default();
        cfg.apply("algo", "early").unwrap();
        assert_eq!(cfg.dcsvm_config().unwrap().stop_after_level, Some(1));
    }

    #[test]
    fn ovo_algo_parses_and_names() {
        assert_eq!(Algo::parse("ovo").unwrap(), Algo::Ovo);
        assert_eq!(Algo::parse("multiclass").unwrap(), Algo::Ovo);
        assert_eq!(Algo::Ovo.name(), "OVO");
        assert!(Algo::all().contains(&Algo::Ovo));
    }

    #[test]
    fn algo_names_unique() {
        let names: std::collections::HashSet<_> =
            Algo::all().iter().map(|a| a.name()).collect();
        assert_eq!(names.len(), Algo::all().len());
    }
}
