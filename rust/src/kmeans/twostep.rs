//! Two-step kernel kmeans (Chitta et al., KDD 2011) — the paper's divide
//! step, O(n·m·d) instead of O(n²·d).
//!
//! Step 1: kernel kmeans on an m-point sample (kernel_kmeans.rs).
//! Step 2: every point is assigned to the nearest *sample-defined* center:
//!
//! ```text
//! d²(x, m_c) = K(x,x) − (2/|M_c|) Σ_{j∈M_c} K(x, s_j) + self_term_c
//! ```
//!
//! which needs one K(all, sample) block pass — exactly the kernel-block
//! operator the AOT artifacts implement. Training-time entry points consume
//! a [`KernelContext`] (shared norms, batched dispatch); the fitted
//! [`Router`] stays backend-agnostic so early-prediction models can route
//! *test* points (paper eq. 11) with whatever kernel backend serves them.

use crate::cache::KernelContext;
use crate::kernel::quant::QuantizedRows;
use crate::kernel::BlockKernel;
use crate::util::prng::Pcg64;

use super::kernel_kmeans::{dense_kernel, kernel_kmeans};

/// A fitted two-step kernel-kmeans model: routes any point to a cluster.
#[derive(Clone, Debug)]
pub struct Router {
    /// Sample rows, row-major [m, dim].
    sample_x: Vec<f32>,
    sample_norms: Vec<f32>,
    dim: usize,
    /// Cluster of each sample point.
    sample_assign: Vec<u16>,
    /// Per-cluster member counts within the sample.
    counts: Vec<usize>,
    /// Per-cluster constant term of the kernel distance.
    self_term: Vec<f64>,
    pub k: usize,
    /// Int8-quantized sample rows (`--quant-route`): when present, every
    /// assignment pass evaluates its K(rows, sample) block against the
    /// quantized operand instead of `sample_x`. Routing is approximation-
    /// tolerant (the paper's early-prediction argument), and the flip rate
    /// vs the f32 path is gated in CI. Never serialized — a loaded router
    /// routes exactly until [`Self::set_quant_route`] re-enables it.
    quant: Option<QuantizedRows>,
}

impl Router {
    /// Fit on a sample drawn from the context's dataset at the given
    /// indices. Sample norms come from the context (computed once per
    /// dataset, never per fit).
    pub fn fit(
        ctx: &KernelContext,
        sample_idx: &[usize],
        k: usize,
        max_iter: usize,
        rng: &mut Pcg64,
    ) -> Router {
        let m = sample_idx.len();
        assert!(m > 0, "empty sample");
        let ds = ctx.ds();
        let dim = ds.dim;
        let mut sample_x = Vec::with_capacity(m * dim);
        let mut sample_norms = Vec::with_capacity(m);
        for &i in sample_idx {
            sample_x.extend_from_slice(ds.row(i));
            sample_norms.push(ctx.norm(i));
        }
        let kmat = dense_kernel(&sample_x, &sample_norms, dim, ctx.kernel());
        // The m×m sample kernel bypasses the row cache; keep the context's
        // whole-run kernel-value accounting honest.
        ctx.count_external_values((m * m) as u64);
        let sc = kernel_kmeans(&kmat, m, k, max_iter, rng);
        Router {
            sample_x,
            sample_norms,
            dim,
            sample_assign: sc.assign,
            counts: sc.counts,
            self_term: sc.self_term,
            k: sc.k,
            quant: None,
        }
    }

    pub fn sample_size(&self) -> usize {
        self.sample_norms.len()
    }

    /// Enable (or disable) the int8-quantized routing operand: quantizes
    /// the sample rows per-row (scale + zero-point) once; subsequent
    /// assignment passes run against the 4×-smaller codes. The exact
    /// `sample_x` stays resident — disabling restores bit-exact routing.
    pub fn set_quant_route(&mut self, on: bool) {
        self.quant = if on {
            Some(QuantizedRows::from_rows(&self.sample_x, self.dim))
        } else {
            None
        };
    }

    /// Whether assignment passes currently run against quantized operands.
    pub fn quant_route(&self) -> bool {
        self.quant.is_some()
    }

    /// Assign a batch of rows ([n, dim] row-major with norms) to clusters.
    /// One K(rows, sample) block pass, chunked. With
    /// [`Self::set_quant_route`] enabled the pass runs against the int8
    /// sample codes (kernel backend supplies only the kernel kind).
    pub fn assign_rows(
        &self,
        x: &[f32],
        norms: &[f32],
        kernel: &dyn BlockKernel,
    ) -> Vec<u16> {
        if let Some(q) = &self.quant {
            let kind = kernel.kind();
            return self.assign_rows_impl(x, norms, |xq, qn, out| {
                q.block(kind, xq, qn, &self.sample_norms, out)
            });
        }
        self.assign_rows_impl(x, norms, |xq, qn, out| {
            kernel.block(xq, qn, &self.sample_x, &self.sample_norms, self.dim, out)
        })
    }

    /// [`Self::assign_rows`] with an in-process thread budget: large
    /// K(rows, sample) chunks fan out over row panels
    /// ([`BlockKernel::block_par`]). Assignments are bit-identical for any
    /// `threads` value. The quantized operand runs on the calling thread —
    /// the sample block is small and the codes make it 4× smaller still.
    pub fn assign_rows_par(
        &self,
        x: &[f32],
        norms: &[f32],
        kernel: &dyn BlockKernel,
        threads: usize,
    ) -> Vec<u16> {
        if self.quant.is_some() {
            let _ = threads;
            return self.assign_rows(x, norms, kernel);
        }
        self.assign_rows_impl(x, norms, |xq, qn, out| {
            kernel.block_par(xq, qn, &self.sample_x, &self.sample_norms, self.dim, threads, out);
        })
    }

    /// Shared assignment core: `block` fills `out` with one
    /// K(chunk, sample) pass — callers choose the dispatch path (plain
    /// backend, thread-budgeted, or a [`KernelContext`] that also counts
    /// parallel dispatches).
    fn assign_rows_impl<F>(&self, x: &[f32], norms: &[f32], block: F) -> Vec<u16>
    where
        F: Fn(&[f32], &[f32], &mut [f32]),
    {
        let n = norms.len();
        let m = self.sample_size();
        let mut out = Vec::with_capacity(n);
        const CHUNK: usize = 1024;
        let mut kblock = vec![0f32; CHUNK.min(n.max(1)) * m];
        for (c0, chunk_norms) in norms.chunks(CHUNK).enumerate() {
            let lo = c0 * CHUNK;
            let take = chunk_norms.len();
            block(
                &x[lo * self.dim..(lo + take) * self.dim],
                chunk_norms,
                &mut kblock[..take * m],
            );
            for qi in 0..take {
                let row = &kblock[qi * m..(qi + 1) * m];
                // cross[c] = Σ_{j∈M_c} K(x, s_j)
                let mut cross = vec![0f64; self.k];
                for (j, &kv) in row.iter().enumerate() {
                    cross[self.sample_assign[j] as usize] += kv as f64;
                }
                let mut best = 0u16;
                let mut best_d = f64::INFINITY;
                for c in 0..self.k {
                    if self.counts[c] == 0 {
                        continue;
                    }
                    // K(x,x) is constant across c — drop it.
                    let d = -2.0 * cross[c] / self.counts[c] as f64 + self.self_term[c];
                    if d < best_d {
                        best_d = d;
                        best = c as u16;
                    }
                }
                out.push(best);
            }
        }
        out
    }

    /// Assign every row of the context's dataset (norms from the context).
    /// Dispatches through the context, so large assignment passes fan out
    /// over its thread budget and are counted in its `ValueStats`.
    pub fn assign_all(&self, ctx: &KernelContext) -> Vec<u16> {
        // One K(all, sample) pass outside the row cache — counted so
        // `ValueStats::values_computed` reflects the whole run.
        ctx.count_external_values((ctx.len() * self.sample_size()) as u64);
        if let Some(q) = &self.quant {
            ctx.count_quantized_values((ctx.len() * self.sample_size()) as u64);
            let kind = ctx.kind();
            return self.assign_rows_impl(&ctx.ds().x, ctx.norms(), |xq, qn, out| {
                q.block(kind, xq, qn, &self.sample_norms, out)
            });
        }
        self.assign_rows_impl(&ctx.ds().x, ctx.norms(), |xq, qn, out| {
            ctx.block_dispatch(xq, qn, &self.sample_x, &self.sample_norms, self.dim, out)
        })
    }

    /// Route a single point.
    pub fn assign_one(&self, x: &[f32], kernel: &dyn BlockKernel) -> u16 {
        let norm: f32 = x.iter().map(|&v| v * v).sum();
        self.assign_rows(x, &[norm], kernel)[0]
    }

    /// Feature dimension the router was fitted on.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Serialize for model persistence (the early-prediction serving path).
    /// Sample norms are recomputed on load, exactly as [`Router::fit`]
    /// computes them, so a round-tripped router assigns identically.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("dim", Json::from(self.dim)),
            ("k", Json::from(self.k)),
            (
                "sample_x",
                Json::arr_f64(&self.sample_x.iter().map(|&v| v as f64).collect::<Vec<_>>()),
            ),
            (
                "sample_assign",
                Json::arr_f64(
                    &self.sample_assign.iter().map(|&c| c as f64).collect::<Vec<_>>(),
                ),
            ),
            (
                "counts",
                Json::arr_f64(&self.counts.iter().map(|&c| c as f64).collect::<Vec<_>>()),
            ),
            ("self_term", Json::arr_f64(&self.self_term)),
        ])
    }

    /// Deserialize a router saved by [`Router::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Router> {
        use anyhow::{anyhow, bail};
        let dim = j.get("dim").as_usize().ok_or_else(|| anyhow!("router: missing dim"))?;
        let k = j.get("k").as_usize().ok_or_else(|| anyhow!("router: missing k"))?;
        let f64s = |key: &str| -> anyhow::Result<Vec<f64>> {
            Ok(j.get(key)
                .as_arr()
                .ok_or_else(|| anyhow!("router: missing {key}"))?
                .iter()
                .map(|v| v.as_f64().unwrap_or(0.0))
                .collect())
        };
        let sample_x: Vec<f32> = f64s("sample_x")?.iter().map(|&v| v as f32).collect();
        let sample_assign: Vec<u16> =
            f64s("sample_assign")?.iter().map(|&v| v as u16).collect();
        let counts: Vec<usize> = f64s("counts")?.iter().map(|&v| v as usize).collect();
        let self_term = f64s("self_term")?;
        if dim == 0 || k == 0 {
            bail!("router: dim/k must be positive");
        }
        let m = sample_assign.len();
        if m == 0 || sample_x.len() != m * dim {
            bail!("router: sample_x/sample_assign/dim inconsistent");
        }
        if counts.len() != k || self_term.len() != k {
            bail!("router: counts/self_term must have k entries");
        }
        if sample_assign.iter().any(|&c| c as usize >= k) {
            bail!("router: sample assignment out of range");
        }
        let sample_norms: Vec<f32> = sample_x
            .chunks(dim)
            .map(|r| r.iter().map(|&v| v * v).sum())
            .collect();
        Ok(Router {
            sample_x,
            sample_norms,
            dim,
            sample_assign,
            counts,
            self_term,
            k,
            quant: None,
        })
    }
}

/// A partition of a dataset into k clusters.
#[derive(Clone, Debug)]
pub struct Partition {
    pub assign: Vec<u16>,
    pub k: usize,
    /// Indices per cluster.
    pub members: Vec<Vec<usize>>,
}

impl Partition {
    pub fn from_assign(assign: Vec<u16>, k: usize) -> Partition {
        let mut members = vec![Vec::new(); k];
        for (i, &c) in assign.iter().enumerate() {
            members[c as usize].push(i);
        }
        Partition { assign, k, members }
    }

    /// A uniformly random partition (the Figure-1 baseline).
    pub fn random(n: usize, k: usize, rng: &mut Pcg64) -> Partition {
        let assign: Vec<u16> = (0..n).map(|_| rng.below(k) as u16).collect();
        Partition::from_assign(assign, k)
    }

    pub fn largest_cluster(&self) -> usize {
        self.members.iter().map(|m| m.len()).max().unwrap_or(0)
    }
}

/// Full two-step pipeline: sample → kernel kmeans → assign all points.
/// `sample_from`: indices eligible for sampling (the adaptive-clustering
/// step samples from the current SV set — Algorithm 1).
pub fn two_step_partition(
    ctx: &KernelContext,
    k: usize,
    m: usize,
    sample_from: Option<&[usize]>,
    rng: &mut Pcg64,
) -> (Router, Partition) {
    let pool_len = sample_from.map(|s| s.len()).unwrap_or(ctx.len());
    let m_eff = m.min(pool_len).max(1);
    let picked = rng.sample_indices(pool_len, m_eff);
    let sample_idx: Vec<usize> = match sample_from {
        Some(pool) => picked.iter().map(|&i| pool[i]).collect(),
        None => picked,
    };
    let mut router = Router::fit(ctx, &sample_idx, k, 30, rng);
    if ctx.quant_route() {
        router.set_quant_route(true);
    }
    let assign = router.assign_all(ctx);
    let part = Partition::from_assign(assign, router.k);
    (router, part)
}

/// [`two_step_partition`] restricted to a member subset (LOCAL indices
/// throughout): the sampling pool, the returned [`Partition`], and
/// `sample_from` are all in `members`-local coordinates, so a caller that
/// restricts one shared [`KernelContext`] to a subproblem (the OVO
/// pairwise trainer) draws the *same* rng sequence and produces the *same*
/// clustering as a solver handed a materialized copy of those rows —
/// `rng.sample_indices` draw counts depend on the pool length, which here
/// is the LOCAL length. Only the member features/norms are gathered into a
/// transient scratch for the assignment pass (O(|members|·dim), freed on
/// return); no `Dataset` is ever materialized.
pub fn two_step_partition_restricted(
    ctx: &KernelContext,
    k: usize,
    m: usize,
    members: &[usize],
    sample_from: Option<&[usize]>,
    rng: &mut Pcg64,
) -> (Router, Partition) {
    let pool_len = sample_from.map(|s| s.len()).unwrap_or(members.len());
    let m_eff = m.min(pool_len).max(1);
    let picked = rng.sample_indices(pool_len, m_eff);
    let sample_idx: Vec<usize> = match sample_from {
        Some(pool) => picked.iter().map(|&i| members[pool[i]]).collect(),
        None => picked.iter().map(|&i| members[i]).collect(),
    };
    let mut router = Router::fit(ctx, &sample_idx, k, 30, rng);
    if ctx.quant_route() {
        router.set_quant_route(true);
    }
    let ds = ctx.ds();
    let dim = ds.dim;
    let mut xs = Vec::with_capacity(members.len() * dim);
    let mut norms = Vec::with_capacity(members.len());
    for &g in members {
        xs.extend_from_slice(ds.row(g));
        norms.push(ctx.norm(g));
    }
    // One K(members, sample) pass outside the row cache — counted like
    // `Router::assign_all` so whole-run `values_computed` stays honest.
    ctx.count_external_values((members.len() * router.sample_size()) as u64);
    let assign = if let Some(q) = &router.quant {
        ctx.count_quantized_values((members.len() * router.sample_size()) as u64);
        let kind = ctx.kind();
        router.assign_rows_impl(&xs, &norms, |xq, qn, out| {
            q.block(kind, xq, qn, &router.sample_norms, out)
        })
    } else {
        router.assign_rows_impl(&xs, &norms, |xq, qn, out| {
            ctx.block_dispatch(xq, qn, &router.sample_x, &router.sample_norms, router.dim, out)
        })
    };
    let part = Partition::from_assign(assign, router.k);
    (router, part)
}

/// Between-cluster kernel mass D(π) = Σ_{π(i)≠π(j)} |K_ij| (Theorem 1).
/// O(n²) — bench/test use on small subsets only.
pub fn off_diagonal_mass(ctx: &KernelContext, assign: &[u16]) -> f64 {
    let ds = ctx.ds();
    let n = ds.len();
    let norms = ctx.norms();
    ctx.count_external_values((n * n) as u64);
    let mut total = 0f64;
    const CHUNK: usize = 256;
    let mut block = vec![0f32; CHUNK * n];
    let mut lo = 0;
    while lo < n {
        let take = CHUNK.min(n - lo);
        ctx.block_dispatch(
            &ds.x[lo * ds.dim..(lo + take) * ds.dim],
            &norms[lo..lo + take],
            &ds.x,
            norms,
            ds.dim,
            &mut block[..take * n],
        );
        for qi in 0..take {
            let ci = assign[lo + qi];
            let row = &block[qi * n..(qi + 1) * n];
            for (j, &kv) in row.iter().enumerate() {
                if assign[j] != ci {
                    total += kv.abs() as f64;
                }
            }
        }
        lo += take;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate};
    use crate::data::Dataset;
    use crate::kernel::{native::NativeKernel, KernelKind};

    fn blobs(n: usize, seed: u64) -> Dataset {
        // 4 well-separated blobs
        let centers = [(0.0f32, 0.0f32), (8.0, 0.0), (0.0, 8.0), (8.0, 8.0)];
        let mut rng = Pcg64::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let (cx, cy) = centers[i % 4];
            x.push(cx + rng.next_gaussian() as f32 * 0.3);
            x.push(cy + rng.next_gaussian() as f32 * 0.3);
            y.push(if i % 2 == 0 { 1 } else { -1 });
        }
        Dataset::new(x, y, 2, "blobs")
    }

    #[test]
    fn twostep_recovers_blobs_and_routes_consistently() {
        let ds = blobs(400, 1);
        let kern = NativeKernel::new(KernelKind::Rbf { gamma: 0.5 });
        let ctx = KernelContext::new(&ds, &kern, 1 << 20);
        let mut rng = Pcg64::new(2);
        let (router, part) = two_step_partition(&ctx, 4, 64, None, &mut rng);
        assert_eq!(part.k, 4);
        // Every blob should map to exactly one cluster.
        for blob in 0..4 {
            let ids: std::collections::HashSet<u16> = (0..ds.len())
                .filter(|i| i % 4 == blob)
                .map(|i| part.assign[i])
                .collect();
            assert_eq!(ids.len(), 1, "blob {blob} split across clusters");
        }
        // Routing a training point again gives its assigned cluster.
        for i in (0..ds.len()).step_by(37) {
            assert_eq!(router.assign_one(ds.row(i), &kern), part.assign[i]);
        }
    }

    #[test]
    fn kernel_partition_beats_random_on_off_diagonal_mass() {
        let mut rng = Pcg64::new(3);
        let ds = generate(&covtype_like(), 300, &mut rng);
        let kern = NativeKernel::new(KernelKind::Rbf { gamma: 16.0 });
        let ctx = KernelContext::new(&ds, &kern, 1 << 20);
        let (_, part) = two_step_partition(&ctx, 8, 100, None, &mut rng);
        let d_kmeans = off_diagonal_mass(&ctx, &part.assign);
        let rand_part = Partition::random(ds.len(), 8, &mut rng);
        let d_rand = off_diagonal_mass(&ctx, &rand_part.assign);
        assert!(
            d_kmeans < d_rand,
            "kernel kmeans D(π)={d_kmeans} not below random {d_rand}"
        );
    }

    #[test]
    fn adaptive_sampling_pool_respected() {
        let ds = blobs(200, 4);
        let kern = NativeKernel::new(KernelKind::Rbf { gamma: 0.5 });
        let ctx = KernelContext::new(&ds, &kern, 1 << 20);
        let mut rng = Pcg64::new(5);
        // Pool = only blob 0 and 1 points
        let pool: Vec<usize> = (0..ds.len()).filter(|i| i % 4 < 2).collect();
        let (router, _) = two_step_partition(&ctx, 2, 32, Some(&pool), &mut rng);
        assert_eq!(router.k, 2);
        assert!(router.sample_size() <= 32);
    }

    #[test]
    fn router_json_roundtrip_routes_identically() {
        let ds = blobs(240, 7);
        let kern = NativeKernel::new(KernelKind::Rbf { gamma: 0.5 });
        let ctx = KernelContext::new(&ds, &kern, 1 << 20);
        let mut rng = Pcg64::new(8);
        let (router, _) = two_step_partition(&ctx, 4, 48, None, &mut rng);
        let text = router.to_json().to_string();
        let back =
            Router::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.k, router.k);
        assert_eq!(back.dim(), router.dim());
        assert_eq!(back.sample_size(), router.sample_size());
        let norms = ds.sq_norms();
        assert_eq!(
            back.assign_rows(&ds.x, &norms, &kern),
            router.assign_rows(&ds.x, &norms, &kern)
        );
    }

    #[test]
    fn router_from_json_rejects_inconsistent_shapes() {
        let ds = blobs(60, 9);
        let kern = NativeKernel::new(KernelKind::Rbf { gamma: 0.5 });
        let ctx = KernelContext::new(&ds, &kern, 1 << 20);
        let mut rng = Pcg64::new(10);
        let (router, _) = two_step_partition(&ctx, 2, 16, None, &mut rng);
        let good = router.to_json().to_string();
        // Drop a required field.
        let broken = good.replace("\"sample_x\"", "\"nope\"");
        assert!(
            Router::from_json(&crate::util::json::Json::parse(&broken).unwrap()).is_err()
        );
    }

    /// Tentpole: quantized routing flips few decisions vs the f32 path —
    /// on well-separated blobs the per-row int8 error (≤ scale/2 per
    /// feature) is far below the inter-cluster kernel-distance margin, so
    /// assignments should be identical; on the noisier covtype-like data
    /// the flip rate must stay under the CI gate threshold.
    #[test]
    fn quant_route_flips_stay_under_gate() {
        // Well-separated blobs: zero flips expected.
        let ds = blobs(400, 11);
        let kern = NativeKernel::new(KernelKind::Rbf { gamma: 0.5 });
        let ctx = KernelContext::new(&ds, &kern, 1 << 20);
        let mut rng = Pcg64::new(12);
        let (router, _) = two_step_partition(&ctx, 4, 64, None, &mut rng);
        let norms = ds.sq_norms();
        let exact = router.assign_rows(&ds.x, &norms, &kern);
        let mut qrouter = router.clone();
        qrouter.set_quant_route(true);
        assert!(qrouter.quant_route() && !router.quant_route());
        let quant = qrouter.assign_rows(&ds.x, &norms, &kern);
        let flips = exact.iter().zip(&quant).filter(|(a, b)| a != b).count();
        assert_eq!(flips, 0, "{flips} routing flips on well-separated blobs");
        // The par entry point routes identically through the quant operand.
        assert_eq!(quant, qrouter.assign_rows_par(&ds.x, &norms, &kern, 4));

        // Noisy data: flips allowed, but bounded by the gate threshold.
        let mut rng = Pcg64::new(13);
        let ds = generate(&covtype_like(), 300, &mut rng);
        let kern = NativeKernel::new(KernelKind::Rbf { gamma: 16.0 });
        let qctx = KernelContext::new(&ds, &kern, 1 << 20).with_quant_route(true);
        let (qrouter, _) = two_step_partition(&qctx, 8, 100, None, &mut rng);
        assert!(qrouter.quant_route(), "quant-route context must arm the router");
        assert!(
            qctx.value_stats().quantized_values >= (ds.len() * qrouter.sample_size()) as u64,
            "quantized assignment pass not counted"
        );
        let norms = ds.sq_norms();
        let quant = qrouter.assign_rows(&ds.x, &norms, &kern);
        let mut exact_router = qrouter.clone();
        exact_router.set_quant_route(false);
        let exact = exact_router.assign_rows(&ds.x, &norms, &kern);
        let flips = exact.iter().zip(&quant).filter(|(a, b)| a != b).count();
        let rate = flips as f64 / ds.len() as f64;
        assert!(rate <= 0.2, "routing flip rate {rate:.3} above gate (0.2)");
    }

    #[test]
    fn partition_members_consistent() {
        let assign = vec![0u16, 1, 0, 2, 1];
        let p = Partition::from_assign(assign.clone(), 3);
        assert_eq!(p.members[0], vec![0, 2]);
        assert_eq!(p.members[1], vec![1, 4]);
        assert_eq!(p.members[2], vec![3]);
        assert_eq!(p.largest_cluster(), 2);
    }

    #[test]
    fn off_diagonal_mass_zero_for_single_cluster() {
        let ds = blobs(50, 6);
        let kern = NativeKernel::new(KernelKind::Rbf { gamma: 0.5 });
        let ctx = KernelContext::new(&ds, &kern, 1 << 20);
        let assign = vec![0u16; ds.len()];
        assert_eq!(off_diagonal_mass(&ctx, &assign), 0.0);
    }
}
