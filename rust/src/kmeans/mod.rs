//! Clustering substrate: kernel kmeans on a sample (`kernel_kmeans`) and the
//! two-step extension to the full dataset with a reusable point router
//! (`twostep`) — the paper's divide step and the early-prediction router.

pub mod kernel_kmeans;
pub mod twostep;

pub use twostep::{
    off_diagonal_mass, two_step_partition, two_step_partition_restricted, Partition, Router,
};
