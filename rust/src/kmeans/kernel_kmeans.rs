//! Kernel kmeans (Lloyd iterations in feature space) on a sample.
//!
//! The divide step needs a partition minimizing the between-cluster kernel
//! mass D(π) (Theorem 1); kernel kmeans minimizes exactly the within-cluster
//! distortion whose complement is D(π) under the normalized-kernel view.
//! Centers live implicitly in feature space: the squared distance of point i
//! to the mean of cluster c over members M_c is
//!
//! ```text
//! ‖φ(x_i) − m_c‖² = K_ii − (2/|M_c|) Σ_{j∈M_c} K_ij
//!                       + (1/|M_c|²) Σ_{j,l∈M_c} K_jl
//! ```
//!
//! This module runs on the m-point *sample* (O(m²) kernel fits in memory;
//! the paper uses m = 1000); `twostep` extends the partition to all n points.

use crate::kernel::BlockKernel;
use crate::util::prng::Pcg64;

/// Result of kernel kmeans on the sample.
#[derive(Clone, Debug)]
pub struct SampleClustering {
    /// Cluster id per sample point.
    pub assign: Vec<u16>,
    /// Number of clusters.
    pub k: usize,
    /// Per-cluster member counts.
    pub counts: Vec<usize>,
    /// Per-cluster (1/|M_c|²)·ΣΣ K_jl — the constant term of the distance.
    pub self_term: Vec<f64>,
    /// Lloyd iterations executed.
    pub iterations: usize,
}

/// Run kernel kmeans on `m` points given their dense kernel matrix
/// (row-major m×m). Deterministic per `rng`.
pub fn kernel_kmeans(
    kmat: &[f32],
    m: usize,
    k: usize,
    max_iter: usize,
    rng: &mut Pcg64,
) -> SampleClustering {
    assert_eq!(kmat.len(), m * m);
    let k = k.min(m).max(1);

    // kmeans++-style greedy init in kernel space: first center random, each
    // next = farthest (in kernel distance) from chosen so far.
    let mut seeds = Vec::with_capacity(k);
    seeds.push(rng.below(m));
    let kd = |i: usize, j: usize| -> f64 {
        (kmat[i * m + i] + kmat[j * m + j] - 2.0 * kmat[i * m + j]) as f64
    };
    let mut min_d: Vec<f64> = (0..m).map(|i| kd(i, seeds[0])).collect();
    while seeds.len() < k {
        // pick the point with max distance to nearest seed
        let (best, _) = min_d
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        seeds.push(best);
        for i in 0..m {
            min_d[i] = min_d[i].min(kd(i, best));
        }
    }
    let mut assign: Vec<u16> = (0..m)
        .map(|i| {
            (0..k)
                .min_by(|&a, &b| kd(i, seeds[a]).total_cmp(&kd(i, seeds[b])))
                .unwrap() as u16
        })
        .collect();

    let mut counts = vec![0usize; k];
    let mut self_term = vec![0f64; k];
    let mut iterations = 0;

    for _ in 0..max_iter {
        iterations += 1;
        // --- recompute cluster statistics --------------------------------
        counts.iter_mut().for_each(|c| *c = 0);
        for &a in &assign {
            counts[a as usize] += 1;
        }
        // Reseed empty clusters with the farthest point from its center.
        for c in 0..k {
            if counts[c] == 0 {
                let victim = rng.below(m);
                counts[assign[victim] as usize] -= 1;
                assign[victim] = c as u16;
                counts[c] = 1;
            }
        }
        // self_term[c] = (1/|M_c|²) ΣΣ K_jl over members
        self_term.iter_mut().for_each(|s| *s = 0.0);
        for i in 0..m {
            let ci = assign[i] as usize;
            for j in 0..m {
                if assign[j] as usize == ci {
                    self_term[ci] += kmat[i * m + j] as f64;
                }
            }
        }
        for c in 0..k {
            let n = counts[c] as f64;
            self_term[c] /= (n * n).max(1.0);
        }

        // --- reassign ------------------------------------------------------
        let mut changed = 0usize;
        // cross[i][c] = Σ_{j∈M_c} K_ij
        for i in 0..m {
            let mut cross = vec![0f64; k];
            for j in 0..m {
                cross[assign[j] as usize] += kmat[i * m + j] as f64;
            }
            let mut best_c = assign[i];
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                if counts[c] == 0 {
                    continue;
                }
                let d = kmat[i * m + i] as f64 - 2.0 * cross[c] / counts[c] as f64
                    + self_term[c];
                if d < best_d {
                    best_d = d;
                    best_c = c as u16;
                }
            }
            if best_c != assign[i] {
                assign[i] = best_c;
                changed += 1;
            }
        }
        if changed == 0 {
            break;
        }
    }

    // Final statistics for the converged assignment.
    counts.iter_mut().for_each(|c| *c = 0);
    for &a in &assign {
        counts[a as usize] += 1;
    }
    self_term.iter_mut().for_each(|s| *s = 0.0);
    for i in 0..m {
        let ci = assign[i] as usize;
        for j in 0..m {
            if assign[j] as usize == ci {
                self_term[ci] += kmat[i * m + j] as f64;
            }
        }
    }
    for c in 0..k {
        let n = counts[c] as f64;
        self_term[c] /= (n * n).max(1.0);
    }

    SampleClustering { assign, k, counts, self_term, iterations }
}

/// Dense kernel matrix of a row set (helper for the sample).
pub fn dense_kernel(
    x: &[f32],
    norms: &[f32],
    dim: usize,
    kernel: &dyn BlockKernel,
) -> Vec<f32> {
    let m = norms.len();
    let mut kmat = vec![0f32; m * m];
    kernel.block(x, norms, x, norms, dim, &mut kmat);
    kmat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{native::NativeKernel, KernelKind};

    /// Three well-separated blobs in 2-D must be recovered exactly.
    fn blob_data() -> (Vec<f32>, Vec<f32>, Vec<usize>) {
        let centers = [(0.0f32, 0.0f32), (10.0, 0.0), (0.0, 10.0)];
        let mut rng = Pcg64::new(5);
        let mut x = Vec::new();
        let mut truth = Vec::new();
        for (ci, &(cx, cy)) in centers.iter().enumerate() {
            for _ in 0..20 {
                x.push(cx + rng.next_gaussian() as f32 * 0.3);
                x.push(cy + rng.next_gaussian() as f32 * 0.3);
                truth.push(ci);
            }
        }
        let norms = x.chunks(2).map(|r| r[0] * r[0] + r[1] * r[1]).collect();
        (x, norms, truth)
    }

    #[test]
    fn recovers_separated_blobs() {
        let (x, norms, truth) = blob_data();
        let kern = NativeKernel::new(KernelKind::Rbf { gamma: 0.5 });
        let kmat = dense_kernel(&x, &norms, 2, &kern);
        let mut rng = Pcg64::new(1);
        let res = kernel_kmeans(&kmat, 60, 3, 50, &mut rng);
        // Clustering must be a relabelling of the truth.
        let mut map = [usize::MAX; 3];
        for i in 0..60 {
            let c = res.assign[i] as usize;
            if map[truth[i]] == usize::MAX {
                map[truth[i]] = c;
            }
            assert_eq!(map[truth[i]], c, "point {i} misclustered");
        }
        assert_eq!(res.counts, vec![20, 20, 20]);
    }

    #[test]
    fn no_empty_clusters() {
        let (x, norms, _) = blob_data();
        let kern = NativeKernel::new(KernelKind::Rbf { gamma: 0.5 });
        let kmat = dense_kernel(&x, &norms, 2, &kern);
        let mut rng = Pcg64::new(2);
        // Ask for more clusters than natural blobs: still no empties.
        let res = kernel_kmeans(&kmat, 60, 7, 50, &mut rng);
        assert!(res.counts.iter().all(|&c| c > 0), "{:?}", res.counts);
        assert_eq!(res.counts.iter().sum::<usize>(), 60);
    }

    #[test]
    fn k_capped_at_m() {
        let kern = NativeKernel::new(KernelKind::Rbf { gamma: 1.0 });
        let x = vec![0.0f32, 1.0, 2.0, 3.0];
        let norms: Vec<f32> = x.iter().map(|v| v * v).collect();
        let kmat = dense_kernel(&x, &norms, 1, &kern);
        let mut rng = Pcg64::new(3);
        let res = kernel_kmeans(&kmat, 4, 10, 20, &mut rng);
        assert_eq!(res.k, 4);
    }

    #[test]
    fn deterministic_per_seed() {
        let (x, norms, _) = blob_data();
        let kern = NativeKernel::new(KernelKind::Rbf { gamma: 0.5 });
        let kmat = dense_kernel(&x, &norms, 2, &kern);
        let a = kernel_kmeans(&kmat, 60, 3, 50, &mut Pcg64::new(7));
        let b = kernel_kmeans(&kmat, 60, 3, 50, &mut Pcg64::new(7));
        assert_eq!(a.assign, b.assign);
    }
}
