//! LIBSVM sparse text format reader/writer.
//!
//! The paper's datasets ship in this format (`label idx:val idx:val ...`,
//! 1-based indices). The reader densifies into `Dataset` (our scales fit in
//! RAM comfortably); the writer lets users export synthetic datasets to run
//! against external solvers.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::dataset::Dataset;

/// Parse LIBSVM text. Labels may be any two values; they are mapped to ±1
/// by sign (0/1 labels map 0 → -1). `dim_hint` pads/validates feature count.
pub fn read_libsvm(path: &Path, dim_hint: Option<usize>) -> Result<Dataset> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    parse_libsvm(BufReader::new(file), dim_hint, path.display().to_string())
}

pub fn parse_libsvm<R: BufRead>(
    reader: R,
    dim_hint: Option<usize>,
    name: String,
) -> Result<Dataset> {
    let mut rows: Vec<Vec<(usize, f32)>> = Vec::new();
    let mut labels: Vec<i8> = Vec::new();
    let mut max_idx = 0usize;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: f64 = parts
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        labels.push(if label > 0.0 { 1 } else { -1 });
        let mut feats = Vec::new();
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .with_context(|| format!("line {}: bad pair '{tok}'", lineno + 1))?;
            let idx: usize = idx
                .parse()
                .with_context(|| format!("line {}: bad index", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: indices are 1-based", lineno + 1);
            }
            let val: f32 = val
                .parse()
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            max_idx = max_idx.max(idx);
            feats.push((idx - 1, val));
        }
        rows.push(feats);
    }

    let dim = dim_hint.unwrap_or(max_idx).max(max_idx);
    if dim == 0 {
        bail!("empty dataset: no features found");
    }
    let mut x = vec![0f32; rows.len() * dim];
    for (i, feats) in rows.iter().enumerate() {
        for &(j, v) in feats {
            x[i * dim + j] = v;
        }
    }
    Ok(Dataset::new(x, labels, dim, name))
}

/// Parse multi-label LIBSVM text: labels are kept as integer class ids
/// (`0, 1, 2, …`) instead of being binarized by sign. Returns
/// `(row-major features, class labels, dim)` — the raw parts, so the data
/// layer stays independent of the multiclass module
/// ([`crate::multiclass::MulticlassDataset::from_libsvm`] wraps them).
pub fn parse_libsvm_multiclass<R: BufRead>(
    reader: R,
    dim_hint: Option<usize>,
) -> Result<(Vec<f32>, Vec<u16>, usize)> {
    let mut rows: Vec<Vec<(usize, f32)>> = Vec::new();
    let mut labels: Vec<u16> = Vec::new();
    let mut max_idx = 0usize;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: f64 = parts
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        if label < 0.0 || label.fract() != 0.0 || label > u16::MAX as f64 {
            bail!(
                "line {}: multiclass labels must be integers in 0..={} (got {label})",
                lineno + 1,
                u16::MAX
            );
        }
        labels.push(label as u16);
        let mut feats = Vec::new();
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .with_context(|| format!("line {}: bad pair '{tok}'", lineno + 1))?;
            let idx: usize = idx
                .parse()
                .with_context(|| format!("line {}: bad index", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: indices are 1-based", lineno + 1);
            }
            let val: f32 = val
                .parse()
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            max_idx = max_idx.max(idx);
            feats.push((idx - 1, val));
        }
        rows.push(feats);
    }

    let dim = dim_hint.unwrap_or(max_idx).max(max_idx);
    if dim == 0 {
        bail!("empty dataset: no features found");
    }
    let mut x = vec![0f32; rows.len() * dim];
    for (i, feats) in rows.iter().enumerate() {
        for &(j, v) in feats {
            x[i * dim + j] = v;
        }
    }
    Ok((x, labels, dim))
}

/// [`parse_libsvm_multiclass`] over a file.
pub fn read_libsvm_multiclass(
    path: &Path,
    dim_hint: Option<usize>,
) -> Result<(Vec<f32>, Vec<u16>, usize)> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    parse_libsvm_multiclass(BufReader::new(file), dim_hint)
}

/// Render multiclass rows as LIBSVM text (`label idx:val ...`, zeros
/// omitted, 1-based) — the writer counterpart of
/// [`parse_libsvm_multiclass`], used by benches/tests to stage multiclass
/// train files for the CLI.
pub fn format_libsvm_multiclass(x: &[f32], labels: &[u16], dim: usize) -> String {
    use std::fmt::Write as _;
    assert_eq!(x.len(), labels.len() * dim);
    let mut out = String::new();
    for (i, &label) in labels.iter().enumerate() {
        let _ = write!(out, "{label}");
        for (j, &v) in x[i * dim..(i + 1) * dim].iter().enumerate() {
            if v != 0.0 {
                let _ = write!(out, " {}:{}", j + 1, v);
            }
        }
        out.push('\n');
    }
    out
}

/// Append one LIBSVM line (`±1 idx:val ...\n`, zeros omitted, 1-based):
/// the single row serializer behind [`format_libsvm`] and [`write_libsvm`].
fn format_libsvm_row(out: &mut String, y: i8, row: &[f32]) {
    use std::fmt::Write as _;
    let _ = write!(out, "{}", if y == 1 { "+1" } else { "-1" });
    for (j, &v) in row.iter().enumerate() {
        if v != 0.0 {
            let _ = write!(out, " {}:{}", j + 1, v);
        }
    }
    out.push('\n');
}

/// Render a dataset as LIBSVM-format text (zeros omitted): the in-memory
/// counterpart of [`write_libsvm`], and the one serializer test harnesses
/// use to build `dcsvm serve` request batches.
pub fn format_libsvm(ds: &Dataset) -> String {
    let mut out = String::new();
    for i in 0..ds.len() {
        format_libsvm_row(&mut out, ds.y[i], ds.row(i));
    }
    out
}

/// Write a dataset in LIBSVM format (zeros omitted), streaming row by row
/// (peak memory stays O(row), not O(file)).
pub fn write_libsvm(ds: &Dataset, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    let mut line = String::new();
    for i in 0..ds.len() {
        line.clear();
        format_libsvm_row(&mut line, ds.y[i], ds.row(i));
        w.write_all(line.as_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_basic() {
        let txt = "+1 1:0.5 3:2.0\n-1 2:1.0\n# comment\n\n+1 1:1\n";
        let ds = parse_libsvm(Cursor::new(txt), None, "t".into()).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim, 3);
        assert_eq!(ds.row(0), &[0.5, 0.0, 2.0]);
        assert_eq!(ds.row(1), &[0.0, 1.0, 0.0]);
        assert_eq!(ds.y, vec![1, -1, 1]);
    }

    #[test]
    fn zero_one_labels_map_to_pm1() {
        let txt = "1 1:1\n0 1:2\n";
        let ds = parse_libsvm(Cursor::new(txt), None, "t".into()).unwrap();
        assert_eq!(ds.y, vec![1, -1]);
    }

    #[test]
    fn dim_hint_pads() {
        let txt = "+1 1:1\n";
        let ds = parse_libsvm(Cursor::new(txt), Some(5), "t".into()).unwrap();
        assert_eq!(ds.dim, 5);
    }

    #[test]
    fn rejects_zero_index() {
        let txt = "+1 0:1\n";
        assert!(parse_libsvm(Cursor::new(txt), None, "t".into()).is_err());
    }

    #[test]
    fn multiclass_parse_keeps_class_ids() {
        let txt = "0 1:0.5\n3 2:1.0\n# comment\n7 1:1 3:2\n";
        let (x, labels, dim) = parse_libsvm_multiclass(Cursor::new(txt), None).unwrap();
        assert_eq!(labels, vec![0, 3, 7]);
        assert_eq!(dim, 3);
        assert_eq!(&x[0..3], &[0.5, 0.0, 0.0]);
        assert_eq!(&x[6..9], &[1.0, 0.0, 2.0]);
    }

    #[test]
    fn multiclass_rejects_negative_and_fractional_labels() {
        assert!(parse_libsvm_multiclass(Cursor::new("-1 1:1\n"), None).is_err());
        assert!(parse_libsvm_multiclass(Cursor::new("1.5 1:1\n"), None).is_err());
    }

    #[test]
    fn multiclass_format_parse_roundtrip() {
        let x = vec![1.0f32, 0.0, 0.25, 0.0, 2.0, -3.0];
        let labels = vec![4u16, 0];
        let txt = format_libsvm_multiclass(&x, &labels, 3);
        let (bx, blabels, bdim) = parse_libsvm_multiclass(Cursor::new(txt), Some(3)).unwrap();
        assert_eq!(bx, x);
        assert_eq!(blabels, labels);
        assert_eq!(bdim, 3);
    }

    #[test]
    fn roundtrip_through_file() {
        let dir = std::env::temp_dir().join("dcsvm_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.svm");
        let ds = Dataset::new(
            vec![1.0, 0.0, 0.25, -2.0, 0.0, 3.0],
            vec![1, -1],
            3,
            "rt",
        );
        write_libsvm(&ds, &path).unwrap();
        let back = read_libsvm(&path, Some(3)).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.row(0), ds.row(0));
        assert_eq!(back.row(1), ds.row(1));
        assert_eq!(back.y, ds.y);
        std::fs::remove_file(&path).ok();
    }
}
