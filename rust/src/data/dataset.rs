//! Dense dataset container: row-major f32 features + ±1 labels.
//!
//! Kernel SVM at our scales is compute-bound on dense kernel blocks, so rows
//! are stored dense and padded-feature-aligned copies are produced on demand
//! by the runtime. Labels are `i8` in {-1, +1} (the paper's binary setting;
//! multiclass datasets are binarized by the generators exactly as the paper
//! does for mnist8m/cifar).

use crate::util::prng::Pcg64;

/// A dense binary-classification dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Row-major features, `n * dim`.
    pub x: Vec<f32>,
    /// Labels in {-1, +1}, length `n`.
    pub y: Vec<i8>,
    pub dim: usize,
    /// Human-readable provenance tag (e.g. "covtype-like(seed=1)").
    pub name: String,
}

impl Dataset {
    pub fn new(x: Vec<f32>, y: Vec<i8>, dim: usize, name: impl Into<String>) -> Self {
        assert_eq!(x.len(), y.len() * dim, "x/y shape mismatch");
        assert!(y.iter().all(|&l| l == 1 || l == -1), "labels must be ±1");
        Dataset { x, y, dim, name: name.into() }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Squared L2 norms of all rows (precomputed once per dataset; the RBF
    /// kernel path consumes these).
    pub fn sq_norms(&self) -> Vec<f32> {
        (0..self.len())
            .map(|i| self.row(i).iter().map(|&v| v * v).sum())
            .collect()
    }

    /// Select a subset of rows (used for cluster subproblems).
    pub fn subset(&self, idx: &[usize], name: impl Into<String>) -> Dataset {
        let mut x = Vec::with_capacity(idx.len() * self.dim);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        Dataset { x, y, dim: self.dim, name: name.into() }
    }

    /// A new dataset whose rows are `self`'s followed by `other`'s — the
    /// streaming-update append. Dimensions must match; `self`'s rows are a
    /// bit-identical prefix of the result (what
    /// `cache::KernelContext::extended` requires).
    pub fn appended(&self, other: &Dataset, name: impl Into<String>) -> Dataset {
        assert_eq!(self.dim, other.dim, "appended(): dimension mismatch");
        let mut x = self.x.clone();
        x.extend_from_slice(&other.x);
        let mut y = self.y.clone();
        y.extend_from_slice(&other.y);
        Dataset { x, y, dim: self.dim, name: name.into() }
    }

    /// Random train/test split with the given train fraction.
    pub fn split(&self, train_frac: f64, rng: &mut Pcg64) -> (Dataset, Dataset) {
        let n = self.len();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let ntr = ((n as f64) * train_frac).round() as usize;
        let tr = self.subset(&idx[..ntr], format!("{}-train", self.name));
        let te = self.subset(&idx[ntr..], format!("{}-test", self.name));
        (tr, te)
    }

    /// Linearly scale every feature to [0, 1] (the paper's preprocessing for
    /// non-image datasets). Constant features map to 0.
    pub fn scale_unit(&mut self) {
        let n = self.len();
        if n == 0 {
            return;
        }
        for j in 0..self.dim {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for i in 0..n {
                let v = self.x[i * self.dim + j];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let span = hi - lo;
            for i in 0..n {
                let v = &mut self.x[i * self.dim + j];
                *v = if span > 0.0 { (*v - lo) / span } else { 0.0 };
            }
        }
    }

    /// Fraction of positive labels.
    pub fn pos_frac(&self) -> f64 {
        self.y.iter().filter(|&&l| l == 1).count() as f64 / self.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new(
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0],
            vec![1, -1, 1],
            2,
            "tiny",
        )
    }

    #[test]
    fn rows_and_norms() {
        let d = tiny();
        assert_eq!(d.len(), 3);
        assert_eq!(d.row(1), &[2.0, 3.0]);
        let n = d.sq_norms();
        assert_eq!(n, vec![1.0, 13.0, 41.0]);
    }

    #[test]
    fn subset_preserves_rows() {
        let d = tiny();
        let s = d.subset(&[2, 0], "s");
        assert_eq!(s.row(0), d.row(2));
        assert_eq!(s.row(1), d.row(0));
        assert_eq!(s.y, vec![1, 1]);
    }

    #[test]
    fn split_partitions() {
        let d = tiny();
        let mut rng = Pcg64::new(1);
        let (tr, te) = d.split(2.0 / 3.0, &mut rng);
        assert_eq!(tr.len() + te.len(), 3);
        assert_eq!(tr.len(), 2);
    }

    #[test]
    fn scale_unit_bounds() {
        let mut d = tiny();
        d.scale_unit();
        for j in 0..d.dim {
            let col: Vec<f32> = (0..d.len()).map(|i| d.x[i * d.dim + j]).collect();
            assert!(col.iter().all(|&v| (0.0..=1.0).contains(&v)));
            assert!(col.iter().any(|&v| v == 0.0));
            assert!(col.iter().any(|&v| v == 1.0));
        }
    }

    #[test]
    #[should_panic(expected = "labels must be ±1")]
    fn rejects_bad_labels() {
        Dataset::new(vec![0.0], vec![2], 1, "bad");
    }

    #[test]
    fn appended_keeps_prefix_bit_identical() {
        let d = tiny();
        let extra = Dataset::new(vec![6.0, 7.0, 8.0, 9.0], vec![-1, 1], 2, "extra");
        let all = d.appended(&extra, "all");
        assert_eq!(all.len(), 5);
        assert_eq!(&all.x[..d.x.len()], &d.x[..]);
        assert_eq!(&all.y[..d.len()], &d.y[..]);
        assert_eq!(all.row(3), extra.row(0));
        assert_eq!(all.y[4], 1);
    }
}
