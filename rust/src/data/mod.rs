//! Data layer: dense dataset container (`dataset`), LIBSVM text IO
//! (`libsvm`), and seeded synthetic counterparts of the paper's seven
//! benchmark datasets (`synthetic`).

pub mod dataset;
pub mod libsvm;
pub mod synthetic;

pub use dataset::Dataset;
