//! Synthetic counterparts of the paper's seven benchmark datasets.
//!
//! The real datasets (Table 2 of the paper) are not available in this
//! offline environment, so each gets a seeded generator matched in feature
//! dimension (capped at the runtime's padded dim, 128), class balance, and
//! geometric character, at a reduced scale suited to a 1-core box. The
//! phenomena DC-SVM exploits — cluster structure in kernel space, SV
//! sparsity, warm-start convergence — depend on this geometry, not on the
//! specific datasets (see DESIGN.md "Substitutions").
//!
//! Every generator returns `(train, test)` and is deterministic per seed.

use crate::data::dataset::Dataset;
use crate::util::prng::Pcg64;

/// Geometric family of a class-conditional mixture mode.
#[derive(Clone, Copy, Debug)]
pub enum ModeShape {
    /// Isotropic Gaussian blob.
    Gauss,
    /// Spherical shell (annulus) — creates curved boundaries with many SVs.
    Ring { radius: f64 },
}

/// Specification for a two-class mixture generator.
#[derive(Clone, Debug)]
pub struct MixtureSpec {
    pub name: &'static str,
    pub dim: usize,
    pub modes_per_class: usize,
    /// Spread of mode centers inside [0, spread]^dim.
    pub center_spread: f64,
    /// Per-mode std deviation.
    pub sigma: f64,
    pub shape: ModeShape,
    /// Fraction of positive examples (0.5 = balanced).
    pub pos_frac: f64,
    /// Margin shift added to positive-class centers along all-ones/√d.
    pub class_shift: f64,
    /// Fraction of labels flipped at random (Bayes noise).
    pub label_noise: f64,
    /// Whether to scale features to [0,1] after generation (the paper scales
    /// all non-image datasets).
    pub scale_unit: bool,
}

/// Draw `n` points from the spec.
pub fn generate(spec: &MixtureSpec, n: usize, rng: &mut Pcg64) -> Dataset {
    let d = spec.dim;
    // Mode centers per class.
    let mut centers = vec![vec![0f64; d]; 2 * spec.modes_per_class];
    let shift = spec.class_shift / (d as f64).sqrt();
    for (m, c) in centers.iter_mut().enumerate() {
        let is_pos = m < spec.modes_per_class;
        for v in c.iter_mut() {
            *v = rng.range_f64(0.0, spec.center_spread)
                + if is_pos { shift } else { 0.0 };
        }
    }

    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    let mut dir = vec![0f64; d];
    for _ in 0..n {
        let is_pos = rng.next_f64() < spec.pos_frac;
        let mode = rng.below(spec.modes_per_class)
            + if is_pos { 0 } else { spec.modes_per_class };
        let c = &centers[mode];
        match spec.shape {
            ModeShape::Gauss => {
                for j in 0..d {
                    x.push((c[j] + spec.sigma * rng.next_gaussian()) as f32);
                }
            }
            ModeShape::Ring { radius } => {
                // Random direction on the sphere, offset by radius + noise.
                let mut norm = 0.0;
                for v in dir.iter_mut() {
                    *v = rng.next_gaussian();
                    norm += *v * *v;
                }
                let norm = norm.sqrt().max(1e-12);
                let r = radius + spec.sigma * rng.next_gaussian();
                for j in 0..d {
                    x.push((c[j] + r * dir[j] / norm) as f32);
                }
            }
        }
        let mut label: i8 = if is_pos { 1 } else { -1 };
        if rng.next_f64() < spec.label_noise {
            label = -label;
        }
        y.push(label);
    }

    let mut ds = Dataset::new(x, y, d, spec.name);
    if spec.scale_unit {
        ds.scale_unit();
    }
    ds
}

/// Generate a (train, test) pair from one stream.
pub fn generate_split(
    spec: &MixtureSpec,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> (Dataset, Dataset) {
    let mut rng = Pcg64::new(seed);
    let all = generate(spec, n_train + n_test, &mut rng);
    let mut idx: Vec<usize> = (0..all.len()).collect();
    rng.shuffle(&mut idx);
    let tr = all.subset(&idx[..n_train], format!("{}-train", spec.name));
    let te = all.subset(&idx[n_train..], format!("{}-test", spec.name));
    (tr, te)
}

// ---------------------------------------------------------------------------
// Paper-dataset counterparts (Table 2). Reduced n; dims match the paper
// except webspam(254→128), kddcup99(125→125), cifar(3072→128),
// mnist8m(784→98) which are capped/compressed to the runtime's padded dim.
// ---------------------------------------------------------------------------

/// ijcnn1-like: 22-dim, ~10% positives, moderate overlap.
pub fn ijcnn1_like() -> MixtureSpec {
    MixtureSpec {
        name: "ijcnn1-like",
        dim: 22,
        modes_per_class: 6,
        center_spread: 1.0,
        sigma: 0.18,
        shape: ModeShape::Gauss,
        pos_frac: 0.10,
        class_shift: 0.25,
        label_noise: 0.01,
        scale_unit: true,
    }
}

/// cifar-like (binary animals vs not): high-dim, low SNR, unscaled.
pub fn cifar_like() -> MixtureSpec {
    MixtureSpec {
        name: "cifar-like",
        dim: 128,
        modes_per_class: 8,
        center_spread: 60.0,  // raw-image scale (paper uses unscaled pixels)
        sigma: 22.0,
        shape: ModeShape::Gauss,
        pos_frac: 0.5,
        class_shift: 10.0,
        label_noise: 0.05,
        scale_unit: false,
    }
}

/// census-like: 64-dim mixed-ish features, mild imbalance.
pub fn census_like() -> MixtureSpec {
    MixtureSpec {
        name: "census-like",
        dim: 64,
        modes_per_class: 10,
        center_spread: 1.0,
        sigma: 0.15,
        shape: ModeShape::Gauss,
        pos_frac: 0.24,
        class_shift: 0.12,
        label_noise: 0.04,
        scale_unit: true,
    }
}

/// covtype-like: 54-dim, hard curved boundary => large SV fraction.
pub fn covtype_like() -> MixtureSpec {
    MixtureSpec {
        name: "covtype-like",
        dim: 54,
        modes_per_class: 12,
        center_spread: 1.0,
        sigma: 0.12,
        shape: ModeShape::Ring { radius: 0.22 },
        pos_frac: 0.49,
        class_shift: 0.05,
        label_noise: 0.02,
        scale_unit: true,
    }
}

/// webspam-like: 128-dim (paper 254), positive-skewed features.
pub fn webspam_like() -> MixtureSpec {
    MixtureSpec {
        name: "webspam-like",
        dim: 128,
        modes_per_class: 8,
        center_spread: 1.0,
        sigma: 0.10,
        shape: ModeShape::Gauss,
        pos_frac: 0.61,
        class_shift: 0.10,
        label_noise: 0.01,
        scale_unit: true,
    }
}

/// kddcup99-like: highly separable (tiny SV fraction) + rare noise.
pub fn kddcup99_like() -> MixtureSpec {
    MixtureSpec {
        name: "kddcup99-like",
        dim: 125,
        modes_per_class: 5,
        center_spread: 1.0,
        sigma: 0.06,
        shape: ModeShape::Gauss,
        pos_frac: 0.80,
        class_shift: 0.60,
        label_noise: 0.002,
        scale_unit: true,
    }
}

/// mnist8m-like (binary round vs non-round digits): 98-dim (paper 784
/// compressed), 10 digit modes relabelled, unscaled.
pub fn mnist8m_like() -> MixtureSpec {
    MixtureSpec {
        name: "mnist8m-like",
        dim: 98,
        modes_per_class: 5, // 5 round + 5 non-round digit modes
        center_spread: 120.0,
        sigma: 28.0,
        shape: ModeShape::Gauss,
        pos_frac: 0.5,
        class_shift: 30.0,
        label_noise: 0.005,
        scale_unit: false,
    }
}

/// Default reduced (n_train, n_test) per dataset — chosen so the full bench
/// suite completes on a 1-core box while keeping the paper's *relative*
/// dataset sizes (covtype/kddcup/mnist largest).
pub fn default_sizes(name: &str) -> (usize, usize) {
    match name {
        "ijcnn1-like" => (4000, 2000),
        "cifar-like" => (3000, 1000),
        "census-like" => (5000, 1500),
        "covtype-like" => (8000, 2000),
        "webspam-like" => (6000, 1500),
        "kddcup99-like" => (10000, 2000),
        "mnist8m-like" => (12000, 2000),
        _ => (4000, 1000),
    }
}

/// All seven specs, in the paper's Table 2 order.
pub fn all_specs() -> Vec<MixtureSpec> {
    vec![
        ijcnn1_like(),
        cifar_like(),
        census_like(),
        covtype_like(),
        webspam_like(),
        kddcup99_like(),
        mnist8m_like(),
    ]
}

/// Convenience: build a named dataset at default reduced size.
pub fn by_name(name: &str, seed: u64) -> Option<(Dataset, Dataset)> {
    let spec = all_specs().into_iter().find(|s| s.name == name)?;
    let (ntr, nte) = default_sizes(name);
    Some(generate_split(&spec, ntr, nte, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let spec = covtype_like();
        let (a, _) = generate_split(&spec, 200, 50, 7);
        let (b, _) = generate_split(&spec, 200, 50, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let (c, _) = generate_split(&spec, 200, 50, 8);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn sizes_and_dims() {
        for spec in all_specs() {
            let (tr, te) = generate_split(&spec, 300, 100, 1);
            assert_eq!(tr.len(), 300);
            assert_eq!(te.len(), 100);
            assert_eq!(tr.dim, spec.dim);
            assert!(tr.dim <= 128, "{} dim > padded dim", spec.name);
        }
    }

    #[test]
    fn class_balance_approx() {
        let spec = ijcnn1_like();
        let mut rng = Pcg64::new(3);
        let ds = generate(&spec, 4000, &mut rng);
        let pf = ds.pos_frac();
        assert!((pf - 0.10).abs() < 0.03, "pos_frac={pf}");
    }

    #[test]
    fn scaled_datasets_are_unit_range() {
        let spec = census_like();
        let mut rng = Pcg64::new(4);
        let ds = generate(&spec, 500, &mut rng);
        assert!(ds.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn separable_spec_is_separable_enough() {
        // kddcup-like should be nearly linearly separable: a trivial
        // nearest-centroid rule should get >95%.
        let spec = kddcup99_like();
        let (tr, te) = generate_split(&spec, 1000, 500, 5);
        let dim = tr.dim;
        let mut cpos = vec![0f64; dim];
        let mut cneg = vec![0f64; dim];
        let (mut np_, mut nn) = (0.0f64, 0.0f64);
        for i in 0..tr.len() {
            let tgt = if tr.y[i] == 1 { (&mut cpos, &mut np_) } else { (&mut cneg, &mut nn) };
            for j in 0..dim {
                tgt.0[j] += tr.row(i)[j] as f64;
            }
            *tgt.1 += 1.0;
        }
        for j in 0..dim {
            cpos[j] /= np_.max(1.0);
            cneg[j] /= nn.max(1.0);
        }
        let mut correct = 0;
        for i in 0..te.len() {
            let (mut dp, mut dn) = (0.0, 0.0);
            for j in 0..dim {
                let v = te.row(i)[j] as f64;
                dp += (v - cpos[j]).powi(2);
                dn += (v - cneg[j]).powi(2);
            }
            let pred: i8 = if dp < dn { 1 } else { -1 };
            if pred == te.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / te.len() as f64;
        assert!(acc > 0.95, "nearest-centroid acc={acc}");
    }

    #[test]
    fn ring_shape_produces_annulus() {
        let spec = MixtureSpec {
            modes_per_class: 1,
            sigma: 0.01,
            center_spread: 0.0,
            class_shift: 0.0,
            scale_unit: false,
            ..covtype_like()
        };
        let mut rng = Pcg64::new(6);
        let ds = generate(&spec, 300, &mut rng);
        // All points should be ~radius away from the (single, zero) center.
        for i in 0..ds.len() {
            let r: f32 = ds.row(i).iter().map(|&v| v * v).sum::<f32>().sqrt();
            assert!((r - 0.22).abs() < 0.06, "r={r}");
        }
    }
}
