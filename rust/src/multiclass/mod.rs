//! Multiclass support: one-vs-one DC-SVM (the LIBSVM convention).
//!
//! The paper binarizes mnist8m/cifar for its experiments, but the released
//! DC-SVM code — like LIBSVM — handles multiclass by training k(k−1)/2
//! pairwise binary machines and predicting by vote. Each pairwise machine
//! is a full DC-SVM (so the divide-and-conquer speedup applies per pair),
//! and ties break toward the smaller class id (LIBSVM's rule).

use crate::data::Dataset;
use crate::dcsvm::{self, DcSvmConfig};
use crate::kernel::BlockKernel;
use crate::predict::SvmModel;

/// A multiclass dataset: dense rows + integer class labels.
#[derive(Clone, Debug)]
pub struct MulticlassDataset {
    pub x: Vec<f32>,
    pub labels: Vec<u16>,
    pub dim: usize,
    pub num_classes: usize,
}

impl MulticlassDataset {
    pub fn new(x: Vec<f32>, labels: Vec<u16>, dim: usize) -> Self {
        assert_eq!(x.len(), labels.len() * dim);
        let num_classes = labels.iter().map(|&l| l as usize + 1).max().unwrap_or(0);
        MulticlassDataset { x, labels, dim, num_classes }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Binary restriction to classes (a, b): labels a → +1, b → −1.
    fn pair_view(&self, a: u16, b: u16) -> (Dataset, Vec<usize>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        let mut idx = Vec::new();
        for i in 0..self.len() {
            if self.labels[i] == a || self.labels[i] == b {
                x.extend_from_slice(self.row(i));
                y.push(if self.labels[i] == a { 1 } else { -1 });
                idx.push(i);
            }
        }
        (Dataset::new(x, y, self.dim, format!("pair-{a}-{b}")), idx)
    }
}

/// One-vs-one ensemble of binary DC-SVM models.
pub struct OvoModel {
    /// (class_a, class_b, model): model decides a (+1) vs b (−1).
    pub machines: Vec<(u16, u16, SvmModel)>,
    pub num_classes: usize,
}

impl OvoModel {
    /// Predict a batch of rows by pairwise vote.
    pub fn predict_batch(
        &self,
        x: &[f32],
        norms: &[f32],
        kernel: &dyn BlockKernel,
    ) -> Vec<u16> {
        let n = norms.len();
        let mut votes = vec![0u32; n * self.num_classes];
        for (a, b, model) in &self.machines {
            let dv = model.decision_batch(x, norms, kernel);
            for (i, &d) in dv.iter().enumerate() {
                let winner = if d >= 0.0 { *a } else { *b };
                votes[i * self.num_classes + winner as usize] += 1;
            }
        }
        (0..n)
            .map(|i| {
                let row = &votes[i * self.num_classes..(i + 1) * self.num_classes];
                // max vote, ties toward the smaller class id
                let mut best = 0u16;
                for (c, &v) in row.iter().enumerate() {
                    if v > row[best as usize] {
                        best = c as u16;
                    }
                }
                best
            })
            .collect()
    }

    pub fn accuracy(&self, test: &MulticlassDataset, kernel: &dyn BlockKernel) -> f64 {
        let norms: Vec<f32> = (0..test.len())
            .map(|i| test.row(i).iter().map(|&v| v * v).sum())
            .collect();
        let preds = self.predict_batch(&test.x, &norms, kernel);
        let correct = preds
            .iter()
            .zip(&test.labels)
            .filter(|(p, y)| p == y)
            .count();
        correct as f64 / test.len().max(1) as f64
    }
}

/// Train one-vs-one DC-SVM.
pub fn train_ovo(
    ds: &MulticlassDataset,
    kernel: &dyn BlockKernel,
    cfg: &DcSvmConfig,
) -> OvoModel {
    let mut machines = Vec::new();
    for a in 0..ds.num_classes as u16 {
        for b in (a + 1)..ds.num_classes as u16 {
            let (pair, _) = ds.pair_view(a, b);
            if pair.is_empty() || pair.pos_frac() == 0.0 || pair.pos_frac() == 1.0 {
                continue;
            }
            // Scale the divide schedule to the pair size: tiny pairs don't
            // need multilevel treatment.
            let mut pcfg = cfg.clone();
            while pcfg.levels > 1
                && pair.len() / pcfg.k_base.pow(pcfg.levels as u32) < 32
            {
                pcfg.levels -= 1;
            }
            let res = dcsvm::train(&pair, kernel, &pcfg);
            machines.push((a, b, SvmModel::from_alpha(&pair, &res.alpha, cfg.kind)));
        }
    }
    OvoModel { machines, num_classes: ds.num_classes }
}

/// Synthetic multiclass mixture (digit-modes style) for tests/benches.
pub fn synthetic_multiclass(
    classes: usize,
    n: usize,
    dim: usize,
    seed: u64,
) -> MulticlassDataset {
    use crate::util::prng::Pcg64;
    let mut rng = Pcg64::new(seed);
    let centers: Vec<f64> = (0..classes * dim).map(|_| rng.range_f64(0.0, 4.0)).collect();
    let mut x = Vec::with_capacity(n * dim);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(classes);
        for j in 0..dim {
            x.push((centers[c * dim + j] + 0.35 * rng.next_gaussian()) as f32);
        }
        labels.push(c as u16);
    }
    MulticlassDataset::new(x, labels, dim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{native::NativeKernel, KernelKind};

    #[test]
    fn ovo_learns_four_classes() {
        let tr = synthetic_multiclass(4, 600, 6, 1);
        let te = synthetic_multiclass(4, 200, 6, 1); // same centers (same seed)
        let kind = KernelKind::Rbf { gamma: 2.0 };
        let kern = NativeKernel::new(kind);
        let cfg = DcSvmConfig {
            kind,
            c: 4.0,
            levels: 1,
            sample_m: 48,
            ..Default::default()
        };
        let model = train_ovo(&tr, &kern, &cfg);
        assert_eq!(model.machines.len(), 6); // 4·3/2
        let acc = model.accuracy(&te, &kern);
        assert!(acc > 0.9, "ovo acc {acc}");
    }

    #[test]
    fn pair_view_extracts_classes() {
        let ds = synthetic_multiclass(3, 90, 2, 2);
        let (pair, idx) = ds.pair_view(0, 2);
        assert_eq!(pair.len(), idx.len());
        for (t, &i) in idx.iter().enumerate() {
            let want: i8 = if ds.labels[i] == 0 { 1 } else { -1 };
            assert_eq!(pair.y[t], want);
            assert!(ds.labels[i] == 0 || ds.labels[i] == 2);
        }
    }

    #[test]
    fn binary_case_single_machine() {
        let ds = synthetic_multiclass(2, 200, 4, 3);
        let kind = KernelKind::Rbf { gamma: 2.0 };
        let kern = NativeKernel::new(kind);
        let cfg = DcSvmConfig { kind, c: 1.0, levels: 1, sample_m: 32, ..Default::default() };
        let model = train_ovo(&ds, &kern, &cfg);
        assert_eq!(model.machines.len(), 1);
    }
}
