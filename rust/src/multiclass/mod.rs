//! Multiclass support: one-vs-one DC-SVM (the LIBSVM convention) over ONE
//! shared [`KernelContext`].
//!
//! The paper binarizes mnist8m/cifar for its experiments, but the released
//! DC-SVM code — like LIBSVM — handles multiclass by training k(k−1)/2
//! pairwise binary machines and predicting by vote (ties break toward the
//! smaller class id, LIBSVM's rule). Each pairwise machine is a full
//! DC-SVM ([`crate::dcsvm::train_restricted`]), so the divide-and-conquer
//! speedup applies per pair — and, because every pair trains through a
//! member view of the *same* context with segment-row stitching on
//! ([`KernelContext::with_segment_stitching`]), the kernel columns pair
//! (a,b) computed for class a's rows are copied — not recomputed — when
//! pairs (a,c), (a,d), … ask for them. The pairwise SV sets overlap
//! heavily (the DCSVM multi-class paper's observation), so each marginal
//! pair gets strictly cheaper (counter-asserted in
//! `tests/multiclass_e2e.rs`).
//!
//! Pairs fan out over the worker pool under the same budget-split rule as
//! the divide phase: N concurrent pair solves each get `threads/N` dispatch
//! workers, so `--threads N` never nests.
//!
//! The trained ensemble is ONE [`OvoModel`]: per-class SV blocks (the
//! ascending-global-index union of each class's SVs across all pairs) plus
//! per-machine coefficient vectors indexed into those blocks. A query's
//! kernel row against class a's block is computed once and folded by every
//! machine that votes with class a — offline ([`OvoModel::predict_batch`])
//! and in serving, which reuses the same [`OvoModel::machine_decisions`]
//! fold so decisions are bit-identical between the two paths.

use std::collections::BTreeSet;
use std::time::Instant;

use crate::cache::{KernelContext, ValueStats};
use crate::data::Dataset;
use crate::dcsvm::{self, DcSvmConfig};
use crate::kernel::{BlockKernel, KernelKind};
use crate::util::threadpool::scope_map;

/// A multiclass dataset: dense rows + integer class labels.
#[derive(Clone, Debug)]
pub struct MulticlassDataset {
    pub x: Vec<f32>,
    pub labels: Vec<u16>,
    pub dim: usize,
    /// `max(label) + 1` — class ids need not be contiguous; absent ids
    /// simply never train a machine (see [`Self::present_classes`]).
    pub num_classes: usize,
}

impl MulticlassDataset {
    pub fn new(x: Vec<f32>, labels: Vec<u16>, dim: usize) -> Self {
        assert_eq!(x.len(), labels.len() * dim);
        let num_classes = labels.iter().map(|&l| l as usize + 1).max().unwrap_or(0);
        MulticlassDataset { x, labels, dim, num_classes }
    }

    /// Load a multi-label LIBSVM file (labels mapped to dense-row u16
    /// class ids as written — no remapping, so non-contiguous ids stay
    /// non-contiguous).
    pub fn from_libsvm(
        path: &std::path::Path,
        dim_hint: Option<usize>,
    ) -> anyhow::Result<Self> {
        let (x, labels, dim) = crate::data::libsvm::read_libsvm_multiclass(path, dim_hint)?;
        Ok(MulticlassDataset::new(x, labels, dim))
    }

    /// View a binary ±1 dataset as a 2-class problem (−1 ↦ class 0,
    /// +1 ↦ class 1) — how the harness runs `--algo ovo` on its binary
    /// synthetic datasets for apples-to-apples algo comparisons.
    pub fn from_binary(ds: &Dataset) -> Self {
        let labels = ds.y.iter().map(|&y| if y > 0 { 1u16 } else { 0 }).collect();
        MulticlassDataset::new(ds.x.clone(), labels, ds.dim)
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Class ids that actually occur, ascending. Pairs are formed over
    /// these only: a dataset with labels {0, 5} trains one machine, and a
    /// single-class dataset trains none (prediction returns the lone
    /// class).
    pub fn present_classes(&self) -> Vec<u16> {
        let set: BTreeSet<u16> = self.labels.iter().copied().collect();
        set.into_iter().collect()
    }
}

/// Global member indices (ascending) and ±1 labels (+1 = class `a`) of the
/// pair (a, b) restriction — the index set a pair's machine trains on.
/// This is bookkeeping only: no feature row is copied here (the pre-PR-8
/// `pair_view` materialized a full per-pair `Dataset`; the shared-context
/// trainer restricts via [`KernelContext::view`] instead).
pub fn pair_members(ds: &MulticlassDataset, a: u16, b: u16) -> (Vec<usize>, Vec<i8>) {
    let mut members = Vec::new();
    let mut labels = Vec::new();
    for i in 0..ds.len() {
        if ds.labels[i] == a || ds.labels[i] == b {
            members.push(i);
            labels.push(if ds.labels[i] == a { 1 } else { -1 });
        }
    }
    (members, labels)
}

/// One pairwise machine of an [`OvoModel`]: `a` (+1) vs `b` (−1), with
/// coefficients indexed into the model's per-class SV blocks (an SV of
/// this machine that sits at position j of class a's block contributes
/// `coef_a[j]`; block positions this machine has no SV at carry 0).
#[derive(Clone, Debug)]
pub struct OvoMachine {
    pub a: u16,
    pub b: u16,
    pub coef_a: Vec<f32>,
    pub coef_b: Vec<f32>,
}

/// One-vs-one ensemble over per-class SV blocks.
#[derive(Clone, Debug)]
pub struct OvoModel {
    pub num_classes: usize,
    pub dim: usize,
    pub kind: KernelKind,
    /// Per-class SV rows, row-major (ascending global training index —
    /// the union over all machines touching the class). Classes with no
    /// SVs (absent ids) hold empty blocks.
    pub class_sv_x: Vec<Vec<f32>>,
    pub class_sv_norms: Vec<Vec<f32>>,
    pub machines: Vec<OvoMachine>,
    /// Class ids present at training time, ascending (the vote domain).
    pub present: Vec<u16>,
}

/// LIBSVM's OVO vote rule: most votes wins, ties break toward the
/// *smaller* class id. `present` is the ascending candidate list; an empty
/// list returns 0, a single class returns that class unconditionally.
pub fn vote_argmax(votes: &[u32], present: &[u16]) -> u16 {
    let mut best: Option<u16> = None;
    for &c in present {
        let v = votes[c as usize];
        match best {
            // Strict `>`: on a tie the earlier (smaller) id sticks.
            Some(bc) if v > votes[bc as usize] => best = Some(c),
            None => best = Some(c),
            _ => {}
        }
    }
    best.unwrap_or(0)
}

impl OvoModel {
    /// Total SVs across the class blocks.
    pub fn num_svs(&self) -> usize {
        self.class_sv_norms.iter().map(|n| n.len()).sum()
    }

    /// Decision value of every machine for ONE query, given the query's
    /// kernel row against each class block (`class_rows[c].len()` =
    /// class c's SV count). This is THE fold — offline prediction and the
    /// serving layer both funnel through it, so a machine's decision is
    /// bit-identical wherever the class rows came from (one contiguous
    /// block pass here, stitched SV-block cache entries in serving):
    /// accumulation runs class-a block ascending then class-b block
    /// ascending, in f64.
    pub fn machine_decisions(&self, class_rows: &[&[f32]]) -> Vec<f32> {
        self.machines
            .iter()
            .map(|m| {
                let mut acc = 0f64;
                let ra = class_rows[m.a as usize];
                for (j, &c) in m.coef_a.iter().enumerate() {
                    acc += c as f64 * ra[j] as f64;
                }
                let rb = class_rows[m.b as usize];
                for (j, &c) in m.coef_b.iter().enumerate() {
                    acc += c as f64 * rb[j] as f64;
                }
                acc as f32
            })
            .collect()
    }

    /// Vote over one query's machine decisions: the winning label plus the
    /// vote margin (winner votes − best other class's votes; the serving
    /// layer reports the margin as the query's `decision`).
    pub fn vote(&self, decisions: &[f32]) -> (u16, f32) {
        let mut votes = vec![0u32; self.num_classes.max(1)];
        for (m, &d) in self.machines.iter().zip(decisions) {
            let w = if d >= 0.0 { m.a } else { m.b };
            votes[w as usize] += 1;
        }
        let label = vote_argmax(&votes, &self.present);
        let best = votes.get(label as usize).copied().unwrap_or(0);
        let runner = self
            .present
            .iter()
            .filter(|&&c| c != label)
            .map(|&c| votes[c as usize])
            .max()
            .unwrap_or(0);
        (label, best as f32 - runner as f32)
    }

    /// Per-class kernel blocks K(batch, class SVs): one backend dispatch
    /// per non-empty class — the rows every machine's vote folds over.
    fn class_kernel_blocks(
        &self,
        x: &[f32],
        norms: &[f32],
        kernel: &dyn BlockKernel,
    ) -> Vec<Vec<f32>> {
        debug_assert_eq!(kernel.kind(), self.kind);
        let n = norms.len();
        (0..self.num_classes)
            .map(|c| {
                let svs = self.class_sv_norms[c].len();
                let mut block = vec![0f32; n * svs];
                if svs > 0 {
                    kernel.block(
                        x,
                        norms,
                        &self.class_sv_x[c],
                        &self.class_sv_norms[c],
                        self.dim,
                        &mut block,
                    );
                }
                block
            })
            .collect()
    }

    /// Labels + vote margins for a row-major batch.
    pub fn predict_with_margins(
        &self,
        x: &[f32],
        norms: &[f32],
        kernel: &dyn BlockKernel,
    ) -> Vec<(u16, f32)> {
        let n = norms.len();
        let blocks = self.class_kernel_blocks(x, norms, kernel);
        (0..n)
            .map(|i| {
                let rows: Vec<&[f32]> = (0..self.num_classes)
                    .map(|c| {
                        let svs = self.class_sv_norms[c].len();
                        &blocks[c][i * svs..(i + 1) * svs]
                    })
                    .collect();
                let dv = self.machine_decisions(&rows);
                self.vote(&dv)
            })
            .collect()
    }

    /// Predict a batch of rows by pairwise vote.
    pub fn predict_batch(&self, x: &[f32], norms: &[f32], kernel: &dyn BlockKernel) -> Vec<u16> {
        self.predict_with_margins(x, norms, kernel)
            .into_iter()
            .map(|(label, _)| label)
            .collect()
    }

    pub fn accuracy(&self, test: &MulticlassDataset, kernel: &dyn BlockKernel) -> f64 {
        let norms: Vec<f32> = (0..test.len())
            .map(|i| test.row(i).iter().map(|&v| v * v).sum())
            .collect();
        let preds = self.predict_batch(&test.x, &norms, kernel);
        let correct = preds.iter().zip(&test.labels).filter(|(p, y)| p == y).count();
        correct as f64 / test.len().max(1) as f64
    }

    /// Serialize for model persistence (`train --algo ovo --save-model`).
    /// The `"machines"` key distinguishes OVO ensembles from plain
    /// [`crate::predict::SvmModel`] / early-model files when loading.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let (kname, gamma, eta) = match self.kind {
            KernelKind::Rbf { gamma } => ("rbf", gamma as f64, 0.0),
            KernelKind::Poly { gamma, eta } => ("poly", gamma as f64, eta as f64),
            KernelKind::Linear => ("linear", 0.0, 0.0),
        };
        Json::obj(vec![
            ("type", Json::from("ovo")),
            ("kernel", Json::from(kname)),
            ("gamma", Json::from(gamma)),
            ("eta", Json::from(eta)),
            ("dim", Json::from(self.dim)),
            ("num_classes", Json::from(self.num_classes)),
            (
                "present",
                Json::arr_f64(&self.present.iter().map(|&c| c as f64).collect::<Vec<_>>()),
            ),
            (
                "class_sv_x",
                Json::Arr(
                    self.class_sv_x
                        .iter()
                        .map(|xs| {
                            Json::arr_f64(&xs.iter().map(|&v| v as f64).collect::<Vec<_>>())
                        })
                        .collect(),
                ),
            ),
            (
                "machines",
                Json::Arr(
                    self.machines
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("a", Json::from(m.a as usize)),
                                ("b", Json::from(m.b as usize)),
                                (
                                    "coef_a",
                                    Json::arr_f64(
                                        &m.coef_a.iter().map(|&c| c as f64).collect::<Vec<_>>(),
                                    ),
                                ),
                                (
                                    "coef_b",
                                    Json::arr_f64(
                                        &m.coef_b.iter().map(|&c| c as f64).collect::<Vec<_>>(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Deserialize a model saved by [`OvoModel::to_json`]. SV norms are
    /// recomputed exactly as training computed them, so a round-tripped
    /// model votes identically.
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<OvoModel> {
        use anyhow::{anyhow, bail};
        let dim = j.get("dim").as_usize().ok_or_else(|| anyhow!("ovo model: missing dim"))?;
        if dim == 0 {
            bail!("ovo model: dim must be positive");
        }
        let num_classes = j
            .get("num_classes")
            .as_usize()
            .ok_or_else(|| anyhow!("ovo model: missing num_classes"))?;
        let gamma = j.get("gamma").as_f64().unwrap_or(0.0) as f32;
        let eta = j.get("eta").as_f64().unwrap_or(0.0) as f32;
        let kind = match j.get("kernel").as_str() {
            Some("rbf") => KernelKind::Rbf { gamma },
            Some("poly") => KernelKind::Poly { gamma, eta },
            Some("linear") => KernelKind::Linear,
            other => bail!("ovo model: bad kernel {other:?}"),
        };
        let present: Vec<u16> = j
            .get("present")
            .as_arr()
            .ok_or_else(|| anyhow!("ovo model: missing present"))?
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0) as u16)
            .collect();
        if present.iter().any(|&c| c as usize >= num_classes) {
            bail!("ovo model: present class out of range");
        }
        if present.windows(2).any(|w| w[0] >= w[1]) {
            bail!("ovo model: present classes must be ascending and distinct");
        }
        let class_sv_x: Vec<Vec<f32>> = j
            .get("class_sv_x")
            .as_arr()
            .ok_or_else(|| anyhow!("ovo model: missing class_sv_x"))?
            .iter()
            .map(|block| {
                block
                    .as_arr()
                    .ok_or_else(|| anyhow!("ovo model: class_sv_x block not an array"))
                    .map(|a| a.iter().map(|v| v.as_f64().unwrap_or(0.0) as f32).collect())
            })
            .collect::<anyhow::Result<_>>()?;
        if class_sv_x.len() != num_classes {
            bail!("ovo model: class_sv_x/num_classes inconsistent");
        }
        if class_sv_x.iter().any(|xs: &Vec<f32>| xs.len() % dim != 0) {
            bail!("ovo model: class block not a multiple of dim");
        }
        let class_sv_norms: Vec<Vec<f32>> = class_sv_x
            .iter()
            .map(|xs| xs.chunks(dim).map(|r| r.iter().map(|&v| v * v).sum()).collect())
            .collect();
        let machines: Vec<OvoMachine> = j
            .get("machines")
            .as_arr()
            .ok_or_else(|| anyhow!("ovo model: missing machines"))?
            .iter()
            .map(|mj| -> anyhow::Result<OvoMachine> {
                let a = mj.get("a").as_usize().ok_or_else(|| anyhow!("machine: missing a"))?;
                let b = mj.get("b").as_usize().ok_or_else(|| anyhow!("machine: missing b"))?;
                if a >= b || b >= num_classes {
                    bail!("machine: bad class pair ({a}, {b})");
                }
                let coefs = |key: &str| -> anyhow::Result<Vec<f32>> {
                    Ok(mj
                        .get(key)
                        .as_arr()
                        .ok_or_else(|| anyhow!("machine: missing {key}"))?
                        .iter()
                        .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                        .collect())
                };
                let coef_a = coefs("coef_a")?;
                let coef_b = coefs("coef_b")?;
                if coef_a.len() != class_sv_norms[a].len()
                    || coef_b.len() != class_sv_norms[b].len()
                {
                    bail!("machine ({a}, {b}): coef length != class block SV count");
                }
                Ok(OvoMachine { a: a as u16, b: b as u16, coef_a, coef_b })
            })
            .collect::<anyhow::Result<_>>()?;
        Ok(OvoModel {
            num_classes,
            dim,
            kind,
            class_sv_x,
            class_sv_norms,
            machines,
            present,
        })
    }
}

/// A solved pairwise subproblem: the inputs [`build_ovo_model`] assembles
/// machines from. Public so tests can build a reference ensemble from
/// independently solved (e.g. materialized per-pair) α and compare votes
/// through the exact same machine-construction and fold code.
pub struct TrainedPair {
    pub a: u16,
    pub b: u16,
    /// Global row indices, ascending.
    pub members: Vec<usize>,
    /// ±1 per member (+1 = class `a`).
    pub labels: Vec<i8>,
    /// Solved α, one per member (local order).
    pub alpha: Vec<f64>,
}

/// Assemble the ensemble: per-class SV blocks (ascending-global union
/// across pairs) + per-machine coefficients at block positions.
pub fn build_ovo_model(
    ds: &MulticlassDataset,
    kind: KernelKind,
    pairs: &[TrainedPair],
    present: &[u16],
) -> OvoModel {
    let nc = ds.num_classes;
    let mut sv_sets: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); nc];
    for p in pairs {
        for (t, &g) in p.members.iter().enumerate() {
            if p.alpha[t] > 0.0 {
                sv_sets[ds.labels[g] as usize].insert(g);
            }
        }
    }
    let class_svs: Vec<Vec<usize>> =
        sv_sets.into_iter().map(|s| s.into_iter().collect()).collect();
    let class_pos: Vec<std::collections::HashMap<usize, usize>> = class_svs
        .iter()
        .map(|svs| svs.iter().enumerate().map(|(t, &g)| (g, t)).collect())
        .collect();
    let mut class_sv_x = Vec::with_capacity(nc);
    let mut class_sv_norms = Vec::with_capacity(nc);
    for svs in &class_svs {
        let mut xs = Vec::with_capacity(svs.len() * ds.dim);
        let mut norms = Vec::with_capacity(svs.len());
        for &g in svs {
            let row = ds.row(g);
            xs.extend_from_slice(row);
            norms.push(row.iter().map(|&v| v * v).sum());
        }
        class_sv_x.push(xs);
        class_sv_norms.push(norms);
    }
    let machines: Vec<OvoMachine> = pairs
        .iter()
        .map(|p| {
            let (a, b) = (p.a as usize, p.b as usize);
            let mut coef_a = vec![0f32; class_svs[a].len()];
            let mut coef_b = vec![0f32; class_svs[b].len()];
            for (t, &g) in p.members.iter().enumerate() {
                if p.alpha[t] > 0.0 {
                    let c = ds.labels[g] as usize;
                    let coef = (p.alpha[t] * p.labels[t] as f64) as f32;
                    if c == a {
                        coef_a[class_pos[a][&g]] = coef;
                    } else {
                        coef_b[class_pos[b][&g]] = coef;
                    }
                }
            }
            OvoMachine { a: p.a, b: p.b, coef_a, coef_b }
        })
        .collect();
    OvoModel {
        num_classes: nc,
        dim: ds.dim,
        kind,
        class_sv_x,
        class_sv_norms,
        machines,
        present: present.to_vec(),
    }
}

/// Shared-context OVO training outcome.
pub struct OvoTrainResult {
    pub model: OvoModel,
    /// Pairwise machines trained (= k(k−1)/2 over present classes).
    pub pair_dispatches: u64,
    /// Kernel entries computed per pair `(a, b, values_computed)`, in
    /// training order — the cross-pair-reuse evidence: with segment
    /// stitching, later pairs copy the columns earlier pairs computed.
    pub pair_values: Vec<(u16, u16, u64)>,
    /// Whether `pair_values` deltas are exact: pairs solved concurrently
    /// interleave on the shared counters, so per-pair attribution is only
    /// exact at one concurrent pair (`threads == 1`). Totals are always
    /// exact.
    pub pair_values_exact: bool,
    /// Whole-run counters of the shared context.
    pub value_stats: ValueStats,
    pub train_s: f64,
}

/// Train one-vs-one DC-SVM over ONE shared [`KernelContext`].
///
/// The context is built over the rows with placeholder labels (every
/// pair's ±1 labeling rides in through
/// [`crate::cache::KernelView::with_labels`]) and segment-row stitching
/// on, so a pair's segment rows are assembled from whatever overlapping
/// columns earlier pairs left in the cache. Pairs fan out over the worker
/// pool; concurrent pair solves split the dispatch budget
/// (`threads / concurrent` each) exactly like the divide phase's cluster
/// fan-out, so `--threads N` never nests.
pub fn train_ovo_shared(
    ds: &MulticlassDataset,
    kernel: &dyn BlockKernel,
    cfg: &DcSvmConfig,
) -> OvoTrainResult {
    assert_eq!(kernel.kind(), cfg.kind, "kernel backend kind mismatch");
    let t0 = Instant::now();
    let n = ds.len();
    let present = ds.present_classes();
    // One context for every pair: rows + norms + cache are shared; labels
    // are per-view overrides, so the dataset's own labels are placeholders.
    let shared = Dataset::new(ds.x.clone(), vec![1i8; n], ds.dim, "ovo-shared");
    let ctx = KernelContext::new(&shared, kernel, cfg.cache_bytes)
        .with_threads(cfg.threads)
        .with_registry_cap(cfg.registry_cap_bytes)
        .with_quant_route(cfg.quant_route)
        .with_segment_stitching(true);

    let mut jobs: Vec<(u16, u16, Vec<usize>, Vec<i8>, DcSvmConfig)> = Vec::new();
    for (ai, &a) in present.iter().enumerate() {
        for &b in &present[ai + 1..] {
            let (members, labels) = pair_members(ds, a, b);
            // Scale the divide schedule to the pair size: tiny pairs don't
            // need multilevel treatment.
            let mut pcfg = cfg.clone();
            while pcfg.levels > 1
                && members.len() / pcfg.k_base.pow(pcfg.levels as u32) < 32
            {
                pcfg.levels -= 1;
            }
            jobs.push((a, b, members, labels, pcfg));
        }
    }

    // Budget split (the PR 5 rule): N concurrent pair solves each get
    // threads/N dispatch workers — the pair fan-out is the parallel axis,
    // so a pair's own cluster solves run serially within its budget.
    let concurrent = cfg.threads.min(jobs.len()).max(1);
    let per_pair = (cfg.threads / concurrent).max(1);
    ctx.set_threads(per_pair);
    let pair_values_exact = concurrent == 1;
    let ctx_ref = &ctx;
    let results: Vec<(TrainedPair, u64)> =
        scope_map(cfg.threads, jobs, |_, (a, b, members, labels, mut pcfg)| {
            pcfg.threads = per_pair;
            let v0 = ctx_ref.value_stats();
            let res = dcsvm::train_restricted(ctx_ref, &members, &labels, &pcfg);
            let dv = ctx_ref.value_stats().since(&v0).values_computed;
            (TrainedPair { a, b, members, labels, alpha: res.alpha }, dv)
        });
    ctx.set_threads(cfg.threads);

    let mut pairs = Vec::with_capacity(results.len());
    let mut pair_values = Vec::with_capacity(results.len());
    for (p, dv) in results {
        pair_values.push((p.a, p.b, dv));
        pairs.push(p);
    }
    let model = build_ovo_model(ds, cfg.kind, &pairs, &present);
    OvoTrainResult {
        model,
        pair_dispatches: pairs.len() as u64,
        pair_values,
        pair_values_exact,
        value_stats: ctx.value_stats(),
        train_s: t0.elapsed().as_secs_f64(),
    }
}

/// Train one-vs-one DC-SVM (ensemble only; [`train_ovo_shared`] exposes
/// the counters).
pub fn train_ovo(ds: &MulticlassDataset, kernel: &dyn BlockKernel, cfg: &DcSvmConfig) -> OvoModel {
    train_ovo_shared(ds, kernel, cfg).model
}

/// Synthetic multiclass mixture (digit-modes style) for tests/benches.
pub fn synthetic_multiclass(
    classes: usize,
    n: usize,
    dim: usize,
    seed: u64,
) -> MulticlassDataset {
    use crate::util::prng::Pcg64;
    let mut rng = Pcg64::new(seed);
    let centers: Vec<f64> = (0..classes * dim).map(|_| rng.range_f64(0.0, 4.0)).collect();
    let mut x = Vec::with_capacity(n * dim);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(classes);
        for j in 0..dim {
            x.push((centers[c * dim + j] + 0.35 * rng.next_gaussian()) as f32);
        }
        labels.push(c as u16);
    }
    MulticlassDataset::new(x, labels, dim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{native::NativeKernel, KernelKind};

    #[test]
    fn ovo_learns_four_classes() {
        let tr = synthetic_multiclass(4, 600, 6, 1);
        let te = synthetic_multiclass(4, 200, 6, 1); // same centers (same seed)
        let kind = KernelKind::Rbf { gamma: 2.0 };
        let kern = NativeKernel::new(kind);
        let cfg = DcSvmConfig {
            kind,
            c: 4.0,
            levels: 1,
            sample_m: 48,
            ..Default::default()
        };
        let res = train_ovo_shared(&tr, &kern, &cfg);
        assert_eq!(res.model.machines.len(), 6); // 4·3/2
        assert_eq!(res.pair_dispatches, 6);
        assert_eq!(res.model.present, vec![0, 1, 2, 3]);
        let acc = res.model.accuracy(&te, &kern);
        assert!(acc > 0.9, "ovo acc {acc}");
    }

    #[test]
    fn pair_members_extracts_classes() {
        let ds = synthetic_multiclass(3, 90, 2, 2);
        let (members, labels) = pair_members(&ds, 0, 2);
        assert_eq!(members.len(), labels.len());
        assert!(members.windows(2).all(|w| w[0] < w[1]), "members not ascending");
        for (t, &i) in members.iter().enumerate() {
            let want: i8 = if ds.labels[i] == 0 { 1 } else { -1 };
            assert_eq!(labels[t], want);
            assert!(ds.labels[i] == 0 || ds.labels[i] == 2);
        }
    }

    #[test]
    fn binary_case_single_machine() {
        let ds = synthetic_multiclass(2, 200, 4, 3);
        let kind = KernelKind::Rbf { gamma: 2.0 };
        let kern = NativeKernel::new(kind);
        let cfg = DcSvmConfig { kind, c: 1.0, levels: 1, sample_m: 32, ..Default::default() };
        let model = train_ovo(&ds, &kern, &cfg);
        assert_eq!(model.machines.len(), 1);
    }

    #[test]
    fn vote_argmax_breaks_ties_to_smaller_class() {
        // 2 vs 2 tie between classes 1 and 3 → 1 wins (smaller id).
        assert_eq!(vote_argmax(&[0, 2, 1, 2], &[0, 1, 2, 3]), 1);
        // Clear winner.
        assert_eq!(vote_argmax(&[0, 1, 3, 2], &[0, 1, 2, 3]), 2);
        // Single class: unconditional.
        assert_eq!(vote_argmax(&[0, 0, 0], &[2]), 2);
        // Empty domain.
        assert_eq!(vote_argmax(&[], &[]), 0);
        // Non-contiguous present ids: absent classes never win.
        assert_eq!(vote_argmax(&[5, 0, 0, 0, 0, 5], &[0, 5]), 0);
    }

    #[test]
    fn ovo_json_roundtrip_votes_identically() {
        let tr = synthetic_multiclass(3, 240, 4, 5);
        let te = synthetic_multiclass(3, 80, 4, 5);
        let kind = KernelKind::Rbf { gamma: 2.0 };
        let kern = NativeKernel::new(kind);
        let cfg = DcSvmConfig { kind, c: 4.0, levels: 1, sample_m: 32, ..Default::default() };
        let model = train_ovo(&tr, &kern, &cfg);
        let text = model.to_json().to_string();
        let back = OvoModel::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.num_classes, model.num_classes);
        assert_eq!(back.present, model.present);
        assert_eq!(back.num_svs(), model.num_svs());
        let norms: Vec<f32> = (0..te.len())
            .map(|i| te.row(i).iter().map(|&v| v * v).sum())
            .collect();
        assert_eq!(
            back.predict_with_margins(&te.x, &norms, &kern),
            model.predict_with_margins(&te.x, &norms, &kern)
        );
    }

    #[test]
    fn ovo_from_json_rejects_inconsistent_shapes() {
        let tr = synthetic_multiclass(3, 120, 3, 6);
        let kind = KernelKind::Rbf { gamma: 2.0 };
        let kern = NativeKernel::new(kind);
        let cfg = DcSvmConfig { kind, c: 1.0, levels: 1, sample_m: 24, ..Default::default() };
        let model = train_ovo(&tr, &kern, &cfg);
        let good = model.to_json().to_string();
        let broken = good.replace("\"machines\"", "\"nope\"");
        assert!(OvoModel::from_json(&crate::util::json::Json::parse(&broken).unwrap()).is_err());
    }
}
