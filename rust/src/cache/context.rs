//! The unified kernel-access layer: one [`KernelContext`] per dataset,
//! **segment-granular** since cache v2.
//!
//! A context owns everything every consumer of kernel values needs and used
//! to recompute privately: the dataset reference, its precomputed squared
//! row norms (previously recomputed via `sq_norms()` at 15+ call sites), the
//! [`BlockKernel`] backend, and the shared [`ShardedRowCache`].
//!
//! **Segment keying.** Cache keys are `(segment, row)` composites
//! (`seg_key`): a *segment* is a registered set of global column indices
//! — the full span `0..n` (segment 0, always present) or a cluster's member
//! set registered by [`KernelContext::view`] during the divide phase. The
//! entry under `(s, i)` is the partial kernel row `K(x_i, cols(s))`, so a
//! cluster subproblem at k clusters computes and caches rows of length
//! ~n/k instead of n — the divide-phase compute and cache bytes shrink by
//! roughly the cluster factor (the structure block-minimization methods
//! exploit; see PAPERS.md).
//!
//! **Stitching.** Cross-phase reuse survives the narrower keys: a full-row
//! request ([`KernelContext::row`]) that misses consults every registered
//! segment's entry for that row, copies the covered columns (bit-identical
//! — each kernel entry is a pure elementwise function of `(x_i, x_j)`, so
//! a value computed inside a segment dispatch equals the one a full-row
//! dispatch would produce), and computes only the uncovered columns in one
//! gathered dispatch. The conquer solve therefore starts from the divide
//! and refine phases' partial rows exactly as it used to start from their
//! full rows (`tests/dcsvm_e2e.rs`).
//!
//! [`KernelView`] is a cheap subset view (local → global index map) for
//! cluster subproblems. A segmented view's rows are **segment-length and
//! local-indexed** (`cols[t] == members[t]`), which also removes the
//! local→global indirection from the solver's gradient loop.
//! [`KernelContext::view_unsegmented`] keeps the v1 behavior (full
//! dataset-length rows under the full-span key) as the ablation baseline —
//! `dcsvm_e2e` proves the segmented divide computes ≥2× fewer kernel
//! values at k ≥ 4 with bit-identical final α.
//!
//! **Grouped stitching.** Warm prefetches ([`KernelContext::compute_rows`])
//! group the stitchable rows by *segment-coverage pattern*: rows whose
//! resident partial entries come from the same segment set share one
//! uncovered-column list, so one gathered dispatch fills the whole group
//! instead of one dispatch per row ([`ValueStats::stitch_groups`] vs
//! [`ValueStats::stitched_rows`] quantifies the collapse).
//!
//! Batched dispatch lives here too: the PJRT backend pays a fixed per-call
//! cost, so the solver's row prefetch, kernel-kmeans assignment and batch
//! prediction all funnel multi-row requests into single backend calls — and
//! large native dispatches fan out over row panels
//! ([`crate::kernel::BlockKernel::block_par`]) across the context's
//! [`KernelContext::threads`] budget, bit-identically to the
//! single-threaded sweep. [`ValueStats`] counts every kernel entry the
//! context computes, copies via stitching, or is told about
//! ([`KernelContext::count_external_values`] — kmeans/predict block
//! passes), feeding the `segment_rows` / `divide_values` /
//! `parallel_dispatches` / `stitch_groups` fields of the harness `Outcome`
//! and `BENCH_ci.json`.
//!
//! **Registry GC.** Partial segments keep a gathered copy of their column
//! features for contiguous dispatch; [`KernelContext::with_registry_cap`]
//! bounds those bytes — once a level is solved and the next level's
//! registrations push past the cap, the oldest segments' gathered copies
//! are dropped (column lists are always retained, so stitching is
//! unaffected) and transparently re-gathered if ever needed again.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::data::Dataset;
use crate::kernel::quant::QuantizedRows;
use crate::kernel::{BlockKernel, KernelKind};
use crate::util::threadpool::default_threads;

use super::sharded::{CacheStats, ShardedRowCache};

/// Default row-cache budget when a caller does not care (tests, one-shot
/// convenience solves): 256 MB, the LIBSVM-style default.
pub const DEFAULT_CACHE_BYTES: usize = 256 << 20;

/// Default shard count: enough to keep `scope_map` cluster workers from
/// serializing on fills without oversharding tiny budgets.
const DEFAULT_SHARDS: usize = 16;

/// The full-span segment id of a freshly built context (columns `0..n`).
/// [`KernelContext::extended`] retires the full span into a prefix
/// segment and registers a new one, so consumers must go through
/// `KernelContext::full_key` / `full_id` instead of this constant.
const FULL_SEGMENT: u32 = 0;

/// Compose the cache key of segment `seg`, row `row`. Row indices occupy
/// the low 40 bits (datasets are far below 2⁴⁰ rows), so `key % shards`
/// still spreads adjacent rows across shards.
#[inline]
fn seg_key(seg: u32, row: usize) -> u64 {
    debug_assert!(row < (1usize << 40));
    ((seg as u64) << 40) | row as u64
}

/// Gathered column features (`[len, dim]`) + norms of a partial segment:
/// the contiguous operand of segment-row dispatches. Handed out as an
/// `Arc` so the registry GC can drop its copy while in-flight dispatches
/// finish on theirs.
struct GatheredCols {
    xs: Vec<f32>,
    norms: Vec<f32>,
    /// Int8-quantized shadow of `xs` (per-row scale+zero-point), built only
    /// when the context runs with `--quant-route`. Exact dispatches never
    /// read it — it serves approximation-tolerant consumers (routing /
    /// early prediction) that want the 4×-smaller operand.
    quant: Option<QuantizedRows>,
}

impl GatheredCols {
    fn bytes(&self) -> usize {
        (self.xs.len() + self.norms.len()) * 4
            + self.quant.as_ref().map(|q| q.bytes()).unwrap_or(0)
    }
}

/// A registered column set: the unit of kernel-cache granularity.
pub struct SegmentData {
    id: u32,
    /// Global column indices (distinct, aligned with the owning view's
    /// local order); `None` = the full span `0..n`. Always retained — the
    /// stitching paths only need the column lists.
    cols: Option<Vec<usize>>,
    /// Gathered column features + norms (`None` for the full span — the
    /// dataset matrix is used directly — or after the registry GC dropped
    /// them; re-gathered on demand).
    gathered: Mutex<Option<Arc<GatheredCols>>>,
    /// Column count (cached; `ds.len()` for the full span).
    len: usize,
    /// Registry generation this segment was last (re)gathered in — see
    /// [`KernelContext::begin_registry_generation`]. Segments stamped with
    /// the current generation belong to the live level's working set and
    /// are exempt from the byte-cap GC, so a level whose own registrations
    /// exceed the cap cannot thrash re-gathers against itself.
    gen: AtomicU64,
}

impl SegmentData {
    pub fn id(&self) -> u32 {
        self.id
    }

    /// Column count of this segment.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether this is the full-span segment.
    pub fn is_full(&self) -> bool {
        self.cols.is_none()
    }

    /// Whether the gathered feature copy is currently resident (tests /
    /// diagnostics; the full span never gathers).
    pub fn has_gathered(&self) -> bool {
        self.gathered.lock().unwrap().is_some()
    }

    /// Whether the resident gathered copy carries an int8-quantized shadow
    /// (quant-route contexts only; tests / diagnostics).
    pub fn has_quant(&self) -> bool {
        self.gathered
            .lock()
            .unwrap()
            .as_ref()
            .map(|g| g.quant.is_some())
            .unwrap_or(false)
    }

    /// Drop the gathered feature copy (registry GC); returns the bytes
    /// released (0 if already dropped or full-span). Column lists stay.
    fn release_gathered(&self) -> usize {
        self.gathered.lock().unwrap().take().map(|g| g.bytes()).unwrap_or(0)
    }
}

/// Shared handle to a registered segment.
pub type SegmentRef = Arc<SegmentData>;

/// One stitchable row in a coverage group: its global index plus its
/// pinned `(index into partials, entry)` pairs.
type StitchRow = (usize, Vec<(usize, Arc<[f32]>)>);

/// Kernel-value accounting of one context: entries computed by backend
/// dispatches, entries reused by full-row stitching, and partial/full rows
/// materialized. Snapshot-and-`since` like [`CacheStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ValueStats {
    /// Kernel entries evaluated by backend dispatches through this context
    /// (plus externally counted block passes — kmeans routing, batch
    /// prediction).
    pub values_computed: u64,
    /// Entries copied out of cached segment rows while stitching full rows.
    pub values_stitched: u64,
    /// Partial (non-full-span) segment rows computed.
    pub segment_rows: u64,
    /// Full-span rows materialized (computed or stitched).
    pub full_rows: u64,
    /// Full rows assembled by stitching (≥1 covered column copied).
    pub stitched_rows: u64,
    /// Gathered stitch-fill dispatches: the per-row path pays one per
    /// stitched row, the grouped prefetch path one per coverage group —
    /// `stitch_groups < stitched_rows` is the batching win.
    pub stitch_groups: u64,
    /// Backend dispatches that fanned out over row panels (> 1 worker).
    pub parallel_dispatches: u64,
    /// Kernel entries evaluated against int8-quantized operands on the
    /// approximation-tolerant routing/early-prediction paths (a subset of
    /// [`Self::values_computed`], which stays the honest whole-run total).
    pub quantized_values: u64,
}

impl ValueStats {
    /// Counter deltas since an earlier snapshot.
    pub fn since(&self, earlier: &ValueStats) -> ValueStats {
        ValueStats {
            values_computed: self.values_computed.saturating_sub(earlier.values_computed),
            values_stitched: self.values_stitched.saturating_sub(earlier.values_stitched),
            segment_rows: self.segment_rows.saturating_sub(earlier.segment_rows),
            full_rows: self.full_rows.saturating_sub(earlier.full_rows),
            stitched_rows: self.stitched_rows.saturating_sub(earlier.stitched_rows),
            stitch_groups: self.stitch_groups.saturating_sub(earlier.stitch_groups),
            parallel_dispatches: self
                .parallel_dispatches
                .saturating_sub(earlier.parallel_dispatches),
            quantized_values: self.quantized_values.saturating_sub(earlier.quantized_values),
        }
    }
}

#[derive(Default)]
struct ValueCounters {
    values_computed: AtomicU64,
    values_stitched: AtomicU64,
    segment_rows: AtomicU64,
    full_rows: AtomicU64,
    stitched_rows: AtomicU64,
    stitch_groups: AtomicU64,
    parallel_dispatches: AtomicU64,
    quantized_values: AtomicU64,
}

/// Kernel-access context for one dataset: rows, norms, backend, shared
/// segment-granular row cache, segment registry, value counters.
pub struct KernelContext<'a> {
    ds: &'a Dataset,
    kernel: &'a dyn BlockKernel,
    norms: Vec<f32>,
    cache: ShardedRowCache,
    /// Registered segments; index = id. `[full_id]` is the live full span
    /// (`[0]` on a fresh context; [`Self::extended`] retires it and
    /// registers a new one).
    segments: Mutex<Vec<SegmentRef>>,
    /// Id of the live full-span segment.
    full_id: u32,
    counters: ValueCounters,
    /// Worker budget for row-panel-parallel backend dispatches
    /// ([`crate::kernel::BlockKernel::block_par`]); 1 = always serial.
    /// Atomic so phases that already run concurrent solvers can shrink the
    /// per-dispatch share for their duration ([`Self::set_threads`]).
    threads: AtomicUsize,
    /// Byte cap on gathered segment features (0 = unlimited).
    registry_cap: usize,
    /// Gathered segment-feature bytes currently resident / their peak.
    registry_bytes: AtomicUsize,
    registry_peak: AtomicUsize,
    /// Segments whose gathered features were dropped and rebuilt on demand.
    regathers: AtomicU64,
    /// Current registry generation (0 = generations never marked; the GC
    /// then falls back to plain oldest-first). Bumped once per divide
    /// level by [`Self::begin_registry_generation`].
    registry_gen: AtomicU64,
    /// Build int8-quantized shadows alongside gathered segment features
    /// for the approximation-tolerant routing paths (`--quant-route`).
    quant_route: bool,
    /// Opt-in **segment-row stitching** (see
    /// [`Self::with_segment_stitching`]): partial-segment row fills copy
    /// columns already resident in the full row or another partial
    /// segment's entry, dispatching only the uncovered rest.
    segment_stitching: bool,
}

impl<'a> KernelContext<'a> {
    /// Build a context with the default shard count. Computes `sq_norms`
    /// once — consumers read them via [`Self::norms`] / [`Self::norm`].
    pub fn new(ds: &'a Dataset, kernel: &'a dyn BlockKernel, cache_bytes: usize) -> Self {
        Self::with_shards(ds, kernel, cache_bytes, DEFAULT_SHARDS)
    }

    pub fn with_shards(
        ds: &'a Dataset,
        kernel: &'a dyn BlockKernel,
        cache_bytes: usize,
        shards: usize,
    ) -> Self {
        let norms = ds.sq_norms();
        let cache = ShardedRowCache::new(cache_bytes, shards);
        let full: SegmentRef = Arc::new(SegmentData {
            id: FULL_SEGMENT,
            cols: None,
            gathered: Mutex::new(None),
            len: ds.len(),
            gen: AtomicU64::new(0),
        });
        KernelContext {
            ds,
            kernel,
            norms,
            cache,
            segments: Mutex::new(vec![full]),
            full_id: FULL_SEGMENT,
            counters: ValueCounters::default(),
            threads: AtomicUsize::new(default_threads()),
            registry_cap: 0,
            registry_bytes: AtomicUsize::new(0),
            registry_peak: AtomicUsize::new(0),
            regathers: AtomicU64::new(0),
            registry_gen: AtomicU64::new(0),
            quant_route: false,
            segment_stitching: false,
        }
    }

    /// Set the worker budget for row-panel-parallel dispatches (defaults
    /// to [`default_threads`]; 1 keeps every dispatch single-threaded).
    /// Dispatch results are bit-identical for every value.
    pub fn with_threads(self, threads: usize) -> Self {
        self.set_threads(threads);
        self
    }

    /// Retarget the dispatch worker budget mid-run: a phase that runs N
    /// solvers concurrently shrinks the per-dispatch share to
    /// `budget / N` for its duration so nesting cannot put `threads²`
    /// workers on the machine (dispatch results are bit-identical for
    /// every value — only wall-clock moves).
    pub fn set_threads(&self, threads: usize) {
        self.threads.store(threads.max(1), Ordering::Relaxed);
    }

    /// The context's parallel-dispatch worker budget.
    pub fn threads(&self) -> usize {
        self.threads.load(Ordering::Relaxed)
    }

    /// Cap the bytes held by gathered segment features (0 = unlimited,
    /// the default). When a registration pushes past the cap, the oldest
    /// partial segments' gathered copies are dropped — their column lists
    /// stay, so stitching is unaffected and a later dispatch re-gathers
    /// transparently (counted by [`Self::segment_regathers`]).
    pub fn with_registry_cap(mut self, bytes: usize) -> Self {
        self.registry_cap = bytes;
        self
    }

    /// Gathered segment-feature bytes currently resident.
    pub fn registry_bytes(&self) -> usize {
        self.registry_bytes.load(Ordering::Relaxed)
    }

    /// Peak of [`Self::registry_bytes`] over the context's lifetime (the
    /// `registry_bytes` counter of the harness `Outcome`).
    pub fn registry_peak_bytes(&self) -> usize {
        self.registry_peak.load(Ordering::Relaxed)
    }

    /// How many times a GC-dropped segment had to re-gather its features.
    pub fn segment_regathers(&self) -> u64 {
        self.regathers.load(Ordering::Relaxed)
    }

    /// Build int8-quantized shadows alongside gathered segment features —
    /// the storage behind the `--quant-route` approximation-tolerant
    /// routing/early-prediction paths. Exact dispatches never read them.
    pub fn with_quant_route(mut self, on: bool) -> Self {
        self.quant_route = on;
        self
    }

    /// Whether quantized routing operands are enabled for this context.
    pub fn quant_route(&self) -> bool {
        self.quant_route
    }

    /// Opt into **segment-row stitching**: a partial-segment row request
    /// that misses first copies every column already resident in the cached
    /// full-span row — or another partial segment's entry for the same row,
    /// consulted in registration order (first-writer-wins, the full-row
    /// stitcher's precedence) — and dispatches only the uncovered columns.
    /// Off by default: the classic path computes the whole segment row in
    /// one contiguous dispatch, and every pre-existing consumer keeps its
    /// exact dispatch shapes and counters. Stitched values are bitwise
    /// copies of pure kernel entries, so row *values* are identical either
    /// way — only the `values_computed` / `values_stitched` split moves.
    /// The OVO multiclass driver turns this on: pairs (a,b) and (a,c)
    /// register overlapping member segments, so the second pair's rows are
    /// mostly assembled from the first pair's cached columns.
    pub fn with_segment_stitching(mut self, on: bool) -> Self {
        self.segment_stitching = on;
        self
    }

    /// Whether segment-row stitching is enabled for this context.
    pub fn segment_stitching(&self) -> bool {
        self.segment_stitching
    }

    /// Open a new registry generation: segments registered (or re-gathered)
    /// from now on are the *live level's working set* and exempt from the
    /// byte-cap GC, which only evicts segments of earlier generations. This
    /// floors `--registry-cap-mb` at the live level's working set, so a
    /// deep run whose current level alone exceeds the cap degrades to
    /// "over cap until the next level" instead of thrashing re-gathers
    /// within the level. The driver calls this once per divide level (and
    /// once before refine registrations). Never calling it (generation
    /// stays 0) keeps the legacy oldest-first behavior.
    pub fn begin_registry_generation(&self) {
        self.registry_gen.fetch_add(1, Ordering::Relaxed);
    }

    pub fn ds(&self) -> &'a Dataset {
        self.ds
    }

    pub fn kernel(&self) -> &'a dyn BlockKernel {
        self.kernel
    }

    pub fn kind(&self) -> KernelKind {
        self.kernel.kind()
    }

    pub fn len(&self) -> usize {
        self.ds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ds.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.ds.dim
    }

    /// Precomputed squared L2 norms of all rows.
    pub fn norms(&self) -> &[f32] {
        &self.norms
    }

    #[inline]
    pub fn norm(&self, i: usize) -> f32 {
        self.norms[i]
    }

    #[inline]
    pub fn label(&self, i: usize) -> i8 {
        self.ds.y[i]
    }

    /// The shared segment cache (tests / diagnostics).
    pub fn cache(&self) -> &ShardedRowCache {
        &self.cache
    }

    /// Cache key of the live full-span row of `i`.
    #[inline]
    fn full_key(&self, i: usize) -> u64 {
        seg_key(self.full_id, i)
    }

    /// Whether the **full-span** row of `i` is resident.
    pub fn is_row_cached(&self, i: usize) -> bool {
        self.cache.contains(self.full_key(i))
    }

    /// The always-present full-span segment.
    pub fn full_segment(&self) -> SegmentRef {
        Arc::clone(&self.segments.lock().unwrap()[self.full_id as usize])
    }

    /// Register (or find) the segment with exactly these columns. `cols`
    /// must be distinct in-range indices; order defines the segment row's
    /// layout (`row[t] = K(x_i, x_{cols[t]})`). The identity column set
    /// resolves to the full-span segment.
    pub fn register_segment(&self, cols: &[usize]) -> SegmentRef {
        debug_assert!(cols.iter().all(|&c| c < self.ds.len()));
        let identity =
            cols.len() == self.ds.len() && cols.iter().enumerate().all(|(t, &c)| t == c);
        let seg = {
            let mut reg = self.segments.lock().unwrap();
            if identity {
                return Arc::clone(&reg[self.full_id as usize]);
            }
            if let Some(existing) = reg.iter().find(|s| s.cols.as_deref() == Some(cols)) {
                return Arc::clone(existing);
            }
            let gathered = self.gather_cols(cols);
            self.add_registry_bytes(gathered.bytes());
            let seg: SegmentRef = Arc::new(SegmentData {
                id: reg.len() as u32,
                cols: Some(cols.to_vec()),
                gathered: Mutex::new(Some(Arc::new(gathered))),
                len: cols.len(),
                gen: AtomicU64::new(self.registry_gen.load(Ordering::Relaxed)),
            });
            reg.push(Arc::clone(&seg));
            seg
        };
        self.enforce_registry_cap(seg.id);
        seg
    }

    /// Gather the features + norms of `cols` into contiguous buffers.
    fn gather_cols(&self, cols: &[usize]) -> GatheredCols {
        let dim = self.ds.dim;
        let mut xs = Vec::with_capacity(cols.len() * dim);
        let mut norms = Vec::with_capacity(cols.len());
        for &c in cols {
            xs.extend_from_slice(self.ds.row(c));
            norms.push(self.norms[c]);
        }
        let quant = if self.quant_route {
            Some(QuantizedRows::from_rows(&xs, dim))
        } else {
            None
        };
        GatheredCols { xs, norms, quant }
    }

    fn add_registry_bytes(&self, bytes: usize) {
        let now = self.registry_bytes.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.registry_peak.fetch_max(now, Ordering::Relaxed);
    }

    /// The gathered columns of a partial segment, rebuilding them if the
    /// registry GC dropped the copy. The returned handle stays valid even
    /// if a concurrent GC drops the registry's copy mid-dispatch.
    fn gathered(&self, seg: &SegmentData) -> Arc<GatheredCols> {
        let cols = seg.cols.as_ref().expect("partial segment has columns");
        let g = {
            let mut slot = seg.gathered.lock().unwrap();
            if let Some(g) = slot.as_ref() {
                return Arc::clone(g);
            }
            let g = Arc::new(self.gather_cols(cols));
            self.add_registry_bytes(g.bytes());
            self.regathers.fetch_add(1, Ordering::Relaxed);
            // A re-gathered segment is live again: pull it into the current
            // generation so the GC stops treating it as evictable history.
            seg.gen.store(self.registry_gen.load(Ordering::Relaxed), Ordering::Relaxed);
            *slot = Some(Arc::clone(&g));
            g
        };
        self.enforce_registry_cap(seg.id);
        g
    }

    /// Drop gathered feature copies, oldest segment first, until the
    /// registry fits its cap. Oldest-first is the solved-level order: the
    /// divide phase registers one generation of segments per level, so by
    /// the time a new level's registrations overflow the cap, the oldest
    /// generations are already solved. `keep` (the segment that triggered
    /// enforcement) is never dropped — and neither is any segment of the
    /// **current** registry generation (the live level's working set; see
    /// [`Self::begin_registry_generation`]), so the cap is effectively
    /// floored at the live level and cannot thrash re-gathers within it.
    /// When generations were never marked (`registry_gen == 0`) every
    /// partial segment is a candidate, preserving the legacy behavior.
    fn enforce_registry_cap(&self, keep: u32) {
        if self.registry_cap == 0
            || self.registry_bytes.load(Ordering::Relaxed) <= self.registry_cap
        {
            return;
        }
        let cur_gen = self.registry_gen.load(Ordering::Relaxed);
        let candidates: Vec<SegmentRef> = {
            let reg = self.segments.lock().unwrap();
            reg.iter()
                .filter(|s| {
                    !s.is_full()
                        && s.id != keep
                        && (cur_gen == 0 || s.gen.load(Ordering::Relaxed) < cur_gen)
                })
                .cloned()
                .collect()
        };
        for seg in candidates {
            if self.registry_bytes.load(Ordering::Relaxed) <= self.registry_cap {
                break;
            }
            let freed = seg.release_gathered();
            if freed > 0 {
                self.registry_bytes.fetch_sub(freed, Ordering::Relaxed);
            }
        }
    }

    /// Backend block dispatch through the context's thread budget: large
    /// blocks fan out over row panels (bit-identically), and the fan-out
    /// is counted in [`ValueStats::parallel_dispatches`]. Shapes are the
    /// caller's — kmeans assignment and prediction passes use this with
    /// their own operand matrices.
    pub fn block_dispatch(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        out: &mut [f32],
    ) {
        let used = self.kernel.block_par(xq, q_norms, xd, d_norms, dim, self.threads(), out);
        if used > 1 {
            self.counters.parallel_dispatches.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Fused decision dispatch through the context's thread budget (the
    /// batch-prediction analogue of [`Self::block_dispatch`]).
    #[allow(clippy::too_many_arguments)] // flat block ABI; see BlockKernel
    pub fn decision_dispatch(
        &self,
        xq: &[f32],
        q_norms: &[f32],
        xd: &[f32],
        d_norms: &[f32],
        dim: usize,
        coef: &[f32],
        out: &mut [f32],
    ) {
        let used =
            self.kernel.decision_par(xq, q_norms, xd, d_norms, dim, coef, self.threads(), out);
        if used > 1 {
            self.counters.parallel_dispatches.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Registered segments including the full span (diagnostics/tests).
    pub fn segment_count(&self) -> usize {
        self.segments.lock().unwrap().len()
    }

    /// Whether segment `seg`'s row of `i` is resident.
    pub fn is_segment_row_cached(&self, seg: &SegmentRef, i: usize) -> bool {
        self.cache.contains(seg_key(seg.id, i))
    }

    /// Segment row `K(x_i, cols(seg))` through the shared cache (one
    /// backend dispatch on miss). For the full-span segment this is
    /// [`Self::row`] — including its stitching path.
    pub fn segment_row(&self, seg: &SegmentRef, i: usize) -> Arc<[f32]> {
        if seg.is_full() {
            return self.row(i);
        }
        if self.segment_stitching {
            return self.segment_row_stitched(seg, i);
        }
        let g = self.gathered(seg);
        self.cache.get_or_compute(seg_key(seg.id, i), seg.len, |out| {
            self.kernel.block(
                self.ds.row(i),
                &self.norms[i..i + 1],
                &g.xs,
                &g.norms,
                self.ds.dim,
                out,
            );
            self.counters
                .values_computed
                .fetch_add(seg.len as u64, Ordering::Relaxed);
            self.counters.segment_rows.fetch_add(1, Ordering::Relaxed);
        })
    }

    /// Cover the columns of partial segment `seg`'s row `i` from entries
    /// already resident in the cache: the full-span row covers everything
    /// at once; otherwise the other partial segments' entries are consulted
    /// in registration order (first-writer-wins — the full-row stitcher's
    /// precedence; overlapping segments hold identical values anyway, since
    /// kernel entries are pure in `(x_i, x_j)`). Fills `buf[t]` and sets
    /// `covered[t]` for each covered target position; returns the count.
    fn cover_segment_from_cache(
        &self,
        seg: &SegmentData,
        i: usize,
        buf: &mut [f32],
        covered: &mut [bool],
    ) -> usize {
        let cols = seg.cols.as_ref().expect("partial segment has columns");
        if let Some(full) = self.cache.get_quiet(self.full_key(i)) {
            for (t, &c) in cols.iter().enumerate() {
                buf[t] = full[c];
                covered[t] = true;
            }
            return cols.len();
        }
        let others: Vec<SegmentRef> = {
            let reg = self.segments.lock().unwrap();
            reg.iter().filter(|s| !s.is_full() && s.id != seg.id).cloned().collect()
        };
        if others.is_empty() {
            return 0;
        }
        let pos: std::collections::HashMap<usize, usize> =
            cols.iter().enumerate().map(|(t, &c)| (c, t)).collect();
        let mut covered_n = 0usize;
        for other in &others {
            if covered_n == cols.len() {
                break;
            }
            let Some(entry) = self.cache.get_quiet(seg_key(other.id, i)) else {
                continue;
            };
            let ocols = other.cols.as_ref().expect("partial segment has columns");
            for (u, &c) in ocols.iter().enumerate() {
                if let Some(&t) = pos.get(&c) {
                    if !covered[t] {
                        buf[t] = entry[u];
                        covered[t] = true;
                        covered_n += 1;
                    }
                }
            }
        }
        covered_n
    }

    /// One gathered dispatch filling the `targets` (local segment
    /// positions) of segment `seg` for every global row of `rows`. Returns
    /// the row-major `[rows.len(), targets.len()]` fills and counts the
    /// computed entries. Operands come straight out of the segment's
    /// gathered feature copy, so no dataset columns are re-gathered.
    fn fill_segment_cols(&self, seg: &SegmentRef, rows: &[usize], targets: &[usize]) -> Vec<f32> {
        let dim = self.ds.dim;
        let g = self.gathered(seg);
        let m = targets.len();
        let mut xs = Vec::with_capacity(m * dim);
        let mut tnorms = Vec::with_capacity(m);
        for &t in targets {
            xs.extend_from_slice(&g.xs[t * dim..(t + 1) * dim]);
            tnorms.push(g.norms[t]);
        }
        let mut xq = Vec::with_capacity(rows.len() * dim);
        let mut qn = Vec::with_capacity(rows.len());
        for &p in rows {
            xq.extend_from_slice(self.ds.row(p));
            qn.push(self.norms[p]);
        }
        let mut out = vec![0f32; rows.len() * m];
        self.block_dispatch(&xq, &qn, &xs, &tnorms, dim, &mut out);
        self.counters
            .values_computed
            .fetch_add((rows.len() * m) as u64, Ordering::Relaxed);
        out
    }

    /// [`Self::segment_row`] with segment-row stitching on: copy the
    /// covered columns out of resident entries, dispatch only the rest.
    /// Assembled outside any shard lock (stitch probes touch other shards
    /// — never nest shard locks), so concurrent fetches of the same row may
    /// duplicate work: values are pure per `(x_i, x_j)`, so only counters
    /// can differ — exactly the [`Self::row`] contract.
    fn segment_row_stitched(&self, seg: &SegmentRef, i: usize) -> Arc<[f32]> {
        let key = seg_key(seg.id, i);
        if let Some(row) = self.cache.get(key) {
            return row;
        }
        let mut buf = vec![0f32; seg.len];
        let mut covered = vec![false; seg.len];
        let covered_n = self.cover_segment_from_cache(seg, i, &mut buf, &mut covered);
        if covered_n < seg.len {
            let missing: Vec<usize> = (0..seg.len).filter(|&t| !covered[t]).collect();
            let fills = self.fill_segment_cols(seg, &[i], &missing);
            for (u, &t) in missing.iter().enumerate() {
                buf[t] = fills[u];
            }
            if covered_n > 0 {
                // A partial cover pays one gathered stitch-fill dispatch,
                // like the per-row full-span path.
                self.counters.stitch_groups.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.counters.values_stitched.fetch_add(covered_n as u64, Ordering::Relaxed);
        self.counters.segment_rows.fetch_add(1, Ordering::Relaxed);
        let row: Arc<[f32]> = buf.into();
        self.cache.put(key, Arc::clone(&row));
        row
    }

    /// Full kernel row K(x_i, ·) against the whole dataset, through the
    /// shared cache. On a miss the row is **stitched**: cached segment
    /// entries of row i cover their columns by copy (bit-identical), and
    /// only the uncovered columns enter the backend dispatch.
    pub fn row(&self, i: usize) -> Arc<[f32]> {
        let key = self.full_key(i);
        if let Some(row) = self.cache.get(key) {
            return row;
        }
        // Miss already recorded by the probe; assemble outside any shard
        // lock (stitch probes touch other shards — never nest shard locks).
        let n = self.ds.len();
        let dim = self.ds.dim;
        let mut buf = vec![0f32; n];
        let mut covered = vec![false; n];
        let mut covered_n = 0usize;
        let partials: Vec<SegmentRef> = {
            let reg = self.segments.lock().unwrap();
            reg.iter().filter(|s| !s.is_full()).cloned().collect()
        };
        for seg in &partials {
            if covered_n == n {
                break;
            }
            let Some(part) = self.cache.get_quiet(seg_key(seg.id, i)) else {
                continue;
            };
            let cols = seg.cols.as_ref().expect("partial segment has columns");
            for (t, &c) in cols.iter().enumerate() {
                if !covered[c] {
                    buf[c] = part[t];
                    covered[c] = true;
                    covered_n += 1;
                }
            }
        }
        if covered_n == 0 {
            // Cold row: one contiguous full-span dispatch.
            self.kernel.block(
                self.ds.row(i),
                &self.norms[i..i + 1],
                &self.ds.x,
                &self.norms,
                dim,
                &mut buf,
            );
            self.counters.values_computed.fetch_add(n as u64, Ordering::Relaxed);
        } else if covered_n < n {
            // Stitch: gather the uncovered columns into one dispatch.
            let missing: Vec<usize> = (0..n).filter(|&c| !covered[c]).collect();
            let mut xs = Vec::with_capacity(missing.len() * dim);
            let mut mnorms = Vec::with_capacity(missing.len());
            for &c in &missing {
                xs.extend_from_slice(self.ds.row(c));
                mnorms.push(self.norms[c]);
            }
            let mut out = vec![0f32; missing.len()];
            self.kernel.block(
                self.ds.row(i),
                &self.norms[i..i + 1],
                &xs,
                &mnorms,
                dim,
                &mut out,
            );
            for (t, &c) in missing.iter().enumerate() {
                buf[c] = out[t];
            }
            self.counters
                .values_computed
                .fetch_add(missing.len() as u64, Ordering::Relaxed);
            // The per-row path pays one gathered dispatch per stitched row
            // (a degenerate group); the grouped prefetch path collapses
            // same-coverage rows into one.
            self.counters.stitch_groups.fetch_add(1, Ordering::Relaxed);
        }
        if covered_n > 0 {
            self.counters.stitched_rows.fetch_add(1, Ordering::Relaxed);
        }
        self.counters
            .values_stitched
            .fetch_add(covered_n as u64, Ordering::Relaxed);
        self.counters.full_rows.fetch_add(1, Ordering::Relaxed);
        let row: Arc<[f32]> = buf.into();
        self.cache.put(key, Arc::clone(&row));
        row
    }

    /// Rebuild this context over `new_ds`, which must **extend** the
    /// current dataset: same `dim`, same labels, and the old rows as a
    /// bit-identical prefix. The cache, segment registry and every counter
    /// move over, so *appending rows never invalidates existing segment
    /// entries* (property-tested below):
    ///
    /// - partial-segment entries keep their keys and values verbatim —
    ///   their columns are global indices into the unchanged prefix;
    /// - the old full span is **retired** into a partial segment over
    ///   `0..old_n` under its old id, so its resident rows stay reachable
    ///   — and become stitch sources: a warm full-row request after the
    ///   append computes only the appended columns;
    /// - a fresh full-span segment over `0..new_n` takes over
    ///   [`Self::row`] / [`Self::view_full`].
    ///
    /// An equal-length `new_ds` (empty append) keeps the registry as-is.
    /// Panics if `new_ds` does not extend the old dataset.
    pub fn extended(self, new_ds: &'a Dataset) -> KernelContext<'a> {
        let old_n = self.ds.len();
        assert!(
            new_ds.len() >= old_n,
            "extended(): new dataset has {} rows < old {}",
            new_ds.len(),
            old_n
        );
        assert_eq!(new_ds.dim, self.ds.dim, "extended(): dimension changed");
        assert!(
            new_ds.y[..old_n] == self.ds.y[..]
                && new_ds.x[..old_n * new_ds.dim]
                    .iter()
                    .zip(&self.ds.x)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
            "extended(): old rows are not a bit-identical prefix of the new dataset"
        );
        let norms = new_ds.sq_norms();
        debug_assert!(
            norms[..old_n].iter().zip(&self.norms).all(|(a, b)| a.to_bits() == b.to_bits()),
            "prefix norms drifted"
        );
        let KernelContext {
            ds: _,
            kernel,
            norms: _,
            cache,
            segments,
            full_id,
            counters,
            threads,
            registry_cap,
            registry_bytes,
            registry_peak,
            regathers,
            registry_gen,
            quant_route,
            segment_stitching,
        } = self;
        let mut reg = segments.into_inner().unwrap();
        let mut new_full_id = full_id;
        if new_ds.len() > old_n {
            let gen = registry_gen.load(Ordering::Relaxed);
            // Retire the old full span: same id, explicit prefix columns,
            // features gathered lazily (stitching only needs the columns).
            reg[full_id as usize] = Arc::new(SegmentData {
                id: full_id,
                cols: Some((0..old_n).collect()),
                gathered: Mutex::new(None),
                len: old_n,
                gen: AtomicU64::new(gen),
            });
            new_full_id = reg.len() as u32;
            reg.push(Arc::new(SegmentData {
                id: new_full_id,
                cols: None,
                gathered: Mutex::new(None),
                len: new_ds.len(),
                gen: AtomicU64::new(gen),
            }));
        }
        KernelContext {
            ds: new_ds,
            kernel,
            norms,
            cache,
            segments: Mutex::new(reg),
            full_id: new_full_id,
            counters,
            threads,
            registry_cap,
            registry_bytes,
            registry_peak,
            regathers,
            registry_gen,
            quant_route,
            segment_stitching,
        }
    }

    /// Compute all currently uncached **full-span** rows of `rows`. Rows
    /// with no cached partial coverage go into ONE backend dispatch (the
    /// batched prefetch path — on the PJRT backend one call amortizes the
    /// fixed dispatch cost); rows with partial coverage are **grouped by
    /// segment-coverage pattern** and each group's shared uncovered
    /// columns are filled in one gathered dispatch (closing the old
    /// per-row-stitching gap — `stitch_groups` counts the dispatches,
    /// `stitched_rows` the rows they cover). Large dispatches fan out over
    /// row panels across [`Self::threads`] workers. Returns how many rows
    /// were materialized.
    pub fn compute_rows(&self, rows: &[usize]) -> usize {
        let missing: Vec<usize> = rows
            .iter()
            .copied()
            .filter(|&p| !self.cache.contains(self.full_key(p)))
            .collect();
        if missing.is_empty() {
            return 0;
        }
        let partials: Vec<SegmentRef> = {
            let reg = self.segments.lock().unwrap();
            reg.iter().filter(|s| !s.is_full()).cloned().collect()
        };
        // Bucket rows by coverage pattern (the ordered list of segment ids
        // holding a resident entry for the row). Entry handles are pinned
        // now so assembly stays valid if the entries are evicted before
        // their group is processed. BTreeMap keeps group order — and hence
        // cache-insertion order — deterministic.
        let mut cold: Vec<usize> = Vec::new();
        let mut groups: BTreeMap<Vec<u32>, Vec<StitchRow>> = BTreeMap::new();
        for &p in &missing {
            let mut pattern: Vec<u32> = Vec::new();
            let mut parts: Vec<(usize, Arc<[f32]>)> = Vec::new();
            for (si, seg) in partials.iter().enumerate() {
                if let Some(entry) = self.cache.get_quiet(seg_key(seg.id, p)) {
                    pattern.push(seg.id);
                    parts.push((si, entry));
                }
            }
            if pattern.is_empty() {
                cold.push(p);
            } else {
                groups.entry(pattern).or_default().push((p, parts));
            }
        }
        for group in groups.values() {
            self.stitch_group(&partials, group);
        }
        if !cold.is_empty() {
            let n = self.ds.len();
            let dim = self.ds.dim;
            let mut xq = Vec::with_capacity(cold.len() * dim);
            let mut qn = Vec::with_capacity(cold.len());
            for &p in &cold {
                xq.extend_from_slice(self.ds.row(p));
                qn.push(self.norms[p]);
            }
            let mut block = vec![0f32; cold.len() * n];
            self.block_dispatch(&xq, &qn, &self.ds.x, &self.norms, dim, &mut block);
            for (t, &p) in cold.iter().enumerate() {
                self.cache.insert_computed(self.full_key(p), &block[t * n..(t + 1) * n]);
            }
            self.counters
                .values_computed
                .fetch_add((cold.len() * n) as u64, Ordering::Relaxed);
            self.counters.full_rows.fetch_add(cold.len() as u64, Ordering::Relaxed);
        }
        missing.len()
    }

    /// Materialize one coverage group's full rows: the group shares a
    /// covered-column set, so the uncovered columns are gathered ONCE and
    /// filled for every row in a single dispatch; covered columns are
    /// copied from the pinned segment entries in registration order —
    /// exactly the per-row stitching order, so grouped assembly is
    /// bit-identical to [`Self::row`]'s.
    fn stitch_group(&self, partials: &[SegmentRef], group: &[StitchRow]) {
        let n = self.ds.len();
        let dim = self.ds.dim;
        // Resolve the covered columns ONCE from the first row's parts —
        // the pattern (and hence the winning (part, local-index) per
        // column under first-writer-wins in registration order) is
        // identical for every row of the group; each row then just copies
        // through the plan.
        let mut covered = vec![false; n];
        let mut covered_n = 0usize;
        let mut plan: Vec<(usize, usize, usize)> = Vec::new(); // (col, part, local)
        for (pi, &(si, _)) in group[0].1.iter().enumerate() {
            let cols = partials[si].cols.as_ref().expect("partial segment has columns");
            for (u, &c) in cols.iter().enumerate() {
                if !covered[c] {
                    covered[c] = true;
                    covered_n += 1;
                    plan.push((c, pi, u));
                }
            }
        }
        let missing_cols: Vec<usize> = (0..n).filter(|&c| !covered[c]).collect();
        let m = missing_cols.len();
        let g = group.len();
        let mut fills = vec![0f32; g * m];
        if m > 0 {
            let mut xs = Vec::with_capacity(m * dim);
            let mut mnorms = Vec::with_capacity(m);
            for &c in &missing_cols {
                xs.extend_from_slice(self.ds.row(c));
                mnorms.push(self.norms[c]);
            }
            let mut xq = Vec::with_capacity(g * dim);
            let mut qn = Vec::with_capacity(g);
            for &(p, _) in group {
                xq.extend_from_slice(self.ds.row(p));
                qn.push(self.norms[p]);
            }
            self.block_dispatch(&xq, &qn, &xs, &mnorms, dim, &mut fills);
            self.counters.stitch_groups.fetch_add(1, Ordering::Relaxed);
            self.counters
                .values_computed
                .fetch_add((g * m) as u64, Ordering::Relaxed);
        }
        for (t, (p, parts)) in group.iter().enumerate() {
            let mut buf = vec![0f32; n];
            // The plan IS first-writer-wins in registration order, exactly
            // like the per-row path (overlapping segments hold identical
            // values anyway — kernel entries are pure in (x_i, x_j)).
            for &(c, pi, u) in &plan {
                buf[c] = parts[pi].1[u];
            }
            for (u, &c) in missing_cols.iter().enumerate() {
                buf[c] = fills[t * m + u];
            }
            self.cache.insert_computed(self.full_key(*p), &buf);
        }
        self.counters
            .values_stitched
            .fetch_add((covered_n * g) as u64, Ordering::Relaxed);
        self.counters.stitched_rows.fetch_add(g as u64, Ordering::Relaxed);
        self.counters.full_rows.fetch_add(g as u64, Ordering::Relaxed);
    }

    /// Batch-compute the uncached rows of `seg` for the given global rows
    /// in ONE backend dispatch; returns how many were computed.
    pub fn compute_segment_rows(&self, seg: &SegmentRef, rows: &[usize]) -> usize {
        if seg.is_full() {
            return self.compute_rows(rows);
        }
        let missing: Vec<usize> = rows
            .iter()
            .copied()
            .filter(|&p| !self.cache.contains(seg_key(seg.id, p)))
            .collect();
        if missing.is_empty() {
            return 0;
        }
        // With segment stitching on, rows whose columns are partly resident
        // (in the full row or a sibling segment's entry) copy the covered
        // part and batch-dispatch only the uncovered columns, grouped by
        // missing-column pattern so each group pays ONE gathered dispatch.
        // Rows with zero coverage fall through to the contiguous cold batch
        // below, identical to the non-stitching path.
        let cold: Vec<usize> = if self.segment_stitching {
            let mut cold = Vec::new();
            let mut groups: std::collections::BTreeMap<Vec<usize>, Vec<(usize, Vec<f32>)>> =
                std::collections::BTreeMap::new();
            for &p in &missing {
                let mut buf = vec![0f32; seg.len];
                let mut covered = vec![false; seg.len];
                let covered_n = self.cover_segment_from_cache(seg, p, &mut buf, &mut covered);
                if covered_n == 0 {
                    cold.push(p);
                } else if covered_n == seg.len {
                    self.cache.insert_computed(seg_key(seg.id, p), &buf);
                    self.counters
                        .values_stitched
                        .fetch_add(seg.len as u64, Ordering::Relaxed);
                    self.counters.segment_rows.fetch_add(1, Ordering::Relaxed);
                } else {
                    let targets: Vec<usize> = (0..seg.len).filter(|&t| !covered[t]).collect();
                    groups.entry(targets).or_default().push((p, buf));
                }
            }
            for (targets, rows) in groups {
                let m = targets.len();
                let grows: Vec<usize> = rows.iter().map(|&(p, _)| p).collect();
                let fills = self.fill_segment_cols(seg, &grows, &targets);
                for (t, (p, mut buf)) in rows.into_iter().enumerate() {
                    for (u, &c) in targets.iter().enumerate() {
                        buf[c] = fills[t * m + u];
                    }
                    self.cache.insert_computed(seg_key(seg.id, p), &buf);
                }
                self.counters.stitch_groups.fetch_add(1, Ordering::Relaxed);
                self.counters
                    .values_stitched
                    .fetch_add((grows.len() * (seg.len - m)) as u64, Ordering::Relaxed);
                self.counters
                    .segment_rows
                    .fetch_add(grows.len() as u64, Ordering::Relaxed);
            }
            cold
        } else {
            missing.clone()
        };
        if cold.is_empty() {
            return missing.len();
        }
        let dim = self.ds.dim;
        let g = self.gathered(seg);
        let mut xq = Vec::with_capacity(cold.len() * dim);
        let mut qn = Vec::with_capacity(cold.len());
        for &p in &cold {
            xq.extend_from_slice(self.ds.row(p));
            qn.push(self.norms[p]);
        }
        let mut block = vec![0f32; cold.len() * seg.len];
        self.block_dispatch(&xq, &qn, &g.xs, &g.norms, dim, &mut block);
        for (t, &p) in cold.iter().enumerate() {
            self.cache
                .insert_computed(seg_key(seg.id, p), &block[t * seg.len..(t + 1) * seg.len]);
        }
        self.counters
            .values_computed
            .fetch_add((cold.len() * seg.len) as u64, Ordering::Relaxed);
        self.counters
            .segment_rows
            .fetch_add(cold.len() as u64, Ordering::Relaxed);
        missing.len()
    }

    /// Record kernel entries computed by a block pass that bypasses the
    /// cache (kernel-kmeans sample/assignment passes, batch prediction):
    /// keeps [`ValueStats::values_computed`] an honest whole-run total.
    pub fn count_external_values(&self, entries: u64) {
        self.counters.values_computed.fetch_add(entries, Ordering::Relaxed);
    }

    /// Record kernel entries evaluated against int8-quantized operands
    /// (quantized routing / early-prediction block passes). These entries
    /// are *also* reported through [`Self::count_external_values`] by
    /// their callers; this counter tracks what fraction ran quantized.
    pub fn count_quantized_values(&self, entries: u64) {
        self.counters.quantized_values.fetch_add(entries, Ordering::Relaxed);
    }

    pub fn stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Kernel-value accounting snapshot.
    pub fn value_stats(&self) -> ValueStats {
        ValueStats {
            values_computed: self.counters.values_computed.load(Ordering::Relaxed),
            values_stitched: self.counters.values_stitched.load(Ordering::Relaxed),
            segment_rows: self.counters.segment_rows.load(Ordering::Relaxed),
            full_rows: self.counters.full_rows.load(Ordering::Relaxed),
            stitched_rows: self.counters.stitched_rows.load(Ordering::Relaxed),
            stitch_groups: self.counters.stitch_groups.load(Ordering::Relaxed),
            parallel_dispatches: self.counters.parallel_dispatches.load(Ordering::Relaxed),
            quantized_values: self.counters.quantized_values.load(Ordering::Relaxed),
        }
    }

    pub fn hit_rate(&self) -> f64 {
        self.cache.hit_rate()
    }

    /// Identity view over the whole dataset (refine-free solves, the final
    /// conquer solve, the LIBSVM comparator). Rows are full-span (stitched
    /// from divide-phase segments where cached).
    pub fn view_full(&self) -> KernelView<'_> {
        KernelView { ctx: self, map: None, seg: None, label_override: None }
    }

    /// Segmented subset view for a cluster subproblem: local index t ↦
    /// global index `members[t]`, and kernel rows are **segment rows**
    /// `K(x_i, members)` — local-indexed, cluster-length, cached under the
    /// member set's segment key.
    pub fn view(&self, members: &[usize]) -> KernelView<'_> {
        let seg = self.register_segment(members);
        if seg.is_full() {
            // Identity member set: behave exactly like the full view, but
            // keep the map so local/global bookkeeping stays valid.
            return KernelView {
                ctx: self,
                map: Some(members.to_vec()),
                seg: None,
                label_override: None,
            };
        }
        KernelView { ctx: self, map: Some(members.to_vec()), seg: Some(seg), label_override: None }
    }

    /// v1-style subset view: full dataset-length rows under the full-span
    /// key, indexed globally. Kept as the ablation baseline
    /// (`DcSvmConfig::segment_views = false`) and for callers that need
    /// whole rows through a subset lens.
    pub fn view_unsegmented(&self, members: &[usize]) -> KernelView<'_> {
        debug_assert!(members.iter().all(|&i| i < self.ds.len()));
        KernelView { ctx: self, map: Some(members.to_vec()), seg: None, label_override: None }
    }
}

/// A subset (or identity) view of a [`KernelContext`]: the solver-facing
/// handle for one subproblem.
///
/// Row access contract ([`Self::local_row`]):
/// - segmented view → rows have length `self.len()` and are **local**
///   indexed (`row[t] = K(x_i, x_{members[t]})`);
/// - full or unsegmented view → rows have length `ctx.len()` and are
///   **global** indexed; [`Self::unsegmented_map`] returns the map to apply.
pub struct KernelView<'a> {
    ctx: &'a KernelContext<'a>,
    /// local → global; `None` = identity (whole dataset).
    map: Option<Vec<usize>>,
    /// Segment backing this view's rows; `None` = full-span rows.
    seg: Option<SegmentRef>,
    /// Per-local-index label override (see [`Self::with_labels`]); `None`
    /// = read labels through the dataset.
    label_override: Option<Vec<i8>>,
}

impl<'a> KernelView<'a> {
    pub fn ctx(&self) -> &'a KernelContext<'a> {
        self.ctx
    }

    pub fn len(&self) -> usize {
        match &self.map {
            Some(m) => m.len(),
            None => self.ctx.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        match &self.map {
            Some(m) => m.is_empty(),
            None => self.ctx.is_empty(),
        }
    }

    /// Whether this view is the identity over the whole dataset.
    pub fn is_full(&self) -> bool {
        self.map.is_none()
    }

    /// Whether this view's rows are segment rows (local-indexed).
    pub fn is_segmented(&self) -> bool {
        self.seg.is_some()
    }

    /// The local → global index map (`None` = identity).
    pub fn map(&self) -> Option<&[usize]> {
        self.map.as_deref()
    }

    /// `Some(map)` iff rows from [`Self::local_row`] are full-length and
    /// must be indexed through `map` (the v1 unsegmented-subset case);
    /// `None` when rows are directly indexed by local position.
    pub fn unsegmented_map(&self) -> Option<&[usize]> {
        if self.seg.is_some() {
            None
        } else {
            self.map.as_deref()
        }
    }

    /// Length of the rows [`Self::local_row`] returns.
    pub fn row_len(&self) -> usize {
        match &self.seg {
            Some(s) => s.len(),
            None => self.ctx.len(),
        }
    }

    #[inline]
    pub fn global(&self, local: usize) -> usize {
        match &self.map {
            Some(m) => m[local],
            None => local,
        }
    }

    /// Feature row of local point `local`.
    #[inline]
    pub fn x_row(&self, local: usize) -> &'a [f32] {
        self.ctx.ds.row(self.global(local))
    }

    #[inline]
    pub fn norm(&self, local: usize) -> f32 {
        self.ctx.norms[self.global(local)]
    }

    #[inline]
    pub fn label(&self, local: usize) -> i8 {
        match &self.label_override {
            Some(l) => l[local],
            None => self.ctx.ds.y[self.global(local)],
        }
    }

    /// Replace this view's labels with `labels` (one per LOCAL index).
    /// Lets many consumers with different ±1 labelings of the same rows —
    /// the k(k−1)/2 OVO pairs — share ONE context (and thus one segment
    /// cache) over a dataset stored with placeholder labels.
    pub fn with_labels(mut self, labels: Vec<i8>) -> Self {
        assert_eq!(labels.len(), self.len(), "label override length mismatch");
        self.label_override = Some(labels);
        self
    }

    /// All local labels, gathered (hot-loop friendly).
    pub fn labels(&self) -> Vec<i8> {
        if let Some(l) = &self.label_override {
            return l.clone();
        }
        match &self.map {
            Some(m) => m.iter().map(|&g| self.ctx.ds.y[g]).collect(),
            None => self.ctx.ds.y.clone(),
        }
    }

    /// Whether this view's row for `local` is resident (segment row for
    /// segmented views, full-span row otherwise).
    pub fn is_row_cached(&self, local: usize) -> bool {
        let g = self.global(local);
        match &self.seg {
            Some(s) => self.ctx.is_segment_row_cached(s, g),
            None => self.ctx.is_row_cached(g),
        }
    }

    /// This view's kernel row of local point `local` — see the indexing
    /// contract in the type docs.
    pub fn local_row(&self, local: usize) -> Arc<[f32]> {
        let g = self.global(local);
        match &self.seg {
            Some(s) => self.ctx.segment_row(s, g),
            None => self.ctx.row(g),
        }
    }

    /// Full (dataset-length) kernel row of local point `local`, via the
    /// shared cache (stitched from segments where possible). Index the
    /// result with **global** indices.
    pub fn global_row(&self, local: usize) -> Arc<[f32]> {
        self.ctx.row(self.global(local))
    }

    /// Batch-compute the uncached rows of the given local points in one
    /// backend dispatch; returns how many were computed.
    pub fn ensure_rows(&self, locals: &[usize]) -> usize {
        let globals: Vec<usize> = match &self.map {
            Some(m) => locals.iter().map(|&l| m[l]).collect(),
            None => locals.to_vec(),
        };
        match &self.seg {
            Some(s) => self.ctx.compute_segment_rows(s, &globals),
            None => self.ctx.compute_rows(&globals),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate};
    use crate::kernel::native::NativeKernel;
    use crate::prop_assert;
    use crate::util::prng::Pcg64;
    use crate::util::proptest::check;

    fn setup(n: usize) -> (Dataset, NativeKernel) {
        let mut rng = Pcg64::new(3);
        let ds = generate(&covtype_like(), n, &mut rng);
        let k = NativeKernel::new(KernelKind::Rbf { gamma: 8.0 });
        (ds, k)
    }

    #[test]
    fn norms_match_dataset() {
        let (ds, k) = setup(40);
        let ctx = KernelContext::new(&ds, &k, 1 << 20);
        assert_eq!(ctx.norms(), &ds.sq_norms()[..]);
        assert_eq!(ctx.len(), 40);
        assert_eq!(ctx.dim(), ds.dim);
        assert_eq!(ctx.segment_count(), 1); // the full span
    }

    #[test]
    fn row_matches_direct_kernel_eval() {
        let (ds, k) = setup(30);
        let ctx = KernelContext::new(&ds, &k, 1 << 20);
        let row = ctx.row(7);
        assert_eq!(row.len(), 30);
        for j in 0..30 {
            let want = ctx.kind().eval(ds.row(7), ds.row(j));
            assert!((row[j] - want).abs() < 1e-5, "row[{j}]: {} vs {want}", row[j]);
        }
        // Second fetch is a hit.
        let s0 = ctx.stats();
        ctx.row(7);
        let d = ctx.stats().since(&s0);
        assert_eq!((d.hits, d.misses), (1, 0));
        let v = ctx.value_stats();
        assert_eq!(v.values_computed, 30);
        assert_eq!(v.full_rows, 1);
    }

    #[test]
    fn compute_rows_batches_and_skips_resident() {
        let (ds, k) = setup(25);
        let ctx = KernelContext::new(&ds, &k, 1 << 20);
        assert_eq!(ctx.compute_rows(&[1, 3, 5]), 3);
        assert_eq!(ctx.compute_rows(&[3, 5, 7]), 1); // only 7 is new
        for &i in &[1, 3, 5, 7] {
            assert!(ctx.is_row_cached(i));
        }
        // Batched rows agree with the single-row path bit-for-bit.
        let via_batch = ctx.row(3);
        let fresh_ctx = KernelContext::new(&ds, &k, 1 << 20);
        let direct = fresh_ctx.row(3);
        assert_eq!(&*via_batch, &*direct);
        assert_eq!(ctx.value_stats().values_computed, 4 * 25);
    }

    #[test]
    fn subset_view_maps_local_to_global() {
        let (ds, k) = setup(20);
        let ctx = KernelContext::new(&ds, &k, 1 << 20);
        let members = vec![4usize, 9, 17];
        let view = ctx.view(&members);
        assert_eq!(view.len(), 3);
        assert!(!view.is_full());
        assert!(view.is_segmented());
        assert_eq!(view.row_len(), 3);
        for (local, &g) in members.iter().enumerate() {
            assert_eq!(view.global(local), g);
            assert_eq!(view.x_row(local), ds.row(g));
            assert_eq!(view.norm(local), ctx.norm(g));
            assert_eq!(view.label(local), ds.y[g]);
        }
        assert_eq!(view.labels(), members.iter().map(|&g| ds.y[g]).collect::<Vec<_>>());
        // A segment row is local-indexed and matches the full row's values
        // at the member columns bit-for-bit.
        let srow = view.local_row(1); // global 9, columns = members
        assert_eq!(srow.len(), 3);
        assert!(view.is_row_cached(1));
        assert!(!ctx.is_row_cached(9), "segment fetch must not fill the full key");
        let full = ctx.view_full().global_row(9);
        for (t, &g) in members.iter().enumerate() {
            assert_eq!(srow[t], full[g], "segment col {t} (global {g})");
        }
        let v = ctx.value_stats();
        assert_eq!(v.segment_rows, 1);
    }

    #[test]
    fn view_ensure_rows_uses_shared_cache() {
        let (ds, k) = setup(18);
        let ctx = KernelContext::new(&ds, &k, 1 << 20);
        let view = ctx.view(&[2, 6, 11]);
        assert_eq!(view.ensure_rows(&[0, 2]), 2); // globals 2 and 11
        assert!(view.is_row_cached(0));
        assert!(view.is_row_cached(2));
        assert!(!view.is_row_cached(1));
        assert_eq!(view.ensure_rows(&[0, 1, 2]), 1); // only global 6 is new
        // The batched segment path agrees with the single-row path.
        let batched = view.local_row(0);
        let fresh = KernelContext::new(&ds, &k, 1 << 20);
        let single = fresh.view(&[2, 6, 11]).local_row(0);
        assert_eq!(&*batched, &*single);
    }

    #[test]
    fn identity_member_set_resolves_to_full_span() {
        let (ds, k) = setup(12);
        let ctx = KernelContext::new(&ds, &k, 1 << 20);
        let all: Vec<usize> = (0..ds.len()).collect();
        let view = ctx.view(&all);
        assert!(!view.is_segmented());
        assert_eq!(view.row_len(), ds.len());
        assert_eq!(ctx.segment_count(), 1);
        let row = view.local_row(5);
        assert!(ctx.is_row_cached(5));
        assert_eq!(row.len(), ds.len());
    }

    #[test]
    fn register_segment_dedupes() {
        let (ds, k) = setup(16);
        let ctx = KernelContext::new(&ds, &k, 1 << 20);
        let a = ctx.register_segment(&[1, 5, 9]);
        let b = ctx.register_segment(&[1, 5, 9]);
        assert_eq!(a.id(), b.id());
        assert_eq!(ctx.segment_count(), 2);
        let c = ctx.register_segment(&[2, 5, 9]);
        assert_ne!(a.id(), c.id());
        assert_eq!(ctx.segment_count(), 3);
    }

    /// Property (ISSUE satellite): segment rows are bit-identical to the
    /// matching slice of full-row computation, across random subsets — and
    /// full rows stitched from segment entries are bit-identical to
    /// cold-computed full rows.
    #[test]
    fn prop_segment_and_stitched_rows_bit_identical() {
        check("segment-bit-identical", 12, |rng: &mut Pcg64| {
            let n = 12 + rng.below(40);
            let ds = generate(&covtype_like(), n, rng);
            let kind = if rng.next_f64() < 0.6 {
                KernelKind::Rbf { gamma: (0.5 + 8.0 * rng.next_f64()) as f32 }
            } else {
                KernelKind::Poly { gamma: (0.1 + rng.next_f64()) as f32, eta: 0.3 }
            };
            let k = NativeKernel::new(kind);

            // Random subset (sorted, distinct, non-empty, proper).
            let mut members: Vec<usize> =
                (0..n).filter(|_| rng.next_f64() < 0.45).collect();
            if members.is_empty() {
                members.push(rng.below(n));
            }
            if members.len() == n {
                members.pop();
            }

            // Reference: cold full rows, no segments registered.
            let ref_ctx = KernelContext::new(&ds, &k, 8 << 20);
            let seg_ctx = KernelContext::new(&ds, &k, 8 << 20);
            let view = seg_ctx.view(&members);
            let probe = rng.below(members.len());
            let srow = view.local_row(probe);
            let frow = ref_ctx.row(members[probe]);
            for (t, &g) in members.iter().enumerate() {
                prop_assert!(
                    srow[t].to_bits() == frow[g].to_bits(),
                    "segment row not bit-identical at col {t} (global {g})"
                );
            }

            // Stitched full row (segment entry resident) == cold full row.
            let stitched = seg_ctx.row(members[probe]);
            for j in 0..n {
                prop_assert!(
                    stitched[j].to_bits() == frow[j].to_bits(),
                    "stitched row differs at col {j}"
                );
            }
            // And the stitch actually reused the segment's values.
            let v = seg_ctx.value_stats();
            prop_assert!(
                v.values_stitched >= members.len() as u64,
                "no stitching recorded ({} stitched)",
                v.values_stitched
            );
            // Exactly |M| (segment row) + (n − |M|) (uncovered stitch fill)
            // kernel entries were evaluated — the covered columns were
            // copied, not recomputed.
            prop_assert!(
                v.values_computed == n as u64,
                "stitch recomputed covered columns: {} values for |M|={} n={n}",
                v.values_computed,
                members.len()
            );
            Ok(())
        });
    }

    #[test]
    fn unsegmented_view_keeps_full_rows() {
        let (ds, k) = setup(24);
        let ctx = KernelContext::new(&ds, &k, 1 << 20);
        let members = vec![1usize, 8, 15, 21];
        let view = ctx.view_unsegmented(&members);
        assert!(!view.is_segmented());
        assert_eq!(view.unsegmented_map(), Some(&members[..]));
        assert_eq!(view.row_len(), ds.len());
        let row = view.local_row(2); // global 15, full-length
        assert_eq!(row.len(), ds.len());
        assert!(ctx.is_row_cached(15));
    }

    /// Tentpole: warm prefetch groups same-coverage rows into ONE gathered
    /// dispatch — fewer `stitch_groups` than `stitched_rows` — and grouped
    /// rows are bit-identical to the per-row stitching path.
    #[test]
    fn grouped_stitching_collapses_dispatches_bit_identically() {
        let (ds, k) = setup(36);
        let n = ds.len();
        let grouped = KernelContext::new(&ds, &k, 4 << 20);
        let perrow = KernelContext::new(&ds, &k, 4 << 20);
        // Three disjoint column clusters; warm each cluster's own rows so
        // row i is covered exactly by its cluster's segment.
        for ctx in [&grouped, &perrow] {
            for r in 0..3usize {
                let members: Vec<usize> = (0..n).filter(|i| i % 3 == r).collect();
                let seg = ctx.register_segment(&members);
                assert_eq!(ctx.compute_segment_rows(&seg, &members), members.len());
            }
        }
        let all: Vec<usize> = (0..n).collect();
        assert_eq!(grouped.compute_rows(&all), n);
        for &p in &all {
            perrow.row(p); // the old per-row stitching path
        }
        for &p in &all {
            let a = grouped.row(p);
            let b = perrow.row(p);
            for j in 0..n {
                assert_eq!(a[j].to_bits(), b[j].to_bits(), "row {p} col {j}");
            }
        }
        let gv = grouped.value_stats();
        let pv = perrow.value_stats();
        assert_eq!(gv.stitched_rows, n as u64);
        assert_eq!(gv.stitch_groups, 3, "one dispatch per coverage pattern");
        assert!(gv.stitch_groups < gv.stitched_rows);
        assert_eq!(pv.stitch_groups, pv.stitched_rows, "per-row = 1 dispatch/row");
        // Same kernel work either way — grouping only batches it.
        assert_eq!(gv.values_computed, pv.values_computed);
        assert_eq!(gv.values_stitched, pv.values_stitched);
    }

    /// Property (ISSUE satellite): grouped stitching over random segment
    /// layouts and random warm sets is bit-identical to the per-row path,
    /// never performs more gathered dispatches than rows stitched, and a
    /// fully-covered group dispatches nothing.
    #[test]
    fn prop_grouped_stitch_matches_per_row_random_subsets() {
        check("grouped-stitch-bit-identical", 10, |rng: &mut Pcg64| {
            let n = 16 + rng.below(36);
            let ds = generate(&covtype_like(), n, rng);
            let k = NativeKernel::new(KernelKind::Rbf {
                gamma: (0.5 + 8.0 * rng.next_f64()) as f32,
            });
            let grouped = KernelContext::new(&ds, &k, 8 << 20);
            let perrow = KernelContext::new(&ds, &k, 8 << 20);
            let nsegs = 1 + rng.below(3);
            for _ in 0..nsegs {
                let members: Vec<usize> = (0..n).filter(|_| rng.next_f64() < 0.4).collect();
                if members.is_empty() || members.len() == n {
                    continue;
                }
                // Warm a random subset of each segment's rows.
                let warm: Vec<usize> = (0..n).filter(|_| rng.next_f64() < 0.5).collect();
                for ctx in [&grouped, &perrow] {
                    let seg = ctx.register_segment(&members);
                    ctx.compute_segment_rows(&seg, &warm);
                }
            }
            let rows: Vec<usize> = (0..n).filter(|_| rng.next_f64() < 0.7).collect();
            grouped.compute_rows(&rows);
            for &p in &rows {
                perrow.row(p);
            }
            for &p in &rows {
                let a = grouped.row(p);
                let b = perrow.row(p);
                for j in 0..n {
                    prop_assert!(
                        a[j].to_bits() == b[j].to_bits(),
                        "row {p} col {j}: {} vs {}",
                        a[j],
                        b[j]
                    );
                }
            }
            let gv = grouped.value_stats();
            prop_assert!(
                gv.stitch_groups <= gv.stitched_rows,
                "groups {} > stitched rows {}",
                gv.stitch_groups,
                gv.stitched_rows
            );
            prop_assert!(
                gv.values_computed == perrow.value_stats().values_computed,
                "grouping changed the kernel work: {} vs {}",
                gv.values_computed,
                perrow.value_stats().values_computed
            );
            Ok(())
        });
    }

    /// Satellite: the registry byte cap drops old segments' gathered
    /// features (column lists survive for stitching), the peak counter
    /// records the high-water mark, and a dropped segment transparently
    /// re-gathers with bit-identical rows.
    #[test]
    fn registry_cap_drops_and_regathers_gathered_features() {
        let (ds, k) = setup(32);
        let n = ds.len();
        // Each segment gathers 16 rows × (54 floats + 1 norm) ≈ 3.5 KB;
        // cap at ~1.5 segments so the third registration must evict.
        let seg_bytes = 16 * (ds.dim + 1) * 4;
        let ctx = KernelContext::new(&ds, &k, 4 << 20).with_registry_cap(seg_bytes * 3 / 2);
        let uncapped = KernelContext::new(&ds, &k, 4 << 20);
        let halves: Vec<Vec<usize>> = vec![
            (0..n).filter(|i| i % 2 == 0).collect(),
            (0..n).filter(|i| i % 2 == 1).collect(),
            (0..n).filter(|i| i / 2 % 2 == 0).collect(),
        ];
        let mut segs = Vec::new();
        for members in &halves {
            segs.push((ctx.register_segment(members), uncapped.register_segment(members)));
        }
        assert!(
            ctx.registry_bytes() <= seg_bytes * 3 / 2,
            "cap violated: {} bytes",
            ctx.registry_bytes()
        );
        assert!(ctx.registry_peak_bytes() >= ctx.registry_bytes());
        assert!(
            uncapped.registry_bytes() > ctx.registry_bytes(),
            "uncapped registry should hold more gathered bytes"
        );
        // The oldest segment's gathered copy was dropped, the newest kept.
        assert!(!segs[0].0.has_gathered(), "oldest segment kept its features");
        assert!(segs[2].0.has_gathered(), "newest segment lost its features");
        // A dropped segment still serves rows — re-gather, bit-identical.
        let row_capped = ctx.segment_row(&segs[0].0, 5);
        let row_uncapped = uncapped.segment_row(&segs[0].1, 5);
        assert_eq!(&*row_capped, &*row_uncapped);
        assert!(ctx.segment_regathers() >= 1, "re-gather not counted");
        assert_eq!(uncapped.segment_regathers(), 0);
    }

    /// Satellite (registry GC pressure fix): segments of the **current**
    /// registry generation are exempt from the byte cap — the cap is
    /// floored at the live level's working set, so a level that alone
    /// exceeds the cap serves all its rows without a single re-gather.
    /// Opening the next generation makes the old level evictable again.
    #[test]
    fn registry_generation_floor_protects_live_level() {
        let (ds, k) = setup(32);
        let n = ds.len();
        let seg_bytes = 16 * (ds.dim + 1) * 4;
        // Cap below the live level's 3-segment working set.
        let ctx = KernelContext::new(&ds, &k, 4 << 20).with_registry_cap(seg_bytes * 3 / 2);
        ctx.begin_registry_generation();
        let halves: Vec<Vec<usize>> = vec![
            (0..n).filter(|i| i % 2 == 0).collect(),
            (0..n).filter(|i| i % 2 == 1).collect(),
            (0..n).filter(|i| i / 2 % 2 == 0).collect(),
        ];
        let segs: Vec<SegmentRef> =
            halves.iter().map(|m| ctx.register_segment(m)).collect();
        // The whole live level keeps its gathered features despite the cap…
        for (si, seg) in segs.iter().enumerate() {
            assert!(seg.has_gathered(), "live-level segment {si} was evicted");
        }
        // …so serving every segment's rows never re-gathers.
        for seg in &segs {
            ctx.segment_row(seg, 3);
        }
        assert_eq!(ctx.segment_regathers(), 0, "live level thrashed re-gathers");
        // Next level: the old generation becomes evictable history.
        ctx.begin_registry_generation();
        let next: Vec<usize> = (0..n).filter(|i| i % 3 == 0).collect();
        let seg_next = ctx.register_segment(&next);
        assert!(seg_next.has_gathered(), "new live segment evicted");
        assert!(
            segs.iter().any(|s| !s.has_gathered()),
            "previous generation survived enforcement over cap"
        );
    }

    /// Tentpole storage: a `--quant-route` context stores an int8 shadow
    /// alongside each gathered segment (accounted in registry bytes);
    /// exact dispatches are bit-identical with and without it.
    #[test]
    fn quant_route_stores_quantized_shadows_in_registry() {
        let (ds, k) = setup(24);
        let n = ds.len();
        let plain = KernelContext::new(&ds, &k, 4 << 20);
        let quant = KernelContext::new(&ds, &k, 4 << 20).with_quant_route(true);
        assert!(quant.quant_route() && !plain.quant_route());
        let members: Vec<usize> = (0..n).filter(|i| i % 2 == 0).collect();
        let sp = plain.register_segment(&members);
        let sq = quant.register_segment(&members);
        assert!(sq.has_quant() && !sp.has_quant());
        assert!(
            quant.registry_bytes() > plain.registry_bytes(),
            "quantized shadow not accounted: {} vs {}",
            quant.registry_bytes(),
            plain.registry_bytes()
        );
        // The exact dispatch path never reads the shadow.
        assert_eq!(&*quant.segment_row(&sq, 7), &*plain.segment_row(&sp, 7));
        // The quantized counter is caller-driven and starts at zero.
        assert_eq!(quant.value_stats().quantized_values, 0);
        quant.count_quantized_values(42);
        assert_eq!(quant.value_stats().quantized_values, 42);
    }

    /// Tentpole (streaming update): extending a context retires the old
    /// full span into a prefix segment, so warm full rows become stitch
    /// sources — a post-append full-row request computes **only the
    /// appended columns** — and the new full span serves new-length rows.
    #[test]
    fn extended_context_stitches_appends_from_retired_full_span() {
        let (ds, k) = setup(20);
        let n = ds.len();
        let mut rng = Pcg64::new(17);
        let extra = generate(&covtype_like(), 6, &mut rng);
        let ds2 = ds.appended(&extra, "appended");
        let ctx = KernelContext::new(&ds, &k, 4 << 20);
        let warm_row = ctx.row(3);
        assert_eq!(warm_row.len(), n);
        let ctx2 = ctx.extended(&ds2);
        assert_eq!(ctx2.len(), n + 6);
        assert_eq!(ctx2.full_segment().len(), n + 6);
        assert!(!ctx2.is_row_cached(3), "old-length row resident under new full key");
        let before = ctx2.value_stats();
        let row2 = ctx2.row(3);
        let d = ctx2.value_stats().since(&before);
        assert_eq!(row2.len(), n + 6);
        assert_eq!(d.values_computed, 6, "recomputed prefix columns on append");
        assert_eq!(d.values_stitched, n as u64);
        for j in 0..n {
            assert_eq!(row2[j].to_bits(), warm_row[j].to_bits(), "prefix col {j}");
        }
        // The stitched row agrees with a cold context over the new data.
        let cold = KernelContext::new(&ds2, &k, 4 << 20);
        let want = cold.row(3);
        for j in 0..n + 6 {
            assert_eq!(row2[j].to_bits(), want[j].to_bits(), "col {j}");
        }
        assert_eq!(ctx2.segment_regathers(), 0);
        // Empty append keeps the registry untouched.
        let segs = ctx2.segment_count();
        let ctx3 = ctx2.extended(&ds2);
        assert_eq!(ctx3.segment_count(), segs);
        assert!(ctx3.is_row_cached(3));
    }

    /// Property (ISSUE satellite): appending rows to a `KernelContext`
    /// never invalidates existing segment entries — every cached
    /// `(segment, row)` value is bit-identical before and after the
    /// append, `segment_regathers` stays 0, and post-append rows are
    /// bit-identical to a cold context over the extended dataset.
    #[test]
    fn prop_extended_preserves_segment_entries_bit_identical() {
        check("extend-preserves-entries", 10, |rng: &mut Pcg64| {
            let n = 10 + rng.below(30);
            let ds = generate(&covtype_like(), n, rng);
            let extra = generate(&covtype_like(), 1 + rng.below(12), rng);
            let ds2 = ds.appended(&extra, "appended");
            let k = NativeKernel::new(KernelKind::Rbf {
                gamma: (0.5 + 8.0 * rng.next_f64()) as f32,
            });
            let ctx = KernelContext::new(&ds, &k, 8 << 20);
            // Register 1–3 random segments and warm random rows of each,
            // plus a few full rows.
            let mut segs = Vec::new();
            for _ in 0..1 + rng.below(3) {
                let members: Vec<usize> = (0..n).filter(|_| rng.next_f64() < 0.4).collect();
                if members.is_empty() || members.len() == n {
                    continue;
                }
                let warm: Vec<usize> = (0..n).filter(|_| rng.next_f64() < 0.5).collect();
                let seg = ctx.register_segment(&members);
                ctx.compute_segment_rows(&seg, &warm);
                segs.push(seg);
            }
            let full_warm: Vec<usize> = (0..n).filter(|_| rng.next_f64() < 0.3).collect();
            ctx.compute_rows(&full_warm);
            // Snapshot every resident (segment, row) entry, full span
            // included (it survives the append as the retired prefix).
            let mut snap: Vec<(u64, Arc<[f32]>)> = Vec::new();
            let full = ctx.full_segment();
            for seg in segs.iter().chain(std::iter::once(&full)) {
                for i in 0..n {
                    if let Some(e) = ctx.cache().get_quiet(seg_key(seg.id(), i)) {
                        snap.push((seg_key(seg.id(), i), e));
                    }
                }
            }
            let regathers_before = ctx.segment_regathers();
            let ctx2 = ctx.extended(&ds2);
            for (key, want) in &snap {
                let got = ctx2.cache().get_quiet(*key);
                prop_assert!(got.is_some(), "entry {key:#x} evicted by append");
                let got = got.unwrap();
                prop_assert!(
                    got.len() == want.len()
                        && got.iter().zip(want.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "entry {key:#x} not bit-identical after append"
                );
            }
            // Post-append reads: segment rows and stitched full rows match
            // a cold context over the extended dataset, bit-for-bit.
            let cold = KernelContext::new(&ds2, &k, 8 << 20);
            let probe = rng.below(ds2.len());
            let a = ctx2.row(probe);
            let b = cold.row(probe);
            for j in 0..ds2.len() {
                prop_assert!(
                    a[j].to_bits() == b[j].to_bits(),
                    "extended row {probe} col {j} differs"
                );
            }
            prop_assert!(
                ctx2.segment_regathers() == regathers_before,
                "append triggered re-gathers"
            );
            Ok(())
        });
    }

    /// Large dispatches fan out over row panels (counted), bit-identically
    /// to a single-threaded context.
    #[test]
    fn parallel_dispatch_counted_and_bit_identical() {
        let (ds, _) = setup(40);
        let n = ds.len();
        // Force the parallel path on small blocks.
        let forced = NativeKernel::with_par_threshold(KernelKind::Rbf { gamma: 8.0 }, 1);
        let par = KernelContext::new(&ds, &forced, 4 << 20).with_threads(4);
        let serial = KernelContext::new(&ds, &forced, 4 << 20).with_threads(1);
        let rows: Vec<usize> = (0..n).collect();
        assert_eq!(par.compute_rows(&rows), n);
        assert_eq!(serial.compute_rows(&rows), n);
        for &p in &rows {
            assert_eq!(&*par.row(p), &*serial.row(p), "thread count changed row {p}");
        }
        assert!(par.value_stats().parallel_dispatches > 0, "fan-out not counted");
        assert_eq!(serial.value_stats().parallel_dispatches, 0);
        assert_eq!(par.threads(), 4);
        assert_eq!(serial.threads(), 1);
        assert_eq!(par.value_stats().values_computed, serial.value_stats().values_computed);
    }
}
