//! The unified kernel-access layer: one [`KernelContext`] per dataset.
//!
//! A context owns everything every consumer of kernel values needs and used
//! to recompute privately: the dataset reference, its precomputed squared
//! row norms (previously recomputed via `sq_norms()` at 15+ call sites), the
//! [`BlockKernel`] backend, and the shared [`ShardedRowCache`] of full
//! kernel rows keyed by **global row index**.
//!
//! [`KernelView`] is a cheap subset view (local → global index map) used for
//! cluster subproblems: a view routes its kernel-row requests through the
//! shared cache, so rows computed while solving one cluster at level l are
//! still resident for level l−1, the refine solve, and the final conquer
//! solve — the cache analogue of the paper's α warm start. Views therefore
//! compute *full* rows (against the whole dataset) rather than
//! cluster-local rows: a subproblem pays up to k× more per cache miss, but
//! each row is computed once per training run instead of once per phase,
//! and the conquer solve starts with the SV rows already resident
//! (`tests/dcsvm_e2e.rs::shared_context_prewarms_conquer_solve`).
//!
//! Batched dispatch lives here too ([`KernelContext::compute_rows`]): the
//! PJRT backend pays a fixed per-call cost, so the solver's row prefetch,
//! kernel-kmeans assignment and batch prediction all funnel multi-row
//! requests into single backend calls.

use std::sync::Arc;

use crate::data::Dataset;
use crate::kernel::{BlockKernel, KernelKind};

use super::sharded::{CacheStats, ShardedRowCache};

/// Default row-cache budget when a caller does not care (tests, one-shot
/// convenience solves): 256 MB, the LIBSVM-style default.
pub const DEFAULT_CACHE_BYTES: usize = 256 << 20;

/// Default shard count: enough to keep `scope_map` cluster workers from
/// serializing on fills without oversharding tiny budgets.
const DEFAULT_SHARDS: usize = 16;

/// Kernel-access context for one dataset: rows, norms, backend, shared
/// row cache.
pub struct KernelContext<'a> {
    ds: &'a Dataset,
    kernel: &'a dyn BlockKernel,
    norms: Vec<f32>,
    cache: ShardedRowCache,
}

impl<'a> KernelContext<'a> {
    /// Build a context with the default shard count. Computes `sq_norms`
    /// once — consumers read them via [`Self::norms`] / [`Self::norm`].
    pub fn new(ds: &'a Dataset, kernel: &'a dyn BlockKernel, cache_bytes: usize) -> Self {
        Self::with_shards(ds, kernel, cache_bytes, DEFAULT_SHARDS)
    }

    pub fn with_shards(
        ds: &'a Dataset,
        kernel: &'a dyn BlockKernel,
        cache_bytes: usize,
        shards: usize,
    ) -> Self {
        let norms = ds.sq_norms();
        let cache = ShardedRowCache::new(ds.len(), cache_bytes, shards);
        KernelContext { ds, kernel, norms, cache }
    }

    pub fn ds(&self) -> &'a Dataset {
        self.ds
    }

    pub fn kernel(&self) -> &'a dyn BlockKernel {
        self.kernel
    }

    pub fn kind(&self) -> KernelKind {
        self.kernel.kind()
    }

    pub fn len(&self) -> usize {
        self.ds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ds.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.ds.dim
    }

    /// Precomputed squared L2 norms of all rows.
    pub fn norms(&self) -> &[f32] {
        &self.norms
    }

    #[inline]
    pub fn norm(&self, i: usize) -> f32 {
        self.norms[i]
    }

    #[inline]
    pub fn label(&self, i: usize) -> i8 {
        self.ds.y[i]
    }

    /// The shared row cache (tests / diagnostics).
    pub fn cache(&self) -> &ShardedRowCache {
        &self.cache
    }

    pub fn is_row_cached(&self, i: usize) -> bool {
        self.cache.contains(i)
    }

    /// Full kernel row K(x_i, ·) against the whole dataset, through the
    /// shared cache (single-row backend dispatch on miss).
    pub fn row(&self, i: usize) -> Arc<[f32]> {
        self.cache.get_or_compute(i, |out| {
            self.kernel.block(
                self.ds.row(i),
                &self.norms[i..i + 1],
                &self.ds.x,
                &self.norms,
                self.ds.dim,
                out,
            );
        })
    }

    /// Compute all currently uncached rows of `rows` in ONE backend
    /// dispatch and insert them into the shared cache; returns how many
    /// rows were computed. This is the batched prefetch path: on the PJRT
    /// backend one call amortizes the fixed dispatch cost across the batch.
    pub fn compute_rows(&self, rows: &[usize]) -> usize {
        let missing: Vec<usize> = rows
            .iter()
            .copied()
            .filter(|&p| !self.cache.contains(p))
            .collect();
        if missing.is_empty() {
            return 0;
        }
        let n = self.ds.len();
        let dim = self.ds.dim;
        let mut xq = Vec::with_capacity(missing.len() * dim);
        let mut qn = Vec::with_capacity(missing.len());
        for &p in &missing {
            xq.extend_from_slice(self.ds.row(p));
            qn.push(self.norms[p]);
        }
        let mut block = vec![0f32; missing.len() * n];
        self.kernel
            .block(&xq, &qn, &self.ds.x, &self.norms, dim, &mut block);
        for (t, &p) in missing.iter().enumerate() {
            self.cache.insert_computed(p, &block[t * n..(t + 1) * n]);
        }
        missing.len()
    }

    pub fn stats(&self) -> CacheStats {
        self.cache.stats()
    }

    pub fn hit_rate(&self) -> f64 {
        self.cache.hit_rate()
    }

    /// Identity view over the whole dataset (refine-free solves, the final
    /// conquer solve, the LIBSVM comparator).
    pub fn view_full(&self) -> KernelView<'_> {
        KernelView { ctx: self, map: None }
    }

    /// Subset view for a cluster subproblem: local index t ↦ global index
    /// `members[t]`. Rows the subproblem computes land in the shared cache
    /// under their global keys.
    pub fn view(&self, members: &[usize]) -> KernelView<'_> {
        debug_assert!(members.iter().all(|&i| i < self.ds.len()));
        KernelView { ctx: self, map: Some(members.to_vec()) }
    }
}

/// A subset (or identity) view of a [`KernelContext`]: the solver-facing
/// handle for one subproblem. Kernel rows fetched through a view are always
/// **full dataset-length rows** — index them with [`Self::global`] indices.
pub struct KernelView<'a> {
    ctx: &'a KernelContext<'a>,
    /// local → global; `None` = identity (whole dataset).
    map: Option<Vec<usize>>,
}

impl<'a> KernelView<'a> {
    pub fn ctx(&self) -> &'a KernelContext<'a> {
        self.ctx
    }

    pub fn len(&self) -> usize {
        match &self.map {
            Some(m) => m.len(),
            None => self.ctx.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether this view is the identity over the whole dataset.
    pub fn is_full(&self) -> bool {
        self.map.is_none()
    }

    /// The local → global index map (`None` = identity).
    pub fn map(&self) -> Option<&[usize]> {
        self.map.as_deref()
    }

    #[inline]
    pub fn global(&self, local: usize) -> usize {
        match &self.map {
            Some(m) => m[local],
            None => local,
        }
    }

    /// Feature row of local point `local`.
    #[inline]
    pub fn x_row(&self, local: usize) -> &'a [f32] {
        self.ctx.ds.row(self.global(local))
    }

    #[inline]
    pub fn norm(&self, local: usize) -> f32 {
        self.ctx.norms[self.global(local)]
    }

    #[inline]
    pub fn label(&self, local: usize) -> i8 {
        self.ctx.ds.y[self.global(local)]
    }

    /// All local labels, gathered (hot-loop friendly).
    pub fn labels(&self) -> Vec<i8> {
        match &self.map {
            Some(m) => m.iter().map(|&g| self.ctx.ds.y[g]).collect(),
            None => self.ctx.ds.y.clone(),
        }
    }

    pub fn is_row_cached(&self, local: usize) -> bool {
        self.ctx.is_row_cached(self.global(local))
    }

    /// Full (dataset-length) kernel row of local point `local`, via the
    /// shared cache. Index the result with **global** indices.
    pub fn global_row(&self, local: usize) -> Arc<[f32]> {
        self.ctx.row(self.global(local))
    }

    /// Batch-compute the uncached rows of the given local points in one
    /// backend dispatch; returns how many were computed.
    pub fn ensure_rows(&self, locals: &[usize]) -> usize {
        match &self.map {
            Some(m) => {
                let globals: Vec<usize> = locals.iter().map(|&l| m[l]).collect();
                self.ctx.compute_rows(&globals)
            }
            None => self.ctx.compute_rows(locals),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{covtype_like, generate};
    use crate::kernel::native::NativeKernel;
    use crate::util::prng::Pcg64;

    fn setup(n: usize) -> (Dataset, NativeKernel) {
        let mut rng = Pcg64::new(3);
        let ds = generate(&covtype_like(), n, &mut rng);
        let k = NativeKernel::new(KernelKind::Rbf { gamma: 8.0 });
        (ds, k)
    }

    #[test]
    fn norms_match_dataset() {
        let (ds, k) = setup(40);
        let ctx = KernelContext::new(&ds, &k, 1 << 20);
        assert_eq!(ctx.norms(), &ds.sq_norms()[..]);
        assert_eq!(ctx.len(), 40);
        assert_eq!(ctx.dim(), ds.dim);
    }

    #[test]
    fn row_matches_direct_kernel_eval() {
        let (ds, k) = setup(30);
        let ctx = KernelContext::new(&ds, &k, 1 << 20);
        let row = ctx.row(7);
        assert_eq!(row.len(), 30);
        for j in 0..30 {
            let want = ctx.kind().eval(ds.row(7), ds.row(j));
            assert!((row[j] - want).abs() < 1e-5, "row[{j}]: {} vs {want}", row[j]);
        }
        // Second fetch is a hit.
        let s0 = ctx.stats();
        ctx.row(7);
        let d = ctx.stats().since(&s0);
        assert_eq!((d.hits, d.misses), (1, 0));
    }

    #[test]
    fn compute_rows_batches_and_skips_resident() {
        let (ds, k) = setup(25);
        let ctx = KernelContext::new(&ds, &k, 1 << 20);
        assert_eq!(ctx.compute_rows(&[1, 3, 5]), 3);
        assert_eq!(ctx.compute_rows(&[3, 5, 7]), 1); // only 7 is new
        for &i in &[1, 3, 5, 7] {
            assert!(ctx.is_row_cached(i));
        }
        // Batched rows agree with the single-row path.
        let via_batch = ctx.row(3);
        let fresh_ctx = KernelContext::new(&ds, &k, 1 << 20);
        let direct = fresh_ctx.row(3);
        assert_eq!(&*via_batch, &*direct);
    }

    #[test]
    fn subset_view_maps_local_to_global() {
        let (ds, k) = setup(20);
        let ctx = KernelContext::new(&ds, &k, 1 << 20);
        let members = vec![4usize, 9, 17];
        let view = ctx.view(&members);
        assert_eq!(view.len(), 3);
        assert!(!view.is_full());
        for (local, &g) in members.iter().enumerate() {
            assert_eq!(view.global(local), g);
            assert_eq!(view.x_row(local), ds.row(g));
            assert_eq!(view.norm(local), ctx.norm(g));
            assert_eq!(view.label(local), ds.y[g]);
        }
        assert_eq!(view.labels(), members.iter().map(|&g| ds.y[g]).collect::<Vec<_>>());
        // A row fetched through the view is cached under the GLOBAL key —
        // visible to the full view afterwards.
        let row = view.global_row(1); // global 9
        assert!(ctx.is_row_cached(9));
        let full = ctx.view_full();
        let again = full.global_row(9);
        assert_eq!(&*row, &*again);
        let s = ctx.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn view_ensure_rows_uses_shared_cache() {
        let (ds, k) = setup(18);
        let ctx = KernelContext::new(&ds, &k, 1 << 20);
        let view = ctx.view(&[2, 6, 11]);
        assert_eq!(view.ensure_rows(&[0, 2]), 2); // globals 2 and 11
        assert!(ctx.is_row_cached(2));
        assert!(ctx.is_row_cached(11));
        assert!(!ctx.is_row_cached(6));
        assert_eq!(view.ensure_rows(&[0, 1, 2]), 1); // only global 6 is new
    }
}
