//! LIBSVM-style LRU kernel-row cache.
//!
//! The decomposition solver touches kernel rows in a highly skewed pattern
//! (free SVs get hit every iteration; shrunk variables never), so a
//! byte-budgeted LRU over rows is the classic design (Chang & Lin 2011,
//! §4.2). DC-SVM's warm start makes this even more effective: with the SV
//! set mostly identified, the working set — and therefore the cached rows —
//! stabilizes early (paper Figure 2).

pub mod lru;

pub use lru::RowCache;
