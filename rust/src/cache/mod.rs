//! Kernel-access layer: context, views, and the shared segment-granular
//! kernel cache (v2).
//!
//! The decomposition solver touches kernel rows in a highly skewed pattern
//! (free SVs get hit every iteration; shrunk variables never), so a
//! byte-budgeted cache over rows is the classic design (Chang & Lin 2011,
//! §4.2). DC-SVM adds two structural twists the v2 layer exploits:
//!
//! 1. **Sharing across solves** (v1): the divide phase already computes
//!    the rows of (most of) the final SV set (paper Figure 2 — the SV set
//!    is identified early), so a per-solve private cache throws away
//!    exactly the rows the refine and conquer solves are about to ask for.
//! 2. **Subproblem locality** (v2): a cluster subproblem only ever reads
//!    the within-cluster block of K, so caching full dataset-length rows
//!    for it wastes ~(k−1)/k of every computed byte at k clusters. Keys
//!    are therefore `(segment, row)` composites — cluster-aligned partial
//!    rows during divide, the full span for conquer/serving — and full
//!    rows are *stitched* from cached segments on demand.
//!
//! Layering, bottom-up:
//!
//! - [`lru::RowCache`] — single-threaded byte-budgeted **CLOCK
//!   (second-chance)** cache over reference-counted variable-length
//!   entries; the per-shard building block. Frequency-aware: a referenced
//!   bit per entry protects hot SV rows from one-shot sweeps.
//! - [`sharded::ShardedRowCache`] — thread-safe sharded wrapper keyed by
//!   `u64`; the byte budget starts evenly split and is periodically
//!   **rebalanced** toward miss pressure (hot shards grow, cold shards
//!   shrink, the global budget is conserved).
//! - [`context::KernelContext`] — one per dataset: owns the precomputed
//!   squared norms, the [`crate::kernel::BlockKernel`] backend, the shared
//!   cache, the segment registry, and the kernel-value counters
//!   ([`context::ValueStats`]); all batched dispatches (row prefetch,
//!   assignment, prediction) funnel through it.
//! - [`context::KernelView`] — cheap local→global subset view handed to
//!   cluster subproblem solvers; segmented views fetch local-indexed
//!   partial rows, and everything a view computes survives into later
//!   phases (the cache analogue of the α warm start).
//!
//! `dcsvm::train` builds exactly one context per training run and threads
//! views through levels → refine → final; the harness builds contexts for
//! its train/test datasets so norms are computed once per dataset.

pub mod context;
pub mod lru;
pub mod sharded;

pub use context::{
    KernelContext, KernelView, SegmentData, SegmentRef, ValueStats, DEFAULT_CACHE_BYTES,
};
pub use lru::RowCache;
pub use sharded::{CacheStats, ShardInfo, ShardedRowCache};
