//! Kernel-access layer: context, views, and the shared kernel-row cache.
//!
//! The decomposition solver touches kernel rows in a highly skewed pattern
//! (free SVs get hit every iteration; shrunk variables never), so a
//! byte-budgeted LRU over rows is the classic design (Chang & Lin 2011,
//! §4.2). DC-SVM makes sharing that cache *across* solves the real win:
//! the divide phase already computes the rows of (most of) the final SV set
//! (paper Figure 2 — the SV set is identified early), so a per-solve
//! private cache throws away exactly the rows the refine and conquer solves
//! are about to ask for.
//!
//! Layering, bottom-up:
//!
//! - [`lru::RowCache`] — single-threaded byte-budgeted LRU over
//!   reference-counted rows; the per-shard building block.
//! - [`sharded::ShardedRowCache`] — thread-safe sharded wrapper, keyed by
//!   **global row index**, budget split across independently locked shards;
//!   concurrent cluster subproblems from `scope_map` fill it in parallel.
//! - [`context::KernelContext`] — one per dataset: owns the precomputed
//!   squared norms, the [`crate::kernel::BlockKernel`] backend and the
//!   shared cache; all batched dispatches (row prefetch, assignment,
//!   prediction) funnel through it.
//! - [`context::KernelView`] — cheap local→global subset view handed to
//!   cluster subproblem solvers; rows computed through a view survive into
//!   later phases (the cache analogue of the α warm start).
//!
//! `dcsvm::train` builds exactly one context per training run and threads
//! views through levels → refine → final; the harness builds contexts for
//! its train/test datasets so norms are computed once per dataset.

pub mod context;
pub mod lru;
pub mod sharded;

pub use context::{KernelContext, KernelView, DEFAULT_CACHE_BYTES};
pub use lru::RowCache;
pub use sharded::{CacheStats, ShardedRowCache};
