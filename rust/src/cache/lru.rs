//! Byte-budgeted LRU cache of kernel rows — the single-shard building block
//! of [`super::sharded::ShardedRowCache`].
//!
//! Keys are *global* row indices of the dataset owned by a
//! [`super::KernelContext`]; values are `Arc<[f32]>` rows of length
//! `row_len`. Rows are reference-counted so a caller can keep using a row
//! after it has been evicted (and so the sharded wrapper can hand rows out
//! across its shard lock). The LRU order lives in an intrusive
//! doubly-linked list over slot indices so touch/evict are O(1), and
//! `get_or_compute` exposes the fill path the solver uses. Hit/miss
//! counters feed EXPERIMENTS.md and the harness `Outcome` structured
//! fields (`cache_hit_rate`, `final_rows`).

use std::collections::HashMap;
use std::sync::Arc;

const NIL: usize = usize::MAX;

struct Slot {
    key: usize,
    row: Arc<[f32]>,
    prev: usize,
    next: usize,
}

/// LRU kernel-row cache with a fixed byte budget.
pub struct RowCache {
    map: HashMap<usize, usize>, // key -> slot index
    slots: Vec<Slot>,
    free: Vec<usize>,
    head: usize, // most recently used
    tail: usize, // least recently used
    row_len: usize,
    capacity_rows: usize,
    pub hits: u64,
    pub misses: u64,
}

impl RowCache {
    /// `budget_bytes` is the total f32 payload budget; at least one row is
    /// always allowed.
    pub fn new(row_len: usize, budget_bytes: usize) -> Self {
        let capacity_rows = (budget_bytes / (row_len.max(1) * 4)).max(1);
        RowCache {
            map: HashMap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            row_len,
            capacity_rows,
            hits: 0,
            misses: 0,
        }
    }

    pub fn row_len(&self) -> usize {
        self.row_len
    }

    pub fn capacity_rows(&self) -> usize {
        self.capacity_rows
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, key: usize) -> bool {
        self.map.contains_key(&key)
    }

    /// Fetch a row, computing and inserting it on miss. `fill` writes the
    /// row contents into the provided buffer.
    pub fn get_or_compute<F>(&mut self, key: usize, fill: F) -> &[f32]
    where
        F: FnOnce(&mut [f32]),
    {
        let slot = self.slot_or_compute(key, fill);
        &self.slots[slot].row
    }

    /// Like [`Self::get_or_compute`] but returns a shared handle that stays
    /// valid after eviction — the form the concurrent sharded cache needs.
    pub fn get_arc_or_compute<F>(&mut self, key: usize, fill: F) -> Arc<[f32]>
    where
        F: FnOnce(&mut [f32]),
    {
        let slot = self.slot_or_compute(key, fill);
        Arc::clone(&self.slots[slot].row)
    }

    /// Probe half of a caller-batched fill: return the resident row
    /// (recording a hit and an LRU touch), or record a miss and return
    /// `None`. The caller computes the missing rows in one batched dispatch
    /// and stores them with [`Self::put_arc`], which does **not** count
    /// again — together one probe+fill records exactly one hit or miss.
    pub fn get_arc(&mut self, key: usize) -> Option<Arc<[f32]>> {
        if let Some(&slot) = self.map.get(&key) {
            self.hits += 1;
            self.touch(slot);
            Some(Arc::clone(&self.slots[slot].row))
        } else {
            self.misses += 1;
            None
        }
    }

    /// Insert a row whose miss was already recorded by [`Self::get_arc`];
    /// counters are left untouched. A resident key keeps its existing row
    /// (row contents are a pure function of the key) and is only touched.
    pub fn put_arc(&mut self, key: usize, row: Arc<[f32]>) {
        debug_assert_eq!(row.len(), self.row_len);
        if let Some(&slot) = self.map.get(&key) {
            self.touch(slot);
            return;
        }
        self.insert_slot(key, row);
    }

    fn slot_or_compute<F>(&mut self, key: usize, fill: F) -> usize
    where
        F: FnOnce(&mut [f32]),
    {
        if let Some(&slot) = self.map.get(&key) {
            self.hits += 1;
            self.touch(slot);
            return slot;
        }
        self.misses += 1;
        let mut buf = vec![0f32; self.row_len];
        fill(&mut buf);
        self.insert_slot(key, buf.into())
    }

    /// Insert an externally computed row (batched fill path). Counts a miss
    /// when the key is new — the caller did compute the row — and a hit
    /// (plus an LRU touch) when the key is already resident, in which case
    /// the existing row is kept.
    pub fn insert_arc(&mut self, key: usize, row: Arc<[f32]>) {
        debug_assert_eq!(row.len(), self.row_len);
        if let Some(&slot) = self.map.get(&key) {
            self.hits += 1;
            self.touch(slot);
            return;
        }
        self.misses += 1;
        self.insert_slot(key, row);
    }

    /// Peek without changing LRU order or counters (used by tests).
    pub fn peek(&self, key: usize) -> Option<&[f32]> {
        self.map.get(&key).map(|&s| &*self.slots[s].row)
    }

    /// Drop all entries, keep slot allocation.
    pub fn clear(&mut self) {
        self.map.clear();
        self.free.clear();
        self.free.extend(0..self.slots.len());
        self.head = NIL;
        self.tail = NIL;
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    // -- intrusive list plumbing -------------------------------------------

    fn detach(&mut self, slot: usize) {
        let (p, n) = (self.slots[slot].prev, self.slots[slot].next);
        if p != NIL {
            self.slots[p].next = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.slots[n].prev = p;
        } else {
            self.tail = p;
        }
    }

    fn push_front(&mut self, slot: usize) {
        self.slots[slot].prev = NIL;
        self.slots[slot].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = slot;
        }
        self.head = slot;
        if self.tail == NIL {
            self.tail = slot;
        }
    }

    fn touch(&mut self, slot: usize) {
        if self.head == slot {
            return;
        }
        self.detach(slot);
        self.push_front(slot);
    }

    fn insert_slot(&mut self, key: usize, row: Arc<[f32]>) -> usize {
        let slot = if self.map.len() >= self.capacity_rows {
            // Evict LRU.
            let victim = self.tail;
            debug_assert_ne!(victim, NIL);
            self.detach(victim);
            self.map.remove(&self.slots[victim].key);
            self.slots[victim].key = key;
            self.slots[victim].row = row;
            victim
        } else if let Some(s) = self.free.pop() {
            self.slots[s].key = key;
            self.slots[s].row = row;
            s
        } else {
            self.slots.push(Slot { key, row, prev: NIL, next: NIL });
            self.slots.len() - 1
        };
        self.push_front(slot);
        self.map.insert(key, slot);
        slot
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::{prng::Pcg64, proptest::check};

    #[test]
    fn hit_returns_cached_value() {
        let mut c = RowCache::new(4, 1024);
        c.get_or_compute(7, |r| r.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]));
        let row = c.get_or_compute(7, |_| panic!("should not recompute"));
        assert_eq!(row, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn evicts_lru_not_mru() {
        let mut c = RowCache::new(1, 3 * 4); // capacity 3 rows
        for k in 0..3 {
            c.get_or_compute(k, |r| r[0] = k as f32);
        }
        c.get_or_compute(0, |_| panic!("0 cached")); // touch 0 -> MRU
        c.get_or_compute(3, |r| r[0] = 3.0); // evicts 1 (LRU)
        assert!(c.contains(0));
        assert!(!c.contains(1));
        assert!(c.contains(2));
        assert!(c.contains(3));
    }

    #[test]
    fn capacity_at_least_one() {
        let mut c = RowCache::new(1000, 1); // budget below one row
        assert_eq!(c.capacity_rows(), 1);
        c.get_or_compute(1, |r| r[0] = 1.0);
        c.get_or_compute(2, |r| r[0] = 2.0);
        assert!(!c.contains(1));
        assert!(c.contains(2));
    }

    #[test]
    fn clear_resets() {
        let mut c = RowCache::new(2, 1024);
        c.get_or_compute(1, |r| r[0] = 1.0);
        c.clear();
        assert!(c.is_empty());
        let mut recomputed = false;
        c.get_or_compute(1, |_| recomputed = true);
        assert!(recomputed);
    }

    #[test]
    fn arc_rows_survive_eviction() {
        let mut c = RowCache::new(1, 4); // capacity 1 row
        let first = c.get_arc_or_compute(10, |r| r[0] = 10.0);
        c.get_arc_or_compute(11, |r| r[0] = 11.0); // evicts key 10
        assert!(!c.contains(10));
        assert_eq!(first[0], 10.0); // handle still valid
    }

    #[test]
    fn get_arc_put_arc_count_once_per_probe() {
        let mut c = RowCache::new(2, 1024);
        assert!(c.get_arc(3).is_none()); // miss recorded
        assert_eq!((c.hits, c.misses), (0, 1));
        c.put_arc(3, vec![1.0f32, 2.0].into()); // quiet insert
        assert_eq!((c.hits, c.misses), (0, 1));
        let row = c.get_arc(3).expect("resident");
        assert_eq!(&*row, &[1.0, 2.0]);
        assert_eq!((c.hits, c.misses), (1, 1));
        // Quiet re-insert of a resident key keeps the existing row.
        c.put_arc(3, vec![9.0f32, 9.0].into());
        assert_eq!(c.peek(3).unwrap(), &[1.0, 2.0]);
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn put_arc_touches_lru_order() {
        let mut c = RowCache::new(1, 2 * 4); // capacity 2 rows
        c.put_arc(0, vec![0.0f32].into());
        c.put_arc(1, vec![1.0f32].into());
        c.put_arc(0, vec![0.0f32].into()); // touch 0 -> MRU
        c.put_arc(2, vec![2.0f32].into()); // evicts 1 (LRU)
        assert!(c.contains(0));
        assert!(!c.contains(1));
        assert!(c.contains(2));
    }

    #[test]
    fn insert_arc_counts_and_keeps_existing() {
        let mut c = RowCache::new(1, 1024);
        c.insert_arc(5, vec![5.0f32].into());
        assert_eq!((c.hits, c.misses), (0, 1));
        // Re-insert of a resident key: hit, existing row kept.
        c.insert_arc(5, vec![99.0f32].into());
        assert_eq!((c.hits, c.misses), (1, 1));
        assert_eq!(c.peek(5).unwrap(), &[5.0]);
    }

    /// Property: the cache behaves exactly like a reference implementation
    /// (hash map + recency queue) over random access traces.
    #[test]
    fn prop_matches_reference_lru() {
        check("lru-vs-reference", 30, |rng: &mut Pcg64| {
            let cap = 1 + rng.below(8);
            let keys = 1 + rng.below(16);
            let ops = 200;
            let mut cache = RowCache::new(1, cap * 4);
            let mut ref_order: Vec<usize> = Vec::new(); // front = MRU

            for _ in 0..ops {
                let k = rng.below(keys);
                let in_ref = ref_order.contains(&k);
                let mut filled = false;
                cache.get_or_compute(k, |r| {
                    filled = true;
                    r[0] = k as f32;
                });
                prop_assert!(
                    filled != in_ref,
                    "cache fill={filled} but reference contains={in_ref} for key {k}"
                );
                // update reference
                ref_order.retain(|&x| x != k);
                ref_order.insert(0, k);
                if ref_order.len() > cap {
                    ref_order.pop();
                }
                prop_assert!(
                    cache.len() == ref_order.len(),
                    "len {} != ref {}",
                    cache.len(),
                    ref_order.len()
                );
                for &rk in &ref_order {
                    prop_assert!(cache.contains(rk), "missing key {rk}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn hit_rate_math() {
        let mut c = RowCache::new(1, 1024);
        assert_eq!(c.hit_rate(), 0.0);
        c.get_or_compute(1, |r| r[0] = 0.0);
        c.get_or_compute(1, |r| r[0] = 0.0);
        c.get_or_compute(1, |r| r[0] = 0.0);
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }
}
