//! Byte-budgeted CLOCK (second-chance) cache of kernel-row segments — the
//! single-shard building block of [`super::sharded::ShardedRowCache`].
//!
//! v2 of the per-shard policy. The v1 cache was a fixed-row-length LRU;
//! two properties of the segment-granular kernel layer forced a redesign:
//!
//! - **Variable-length entries.** Keys are now `(row, segment)` composites
//!   (see [`super::context`]), and a segment row's length is the segment's
//!   column count — a cluster-aligned segment at k clusters is ~n/k long
//!   while a full-span row is n long. The budget is therefore tracked in
//!   **bytes actually resident**, not row slots.
//! - **Skewed reuse.** The solver hits free-SV rows every iteration and
//!   shrunk-variable rows never (paper Figure 2). Plain LRU evicts a hot SV
//!   row the moment a burst of one-shot rows sweeps through. CLOCK keeps a
//!   *referenced* bit per entry: the sweep hand clears the bit on first
//!   pass and evicts only entries that were not touched since the previous
//!   pass — one-bit frequency information at O(1) per access, no list
//!   surgery on the hit path.
//!
//! Entries are `Arc<[f32]>` so a caller can keep using a row after it has
//! been evicted (and so the sharded wrapper hands rows out across its shard
//! lock). Hit/miss counters feed EXPERIMENTS.md and the harness `Outcome`
//! structured fields.
//!
//! Budget invariant (property-tested here and in the sharded wrapper):
//! after any operation, `bytes_used() <= budget_bytes()` **or** the cache
//! holds exactly one entry (a single entry larger than the whole budget is
//! always admitted, mirroring v1's one-row-per-shard floor).

use std::collections::HashMap;
use std::sync::Arc;

struct Slot {
    key: u64,
    row: Arc<[f32]>,
    /// Second-chance bit: set on every access, cleared by the sweep hand.
    referenced: bool,
    live: bool,
}

/// CLOCK (second-chance) kernel-segment cache with a byte budget.
pub struct RowCache {
    map: HashMap<u64, usize>, // key -> slot index
    slots: Vec<Slot>,
    free: Vec<usize>,
    /// Sweep position of the CLOCK hand (index into `slots`).
    hand: usize,
    budget_bytes: usize,
    used_bytes: usize,
    pub hits: u64,
    pub misses: u64,
}

/// f32 payload bytes of one entry.
#[inline]
fn entry_bytes(row: &[f32]) -> usize {
    row.len() * 4
}

impl RowCache {
    /// `budget_bytes` is the f32 payload budget; one entry is always
    /// admitted even if it alone exceeds the budget.
    pub fn new(budget_bytes: usize) -> Self {
        RowCache {
            map: HashMap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            hand: 0,
            budget_bytes,
            used_bytes: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    pub fn bytes_used(&self) -> usize {
        self.used_bytes
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, key: u64) -> bool {
        self.map.contains_key(&key)
    }

    /// Retarget the byte budget (shard rebalancing), evicting down to the
    /// new budget immediately (the one-entry floor still applies).
    pub fn set_budget(&mut self, budget_bytes: usize) {
        self.budget_bytes = budget_bytes;
        while self.used_bytes > self.budget_bytes && self.map.len() > 1 {
            self.evict_one();
        }
    }

    /// Fetch an entry, computing and inserting it on miss. `len` is the
    /// entry length to allocate; `fill` writes the contents.
    pub fn get_arc_or_compute<F>(&mut self, key: u64, len: usize, fill: F) -> Arc<[f32]>
    where
        F: FnOnce(&mut [f32]),
    {
        if let Some(&slot) = self.map.get(&key) {
            self.hits += 1;
            self.slots[slot].referenced = true;
            return Arc::clone(&self.slots[slot].row);
        }
        self.misses += 1;
        let mut buf = vec![0f32; len];
        fill(&mut buf);
        let row: Arc<[f32]> = buf.into();
        self.insert_new(key, Arc::clone(&row));
        row
    }

    /// Probe half of a caller-batched fill: return the resident entry
    /// (recording a hit and setting its referenced bit), or record a miss
    /// and return `None`. The caller computes the missing entries in one
    /// batched dispatch and stores them with [`Self::put_arc`], which does
    /// **not** count again — together one probe+fill records exactly one
    /// hit or miss.
    pub fn get_arc(&mut self, key: u64) -> Option<Arc<[f32]>> {
        if let Some(&slot) = self.map.get(&key) {
            self.hits += 1;
            self.slots[slot].referenced = true;
            Some(Arc::clone(&self.slots[slot].row))
        } else {
            self.misses += 1;
            None
        }
    }

    /// Counter-free probe (sets the referenced bit on a find): the full-row
    /// *stitching* path uses it to consult sibling segment entries without
    /// perturbing the `hits + misses == probe calls` accounting contract.
    pub fn get_quiet(&mut self, key: u64) -> Option<Arc<[f32]>> {
        if let Some(&slot) = self.map.get(&key) {
            self.slots[slot].referenced = true;
            Some(Arc::clone(&self.slots[slot].row))
        } else {
            None
        }
    }

    /// Insert an entry whose miss was already recorded by [`Self::get_arc`];
    /// counters are left untouched. A resident key keeps its existing entry
    /// (contents are a pure function of the key) and is only re-referenced.
    pub fn put_arc(&mut self, key: u64, row: Arc<[f32]>) {
        if let Some(&slot) = self.map.get(&key) {
            self.slots[slot].referenced = true;
            return;
        }
        self.insert_new(key, row);
    }

    /// Insert an entry, **replacing** any resident one (counter-free, like
    /// [`Self::put_arc`]). The keep-existing policy of `put_arc` assumes
    /// entry contents are a pure function of the key; the serving layer's
    /// hot-swap path breaks that assumption on purpose (a model swap
    /// changes what a tagged entry under an unchanged key must contain),
    /// so it needs an overwrite primitive. Byte accounting follows the
    /// length change; the budget is re-enforced afterwards.
    pub fn replace_arc(&mut self, key: u64, row: Arc<[f32]>) {
        if let Some(&slot) = self.map.get(&key) {
            self.used_bytes -= entry_bytes(&self.slots[slot].row);
            self.used_bytes += entry_bytes(&row);
            self.slots[slot].row = row;
            self.slots[slot].referenced = true;
            while self.used_bytes > self.budget_bytes && self.map.len() > 1 {
                self.evict_one();
            }
            return;
        }
        self.insert_new(key, row);
    }

    /// Insert an externally computed entry (batched fill path). Counts a
    /// miss when the key is new — the caller did compute it — and a hit
    /// when already resident, in which case the existing entry is kept.
    pub fn insert_arc(&mut self, key: u64, row: Arc<[f32]>) {
        if let Some(&slot) = self.map.get(&key) {
            self.hits += 1;
            self.slots[slot].referenced = true;
            return;
        }
        self.misses += 1;
        self.insert_new(key, row);
    }

    /// Peek without touching the referenced bit or counters (tests).
    pub fn peek(&self, key: u64) -> Option<&[f32]> {
        self.map.get(&key).map(|&s| &*self.slots[s].row)
    }

    /// Drop all entries, keep slot allocation.
    pub fn clear(&mut self) {
        self.map.clear();
        self.free.clear();
        for (i, s) in self.slots.iter_mut().enumerate() {
            s.live = false;
            self.free.push(i);
        }
        self.used_bytes = 0;
        self.hand = 0;
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    // -- CLOCK plumbing ----------------------------------------------------

    /// Advance the hand to the next victim and evict it: a live entry whose
    /// referenced bit is clear; entries passed with the bit set get their
    /// second chance (bit cleared, skipped).
    fn evict_one(&mut self) {
        debug_assert!(!self.map.is_empty());
        loop {
            if self.hand >= self.slots.len() {
                self.hand = 0;
            }
            let s = self.hand;
            self.hand += 1;
            if !self.slots[s].live {
                continue;
            }
            if self.slots[s].referenced {
                self.slots[s].referenced = false;
                continue;
            }
            self.map.remove(&self.slots[s].key);
            self.used_bytes -= entry_bytes(&self.slots[s].row);
            self.slots[s].live = false;
            self.slots[s].row = Arc::from(Vec::<f32>::new());
            self.free.push(s);
            return;
        }
    }

    /// Insert a key known to be absent, evicting until the entry fits (or
    /// the cache is empty — the one-entry floor).
    fn insert_new(&mut self, key: u64, row: Arc<[f32]>) {
        let bytes = entry_bytes(&row);
        while self.used_bytes + bytes > self.budget_bytes && !self.map.is_empty() {
            self.evict_one();
        }
        self.used_bytes += bytes;
        let slot = Slot { key, row, referenced: true, live: true };
        let idx = if let Some(i) = self.free.pop() {
            self.slots[i] = slot;
            i
        } else {
            self.slots.push(slot);
            self.slots.len() - 1
        };
        self.map.insert(key, idx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::{prng::Pcg64, proptest::check};

    fn row(vals: &[f32]) -> Arc<[f32]> {
        Arc::from(vals)
    }

    #[test]
    fn hit_returns_cached_value() {
        let mut c = RowCache::new(1024);
        c.get_arc_or_compute(7, 4, |r| r.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]));
        let got = c.get_arc_or_compute(7, 4, |_| panic!("should not recompute"));
        assert_eq!(&*got, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn budget_is_byte_accurate_with_variable_lengths() {
        let mut c = RowCache::new(10 * 4); // 40 bytes = 10 f32s
        c.put_arc(0, row(&[0.0; 4])); // 16 bytes
        c.put_arc(1, row(&[1.0; 4])); // 32 bytes
        assert_eq!(c.bytes_used(), 32);
        assert_eq!(c.len(), 2);
        // A 3rd 4-long entry (would be 48 bytes) forces an eviction.
        c.put_arc(2, row(&[2.0; 4]));
        assert!(c.bytes_used() <= c.budget_bytes());
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn oversized_entry_admitted_alone() {
        let mut c = RowCache::new(4); // 1 f32 budget
        c.put_arc(1, row(&[1.0; 100]));
        assert_eq!(c.len(), 1);
        assert!(c.bytes_used() > c.budget_bytes());
        // The next insert evicts it (floor: exactly one entry resident).
        c.put_arc(2, row(&[2.0; 100]));
        assert_eq!(c.len(), 1);
        assert!(!c.contains(1));
        assert!(c.contains(2));
    }

    #[test]
    fn second_chance_protects_referenced_entries() {
        let mut c = RowCache::new(3 * 4); // room for 3 one-float entries
        for k in 0..3u64 {
            c.put_arc(k, row(&[k as f32]));
        }
        // Sweep once so every inserted entry's bit has been cleared, then
        // re-reference key 0 only.
        c.put_arc(3, row(&[3.0])); // evicts one of 0,1,2 after clearing bits
        assert_eq!(c.len(), 3);
        let survivor = (0..3u64).find(|&k| c.contains(k)).unwrap();
        assert!(c.get_quiet(survivor).is_some()); // referenced = true
        // Next eviction must pass over `survivor` (second chance) and take
        // the unreferenced newcomer's neighbor instead.
        c.put_arc(4, row(&[4.0]));
        assert!(
            c.contains(survivor),
            "referenced entry was evicted before unreferenced ones"
        );
    }

    #[test]
    fn set_budget_shrinks_immediately() {
        let mut c = RowCache::new(8 * 4);
        for k in 0..8u64 {
            c.put_arc(k, row(&[k as f32]));
        }
        assert_eq!(c.len(), 8);
        c.set_budget(3 * 4);
        assert!(c.bytes_used() <= 3 * 4);
        assert_eq!(c.len(), 3);
        // Growing back does not resurrect anything.
        c.set_budget(8 * 4);
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn clear_resets() {
        let mut c = RowCache::new(1024);
        c.put_arc(1, row(&[1.0, 2.0]));
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.bytes_used(), 0);
        let mut recomputed = false;
        c.get_arc_or_compute(1, 2, |_| recomputed = true);
        assert!(recomputed);
    }

    #[test]
    fn arc_rows_survive_eviction() {
        let mut c = RowCache::new(4); // one f32
        let first = c.get_arc_or_compute(10, 1, |r| r[0] = 10.0);
        c.get_arc_or_compute(11, 1, |r| r[0] = 11.0); // evicts key 10
        assert!(!c.contains(10));
        assert_eq!(first[0], 10.0); // handle still valid
    }

    #[test]
    fn get_arc_put_arc_count_once_per_probe() {
        let mut c = RowCache::new(1024);
        assert!(c.get_arc(3).is_none()); // miss recorded
        assert_eq!((c.hits, c.misses), (0, 1));
        c.put_arc(3, row(&[1.0, 2.0])); // quiet insert
        assert_eq!((c.hits, c.misses), (0, 1));
        let got = c.get_arc(3).expect("resident");
        assert_eq!(&*got, &[1.0, 2.0]);
        assert_eq!((c.hits, c.misses), (1, 1));
        // Quiet re-insert of a resident key keeps the existing entry.
        c.put_arc(3, row(&[9.0, 9.0]));
        assert_eq!(c.peek(3).unwrap(), &[1.0, 2.0]);
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn get_quiet_finds_without_counting() {
        let mut c = RowCache::new(1024);
        assert!(c.get_quiet(5).is_none());
        c.put_arc(5, row(&[5.0]));
        assert_eq!(&*c.get_quiet(5).unwrap(), &[5.0]);
        assert_eq!((c.hits, c.misses), (0, 0));
    }

    #[test]
    fn replace_arc_overwrites_and_tracks_bytes() {
        let mut c = RowCache::new(1024);
        c.put_arc(3, row(&[1.0, 2.0]));
        assert_eq!(c.bytes_used(), 8);
        c.replace_arc(3, row(&[9.0, 8.0, 7.0]));
        assert_eq!(c.peek(3).unwrap(), &[9.0, 8.0, 7.0]);
        assert_eq!(c.bytes_used(), 12);
        assert_eq!((c.hits, c.misses), (0, 0)); // counter-free, like put_arc
        // Absent key behaves like a plain insert.
        c.replace_arc(4, row(&[4.0]));
        assert_eq!(c.peek(4).unwrap(), &[4.0]);
        // Growing a resident entry past the budget re-enforces it.
        let mut small = RowCache::new(3 * 4);
        small.put_arc(0, row(&[0.0]));
        small.put_arc(1, row(&[1.0]));
        small.replace_arc(0, row(&[5.0, 5.0, 5.0]));
        assert!(small.bytes_used() <= small.budget_bytes() || small.len() == 1);
        assert_eq!(small.peek(0).map(|r| r.len()), Some(3).filter(|_| small.contains(0)));
    }

    #[test]
    fn insert_arc_counts_and_keeps_existing() {
        let mut c = RowCache::new(1024);
        c.insert_arc(5, row(&[5.0]));
        assert_eq!((c.hits, c.misses), (0, 1));
        c.insert_arc(5, row(&[99.0]));
        assert_eq!((c.hits, c.misses), (1, 1));
        assert_eq!(c.peek(5).unwrap(), &[5.0]);
    }

    /// Property: over random mixed-length traces the byte-budget invariant
    /// holds after every operation, resident entries always return the
    /// value their key demands, and counters add up.
    #[test]
    fn prop_budget_and_contents_random_traces() {
        check("clock-budget", 30, |rng: &mut Pcg64| {
            let budget = (1 + rng.below(64)) * 4;
            let keys = 1 + rng.below(24) as u64;
            let max_len = 1 + rng.below(12);
            let mut c = RowCache::new(budget);
            let mut probes = 0u64;
            for _ in 0..300 {
                let k = rng.below(keys as usize) as u64;
                let len = 1 + (k as usize) % max_len;
                let got = c.get_arc_or_compute(k, len, |r| r.fill(k as f32));
                probes += 1;
                prop_assert!(
                    got.len() == len && got.iter().all(|&v| v == k as f32),
                    "wrong contents for key {k}"
                );
                prop_assert!(
                    c.bytes_used() <= c.budget_bytes() || c.len() == 1,
                    "budget violated: {} bytes > {} with {} entries",
                    c.bytes_used(),
                    c.budget_bytes(),
                    c.len()
                );
                let resident: usize =
                    (0..keys).filter_map(|k| c.peek(k).map(|r| r.len() * 4)).sum();
                prop_assert!(
                    resident == c.bytes_used(),
                    "bytes_used {} out of sync with resident {}",
                    c.bytes_used(),
                    resident
                );
            }
            prop_assert!(
                c.hits + c.misses == probes,
                "hits {} + misses {} != probes {probes}",
                c.hits,
                c.misses
            );
            Ok(())
        });
    }

    #[test]
    fn hit_rate_math() {
        let mut c = RowCache::new(1024);
        assert_eq!(c.hit_rate(), 0.0);
        for _ in 0..3 {
            c.get_arc_or_compute(1, 1, |r| r[0] = 0.0);
        }
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }
}
