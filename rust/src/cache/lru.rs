//! Byte-budgeted LRU cache of kernel rows.
//!
//! Keys are row indices of the *active problem* (a cluster subproblem or the
//! whole dataset); values are `Box<[f32]>` rows of length `row_len`. The LRU
//! order lives in an intrusive doubly-linked list over slot indices so
//! touch/evict are O(1), and `get_or_compute` exposes the fill path the
//! solver uses. Hit/miss counters feed EXPERIMENTS.md §Perf.

use std::collections::HashMap;

const NIL: usize = usize::MAX;

struct Slot {
    key: usize,
    row: Box<[f32]>,
    prev: usize,
    next: usize,
}

/// LRU kernel-row cache with a fixed byte budget.
pub struct RowCache {
    map: HashMap<usize, usize>, // key -> slot index
    slots: Vec<Slot>,
    free: Vec<usize>,
    head: usize, // most recently used
    tail: usize, // least recently used
    row_len: usize,
    capacity_rows: usize,
    pub hits: u64,
    pub misses: u64,
}

impl RowCache {
    /// `budget_bytes` is the total f32 payload budget; at least one row is
    /// always allowed.
    pub fn new(row_len: usize, budget_bytes: usize) -> Self {
        let capacity_rows = (budget_bytes / (row_len.max(1) * 4)).max(1);
        RowCache {
            map: HashMap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            row_len,
            capacity_rows,
            hits: 0,
            misses: 0,
        }
    }

    pub fn row_len(&self) -> usize {
        self.row_len
    }

    pub fn capacity_rows(&self) -> usize {
        self.capacity_rows
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, key: usize) -> bool {
        self.map.contains_key(&key)
    }

    /// Fetch a row, computing and inserting it on miss. `fill` writes the
    /// row contents into the provided buffer.
    pub fn get_or_compute<F>(&mut self, key: usize, fill: F) -> &[f32]
    where
        F: FnOnce(&mut [f32]),
    {
        if let Some(&slot) = self.map.get(&key) {
            self.hits += 1;
            self.touch(slot);
            return &self.slots[slot].row;
        }
        self.misses += 1;
        let slot = self.insert_slot(key);
        fill(&mut self.slots[slot].row);
        &self.slots[slot].row
    }

    /// Peek without changing LRU order or counters (used by tests).
    pub fn peek(&self, key: usize) -> Option<&[f32]> {
        self.map.get(&key).map(|&s| &*self.slots[s].row)
    }

    /// Drop all entries, keep allocation.
    pub fn clear(&mut self) {
        self.map.clear();
        for i in 0..self.slots.len() {
            self.free.push(i);
        }
        self.head = NIL;
        self.tail = NIL;
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    // -- intrusive list plumbing -------------------------------------------

    fn detach(&mut self, slot: usize) {
        let (p, n) = (self.slots[slot].prev, self.slots[slot].next);
        if p != NIL {
            self.slots[p].next = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.slots[n].prev = p;
        } else {
            self.tail = p;
        }
    }

    fn push_front(&mut self, slot: usize) {
        self.slots[slot].prev = NIL;
        self.slots[slot].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = slot;
        }
        self.head = slot;
        if self.tail == NIL {
            self.tail = slot;
        }
    }

    fn touch(&mut self, slot: usize) {
        if self.head == slot {
            return;
        }
        self.detach(slot);
        self.push_front(slot);
    }

    fn insert_slot(&mut self, key: usize) -> usize {
        let slot = if self.map.len() >= self.capacity_rows {
            // Evict LRU.
            let victim = self.tail;
            debug_assert_ne!(victim, NIL);
            self.detach(victim);
            self.map.remove(&self.slots[victim].key);
            self.slots[victim].key = key;
            victim
        } else if let Some(s) = self.free.pop() {
            self.slots[s].key = key;
            s
        } else {
            self.slots.push(Slot {
                key,
                row: vec![0f32; self.row_len].into_boxed_slice(),
                prev: NIL,
                next: NIL,
            });
            self.slots.len() - 1
        };
        self.push_front(slot);
        self.map.insert(key, slot);
        slot
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::{prng::Pcg64, proptest::check};

    #[test]
    fn hit_returns_cached_value() {
        let mut c = RowCache::new(4, 1024);
        c.get_or_compute(7, |r| r.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]));
        let row = c.get_or_compute(7, |_| panic!("should not recompute"));
        assert_eq!(row, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn evicts_lru_not_mru() {
        let mut c = RowCache::new(1, 3 * 4); // capacity 3 rows
        for k in 0..3 {
            c.get_or_compute(k, |r| r[0] = k as f32);
        }
        c.get_or_compute(0, |_| panic!("0 cached")); // touch 0 -> MRU
        c.get_or_compute(3, |r| r[0] = 3.0); // evicts 1 (LRU)
        assert!(c.contains(0));
        assert!(!c.contains(1));
        assert!(c.contains(2));
        assert!(c.contains(3));
    }

    #[test]
    fn capacity_at_least_one() {
        let mut c = RowCache::new(1000, 1); // budget below one row
        assert_eq!(c.capacity_rows(), 1);
        c.get_or_compute(1, |r| r[0] = 1.0);
        c.get_or_compute(2, |r| r[0] = 2.0);
        assert!(!c.contains(1));
        assert!(c.contains(2));
    }

    #[test]
    fn clear_resets() {
        let mut c = RowCache::new(2, 1024);
        c.get_or_compute(1, |r| r[0] = 1.0);
        c.clear();
        assert!(c.is_empty());
        let mut recomputed = false;
        c.get_or_compute(1, |_| recomputed = true);
        assert!(recomputed);
    }

    /// Property: the cache behaves exactly like a reference implementation
    /// (hash map + recency queue) over random access traces.
    #[test]
    fn prop_matches_reference_lru() {
        check("lru-vs-reference", 30, |rng: &mut Pcg64| {
            let cap = 1 + rng.below(8);
            let keys = 1 + rng.below(16);
            let ops = 200;
            let mut cache = RowCache::new(1, cap * 4);
            let mut ref_order: Vec<usize> = Vec::new(); // front = MRU

            for _ in 0..ops {
                let k = rng.below(keys);
                let in_ref = ref_order.contains(&k);
                let mut filled = false;
                cache.get_or_compute(k, |r| {
                    filled = true;
                    r[0] = k as f32;
                });
                prop_assert!(
                    filled != in_ref,
                    "cache fill={filled} but reference contains={in_ref} for key {k}"
                );
                // update reference
                ref_order.retain(|&x| x != k);
                ref_order.insert(0, k);
                if ref_order.len() > cap {
                    ref_order.pop();
                }
                prop_assert!(
                    cache.len() == ref_order.len(),
                    "len {} != ref {}",
                    cache.len(),
                    ref_order.len()
                );
                for &rk in &ref_order {
                    prop_assert!(cache.contains(rk), "missing key {rk}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn hit_rate_math() {
        let mut c = RowCache::new(1, 1024);
        assert_eq!(c.hit_rate(), 0.0);
        c.get_or_compute(1, |r| r[0] = 0.0);
        c.get_or_compute(1, |r| r[0] = 0.0);
        c.get_or_compute(1, |r| r[0] = 0.0);
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }
}
