//! Thread-safe sharded byte-budgeted CLOCK cache of kernel-row segments.
//!
//! One [`super::KernelContext`] owns one of these for its dataset; keys are
//! 64-bit **(segment, row)** composites (see `super::context::seg_key`) and
//! values are `Arc<[f32]>` segment rows — full dataset-length rows for the
//! full-span segment, cluster-length partial rows for divide-phase
//! segments. The serving layer reuses the same type with content
//! fingerprints as keys. Each shard is an independently locked
//! [`RowCache`] (CLOCK second-chance, byte-budgeted) and a key maps to
//! shard `key % k` — row indices occupy the low key bits, so adjacent rows
//! (which cluster subproblems touch together) spread across shards and
//! concurrent subproblem solves rarely contend.
//!
//! **Budget rebalancing.** The total byte budget starts evenly split, but
//! skewed access (a hot cluster hammering one shard while another idles)
//! wastes budget on cold shards. Every `REBALANCE_OPS` counted
//! operations, per-shard miss deltas since the previous rebalance reweight
//! the split: shard i gets `total · (1 + missesΔ_i) / Σ(1 + missesΔ)`,
//! floored at a quarter of the even share, then scaled so the shard
//! budgets never sum above the configured total. Shards over their new
//! budget evict down immediately.
//!
//! Concurrency contract:
//! - `get_or_compute` holds the owning shard's lock across the fill, so a
//!   given key is computed at most once; concurrent requests for the same
//!   key serialize and all but the first hit.
//! - Returned rows are `Arc` handles: they stay valid after eviction, so no
//!   lock is held while a caller consumes a row.
//! - Counters are maintained per shard under its lock; `stats()` aggregates,
//!   and `hits + misses` exactly equals the number of counting calls
//!   (`get_or_compute`/`insert_computed`/`get` — quiet probes and `put` are
//!   excluded), property-tested below under concurrent `scope_map` workers.
//! - Rebalancing locks one shard at a time (never two), so it cannot
//!   deadlock against fills or against a concurrent rebalance attempt
//!   (excluded via an atomic flag).

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::lru::RowCache;

/// Counted operations between budget rebalances.
const REBALANCE_OPS: u64 = 8192;

/// Aggregated hit/miss counters of a sharded cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn total(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.hits as f64 / self.total() as f64
        }
    }

    /// Counter deltas since an earlier snapshot (per-solve attribution).
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
        }
    }
}

/// Per-shard snapshot (diagnostics + budget-invariant tests).
#[derive(Clone, Copy, Debug)]
pub struct ShardInfo {
    pub entries: usize,
    pub bytes_used: usize,
    pub budget_bytes: usize,
    pub hits: u64,
    pub misses: u64,
}

/// Sharded thread-safe CLOCK segment cache with a global byte budget and
/// periodic hot/cold budget rebalancing.
pub struct ShardedRowCache {
    shards: Vec<Mutex<RowCache>>,
    /// Configured total byte budget (shard budgets never sum above it).
    total_budget: usize,
    /// Smallest current per-shard budget (lock-free read for the solver's
    /// prefetch cap; updated on rebalance).
    min_shard_budget: AtomicUsize,
    /// Counted operations since construction (rebalance trigger).
    ops: AtomicU64,
    /// Rebalance cadence in counted operations; 0 disables rebalancing.
    rebalance_every: u64,
    /// At most one rebalance runs at a time.
    rebalancing: AtomicBool,
    /// Per-shard miss counts at the previous rebalance.
    last_misses: Mutex<Vec<u64>>,
}

impl ShardedRowCache {
    /// `budget_bytes` is the total f32 payload budget, split evenly across
    /// `shards` to start; rebalancing reweights the split every
    /// `REBALANCE_OPS` operations.
    pub fn new(budget_bytes: usize, shards: usize) -> Self {
        Self::with_rebalance_interval(budget_bytes, shards, REBALANCE_OPS)
    }

    /// Like [`Self::new`] with an explicit rebalance cadence (tests);
    /// `rebalance_every == 0` disables rebalancing.
    pub fn with_rebalance_interval(
        budget_bytes: usize,
        shards: usize,
        rebalance_every: u64,
    ) -> Self {
        let shards_n = shards.max(1);
        let per_shard = budget_bytes / shards_n;
        let shards: Vec<Mutex<RowCache>> =
            (0..shards_n).map(|_| Mutex::new(RowCache::new(per_shard))).collect();
        ShardedRowCache {
            shards,
            total_budget: budget_bytes,
            min_shard_budget: AtomicUsize::new(per_shard),
            ops: AtomicU64::new(0),
            rebalance_every,
            rebalancing: AtomicBool::new(false),
            last_misses: Mutex::new(vec![0; shards_n]),
        }
    }

    #[inline]
    fn shard(&self, key: u64) -> &Mutex<RowCache> {
        &self.shards[(key % self.shards.len() as u64) as usize]
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Configured total byte budget. Constant after construction;
    /// lock-free.
    pub fn budget_bytes(&self) -> usize {
        self.total_budget
    }

    /// Smallest current per-shard byte budget (prefetch sizing); lock-free.
    pub fn min_shard_budget_bytes(&self) -> usize {
        self.min_shard_budget.load(Ordering::Relaxed)
    }

    /// Payload bytes currently resident across shards.
    pub fn bytes_used(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().bytes_used())
            .sum()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.lock().unwrap().is_empty())
    }

    /// Residency probe; does not touch CLOCK state or counters.
    pub fn contains(&self, key: u64) -> bool {
        self.shard(key).lock().unwrap().contains(key)
    }

    /// Fetch an entry of length `len`, computing it under the shard lock on
    /// miss. Exactly one hit or miss is recorded per call.
    pub fn get_or_compute<F>(&self, key: u64, len: usize, fill: F) -> Arc<[f32]>
    where
        F: FnOnce(&mut [f32]),
    {
        let row = self.shard(key).lock().unwrap().get_arc_or_compute(key, len, fill);
        self.count_op();
        row
    }

    /// Insert an entry computed outside the lock (batched dispatch path).
    /// Records a miss when the key is new, a hit when already resident (the
    /// resident entry is kept — contents are a pure function of the key).
    pub fn insert_computed(&self, key: u64, row: &[f32]) {
        self.shard(key).lock().unwrap().insert_arc(key, Arc::from(row));
        self.count_op();
    }

    /// Probe for a resident entry: a hit (plus a CLOCK touch) returns the
    /// handle, absence records a miss and returns `None`. Pair with
    /// [`Self::put`] for caller-batched fills — the probe counts, the store
    /// does not, so one probe+fill records exactly one hit or miss (the
    /// serving path's contract; see `serving`).
    pub fn get(&self, key: u64) -> Option<Arc<[f32]>> {
        let row = self.shard(key).lock().unwrap().get_arc(key);
        self.count_op();
        row
    }

    /// Counter-free probe (still sets the entry's referenced bit): the
    /// full-row stitching path consults sibling segment entries with it.
    pub fn get_quiet(&self, key: u64) -> Option<Arc<[f32]>> {
        self.shard(key).lock().unwrap().get_quiet(key)
    }

    /// Store an entry whose miss was already recorded by [`Self::get`];
    /// counters unchanged. A resident key keeps its existing entry.
    pub fn put(&self, key: u64, row: Arc<[f32]>) {
        self.shard(key).lock().unwrap().put_arc(key, row);
    }

    /// Store an entry, **replacing** any resident one (counter-free). The
    /// keep-existing policy of [`Self::put`] assumes contents are a pure
    /// function of the key; the serving hot-swap path overwrites stale
    /// entries whose model block changed under an unchanged key, so it
    /// needs this overwrite primitive.
    pub fn put_replace(&self, key: u64, row: Arc<[f32]>) {
        self.shard(key).lock().unwrap().replace_arc(key, row);
    }

    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for shard in &self.shards {
            let c = shard.lock().unwrap();
            s.hits += c.hits;
            s.misses += c.misses;
        }
        s
    }

    /// Per-shard snapshots (diagnostics; budget-invariant tests).
    pub fn shard_infos(&self) -> Vec<ShardInfo> {
        self.shards
            .iter()
            .map(|s| {
                let c = s.lock().unwrap();
                ShardInfo {
                    entries: c.len(),
                    bytes_used: c.bytes_used(),
                    budget_bytes: c.budget_bytes(),
                    hits: c.hits,
                    misses: c.misses,
                }
            })
            .collect()
    }

    pub fn hit_rate(&self) -> f64 {
        self.stats().hit_rate()
    }

    pub fn clear(&self) {
        for shard in &self.shards {
            shard.lock().unwrap().clear();
        }
    }

    /// Count one operation toward the rebalance cadence and run a
    /// rebalance when due (at most one at a time; shards are locked one at
    /// a time, never nested).
    fn count_op(&self) {
        if self.rebalance_every == 0 || self.shards.len() < 2 {
            return;
        }
        let n = self.ops.fetch_add(1, Ordering::Relaxed) + 1;
        if n % self.rebalance_every != 0 {
            return;
        }
        if self.rebalancing.swap(true, Ordering::Acquire) {
            return; // another thread is already rebalancing
        }
        self.rebalance();
        self.rebalancing.store(false, Ordering::Release);
    }

    /// Reweight shard budgets by miss pressure since the last rebalance.
    fn rebalance(&self) {
        let k = self.shards.len();
        let mut misses = Vec::with_capacity(k);
        for s in &self.shards {
            misses.push(s.lock().unwrap().misses);
        }
        let mut last = self.last_misses.lock().unwrap();
        let deltas: Vec<u64> = misses
            .iter()
            .zip(last.iter())
            .map(|(&m, &l)| m.saturating_sub(l))
            .collect();
        last.clone_from(&misses);
        drop(last);

        let even = (self.total_budget / k).max(1);
        let floor = (even / 4).max(1);
        let sum_w: u128 = deltas.iter().map(|&d| 1 + d as u128).sum();
        let mut budgets: Vec<usize> = deltas
            .iter()
            .map(|&d| {
                let raw = (self.total_budget as u128 * (1 + d as u128) / sum_w) as usize;
                raw.max(floor)
            })
            .collect();
        // The floor can push the sum above the configured total; scale the
        // whole vector back down so shard budgets never sum above it.
        let sum_b: u128 = budgets.iter().map(|&b| b as u128).sum();
        if sum_b > self.total_budget as u128 && sum_b > 0 {
            for b in budgets.iter_mut() {
                *b = ((*b as u128 * self.total_budget as u128 / sum_b) as usize).max(1);
            }
        }
        let mut min_budget = usize::MAX;
        for (shard, &b) in self.shards.iter().zip(&budgets) {
            shard.lock().unwrap().set_budget(b);
            min_budget = min_budget.min(b);
        }
        self.min_shard_budget.store(min_budget, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prng::Pcg64;
    use crate::util::proptest::check;
    use crate::util::threadpool::scope_map;

    #[test]
    fn basic_get_insert_and_budget() {
        // 4 one-float entries total, 2 shards.
        let c = ShardedRowCache::new(4 * 4, 2);
        for k in 0..8u64 {
            let row = c.get_or_compute(k, 2, |r| r.fill(k as f32));
            assert_eq!(&*row, &[k as f32, k as f32]);
        }
        assert!(c.bytes_used() <= c.budget_bytes());
        let s = c.stats();
        assert_eq!(s.misses, 8); // 8 distinct keys, all cold
        assert_eq!(s.hits, 0);
        // Re-fetch of the most recent key per shard must hit.
        c.get_or_compute(6, 2, |_| panic!("6 must be resident"));
        c.get_or_compute(7, 2, |_| panic!("7 must be resident"));
        assert_eq!(c.stats().hits, 2);
    }

    #[test]
    fn insert_computed_then_get_hits() {
        let c = ShardedRowCache::new(1 << 20, 4);
        c.insert_computed(11, &[1.0, 2.0, 3.0]);
        assert!(c.contains(11));
        let row = c.get_or_compute(11, 3, |_| panic!("resident"));
        assert_eq!(&*row, &[1.0, 2.0, 3.0]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn get_put_probe_then_fill_counts_once() {
        let c = ShardedRowCache::new(1 << 20, 4);
        assert!(c.get(9).is_none());
        c.put(9, vec![1.0f32, 2.0].into());
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (0, 1)); // put is quiet
        let row = c.get(9).expect("resident");
        assert_eq!(&*row, &[1.0, 2.0]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        // put on a resident key keeps the first row.
        c.put(9, vec![7.0f32, 7.0].into());
        assert_eq!(&*c.get(9).unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn get_quiet_does_not_count() {
        let c = ShardedRowCache::new(1 << 20, 2);
        assert!(c.get_quiet(3).is_none());
        c.put(3, vec![3.0f32].into());
        assert_eq!(&*c.get_quiet(3).unwrap(), &[3.0]);
        assert_eq!(c.stats(), CacheStats::default());
    }

    #[test]
    fn put_replace_overwrites_resident_entry() {
        let c = ShardedRowCache::new(1 << 20, 2);
        c.put(3, vec![1.0f32, 2.0].into());
        c.put(3, vec![9.0f32, 9.0].into()); // keep-existing policy
        assert_eq!(&*c.get_quiet(3).unwrap(), &[1.0, 2.0]);
        c.put_replace(3, vec![9.0f32, 8.0, 7.0].into());
        assert_eq!(&*c.get_quiet(3).unwrap(), &[9.0, 8.0, 7.0]);
        assert_eq!(c.stats(), CacheStats::default()); // counter-free
    }

    #[test]
    fn stats_since_snapshot() {
        let c = ShardedRowCache::new(1 << 10, 2);
        c.get_or_compute(0, 1, |r| r[0] = 0.0);
        let snap = c.stats();
        c.get_or_compute(0, 1, |_| panic!("resident"));
        c.get_or_compute(1, 1, |r| r[0] = 1.0);
        let d = c.stats().since(&snap);
        assert_eq!((d.hits, d.misses), (1, 1));
    }

    #[test]
    fn rebalance_moves_budget_toward_miss_pressure() {
        // 2 shards, rebalance every 64 counted ops. Keys are chosen so all
        // traffic lands on shard 1 (odd keys): its miss pressure must earn
        // it more than the even split after a rebalance.
        let c = ShardedRowCache::with_rebalance_interval(1 << 16, 2, 64);
        let even = (1 << 16) / 2;
        let mut key = 1u64;
        for _ in 0..256 {
            c.get_or_compute(key, 4, |r| r.fill(0.5));
            key += 2; // stays odd -> shard 1
        }
        let infos = c.shard_infos();
        assert!(
            infos[1].budget_bytes > even,
            "hot shard budget {} not above even split {even}",
            infos[1].budget_bytes
        );
        assert!(
            infos[0].budget_bytes < even,
            "cold shard budget {} not below even split {even}",
            infos[0].budget_bytes
        );
        // Global budget conserved.
        let total: usize = infos.iter().map(|i| i.budget_bytes).sum();
        assert!(total <= c.budget_bytes(), "budgets sum {total} over configured");
        assert_eq!(c.min_shard_budget_bytes(), infos[0].budget_bytes);
    }

    /// Property (ISSUE satellite): under concurrent `get_or_compute` from
    /// `scope_map` workers — with rebalancing forced on a short cadence —
    /// every returned row holds the value its key demands, hits + misses
    /// equals the exact number of calls, and every shard obeys the CLOCK
    /// byte-budget invariant (bytes ≤ budget, or a single oversized
    /// entry).
    #[test]
    fn prop_concurrent_budget_and_counters() {
        check("sharded-concurrent", 10, |rng: &mut Pcg64| {
            let row_len = 1 + rng.below(8);
            let cap_rows = 1 + rng.below(24);
            let shards = 1 + rng.below(8);
            let threads = 2 + rng.below(6);
            let keys = 1 + rng.below(48);
            let ops_per_worker = 200usize;
            let cache = ShardedRowCache::with_rebalance_interval(
                cap_rows * row_len * 4,
                shards,
                64,
            );

            let seeds: Vec<u64> = (0..threads).map(|_| rng.next_u64()).collect();
            let cache_ref = &cache;
            let ok_counts: Vec<usize> = scope_map(threads, seeds, |_, seed| {
                let mut r = Pcg64::new(seed);
                let mut ok = 0usize;
                for _ in 0..ops_per_worker {
                    let k = r.below(keys) as u64;
                    let row = cache_ref.get_or_compute(k, row_len, |buf| {
                        buf.fill(k as f32)
                    });
                    if row.len() == row_len && row.iter().all(|&v| v == k as f32) {
                        ok += 1;
                    }
                }
                ok
            });

            let total_ops = (threads * ops_per_worker) as u64;
            prop_assert!(
                ok_counts.iter().sum::<usize>() as u64 == total_ops,
                "some rows held wrong contents"
            );
            let s = cache.stats();
            prop_assert!(
                s.hits + s.misses == total_ops,
                "hits {} + misses {} != ops {total_ops}",
                s.hits,
                s.misses
            );
            for (i, info) in cache.shard_infos().iter().enumerate() {
                prop_assert!(
                    info.bytes_used <= info.budget_bytes || info.entries == 1,
                    "shard {i} budget violated: {} bytes > {} with {} entries",
                    info.bytes_used,
                    info.budget_bytes,
                    info.entries
                );
            }
            // Every resident entry must have been computed at least once.
            prop_assert!(
                s.misses >= cache.len() as u64,
                "misses {} < resident entries {}",
                s.misses,
                cache.len()
            );
            Ok(())
        });
    }

    /// Same-key contention: concurrent workers hammering ONE key must
    /// compute it exactly once (fill serializes under the shard lock).
    #[test]
    fn concurrent_same_key_computes_once() {
        use std::sync::atomic::AtomicUsize;
        let cache = ShardedRowCache::new(1 << 20, 8);
        let fills = AtomicUsize::new(0);
        let (cache_ref, fills_ref) = (&cache, &fills);
        scope_map(8, (0..64).collect::<Vec<u32>>(), |_, _| {
            let row = cache_ref.get_or_compute(3, 4, |buf| {
                fills_ref.fetch_add(1, Ordering::Relaxed);
                buf.fill(3.0);
            });
            assert_eq!(&*row, &[3.0; 4]);
        });
        assert_eq!(fills.load(Ordering::Relaxed), 1);
        let s = cache.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 63);
    }
}
