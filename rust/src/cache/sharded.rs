//! Thread-safe sharded byte-budgeted LRU kernel-row cache.
//!
//! One [`super::KernelContext`] owns one of these for its dataset; keys are
//! **global row indices**, values are full kernel rows (`Arc<[f32]>` of
//! length n). The byte budget is split evenly across shards, each an
//! independently locked [`RowCache`], and a key maps to shard `key % k` —
//! global row indices are dense integers, so adjacent keys (which cluster
//! subproblems touch together) spread across shards and concurrent
//! subproblem solves rarely contend.
//!
//! Concurrency contract:
//! - `get_or_compute` holds the owning shard's lock across the fill, so a
//!   given key is computed at most once; concurrent requests for the same
//!   key serialize and all but the first hit.
//! - Returned rows are `Arc` handles: they stay valid after eviction, so no
//!   lock is held while a caller consumes a row.
//! - Counters are maintained per shard under its lock; `stats()` aggregates,
//!   and `hits + misses` exactly equals the number of
//!   `get_or_compute`/`insert_computed` calls (property-tested below under
//!   concurrent access from `scope_map` workers).

use std::sync::{Arc, Mutex};

use super::lru::RowCache;

/// Aggregated hit/miss counters of a sharded cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn total(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.hits as f64 / self.total() as f64
        }
    }

    /// Counter deltas since an earlier snapshot (per-solve attribution).
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
        }
    }
}

/// Sharded thread-safe LRU row cache with a global byte budget.
pub struct ShardedRowCache {
    shards: Vec<Mutex<RowCache>>,
    row_len: usize,
    /// Total row capacity across shards, fixed at construction (hot-path
    /// readers like the solver's prefetch cap read it lock-free).
    capacity_rows: usize,
}

impl ShardedRowCache {
    /// `budget_bytes` is the total f32 payload budget, split evenly across
    /// `shards`; each shard always admits at least one row.
    pub fn new(row_len: usize, budget_bytes: usize, shards: usize) -> Self {
        let shards_n = shards.max(1);
        let per_shard = budget_bytes / shards_n;
        let shards: Vec<Mutex<RowCache>> = (0..shards_n)
            .map(|_| Mutex::new(RowCache::new(row_len, per_shard)))
            .collect();
        let capacity_rows = shards
            .iter()
            .map(|s| s.lock().unwrap().capacity_rows())
            .sum();
        ShardedRowCache { shards, row_len, capacity_rows }
    }

    #[inline]
    fn shard(&self, key: usize) -> &Mutex<RowCache> {
        &self.shards[key % self.shards.len()]
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn row_len(&self) -> usize {
        self.row_len
    }

    /// Total row capacity across shards (the byte budget in rows, with the
    /// one-row-per-shard floor). Constant after construction; lock-free.
    pub fn capacity_rows(&self) -> usize {
        self.capacity_rows
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Residency probe; does not touch LRU order or counters.
    pub fn contains(&self, key: usize) -> bool {
        self.shard(key).lock().unwrap().contains(key)
    }

    /// Fetch a row, computing it under the shard lock on miss. Exactly one
    /// hit or miss is recorded per call.
    pub fn get_or_compute<F>(&self, key: usize, fill: F) -> Arc<[f32]>
    where
        F: FnOnce(&mut [f32]),
    {
        self.shard(key).lock().unwrap().get_arc_or_compute(key, fill)
    }

    /// Insert a row computed outside the lock (batched dispatch path).
    /// Records a miss when the key is new, a hit when already resident (the
    /// resident row is kept — row contents are a pure function of the key).
    pub fn insert_computed(&self, key: usize, row: &[f32]) {
        self.shard(key).lock().unwrap().insert_arc(key, Arc::from(row));
    }

    /// Probe for a resident row: a hit (plus LRU touch) returns the handle,
    /// absence records a miss and returns `None`. Pair with [`Self::put`]
    /// for caller-batched fills — the probe counts, the store does not, so
    /// one probe+fill records exactly one hit or miss (the serving path's
    /// contract; see `serving`).
    pub fn get(&self, key: usize) -> Option<Arc<[f32]>> {
        self.shard(key).lock().unwrap().get_arc(key)
    }

    /// Store a row whose miss was already recorded by [`Self::get`];
    /// counters unchanged. A resident key keeps its existing row.
    pub fn put(&self, key: usize, row: Arc<[f32]>) {
        self.shard(key).lock().unwrap().put_arc(key, row);
    }

    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for shard in &self.shards {
            let c = shard.lock().unwrap();
            s.hits += c.hits;
            s.misses += c.misses;
        }
        s
    }

    pub fn hit_rate(&self) -> f64 {
        self.stats().hit_rate()
    }

    pub fn clear(&self) {
        for shard in &self.shards {
            shard.lock().unwrap().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prng::Pcg64;
    use crate::util::proptest::check;
    use crate::util::threadpool::scope_map;

    #[test]
    fn basic_get_insert_and_budget() {
        let c = ShardedRowCache::new(2, 4 * 2 * 4, 2); // 4 rows total, 2 shards
        assert_eq!(c.capacity_rows(), 4);
        for k in 0..8 {
            let row = c.get_or_compute(k, |r| r.fill(k as f32));
            assert_eq!(&*row, &[k as f32, k as f32]);
        }
        assert!(c.len() <= c.capacity_rows());
        let s = c.stats();
        assert_eq!(s.misses, 8); // 8 distinct keys, all cold
        assert_eq!(s.hits, 0);
        // Re-fetch of the most recent key per shard must hit.
        c.get_or_compute(6, |_| panic!("6 must be resident"));
        c.get_or_compute(7, |_| panic!("7 must be resident"));
        assert_eq!(c.stats().hits, 2);
    }

    #[test]
    fn insert_computed_then_get_hits() {
        let c = ShardedRowCache::new(3, 1 << 20, 4);
        c.insert_computed(11, &[1.0, 2.0, 3.0]);
        assert!(c.contains(11));
        let row = c.get_or_compute(11, |_| panic!("resident"));
        assert_eq!(&*row, &[1.0, 2.0, 3.0]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn get_put_probe_then_fill_counts_once() {
        let c = ShardedRowCache::new(2, 1 << 20, 4);
        assert!(c.get(9).is_none());
        c.put(9, vec![1.0f32, 2.0].into());
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (0, 1)); // put is quiet
        let row = c.get(9).expect("resident");
        assert_eq!(&*row, &[1.0, 2.0]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        // put on a resident key keeps the first row.
        c.put(9, vec![7.0f32, 7.0].into());
        assert_eq!(&*c.get(9).unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn stats_since_snapshot() {
        let c = ShardedRowCache::new(1, 1 << 10, 2);
        c.get_or_compute(0, |r| r[0] = 0.0);
        let snap = c.stats();
        c.get_or_compute(0, |_| panic!("resident"));
        c.get_or_compute(1, |r| r[0] = 1.0);
        let d = c.stats().since(&snap);
        assert_eq!((d.hits, d.misses), (1, 1));
    }

    /// Property (ISSUE satellite): under concurrent `get_or_compute` from
    /// `scope_map` workers, the byte budget holds, every returned row holds
    /// the value its key demands, and hits + misses equals the exact number
    /// of calls.
    #[test]
    fn prop_concurrent_budget_and_counters() {
        check("sharded-concurrent", 10, |rng: &mut Pcg64| {
            let row_len = 1 + rng.below(8);
            let cap_rows = 1 + rng.below(24);
            let shards = 1 + rng.below(8);
            let threads = 2 + rng.below(6);
            let keys = 1 + rng.below(48);
            let ops_per_worker = 200usize;
            let cache = ShardedRowCache::new(row_len, cap_rows * row_len * 4, shards);

            let seeds: Vec<u64> = (0..threads).map(|_| rng.next_u64()).collect();
            let cache_ref = &cache;
            let ok_counts: Vec<usize> = scope_map(threads, seeds, |_, seed| {
                let mut r = Pcg64::new(seed);
                let mut ok = 0usize;
                for _ in 0..ops_per_worker {
                    let k = r.below(keys);
                    let row = cache_ref.get_or_compute(k, |buf| buf.fill(k as f32));
                    if row.len() == row_len && row.iter().all(|&v| v == k as f32) {
                        ok += 1;
                    }
                }
                ok
            });

            let total_ops = (threads * ops_per_worker) as u64;
            prop_assert!(
                ok_counts.iter().sum::<usize>() as u64 == total_ops,
                "some rows held wrong contents"
            );
            let s = cache.stats();
            prop_assert!(
                s.hits + s.misses == total_ops,
                "hits {} + misses {} != ops {total_ops}",
                s.hits,
                s.misses
            );
            prop_assert!(
                cache.len() <= cache.capacity_rows(),
                "budget violated: {} rows > capacity {}",
                cache.len(),
                cache.capacity_rows()
            );
            // Every resident row must have been computed at least once.
            prop_assert!(
                s.misses >= cache.len() as u64,
                "misses {} < resident rows {}",
                s.misses,
                cache.len()
            );
            Ok(())
        });
    }

    /// Same-key contention: concurrent workers hammering ONE key must
    /// compute it exactly once (fill serializes under the shard lock).
    #[test]
    fn concurrent_same_key_computes_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let cache = ShardedRowCache::new(4, 1 << 20, 8);
        let fills = AtomicUsize::new(0);
        let (cache_ref, fills_ref) = (&cache, &fills);
        scope_map(8, (0..64).collect::<Vec<u32>>(), |_, _| {
            let row = cache_ref.get_or_compute(3, |buf| {
                fills_ref.fetch_add(1, Ordering::Relaxed);
                buf.fill(3.0);
            });
            assert_eq!(&*row, &[3.0; 4]);
        });
        assert_eq!(fills.load(Ordering::Relaxed), 1);
        let s = cache.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 63);
    }
}
