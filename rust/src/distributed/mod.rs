//! Distributed parallel block minimization over the shared wire layer.
//!
//! `dcsvm train --distributed true` trains the same dual problem as the
//! single-process solvers, but across worker *processes* (or any TCP
//! endpoints running `dcsvm worker --listen ADDR`), following the
//! communication-efficient parallel block minimization scheme of
//! arXiv:1608.02010 adapted to this crate's DC-SVM machinery:
//!
//! 1. **Shard.** The coordinator round-robins training-row ownership
//!    across P workers (`i mod P`). No feature data crosses the wire:
//!    the hello message carries only the *dataset spec* (name, sizes,
//!    seed, kernel), and every worker regenerates its bit-identical copy
//!    locally ([`crate::data::synthetic::generate_split`] is
//!    deterministic per seed).
//! 2. **Local block minimization.** Each round, every worker re-solves
//!    its block's dual sub-problem against its own [`crate::cache::KernelContext`]
//!    and segment cache, with the out-of-block variables frozen into a
//!    linear offset ([`crate::solver::SmoSolver::with_linear_offset`]):
//!    `q_i = y_i Σ_{j∉B} ᾱ_j y_j K(x_i, x_j)`, warm-started from its own
//!    previous α.
//! 3. **Summary exchange.** Workers return only (support-vector global
//!    id, α) pairs — never kernel rows or matrices — and the coordinator
//!    broadcasts each worker the *other* workers' summaries for the next
//!    round. Total traffic is the `comm_bytes` counter (the wire
//!    [`crate::util::wire::Codec`] byte counts, both directions).
//! 4. **Conquer.** After the last round the coordinator gathers the full
//!    α and runs one warm-started exact solve at the final tolerance on
//!    its own context — so the returned model satisfies the same ε-KKT
//!    conditions as a single-process solve (the e2e equivalence test
//!    pins the objectives to 1e-6 relative).
//!
//! Framing is one JSON object per line over the same [`crate::util::wire`]
//! codec the serve transport uses; PROTOCOL.md §"Worker wire protocol"
//! documents every message and error code (`tests/docs_sync.rs` pins the
//! catalogue).

use anyhow::{bail, Result};

use crate::util::flags::{FlagSet, FlagSpec};
use crate::util::json::Json;

pub mod coordinator;
pub mod worker;

pub use coordinator::train_distributed;
pub use worker::{run_worker, serve_session, WorkerOptions};

// ---------------------------------------------------------------------------
// Error codes (PROTOCOL.md catalogues each; docs_sync.rs enforces it).

/// A request line was not valid JSON (or not valid UTF-8).
pub const ERR_PARSE: &str = "parse";
/// A message arrived out of protocol order or with missing/mistyped
/// fields (e.g. a `round` before `shard`, or `ext_ids`/`ext_alpha` of
/// different lengths).
pub const ERR_PROTOCOL: &str = "protocol";
/// A well-formed message carried unusable values (unknown dataset or
/// kernel, out-of-range row ids, oversized line).
pub const ERR_BAD_REQUEST: &str = "bad_request";
/// Coordinator-synthesized (never sent on the wire): a worker connection
/// closed or errored mid-session. The coordinator aborts the run cleanly
/// — remaining workers are dropped and spawned children are killed.
pub const ERR_WORKER_LOST: &str = "worker_lost";

/// Every `code` a worker error object (or a coordinator-side distributed
/// failure) can carry.
pub const WORKER_ERROR_CODES: &[&str] =
    &[ERR_PARSE, ERR_PROTOCOL, ERR_BAD_REQUEST, ERR_WORKER_LOST];

// ---------------------------------------------------------------------------
// Flag tables (rendered into `--help` and README.md; docs_sync.rs pins the
// README rows, cli_roundtrip.rs pins the strict parse).

/// `dcsvm worker` flag table.
pub const WORKER_FLAGS: &[FlagSpec] = &[
    FlagSpec {
        flag: "--listen",
        value: "ADDR",
        default: "required",
        help: "TCP address to bind (port 0 = ephemeral; announced on stderr)",
    },
    FlagSpec {
        flag: "--threads",
        value: "N",
        default: "all cores",
        help: "kernel-dispatch worker budget of this worker process",
    },
    FlagSpec {
        flag: "--cache-mb",
        value: "MB",
        default: "256",
        help: "kernel-row cache budget of the worker's shard context",
    },
    FlagSpec {
        flag: "--backend",
        value: "KIND",
        default: "native",
        help: "kernel backend: auto, native, or pjrt",
    },
];

/// The `dcsvm worker` flag surface (usage text + strict parser).
pub const WORKER_FLAG_SET: FlagSet =
    FlagSet { cmd: "worker", required: "--listen ADDR", flags: WORKER_FLAGS };

/// The distributed flags `dcsvm train` accepts (they flow through
/// [`crate::config::RunConfig::apply`] like every train flag; this table
/// renders the README rows and keeps help text in one place).
pub const DIST_FLAGS: &[FlagSpec] = &[
    FlagSpec {
        flag: "--distributed",
        value: "BOOL",
        default: "false",
        help: "train via parallel block minimization over worker processes",
    },
    FlagSpec {
        flag: "--workers",
        value: "N",
        default: "2",
        help: "local `dcsvm worker` processes to spawn when --workers-addr is not given",
    },
    FlagSpec {
        flag: "--workers-addr",
        value: "LIST",
        default: "spawn local",
        help: "comma-separated addresses of already-running workers",
    },
    FlagSpec {
        flag: "--rounds",
        value: "R",
        default: "2",
        help: "block-minimization rounds before the conquer solve",
    },
];

// ---------------------------------------------------------------------------
// Messages. One JSON object per line; builders/parsers shared by both ends
// so the two sides cannot drift.

/// The handshake: everything a worker needs to regenerate the training
/// split and configure its local solver. Carries the dataset *spec*, not
/// data — workers rebuild the split deterministically from the seed.
#[derive(Clone, Debug)]
pub struct Hello {
    pub dataset: String,
    pub n_train: usize,
    pub n_test: usize,
    pub seed: u64,
    /// "rbf" | "poly" | "linear"
    pub kernel: String,
    pub gamma: f64,
    pub eta: f64,
    /// Box constraint of the block sub-problems.
    pub c: f64,
    /// KKT tolerance of the block sub-problems (the conquer solve runs at
    /// the coordinator's final tolerance, not this one).
    pub eps: f64,
}

impl Hello {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::from(self.dataset.as_str())),
            ("n_train", Json::from(self.n_train)),
            ("n_test", Json::from(self.n_test)),
            ("seed", Json::from(self.seed as f64)),
            ("kernel", Json::from(self.kernel.as_str())),
            ("gamma", Json::from(self.gamma)),
            ("eta", Json::from(self.eta)),
            ("c", Json::from(self.c)),
            ("eps", Json::from(self.eps)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Hello> {
        let field = |k: &str| -> Result<f64> {
            j.get(k).as_f64().ok_or_else(|| anyhow::anyhow!("hello: missing number '{k}'"))
        };
        Ok(Hello {
            dataset: j
                .get("dataset")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("hello: missing 'dataset'"))?
                .to_string(),
            n_train: j
                .get("n_train")
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("hello: missing 'n_train'"))?,
            n_test: j
                .get("n_test")
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("hello: missing 'n_test'"))?,
            seed: field("seed")? as u64,
            kernel: j
                .get("kernel")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("hello: missing 'kernel'"))?
                .to_string(),
            gamma: field("gamma")?,
            eta: field("eta")?,
            c: field("c")?,
            eps: field("eps")?,
        })
    }
}

/// Row-id list as a JSON array.
pub fn ids_json(ids: &[usize]) -> Json {
    Json::Arr(ids.iter().map(|&i| Json::from(i)).collect())
}

/// Parse a JSON array of row ids.
pub fn parse_ids(j: &Json) -> Result<Vec<usize>> {
    let Some(arr) = j.as_arr() else { bail!("expected an id array") };
    arr.iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("ids must be non-negative integers")))
        .collect()
}

/// Parse a JSON array of numbers.
pub fn parse_f64s(j: &Json) -> Result<Vec<f64>> {
    let Some(arr) = j.as_arr() else { bail!("expected a number array") };
    arr.iter()
        .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("alpha entries must be numbers")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_roundtrips() {
        let h = Hello {
            dataset: "covtype-like".into(),
            n_train: 300,
            n_test: 100,
            seed: 7,
            kernel: "rbf".into(),
            gamma: 16.0,
            eta: 0.0,
            c: 4.0,
            eps: 1e-3,
        };
        let back = Hello::from_json(&h.to_json()).unwrap();
        assert_eq!(back.dataset, h.dataset);
        assert_eq!(back.n_train, h.n_train);
        assert_eq!(back.n_test, h.n_test);
        assert_eq!(back.seed, h.seed);
        assert_eq!(back.kernel, h.kernel);
        assert_eq!(back.gamma, h.gamma);
        assert_eq!(back.c, h.c);
        assert_eq!(back.eps, h.eps);
        assert!(Hello::from_json(&Json::obj(vec![("dataset", Json::from("x"))])).is_err());
    }

    #[test]
    fn id_and_alpha_arrays_roundtrip() {
        let ids = vec![0usize, 7, 42];
        let back = parse_ids(&ids_json(&ids)).unwrap();
        assert_eq!(back, ids);
        let al = [0.5f64, 1.25];
        assert_eq!(parse_f64s(&Json::arr_f64(&al)).unwrap(), al);
        assert!(parse_ids(&Json::from(3usize)).is_err());
        assert!(parse_ids(&Json::Arr(vec![Json::from(-1.0)])).is_err());
    }

    #[test]
    fn worker_flag_set_is_strict() {
        let u = WORKER_FLAG_SET.usage();
        assert!(u.starts_with("usage: dcsvm worker --listen ADDR [flags]\n"), "{u}");
        for f in WORKER_FLAGS {
            assert!(u.contains(f.flag) && u.contains(f.help), "{u}");
        }
        let args: Vec<String> = ["--bogus", "x"].iter().map(|s| s.to_string()).collect();
        let e = WORKER_FLAG_SET.parse(&args).unwrap_err().to_string();
        assert!(e.contains("worker: unknown flag '--bogus'"), "{e}");
    }
}
