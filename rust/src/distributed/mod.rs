//! Distributed parallel block minimization over the shared wire layer.
//!
//! `dcsvm train --distributed true` trains the same dual problem as the
//! single-process solvers, but across worker *processes* (or any TCP
//! endpoints running `dcsvm worker --listen ADDR`), following the
//! communication-efficient parallel block minimization scheme of
//! arXiv:1608.02010 adapted to this crate's DC-SVM machinery:
//!
//! 1. **Shard.** The coordinator round-robins training-row ownership
//!    across P workers (`i mod P`). No feature data crosses the wire:
//!    the hello message carries only the *dataset spec* (name, sizes,
//!    seed, kernel), and every worker regenerates its bit-identical copy
//!    locally ([`crate::data::synthetic::generate_split`] is
//!    deterministic per seed).
//! 2. **Local block minimization.** Each round, every worker re-solves
//!    its block's dual sub-problem against its own [`crate::cache::KernelContext`]
//!    and segment cache, with the out-of-block variables frozen into a
//!    linear offset ([`crate::solver::SmoSolver::with_linear_offset`]):
//!    `q_i = y_i Σ_{j∉B} ᾱ_j y_j K(x_i, x_j)`, warm-started from its own
//!    previous α.
//! 3. **Summary exchange.** Workers return only (support-vector global
//!    id, α) pairs — never kernel rows or matrices — and the coordinator
//!    broadcasts each worker the *other* workers' summaries for the next
//!    round. Total traffic is the `comm_bytes` counter (the wire
//!    [`crate::util::wire::Codec`] byte counts, both directions).
//! 4. **Conquer.** After the last round the coordinator gathers the full
//!    α and runs one warm-started exact solve at the final tolerance on
//!    its own context — so the returned model satisfies the same ε-KKT
//!    conditions as a single-process solve (the e2e equivalence test
//!    pins the objectives to 1e-6 relative).
//! 5. **Recover.** A worker that dies, stalls past `--round-timeout`, or
//!    returns garbage mid-round is retired: locally-spawned workers get
//!    bounded respawn attempts (`--worker-retries`), otherwise the lost
//!    rows are re-sharded onto survivors (the `reshard` message — pure
//!    engineering, since every worker's context covers the full training
//!    set) and the interrupted round replays. The run degrades from P
//!    workers down to 1 and aborts only when all workers are gone. The
//!    [`FaultPlan`] layer ([`FAULT_ENV`]) injects deterministic faults so
//!    tests can pin this machinery.
//!
//! Framing is one JSON object per line over the same [`crate::util::wire`]
//! codec the serve transport uses; PROTOCOL.md §"Worker wire protocol"
//! documents every message and error code (`tests/docs_sync.rs` pins the
//! catalogue).

use anyhow::{bail, Result};

use crate::util::flags::{FlagSet, FlagSpec};
use crate::util::json::Json;

pub mod coordinator;
pub mod worker;

pub use coordinator::train_distributed;
pub use worker::{run_worker, serve_session, WorkerOptions};

// ---------------------------------------------------------------------------
// Error codes (PROTOCOL.md catalogues each; docs_sync.rs enforces it).

/// A request line was not valid JSON (or not valid UTF-8).
pub const ERR_PARSE: &str = "parse";
/// A message arrived out of protocol order or with missing/mistyped
/// fields (e.g. a `round` before `shard`, or `ext_ids`/`ext_alpha` of
/// different lengths).
pub const ERR_PROTOCOL: &str = "protocol";
/// A well-formed message carried unusable values (unknown dataset or
/// kernel, out-of-range row ids, oversized line).
pub const ERR_BAD_REQUEST: &str = "bad_request";
/// Coordinator-synthesized (never sent on the wire): a worker connection
/// closed, errored, stalled past `--round-timeout`, or returned garbage
/// mid-session. The coordinator *recovers* — it respawns locally-spawned
/// workers (`--worker-retries`), re-shards the lost rows onto survivors,
/// and replays the interrupted round — and only aborts with this code
/// when every worker is gone.
pub const ERR_WORKER_LOST: &str = "worker_lost";

/// Every `code` a worker error object (or a coordinator-side distributed
/// failure) can carry.
pub const WORKER_ERROR_CODES: &[&str] =
    &[ERR_PARSE, ERR_PROTOCOL, ERR_BAD_REQUEST, ERR_WORKER_LOST];

// ---------------------------------------------------------------------------
// Flag tables (rendered into `--help` and README.md; docs_sync.rs pins the
// README rows, cli_roundtrip.rs pins the strict parse).

/// `dcsvm worker` flag table.
pub const WORKER_FLAGS: &[FlagSpec] = &[
    FlagSpec {
        flag: "--listen",
        value: "ADDR",
        default: "required",
        help: "TCP address to bind (port 0 = ephemeral; announced on stderr)",
    },
    FlagSpec {
        flag: "--threads",
        value: "N",
        default: "all cores",
        help: "kernel-dispatch worker budget of this worker process",
    },
    FlagSpec {
        flag: "--cache-mb",
        value: "MB",
        default: "256",
        help: "kernel-row cache budget of the worker's shard context",
    },
    FlagSpec {
        flag: "--backend",
        value: "KIND",
        default: "native",
        help: "kernel backend: auto, native, or pjrt",
    },
];

/// The `dcsvm worker` flag surface (usage text + strict parser).
pub const WORKER_FLAG_SET: FlagSet =
    FlagSet { cmd: "worker", required: "--listen ADDR", flags: WORKER_FLAGS };

/// The distributed flags `dcsvm train` accepts (they flow through
/// [`crate::config::RunConfig::apply`] like every train flag; this table
/// renders the README rows and keeps help text in one place).
pub const DIST_FLAGS: &[FlagSpec] = &[
    FlagSpec {
        flag: "--distributed",
        value: "BOOL",
        default: "false",
        help: "train via parallel block minimization over worker processes",
    },
    FlagSpec {
        flag: "--workers",
        value: "N",
        default: "2",
        help: "local `dcsvm worker` processes to spawn when --workers-addr is not given",
    },
    FlagSpec {
        flag: "--workers-addr",
        value: "LIST",
        default: "spawn local",
        help: "comma-separated addresses of already-running workers",
    },
    FlagSpec {
        flag: "--rounds",
        value: "R",
        default: "2",
        help: "block-minimization rounds before the conquer solve",
    },
    FlagSpec {
        flag: "--round-timeout",
        value: "SECS",
        default: "60",
        help: "declare a worker lost if its round reply takes longer than this",
    },
    FlagSpec {
        flag: "--connect-timeout",
        value: "SECS",
        default: "10",
        help: "deadline for connecting to each worker address",
    },
    FlagSpec {
        flag: "--worker-retries",
        value: "N",
        default: "0",
        help: "respawn attempts for a lost locally-spawned worker before re-sharding",
    },
];

// ---------------------------------------------------------------------------
// Messages. One JSON object per line; builders/parsers shared by both ends
// so the two sides cannot drift.

/// The handshake: everything a worker needs to regenerate the training
/// split and configure its local solver. Carries the dataset *spec*, not
/// data — workers rebuild the split deterministically from the seed.
#[derive(Clone, Debug)]
pub struct Hello {
    pub dataset: String,
    pub n_train: usize,
    pub n_test: usize,
    pub seed: u64,
    /// "rbf" | "poly" | "linear"
    pub kernel: String,
    pub gamma: f64,
    pub eta: f64,
    /// Box constraint of the block sub-problems.
    pub c: f64,
    /// KKT tolerance of the block sub-problems (the conquer solve runs at
    /// the coordinator's final tolerance, not this one).
    pub eps: f64,
}

impl Hello {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::from(self.dataset.as_str())),
            ("n_train", Json::from(self.n_train)),
            ("n_test", Json::from(self.n_test)),
            ("seed", Json::from(self.seed as f64)),
            ("kernel", Json::from(self.kernel.as_str())),
            ("gamma", Json::from(self.gamma)),
            ("eta", Json::from(self.eta)),
            ("c", Json::from(self.c)),
            ("eps", Json::from(self.eps)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Hello> {
        let field = |k: &str| -> Result<f64> {
            j.get(k).as_f64().ok_or_else(|| anyhow::anyhow!("hello: missing number '{k}'"))
        };
        Ok(Hello {
            dataset: j
                .get("dataset")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("hello: missing 'dataset'"))?
                .to_string(),
            n_train: j
                .get("n_train")
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("hello: missing 'n_train'"))?,
            n_test: j
                .get("n_test")
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("hello: missing 'n_test'"))?,
            seed: field("seed")? as u64,
            kernel: j
                .get("kernel")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("hello: missing 'kernel'"))?
                .to_string(),
            gamma: field("gamma")?,
            eta: field("eta")?,
            c: field("c")?,
            eps: field("eps")?,
        })
    }
}

// ---------------------------------------------------------------------------
// Deterministic fault injection (tests + bench fault leg only).

/// Env var the *coordinator* reads: `worker:W,round:R,kind:KIND` injects a
/// fault into locally-spawned worker `W` at round `R`. The coordinator
/// strips it from child environments and hands the targeted worker its
/// plan via [`FAULT_SELF_ENV`], so respawned replacements run clean.
pub const FAULT_ENV: &str = "DCSVM_FAULT";

/// Env var a *worker* process reads: `round:R,kind:KIND` (set by the
/// coordinator on the one targeted child, never by hand).
pub const FAULT_SELF_ENV: &str = "DCSVM_FAULT_SELF";

/// How an injected fault manifests at the pinned round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Close the session without replying (a crashed worker: the
    /// coordinator sees EOF within one read-poll tick).
    Exit,
    /// Stop replying but hold the connection open (a hung worker: only
    /// the `--round-timeout` deadline can detect it). The worker unblocks
    /// and exits once the coordinator drops the connection.
    Stall,
    /// Reply with a non-protocol frame (a corrupted worker: the
    /// coordinator must treat the reply as unusable, not crash on it).
    Garbage,
}

/// One deterministic injected fault: at the round message numbered
/// `round`, misbehave per `kind` instead of solving.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    pub round: usize,
    pub kind: FaultKind,
}

impl FaultPlan {
    /// Parse `round:R,kind:exit|stall|garbage` (the [`FAULT_SELF_ENV`]
    /// format; key order is free).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut round = None;
        let mut kind = None;
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            match part.split_once(':') {
                Some(("round", v)) => {
                    round = Some(v.trim().parse::<usize>().map_err(|_| {
                        anyhow::anyhow!("fault spec: round must be an integer, got '{v}'")
                    })?)
                }
                Some(("kind", v)) => {
                    kind = Some(match v.trim() {
                        "exit" => FaultKind::Exit,
                        "stall" => FaultKind::Stall,
                        "garbage" => FaultKind::Garbage,
                        other => bail!("fault spec: unknown kind '{other}' (exit|stall|garbage)"),
                    })
                }
                _ => bail!("fault spec: unknown part '{part}' (want round:R,kind:K)"),
            }
        }
        Ok(FaultPlan {
            round: round.ok_or_else(|| anyhow::anyhow!("fault spec: missing round:R"))?,
            kind: kind.ok_or_else(|| anyhow::anyhow!("fault spec: missing kind:K"))?,
        })
    }

    /// The `round:R,kind:K` string [`FaultPlan::parse`] accepts.
    pub fn spec_string(&self) -> String {
        let kind = match self.kind {
            FaultKind::Exit => "exit",
            FaultKind::Stall => "stall",
            FaultKind::Garbage => "garbage",
        };
        format!("round:{},kind:{kind}", self.round)
    }

    /// The worker-side plan from [`FAULT_SELF_ENV`], if set.
    pub fn from_self_env() -> Result<Option<FaultPlan>> {
        match std::env::var(FAULT_SELF_ENV) {
            Ok(s) if !s.trim().is_empty() => FaultPlan::parse(&s).map(Some),
            _ => Ok(None),
        }
    }
}

/// The coordinator-side fault directive: which spawned worker gets which
/// [`FaultPlan`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub worker: usize,
    pub plan: FaultPlan,
}

impl FaultSpec {
    /// Parse `worker:W,round:R,kind:K` (the [`FAULT_ENV`] format).
    pub fn parse(spec: &str) -> Result<FaultSpec> {
        let mut worker = None;
        let mut rest = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            match part.split_once(':') {
                Some(("worker", v)) => {
                    worker = Some(v.trim().parse::<usize>().map_err(|_| {
                        anyhow::anyhow!("fault spec: worker must be an index, got '{v}'")
                    })?)
                }
                _ => rest.push(part),
            }
        }
        Ok(FaultSpec {
            worker: worker.ok_or_else(|| anyhow::anyhow!("fault spec: missing worker:W"))?,
            plan: FaultPlan::parse(&rest.join(","))?,
        })
    }

    /// The coordinator-side directive from [`FAULT_ENV`], if set.
    pub fn from_env() -> Result<Option<FaultSpec>> {
        match std::env::var(FAULT_ENV) {
            Ok(s) if !s.trim().is_empty() => FaultSpec::parse(&s).map(Some),
            _ => Ok(None),
        }
    }
}

/// Row-id list as a JSON array.
pub fn ids_json(ids: &[usize]) -> Json {
    Json::Arr(ids.iter().map(|&i| Json::from(i)).collect())
}

/// Parse a JSON array of row ids.
pub fn parse_ids(j: &Json) -> Result<Vec<usize>> {
    let Some(arr) = j.as_arr() else { bail!("expected an id array") };
    arr.iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("ids must be non-negative integers")))
        .collect()
}

/// Parse a JSON array of numbers.
pub fn parse_f64s(j: &Json) -> Result<Vec<f64>> {
    let Some(arr) = j.as_arr() else { bail!("expected a number array") };
    arr.iter()
        .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("alpha entries must be numbers")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_roundtrips() {
        let h = Hello {
            dataset: "covtype-like".into(),
            n_train: 300,
            n_test: 100,
            seed: 7,
            kernel: "rbf".into(),
            gamma: 16.0,
            eta: 0.0,
            c: 4.0,
            eps: 1e-3,
        };
        let back = Hello::from_json(&h.to_json()).unwrap();
        assert_eq!(back.dataset, h.dataset);
        assert_eq!(back.n_train, h.n_train);
        assert_eq!(back.n_test, h.n_test);
        assert_eq!(back.seed, h.seed);
        assert_eq!(back.kernel, h.kernel);
        assert_eq!(back.gamma, h.gamma);
        assert_eq!(back.c, h.c);
        assert_eq!(back.eps, h.eps);
        assert!(Hello::from_json(&Json::obj(vec![("dataset", Json::from("x"))])).is_err());
    }

    #[test]
    fn id_and_alpha_arrays_roundtrip() {
        let ids = vec![0usize, 7, 42];
        let back = parse_ids(&ids_json(&ids)).unwrap();
        assert_eq!(back, ids);
        let al = [0.5f64, 1.25];
        assert_eq!(parse_f64s(&Json::arr_f64(&al)).unwrap(), al);
        assert!(parse_ids(&Json::from(3usize)).is_err());
        assert!(parse_ids(&Json::Arr(vec![Json::from(-1.0)])).is_err());
    }

    #[test]
    fn fault_specs_parse_and_roundtrip() {
        let s = FaultSpec::parse("worker:1,round:2,kind:exit").unwrap();
        assert_eq!(s.worker, 1);
        assert_eq!(s.plan, FaultPlan { round: 2, kind: FaultKind::Exit });
        // Key order is free; the plan round-trips through its spec string.
        let s = FaultSpec::parse("kind:stall, worker:0, round:3").unwrap();
        assert_eq!(s.plan.kind, FaultKind::Stall);
        assert_eq!(FaultPlan::parse(&s.plan.spec_string()).unwrap(), s.plan);
        assert_eq!(
            FaultPlan::parse("round:1,kind:garbage").unwrap().kind,
            FaultKind::Garbage
        );
        assert!(FaultPlan::parse("round:1,kind:melt").is_err());
        assert!(FaultPlan::parse("round:1").is_err());
        assert!(FaultSpec::parse("round:1,kind:exit").is_err(), "worker index required");
        assert!(FaultSpec::parse("worker:x,round:1,kind:exit").is_err());
    }

    #[test]
    fn worker_flag_set_is_strict() {
        let u = WORKER_FLAG_SET.usage();
        assert!(u.starts_with("usage: dcsvm worker --listen ADDR [flags]\n"), "{u}");
        for f in WORKER_FLAGS {
            assert!(u.contains(f.flag) && u.contains(f.help), "{u}");
        }
        let args: Vec<String> = ["--bogus", "x"].iter().map(|s| s.to_string()).collect();
        let e = WORKER_FLAG_SET.parse(&args).unwrap_err().to_string();
        assert!(e.contains("worker: unknown flag '--bogus'"), "{e}");
    }
}
