//! The coordinator side of distributed block minimization: shard the
//! problem, drive the rounds, gather the α summaries, conquer locally.
//!
//! Endpoints come from `--workers-addr` (already-running `dcsvm worker`
//! processes, possibly on other machines) or are spawned as local child
//! processes of the current binary. Either way the coordinator speaks the
//! worker wire protocol over [`crate::util::wire::Codec`]s, and the sum of
//! their byte counters IS the run's `comm_bytes` — the quantity the
//! communication-efficient scheme (arXiv:1608.02010) minimizes, and the
//! number the e2e test pins far below one serialized kernel block.
//!
//! A worker connection that closes or errors mid-round aborts the run
//! with a structured [`super::ERR_WORKER_LOST`] error within one
//! read-poll tick: remaining connections are dropped and spawned children
//! are killed (the [`Spawned`] guard), never hung.

use std::io::{BufRead, BufReader};
use std::net::TcpStream;
use std::process::{Child, ChildStderr, Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::cache::KernelContext;
use crate::config::RunConfig;
use crate::data::Dataset;
use crate::harness::{make_kernel, Outcome};
use crate::predict::SvmModel;
use crate::solver::{SmoConfig, SmoSolver};
use crate::util::json::Json;
use crate::util::wire::{self, Frame, TcpCodec};

use super::{ids_json, parse_f64s, parse_ids, Hello, ERR_PROTOCOL, ERR_WORKER_LOST};

/// Child-process guard: whatever path exits [`train_distributed`] —
/// success, worker loss, protocol error — spawned workers are killed and
/// reaped, never leaked.
struct Spawned {
    children: Vec<Child>,
    /// Held open so a worker writing to stderr after its announce line
    /// never hits a closed pipe.
    _logs: Vec<BufReader<ChildStderr>>,
}

impl Drop for Spawned {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Spawn `count` local `dcsvm worker` processes (the current binary) on
/// ephemeral ports and return their announced addresses.
fn spawn_local_workers(cfg: &RunConfig, count: usize, guard: &mut Spawned) -> Result<Vec<String>> {
    let exe = std::env::current_exe().context("locate the dcsvm binary for local workers")?;
    // Split the coordinator's thread budget so P workers don't put
    // P × threads dispatch workers on the machine.
    let per_worker = (cfg.threads / count.max(1)).max(1);
    let mut addrs = Vec::with_capacity(count);
    for _ in 0..count {
        let mut child = Command::new(&exe)
            .arg("worker")
            .arg("--listen")
            .arg("127.0.0.1:0")
            .arg("--threads")
            .arg(per_worker.to_string())
            .arg("--cache-mb")
            .arg(cfg.cache_mb.max(1).to_string())
            .arg("--backend")
            .arg(&cfg.backend)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::piped())
            .spawn()
            .context("spawn local worker")?;
        let mut log = BufReader::new(child.stderr.take().expect("piped stderr"));
        let mut line = String::new();
        log.read_line(&mut line).context("read worker announce line")?;
        let addr = Json::parse(line.trim())
            .ok()
            .and_then(|j| j.get("worker_listening").as_str().map(str::to_string));
        guard.children.push(child);
        guard._logs.push(log);
        let Some(addr) = addr else {
            bail!("worker did not announce a listening address (got {line:?})");
        };
        addrs.push(addr);
    }
    Ok(addrs)
}

/// Connect with retry (externally-started workers may still be binding).
fn connect_retry(addr: &str, deadline: Duration) -> Result<TcpStream> {
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(_) if t0.elapsed() < deadline => {
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) => return Err(anyhow!("connect worker {addr}: {e}")),
        }
    }
}

/// Write one message; an I/O failure means the worker is gone.
fn send(codec: &mut TcpCodec, w: usize, msg: &Json) -> Result<()> {
    codec
        .write_json(msg)
        .map_err(|e| anyhow!("[{ERR_WORKER_LOST}] worker {w}: write failed: {e}"))
}

/// Read one parsed message; EOF or a transport error mid-session is a
/// structured worker-lost failure (surfaced within one read-poll tick of
/// the OS seeing the close — the coordinator never hangs on a dead peer).
fn recv(codec: &mut TcpCodec, w: usize) -> Result<Json> {
    loop {
        match codec.read_frame() {
            Ok(Frame::Line(line)) => {
                let t = line.trim();
                if t.is_empty() {
                    continue;
                }
                return Json::parse(t)
                    .map_err(|e| anyhow!("[{ERR_PROTOCOL}] worker {w}: bad response line: {e}"));
            }
            Ok(Frame::Idle) => continue,
            Ok(Frame::Eof) => {
                bail!("[{ERR_WORKER_LOST}] worker {w}: connection closed mid-session")
            }
            Ok(Frame::Overflow) | Ok(Frame::NotUtf8) => {
                bail!("[{ERR_PROTOCOL}] worker {w}: unreadable response line")
            }
            Err(e) => bail!("[{ERR_WORKER_LOST}] worker {w}: {e}"),
        }
    }
}

/// Fail on a structured error reply; otherwise require `"ok": true`.
fn expect_ok(reply: &Json, w: usize, stage: &str) -> Result<()> {
    if reply.get("error") != &Json::Null {
        bail!(
            "worker {w} rejected {stage}: [{}] {}",
            reply.get("error").get("code").as_str().unwrap_or("?"),
            reply.get("error").get("message").as_str().unwrap_or("?")
        );
    }
    if reply.get("ok").as_bool() != Some(true) {
        bail!("[{ERR_PROTOCOL}] worker {w}: expected ok to {stage}, got {reply}");
    }
    Ok(())
}

/// Train `(tr, te)` by parallel block minimization over worker processes,
/// then conquer locally. Workers regenerate the split from `cfg`'s
/// dataset spec, so `tr`/`te` MUST come from that spec (the harness
/// loader) — only α summaries and row ids cross the wire.
pub fn train_distributed(cfg: &RunConfig, tr: &Dataset, te: &Dataset) -> Result<Outcome> {
    let t0 = Instant::now();
    let n = tr.len();
    let rounds = cfg.rounds.max(1);
    let mut guard = Spawned { children: Vec::new(), _logs: Vec::new() };

    // --- endpoints --------------------------------------------------------
    let addrs: Vec<String> = match &cfg.workers_addr {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        None => spawn_local_workers(cfg, cfg.dist_workers.max(1), &mut guard)?,
    };
    if addrs.is_empty() {
        bail!("distributed: no worker addresses (--workers-addr was empty)");
    }
    let p = addrs.len();
    let mut codecs: Vec<TcpCodec> = Vec::with_capacity(p);
    for addr in &addrs {
        let stream = connect_retry(addr, Duration::from_secs(10))?;
        codecs.push(wire::tcp_codec(stream).context("worker codec")?);
    }

    // --- handshake: dataset spec only, never data -------------------------
    let hello = Hello {
        dataset: cfg.dataset.clone(),
        n_train: tr.len(),
        n_test: te.len(),
        seed: cfg.seed,
        kernel: cfg.kernel.clone(),
        gamma: cfg.gamma,
        eta: cfg.eta,
        c: cfg.c,
        // Block sub-problems run at a looser tolerance (the conquer solve
        // enforces cfg.eps on the whole problem) — same policy as the
        // DC-SVM divide phase.
        eps: cfg.eps.max(1e-3),
    };
    let hello_msg = Json::obj(vec![("hello", hello.to_json())]);
    for (w, codec) in codecs.iter_mut().enumerate() {
        send(codec, w, &hello_msg)?;
    }
    for (w, codec) in codecs.iter_mut().enumerate() {
        let reply = recv(codec, w)?;
        expect_ok(&reply, w, "hello")?;
        if reply.get("n").as_usize() != Some(n) {
            bail!("[{ERR_PROTOCOL}] worker {w}: regenerated n {} != {n}", reply.get("n"));
        }
    }

    // --- shard ownership: round-robin i mod P -----------------------------
    let shards: Vec<Vec<usize>> = (0..p).map(|w| (w..n).step_by(p).collect()).collect();
    for (w, codec) in codecs.iter_mut().enumerate() {
        send(codec, w, &Json::obj(vec![("shard", ids_json(&shards[w]))]))?;
    }
    for (w, codec) in codecs.iter_mut().enumerate() {
        let reply = recv(codec, w)?;
        expect_ok(&reply, w, "shard")?;
    }

    // --- rounds: broadcast external summaries, gather block solutions ----
    let mut sv: Vec<(Vec<usize>, Vec<f64>)> = vec![(Vec::new(), Vec::new()); p];
    let mut worker_values = 0u64;
    let mut worker_iters = 0u64;
    for r in 1..=rounds {
        // Jacobi-style: every worker sees the *previous* round's summaries
        // from its peers, so all P block solves run concurrently.
        for w in 0..p {
            let mut ext_ids = Vec::new();
            let mut ext_alpha = Vec::new();
            for (o, (ids, al)) in sv.iter().enumerate() {
                if o != w {
                    ext_ids.extend_from_slice(ids);
                    ext_alpha.extend_from_slice(al);
                }
            }
            let msg = Json::obj(vec![
                ("round", Json::from(r)),
                ("ext_ids", ids_json(&ext_ids)),
                ("ext_alpha", Json::arr_f64(&ext_alpha)),
            ]);
            send(&mut codecs[w], w, &msg)?;
        }
        for w in 0..p {
            let reply = recv(&mut codecs[w], w)?;
            if reply.get("error") != &Json::Null {
                bail!(
                    "worker {w} failed round {r}: [{}] {}",
                    reply.get("error").get("code").as_str().unwrap_or("?"),
                    reply.get("error").get("message").as_str().unwrap_or("?")
                );
            }
            if reply.get("round").as_usize() != Some(r) {
                bail!("[{ERR_PROTOCOL}] worker {w}: round echo mismatch in {reply}");
            }
            let ids = parse_ids(reply.get("ids"))
                .map_err(|e| anyhow!("[{ERR_PROTOCOL}] worker {w}: {e}"))?;
            let al = parse_f64s(reply.get("alpha"))
                .map_err(|e| anyhow!("[{ERR_PROTOCOL}] worker {w}: {e}"))?;
            if ids.len() != al.len() || ids.iter().any(|&i| i >= n || i % p != w) {
                bail!("[{ERR_PROTOCOL}] worker {w}: summary ids outside its shard");
            }
            worker_values += reply.get("values_computed").as_f64().unwrap_or(0.0) as u64;
            worker_iters += reply.get("iterations").as_f64().unwrap_or(0.0) as u64;
            sv[w] = (ids, al);
        }
    }

    // --- release workers (best effort; the run already has everything).
    // The ok reply is consumed so workers finish their session before the
    // coordinator closes the sockets (no write-after-close races).
    for (w, codec) in codecs.iter_mut().enumerate() {
        if codec.write_json(&Json::obj(vec![("shutdown", Json::from(true))])).is_ok() {
            let _ = recv(codec, w);
        }
    }
    let comm_bytes: u64 = codecs.iter().map(|c| c.bytes_in() + c.bytes_out()).sum();
    drop(codecs);

    // --- conquer: gather α, one warm-started exact solve at cfg.eps ------
    let mut alpha = vec![0f64; n];
    for (ids, al) in &sv {
        for (&i, &a) in ids.iter().zip(al) {
            alpha[i] = a;
        }
    }
    let kind = cfg.kernel_kind()?;
    let kernel = make_kernel(kind, &cfg.backend, tr.dim)?;
    let ctx = KernelContext::new(tr, kernel.as_ref(), (cfg.cache_mb.max(1)) << 20)
        .with_threads(cfg.threads);
    let mut solver = SmoSolver::new(
        ctx.view_full(),
        SmoConfig { c: cfg.c, eps: cfg.eps, ..SmoConfig::default() },
    );
    let res = solver.solve_warm(Some(alpha.as_slice()), &mut |_| {});
    let model = SvmModel::from_ctx_alpha(&ctx, &res.alpha);
    let te_ctx = KernelContext::new(te, kernel.as_ref(), 1 << 20).with_threads(cfg.threads);
    let accuracy = model.accuracy_ctx(&te_ctx);

    Ok(Outcome {
        algo: "Distributed",
        train_s: t0.elapsed().as_secs_f64(),
        accuracy,
        objective: Some(res.objective),
        svs: res.sv_count,
        cache_hit_rate: Some(res.cache_hit_rate),
        simd_tier: crate::kernel::simd_tier().name(),
        comm_bytes: Some(comm_bytes),
        rounds: Some(rounds as u64),
        worker_values_computed: Some(worker_values),
        note: format!(
            "workers={p} spawned={} conquer_iters={} worker_iters={worker_iters}",
            !guard.children.is_empty(),
            res.iterations
        ),
        ..Default::default()
    })
}
