//! The coordinator side of distributed block minimization: shard the
//! problem, drive the rounds, gather the α summaries, conquer locally.
//!
//! Endpoints come from `--workers-addr` (already-running `dcsvm worker`
//! processes, possibly on other machines) or are spawned as local child
//! processes of the current binary. Either way the coordinator speaks the
//! worker wire protocol over [`crate::util::wire::Codec`]s, and the sum of
//! their byte counters IS the run's `comm_bytes` — the quantity the
//! communication-efficient scheme (arXiv:1608.02010) minimizes, and the
//! number the e2e test pins far below one serialized kernel block.
//!
//! # Recovery state machine
//!
//! A worker that closes its connection, errors, replies with garbage, or
//! stalls past the per-round deadline (`--round-timeout`, counted in
//! read-poll ticks via [`Codec::read_frame_deadline`]) is *retired*, and
//! the interrupted round replays:
//!
//! 1. **Detect** — EOF/garbage within one read-poll tick, stalls at the
//!    round deadline. The failed attempt's replies are discarded.
//! 2. **Respawn** (locally-spawned workers only) — up to
//!    `--worker-retries` attempts with linear backoff: a fresh child gets
//!    the same hello and the same shard and the round replays. Its warm
//!    start is lost; the solution is not (each block solve is determined
//!    by the frozen external α, not the starting point).
//! 3. **Re-shard** — otherwise the lost rows are appended round-robin to
//!    the survivors via `reshard` messages, seeded with the lost worker's
//!    last committed α so the warm start survives the move. A survivor
//!    failing mid-re-shard joins the dead set and distribution restarts
//!    over the remainder.
//! 4. **Replay** — the round that was interrupted runs again with the
//!    new ownership. P degrades toward 1 (single-process training); only
//!    losing *every* worker aborts the run, with a structured
//!    [`super::ERR_WORKER_LOST`] error, never a hang. Spawned children
//!    are killed and reaped on every exit path (the [`Roster`] guard).

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader};
use std::net::TcpStream;
use std::process::{Child, ChildStderr, Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::cache::KernelContext;
use crate::config::RunConfig;
use crate::data::Dataset;
use crate::harness::{make_kernel, Outcome};
use crate::predict::SvmModel;
use crate::solver::{SmoConfig, SmoSolver};
use crate::util::json::Json;
use crate::util::wire::{self, Frame, TcpCodec};

use super::{
    ids_json, parse_f64s, parse_ids, FaultPlan, FaultSpec, Hello, ERR_PROTOCOL, ERR_WORKER_LOST,
    FAULT_ENV, FAULT_SELF_ENV,
};

/// One worker endpoint's full lifecycle state. `codec: None` means the
/// worker has been retired (lost and not respawned); its rows and last
/// committed summary move to survivors during re-sharding.
struct WorkerState {
    addr: String,
    codec: Option<TcpCodec>,
    /// Rows this worker currently owns (arbitrary after re-sharding —
    /// round-robin `i mod P` only at startup).
    shard: Vec<usize>,
    /// `shard` as a set, for validating summary ids.
    owned: HashSet<usize>,
    /// Last *committed* round summary (global id, α): what peers see as
    /// external α, and the warm seed if this worker's rows move.
    summary: (Vec<usize>, Vec<f64>),
    /// The child process, when locally spawned (respawn candidates).
    child: Option<Child>,
    /// Held open so a worker writing to stderr after its announce line
    /// never hits a closed pipe.
    _log: Option<BufReader<ChildStderr>>,
    /// Spawned by this coordinator (killable, respawnable)?
    local: bool,
    /// Respawn attempts remaining (`--worker-retries`; local only).
    retries_left: usize,
}

/// Worker guard: whatever path exits [`train_distributed`] — success,
/// all-workers-lost, protocol error — spawned children are killed and
/// reaped, never leaked, and retired codecs' bytes stay counted.
struct Roster {
    workers: Vec<WorkerState>,
    /// `bytes_in + bytes_out` of codecs already dropped by [`Roster::retire`].
    retired_bytes: u64,
}

impl Drop for Roster {
    fn drop(&mut self) {
        for w in &mut self.workers {
            if let Some(c) = &mut w.child {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }
}

impl Roster {
    /// Indices of workers still holding a live connection.
    fn live(&self) -> Vec<usize> {
        (0..self.workers.len()).filter(|&w| self.workers[w].codec.is_some()).collect()
    }

    /// Retire worker `w`: drop its connection (keeping its byte counts),
    /// kill and reap its child if locally spawned. Its shard/summary stay
    /// for the respawn or re-shard step to consume.
    fn retire(&mut self, w: usize) {
        let ws = &mut self.workers[w];
        if let Some(codec) = ws.codec.take() {
            self.retired_bytes += codec.bytes_in() + codec.bytes_out();
        }
        if let Some(mut child) = ws.child.take() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// Spawn one local `dcsvm worker` process (the current binary) on an
/// ephemeral port and return it with its announced address. `fault`
/// plants the injected-fault plan in the child's environment (initial
/// spawns only — respawned replacements always run clean, and any
/// coordinator-level [`FAULT_ENV`] is stripped so children can't
/// misread it).
fn spawn_one(
    cfg: &RunConfig,
    threads: usize,
    fault: Option<&FaultPlan>,
) -> Result<(Child, BufReader<ChildStderr>, String)> {
    let exe = std::env::current_exe().context("locate the dcsvm binary for local workers")?;
    let mut cmd = Command::new(&exe);
    cmd.arg("worker")
        .arg("--listen")
        .arg("127.0.0.1:0")
        .arg("--threads")
        .arg(threads.to_string())
        .arg("--cache-mb")
        .arg(cfg.cache_mb.max(1).to_string())
        .arg("--backend")
        .arg(&cfg.backend)
        .env_remove(FAULT_ENV)
        .env_remove(FAULT_SELF_ENV)
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::piped());
    if let Some(f) = fault {
        cmd.env(FAULT_SELF_ENV, f.spec_string());
    }
    let mut child = cmd.spawn().context("spawn local worker")?;
    let mut log = BufReader::new(child.stderr.take().expect("piped stderr"));
    let mut line = String::new();
    log.read_line(&mut line).context("read worker announce line")?;
    let addr = Json::parse(line.trim())
        .ok()
        .and_then(|j| j.get("worker_listening").as_str().map(str::to_string));
    let Some(addr) = addr else {
        let _ = child.kill();
        let _ = child.wait();
        bail!("worker did not announce a listening address (got {line:?})");
    };
    Ok((child, log, addr))
}

/// Connect with retry (externally-started workers may still be binding)
/// under the `--connect-timeout` deadline; the error names the address
/// that could not be reached.
fn connect_retry(addr: &str, deadline: Duration) -> Result<TcpStream> {
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(_) if t0.elapsed() < deadline => {
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) => {
                return Err(anyhow!(
                    "connect worker {addr}: {e} (gave up after {:.1}s; see --connect-timeout)",
                    t0.elapsed().as_secs_f64()
                ))
            }
        }
    }
}

/// Write one message; an I/O failure means the worker is gone.
fn send(codec: &mut TcpCodec, w: usize, msg: &Json) -> Result<()> {
    codec
        .write_json(msg)
        .map_err(|e| anyhow!("[{ERR_WORKER_LOST}] worker {w}: write failed: {e}"))
}

/// Read one parsed message before `deadline`. `Ok(None)` means the
/// deadline passed with no complete reply — the caller decides whether
/// that retires the worker (round gather) or fails the stage (setup).
/// EOF or a transport error is a structured worker-lost failure,
/// surfaced within one read-poll tick of the OS seeing the close — the
/// coordinator never hangs on a dead peer.
fn recv_deadline(codec: &mut TcpCodec, w: usize, deadline: Instant) -> Result<Option<Json>> {
    loop {
        match codec.read_frame_deadline(deadline) {
            Ok(Some(Frame::Line(line))) => {
                let t = line.trim();
                if t.is_empty() {
                    continue;
                }
                return Json::parse(t)
                    .map(Some)
                    .map_err(|e| anyhow!("[{ERR_PROTOCOL}] worker {w}: bad response line: {e}"));
            }
            Ok(Some(Frame::Idle)) => continue, // read_frame_deadline consumes these
            Ok(Some(Frame::Eof)) => {
                bail!("[{ERR_WORKER_LOST}] worker {w}: connection closed mid-session")
            }
            Ok(Some(Frame::Overflow)) | Ok(Some(Frame::NotUtf8)) => {
                bail!("[{ERR_PROTOCOL}] worker {w}: unreadable response line")
            }
            Ok(None) => return Ok(None),
            Err(e) => bail!("[{ERR_WORKER_LOST}] worker {w}: {e}"),
        }
    }
}

/// [`recv_deadline`] that treats the deadline as fatal (setup stages,
/// where there is no lost-worker recovery to fall back on).
fn recv_required(
    codec: &mut TcpCodec,
    w: usize,
    stage: &str,
    timeout: Duration,
) -> Result<Json> {
    recv_deadline(codec, w, Instant::now() + timeout)?.ok_or_else(|| {
        anyhow!(
            "[{ERR_WORKER_LOST}] worker {w}: no {stage} reply within {:.1}s",
            timeout.as_secs_f64()
        )
    })
}

/// Fail on a structured error reply; otherwise require `"ok": true`.
fn expect_ok(reply: &Json, w: usize, stage: &str) -> Result<()> {
    if reply.get("error") != &Json::Null {
        bail!(
            "worker {w} rejected {stage}: [{}] {}",
            reply.get("error").get("code").as_str().unwrap_or("?"),
            reply.get("error").get("message").as_str().unwrap_or("?")
        );
    }
    if reply.get("ok").as_bool() != Some(true) {
        bail!("[{ERR_PROTOCOL}] worker {w}: expected ok to {stage}, got {reply}");
    }
    Ok(())
}

/// Full session setup over one connection: hello (spec regeneration,
/// checked against `n`) then the shard assignment. Used worker-by-worker
/// on the respawn path; initial setup pipelines the same messages across
/// all workers instead.
fn handshake(
    codec: &mut TcpCodec,
    w: usize,
    hello_msg: &Json,
    n: usize,
    shard: &[usize],
    reply_timeout: Duration,
) -> Result<()> {
    send(codec, w, hello_msg)?;
    let reply = recv_required(codec, w, "hello", reply_timeout)?;
    expect_ok(&reply, w, "hello")?;
    if reply.get("n").as_usize() != Some(n) {
        bail!("[{ERR_PROTOCOL}] worker {w}: regenerated n {} != {n}", reply.get("n"));
    }
    send(codec, w, &Json::obj(vec![("shard", ids_json(shard))]))?;
    let reply = recv_required(codec, w, "shard", reply_timeout)?;
    expect_ok(&reply, w, "shard")
}

/// One worker's round reply, validated: round echo, matching id/α arrays,
/// every id inside the worker's *current* ownership set (arbitrary after
/// re-sharding). Any unusable reply — deadline, EOF, error object,
/// garbage — is an `Err` that retires the worker.
fn gather_round_reply(
    codec: &mut TcpCodec,
    w: usize,
    r: usize,
    n: usize,
    owned: &HashSet<usize>,
    deadline: Instant,
) -> Result<(Vec<usize>, Vec<f64>, u64, u64)> {
    let Some(reply) = recv_deadline(codec, w, deadline)? else {
        bail!(
            "[{ERR_WORKER_LOST}] worker {w}: no round-{r} reply within the --round-timeout deadline"
        );
    };
    if reply.get("error") != &Json::Null {
        bail!(
            "worker {w} failed round {r}: [{}] {}",
            reply.get("error").get("code").as_str().unwrap_or("?"),
            reply.get("error").get("message").as_str().unwrap_or("?")
        );
    }
    if reply.get("round").as_usize() != Some(r) {
        bail!("[{ERR_PROTOCOL}] worker {w}: round echo mismatch in {reply}");
    }
    let ids =
        parse_ids(reply.get("ids")).map_err(|e| anyhow!("[{ERR_PROTOCOL}] worker {w}: {e}"))?;
    let al = parse_f64s(reply.get("alpha"))
        .map_err(|e| anyhow!("[{ERR_PROTOCOL}] worker {w}: {e}"))?;
    if ids.len() != al.len() || ids.iter().any(|i| *i >= n || !owned.contains(i)) {
        bail!("[{ERR_PROTOCOL}] worker {w}: summary ids outside its shard");
    }
    let values = reply.get("values_computed").as_f64().unwrap_or(0.0) as u64;
    let iters = reply.get("iterations").as_f64().unwrap_or(0.0) as u64;
    Ok((ids, al, values, iters))
}

/// Train `(tr, te)` by parallel block minimization over worker processes,
/// then conquer locally. Workers regenerate the split from `cfg`'s
/// dataset spec, so `tr`/`te` MUST come from that spec (the harness
/// loader) — only α summaries and row ids cross the wire. Worker loss
/// mid-round recovers per the module-level state machine.
pub fn train_distributed(cfg: &RunConfig, tr: &Dataset, te: &Dataset) -> Result<Outcome> {
    let t0 = Instant::now();
    let n = tr.len();
    let rounds = cfg.rounds.max(1);
    let round_timeout = Duration::from_secs_f64(cfg.round_timeout.max(1e-3));
    let connect_timeout = Duration::from_secs_f64(cfg.connect_timeout.max(1e-3));
    // Injected fault directive (tests and the bench fault leg): parsed
    // here, delivered only to the targeted spawned child's environment.
    let fault = FaultSpec::from_env()?;

    let mut roster = Roster { workers: Vec::new(), retired_bytes: 0 };

    // --- endpoints --------------------------------------------------------
    let local = cfg.workers_addr.is_none();
    let count = match &cfg.workers_addr {
        Some(list) => list.split(',').filter(|s| !s.trim().is_empty()).count(),
        None => cfg.dist_workers.max(1),
    };
    // Split the coordinator's thread budget so P workers don't put
    // P × threads dispatch workers on the machine.
    let per_worker = (cfg.threads / count.max(1)).max(1);
    match &cfg.workers_addr {
        Some(list) => {
            for addr in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                roster.workers.push(WorkerState {
                    addr: addr.to_string(),
                    codec: None,
                    shard: Vec::new(),
                    owned: HashSet::new(),
                    summary: (Vec::new(), Vec::new()),
                    child: None,
                    _log: None,
                    local: false,
                    retries_left: 0,
                });
            }
        }
        None => {
            for i in 0..count {
                let plan = fault.as_ref().filter(|f| f.worker == i).map(|f| &f.plan);
                let (child, log, addr) = spawn_one(cfg, per_worker, plan)?;
                roster.workers.push(WorkerState {
                    addr,
                    codec: None,
                    shard: Vec::new(),
                    owned: HashSet::new(),
                    summary: (Vec::new(), Vec::new()),
                    child: Some(child),
                    _log: Some(log),
                    local: true,
                    retries_left: cfg.worker_retries,
                });
            }
        }
    }
    if roster.workers.is_empty() {
        bail!("distributed: no worker addresses (--workers-addr was empty)");
    }
    let p = roster.workers.len();
    for w in 0..p {
        let stream = connect_retry(&roster.workers[w].addr, connect_timeout)?;
        roster.workers[w].codec = Some(wire::tcp_codec(stream).context("worker codec")?);
    }

    // --- handshake: dataset spec only, never data -------------------------
    let hello = Hello {
        dataset: cfg.dataset.clone(),
        n_train: tr.len(),
        n_test: te.len(),
        seed: cfg.seed,
        kernel: cfg.kernel.clone(),
        gamma: cfg.gamma,
        eta: cfg.eta,
        c: cfg.c,
        // Block sub-problems run at a looser tolerance (the conquer solve
        // enforces cfg.eps on the whole problem) — same policy as the
        // DC-SVM divide phase.
        eps: cfg.eps.max(1e-3),
    };
    let hello_msg = Json::obj(vec![("hello", hello.to_json())]);
    for w in 0..p {
        send(roster.workers[w].codec.as_mut().expect("connected"), w, &hello_msg)?;
    }
    for w in 0..p {
        let codec = roster.workers[w].codec.as_mut().expect("connected");
        let reply = recv_required(codec, w, "hello", round_timeout)?;
        expect_ok(&reply, w, "hello")?;
        if reply.get("n").as_usize() != Some(n) {
            bail!("[{ERR_PROTOCOL}] worker {w}: regenerated n {} != {n}", reply.get("n"));
        }
    }

    // --- shard ownership: round-robin i mod P at startup ------------------
    for w in 0..p {
        let shard: Vec<usize> = (w..n).step_by(p).collect();
        roster.workers[w].owned = shard.iter().copied().collect();
        roster.workers[w].shard = shard;
    }
    for w in 0..p {
        let msg = Json::obj(vec![("shard", ids_json(&roster.workers[w].shard))]);
        send(roster.workers[w].codec.as_mut().expect("connected"), w, &msg)?;
    }
    for w in 0..p {
        let codec = roster.workers[w].codec.as_mut().expect("connected");
        let reply = recv_required(codec, w, "shard", round_timeout)?;
        expect_ok(&reply, w, "shard")?;
    }

    // --- rounds: broadcast external summaries, gather block solutions,
    //     recover from losses, replay interrupted rounds -------------------
    let mut worker_values = 0u64;
    let mut worker_iters = 0u64;
    let mut workers_lost = 0u64;
    let mut resharded_rows = 0u64;
    let mut rounds_replayed = 0u64;
    let mut respawns = 0u64;
    let mut r = 1;
    while r <= rounds {
        let live = roster.live();
        // Jacobi-style: every worker sees the *previous* round's committed
        // summaries from its live peers, so all block solves run
        // concurrently. A send failure retires the worker immediately.
        let mut lost: Vec<(usize, String)> = Vec::new();
        for &w in &live {
            let mut ext_ids = Vec::new();
            let mut ext_alpha = Vec::new();
            for &o in &live {
                if o != w {
                    ext_ids.extend_from_slice(&roster.workers[o].summary.0);
                    ext_alpha.extend_from_slice(&roster.workers[o].summary.1);
                }
            }
            let msg = Json::obj(vec![
                ("round", Json::from(r)),
                ("ext_ids", ids_json(&ext_ids)),
                ("ext_alpha", Json::arr_f64(&ext_alpha)),
            ]);
            let codec = roster.workers[w].codec.as_mut().expect("live");
            if let Err(e) = send(codec, w, &msg) {
                lost.push((w, e.to_string()));
            }
        }
        // One absolute deadline for the whole gather: the round, not each
        // reply, is deadline-bounded (replies buffer while earlier ones
        // are read, so one stalled worker costs at most one timeout).
        let deadline = Instant::now() + round_timeout;
        let mut fresh: Vec<(usize, Vec<usize>, Vec<f64>, u64, u64)> = Vec::new();
        for &w in &live {
            if lost.iter().any(|(l, _)| *l == w) {
                continue;
            }
            let WorkerState { codec, owned, .. } = &mut roster.workers[w];
            match gather_round_reply(codec.as_mut().expect("live"), w, r, n, owned, deadline) {
                Ok(summary) => fresh.push((w, summary.0, summary.1, summary.2, summary.3)),
                Err(e) => lost.push((w, e.to_string())),
            }
        }
        if lost.is_empty() {
            for (w, ids, al, values, iters) in fresh {
                worker_values += values;
                worker_iters += iters;
                roster.workers[w].summary = (ids, al);
            }
            r += 1;
            continue;
        }

        // --- recovery: this attempt's replies are discarded wholesale and
        // round r replays once ownership is consistent again.
        workers_lost += lost.len() as u64;
        let mut need_rows: Vec<usize> = Vec::new();
        for (w, reason) in lost {
            eprintln!(
                "[distributed] worker {w} ({}) lost in round {r}: {reason}",
                roster.workers[w].addr
            );
            roster.retire(w);
            let mut recovered = false;
            let total_retries = cfg.worker_retries;
            while roster.workers[w].local && roster.workers[w].retries_left > 0 {
                let attempt = total_retries - roster.workers[w].retries_left + 1;
                roster.workers[w].retries_left -= 1;
                match respawn_worker(
                    cfg,
                    per_worker,
                    &hello_msg,
                    n,
                    w,
                    &mut roster.workers[w],
                    connect_timeout,
                    round_timeout,
                ) {
                    Ok(()) => {
                        eprintln!(
                            "[distributed] worker {w} respawned at {} (attempt {attempt}/{total_retries})",
                            roster.workers[w].addr
                        );
                        respawns += 1;
                        recovered = true;
                        break;
                    }
                    Err(e) => {
                        eprintln!(
                            "[distributed] respawn attempt {attempt}/{total_retries} for worker {w} failed: {e:#}"
                        );
                        // Linear backoff before the next attempt.
                        std::thread::sleep(Duration::from_millis(100 * attempt as u64));
                    }
                }
            }
            if !recovered {
                need_rows.push(w);
            }
        }

        // --- re-shard: move the dead workers' rows (with their last
        // committed α as warm seeds) onto survivors, round-robin.
        let mut pending: Vec<(usize, f64)> = Vec::new();
        for &w in &need_rows {
            let ws = &mut roster.workers[w];
            let seeds: HashMap<usize, f64> =
                ws.summary.0.iter().copied().zip(ws.summary.1.iter().copied()).collect();
            for &i in &ws.shard {
                pending.push((i, seeds.get(&i).copied().unwrap_or(0.0)));
            }
            ws.shard.clear();
            ws.owned.clear();
            ws.summary = (Vec::new(), Vec::new());
        }
        while !pending.is_empty() {
            let survivors = roster.live();
            if survivors.is_empty() {
                bail!(
                    "[{ERR_WORKER_LOST}] all {p} workers lost (round {r}): \
                     nothing left to re-shard onto"
                );
            }
            let mut per: Vec<Vec<(usize, f64)>> = vec![Vec::new(); survivors.len()];
            for (k, row) in pending.drain(..).enumerate() {
                per[k % survivors.len()].push(row);
            }
            for (k, rows) in per.into_iter().enumerate() {
                if rows.is_empty() {
                    continue;
                }
                let s = survivors[k];
                let expect = roster.workers[s].shard.len() + rows.len();
                let codec = roster.workers[s].codec.as_mut().expect("live");
                match send_reshard(codec, s, &rows, expect, round_timeout) {
                    Ok(()) => {
                        resharded_rows += rows.len() as u64;
                        let ws = &mut roster.workers[s];
                        for (i, _seed) in rows {
                            ws.shard.push(i);
                            ws.owned.insert(i);
                        }
                    }
                    Err(e) => {
                        // The survivor died mid-re-shard: retire it and
                        // put both its own rows and this batch back.
                        eprintln!(
                            "[distributed] worker {s} ({}) lost during re-shard: {e}",
                            roster.workers[s].addr
                        );
                        workers_lost += 1;
                        roster.retire(s);
                        let ws = &mut roster.workers[s];
                        let seeds: HashMap<usize, f64> = ws
                            .summary
                            .0
                            .iter()
                            .copied()
                            .zip(ws.summary.1.iter().copied())
                            .collect();
                        for &i in &ws.shard {
                            pending.push((i, seeds.get(&i).copied().unwrap_or(0.0)));
                        }
                        ws.shard.clear();
                        ws.owned.clear();
                        ws.summary = (Vec::new(), Vec::new());
                        pending.extend(rows);
                    }
                }
            }
        }
        if roster.live().is_empty() {
            bail!("[{ERR_WORKER_LOST}] all {p} workers lost (round {r})");
        }
        rounds_replayed += 1;
        eprintln!(
            "[distributed] replaying round {r} over {} surviving worker(s)",
            roster.live().len()
        );
    }

    // --- release workers (best effort; the run already has everything).
    // The ok reply is consumed so workers finish their session before the
    // coordinator closes the sockets (no write-after-close races).
    for w in roster.live() {
        let codec = roster.workers[w].codec.as_mut().expect("live");
        if codec.write_json(&Json::obj(vec![("shutdown", Json::from(true))])).is_ok() {
            let _ = recv_deadline(codec, w, Instant::now() + Duration::from_secs(5));
        }
    }
    let comm_bytes: u64 = roster.retired_bytes
        + roster
            .workers
            .iter()
            .filter_map(|w| w.codec.as_ref())
            .map(|c| c.bytes_in() + c.bytes_out())
            .sum::<u64>();

    // --- conquer: gather α, one warm-started exact solve at cfg.eps ------
    let mut alpha = vec![0f64; n];
    for ws in &roster.workers {
        for (&i, &a) in ws.summary.0.iter().zip(&ws.summary.1) {
            alpha[i] = a;
        }
    }
    drop(roster);
    let kind = cfg.kernel_kind()?;
    let kernel = make_kernel(kind, &cfg.backend, tr.dim)?;
    let ctx = KernelContext::new(tr, kernel.as_ref(), (cfg.cache_mb.max(1)) << 20)
        .with_threads(cfg.threads);
    let mut solver = SmoSolver::new(
        ctx.view_full(),
        SmoConfig { c: cfg.c, eps: cfg.eps, ..SmoConfig::default() },
    );
    let res = solver.solve_warm(Some(alpha.as_slice()), &mut |_| {});
    let model = SvmModel::from_ctx_alpha(&ctx, &res.alpha);
    let te_ctx = KernelContext::new(te, kernel.as_ref(), 1 << 20).with_threads(cfg.threads);
    let accuracy = model.accuracy_ctx(&te_ctx);

    Ok(Outcome {
        algo: "Distributed",
        train_s: t0.elapsed().as_secs_f64(),
        accuracy,
        objective: Some(res.objective),
        svs: res.sv_count,
        cache_hit_rate: Some(res.cache_hit_rate),
        simd_tier: crate::kernel::simd_tier().name(),
        comm_bytes: Some(comm_bytes),
        rounds: Some(rounds as u64),
        worker_values_computed: Some(worker_values),
        workers_lost: Some(workers_lost),
        resharded_rows: Some(resharded_rows),
        rounds_replayed: Some(rounds_replayed),
        respawns: Some(respawns),
        note: format!(
            "workers={p} spawned={local} conquer_iters={} worker_iters={worker_iters}",
            res.iterations
        ),
        ..Default::default()
    })
}

/// One respawn attempt for worker `w`: fresh child (never with a fault
/// plan), connect, hello, same shard. On failure the partially-started
/// child is killed; the caller decides whether to retry or re-shard.
#[allow(clippy::too_many_arguments)]
fn respawn_worker(
    cfg: &RunConfig,
    threads: usize,
    hello_msg: &Json,
    n: usize,
    w: usize,
    ws: &mut WorkerState,
    connect_timeout: Duration,
    reply_timeout: Duration,
) -> Result<()> {
    let (mut child, log, addr) = spawn_one(cfg, threads, None)?;
    let setup = (|| -> Result<TcpCodec> {
        let stream = connect_retry(&addr, connect_timeout)?;
        let mut codec = wire::tcp_codec(stream).context("worker codec")?;
        handshake(&mut codec, w, hello_msg, n, &ws.shard, reply_timeout)?;
        Ok(codec)
    })();
    match setup {
        Ok(codec) => {
            ws.addr = addr;
            ws.child = Some(child);
            ws._log = Some(log);
            ws.codec = Some(codec);
            Ok(())
        }
        Err(e) => {
            let _ = child.kill();
            let _ = child.wait();
            Err(e)
        }
    }
}

/// Hand `rows` (id, warm-seed α) to survivor `s` via a `reshard` message
/// and verify the acknowledged shard size.
fn send_reshard(
    codec: &mut TcpCodec,
    s: usize,
    rows: &[(usize, f64)],
    expect_rows: usize,
    reply_timeout: Duration,
) -> Result<()> {
    let ids: Vec<usize> = rows.iter().map(|(i, _)| *i).collect();
    let seeds: Vec<f64> = rows.iter().map(|(_, a)| *a).collect();
    let msg = Json::obj(vec![("reshard", ids_json(&ids)), ("alpha", Json::arr_f64(&seeds))]);
    send(codec, s, &msg)?;
    let reply = recv_required(codec, s, "reshard", reply_timeout)?;
    expect_ok(&reply, s, "reshard")?;
    if reply.get("rows").as_usize() != Some(expect_rows) {
        bail!(
            "[{ERR_PROTOCOL}] worker {s}: reshard acknowledged {} rows, expected {expect_rows}",
            reply.get("rows")
        );
    }
    Ok(())
}
