//! The worker side of distributed block minimization: one process, one
//! shard, one local [`KernelContext`].
//!
//! A worker serves exactly one coordinator session: `hello` (regenerate
//! the training split from its spec), `shard` (the row ids this worker
//! owns), then `round` messages — each re-solves the block dual with the
//! coordinator-supplied external α frozen into a linear offset
//! ([`SmoSolver::with_linear_offset`]), warm-started from the worker's own
//! previous α — until `done`/`shutdown`. Replies carry only (global id, α)
//! support-vector summaries; kernel values never leave the process.

use std::net::{TcpListener, TcpStream};

use anyhow::{Context, Result};

use crate::cache::KernelContext;
use crate::data::synthetic::all_specs;
use crate::data::Dataset;
use crate::harness::make_kernel;
use crate::kernel::KernelKind;
use crate::solver::{SmoConfig, SmoSolver};
use crate::util::json::Json;
use crate::util::wire::{self, error_response, Frame, TcpCodec};

use super::{
    parse_f64s, parse_ids, FaultKind, FaultPlan, Hello, ERR_BAD_REQUEST, ERR_PARSE, ERR_PROTOCOL,
};

/// Per-process worker settings (`dcsvm worker` flags).
pub struct WorkerOptions {
    /// Kernel-dispatch thread budget (0 = the context default: all cores).
    pub threads: usize,
    /// Kernel-row cache budget of the shard context, in MB.
    pub cache_mb: usize,
    /// "native" | "pjrt" | "auto"
    pub backend: String,
    /// Deterministic injected fault ([`super::FAULT_SELF_ENV`]); tests and
    /// the bench fault leg only — production workers run with `None`.
    pub fault: Option<FaultPlan>,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        WorkerOptions { threads: 0, cache_mb: 256, backend: "native".into(), fault: None }
    }
}

/// Serve one coordinator session on `listener`. The bound address is
/// announced first as one parseable stderr line
/// (`{"worker_listening": ADDR}`) — binding port 0 picks an ephemeral
/// port, and a spawning coordinator discovers it from this line. Returns
/// after the session ends (shutdown, done, or coordinator EOF).
pub fn run_worker(listener: TcpListener, opts: &WorkerOptions) -> Result<()> {
    let addr = listener.local_addr().context("worker: local_addr")?;
    eprintln!(
        "{}",
        Json::obj(vec![("worker_listening", Json::from(addr.to_string()))])
    );
    let (stream, _) = listener.accept().context("worker: accept")?;
    serve_session(stream, opts)
}

/// Read frames until one parses as JSON; `None` on EOF. Invalid JSON gets
/// a structured `parse` error reply and the read continues (framing is
/// intact); an over-cap line is unrecoverable and ends the session.
fn read_msg(codec: &mut TcpCodec) -> Result<Option<Json>> {
    loop {
        match codec.read_frame().context("worker: read")? {
            Frame::Line(line) => {
                let t = line.trim();
                if t.is_empty() {
                    continue;
                }
                match Json::parse(t) {
                    Ok(j) => return Ok(Some(j)),
                    Err(e) => codec.write_json(&error_response(
                        Json::Null,
                        ERR_PARSE,
                        &format!("invalid request JSON: {e}"),
                    ))?,
                }
            }
            Frame::Idle => continue,
            Frame::Eof => return Ok(None),
            Frame::Overflow => {
                codec.write_json(&error_response(
                    Json::Null,
                    ERR_BAD_REQUEST,
                    &format!("request line exceeds {} bytes", wire::MAX_FRAME_BYTES),
                ))?;
                return Ok(None);
            }
            Frame::NotUtf8 => {
                codec.write_json(&error_response(
                    Json::Null,
                    ERR_PARSE,
                    "request line is not valid UTF-8",
                ))?;
            }
        }
    }
}

/// Reply with a structured error object. Returns `Ok(())` so callers can
/// decide whether the session continues.
fn send_error(codec: &mut TcpCodec, code: &str, message: &str) -> Result<()> {
    codec.write_json(&error_response(Json::Null, code, message))?;
    Ok(())
}

/// Serve one coordinator connection end to end.
pub fn serve_session(stream: TcpStream, opts: &WorkerOptions) -> Result<()> {
    let mut codec = wire::tcp_codec(stream).context("worker: codec")?;

    // --- hello: regenerate the training split from its spec --------------
    let Some(msg) = read_msg(&mut codec)? else { return Ok(()) };
    let hello_obj = msg.get("hello");
    if hello_obj == &Json::Null {
        return send_error(&mut codec, ERR_PROTOCOL, "expected a hello message first");
    }
    let hello = match Hello::from_json(hello_obj) {
        Ok(h) => h,
        Err(e) => return send_error(&mut codec, ERR_BAD_REQUEST, &format!("{e}")),
    };
    let Some(spec) = all_specs().into_iter().find(|s| s.name == hello.dataset) else {
        return send_error(
            &mut codec,
            ERR_BAD_REQUEST,
            &format!("unknown dataset '{}'", hello.dataset),
        );
    };
    let kind = match hello.kernel.as_str() {
        "rbf" => KernelKind::Rbf { gamma: hello.gamma as f32 },
        "poly" => KernelKind::Poly { gamma: hello.gamma as f32, eta: hello.eta as f32 },
        "linear" => KernelKind::Linear,
        other => {
            return send_error(
                &mut codec,
                ERR_BAD_REQUEST,
                &format!("unknown kernel '{other}'"),
            )
        }
    };
    // Deterministic per seed: this split is bit-identical to the
    // coordinator's (and every other worker's) copy.
    let (tr, _te) =
        crate::data::synthetic::generate_split(&spec, hello.n_train, hello.n_test, hello.seed);
    codec.write_json(&Json::obj(vec![
        ("ok", Json::from(true)),
        ("n", Json::from(tr.len())),
    ]))?;

    // --- shard: the row ids this worker owns ------------------------------
    let Some(msg) = read_msg(&mut codec)? else { return Ok(()) };
    let mut shard = match parse_ids(msg.get("shard")) {
        Ok(ids) if !ids.is_empty() && ids.iter().all(|&i| i < tr.len()) => ids,
        Ok(_) => {
            return send_error(&mut codec, ERR_BAD_REQUEST, "shard ids empty or out of range")
        }
        Err(_) => return send_error(&mut codec, ERR_PROTOCOL, "expected a shard message"),
    };
    codec.write_json(&Json::obj(vec![
        ("ok", Json::from(true)),
        ("rows", Json::from(shard.len())),
    ]))?;

    // --- rounds over this shard's own kernel context ----------------------
    let kernel = make_kernel(kind, &opts.backend, tr.dim)
        .map_err(|e| anyhow::anyhow!("worker: kernel backend: {e}"))?;
    let ctx = KernelContext::new(&tr, kernel.as_ref(), opts.cache_mb << 20);
    if opts.threads > 0 {
        ctx.set_threads(opts.threads);
    }
    let smo_cfg = SmoConfig { c: hello.c, eps: hello.eps, ..SmoConfig::default() };
    let mut alpha_local = vec![0f64; shard.len()];

    loop {
        let Some(msg) = read_msg(&mut codec)? else { return Ok(()) };
        if msg.get("shutdown") != &Json::Null || msg.get("done") != &Json::Null {
            codec.write_json(&Json::obj(vec![("ok", Json::from(true))]))?;
            return Ok(());
        }
        // Re-shard: adopt rows from a worker the coordinator lost. The
        // context already covers the full training set (hello regenerated
        // it), so extending ownership is pure bookkeeping; optional
        // `alpha` seeds warm-start the adopted rows from the lost
        // worker's last committed summary.
        if msg.get("reshard") != &Json::Null {
            let ids = match parse_ids(msg.get("reshard")) {
                Ok(ids) => ids,
                Err(_) => {
                    send_error(&mut codec, ERR_PROTOCOL, "reshard needs an id array")?;
                    continue;
                }
            };
            if ids.is_empty() || ids.iter().any(|&i| i >= tr.len() || shard.contains(&i)) {
                send_error(
                    &mut codec,
                    ERR_BAD_REQUEST,
                    "reshard ids empty, out of range, or already owned",
                )?;
                continue;
            }
            let seeds = if msg.get("alpha") != &Json::Null {
                match parse_f64s(msg.get("alpha")) {
                    Ok(a) if a.len() == ids.len() => a,
                    _ => {
                        send_error(
                            &mut codec,
                            ERR_PROTOCOL,
                            "reshard alpha must match the id array",
                        )?;
                        continue;
                    }
                }
            } else {
                vec![0.0; ids.len()]
            };
            shard.extend_from_slice(&ids);
            alpha_local.extend_from_slice(&seeds);
            codec.write_json(&Json::obj(vec![
                ("ok", Json::from(true)),
                ("rows", Json::from(shard.len())),
            ]))?;
            continue;
        }
        let Some(r) = msg.get("round").as_usize() else {
            send_error(&mut codec, ERR_PROTOCOL, "expected round, reshard, done, or shutdown")?;
            continue;
        };
        // Injected fault at the pinned round (tests/bench only).
        if let Some(fault) = opts.fault.filter(|f| f.round == r) {
            match fault.kind {
                // Crash: drop the connection without replying.
                FaultKind::Exit => return Ok(()),
                // Hang: never reply, but unblock once the coordinator
                // gives up on us and closes the connection.
                FaultKind::Stall => loop {
                    match codec.read_frame() {
                        Ok(Frame::Eof) | Err(_) => return Ok(()),
                        Ok(_) => continue,
                    }
                },
                // Corruption: a syntactically-valid line that is not a
                // round reply; the next read ends the session when the
                // coordinator drops us.
                FaultKind::Garbage => {
                    codec.write_json(&Json::from("garbage-frame"))?;
                    continue;
                }
            }
        }
        let (ext_ids, ext_alpha) =
            match (parse_ids(msg.get("ext_ids")), parse_f64s(msg.get("ext_alpha"))) {
                (Ok(i), Ok(a)) if i.len() == a.len() => (i, a),
                _ => {
                    send_error(
                        &mut codec,
                        ERR_PROTOCOL,
                        "round needs matching ext_ids/ext_alpha arrays",
                    )?;
                    continue;
                }
            };
        if ext_ids.iter().any(|&j| j >= tr.len()) {
            send_error(&mut codec, ERR_BAD_REQUEST, "external ids out of range")?;
            continue;
        }

        // Frozen external α enters as the linear offset
        // q_i = y_i Σ_ext ᾱ_j y_j K(x_i, x_j): one fused decision
        // dispatch, |shard|×|ext| kernel entries.
        let mut values = 0u64;
        let mut solver = SmoSolver::new(ctx.view(&shard), smo_cfg.clone());
        if !ext_ids.is_empty() {
            let q = external_offset(&ctx, &tr, &shard, &ext_ids, &ext_alpha);
            let entries = (shard.len() as u64) * (ext_ids.len() as u64);
            ctx.count_external_values(entries);
            values += entries;
            solver = solver.with_linear_offset(q);
        }
        let warm = alpha_local.iter().any(|&a| a != 0.0);
        let res = solver.solve_warm(warm.then_some(alpha_local.as_slice()), &mut |_| {});
        values += res.values_computed;
        alpha_local = res.alpha;

        // Summary reply: only the nonzero α, by global id.
        let mut ids = Vec::new();
        let mut al = Vec::new();
        for (t, &a) in alpha_local.iter().enumerate() {
            if a != 0.0 {
                ids.push(shard[t]);
                al.push(a);
            }
        }
        codec.write_json(&Json::obj(vec![
            ("round", Json::from(r)),
            ("ids", super::ids_json(&ids)),
            ("alpha", Json::arr_f64(&al)),
            ("objective", Json::from(res.objective)),
            ("values_computed", Json::from(values as f64)),
            ("iterations", Json::from(res.iterations)),
        ]))?;
    }
}

/// The linear offset of the block sub-problem: for each shard-local i,
/// `q_i = y_i Σ_j ᾱ_j y_j K(x_i, x_j)` over the external (id, α) pairs —
/// one fused decision dispatch with coefficients `ᾱ_j y_j`.
fn external_offset(
    ctx: &KernelContext,
    tr: &Dataset,
    shard: &[usize],
    ext_ids: &[usize],
    ext_alpha: &[f64],
) -> Vec<f64> {
    let dim = tr.dim;
    let mut xq = Vec::with_capacity(shard.len() * dim);
    let mut qn = Vec::with_capacity(shard.len());
    for &i in shard {
        xq.extend_from_slice(tr.row(i));
        qn.push(ctx.norm(i));
    }
    let mut xd = Vec::with_capacity(ext_ids.len() * dim);
    let mut dn = Vec::with_capacity(ext_ids.len());
    let mut coef = Vec::with_capacity(ext_ids.len());
    for (&j, &a) in ext_ids.iter().zip(ext_alpha) {
        xd.extend_from_slice(tr.row(j));
        dn.push(ctx.norm(j));
        coef.push((a * tr.y[j] as f64) as f32);
    }
    let mut dv = vec![0f32; shard.len()];
    ctx.decision_dispatch(&xq, &qn, &xd, &dn, dim, &coef, &mut dv);
    shard
        .iter()
        .zip(&dv)
        .map(|(&i, &d)| tr.y[i] as f64 * d as f64)
        .collect()
}
