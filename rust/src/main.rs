//! dcsvm — CLI launcher for the DC-SVM framework.
//!
//! ```text
//! dcsvm datasets                         # Table-2 counterpart statistics
//! dcsvm train   [--algo dcsvm] [--dataset covtype-like] [--gamma 32] ...
//! dcsvm predict --model m.json --dataset covtype-like
//! dcsvm kmeans  [--dataset ...] [--k-base 4] # partition quality report
//! dcsvm sweep   [--dataset ...]          # (C, γ) grid, Tables 7–10 style
//! dcsvm serve   --model m.json [--listen ADDR] [--batch 256] [--workers 4]
//! dcsvm worker  --listen ADDR            # distributed-training worker
//! dcsvm info                             # backend/artifact status
//! ```
//!
//! Flags are `--key value`; `--config file.json` loads a config file first,
//! later flags override (see rust/src/config). Python is never invoked:
//! the PJRT backend loads pre-built `artifacts/*.hlo.txt`.

use anyhow::{anyhow, bail, Context, Result};

use dcsvm::bench::{fmt_secs, Table};
use dcsvm::config::{Algo, RunConfig};
use dcsvm::data::synthetic;
use dcsvm::harness;
use dcsvm::kernel::BlockKernel;
use dcsvm::predict::SvmModel;
use dcsvm::serving::{ServingContext, ServingModel};
use dcsvm::util::flags::{FlagSet, FlagSpec};
use dcsvm::util::json::Json;
use dcsvm::util::logging;
use dcsvm::util::prng::Pcg64;

fn main() {
    logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "datasets" => cmd_datasets(),
        "train" => cmd_train(rest),
        "predict" => cmd_predict(rest),
        "update" => cmd_update(rest),
        "kmeans" => cmd_kmeans(rest),
        "sweep" => cmd_sweep(rest),
        "serve" => cmd_serve(rest),
        "worker" => cmd_worker(rest),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `dcsvm help`)"),
    }
}

fn print_usage() {
    println!(
        "dcsvm — divide-and-conquer kernel SVM (Hsieh, Si, Dhillon, ICML 2014)\n\
         \n\
         commands:\n\
         \x20 datasets                      dataset statistics (Table 2)\n\
         \x20 train    [--flags]            train one algorithm, report time/acc\n\
         \x20 predict  --model M [--flags]  load a saved model, evaluate\n\
         \x20 update   --model M --data F   warm-started incremental update from\n\
         \x20                               new labeled LIBSVM rows (flags:\n\
         \x20                               `dcsvm update --help`)\n\
         \x20 kmeans   [--flags]            two-step kernel kmeans report\n\
         \x20 sweep    [--flags]            (C, γ) grid (Tables 7–10 style)\n\
         \x20 serve    --model M [--flags]  persistent server: LIBSVM rows on stdin\n\
         \x20                               or NDJSON over TCP with --listen ADDR\n\
         \x20                               (flags: `dcsvm serve --help`)\n\
         \x20 worker   --listen ADDR        distributed-training worker: serves one\n\
         \x20                               coordinator session over the wire\n\
         \x20                               protocol (flags: `dcsvm worker --help`)\n\
         \x20 info                          backend / artifact status\n\
         \n\
         common flags: --algo {{dcsvm,early,libsvm,cascade,lasvm,llsvm,fastfood,ltpu,spsvm,ovo}}\n\
         \x20 (--algo ovo trains one-vs-one multiclass over one shared kernel\n\
         \x20  context; --dataset accepts mc<K> synthetic mixtures, e.g. mc4,\n\
         \x20  or a multi-label LIBSVM file path — binary specs run as 2 classes)\n\
         \x20 --dataset NAME --n-train N --n-test N --kernel {{rbf,poly,linear}}\n\
         \x20 --gamma G --c C --eps E --levels L --k-base K --sample-m M\n\
         \x20 --backend {{auto,native,pjrt}} --budget B --seed S --config FILE\n\
         \x20 --threads T (default: DCSVM_THREADS or all cores; also fans large\n\
         \x20              kernel dispatches out over row panels, bit-identically)\n\
         \x20 --cache-mb MB\n\
         \x20 --segments {{true,false}} (segment-granular divide cache; default true)\n\
         \x20 --registry-cap-mb MB (gathered segment-feature cap; 0 = unlimited)\n\
         \x20 --quant-route {{true,false}} (int8-quantized routing/early prediction;\n\
         \x20              exact solves untouched; default false)\n\
         \x20 --save-model FILE\n\
         \x20 --distributed {{true,false}} --workers N --workers-addr LIST --rounds R\n\
         \x20              (parallel block minimization over worker processes;\n\
         \x20               spawns N local workers unless --workers-addr names\n\
         \x20               running `dcsvm worker` endpoints)\n\
         \x20 --round-timeout SECS --connect-timeout SECS --worker-retries N\n\
         \x20              (fault tolerance: a worker that dies, garbles, or\n\
         \x20               stalls past the round deadline is respawned or its\n\
         \x20               rows re-shard onto survivors and the round replays)"
    );
}

/// Parse `--key value` flags into a RunConfig (honoring `--config`).
fn parse_cfg(args: &[String]) -> Result<RunConfig> {
    let mut cfg = RunConfig::default();
    // First pass: --config file
    let mut i = 0;
    while i + 1 < args.len() {
        if args[i] == "--config" {
            cfg = RunConfig::from_file(std::path::Path::new(&args[i + 1]))?;
        }
        i += 2;
    }
    // Second pass: flag overrides
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(key) = a.strip_prefix("--") else {
            bail!("expected --flag, got '{a}'");
        };
        if key == "config" {
            i += 2;
            continue;
        }
        let Some(val) = args.get(i + 1) else {
            bail!("flag --{key} needs a value");
        };
        cfg.apply(key, val).with_context(|| format!("flag --{key}"))?;
        i += 2;
    }
    Ok(cfg)
}

fn cmd_datasets() -> Result<()> {
    let mut t = Table::new(&["dataset", "n_train", "n_test", "dim", "pos%", "scaled"]);
    for spec in synthetic::all_specs() {
        let (ntr, nte) = synthetic::default_sizes(spec.name);
        let (tr, _) = synthetic::generate_split(&spec, 2000.min(ntr), 100, 0);
        t.row(&[
            spec.name.to_string(),
            ntr.to_string(),
            nte.to_string(),
            spec.dim.to_string(),
            format!("{:.1}", 100.0 * tr.pos_frac()),
            spec.scale_unit.to_string(),
        ]);
    }
    t.print();
    println!("(synthetic counterparts of the paper's Table 2 — see DESIGN.md §5)");
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    let cfg = parse_cfg(args)?;
    if cfg.algo == Algo::Ovo {
        return cmd_train_ovo(&cfg);
    }
    if cfg.distributed || cfg.workers_addr.is_some() {
        return cmd_train_distributed(&cfg);
    }
    let (tr, te) = harness::load_dataset(&cfg)?;
    println!(
        "training {} on {} (n={}, d={}, kernel={} γ={} C={}, backend={})",
        cfg.algo.name(),
        cfg.dataset,
        tr.len(),
        tr.dim,
        cfg.kernel,
        cfg.gamma,
        cfg.c,
        cfg.backend
    );
    let out = harness::run(&cfg, &tr, &te)?;
    let mut extra = String::new();
    if let Some(h) = out.cache_hit_rate {
        extra.push_str(&format!(" cache_hit={h:.2}"));
    }
    if let Some(r) = out.final_rows {
        extra.push_str(&format!(" final_rows={r}"));
    }
    println!(
        "{}: time={} acc={:.2}% svs={}{} {}",
        out.algo,
        fmt_secs(out.train_s),
        100.0 * out.accuracy,
        out.svs,
        extra,
        out.note
    );
    if let Some(obj) = out.objective {
        println!("objective f(α) = {obj:.6}");
    }
    if let Some(path) = &cfg.save_model {
        let kind = cfg.kernel_kind()?;
        let kernel = harness::make_kernel(kind, &cfg.backend, tr.dim)?;
        let (json, svs) = train_model_for_save(&cfg, &tr, kernel.as_ref())?;
        std::fs::write(path, json.to_string())?;
        println!("model saved to {path} ({svs} SVs)");
    }
    Ok(())
}

/// `dcsvm train --distributed true` (or `--workers-addr ...`): parallel
/// block minimization over worker processes
/// ([`dcsvm::distributed::train_distributed`]) — only α summaries cross
/// the wire, and the structured counters (`comm_bytes`, `rounds`,
/// `worker_values_computed`) land in the same results.jsonl contract the
/// benches collect.
fn cmd_train_distributed(cfg: &RunConfig) -> Result<()> {
    if cfg.save_model.is_some() {
        bail!("--save-model is not supported with --distributed (train single-process to save)");
    }
    let (tr, te) = harness::load_dataset(cfg)?;
    println!(
        "training distributed block minimization on {} (n={}, d={}, kernel={} γ={} C={}, rounds={})",
        cfg.dataset,
        tr.len(),
        tr.dim,
        cfg.kernel,
        cfg.gamma,
        cfg.c,
        cfg.rounds.max(1)
    );
    let out = dcsvm::distributed::train_distributed(cfg, &tr, &te)?;
    println!(
        "{}: time={} acc={:.2}% svs={} comm_bytes={} rounds={} worker_values={} \
         workers_lost={} resharded={} replays={} respawns={} {}",
        out.algo,
        fmt_secs(out.train_s),
        100.0 * out.accuracy,
        out.svs,
        out.comm_bytes.unwrap_or(0),
        out.rounds.unwrap_or(0),
        out.worker_values_computed.unwrap_or(0),
        out.workers_lost.unwrap_or(0),
        out.resharded_rows.unwrap_or(0),
        out.rounds_replayed.unwrap_or(0),
        out.respawns.unwrap_or(0),
        out.note
    );
    if let Some(obj) = out.objective {
        println!("objective f(α) = {obj:.6}");
    }
    // Same env contract as harness::run — benches collect the distributed
    // counters from results.jsonl.
    if let Ok(dir) = std::env::var("DCSVM_RESULTS_DIR") {
        if !dir.is_empty() {
            let _ = harness::record_result_to(std::path::Path::new(&dir), cfg, &out);
        }
    }
    Ok(())
}

/// `dcsvm worker`: serve one distributed-training coordinator session
/// ([`dcsvm::distributed::run_worker`]). Binds `--listen` (port 0 picks an
/// ephemeral port) and announces the bound address as one parseable
/// stderr line, `{"worker_listening": ADDR}`.
fn cmd_worker(args: &[String]) -> Result<()> {
    use dcsvm::distributed::{run_worker, WorkerOptions, WORKER_FLAG_SET};
    let set = &WORKER_FLAG_SET;
    let Some(pairs) = set.parse(args)? else {
        println!("{}", set.usage());
        return Ok(());
    };
    let mut listen: Option<String> = None;
    let mut opts = WorkerOptions::default();
    for (flag, val) in pairs {
        match flag {
            "--listen" => listen = Some(val.to_string()),
            "--threads" => opts.threads = set.count("--threads", val)?,
            "--cache-mb" => opts.cache_mb = set.positive("--cache-mb", val)?,
            "--backend" => opts.backend = val.to_string(),
            _ => unreachable!("WORKER_FLAGS covers every match arm"),
        }
    }
    let Some(listen) = listen else {
        bail!("worker requires --listen ADDR\n{}", set.usage());
    };
    // Injected-fault plan, planted by the coordinator on this one child
    // (tests and the bench fault leg; never set by hand).
    opts.fault = dcsvm::distributed::FaultPlan::from_self_env()?;
    let listener = std::net::TcpListener::bind(listen.as_str())
        .with_context(|| format!("worker: bind {listen}"))?;
    run_worker(listener, &opts)
}

/// Train and serialize the model `--save-model` writes: an exact
/// [`SvmModel`] for dcsvm/libsvm, the early-prediction model (router +
/// local models) for `--algo early` — both loadable by `dcsvm serve`.
/// Note: this trains a second time after `harness::run`'s measured run
/// (the harness reports metrics, not models); threading models out of
/// the harness to avoid the retrain is future work.
fn train_model_for_save(
    cfg: &RunConfig,
    tr: &dcsvm::data::Dataset,
    kernel: &dyn BlockKernel,
) -> Result<(Json, usize)> {
    match cfg.algo {
        Algo::Libsvm | Algo::DcSvm => {
            let res = dcsvm::dcsvm::train(tr, kernel, &cfg.dcsvm_config()?);
            let model = SvmModel::from_alpha(tr, &res.alpha, cfg.kernel_kind()?);
            let svs = model.num_svs();
            Ok((model.to_json(), svs))
        }
        Algo::DcSvmEarly => {
            let res = dcsvm::dcsvm::train(tr, kernel, &cfg.dcsvm_config()?);
            let em = res
                .early_model
                .ok_or_else(|| anyhow!("early run produced no early model"))?;
            let svs = em.total_svs();
            Ok((em.to_json(), svs))
        }
        _ => bail!("--save-model supports kernel-expansion algos (dcsvm, early, libsvm, ovo)"),
    }
}

/// Resolve the train/test pair for `--algo ovo`, multiclass-first:
/// `mc<K>` (e.g. `mc4`) names a synthetic K-class mixture split by seed,
/// an existing file path is read as multi-label LIBSVM rows (the last
/// `--n-test` rows held out; 0 reports training accuracy), and any binary
/// synthetic spec is viewed as a 2-class problem.
fn load_multiclass(
    cfg: &RunConfig,
) -> Result<(dcsvm::multiclass::MulticlassDataset, dcsvm::multiclass::MulticlassDataset)> {
    use dcsvm::multiclass::{synthetic_multiclass, MulticlassDataset};
    if let Some(k) = cfg.dataset.strip_prefix("mc").and_then(|s| s.parse::<usize>().ok()) {
        if k < 2 {
            bail!("--dataset mc<K> needs K >= 2, got mc{k}");
        }
        let ntr = cfg.n_train.unwrap_or(400);
        let nte = cfg.n_test.unwrap_or(120);
        let dim = 4;
        let tr = synthetic_multiclass(k, ntr, dim, cfg.seed);
        let te = synthetic_multiclass(k, nte, dim, cfg.seed.wrapping_add(1));
        return Ok((tr, te));
    }
    let path = std::path::Path::new(&cfg.dataset);
    if path.exists() {
        let ds = MulticlassDataset::from_libsvm(path, None)?;
        let hold = cfg.n_test.unwrap_or(0).min(ds.len().saturating_sub(1));
        if hold == 0 {
            let te = MulticlassDataset::new(ds.x.clone(), ds.labels.clone(), ds.dim);
            return Ok((ds, te));
        }
        let (cut, dim) = (ds.len() - hold, ds.dim);
        let tr = MulticlassDataset::new(
            ds.x[..cut * dim].to_vec(),
            ds.labels[..cut].to_vec(),
            dim,
        );
        let te = MulticlassDataset::new(
            ds.x[cut * dim..].to_vec(),
            ds.labels[cut..].to_vec(),
            dim,
        );
        return Ok((tr, te));
    }
    let (tr, te) = harness::load_dataset(cfg)?;
    Ok((
        MulticlassDataset::from_binary(&tr),
        MulticlassDataset::from_binary(&te),
    ))
}

/// `dcsvm train --algo ovo`: all k(k−1)/2 pairwise DC-SVM machines over
/// ONE shared kernel context (pair restriction via segment views — cached
/// kernel columns computed for one pair are stitched into every later
/// pair that shares a class). `--save-model` writes the whole ensemble as
/// a single JSON that `dcsvm serve` loads and serves with per-class
/// SV blocks.
fn cmd_train_ovo(cfg: &RunConfig) -> Result<()> {
    let (tr, te) = load_multiclass(cfg)?;
    if tr.is_empty() {
        bail!("--algo ovo: empty training set from --dataset {}", cfg.dataset);
    }
    let kind = cfg.kernel_kind()?;
    let kernel = harness::make_kernel(kind, &cfg.backend, tr.dim)?;
    println!(
        "training OVO on {} (n={}, d={}, classes={}, kernel={} γ={} C={}, backend={})",
        cfg.dataset,
        tr.len(),
        tr.dim,
        tr.present_classes().len(),
        cfg.kernel,
        cfg.gamma,
        cfg.c,
        cfg.backend
    );
    let res = dcsvm::multiclass::train_ovo_shared(&tr, kernel.as_ref(), &cfg.dcsvm_config()?);
    let machines = res.model.machines.len();
    let votes = machines as u64 * te.len() as u64;
    let acc = res.model.accuracy(&te, kernel.as_ref());
    println!(
        "OVO: time={} acc={:.2}% svs={} machines={} pair_dispatches={} votes={}",
        fmt_secs(res.train_s),
        100.0 * acc,
        res.model.num_svs(),
        machines,
        res.pair_dispatches,
        votes
    );
    if res.pair_values_exact && machines > 1 {
        let parts: Vec<String> = res
            .pair_values
            .iter()
            .map(|(a, b, v)| format!("({a},{b})={v}"))
            .collect();
        println!("per-pair kernel values (shared-context reuse): {}", parts.join(" "));
    }
    if let Some(path) = &cfg.save_model {
        std::fs::write(path, res.model.to_json().to_string())?;
        println!(
            "model saved to {path} ({} SVs, {machines} machines)",
            res.model.num_svs()
        );
    }
    // Same env contract as harness::run — benches collect the multiclass
    // counters from results.jsonl.
    if let Ok(dir) = std::env::var("DCSVM_RESULTS_DIR") {
        if !dir.is_empty() {
            let vs = res.value_stats;
            let outcome = harness::Outcome {
                algo: cfg.algo.name(),
                train_s: res.train_s,
                accuracy: acc,
                objective: None,
                svs: res.model.num_svs(),
                cache_hit_rate: None,
                final_rows: None,
                segment_rows: Some(vs.segment_rows),
                divide_values: None,
                stitched_values: Some(vs.values_stitched),
                parallel_dispatches: Some(vs.parallel_dispatches),
                stitch_groups: Some(vs.stitch_groups),
                registry_bytes: None,
                simd_tier: dcsvm::kernel::simd_tier().name(),
                quantized_values: Some(vs.quantized_values),
                segment_regathers: None,
                update_values_computed: None,
                svs_added: None,
                svs_dropped: None,
                pair_dispatches: Some(res.pair_dispatches),
                votes: Some(votes),
                note: format!(
                    "classes={} machines={machines}",
                    res.model.present.len()
                ),
                ..Default::default()
            };
            let _ = harness::record_result_to(std::path::Path::new(&dir), cfg, &outcome);
        }
    }
    Ok(())
}

fn cmd_predict(args: &[String]) -> Result<()> {
    // extract --model, pass the rest to config
    let mut model_path = None;
    let mut rest = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--model" {
            model_path = args.get(i + 1).cloned();
            i += 2;
        } else {
            rest.push(args[i].clone());
            i += 1;
        }
    }
    let Some(model_path) = model_path else {
        bail!("predict requires --model FILE");
    };
    let cfg = parse_cfg(&rest)?;
    let text = std::fs::read_to_string(&model_path)
        .with_context(|| format!("read {model_path}"))?;
    let model = SvmModel::from_json(&Json::parse(&text)?)?;
    let (_, te) = harness::load_dataset(&cfg)?;
    let kernel = harness::make_kernel(model.kind, &cfg.backend, te.dim)?;
    let t0 = std::time::Instant::now();
    let acc = model.accuracy(&te, kernel.as_ref());
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "model {} ({} SVs): acc={:.2}% on {} ({} samples, {:.2} ms/sample)",
        model_path,
        model.num_svs(),
        100.0 * acc,
        cfg.dataset,
        te.len(),
        1e3 * dt / te.len().max(1) as f64
    );
    Ok(())
}

/// `dcsvm update` flag table — usage text, README rows, and the strict
/// parser all render from this one [`FlagSpec`] table (the serve-flag
/// convention, generalized by [`dcsvm::util::flags`]).
const UPDATE_FLAGS: &[FlagSpec] = &[
    FlagSpec {
        flag: "--model",
        value: "FILE",
        default: "required",
        help: "model JSON to update (train --save-model or a previous update)",
    },
    FlagSpec {
        flag: "--data",
        value: "FILE",
        default: "required",
        help: "new labeled rows, LIBSVM format (empty file = bit-identical no-op)",
    },
    FlagSpec {
        flag: "--out",
        value: "FILE",
        default: "--model (in place)",
        help: "where to write the updated model JSON",
    },
    FlagSpec {
        flag: "--c",
        value: "C",
        default: "1",
        help: "box constraint of the warm re-solve",
    },
    FlagSpec { flag: "--eps", value: "E", default: "1e-3", help: "KKT stopping tolerance" },
    FlagSpec {
        flag: "--max-iter",
        value: "N",
        default: "0 (unlimited)",
        help: "iteration cap of the warm re-solve",
    },
    FlagSpec {
        flag: "--cache-mb",
        value: "MB",
        default: "64",
        help: "kernel-row cache budget of the update solve",
    },
    FlagSpec {
        flag: "--backend",
        value: "KIND",
        default: "auto",
        help: "kernel backend: auto, native, or pjrt",
    },
    FlagSpec {
        flag: "--threads",
        value: "N",
        default: "all cores",
        help: "worker budget for kernel dispatches",
    },
    FlagSpec {
        flag: "--compare-cold",
        value: "FILE",
        default: "off",
        help: "also cold-retrain on FILE (cumulative LIBSVM data) and report its kernel-value count",
    },
];

/// The `dcsvm update` flag surface (usage text + strict parser).
const UPDATE_FLAG_SET: FlagSet =
    FlagSet { cmd: "update", required: "--model FILE --data FILE", flags: UPDATE_FLAGS };

/// Warm-started incremental model update (`dcsvm update`): load a trained
/// model JSON plus new labeled rows, re-solve over `SVs ∪ delta` seeded
/// from the model's α ([`dcsvm::dcsvm::update`]), and write the updated
/// model. Emits one JSON line with the update counters on stdout (the
/// bench-smoke CI leg parses it); human-readable notes go to stderr. An
/// empty delta copies the model file through byte-identically.
fn cmd_update(args: &[String]) -> Result<()> {
    use dcsvm::dcsvm::update::{cold_solve, update, UpdateConfig};

    let set = &UPDATE_FLAG_SET;
    let usage = set.usage();
    // Strict table-driven parse: unknown flags rejected before a value is
    // demanded, `--help` anywhere prints usage.
    let Some(pairs) = set.parse(args)? else {
        println!("{usage}");
        return Ok(());
    };
    let mut model_path: Option<String> = None;
    let mut data_path: Option<String> = None;
    let mut out_path: Option<String> = None;
    let mut c = 1.0f64;
    let mut eps = 1e-3f64;
    let mut max_iter = 0usize;
    let mut cache_mb = 64usize;
    let mut backend = "auto".to_string();
    let mut threads = 0usize;
    let mut cold_path: Option<String> = None;
    for (flag, val) in pairs {
        match flag {
            "--model" => model_path = Some(val.to_string()),
            "--data" => data_path = Some(val.to_string()),
            "--out" => out_path = Some(val.to_string()),
            "--c" => c = set.positive_f("--c", val)?,
            "--eps" => eps = set.positive_f("--eps", val)?,
            "--max-iter" => max_iter = set.count("--max-iter", val)?,
            "--cache-mb" => cache_mb = set.positive("--cache-mb", val)?,
            "--backend" => backend = val.to_string(),
            "--threads" => threads = set.count("--threads", val)?,
            "--compare-cold" => cold_path = Some(val.to_string()),
            _ => unreachable!("UPDATE_FLAGS covers every match arm"),
        }
    }
    let Some(model_path) = model_path else {
        bail!("update requires --model FILE\n{usage}");
    };
    let Some(data_path) = data_path else {
        bail!("update requires --data FILE\n{usage}");
    };
    let out_path = out_path.unwrap_or_else(|| model_path.clone());

    let text = std::fs::read_to_string(&model_path)
        .with_context(|| format!("read {model_path}"))?;
    let model = SvmModel::from_json(&Json::parse(&text)?)?;
    let file = std::fs::File::open(&data_path)
        .with_context(|| format!("read {data_path}"))?;
    let delta = dcsvm::data::libsvm::parse_libsvm(
        std::io::BufReader::new(file),
        Some(model.dim),
        format!("delta:{data_path}"),
    )?;
    let kernel = harness::make_kernel(model.kind, &backend, model.dim)?;
    let cfg = UpdateConfig { c, eps, max_iter, cache_bytes: cache_mb << 20, threads };
    eprintln!(
        "updating {model_path} ({} SVs, dim {}) with {} delta rows from {data_path}",
        model.num_svs(),
        model.dim,
        delta.len()
    );
    let res = update(&model, &delta, kernel.as_ref(), &cfg)?;

    // Persist. An empty delta is a bit-identical no-op: copy the input
    // file bytes through verbatim (a JSON re-serialization round-trip is
    // NOT guaranteed byte-stable).
    if res.noop {
        if out_path != model_path {
            std::fs::write(&out_path, &text)
                .with_context(|| format!("write {out_path}"))?;
        }
    } else {
        std::fs::write(&out_path, res.model.to_json().to_string())
            .with_context(|| format!("write {out_path}"))?;
    }

    let mut pairs = vec![
        ("algo", Json::from("update")),
        ("noop", Json::from(res.noop)),
        ("svs", Json::from(res.model.num_svs())),
        ("update_values_computed", Json::from(res.values_computed as f64)),
        ("svs_added", Json::from(res.svs_added as f64)),
        ("svs_dropped", Json::from(res.svs_dropped as f64)),
        ("margin_violations", Json::from(res.margin_violations as f64)),
        ("objective", Json::from(res.objective)),
        ("iterations", Json::from(res.iterations)),
        ("elapsed_s", Json::from(res.elapsed_s)),
        ("out", Json::from(out_path.as_str())),
    ];
    if let Some(cold_path) = &cold_path {
        let file = std::fs::File::open(cold_path)
            .with_context(|| format!("read {cold_path}"))?;
        let all = dcsvm::data::libsvm::parse_libsvm(
            std::io::BufReader::new(file),
            Some(model.dim),
            format!("cold:{cold_path}"),
        )?;
        let cold = cold_solve(&all, kernel.as_ref(), &cfg);
        eprintln!(
            "cold retrain on {} cumulative rows: {} kernel values (warm update: {})",
            all.len(),
            cold.values_computed,
            res.values_computed
        );
        pairs.push(("cold_values_computed", Json::from(cold.values_computed as f64)));
        pairs.push(("cold_objective", Json::from(cold.objective)));
        pairs.push((
            "warm_beats_cold",
            Json::from(res.values_computed < cold.values_computed),
        ));
    }
    println!("{}", Json::obj(pairs));

    // Thread the update counters into the structured results file when a
    // bench collects one (same env contract as harness::run).
    if let Ok(dir) = std::env::var("DCSVM_RESULTS_DIR") {
        if !dir.is_empty() {
            let (kname, gamma) = match model.kind {
                dcsvm::kernel::KernelKind::Rbf { gamma } => ("rbf", gamma as f64),
                dcsvm::kernel::KernelKind::Poly { gamma, .. } => ("poly", gamma as f64),
                dcsvm::kernel::KernelKind::Linear => ("linear", 0.0),
            };
            let rc = RunConfig {
                dataset: data_path.clone(),
                kernel: kname.to_string(),
                gamma,
                c,
                eps,
                cache_mb,
                backend: backend.clone(),
                threads,
                ..RunConfig::default()
            };
            let accuracy = if delta.is_empty() {
                0.0
            } else {
                res.model.accuracy(&delta, kernel.as_ref())
            };
            let outcome = harness::Outcome {
                algo: "update",
                train_s: res.elapsed_s,
                accuracy,
                objective: Some(res.objective),
                svs: res.model.num_svs(),
                cache_hit_rate: None,
                final_rows: None,
                segment_rows: None,
                divide_values: None,
                stitched_values: None,
                parallel_dispatches: None,
                stitch_groups: None,
                registry_bytes: None,
                simd_tier: dcsvm::kernel::simd_tier().name(),
                quantized_values: None,
                segment_regathers: None,
                update_values_computed: Some(res.values_computed),
                svs_added: Some(res.svs_added),
                svs_dropped: Some(res.svs_dropped),
                pair_dispatches: None,
                votes: None,
                note: format!("margin_violations={}", res.margin_violations),
                ..Default::default()
            };
            let _ = harness::record_result_to(
                std::path::Path::new(&dir),
                &rc,
                &outcome,
            );
        }
    }
    Ok(())
}

fn cmd_kmeans(args: &[String]) -> Result<()> {
    let cfg = parse_cfg(args)?;
    let (tr, _) = harness::load_dataset(&cfg)?;
    let kind = cfg.kernel_kind()?;
    let kernel = harness::make_kernel(kind, &cfg.backend, tr.dim)?;
    let k = cfg.k_base.max(2);
    let mut rng = Pcg64::new(cfg.seed);
    let ctx = dcsvm::cache::KernelContext::new(&tr, kernel.as_ref(), cfg.cache_mb << 20)
        .with_threads(cfg.threads);
    let t0 = std::time::Instant::now();
    let (_, part) =
        dcsvm::kmeans::two_step_partition(&ctx, k, cfg.sample_m, None, &mut rng);
    let dt = t0.elapsed().as_secs_f64();
    let sizes: Vec<usize> = part.members.iter().map(|m| m.len()).collect();
    println!(
        "two-step kernel kmeans: k={} m={} time={} sizes={:?}",
        part.k,
        cfg.sample_m,
        fmt_secs(dt),
        sizes
    );
    if tr.len() <= 4000 {
        let d = dcsvm::kmeans::off_diagonal_mass(&ctx, &part.assign);
        let rand_part = dcsvm::kmeans::Partition::random(tr.len(), part.k, &mut rng);
        let dr = dcsvm::kmeans::off_diagonal_mass(&ctx, &rand_part.assign);
        println!("D(π) kernel-kmeans = {d:.1}, random = {dr:.1} (lower is better)");
    }
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let cfg = parse_cfg(args)?;
    let (tr, te) = harness::load_dataset(&cfg)?;
    let cs = [2f64.powi(-6), 2f64.powi(1), 2f64.powi(6)];
    let gammas = [2f64.powi(-6), 2f64.powi(1), 2f64.powi(6)];
    let mut t = Table::new(&["C", "γ", "algo", "time", "acc%"]);
    let mut totals: std::collections::BTreeMap<&str, f64> = Default::default();
    for &c in &cs {
        for &g in &gammas {
            for algo in [Algo::DcSvmEarly, Algo::DcSvm, Algo::Libsvm] {
                let mut rc = cfg.clone();
                rc.algo = algo;
                rc.c = c;
                rc.gamma = g;
                let out = harness::run(&rc, &tr, &te)?;
                *totals.entry(out.algo).or_default() += out.train_s;
                t.row(&[
                    format!("2^{}", c.log2() as i32),
                    format!("2^{}", g.log2() as i32),
                    out.algo.to_string(),
                    fmt_secs(out.train_s),
                    format!("{:.2}", 100.0 * out.accuracy),
                ]);
            }
        }
    }
    t.print();
    println!("accumulated grid time (Table 5 style):");
    for (algo, total) in totals {
        println!("  {algo}: {}", fmt_secs(total));
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("dcsvm {}", env!("CARGO_PKG_VERSION"));
    match harness::global_engine() {
        Some(e) => {
            let abi = e.abi();
            println!(
                "PJRT backend: ACTIVE (d_pad={}, tiles slim={} wide={} x nd={})",
                abi.d_pad,
                abi.nq_slim,
                abi.nq_wide,
                abi.nd_blk
            );
            println!("artifact dir: {}", e.artifact_dir().display());
        }
        None => println!("PJRT backend: unavailable (run `make artifacts`); native fallback"),
    }
    println!("threads default: {}", dcsvm::util::threadpool::default_threads());
    Ok(())
}

/// Request loop over one persistent [`ServingContext`], behind two
/// transports sharing one request core
/// ([`dcsvm::serving::transport::ServeCore`]):
///
/// - **stdio** (default): LIBSVM rows on stdin, one `±1 decision` line per
///   row on stdout, one JSON stats line per request batch on stderr.
/// - **socket** (`--listen ADDR`): newline-delimited JSON over TCP (see
///   PROTOCOL.md) serving N concurrent connections — kernel rows computed
///   for one client warm the shared cache for every other client.
///
/// Flags, defaults, and the usage text all come from one table
/// ([`dcsvm::serving::transport::SERVE_FLAGS`]) shared with README.md, so
/// docs and CLI cannot drift (`tests/docs_sync.rs` enforces it).
fn cmd_serve(args: &[String]) -> Result<()> {
    use dcsvm::serving::transport::{self, ServeCore};

    let set = &transport::SERVE_FLAG_SET;
    let usage = transport::serve_usage();
    // Strict table-driven parse against SERVE_FLAGS: unknown flags are
    // rejected before a value is demanded, `--help` anywhere prints usage.
    let Some(pairs) = set.parse(args)? else {
        println!("{usage}");
        return Ok(());
    };
    let mut model_path: Option<String> = None;
    let mut listen: Option<String> = None;
    let mut batch = 256usize;
    let mut workers = dcsvm::util::threadpool::default_threads();
    let mut conns = 8usize;
    let mut cache_mb = 64usize;
    let mut backend = "auto".to_string();
    let mut quant_route = false;
    let mut allow_swap = false;
    let mut request_timeout: Option<f64> = None;
    for (flag, val) in pairs {
        match flag {
            "--model" => model_path = Some(val.to_string()),
            "--listen" => listen = Some(val.to_string()),
            "--batch" => batch = set.positive("--batch", val)?,
            "--workers" => workers = set.positive("--workers", val)?,
            "--conns" => conns = set.positive("--conns", val)?,
            "--cache-mb" => cache_mb = set.positive("--cache-mb", val)?,
            "--backend" => backend = val.to_string(),
            "--quant-route" => quant_route = set.boolean("--quant-route", val)?,
            "--allow-swap" => allow_swap = set.boolean("--allow-swap", val)?,
            "--request-timeout" => {
                request_timeout = Some(set.positive_f("--request-timeout", val)?)
            }
            _ => unreachable!("SERVE_FLAGS covers every match arm"),
        }
    }
    let Some(model_path) = model_path else {
        bail!("serve requires --model FILE\n{usage}");
    };
    let text = std::fs::read_to_string(&model_path)
        .with_context(|| format!("read {model_path}"))?;
    let mut model = ServingModel::from_json(&Json::parse(&text)?)?;
    model.set_quant_route(quant_route);
    let kernel = harness::make_kernel(model.kind(), &backend, model.dim())?;
    let ctx = ServingContext::new(model, kernel, cache_mb << 20);
    eprintln!(
        "serving {} model {} ({} SVs, dim {}), {workers} workers, cache {cache_mb} MB{}",
        ctx.model().describe(),
        model_path,
        ctx.num_svs(),
        ctx.dim(),
        if ctx.model().quant_route() { ", quantized routing" } else { "" }
    );
    let mut core = ServeCore::new(ctx, workers);
    if allow_swap {
        // Swapped-in models rebuild their kernel through the same backend
        // selection as the initial load (the factory keeps the serving
        // layer free of a harness dependency).
        let backend = backend.clone();
        let factory: transport::KernelFactory =
            Box::new(move |kind, dim| harness::make_kernel(kind, &backend, dim));
        core = core.with_swap(factory, cache_mb << 20);
        eprintln!("hot swap enabled: {{\"swap_model\": FILE}} requests accepted");
    }
    if let Some(secs) = request_timeout {
        core = core.with_request_timeout(std::time::Duration::from_secs_f64(secs));
        eprintln!("request timeout: idle connections closed after {secs}s");
    }
    match &listen {
        Some(addr) => {
            let listener = std::net::TcpListener::bind(addr.as_str())
                .with_context(|| format!("serve: bind {addr}"))?;
            // One parseable line announcing the bound address (binding
            // port 0 picks an ephemeral port; clients and tests discover
            // it from this line).
            eprintln!(
                "{}",
                Json::obj(vec![
                    ("listening", Json::from(listener.local_addr()?.to_string())),
                    ("conns", Json::from(conns)),
                ])
            );
            transport::run_listener(&core, listener, conns)?;
        }
        None => {
            eprintln!("stdio mode: LIBSVM rows on stdin, batch {batch}");
            transport::run_stdio(&core, batch)?;
        }
    }
    eprintln!("{}", core.summary_json());
    Ok(())
}
