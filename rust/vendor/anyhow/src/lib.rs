//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build container has no registry access, so the few external crates
//! this repository uses are vendored as API-compatible shims (same crate
//! name, path dependency). This one covers exactly the subset the codebase
//! consumes: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] macros, and
//! the [`Context`] extension trait for `Result` and `Option`.
//!
//! An [`Error`] is a chain of display strings, outermost context first.
//! Plain `{}` prints the outermost message; `{:#}` and `{:?}` print the
//! whole chain joined by `": "` — matching how the real crate is used in
//! `main.rs` (`error: {e:#}`).

use std::fmt;

/// A lightweight error: a chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => {
        $crate::Error::msg(::std::format!($($t)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn context_chains_and_formats() {
        let err = io_fail().unwrap_err();
        let plain = format!("{err}");
        let alt = format!("{err:#}");
        assert_eq!(plain, "reading config");
        assert!(alt.starts_with("reading config: "), "{alt}");
        assert!(alt.len() > plain.len());
    }

    #[test]
    fn macros_and_option_context() {
        fn inner(x: Option<u32>) -> Result<u32> {
            let v = x.context("missing value")?;
            if v == 0 {
                bail!("zero not allowed (got {v})");
            }
            Ok(v)
        }
        assert_eq!(inner(Some(3)).unwrap(), 3);
        assert_eq!(format!("{}", inner(None).unwrap_err()), "missing value");
        let e = inner(Some(0)).unwrap_err();
        assert_eq!(format!("{e}"), "zero not allowed (got 0)");
        let direct: Error = anyhow!("plain {}", 42);
        assert_eq!(format!("{direct}"), "plain 42");
    }
}
