//! Integration: all nine solvers through the run harness on one workload —
//! the Table-3 orderings the paper claims must hold in miniature.

use dcsvm::config::{Algo, RunConfig};
use dcsvm::harness;

fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.dataset = "covtype-like".into();
    cfg.n_train = Some(900);
    cfg.n_test = Some(300);
    cfg.gamma = 16.0;
    cfg.c = 4.0;
    cfg.levels = 2;
    cfg.sample_m = 96;
    cfg.budget = 48;
    cfg.backend = "native".into();
    cfg.eps = 1e-4;
    cfg.cache_mb = 4; // paper regime: cache holds a fraction of rows
    cfg
}

#[test]
fn table3_orderings_hold() {
    // All nine solvers at small scale: accuracy orderings only (wall-clock
    // orderings need realistic n and are asserted in the exact-family test
    // below + measured in the benches/EXPERIMENTS.md).
    let base = base_cfg();
    let (tr, te) = harness::load_dataset(&base).unwrap();
    let mut results = std::collections::BTreeMap::new();
    for algo in Algo::all() {
        let mut cfg = base.clone();
        cfg.algo = algo;
        let out = harness::run(&cfg, &tr, &te).unwrap();
        results.insert(out.algo, out);
    }

    let acc = |name: &str| results[name].accuracy;

    // exact solvers agree on accuracy (same optimum)
    assert!(
        (acc("DC-SVM") - acc("LIBSVM")).abs() < 0.03,
        "DC-SVM {} vs LIBSVM {}",
        acc("DC-SVM"),
        acc("LIBSVM")
    );
    // early accuracy near exact (paper: within ~1%)
    assert!(
        acc("DC-SVM (early)") > acc("LIBSVM") - 0.05,
        "early {} vs exact {}",
        acc("DC-SVM (early)"),
        acc("LIBSVM")
    );
    // every method learns something
    for (name, out) in &results {
        assert!(out.accuracy > 0.6, "{name}: acc {}", out.accuracy);
    }
}

#[test]
fn exact_family_time_ordering_at_scale() {
    // At a cache-constrained, larger n the paper's wall-clock ordering must
    // hold: early < libsvm and dcsvm within a small factor of libsvm.
    let mut base = base_cfg();
    base.n_train = Some(2200);
    base.n_test = Some(400);
    let (tr, te) = harness::load_dataset(&base).unwrap();
    let mut time = std::collections::BTreeMap::new();
    for algo in [Algo::DcSvmEarly, Algo::DcSvm, Algo::Libsvm] {
        let mut cfg = base.clone();
        cfg.algo = algo;
        let out = harness::run(&cfg, &tr, &te).unwrap();
        time.insert(out.algo, out.train_s);
    }
    assert!(
        time["DC-SVM (early)"] < time["LIBSVM"] * 1.2,
        "early {} vs LIBSVM {}",
        time["DC-SVM (early)"],
        time["LIBSVM"]
    );
    assert!(
        time["DC-SVM"] <= time["LIBSVM"] * 3.0,
        "DC-SVM {} vs LIBSVM {}",
        time["DC-SVM"],
        time["LIBSVM"]
    );
}

#[test]
fn approximate_solvers_below_exact_on_hard_data() {
    // covtype-like has a curved boundary: fixed-budget approximations
    // (Nyström/RFF/units/basis) should trail the exact solution — the
    // crossover the paper's Figure 3 shows.
    let mut base = base_cfg();
    base.budget = 16; // deliberately tight budget
    let (tr, te) = harness::load_dataset(&base).unwrap();
    let exact = {
        let mut cfg = base.clone();
        cfg.algo = Algo::Libsvm;
        harness::run(&cfg, &tr, &te).unwrap()
    };
    for algo in [Algo::Llsvm, Algo::Ltpu, Algo::Spsvm] {
        let mut cfg = base.clone();
        cfg.algo = algo;
        let out = harness::run(&cfg, &tr, &te).unwrap();
        assert!(
            out.accuracy < exact.accuracy + 0.01,
            "{}: {} not below exact {}",
            out.algo,
            out.accuracy,
            exact.accuracy
        );
    }
}

#[test]
fn polynomial_kernel_pipeline() {
    // Figure 4's setting: degree-3 polynomial kernel through the whole
    // DC-SVM pipeline vs the cold solver.
    let mut cfg = base_cfg();
    cfg.kernel = "poly".into();
    cfg.gamma = 1.0;
    cfg.eta = 0.0;
    cfg.c = 2.0;
    let (tr, te) = harness::load_dataset(&cfg).unwrap();

    cfg.algo = Algo::DcSvm;
    let dc = harness::run(&cfg, &tr, &te).unwrap();
    cfg.algo = Algo::Libsvm;
    let lib = harness::run(&cfg, &tr, &te).unwrap();

    let (a, b) = (dc.objective.unwrap(), lib.objective.unwrap());
    assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "poly: dc {a} lib {b}");
    assert!((dc.accuracy - lib.accuracy).abs() < 0.03);
}
