//! Integration: the PJRT-backed kernel must agree with the native backend
//! (which is itself verified against the scalar formulas and, through the
//! python tests, against the pure-jnp oracle). Skips gracefully when
//! `artifacts/` has not been built (`make artifacts`). The whole file is
//! gated on the real runtime (`pjrt` + `pjrt-xla`) — with either feature
//! missing the runtime is a stub that can never load artifacts.

#![cfg(all(feature = "pjrt", feature = "pjrt-xla"))]

use dcsvm::kernel::{native::NativeKernel, BlockKernel, KernelKind};
use dcsvm::runtime::{Engine, PjrtKernel};
use dcsvm::util::prng::Pcg64;

fn engine() -> Option<Engine> {
    // Tests run from the crate root, so ./artifacts is correct.
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(dir).expect("artifacts present but failed to load"))
}

fn rand_rows(rng: &mut Pcg64, n: usize, d: usize, scale: f32) -> (Vec<f32>, Vec<f32>) {
    let x: Vec<f32> = (0..n * d).map(|_| rng.next_gaussian() as f32 * scale).collect();
    let norms = x.chunks(d).map(|r| r.iter().map(|&v| v * v).sum()).collect();
    (x, norms)
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: len");
    for (i, (&u, &v)) in a.iter().zip(b).enumerate() {
        assert!(
            (u - v).abs() <= tol * (1.0 + v.abs()),
            "{what}[{i}]: pjrt={u} native={v}"
        );
    }
}

#[test]
fn pjrt_block_matches_native_across_shapes_and_kernels() {
    let Some(engine) = engine() else { return };
    let mut rng = Pcg64::new(42);
    // (nq, nd, dim) cases spanning slim/wide tiles, multi-tile columns,
    // ragged edges, and tiny requests.
    let cases = [
        (1usize, 1usize, 1usize),
        (3, 50, 10),
        (64, 1024, 128),   // exact slim tile
        (65, 1030, 54),    // just past tile edges
        (256, 2048, 128),  // exact wide tiles, 2 column blocks
        (300, 1500, 22),
    ];
    for kind in [
        KernelKind::Rbf { gamma: 0.7 },
        KernelKind::Poly { gamma: 0.05, eta: 0.5 },
        KernelKind::Linear,
    ] {
        let pjrt = PjrtKernel::new(&engine, kind);
        let native = NativeKernel::new(kind);
        for &(nq, nd, dim) in &cases {
            let (xq, qn) = rand_rows(&mut rng, nq, dim, 0.5);
            let (xd, dn) = rand_rows(&mut rng, nd, dim, 0.5);
            let mut got = vec![0f32; nq * nd];
            let mut want = vec![0f32; nq * nd];
            pjrt.block(&xq, &qn, &xd, &dn, dim, &mut got);
            native.block(&xq, &qn, &xd, &dn, dim, &mut want);
            assert_close(&got, &want, 2e-4, &format!("{kind:?} block {nq}x{nd}x{dim}"));
        }
    }
}

#[test]
fn pjrt_decision_matches_native() {
    let Some(engine) = engine() else { return };
    let mut rng = Pcg64::new(7);
    for kind in [
        KernelKind::Rbf { gamma: 1.3 },
        KernelKind::Poly { gamma: 0.1, eta: 0.0 },
    ] {
        let pjrt = PjrtKernel::new(&engine, kind);
        let native = NativeKernel::new(kind);
        for &(nq, nd, dim) in &[(5usize, 80usize, 16usize), (130, 1500, 54), (256, 1024, 128)] {
            let (xq, qn) = rand_rows(&mut rng, nq, dim, 0.4);
            let (xd, dn) = rand_rows(&mut rng, nd, dim, 0.4);
            let coef: Vec<f32> =
                (0..nd).map(|_| rng.next_gaussian() as f32).collect();
            let mut got = vec![0f32; nq];
            let mut want = vec![0f32; nq];
            pjrt.decision(&xq, &qn, &xd, &dn, dim, &coef, &mut got);
            native.decision(&xq, &qn, &xd, &dn, dim, &coef, &mut want);
            assert_close(&got, &want, 5e-4, &format!("{kind:?} decision {nq}x{nd}x{dim}"));
        }
    }
}

#[test]
fn pjrt_property_random_shapes() {
    let Some(engine) = engine() else { return };
    let kind = KernelKind::Rbf { gamma: 2.0 };
    let pjrt = PjrtKernel::new(&engine, kind);
    let native = NativeKernel::new(kind);
    let mut rng = Pcg64::new(1234);
    for case in 0..10 {
        let nq = 1 + rng.below(90);
        let nd = 1 + rng.below(700);
        let dim = 1 + rng.below(128);
        let (xq, qn) = rand_rows(&mut rng, nq, dim, 0.6);
        let (xd, dn) = rand_rows(&mut rng, nd, dim, 0.6);
        let mut got = vec![0f32; nq * nd];
        let mut want = vec![0f32; nq * nd];
        pjrt.block(&xq, &qn, &xd, &dn, dim, &mut got);
        native.block(&xq, &qn, &xd, &dn, dim, &mut want);
        assert_close(&got, &want, 2e-4, &format!("case {case}: {nq}x{nd}x{dim}"));
    }
}

#[test]
fn smo_solver_runs_on_pjrt_backend() {
    let Some(engine) = engine() else { return };
    use dcsvm::cache::KernelContext;
    use dcsvm::data::synthetic::{covtype_like, generate};
    use dcsvm::solver::{SmoConfig, SmoSolver};

    let mut rng = Pcg64::new(9);
    let ds = generate(&covtype_like(), 120, &mut rng);
    let kind = KernelKind::Rbf { gamma: 8.0 };
    let cfg = SmoConfig { c: 1.0, eps: 1e-6, ..Default::default() };

    let pjrt = PjrtKernel::new(&engine, kind);
    let pjrt_ctx = KernelContext::new(&ds, &pjrt, 64 << 20);
    let res_pjrt = SmoSolver::new(pjrt_ctx.view_full(), cfg.clone()).solve();

    let native = NativeKernel::new(kind);
    let native_ctx = KernelContext::new(&ds, &native, 64 << 20);
    let res_native = SmoSolver::new(native_ctx.view_full(), cfg).solve();

    let rel = (res_pjrt.objective - res_native.objective).abs()
        / (1.0 + res_native.objective.abs());
    assert!(rel < 1e-4, "pjrt {} vs native {}", res_pjrt.objective, res_native.objective);
    assert!(res_pjrt.final_violation < 1e-5);
}
